"""Fault-tolerant checkpointing: async save, atomic publish, elastic restore.

Design (what a 1000-node deployment needs, scaled to this container):

* **Atomicity** — a checkpoint is written into ``step_<N>.tmp`` and
  published with ``os.replace`` to ``step_<N>``; a crash mid-save can never
  corrupt the latest restorable state. A ``manifest.json`` inside the step
  dir carries step, flattened key paths, dtypes/shapes, and the data
  pipeline state, and is written last.
* **Async** — ``save`` snapshots to host memory synchronously (cheap)
  and performs file I/O on a background thread, overlapping with the next
  training step; ``wait`` joins before the next save or at exit.
* **Elastic resharding** — leaves are stored unsharded (np arrays); restore
  takes an optional sharding tree and ``jax.device_put``s each leaf to its
  (possibly different) mesh placement. A checkpoint saved on a 16x16 mesh
  restores on 2x16x16 or on 1 CPU device unchanged. On a real multi-host
  pod the same layout works with per-host shard files keyed by
  ``process_index`` — the manifest format already carries the tree.
* **Retention** — keeps the newest ``keep`` checkpoints, deleting older
  ones only after a successful publish.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import numpy as np

import jax


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, state: Any, extras: Optional[dict] = None,
             blocking: bool = False) -> None:
        """Snapshot now, write in background (unless blocking)."""
        self.wait()
        host_leaves, _ = _flatten_with_paths(jax.device_get(state))
        extras = dict(extras or {})

        def _write():
            try:
                tmp = os.path.join(self.dir, f"step_{step}.tmp")
                final = os.path.join(self.dir, f"step_{step}")
                if os.path.exists(tmp):
                    shutil.rmtree(tmp)
                os.makedirs(tmp)
                manifest = {"step": step, "extras": extras, "leaves": []}
                for i, (key, leaf) in enumerate(host_leaves):
                    arr = np.asarray(leaf)
                    np.save(os.path.join(tmp, f"leaf_{i}.npy"), arr)
                    manifest["leaves"].append(
                        {"key": key, "file": f"leaf_{i}.npy",
                         "shape": list(arr.shape), "dtype": str(arr.dtype)})
                with open(os.path.join(tmp, "manifest.json"), "w") as f:
                    json.dump(manifest, f)
                if os.path.exists(final):
                    shutil.rmtree(final)
                os.replace(tmp, final)
                self._gc()
            except BaseException as e:  # surfaced at next wait()
                self._error = e

        if blocking:
            _write()
            self._raise_pending()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_pending()

    def _raise_pending(self):
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError("async checkpoint save failed") from err

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, target_tree: Any, step: Optional[int] = None,
                shardings: Any = None) -> tuple[Any, dict]:
        """Restore into the structure of ``target_tree``.

        ``shardings``: optional matching tree of NamedSharding — each leaf is
        device_put to its target placement (elastic resharding).
        Returns (state, extras).
        """
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        cdir = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(cdir, "manifest.json")) as f:
            manifest = json.load(f)
        by_key = {e["key"]: e for e in manifest["leaves"]}

        flat, treedef = _flatten_with_paths(target_tree)
        shard_leaves = (jax.tree.leaves(shardings)
                        if shardings is not None else [None] * len(flat))
        leaves = []
        for (key, tgt), sh in zip(flat, shard_leaves):
            entry = by_key.get(key)
            if entry is None:
                raise KeyError(f"checkpoint {step} missing leaf {key!r}")
            arr = np.load(os.path.join(cdir, entry["file"]))
            if tuple(arr.shape) != tuple(np.shape(tgt)):
                raise ValueError(
                    f"leaf {key!r}: checkpoint shape {arr.shape} != "
                    f"target {np.shape(tgt)}")
            leaves.append(jax.device_put(arr, sh) if sh is not None
                          else jax.device_put(arr))
        _, target_def = jax.tree_util.tree_flatten(target_tree)
        state = jax.tree_util.tree_unflatten(target_def, leaves)
        return state, manifest.get("extras", {})
