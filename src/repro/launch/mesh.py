"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not module-level state) so importing
this module never touches jax device initialization. Smoke tests see 1 CPU
device; only the dry-run sets XLA_FLAGS to fabricate 512 host devices.
"""
from __future__ import annotations

from repro.parallel.jax_compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def mesh_desc(mesh) -> str:
    return "x".join(f"{n}={s}" for n, s in
                    zip(mesh.axis_names, mesh.devices.shape))


def n_chips(mesh) -> int:
    return mesh.devices.size
