import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
cell on the production mesh, prove it fits, and extract roofline terms.

MUST set XLA_FLAGS above before ANY other import — jax locks the device
count at first initialization. This is the only module that fabricates 512
host devices; smoke tests and benchmarks see the real single CPU device.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-32b \
      --shape train_4k [--multi-pod] [--rules baseline] [--out results/...]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""
import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import SHAPES, ArchConfig, ShapeConfig, get_arch, \
    list_archs
from repro.core.roofline import model_flops_estimate, report_from_hlo
from repro.data.specs import batch_specs
from repro.launch.mesh import make_production_mesh, mesh_desc, n_chips
from repro.parallel.jax_compat import set_mesh
from repro.models import model as M
from repro.models import registry
from repro.models.param import is_spec, tree_sds
from repro.optim.adamw import AdamWConfig
from repro.parallel.sharding import (RULE_VARIANTS, ShardingRules, act_pspec,
                                     param_pspec, use_rules)
from repro.train.steps import TrainState, make_prefill_step, \
    make_serve_step, make_train_step

# ---------------------------------------------------------------------------
# per-cell configuration (memory-driven; see EXPERIMENTS.md §Dry-run)
# ---------------------------------------------------------------------------

SERVE_DTYPE = jnp.bfloat16


# Microbatch counts size the per-layer scan residual (B_local x S x D bf16
# x n_layers must fit alongside params+moments in 16 GB HBM). Moment dtype
# bf16 where fp32 optimizer state alone would blow the budget.
_TRAIN_OVERRIDES = {
    "deepseek-v2-236b": dict(moment_dtype=jnp.bfloat16, microbatches=8,
                             accum_dtype=jnp.bfloat16),
    "qwen3-32b": dict(moment_dtype=jnp.float32, microbatches=8),
    "qwen2.5-14b": dict(moment_dtype=jnp.float32, microbatches=8),
    "qwen2.5-3b": dict(moment_dtype=jnp.float32, microbatches=4),
    "qwen1.5-4b": dict(moment_dtype=jnp.float32, microbatches=4),
    "hymba-1.5b": dict(moment_dtype=jnp.float32, microbatches=4),
    "hubert-xlarge": dict(moment_dtype=jnp.float32, microbatches=4),
    "mamba2-780m": dict(moment_dtype=jnp.float32, microbatches=4),
    "paligemma-3b": dict(moment_dtype=jnp.float32, microbatches=2),
    "granite-moe-1b-a400m": dict(moment_dtype=jnp.float32, microbatches=1),
}


def train_overrides(arch: str) -> dict:
    ov = dict(_TRAIN_OVERRIDES.get(
        arch, dict(moment_dtype=jnp.float32, microbatches=1)))
    ov.setdefault("remat", "full")
    ov.setdefault("accum_dtype", jnp.float32)
    return ov


def rules_for(cell_kind: str, rules_name: str) -> ShardingRules:
    if rules_name != "auto":
        return RULE_VARIANTS[rules_name]
    # decode cells shard the KV cache along kv_seq (flash-decoding);
    # train/prefill use the baseline FSDP x TP table
    return RULE_VARIANTS["kv_seq" if cell_kind == "decode"
                         else "baseline"]


# ---------------------------------------------------------------------------
# abstract inputs + shardings
# ---------------------------------------------------------------------------

BATCH_AXES = {
    "tokens": ("batch", "seq"),
    "labels": ("batch", "seq"),
    "frames": ("batch", "seq", None),
    "patches": ("batch", None, None),
    "cache_len": (),
}


def batch_pspecs(specs: dict, rules: ShardingRules, mesh) -> dict:
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = {}
    for k, s in specs.items():
        axes = BATCH_AXES[k]
        out[k] = act_pspec(rules, axes, s.shape, mesh_shape)
    return out


def cache_axes(cfg: ArchConfig, entry) -> tuple:
    """Logical axes for one layer-cache entry (pre-stacking)."""
    if cfg.family == "ssm":
        conv_axes = (("batch", None, "ssm_inner"),
                     ("batch", None, None), ("batch", None, None))
        return (conv_axes, ("batch", "heads", None, None))
    if cfg.family == "hybrid":
        kv = (("batch", "kv_seq", "kv_heads", None),) * 2
        conv_axes = (("batch", None, "ssm_inner"),
                     ("batch", None, None), ("batch", None, None))
        return (kv, (conv_axes, ("batch", "heads", None, None)))
    if cfg.mla:
        return (("batch", "kv_seq", None), ("batch", "kv_seq", None))
    return (("batch", "kv_seq", "kv_heads", None),) * 2


def cache_sds(cfg: ArchConfig, B: int, Smax: int, dtype):
    L = registry.n_scanned_layers(cfg)
    entry = M.layer_cache_struct(cfg, B, Smax, dtype)
    stacked = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((L, *s.shape), s.dtype), entry)
    out = {"layers": stacked}
    if cfg.moe and cfg.moe.first_dense_layers:
        out["dense0"] = M.mla_cache_struct(cfg, B, Smax, dtype)
    return out


def cache_pspecs(cfg: ArchConfig, B: int, Smax: int, rules: ShardingRules,
                 mesh, dtype):
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    entry_axes = cache_axes(cfg, None)
    entry = M.layer_cache_struct(cfg, B, Smax, dtype)

    def resolve(s, axes):
        return act_pspec(rules, (None, *axes), (0, *s.shape), mesh_shape)

    stacked = jax.tree.map(resolve, entry, entry_axes,
                           is_leaf=lambda x: isinstance(
                               x, jax.ShapeDtypeStruct))
    out = {"layers": stacked}
    if cfg.moe and cfg.moe.first_dense_layers:
        d0 = M.mla_cache_struct(cfg, B, Smax, dtype)
        d0_axes = (("batch", "kv_seq", None), ("batch", "kv_seq", None))
        out["dense0"] = jax.tree.map(
            lambda s, a: act_pspec(rules, a, s.shape, mesh_shape),
            d0, d0_axes,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    return out


def param_pspecs(cfg: ArchConfig, rules: ShardingRules, mesh):
    specs = registry.param_specs(cfg)
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    return jax.tree.map(
        lambda s: param_pspec(rules, s.axes, s.shape, mesh_shape),
        specs, is_leaf=is_spec)


def serve_param_sds(cfg: ArchConfig):
    specs = registry.param_specs(cfg)

    def cast(s):
        dt = SERVE_DTYPE if jnp.issubdtype(s.dtype, jnp.floating) \
            else s.dtype
        return jax.ShapeDtypeStruct(s.shape, dt)
    return jax.tree.map(cast, specs, is_leaf=is_spec)


# ---------------------------------------------------------------------------
# cell lowering
# ---------------------------------------------------------------------------

def lower_cell(cfg: ArchConfig, shape: ShapeConfig, mesh,
               rules: ShardingRules, *, grad_compression=None,
               remat_override=None, extra_note=""):
    """Returns (lowered, meta). Must be called inside jax.set_mesh(mesh)."""
    ov = train_overrides(cfg.name)
    if remat_override:
        ov["remat"] = remat_override
    kind = shape.kind
    note = extra_note

    if kind == "train":
        opt = AdamWConfig(moment_dtype=ov["moment_dtype"])
        step = make_train_step(cfg, opt, microbatches=ov["microbatches"],
                               remat=ov["remat"],
                               accum_dtype=ov["accum_dtype"],
                               grad_compression=grad_compression)
        p_ps = param_pspecs(cfg, rules, mesh)
        p_sds = tree_sds(registry.param_specs(cfg))
        mom = jax.tree.map(lambda s: jax.ShapeDtypeStruct(
            s.shape, ov["moment_dtype"]), p_sds)
        state_sds = TrainState(
            params=p_sds,
            opt_state={"m": mom, "v": mom,
                       "step": jax.ShapeDtypeStruct((), jnp.int32)},
            step=jax.ShapeDtypeStruct((), jnp.int32))
        state_ps = TrainState(
            params=p_ps,
            opt_state={"m": p_ps, "v": p_ps, "step": P()},
            step=P())
        b_sds = batch_specs(cfg, shape)
        b_ps = batch_pspecs(b_sds, rules, mesh)
        jitted = jax.jit(step, in_shardings=(state_ps, b_ps),
                         out_shardings=(state_ps, None),
                         donate_argnums=(0,))
        lowered = jitted.lower(state_sds, b_sds)
    elif kind == "prefill":
        if cfg.encoder_only:
            # encoder: inference forward (no cache/decode exists)
            def enc_step(params, batch):
                logits, _ = M.forward(params, batch, cfg, remat="none",
                                      dtype=SERVE_DTYPE)
                return logits
            p_ps = param_pspecs(cfg, rules, mesh)
            b_sds = batch_specs(cfg, shape)
            b_ps = batch_pspecs(b_sds, rules, mesh)
            jitted = jax.jit(enc_step, in_shardings=(p_ps, b_ps),
                             out_shardings=None)
            lowered = jitted.lower(serve_param_sds(cfg), b_sds)
            note += "encoder-only: prefill lowers the inference forward"
        else:
            step = make_prefill_step(cfg, remat="none", dtype=SERVE_DTYPE)
            p_ps = param_pspecs(cfg, rules, mesh)
            b_sds = batch_specs(cfg, shape)
            b_ps = batch_pspecs(b_sds, rules, mesh)
            jitted = jax.jit(step, in_shardings=(p_ps, b_ps),
                             out_shardings=None)
            lowered = jitted.lower(serve_param_sds(cfg), b_sds)
    else:  # decode
        B = shape.global_batch
        Smax = shape.seq_len
        step = make_serve_step(cfg, dtype=SERVE_DTYPE)
        p_ps = param_pspecs(cfg, rules, mesh)
        c_sds = cache_sds(cfg, B, Smax, SERVE_DTYPE)
        c_ps = cache_pspecs(cfg, B, Smax, rules, mesh, SERVE_DTYPE)
        b_sds = batch_specs(cfg, shape)
        b_ps = batch_pspecs(b_sds, rules, mesh)
        jitted = jax.jit(step, in_shardings=(p_ps, c_ps, b_ps),
                         out_shardings=(None, c_ps),
                         donate_argnums=(1,))
        lowered = jitted.lower(serve_param_sds(cfg), c_sds, b_sds)
    return lowered, {"note": note, "rules": rules.name}


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             rules_name: str = "auto", out_dir: str = "results/dryrun",
             grad_compression=None, remat_override=None,
             attention: str = "baseline", segments: bool = False,
             moe: str = "gspmd", tag: str = "") -> dict:
    from repro.models.blocks import MOE_SHARD_MAP
    from repro.models.common import ATTENTION_VARIANT
    from repro.models.model import STATIC_WINDOW_SEGMENTS
    ATTENTION_VARIANT["impl"] = attention
    STATIC_WINDOW_SEGMENTS["enabled"] = segments
    MOE_SHARD_MAP["enabled"] = moe == "shard_map"
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    support = cfg.supported_shapes()[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mdesc = mesh_desc(mesh)
    cell_id = f"{arch}-{shape_name}" + (f"-{tag}" if tag else "")
    result: dict = {"arch": arch, "shape": shape_name, "mesh": mdesc,
                    "chips": n_chips(mesh), "status": "ok", "tag": tag}
    if support != "ok":
        result["status"] = support
        _dump(result, out_dir, multi_pod, cell_id)
        print(f"[dryrun] {cell_id} on {mdesc}: {support}")
        return result

    rules = rules_for(shape.kind, rules_name)
    t0 = time.time()
    try:
        with set_mesh(mesh), use_rules(rules):
            lowered, meta = lower_cell(cfg, shape, mesh, rules,
                                       grad_compression=grad_compression,
                                       remat_override=remat_override)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            txt = compiled.as_text()
        per_dev_bytes = (mem.argument_size_in_bytes
                         + mem.output_size_in_bytes
                         + mem.temp_size_in_bytes
                         - mem.alias_size_in_bytes)
        rep = report_from_hlo(
            txt, arch=arch, shape=shape_name, mesh=mdesc,
            n_chips=n_chips(mesh),
            model_flops=model_flops_estimate(cfg, shape),
            bytes_per_device=per_dev_bytes,
            xla_cost_flops=float(cost.get("flops", 0.0)),
            notes=meta["note"])
        result.update(rep.to_json())
        result.update(
            rules=meta["rules"],
            lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
            memory_analysis={
                "argument_size_in_bytes": mem.argument_size_in_bytes,
                "output_size_in_bytes": mem.output_size_in_bytes,
                "temp_size_in_bytes": mem.temp_size_in_bytes,
                "alias_size_in_bytes": mem.alias_size_in_bytes,
                "generated_code_size_in_bytes":
                    mem.generated_code_size_in_bytes,
            },
            hbm_gb_per_device=round(per_dev_bytes / 2 ** 30, 3))
        print(f"[dryrun] {cell_id} on {mdesc}: OK "
              f"{per_dev_bytes / 2**30:.2f} GiB/dev, "
              f"compute {rep.compute_s*1e3:.1f} ms, "
              f"memory {rep.memory_s*1e3:.1f} ms, "
              f"collective {rep.collective_s*1e3:.1f} ms, "
              f"dominant={rep.dominant}, RF={rep.roofline_fraction:.2f} "
              f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)")
    except Exception as e:  # noqa
        result["status"] = f"FAIL: {type(e).__name__}: {e}"
        result["traceback"] = traceback.format_exc()[-4000:]
        print(f"[dryrun] {cell_id} on {mdesc}: FAILED {type(e).__name__}: "
              f"{str(e)[:200]}")
    _dump(result, out_dir, multi_pod, cell_id)
    return result


def _dump(result: dict, out_dir: str, multi_pod: bool, cell_id: str):
    d = os.path.join(out_dir, "multipod" if multi_pod else "singlepod")
    os.makedirs(d, exist_ok=True)
    with open(os.path.join(d, f"{cell_id}.json"), "w") as f:
        json.dump(result, f, indent=1, default=str)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=[*SHAPES, None])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--rules", default="auto")
    ap.add_argument("--grad-compression", default=None)
    ap.add_argument("--remat", default=None)
    ap.add_argument("--attention", default="baseline",
                    choices=["baseline", "triangle"])
    ap.add_argument("--segments", action="store_true",
                    help="static-window layer segments (hymba hillclimb)")
    ap.add_argument("--moe", default="gspmd",
                    choices=["gspmd", "shard_map"])
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args(argv)

    cells: list[tuple[str, str]] = []
    archs = [args.arch] if args.arch else list_archs()
    shapes = [args.shape] if args.shape else list(SHAPES)
    for a in archs:
        for s in shapes:
            cells.append((a, s))
    failures = 0
    for a, s in cells:
        r = run_cell(a, s, multi_pod=args.multi_pod, rules_name=args.rules,
                     out_dir=args.out,
                     grad_compression=args.grad_compression,
                     remat_override=args.remat, attention=args.attention,
                     segments=args.segments, moe=args.moe, tag=args.tag)
        if str(r.get("status", "")).startswith("FAIL"):
            failures += 1
    print(f"[dryrun] done: {len(cells)} cells, {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
