"""Batched serving driver: prefill + decode with a KV/state cache.

Serves a reduced-config model on CPU end-to-end (examples/serve_batched.py
drives it); the same step functions lower on the production meshes in the
dry-run. Continuous-batching style: a request joins at the next decode
step boundary; all requests share one cache of max_seq slots.

``--arrivals`` switches to arrival-driven serving: a seeded request
trace from the fleet plane's generators (``repro.core.fleet`` — the
same Poisson/diurnal/bursty processes that drive the 4k-chip
simulator) feeds the server epoch by epoch, requests joining at the
next epoch boundary and queuing until a full batch forms — the fleet
simulator's binning rule exercised at single-server scale.
"""
from __future__ import annotations

import argparse
import math
import signal
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ShapeConfig, get_arch
from repro.models import model as M
from repro.models import registry
from repro.models.param import init_params
from repro.parallel.sharding import BASELINE, use_rules
from repro.train.steps import make_prefill_step, make_serve_step


class Server:
    def __init__(self, arch: str, *, reduced: bool = True,
                 batch: int = 4, max_seq: int = 128, seed: int = 0):
        base = get_arch(arch)
        self.cfg = base.reduced() if reduced else base
        if self.cfg.encoder_only:
            raise ValueError("encoder-only arch has no decode step")
        self.batch = batch
        self.max_seq = max_seq
        self.params = init_params(registry.param_specs(self.cfg),
                                  jax.random.PRNGKey(seed))
        self.prefill = jax.jit(make_prefill_step(self.cfg, remat="none"))
        self.decode = jax.jit(make_serve_step(self.cfg))
        self.cache = None
        self.cache_len = 0

    def prefill_prompts(self, prompts: np.ndarray):
        """prompts: (B, S0) int32. Builds the shared cache."""
        B, S0 = prompts.shape
        assert B == self.batch
        batch = {"tokens": jnp.asarray(prompts, jnp.int32)}
        if self.cfg.frontend == "vision":
            batch["patches"] = jnp.zeros(
                (B, self.cfg.frontend_seq, self.cfg.frontend_dim),
                jnp.bfloat16)
            S0 = S0 + self.cfg.frontend_seq
        logits, cache = self.prefill(self.params, batch)
        # graft the prefill cache into a max_seq-slot decode cache
        full = M.init_cache(self.cfg, B, self.max_seq)
        def graft(dst, src):
            if dst.shape == src.shape:  # states (ssm/conv) — same shape
                return src.astype(dst.dtype)
            # KV-like: copy the first S0 slots along the seq axis (axis 2
            # for stacked (L, B, S, ...) arrays)
            return jax.lax.dynamic_update_slice_in_dim(
                dst, src.astype(dst.dtype), 0, axis=2)
        self.cache = jax.tree.map(graft, full, cache)
        self.cache_len = S0
        return np.asarray(jnp.argmax(logits[:, -1], axis=-1))

    def step(self, tokens: np.ndarray) -> np.ndarray:
        """tokens: (B,) int32 — the previous step's outputs."""
        batch = {"tokens": jnp.asarray(tokens, jnp.int32)[:, None],
                 "cache_len": jnp.asarray(self.cache_len, jnp.int32)}
        logits, self.cache = self.decode(self.params, self.cache, batch)
        self.cache_len += 1
        return np.asarray(jnp.argmax(logits[:, -1], axis=-1))

    def generate(self, prompts: np.ndarray, n_tokens: int) -> np.ndarray:
        out = [self.prefill_prompts(prompts)]
        for _ in range(n_tokens - 1):
            out.append(self.step(out[-1]))
        return np.stack(out, axis=1)  # (B, n_tokens)


def serve_arrivals(srv: Server, spec, *, duration_s: float,
                   epoch_s: float, prompt_len: int, n_tokens: int,
                   seed: int = 0, checkpoint: str | None = None) \
        -> list[dict]:
    """Serve a seeded arrival trace with epoch-boundary batching.

    ``spec`` is a ``repro.core.fleet.ArrivalSpec``; its per-epoch
    request counts (fixed-draw-count generators, deterministic under
    ``seed``) land on the queue at each epoch boundary, and the server
    drains the queue in full ``srv.batch``-sized waves — the remainder
    carries to the next epoch, exactly how the fleet simulator bins
    requests into epochs. Returns one stats dict per epoch.

    SIGTERM/SIGINT are handled guard-plane style (ISSUE 9): instead of
    dying mid-epoch, the in-flight wave finishes, the current epoch's
    stats are recorded (flagged ``"drained": True``), and the final
    report is emitted to the caller — plus, when ``checkpoint`` names
    a path, an atomic JSON report (``guard.atomic_write_json``) with
    the per-epoch stats and the interrupting signal, so an operator
    preempting the server still gets a crash-consistent record. The
    previous signal handlers are restored on exit either way.
    """
    from repro.core.fleet import arrival_counts
    from repro.core.guard import atomic_write_json
    n_epochs = max(1, int(math.ceil(duration_s / epoch_s)))
    rng = np.random.default_rng(seed)
    counts = arrival_counts(spec, n_epochs, epoch_s, rng)
    queue = 0
    stats: list[dict] = []
    stop: dict = {"signum": None}

    def _handler(signum, frame):
        stop["signum"] = signum

    prev = {s: signal.signal(s, _handler)
            for s in (signal.SIGTERM, signal.SIGINT)}
    try:
        for e in range(n_epochs):
            queue += int(counts[e])
            served = 0
            t0 = time.time()
            while queue >= srv.batch and stop["signum"] is None:
                prompts = rng.integers(0, srv.cfg.vocab_size,
                                       (srv.batch, prompt_len),
                                       dtype=np.int32)
                srv.generate(prompts, n_tokens)
                queue -= srv.batch
                served += srv.batch
            rec = {"epoch": e, "arrived": int(counts[e]),
                   "served": served, "queued": queue,
                   "wall_s": time.time() - t0}
            if stop["signum"] is not None:
                rec["drained"] = True
                stats.append(rec)
                break
            stats.append(rec)
    finally:
        for s, h in prev.items():
            signal.signal(s, h)
        if checkpoint is not None:
            sig = stop["signum"]
            atomic_write_json(checkpoint, {
                "epochs": stats,
                "served_total": sum(s["served"] for s in stats),
                "interrupted": (signal.Signals(sig).name
                                if sig is not None else None)})
    return stats


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=8)
    ap.add_argument("--arrivals", choices=("poisson", "diurnal",
                                           "bursty"), default=None,
                    help="serve a seeded arrival trace (fleet-plane "
                         "generators) instead of one fixed batch")
    ap.add_argument("--rate", type=float, default=1.0,
                    help="mean arrival rate, requests/s")
    ap.add_argument("--duration", type=float, default=30.0,
                    help="arrival-trace window, seconds")
    ap.add_argument("--epoch", type=float, default=5.0,
                    help="batching epoch length, seconds")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--checkpoint", default=None,
                    help="write the arrival-mode final report to this "
                         "path (atomic JSON; also written when a "
                         "SIGTERM/SIGINT drain ends the run early)")
    args = ap.parse_args(argv)
    with use_rules(BASELINE):
        srv = Server(args.arch, batch=args.batch,
                     max_seq=args.prompt_len + args.tokens + 8)
        if args.arrivals:
            from repro.core.fleet import ArrivalSpec
            spec = ArrivalSpec(args.arrivals, rate_rps=args.rate,
                               period_s=args.duration)
            stats = serve_arrivals(srv, spec, duration_s=args.duration,
                                   epoch_s=args.epoch,
                                   prompt_len=args.prompt_len,
                                   n_tokens=args.tokens, seed=args.seed,
                                   checkpoint=args.checkpoint)
            for s in stats:
                drain = " [drained]" if s.get("drained") else ""
                print(f"[serve] epoch {s['epoch']}: arrived "
                      f"{s['arrived']}, served {s['served']}, queued "
                      f"{s['queued']} ({s['wall_s']:.2f}s){drain}")
            tot = sum(s["served"] for s in stats)
            print(f"[serve] {tot} requests served over "
                  f"{len(stats)} epochs")
            return
        rng = np.random.default_rng(args.seed)
        prompts = rng.integers(0, srv.cfg.vocab_size,
                               (args.batch, args.prompt_len), dtype=np.int32)
        t0 = time.time()
        toks = srv.generate(prompts, args.tokens)
        dt = time.time() - t0
        print(f"[serve] {args.batch} requests x {args.tokens} tokens in "
              f"{dt:.2f}s ({args.batch*args.tokens/dt:.1f} tok/s)")
        print("[serve] outputs:", toks[:, :8].tolist())


if __name__ == "__main__":
    main()
