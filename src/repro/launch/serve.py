"""Batched serving driver: prefill + decode with a KV/state cache.

Serves a reduced-config model on CPU end-to-end (examples/serve_batched.py
drives it); the same step functions lower on the production meshes in the
dry-run. Continuous-batching style: a request joins at the next decode
step boundary; all requests share one cache of max_seq slots.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ShapeConfig, get_arch
from repro.models import model as M
from repro.models import registry
from repro.models.param import init_params
from repro.parallel.sharding import BASELINE, use_rules
from repro.train.steps import make_prefill_step, make_serve_step


class Server:
    def __init__(self, arch: str, *, reduced: bool = True,
                 batch: int = 4, max_seq: int = 128, seed: int = 0):
        base = get_arch(arch)
        self.cfg = base.reduced() if reduced else base
        if self.cfg.encoder_only:
            raise ValueError("encoder-only arch has no decode step")
        self.batch = batch
        self.max_seq = max_seq
        self.params = init_params(registry.param_specs(self.cfg),
                                  jax.random.PRNGKey(seed))
        self.prefill = jax.jit(make_prefill_step(self.cfg, remat="none"))
        self.decode = jax.jit(make_serve_step(self.cfg))
        self.cache = None
        self.cache_len = 0

    def prefill_prompts(self, prompts: np.ndarray):
        """prompts: (B, S0) int32. Builds the shared cache."""
        B, S0 = prompts.shape
        assert B == self.batch
        batch = {"tokens": jnp.asarray(prompts, jnp.int32)}
        if self.cfg.frontend == "vision":
            batch["patches"] = jnp.zeros(
                (B, self.cfg.frontend_seq, self.cfg.frontend_dim),
                jnp.bfloat16)
            S0 = S0 + self.cfg.frontend_seq
        logits, cache = self.prefill(self.params, batch)
        # graft the prefill cache into a max_seq-slot decode cache
        full = M.init_cache(self.cfg, B, self.max_seq)
        def graft(dst, src):
            if dst.shape == src.shape:  # states (ssm/conv) — same shape
                return src.astype(dst.dtype)
            # KV-like: copy the first S0 slots along the seq axis (axis 2
            # for stacked (L, B, S, ...) arrays)
            return jax.lax.dynamic_update_slice_in_dim(
                dst, src.astype(dst.dtype), 0, axis=2)
        self.cache = jax.tree.map(graft, full, cache)
        self.cache_len = S0
        return np.asarray(jnp.argmax(logits[:, -1], axis=-1))

    def step(self, tokens: np.ndarray) -> np.ndarray:
        """tokens: (B,) int32 — the previous step's outputs."""
        batch = {"tokens": jnp.asarray(tokens, jnp.int32)[:, None],
                 "cache_len": jnp.asarray(self.cache_len, jnp.int32)}
        logits, self.cache = self.decode(self.params, self.cache, batch)
        self.cache_len += 1
        return np.asarray(jnp.argmax(logits[:, -1], axis=-1))

    def generate(self, prompts: np.ndarray, n_tokens: int) -> np.ndarray:
        out = [self.prefill_prompts(prompts)]
        for _ in range(n_tokens - 1):
            out.append(self.step(out[-1]))
        return np.stack(out, axis=1)  # (B, n_tokens)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=8)
    args = ap.parse_args(argv)
    with use_rules(BASELINE):
        srv = Server(args.arch, batch=args.batch,
                     max_seq=args.prompt_len + args.tokens + 8)
        rng = np.random.default_rng(0)
        prompts = rng.integers(0, srv.cfg.vocab_size,
                               (args.batch, args.prompt_len), dtype=np.int32)
        t0 = time.time()
        toks = srv.generate(prompts, args.tokens)
        dt = time.time() - t0
        print(f"[serve] {args.batch} requests x {args.tokens} tokens in "
              f"{dt:.2f}s ({args.batch*args.tokens/dt:.1f} tok/s)")
        print("[serve] outputs:", toks[:, :8].tolist())


if __name__ == "__main__":
    main()
