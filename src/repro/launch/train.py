"""Fault-tolerant training driver.

Production loop structure, exercised end-to-end on CPU with reduced
configs (examples/train_e2e.py) and designed for the 256/512-chip meshes:

* **Checkpoint/restart** — async CheckpointManager with atomic publish;
  on start, resumes from the latest step (data pipeline state rides in the
  manifest, so the token stream continues bit-exactly).
* **Elastic resharding** — restore maps every leaf onto the CURRENT mesh's
  NamedShardings; a checkpoint taken on mesh A restores on mesh B.
* **Straggler mitigation** — per-step wall-time EWMA; a step slower than
  ``straggler_factor`` x EWMA is logged and counted (on a real pod this
  feeds the reschedule/deadline logic; here it drives the log + metrics).
* **Failure injection** — ``--fail-at-step N`` raises mid-run; rerunning
  the same command resumes from the last checkpoint (tests do exactly
  this), proving the restart path.

Run (CPU, reduced config):
  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b \
      --reduced --steps 20 --ckpt-dir /tmp/ckpt --checkpoint-every 5
"""
from __future__ import annotations

import argparse
import json
import os
import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import SHAPES, ShapeConfig, get_arch
from repro.data.pipeline import SyntheticDataset
from repro.models import registry
from repro.models.param import init_params
from repro.optim.adamw import AdamWConfig
from repro.parallel.sharding import BASELINE, RULE_VARIANTS, use_rules
from repro.train.steps import TrainState, make_train_step


@dataclass
class TrainLoopConfig:
    arch: str = "qwen2.5-3b"
    reduced: bool = True
    steps: int = 20
    seq_len: int = 64
    global_batch: int = 8
    microbatches: int = 1
    ckpt_dir: str = ""
    checkpoint_every: int = 10
    keep: int = 3
    seed: int = 0
    lr: float = 3e-4
    straggler_factor: float = 2.0
    fail_at_step: int = -1
    grad_compression: str | None = None
    rules: str = "baseline"
    log_every: int = 1


def run(cfg_loop: TrainLoopConfig) -> dict:
    arch = get_arch(cfg_loop.arch)
    cfg = arch.reduced() if cfg_loop.reduced else arch
    shape = ShapeConfig("train_custom", cfg_loop.seq_len,
                        cfg_loop.global_batch, "train")
    opt = AdamWConfig(lr_peak=cfg_loop.lr, warmup_steps=2,
                      total_steps=max(10, cfg_loop.steps))
    rules = RULE_VARIANTS[cfg_loop.rules]
    data = SyntheticDataset(cfg, shape, seed=cfg_loop.seed)
    step_fn = make_train_step(
        cfg, opt, microbatches=cfg_loop.microbatches,
        grad_compression=cfg_loop.grad_compression)

    ckpt = CheckpointManager(cfg_loop.ckpt_dir, keep=cfg_loop.keep) \
        if cfg_loop.ckpt_dir else None

    with use_rules(rules):
        params = init_params(registry.param_specs(cfg),
                             jax.random.PRNGKey(cfg_loop.seed))
        state = TrainState.create(
            params, opt, grad_compression=cfg_loop.grad_compression)
        start_step = 0
        if ckpt is not None and ckpt.latest_step() is not None:
            state, extras = ckpt.restore(state)
            start_step = int(extras.get("data_state", {}).get("step", 0))
            print(f"[train] resumed from checkpoint step {start_step}")
        jstep = jax.jit(step_fn, donate_argnums=(0,))

        ewma = None
        stragglers = 0
        losses = []
        for step in range(start_step, cfg_loop.steps):
            if step == cfg_loop.fail_at_step:
                raise RuntimeError(
                    f"[train] injected failure at step {step}")
            t0 = time.time()
            batch = data.batch(step)
            state, metrics = jstep(state, batch)
            loss = float(metrics["loss"])
            dt = time.time() - t0
            if ewma is None:
                ewma = dt
            if dt > cfg_loop.straggler_factor * ewma and step > start_step:
                stragglers += 1
                print(f"[train] step {step}: STRAGGLER {dt:.3f}s "
                      f"(ewma {ewma:.3f}s) — deterministic batch would be "
                      f"re-issued on a spare")
            ewma = 0.9 * ewma + 0.1 * dt
            losses.append(loss)
            if step % cfg_loop.log_every == 0:
                print(f"[train] step {step}: loss={loss:.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f} "
                      f"lr={float(metrics['lr']):.2e} {dt*1e3:.0f}ms")
            if (ckpt is not None and cfg_loop.checkpoint_every > 0
                    and (step + 1) % cfg_loop.checkpoint_every == 0):
                ckpt.save(step + 1, state,
                          extras={"data_state": data.state(step + 1),
                                  "arch": cfg.name})
        if ckpt is not None:
            ckpt.save(cfg_loop.steps, state,
                      extras={"data_state": data.state(cfg_loop.steps),
                              "arch": cfg.name}, blocking=True)
    return {"losses": losses, "stragglers": stragglers,
            "final_loss": losses[-1] if losses else None}


def main(argv=None):
    ap = argparse.ArgumentParser()
    for f in ("arch", "ckpt_dir", "grad_compression", "rules"):
        ap.add_argument(f"--{f.replace('_', '-')}", default=None)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    for f in ("steps", "seq_len", "global_batch", "microbatches",
              "checkpoint_every", "seed", "fail_at_step"):
        ap.add_argument(f"--{f.replace('_', '-')}", type=int, default=None)
    args = ap.parse_args(argv)
    cfg = TrainLoopConfig()
    for k, v in vars(args).items():
        if v is not None:
            setattr(cfg, k, v)
    out = run(cfg)
    print(f"[train] done: final_loss={out['final_loss']:.4f} "
          f"stragglers={out['stragglers']}")


if __name__ == "__main__":
    main()
