from repro.data.pipeline import SyntheticDataset
from repro.data.specs import batch_specs, make_batch

__all__ = ["SyntheticDataset", "batch_specs", "make_batch"]
