"""Deterministic, resumable synthetic token pipeline.

Every batch is a pure function of (seed, step), so preemption-safe resume
needs only the integer step from the checkpoint manifest — the property the
paper's §4.3 static-graph argument relies on (deterministic programs), and
the property our straggler re-issue logic needs (a re-issued batch is
bit-identical).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.data.specs import batch_specs


@dataclass
class SyntheticDataset:
    cfg: ArchConfig
    shape: ShapeConfig
    seed: int = 0
    batch_override: int | None = None

    def batch(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        specs = batch_specs(self.cfg, self.shape, self.batch_override)
        out = {}
        # generate tokens FIRST so labels can be their shift
        order = sorted(specs, key=lambda k: (k != "tokens", k))
        tokens = None
        for name in order:
            s = specs[name]
            if name == "cache_len":
                out[name] = jnp.asarray(self.shape.seq_len // 2, jnp.int32)
            elif name == "tokens":
                tokens = rng.integers(0, self.cfg.vocab_size, size=s.shape,
                                      dtype=np.int32)
                out[name] = jnp.asarray(tokens)
            elif name == "labels":
                if tokens is not None and tokens.shape == s.shape:
                    lbl = np.roll(tokens, -1, axis=-1)
                    lbl[..., -1] = 0
                else:
                    lbl = rng.integers(0, self.cfg.vocab_size, size=s.shape,
                                       dtype=np.int32)
                out[name] = jnp.asarray(lbl)
            else:
                out[name] = jnp.asarray(
                    rng.standard_normal(size=s.shape).astype(np.float32),
                    dtype=s.dtype)
        return out

    def state(self, step: int) -> dict:
        return {"seed": self.seed, "step": step}
