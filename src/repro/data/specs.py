"""Input specifications per (architecture x shape) cell.

``batch_specs`` returns ``jax.ShapeDtypeStruct`` stand-ins for every model
input — weak-type-correct, shardable, never allocated — used by the dry-run.
``make_batch`` materializes the same structure with deterministic synthetic
data for smoke tests and real training.

Modality frontends are STUBS per the assignment: audio cells feed
precomputed frame embeddings, VLM cells feed precomputed patch embeddings.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig


def batch_specs(cfg: ArchConfig, shape: ShapeConfig,
                batch_override: int | None = None) -> dict:
    """Abstract input tree for train/prefill cells (decode handled in
    launch.dryrun with the cache struct)."""
    B = batch_override if batch_override is not None else shape.global_batch
    S = shape.seq_len
    i32 = jnp.int32
    if shape.kind == "decode":
        return {"tokens": jax.ShapeDtypeStruct((B, 1), i32),
                "cache_len": jax.ShapeDtypeStruct((), i32)}
    if cfg.frontend == "audio":
        out = {"frames": jax.ShapeDtypeStruct((B, S, cfg.frontend_dim),
                                              jnp.bfloat16)}
        if shape.kind == "train":
            out["labels"] = jax.ShapeDtypeStruct((B, S), i32)
        return out
    if cfg.frontend == "vision":
        text = S - cfg.frontend_seq
        out = {
            "patches": jax.ShapeDtypeStruct(
                (B, cfg.frontend_seq, cfg.frontend_dim), jnp.bfloat16),
            "tokens": jax.ShapeDtypeStruct((B, text), i32),
        }
        if shape.kind == "train":
            out["labels"] = jax.ShapeDtypeStruct((B, text), i32)
        return out
    out = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
    if shape.kind == "train":
        out["labels"] = jax.ShapeDtypeStruct((B, S), i32)
    return out


def make_batch(cfg: ArchConfig, shape: ShapeConfig, *, seed: int = 0,
               batch_override: int | None = None) -> dict:
    """Concrete synthetic batch matching ``batch_specs`` (numpy -> jnp)."""
    rng = np.random.default_rng(seed)
    specs = batch_specs(cfg, shape, batch_override)
    out = {}
    for name, s in specs.items():
        if name == "cache_len":
            out[name] = jnp.asarray(shape.seq_len // 2, jnp.int32)
        elif s.dtype == jnp.int32:
            hi = cfg.vocab_size if name in ("tokens", "labels") else 2
            out[name] = jnp.asarray(
                rng.integers(0, hi, size=s.shape, dtype=np.int32))
        else:
            out[name] = jnp.asarray(
                rng.standard_normal(size=s.shape).astype(np.float32),
                dtype=s.dtype)
    return out
