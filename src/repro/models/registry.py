"""Per-architecture parameter trees + layer metadata.

``param_specs(cfg)`` returns the full abstract parameter tree (ParamSpec
leaves). ``count_params`` sums it analytically; ``active_only=True`` counts
only the parameters touched per token (MoE: top_k + shared experts).
"""
from __future__ import annotations

import math
from typing import Any

import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import blocks
from repro.models.param import ParamSpec, is_spec, spec, stack_specs
import jax


def layer_specs(cfg: ArchConfig) -> dict:
    """ParamSpec tree for one (scanned) layer."""
    fam = cfg.family
    if fam == "ssm":
        return {"ssd": blocks.ssd_specs(cfg)}
    if fam == "hybrid":
        return {"mix": blocks.hybrid_specs(cfg),
                "mlp": blocks.mlp_specs(cfg)}
    if fam == "moe":
        attn = (blocks.mla_specs(cfg) if cfg.mla else blocks.attn_specs(cfg))
        return {"attn": attn, "moe": blocks.moe_specs(cfg)}
    # dense / audio / vlm
    return {"attn": blocks.attn_specs(cfg), "mlp": blocks.mlp_specs(cfg)}


def dense0_specs(cfg: ArchConfig) -> dict:
    """DeepSeek-style leading dense layer(s) (MLA attn + wide dense MLP)."""
    return {"attn": blocks.mla_specs(cfg),
            "mlp": blocks.mlp_specs(cfg, d_ff=cfg.d_ff)}


def n_scanned_layers(cfg: ArchConfig) -> int:
    lead = cfg.moe.first_dense_layers if cfg.moe else 0
    return cfg.n_layers - lead


def param_specs(cfg: ArchConfig) -> dict:
    D, Vp = cfg.d_model, cfg.vocab_padded
    tree: dict[str, Any] = {
        "embed": spec((Vp, D), ("vocab", "embed"), init_scale=1.0),
        "final_norm": spec((D,), ("embed",), init="ones"),
    }
    if cfg.frontend:
        tree["frontend_proj"] = spec((cfg.frontend_dim, D),
                                     ("frontend", "embed"))
    if not cfg.tie_embeddings:
        tree["lm_head"] = spec((D, Vp), ("embed", "vocab"))
    if cfg.moe and cfg.moe.first_dense_layers:
        tree["dense0"] = dense0_specs(cfg)
    tree["layers"] = stack_specs(n_scanned_layers(cfg), layer_specs(cfg))
    return tree


def count_params(cfg: ArchConfig, active_only: bool = False) -> int:
    """Analytic parameter count from the spec tree (exact for our impl)."""
    tree = param_specs(cfg)
    total = 0
    for leaf in jax.tree.leaves(tree, is_leaf=is_spec):
        total += math.prod(leaf.shape)
    if active_only and cfg.moe:
        # replace the full expert bank contribution with top_k experts
        mo = cfg.moe
        L = n_scanned_layers(cfg)
        per_expert = 3 * cfg.d_model * mo.d_ff_expert
        total -= L * mo.n_experts * per_expert
        total += L * mo.top_k * per_expert
    return total


def global_layer_indices(cfg: ArchConfig) -> list[int]:
    """hymba: full-attention layers are first / middle / last."""
    if cfg.n_global_layers <= 0:
        return []
    L = cfg.n_layers
    if cfg.n_global_layers >= L:
        return list(range(L))
    if cfg.n_global_layers == 1:
        return [0]
    step = (L - 1) / (cfg.n_global_layers - 1)
    return sorted({int(round(i * step)) for i in range(cfg.n_global_layers)})


def window_array(cfg: ArchConfig, seq_hint: int):
    """(L,) int32 per-layer attention window (>=seq => effectively global).

    None if the arch has no sliding-window mixing (static full attention).
    """
    if cfg.sliding_window <= 0:
        return None
    glob = set(global_layer_indices(cfg))
    big = seq_hint + cfg.sliding_window + 1
    vals = [big if i in glob else cfg.sliding_window
            for i in range(cfg.n_layers)]
    return jnp.asarray(vals, jnp.int32)
