"""Parameter specification machinery.

Models declare an *abstract* parameter tree of ``ParamSpec`` (shape, dtype,
logical axes, initializer). From it we derive:

* ``jax.ShapeDtypeStruct`` trees for the dry-run (no allocation — the full
  236B-parameter configs are only ever lowered, never materialized);
* ``NamedSharding`` trees via the logical-axis rules in ``repro.parallel``;
* materialized parameter trees for the smoke tests / real training.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[Optional[str], ...]  # logical axis names, len == len(shape)
    dtype: Any = jnp.float32
    init: str = "normal"  # normal | zeros | ones | small_normal | custom
    init_scale: float = 1.0
    custom_init: Optional[Callable[[jax.Array, tuple, Any], jax.Array]] = None

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)

    @property
    def sds(self) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.shape, self.dtype)


def spec(shape, axes, init="normal", init_scale=1.0, dtype=jnp.float32,
         custom_init=None) -> ParamSpec:
    return ParamSpec(tuple(shape), tuple(axes), dtype, init, init_scale,
                     custom_init)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def tree_sds(specs) -> Any:
    """Abstract ShapeDtypeStruct tree (dry-run inputs)."""
    return jax.tree.map(lambda s: s.sds, specs, is_leaf=is_spec)


def tree_num_params(specs) -> int:
    leaves = jax.tree.leaves(specs, is_leaf=is_spec)
    return sum(math.prod(s.shape) for s in leaves)


def _init_one(key: jax.Array, s: ParamSpec) -> jax.Array:
    if s.custom_init is not None:
        return s.custom_init(key, s.shape, s.dtype)
    if s.init == "zeros":
        return jnp.zeros(s.shape, s.dtype)
    if s.init == "ones":
        return jnp.ones(s.shape, s.dtype)
    # fan-in-scaled normal; last axis is fan-out by convention
    fan_in = s.shape[-2] if len(s.shape) >= 2 else s.shape[-1]
    std = s.init_scale / math.sqrt(max(1, fan_in))
    return (jax.random.normal(key, s.shape, jnp.float32) * std).astype(s.dtype)


def init_params(specs, key: jax.Array) -> Any:
    """Materialize a parameter tree from specs (smoke tests / real training)."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    vals = [_init_one(k, s) for k, s in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, vals)


def cast_tree(tree, dtype) -> Any:
    """Cast floating leaves (mixed-precision compute cast)."""
    def _c(x):
        if jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x
    return jax.tree.map(_c, tree)


def stacked(n: int, s: ParamSpec) -> ParamSpec:
    """Stack a per-layer spec along a leading 'layers' axis (for lax.scan)."""
    return dataclasses.replace(s, shape=(n, *s.shape), axes=("layers", *s.axes))


def stack_specs(n: int, tree) -> Any:
    return jax.tree.map(lambda s: stacked(n, s), tree, is_leaf=is_spec)
