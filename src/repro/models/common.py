"""Shared model primitives: norms, RoPE, activations, chunked attention.

``window`` arguments are ``None`` (no sliding window — static) or an int /
traced int32 scalar (sliding-window size). Traced windows let one scanned
layer stack mix global and SWA layers (hymba) without unrolling.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Union

import jax
import jax.numpy as jnp

from repro.parallel.sharding import constrain

Window = Union[None, int, jax.Array]


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32)).astype(dt)


def gelu(x):  # tanh approximation (TPU-friendly)
    return jax.nn.gelu(x, approximate=True)


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": gelu, "gelu_glu": gelu}[name]


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (seq,) or (..., seq)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (d/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, d/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., None, :]  # broadcast over heads
    sin = sin[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# Attention cores. ``plain`` materializes (S, S) scores — used for short
# sequences; ``chunked`` is an online-softmax scan over KV blocks (flash
# semantics in XLA), used for the 32k/500k cells so the dry-run never claims
# a quadratic score buffer.
# --------------------------------------------------------------------------

NEG_INF = -1e30


def _window_ok(ok, q_pos, k_pos, window: Window):
    if window is None:
        return ok
    return ok & (k_pos[None, :] > q_pos[:, None] - window)


def plain_attention(q, k, v, *, causal: bool, window: Window = None,
                    q_offset=0, scale: Optional[float] = None,
                    prefix_len: int = 0) -> jax.Array:
    """q: (B, Sq, H, D); k/v: (B, Sk, Hkv, D[v]). GQA by head grouping."""
    B, Sq, H, D = q.shape
    Hkv = k.shape[2]
    scale = scale if scale is not None else D ** -0.5
    groups = H // Hkv
    qg = q.reshape(B, Sq, Hkv, groups, D)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32) * scale,
                        k.astype(jnp.float32))
    q_pos = q_offset + jnp.arange(Sq)
    k_pos = jnp.arange(k.shape[1])
    ok = jnp.ones((Sq, k.shape[1]), jnp.bool_)
    if causal:
        ok = k_pos[None, :] <= q_pos[:, None]
    ok = _window_ok(ok, q_pos, k_pos, window)
    if prefix_len > 0:  # prefix-LM: everything attends to the prefix block
        ok = ok | (k_pos[None, :] < prefix_len)
    scores = scores + jnp.where(ok, 0.0, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, v.shape[-1]).astype(q.dtype)


def _pad_seq(x, chunk):
    S = x.shape[1]
    n = (S + chunk - 1) // chunk
    pad = n * chunk - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)) + ((0, 0),) * (x.ndim - 2))
    return x, n


def flash_attention_jax(q, k, v, *, causal: bool, window: Window = None,
                        q_offset=0, q_chunk: int = 2048,
                        kv_chunk: int = 1024,
                        scale: Optional[float] = None,
                        prefix_len: int = 0) -> jax.Array:
    """Double-chunked online-softmax attention (flash semantics in XLA).

    Both the query and KV sequence dims are blocked, so peak memory is
    O(q_chunk x kv_chunk) per (batch, head) instead of O(Sq x Sk). KV heads
    are broadcast to the full head count first so the head dim (not the tiny
    kv-head dim) carries the tensor-parallel sharding.

    Baseline limitation (recorded in EXPERIMENTS.md §Perf): the kv scan
    always runs the full rectangle and relies on masking for causality, so
    causal attention does ~2x the useful FLOPs. The Pallas kernel and the
    hillclimbed variant (triangle blocking) eliminate this.
    """
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    Hkv = k.shape[2]
    Dv = v.shape[-1]
    scale = scale if scale is not None else D ** -0.5
    if Hkv != H:
        rep = H // Hkv
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    k = constrain(k, "batch", "kv_seq", "heads", None)
    v = constrain(v, "batch", "kv_seq", "heads", None)

    q = (q.astype(jnp.float32) * scale)
    qp, nq = _pad_seq(q, q_chunk)
    kp, nk = _pad_seq(k, kv_chunk)
    vp, _ = _pad_seq(v, kv_chunk)
    qc = qp.reshape(B, nq, q_chunk, H, D).transpose(1, 0, 2, 3, 4)
    kc = kp.reshape(B, nk, kv_chunk, H, D).transpose(1, 0, 2, 3, 4)
    vc = vp.reshape(B, nk, kv_chunk, H, Dv).transpose(1, 0, 2, 3, 4)

    def q_block(_, xs):
        qb, qi = xs  # (B, qc, H, D)
        q_pos = q_offset + qi * q_chunk + jnp.arange(q_chunk)

        def kv_block(carry, ys):
            m, l, acc = carry
            kb, vb, ki = ys
            k_pos = ki * kv_chunk + jnp.arange(kv_chunk)
            s = jnp.einsum("bqhd,bkhd->bhqk", qb.astype(kb.dtype), kb,
                           preferred_element_type=jnp.float32)
            bounds = (k_pos[None, :] < Sk) & (q_pos[:, None] < Sq + q_offset)
            ok = bounds
            if causal:
                ok = ok & (k_pos[None, :] <= q_pos[:, None])
            okw = _window_ok(ok, q_pos, k_pos, window)
            if prefix_len > 0:  # bidirectional attention within the prefix
                okw = okw | (bounds & (k_pos[None, :] < prefix_len))
            s = s + jnp.where(okw, 0.0, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l = l * alpha + p.sum(axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p.astype(vb.dtype), vb,
                preferred_element_type=jnp.float32)
            return (m_new, l, acc), None

        m0 = jnp.full((B, H, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, H, q_chunk, Dv), jnp.float32)
        # remat per kv block: the scan backward otherwise stacks every
        # (q_block x kv_block) score tensor as a residual
        (m, l, acc), _ = jax.lax.scan(
            jax.checkpoint(kv_block), (m0, l0, a0),
            (kc, vc, jnp.arange(nk)))
        ob = acc / jnp.maximum(l, 1e-30)[..., None]  # (B,H,qc,Dv)
        return None, ob.transpose(0, 2, 1, 3)

    _, oc = jax.lax.scan(jax.checkpoint(q_block), None,
                         (qc, jnp.arange(nq)))
    out = oc.transpose(1, 0, 2, 3, 4).reshape(B, nq * q_chunk, H, Dv)
    return out[:, :Sq].astype(v.dtype)


def flash_attention_triangle(q, k, v, *, causal: bool = True,
                             window: Optional[int] = None,
                             q_chunk: int = 2048, kv_chunk: int = 1024,
                             scale: Optional[float] = None) -> jax.Array:
    """Triangle/window-blocked causal attention (§Perf hillclimb variant).

    The baseline ``flash_attention_jax`` scans the full (q x kv) rectangle
    and masks — 2x the useful work for causal, and ~S/window x for
    sliding-window layers. This variant unrolls the q-chunk loop (a small
    static count) and gives each q chunk a kv scan over ONLY the blocks
    that can be live: ``[lo(window), qi]``. Requires static ``window``
    (hymba's global layers pass ``window=None``), self-attention (Sq==Sk),
    and no prefix (prefix-LM cells use the baseline path).
    """
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    assert causal and Sq == Sk, "triangle variant is causal self-attn only"
    Hkv = k.shape[2]
    Dv = v.shape[-1]
    scale = scale if scale is not None else D ** -0.5
    if Hkv != H:
        rep = H // Hkv
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    k = constrain(k, "batch", "kv_seq", "heads", None)
    v = constrain(v, "batch", "kv_seq", "heads", None)

    q = (q.astype(jnp.float32) * scale).astype(k.dtype)
    qp, nq = _pad_seq(q, q_chunk)
    kp, nk = _pad_seq(k, kv_chunk)
    vp, _ = _pad_seq(v, kv_chunk)
    kc = kp.reshape(B, nk, kv_chunk, H, D)
    vc = vp.reshape(B, nk, kv_chunk, H, Dv)

    def kv_block(qb, q_pos, carry, kb, vb, k0):
        m, l, acc = carry
        k_pos = k0 + jnp.arange(kv_chunk)
        s = jnp.einsum("bqhd,bkhd->bhqk", qb, kb,
                       preferred_element_type=jnp.float32)
        ok = (k_pos[None, :] < Sk) & (q_pos[:, None] < Sq) \
            & (k_pos[None, :] <= q_pos[:, None])
        ok = _window_ok(ok, q_pos, k_pos, window)
        s = s + jnp.where(ok, 0.0, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l = l * alpha + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p.astype(vb.dtype), vb,
            preferred_element_type=jnp.float32)
        return m_new, l, acc

    outs = []
    for qi in range(nq):  # static unroll: nq is small (S / q_chunk)
        qb = jax.lax.dynamic_slice_in_dim(qp, qi * q_chunk, q_chunk, 1)
        q_pos = qi * q_chunk + jnp.arange(q_chunk)
        hi = qi * q_chunk + q_chunk - 1          # last live kv position
        lo = 0 if window is None else max(0, qi * q_chunk - int(window))
        k_lo, k_hi = lo // kv_chunk, hi // kv_chunk  # inclusive blocks
        m = jnp.full((B, H, q_chunk), NEG_INF, jnp.float32)
        l = jnp.zeros((B, H, q_chunk), jnp.float32)
        acc = jnp.zeros((B, H, q_chunk, Dv), jnp.float32)
        n_blk = k_hi - k_lo + 1
        if n_blk > 2:  # scan the interior blocks, unroll none
            kcs = jax.lax.dynamic_slice_in_dim(kc, k_lo, n_blk, 1)
            vcs = jax.lax.dynamic_slice_in_dim(vc, k_lo, n_blk, 1)

            def body(carry, xs):
                kb, vb, ki = xs
                return kv_block(qb, q_pos, carry, kb, vb,
                                (k_lo + ki) * kv_chunk), None

            (m, l, acc), _ = jax.lax.scan(
                jax.checkpoint(body), (m, l, acc),
                (kcs.transpose(1, 0, 2, 3, 4),
                 vcs.transpose(1, 0, 2, 3, 4), jnp.arange(n_blk)))
        else:
            for ki in range(k_lo, k_hi + 1):
                kb = jax.lax.dynamic_slice_in_dim(kc, ki, 1, 1)[:, 0]
                vb = jax.lax.dynamic_slice_in_dim(vc, ki, 1, 1)[:, 0]
                m, l, acc = kv_block(qb, q_pos, (m, l, acc), kb, vb,
                                     ki * kv_chunk)
        ob = acc / jnp.maximum(l, 1e-30)[..., None]
        outs.append(ob.transpose(0, 2, 1, 3))
    out = jnp.concatenate(outs, axis=1)
    return out[:, :Sq].astype(v.dtype)


# toggled by the hillclimb (--attention triangle); see EXPERIMENTS.md §Perf
ATTENTION_VARIANT = {"impl": "baseline"}


def attention(q, k, v, *, causal: bool, window: Window = None, q_offset=0,
              scale: Optional[float] = None, prefix_len: int = 0,
              chunk_threshold: int = 2048, q_chunk: int = 2048,
              kv_chunk: int = 1024) -> jax.Array:
    """Dispatch between plain and flash attention by KV length."""
    if k.shape[1] <= chunk_threshold:
        return plain_attention(q, k, v, causal=causal, window=window,
                               q_offset=q_offset, scale=scale,
                               prefix_len=prefix_len)
    if (ATTENTION_VARIANT["impl"] == "triangle" and causal
            and prefix_len == 0 and q.shape[1] == k.shape[1]
            and isinstance(window, (int, type(None)))):
        fn = partial(flash_attention_triangle, causal=True, window=window,
                     q_chunk=min(q_chunk, q.shape[1]), kv_chunk=kv_chunk,
                     scale=scale)
        return jax.checkpoint(fn)(q, k, v)
    fn = partial(flash_attention_jax, causal=causal, window=window,
                 q_offset=q_offset, q_chunk=min(q_chunk, q.shape[1]),
                 kv_chunk=kv_chunk, scale=scale, prefix_len=prefix_len)
    return jax.checkpoint(fn)(q, k, v)


def decode_attention(q, k_cache, v_cache, cache_len, *, scale=None,
                     window: Window = None, prefix_len: int = 0) -> jax.Array:
    """Single-token attention against a (possibly sharded) KV cache.

    q: (B, 1, H, D); caches: (B, S, Hkv, D). Positions > cache_len masked
    (the new token itself sits at slot ``cache_len``).
    The KV-seq dim may carry a sharding constraint; GSPMD lowers the softmax
    to partial reduce + all-reduce (flash-decoding semantics).
    """
    B, _, H, D = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    scale = scale if scale is not None else D ** -0.5
    groups = H // Hkv
    # bf16 einsums with f32 accumulation: never materialize an f32 copy of
    # the (big) KV cache — the dot consumes bf16 directly, as on TPU.
    qg = (q.astype(jnp.float32) * scale).astype(k_cache.dtype) \
        .reshape(B, Hkv, groups, D)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache,
                   preferred_element_type=jnp.float32)
    k_pos = jnp.arange(S)
    ok = k_pos <= cache_len
    if window is not None:
        okw = ok & (k_pos > cache_len - window)
        if prefix_len > 0:
            okw = okw | (ok & (k_pos < prefix_len))
        ok = okw
    s = s + jnp.where(ok, 0.0, NEG_INF)
    s = constrain(s, "batch", "kv_heads", None, "kv_seq")
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, v_cache.shape[-1]).astype(q.dtype)
