"""Transformer / SSM / MoE block implementations.

Every block family exposes:

* ``<family>_specs(cfg)``   -> ParamSpec tree for one layer;
* ``<family>_fwd(p, x, ...)``  -> sequence forward (train / prefill). In
  prefill mode it also returns the per-layer cache entries;
* ``<family>_decode(p, x, cache, ...)`` -> single-token forward + new cache.

All matmul weights carry logical axes so the sharding rule tables in
``repro.parallel.sharding`` place them on the mesh; activations get
``constrain`` hints at block boundaries and GSPMD inserts the collectives.
"""
from __future__ import annotations

import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import (
    Window, act_fn, apply_rope, attention, decode_attention, rms_norm)
from repro.models.param import ParamSpec, spec
from repro.parallel.sharding import constrain


# ==========================================================================
# Dense / GQA attention
# ==========================================================================

def attn_specs(cfg: ArchConfig) -> dict:
    D, H, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    s: dict[str, Any] = {
        "ln": spec((D,), ("embed",), init="ones"),
        "wq": spec((D, H * hd), ("embed", "q_heads")),
        "wk": spec((D, Hkv * hd), ("embed", "kv_heads")),
        "wv": spec((D, Hkv * hd), ("embed", "kv_heads")),
        "wo": spec((H * hd, D), ("q_heads", "embed")),
    }
    if cfg.qkv_bias:
        s["bq"] = spec((H * hd,), ("q_heads",), init="zeros")
        s["bk"] = spec((Hkv * hd,), ("kv_heads",), init="zeros")
        s["bv"] = spec((Hkv * hd,), ("kv_heads",), init="zeros")
    if cfg.qk_norm:
        s["q_norm"] = spec((hd,), (None,), init="ones")
        s["k_norm"] = spec((hd,), (None,), init="ones")
    return s


def _qkv(p, x, cfg: ArchConfig, positions):
    B, S, _ = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, Hkv, hd)
    v = v.reshape(B, S, Hkv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if not cfg.encoder_only:  # encoder (hubert) uses learned/conv pos, stubbed
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = constrain(q, "batch", "seq", "heads", None)
    k = constrain(k, "batch", "seq", "kv_heads", None)
    return q, k, v


def attn_fwd(p, x, cfg: ArchConfig, *, window: Window = None,
             prefix_len: int = 0, return_cache: bool = False):
    """x: (B, S, D) -> (B, S, D) [+ (k, v) cache entries]."""
    B, S, _ = x.shape
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    positions = jnp.arange(S)
    q, k, v = _qkv(p, h, cfg, positions)
    causal = not cfg.encoder_only
    o = attention(q, k, v, causal=causal, window=window,
                  prefix_len=prefix_len)
    o = o.reshape(B, S, cfg.n_heads * cfg.head_dim)
    out = o @ p["wo"]
    out = constrain(out, "batch", "seq", "embed")
    if return_cache:
        return out, (k, v)
    return out


def attn_decode(p, x, k_cache, v_cache, cache_len, cfg: ArchConfig, *,
                window: Window = None, prefix_len: int = 0):
    """x: (B, 1, D); caches: (B, Smax, Hkv, hd). Returns out, new caches."""
    B = x.shape[0]
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    positions = jnp.full((1,), cache_len, jnp.int32)
    q, k, v = _qkv(p, h, cfg, positions)
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        k_cache, k.astype(k_cache.dtype), cache_len, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        v_cache, v.astype(v_cache.dtype), cache_len, axis=1)
    o = decode_attention(q, k_cache, v_cache, cache_len, window=window,
                         prefix_len=prefix_len)
    out = o.reshape(B, 1, H * hd) @ p["wo"]
    return constrain(out, "batch", None, "embed"), k_cache, v_cache


# ==========================================================================
# MLA (DeepSeek-V2 multi-head latent attention)
# ==========================================================================

def mla_specs(cfg: ArchConfig) -> dict:
    m = cfg.mla
    D, H = cfg.d_model, cfg.n_heads
    qh = m.nope_head_dim + m.rope_head_dim
    return {
        "ln": spec((D,), ("embed",), init="ones"),
        "q_a": spec((D, m.q_lora_rank), ("embed", "q_lora")),
        "q_a_norm": spec((m.q_lora_rank,), ("q_lora",), init="ones"),
        "q_b": spec((m.q_lora_rank, H * qh), ("q_lora", "q_heads")),
        "kv_a": spec((D, m.kv_lora_rank + m.rope_head_dim),
                     ("embed", "kv_lora")),
        "kv_a_norm": spec((m.kv_lora_rank,), ("kv_lora",), init="ones"),
        "kv_b": spec((m.kv_lora_rank, H * (m.nope_head_dim + m.v_head_dim)),
                     ("kv_lora", "q_heads")),
        "wo": spec((H * m.v_head_dim, D), ("q_heads", "embed")),
    }


def _mla_q(p, h, cfg: ArchConfig, positions):
    m, H = cfg.mla, cfg.n_heads
    B, S, _ = h.shape
    q = rms_norm(h @ p["q_a"], p["q_a_norm"], cfg.norm_eps) @ p["q_b"]
    q = q.reshape(B, S, H, m.nope_head_dim + m.rope_head_dim)
    q_nope, q_rope = jnp.split(q, [m.nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_ckv(p, h, cfg: ArchConfig, positions):
    m = cfg.mla
    ckv = h @ p["kv_a"]  # (B, S, kv_lora + rope)
    c, k_rope = jnp.split(ckv, [m.kv_lora_rank], axis=-1)
    c = rms_norm(c, p["kv_a_norm"], cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], positions,
                        cfg.rope_theta)[:, :, 0, :]
    return c, k_rope


def mla_fwd(p, x, cfg: ArchConfig, *, return_cache: bool = False):
    """Non-absorbed MLA (train / prefill): materialize per-head K/V."""
    m, H = cfg.mla, cfg.n_heads
    B, S, _ = x.shape
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    positions = jnp.arange(S)
    q_nope, q_rope = _mla_q(p, h, cfg, positions)
    c, k_rope = _mla_ckv(p, h, cfg, positions)
    kv = (c @ p["kv_b"]).reshape(B, S, H, m.nope_head_dim + m.v_head_dim)
    k_nope, v = jnp.split(kv, [m.nope_head_dim], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (B, S, H, m.rope_head_dim))], axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    scale = (m.nope_head_dim + m.rope_head_dim) ** -0.5
    o = attention(q, k, v, causal=True, scale=scale)
    out = o.reshape(B, S, H * m.v_head_dim) @ p["wo"]
    out = constrain(out, "batch", "seq", "embed")
    if return_cache:
        return out, (c, k_rope)  # compressed cache: kv_lora + rope dims only
    return out


def mla_decode(p, x, c_cache, krope_cache, cache_len, cfg: ArchConfig):
    """Absorbed MLA decode: scores/values computed in the latent space.

    caches: c (B, Smax, kv_lora), k_rope (B, Smax, rope_dim). This is the
    memory win of MLA — the per-head K/V are never materialized at decode.
    """
    m, H = cfg.mla, cfg.n_heads
    B = x.shape[0]
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    positions = jnp.full((1,), cache_len, jnp.int32)
    q_nope, q_rope = _mla_q(p, h, cfg, positions)  # (B,1,H,·)
    c, k_rope = _mla_ckv(p, h, cfg, positions)
    c_cache = jax.lax.dynamic_update_slice_in_dim(
        c_cache, c.astype(c_cache.dtype), cache_len, axis=1)
    krope_cache = jax.lax.dynamic_update_slice_in_dim(
        krope_cache, k_rope.astype(krope_cache.dtype), cache_len, axis=1)

    # absorb kv_b into q: q_lat[h] = q_nope[h] @ W_uk[h]^T  (per head)
    w_kv = p["kv_b"].reshape(m.kv_lora_rank, H, m.nope_head_dim + m.v_head_dim)
    w_uk = w_kv[:, :, :m.nope_head_dim]      # (lora, H, nope)
    w_uv = w_kv[:, :, m.nope_head_dim:]      # (lora, H, v)
    q_lat = jnp.einsum("bqhn,lhn->bhql", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))  # (B,H,1,lora)

    scale = (m.nope_head_dim + m.rope_head_dim) ** -0.5
    s = jnp.einsum("bhql,bkl->bhqk", q_lat,
                   c_cache.astype(jnp.float32))
    s = s + jnp.einsum("bqhr,bkr->bhqk", q_rope.astype(jnp.float32),
                       krope_cache.astype(jnp.float32))
    s = s * scale
    k_pos = jnp.arange(c_cache.shape[1])
    s = s + jnp.where(k_pos <= cache_len, 0.0, -1e30)
    s = constrain(s, "batch", "heads", None, "kv_seq")
    prob = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhqk,bkl->bhql", prob, c_cache.astype(jnp.float32))
    o = jnp.einsum("bhql,lhv->bqhv", o_lat, w_uv.astype(jnp.float32))
    out = o.reshape(B, 1, H * m.v_head_dim).astype(x.dtype) @ p["wo"]
    return constrain(out, "batch", None, "embed"), c_cache, krope_cache


# ==========================================================================
# MLPs (dense)
# ==========================================================================

def mlp_specs(cfg: ArchConfig, d_ff: Optional[int] = None) -> dict:
    D = cfg.d_model
    F = d_ff if d_ff is not None else cfg.d_ff
    s = {"ln": spec((D,), ("embed",), init="ones")}
    if cfg.act in ("silu", "gelu_glu"):  # gated (SwiGLU / GeGLU)
        s["wg"] = spec((D, F), ("embed", "mlp"))
        s["wu"] = spec((D, F), ("embed", "mlp"))
        s["wd"] = spec((F, D), ("mlp", "embed"))
    else:  # plain 2-layer (hubert)
        s["w1"] = spec((D, F), ("embed", "mlp"))
        s["w2"] = spec((F, D), ("mlp", "embed"))
    return s


def mlp_fwd(p, x, cfg: ArchConfig):
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    a = act_fn(cfg.act)
    if "wg" in p:
        y = (a(h @ p["wg"]) * (h @ p["wu"])) @ p["wd"]
    else:
        y = a(h @ p["w1"]) @ p["w2"]
    return constrain(y, "batch", "seq", "embed")


# ==========================================================================
# MoE (sort-free GShard-style dispatch; honest FLOPs)
# ==========================================================================

# §Perf hillclimb: shard_map dispatch. GSPMD lowers the scatter from
# data-sharded tokens into the expert-sharded buffer as a replicated
# partial-buffer all-reduce (16x the needed bytes). With shard_map, every
# model-rank selects ITS experts' tokens locally (tokens are replicated
# across the model axis anyway) and the combine is one (G, D) psum that
# merges with the block's existing TP all-reduce. Expert weights are
# all-gathered over the FSDP axis ONCE per layer, outside the group scan.
MOE_SHARD_MAP = {"enabled": False}


def _moe_group_smap_fn(cfg: ArchConfig, n_model: int, batch_axes):
    mo = cfg.moe
    E, K = mo.n_experts, mo.top_k
    E_loc = E // n_model

    def f(tok, router, wg, wu, wd):
        # tok: (G_loc, D) — this data-shard's tokens, replicated over model
        # wg/wu/wd: (E_loc, D, F) — this model-rank's experts
        G, D = tok.shape
        C = max(8, int(math.ceil(G * K * mo.capacity_factor / E / 8.0)) * 8)
        r = jax.lax.axis_index("model")
        logits = tok.astype(jnp.float32) @ router
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, topk_idx = jax.lax.top_k(probs, K)
        gate_vals = gate_vals / jnp.maximum(
            gate_vals.sum(-1, keepdims=True), 1e-9)
        me = probs.mean(axis=0)
        ce = jnp.zeros((E,), jnp.float32).at[topk_idx.reshape(-1)].add(
            1.0 / (G * K))
        aux = E * jnp.sum(me * ce)

        flat_e = topk_idx.reshape(-1)
        sel = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
        ranks = jnp.cumsum(sel, axis=0) - sel
        pos = jnp.sum(ranks * sel, axis=-1)
        mine = (flat_e // E_loc) == r
        keep = (pos < C) & mine
        le = jnp.where(mine, flat_e % E_loc, E_loc)     # E_loc = drop row
        pos_c = jnp.where(keep, pos, C)
        src_tok = jnp.arange(G * K) // K

        buf = jnp.zeros((E_loc + 1, C + 1, D), tok.dtype)
        buf = buf.at[le, pos_c].add(tok[src_tok])
        xin = buf[:E_loc, :C, :]
        a = act_fn(cfg.act)
        hmid = a(jnp.einsum("ecd,edf->ecf", xin, wg)) * \
            jnp.einsum("ecd,edf->ecf", xin, wu)
        hout = jnp.einsum("ecf,efd->ecd", hmid, wd)
        hpad = jnp.pad(hout, ((0, 1), (0, 1), (0, 0)))
        picked = hpad[le, pos_c].astype(jnp.float32) \
            * gate_vals.reshape(-1)[:, None]
        picked = jnp.where(keep[:, None], picked, 0.0)
        y = jnp.zeros((G, D), jnp.float32).at[src_tok].add(picked)
        y = jax.lax.psum(y, "model")
        return y.astype(tok.dtype), aux

    return f


def _moe_group_smap(expert_w, router, tok, cfg: ArchConfig):
    from jax.sharding import PartitionSpec as P

    from repro.parallel.jax_compat import (get_abstract_mesh,
                                           mesh_axis_sizes, shard_map)
    mesh = get_abstract_mesh()
    axes = mesh_axis_sizes(mesh)
    n_model = axes.get("model", 1)
    batch_axes = tuple(a for a in ("pod", "data") if a in axes)
    f = _moe_group_smap_fn(cfg, n_model, batch_axes)
    tok_spec = P(batch_axes if batch_axes else None)
    return shard_map(
        f, mesh=mesh,
        in_specs=(tok_spec, P(), P("model"), P("model"), P("model")),
        out_specs=(tok_spec, P()),
        check=False,
    )(tok, router, *expert_w)


def moe_shard_map_applicable(cfg: ArchConfig) -> bool:
    from repro.parallel.jax_compat import get_abstract_mesh, mesh_axis_sizes
    mesh = get_abstract_mesh()
    if mesh is None:
        return False
    axes = mesh_axis_sizes(mesh)
    n_model = axes.get("model", 1)
    return cfg.moe is not None and cfg.moe.n_experts % n_model == 0

def moe_specs(cfg: ArchConfig) -> dict:
    mo = cfg.moe
    D, E, Fe = cfg.d_model, mo.n_experts, mo.d_ff_expert
    s = {
        "ln": spec((D,), ("embed",), init="ones"),
        "router": spec((D, E), ("embed", "experts"), dtype=jnp.float32),
        "wg": spec((E, D, Fe), ("experts", "embed", "expert_mlp")),
        "wu": spec((E, D, Fe), ("experts", "embed", "expert_mlp")),
        "wd": spec((E, Fe, D), ("experts", "expert_mlp", "embed")),
    }
    if mo.n_shared_experts:
        Fs = mo.n_shared_experts * Fe
        s["sh_wg"] = spec((D, Fs), ("embed", "mlp"))
        s["sh_wu"] = spec((D, Fs), ("embed", "mlp"))
        s["sh_wd"] = spec((Fs, D), ("mlp", "embed"))
    return s


def _moe_group(p, tok, cfg: ArchConfig):
    """Dispatch one token group through the experts.

    tok: (G, D). Sort-free GShard-style dispatch: rank each (token, slot)
    within its expert by a one-hot cumsum, slot into per-expert capacity
    buffers, batched expert matmul, weighted scatter-add back. (An argsort
    dispatch lowers to XLA sort loops — whiles over the full buffer per
    pass — which wrecks both compile-time and the HBM roofline term.)
    Aux = Switch-style load-balance loss.
    """
    mo = cfg.moe
    G, D = tok.shape
    E, K = mo.n_experts, mo.top_k
    C = max(8, int(math.ceil(G * K * mo.capacity_factor / E / 8.0)) * 8)

    tok = constrain(tok, "batch", None)
    logits = (tok.astype(jnp.float32) @ p["router"])  # (G, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, topk_idx = jax.lax.top_k(probs, K)  # (G, K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # load-balance aux: mean prob per expert x fraction of tokens routed
    me = probs.mean(axis=0)                                   # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[topk_idx.reshape(-1)].add(
        1.0 / (G * K))
    aux = E * jnp.sum(me * ce)

    flat_e = topk_idx.reshape(-1)                             # (G*K,)
    sel = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)          # (G*K, E)
    ranks = jnp.cumsum(sel, axis=0) - sel                     # rank in expert
    pos = jnp.sum(ranks * sel, axis=-1)                       # (G*K,)
    keep = pos < C
    pos_c = jnp.where(keep, pos, C)                           # C = drop slot
    src_tok = jnp.arange(G * K) // K

    # expert-major 3D scatter (.add: kept destinations unique, drops land
    # in the never-read slot C; add-combine avoids XLA's last-writer
    # machinery). Keeping the expert dim explicit lets GSPMD partition the
    # scatter/gather along the expert-sharded buffer.
    buf = jnp.zeros((E, C + 1, D), tok.dtype)
    buf = constrain(buf, "experts", None, None)
    buf = buf.at[flat_e, pos_c].add(tok[src_tok])
    xin = buf[:, :C, :]
    xin = constrain(xin, "experts", None, None)
    a = act_fn(cfg.act)
    hmid = a(jnp.einsum("ecd,edf->ecf", xin, p["wg"])) * \
        jnp.einsum("ecd,edf->ecf", xin, p["wu"])
    hout = jnp.einsum("ecf,efd->ecd", hmid, p["wd"])          # (E, C, D)
    hout = constrain(hout, "experts", None, None)

    hpad = jnp.pad(hout, ((0, 0), (0, 1), (0, 0)))
    picked = hpad[flat_e, pos_c].astype(jnp.float32) \
        * (gate_vals.reshape(-1))[:, None]
    picked = jnp.where(keep[:, None], picked, 0.0)
    y = jnp.zeros((G, D), jnp.float32).at[src_tok].add(picked)
    return y.astype(tok.dtype), aux


def moe_fwd(p, x, cfg: ArchConfig):
    """x: (B, S, D) -> (y, aux_loss).

    Token groups are SEQUENCE chunks (full batch dim per group), so the
    batch sharding survives the grouping reshape and the group scan's
    saved residuals stay sharded — grouping flat token blocks instead
    replicates the whole token tensor per device.
    """
    mo = cfg.moe
    B, S, D = x.shape
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    T = B * S
    G = min(mo.group_size, T)
    gs = max(1, G // B)       # sequence chunk per group
    if S % gs != 0:
        gs = 1
    nc = S // gs

    use_smap = MOE_SHARD_MAP["enabled"] and moe_shard_map_applicable(cfg)
    if use_smap:
        from jax.sharding import PartitionSpec as P
        # gather expert weights over the FSDP axis ONCE, outside the scan
        expert_w = tuple(jax.lax.with_sharding_constraint(
            p[k], P("model")) for k in ("wg", "wu", "wd"))
        router = jax.lax.with_sharding_constraint(
            p["router"].astype(jnp.float32), P())
        grp = lambda t: _moe_group_smap(expert_w, router, t, cfg)
    else:
        grp = lambda t: _moe_group(p, t, cfg)

    if nc == 1:
        y, aux = grp(h.reshape(T, D))
        y = y.reshape(B, S, D)
    else:
        tok = h.reshape(B, nc, gs, D).transpose(1, 0, 2, 3)

        def body(_, t):  # t: (B, gs, D)
            yg, auxg = grp(t.reshape(B * gs, D))
            return None, (yg.reshape(B, gs, D), auxg)

        _, (y, auxs) = jax.lax.scan(jax.checkpoint(body), None, tok)
        aux = auxs.mean()
        y = y.transpose(1, 0, 2, 3).reshape(B, S, D)

    if mo.n_shared_experts:
        a = act_fn(cfg.act)
        y = y + (a(h @ p["sh_wg"]) * (h @ p["sh_wu"])) @ p["sh_wd"]
    return constrain(y, "batch", "seq", "embed"), aux


# ==========================================================================
# SSD (Mamba-2 state-space duality)
# ==========================================================================

def ssd_specs(cfg: ArchConfig) -> dict:
    ss = cfg.ssm
    D = cfg.d_model
    di = ss.d_inner(D)
    nh = ss.n_heads(D)
    GN = ss.n_groups * ss.d_state
    w = ss.conv_width

    def a_init(key, shape, dtype):
        lo, hi = 1.0, 16.0
        u = jax.random.uniform(key, shape, jnp.float32)
        return jnp.log(lo + u * (hi - lo)).astype(dtype)

    return {
        "ln": spec((D,), ("embed",), init="ones"),
        "in_x": spec((D, di), ("embed", "ssm_inner")),
        "in_z": spec((D, di), ("embed", "ssm_inner")),
        "in_B": spec((D, GN), ("embed", None)),
        "in_C": spec((D, GN), ("embed", None)),
        "in_dt": spec((D, nh), ("embed", None)),
        "conv_x": spec((w, di), (None, "ssm_inner"), init="small_normal",
                       init_scale=0.5),
        "conv_B": spec((w, GN), (None, None), init="small_normal",
                       init_scale=0.5),
        "conv_C": spec((w, GN), (None, None), init="small_normal",
                       init_scale=0.5),
        "conv_b": spec((di + 2 * GN,), (None,), init="zeros"),
        "dt_bias": spec((nh,), (None,), init="zeros"),
        "A_log": spec((nh,), (None,), custom_init=a_init),
        "D_skip": spec((nh,), (None,), init="ones"),
        "gnorm": spec((di,), ("ssm_inner",), init="ones"),
        "out_proj": spec((di, D), ("ssm_inner", "embed")),
    }


def _causal_conv(x, w, b):
    """x: (B, S, C); w: (W, C) depthwise causal conv via shifted adds."""
    W = w.shape[0]
    y = x * w[W - 1]
    for i in range(1, W):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, :-i or None, :]
        y = y + shifted * w[W - 1 - i]
    return y + b


def _ssd_chunk_scan(xh, dt, A, Bm, Cm, h0=None):
    """Chunked SSD core.

    xh: (B, S, nh, hd); dt: (B, S, nh) (post-softplus); A: (nh,) negative;
    Bm/Cm: (B, S, nh, N) (already broadcast from groups to heads).
    Returns y: (B, S, nh, hd) and the final state (B, nh, hd, N).
    """
    Bsz, S, nh, hd = xh.shape
    N = Bm.shape[-1]
    Q = min(S, 256) if S % 256 == 0 or S < 256 else _largest_chunk(S)
    nc = S // Q

    def split(t):
        return t.reshape(Bsz, nc, Q, *t.shape[2:]).transpose(
            1, 0, *range(2, t.ndim + 1))

    xc, dtc, Bc, Cc = split(xh), split(dt), split(Bm), split(Cm)
    if h0 is None:
        h0 = jnp.zeros((Bsz, nh, hd, N), jnp.float32)

    def body(h, xs):
        xq, dtq, Bq, Cq = xs  # (B,Q,nh,·)
        dA = dtq.astype(jnp.float32) * A  # (B,Q,nh) negative
        cum = jnp.cumsum(dA, axis=1)      # within-chunk decay logs
        # intra-chunk (dual quadratic form). Mask the log BEFORE exp —
        # non-causal entries have positive logs that overflow exp and
        # poison the backward pass (inf * 0 = NaN) if masked after.
        Lmat = cum[:, :, None, :] - cum[:, None, :, :]  # (B,Qi,Qj,nh)
        iq = jnp.arange(Q)
        causal = iq[:, None] >= iq[None, :]
        Lmat = jnp.where(causal[None, :, :, None], Lmat, -1e30)
        Lmat = jnp.exp(Lmat)
        scores = jnp.einsum("bihn,bjhn->bijh", Cq.astype(jnp.float32),
                            Bq.astype(jnp.float32))
        dx = dtq.astype(jnp.float32)[..., None] * xh_f(xq)  # (B,Q,nh,hd)
        y_intra = jnp.einsum("bijh,bijh,bjhp->bihp",
                             scores, Lmat, dx)
        # inter-chunk: contribution of carried state
        y_inter = jnp.einsum("bihn,bhpn,bih->bihp",
                             Cq.astype(jnp.float32), h, jnp.exp(cum))
        # state update
        decay_to_end = jnp.exp(cum[:, -1:, :] - cum)  # (B,Q,nh)
        s_chunk = jnp.einsum("bjhn,bjhp,bjh->bhpn",
                             Bq.astype(jnp.float32), dx, decay_to_end)
        h_new = h * jnp.exp(cum[:, -1, :])[:, :, None, None] + s_chunk
        return h_new, (y_intra + y_inter)

    h_final, yc = jax.lax.scan(jax.checkpoint(body), h0,
                               (xc, dtc, Bc, Cc))
    y = yc.transpose(1, 0, 2, 3, 4).reshape(Bsz, S, nh, hd)
    return y, h_final


def xh_f(x):
    return x.astype(jnp.float32)


def _largest_chunk(S: int) -> int:
    for q in (256, 128, 64, 32, 16, 8, 4, 2, 1):
        if S % q == 0:
            return q
    return 1


def _ssd_inputs(p, h, cfg: ArchConfig, conv_state=None):
    """Shared projection + causal conv for fwd and decode.

    h: (B, S, D). Returns x (B,S,nh,hd), z, dt, Bm, Cm (+ new conv tail).
    """
    ss = cfg.ssm
    D = cfg.d_model
    di, nh = ss.d_inner(D), ss.n_heads(D)
    GN = ss.n_groups * ss.d_state
    B_, S, _ = h.shape

    x = h @ p["in_x"]
    z = h @ p["in_z"]
    Bm = h @ p["in_B"]
    Cm = h @ p["in_C"]
    dt = h @ p["in_dt"]

    bx, bB, bC = jnp.split(p["conv_b"], [di, di + GN])
    if conv_state is not None:  # decode: prepend stored tail
        tail_x, tail_B, tail_C = conv_state
        x_full = jnp.concatenate([tail_x, x], axis=1)
        B_full = jnp.concatenate([tail_B, Bm], axis=1)
        C_full = jnp.concatenate([tail_C, Cm], axis=1)
        W = ss.conv_width
        x = _causal_conv(x_full, p["conv_x"], bx)[:, W - 1:]
        Bm = _causal_conv(B_full, p["conv_B"], bB)[:, W - 1:]
        Cm = _causal_conv(C_full, p["conv_C"], bC)[:, W - 1:]
        new_state = (x_full[:, -(W - 1):], B_full[:, -(W - 1):],
                     C_full[:, -(W - 1):])
    else:
        x_pre, B_pre, C_pre = x, Bm, Cm
        x = _causal_conv(x, p["conv_x"], bx)
        Bm = _causal_conv(Bm, p["conv_B"], bB)
        Cm = _causal_conv(Cm, p["conv_C"], bC)
        W = ss.conv_width
        new_state = (x_pre[:, -(W - 1):], B_pre[:, -(W - 1):],
                     C_pre[:, -(W - 1):])
    x = jax.nn.silu(x)
    Bm = jax.nn.silu(Bm)
    Cm = jax.nn.silu(Cm)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])

    x = x.reshape(B_, S, nh, ss.head_dim)
    # broadcast groups -> heads
    g_of_h = nh // ss.n_groups
    Bm = jnp.repeat(Bm.reshape(B_, S, ss.n_groups, ss.d_state), g_of_h,
                    axis=2)
    Cm = jnp.repeat(Cm.reshape(B_, S, ss.n_groups, ss.d_state), g_of_h,
                    axis=2)
    return x, z, dt, Bm, Cm, new_state


def _ssd_output(p, y, x, z, cfg: ArchConfig):
    ss = cfg.ssm
    B_, S = y.shape[0], y.shape[1]
    di = ss.d_inner(cfg.d_model)
    y = y + x.astype(jnp.float32) * p["D_skip"][..., None]
    y = y.reshape(B_, S, di)
    y = rms_norm(y.astype(z.dtype) * jax.nn.silu(z), p["gnorm"],
                 cfg.norm_eps)
    out = y @ p["out_proj"]
    return constrain(out, "batch", "seq", "embed")


def ssd_fwd(p, x_res, cfg: ArchConfig, *, return_cache: bool = False):
    """Mamba-2 block over a sequence. x_res: (B, S, D)."""
    h = rms_norm(x_res, p["ln"], cfg.norm_eps)
    x, z, dt, Bm, Cm, conv_tail = _ssd_inputs(p, h, cfg)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, h_final = _ssd_chunk_scan(x, dt, A, Bm, Cm)
    out = _ssd_output(p, y, x, z, cfg)
    if return_cache:
        return out, (conv_tail, h_final)
    return out


def ssd_decode(p, x_res, conv_state, ssm_state, cfg: ArchConfig):
    """Single-token recurrent update. conv_state: 3x (B, W-1, ·);
    ssm_state: (B, nh, hd, N)."""
    h = rms_norm(x_res, p["ln"], cfg.norm_eps)
    x, z, dt, Bm, Cm, new_conv = _ssd_inputs(p, h, cfg, conv_state)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    # recurrent step: h' = exp(dt*A) h + dt * B (outer) x ; y = C . h'
    dtq = dt[:, 0]                     # (B, nh)
    xq = x[:, 0].astype(jnp.float32)   # (B, nh, hd)
    Bq = Bm[:, 0].astype(jnp.float32)  # (B, nh, N)
    Cq = Cm[:, 0].astype(jnp.float32)
    decay = jnp.exp(dtq * A)[..., None, None]
    upd = jnp.einsum("bh,bhp,bhn->bhpn", dtq, xq, Bq)
    ssm_new = ssm_state * decay + upd
    y = jnp.einsum("bhpn,bhn->bhp", ssm_new, Cq)[:, None]  # (B,1,nh,hd)
    out = _ssd_output(p, y, x, z, cfg)
    return out, new_conv, ssm_new


# ==========================================================================
# Hybrid (hymba): parallel attention + SSD branches sharing the residual
# ==========================================================================

def hybrid_specs(cfg: ArchConfig) -> dict:
    return {"attn": attn_specs(cfg), "ssd": ssd_specs(cfg)}


def hybrid_fwd(p, x, cfg: ArchConfig, *, window: Window = None,
               return_cache: bool = False):
    if return_cache:
        a, kv = attn_fwd(p["attn"], x, cfg, window=window, return_cache=True)
        s, st = ssd_fwd(p["ssd"], x, cfg, return_cache=True)
        return 0.5 * (a + s), (kv, st)
    a = attn_fwd(p["attn"], x, cfg, window=window)
    s = ssd_fwd(p["ssd"], x, cfg)
    return 0.5 * (a + s)


def hybrid_decode(p, x, k_cache, v_cache, conv_state, ssm_state, cache_len,
                  cfg: ArchConfig, *, window: Window = None):
    a, k_cache, v_cache = attn_decode(p["attn"], x, k_cache, v_cache,
                                      cache_len, cfg, window=window)
    s, conv_state, ssm_state = ssd_decode(p["ssd"], x, conv_state, ssm_state,
                                          cfg)
    return 0.5 * (a + s), k_cache, v_cache, conv_state, ssm_state
