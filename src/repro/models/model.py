"""Model assembly: embedding -> scanned layer stack -> unembedding.

One implementation serves all ten assigned architectures; family-specific
behaviour (SSD, MoE, MLA, hybrid windows, encoder-only, modality frontends)
is dispatched from the ArchConfig. Layers run under ``jax.lax.scan`` with
per-layer remat, so HLO size and compile time are O(1) in depth and the
roofline extractor multiplies while-body costs by the trip count.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import blocks, registry
from repro.models.common import rms_norm
from repro.models.param import cast_tree
from repro.parallel.sharding import constrain

REMAT_POLICIES = {
    "full": None,  # save nothing
    "dots": "dots_with_no_batch_dims_saveable",
    "none": "everything_saveable",
}


def _remat(fn, policy: str):
    if policy == "none":
        return fn
    pol = None
    if policy == "dots":
        pol = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return jax.checkpoint(fn, policy=pol)


# --------------------------------------------------------------------------
# Embedding / unembedding
# --------------------------------------------------------------------------

def embed_inputs(params, batch: dict, cfg: ArchConfig, dtype=jnp.bfloat16):
    """Returns x: (B, S_total, D) and the prefix length (vlm image tokens)."""
    emb = params["embed"].astype(dtype)
    prefix = 0
    if cfg.frontend == "audio":
        x = batch["frames"].astype(dtype) @ params["frontend_proj"].astype(
            dtype)
        # sinusoidal positions (conv-positional frontend is stubbed)
        S, D = x.shape[1], x.shape[2]
        pos = jnp.arange(S)[:, None].astype(jnp.float32)
        div = jnp.exp(jnp.arange(0, D, 2, dtype=jnp.float32)
                      * (-jnp.log(10000.0) / D))
        pe = jnp.zeros((S, D), jnp.float32)
        pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
        pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
        x = x + pe.astype(dtype)
    elif cfg.frontend == "vision":
        img = batch["patches"].astype(dtype) @ params["frontend_proj"].astype(
            dtype)
        tx = emb[batch["tokens"]]
        tx = tx * jnp.asarray(cfg.d_model ** 0.5, dtype)  # gemma scaling
        x = jnp.concatenate([img, tx], axis=1)
        prefix = cfg.frontend_seq
    else:
        x = emb[batch["tokens"]]
    return constrain(x, "batch", "seq", "embed"), prefix


def unembed(params, x, cfg: ArchConfig):
    h = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if "lm_head" in params:
        logits = h @ params["lm_head"].astype(h.dtype)
    else:
        logits = jnp.einsum("bsd,vd->bsv", h, params["embed"].astype(h.dtype))
    return constrain(logits, "batch", "seq", "vocab")


# --------------------------------------------------------------------------
# Layer application (shared by forward and prefill)
# --------------------------------------------------------------------------

def _apply_layer(p, x, cfg: ArchConfig, *, window, prefix_len: int,
                 prefill: bool):
    """Returns (x, aux, cache_entry_or_None)."""
    fam = cfg.family
    aux = jnp.zeros((), jnp.float32)
    cache = None
    if fam == "ssm":
        if prefill:
            y, cache = blocks.ssd_fwd(p["ssd"], x, cfg, return_cache=True)
        else:
            y = blocks.ssd_fwd(p["ssd"], x, cfg)
        return x + y, aux, cache
    if fam == "hybrid":
        if prefill:
            y, cache = blocks.hybrid_fwd(p["mix"], x, cfg, window=window,
                                         return_cache=True)
        else:
            y = blocks.hybrid_fwd(p["mix"], x, cfg, window=window)
        x = x + y
        x = x + blocks.mlp_fwd(p["mlp"], x, cfg)
        return x, aux, cache
    # attention (GQA or MLA)
    if cfg.mla:
        if prefill:
            y, cache = blocks.mla_fwd(p["attn"], x, cfg, return_cache=True)
        else:
            y = blocks.mla_fwd(p["attn"], x, cfg)
    else:
        if prefill:
            y, cache = blocks.attn_fwd(p["attn"], x, cfg, window=window,
                                       prefix_len=prefix_len,
                                       return_cache=True)
        else:
            y = blocks.attn_fwd(p["attn"], x, cfg, window=window,
                                prefix_len=prefix_len)
    x = x + y
    if "moe" in p:
        y, aux = blocks.moe_fwd(p["moe"], x, cfg)
        x = x + y
    else:
        x = x + blocks.mlp_fwd(p["mlp"], x, cfg)
    return x, aux, cache


def _apply_dense0(p, x, cfg: ArchConfig, *, prefill: bool):
    """DeepSeek leading dense layer: MLA attn + wide dense MLP."""
    if prefill:
        y, cache = blocks.mla_fwd(p["attn"], x, cfg, return_cache=True)
    else:
        y = blocks.mla_fwd(p["attn"], x, cfg)
        cache = None
    x = x + y
    x = x + blocks.mlp_fwd(p["mlp"], x, cfg)
    return x, cache


# --------------------------------------------------------------------------
# Sequence forward (train / prefill)
# --------------------------------------------------------------------------

# §Perf hillclimb toggle: run sliding-window archs as static-window layer
# SEGMENTS (scan per contiguous SWA run, global layers unrolled) so the
# triangle/window-blocked attention kernel can skip dead kv blocks.
STATIC_WINDOW_SEGMENTS = {"enabled": False}


def _segmented_stack(params, x, cfg, *, prefix_len, prefill, remat, dtype):
    """hymba-style stack as [SWA segment]* with global layers unrolled."""
    L = cfg.n_layers
    glob = sorted(registry.global_layer_indices(cfg))
    layers = jax.tree.map(lambda a: a.astype(dtype)
                          if jnp.issubdtype(a.dtype, jnp.floating) else a,
                          params["layers"])
    aux = jnp.zeros((), jnp.float32)
    caches = []

    def seg_scan(x, aux, lo, hi, window):
        seg = jax.tree.map(lambda a: a[lo:hi], layers)

        def body(carry, p_layer):
            x, aux = carry
            x, a2, cache = _apply_layer(p_layer, x, cfg, window=window,
                                        prefix_len=prefix_len,
                                        prefill=prefill)
            return (x, aux + a2), cache

        (x, aux), c = jax.lax.scan(_remat(body, remat), (x, aux), seg)
        return x, aux, c

    pos = 0
    bounds = glob + [L]
    for g in bounds:
        if g > pos:  # SWA segment [pos, g)
            x, aux, c = seg_scan(x, aux, pos, g, cfg.sliding_window)
            caches.append(c)
        if g < L:    # the global layer g, unrolled, full attention
            pl = jax.tree.map(lambda a: a[g], layers)

            def one(pl, x):
                return _apply_layer(pl, x, cfg, window=None,
                                    prefix_len=prefix_len, prefill=prefill)

            x, a2, c = _remat(one, remat)(pl, x)
            aux = aux + a2
            if c is not None:
                caches.append(jax.tree.map(lambda t: t[None], c))
        pos = g + 1
    if prefill:
        cache = jax.tree.map(lambda *cs: jnp.concatenate(cs, axis=0),
                             *caches)
    else:
        cache = None
    return x, aux, cache


def forward(params, batch: dict, cfg: ArchConfig, *, prefill: bool = False,
            remat: str = "full", dtype=jnp.bfloat16):
    """Full-sequence forward.

    Returns (logits, aux_loss) when ``prefill=False``;
    (last_logits, cache) when ``prefill=True``.
    """
    params = cast_tree(params, dtype)
    x, prefix_len = embed_inputs(params, batch, cfg, dtype)
    S = x.shape[1]
    warr = registry.window_array(cfg, S)

    def body(carry, xs):
        x, aux = carry
        if warr is not None:
            p_layer, w = xs
        else:
            p_layer, w = xs, None
        x, aux2, cache = _apply_layer(
            p_layer, x, cfg, window=w, prefix_len=prefix_len,
            prefill=prefill)
        return (x, aux + aux2), cache

    if "dense0" in params:
        x, cache0 = _apply_dense0(params["dense0"], x, cfg, prefill=prefill)
    else:
        cache0 = None

    if warr is not None and STATIC_WINDOW_SEGMENTS["enabled"]:
        x, aux, caches = _segmented_stack(
            params, x, cfg, prefix_len=prefix_len, prefill=prefill,
            remat=remat, dtype=dtype)
        if prefill:
            last = unembed(params, x[:, -1:], cfg)
            return last, {"layers": caches}
        return unembed(params, x, cfg), aux

    layers = jax.tree.map(lambda a: a.astype(dtype)
                          if jnp.issubdtype(a.dtype, jnp.floating) else a,
                          params["layers"])
    xs = (layers, warr) if warr is not None else layers
    (x, aux), caches = jax.lax.scan(
        _remat(body, remat), (x, jnp.zeros((), jnp.float32)), xs)

    if prefill:
        last = unembed(params, x[:, -1:], cfg)
        full_cache = {"layers": caches}
        if cache0 is not None:
            full_cache["dense0"] = cache0
        return last, full_cache
    logits = unembed(params, x, cfg)
    return logits, aux


# --------------------------------------------------------------------------
# Loss
# --------------------------------------------------------------------------

def loss_fn(params, batch: dict, cfg: ArchConfig, *, remat: str = "full",
            dtype=jnp.bfloat16, aux_weight: float = 0.01,
            z_weight: float = 1e-4):
    logits, aux = forward(params, batch, cfg, prefill=False, remat=remat,
                          dtype=dtype)
    labels = batch["labels"]
    if cfg.frontend == "vision":  # image positions carry no labels
        logits = logits[:, cfg.frontend_seq:]
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    ce = (lse - ll).mean()
    z = (lse ** 2).mean()  # z-loss keeps logits bounded
    loss = ce + aux_weight * aux + z_weight * z
    metrics = {"loss": loss, "ce": ce, "aux": aux, "z": z}
    return loss, metrics


# --------------------------------------------------------------------------
# Decode (one token against the cache)
# --------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch: int, max_seq: int,
               dtype=jnp.bfloat16) -> dict:
    """Abstract-shape-compatible cache initializer (also used by input_specs).
    """
    L = registry.n_scanned_layers(cfg)
    c: dict[str, Any] = {}
    entry = layer_cache_struct(cfg, batch, max_seq, dtype)
    c["layers"] = jax.tree.map(
        lambda s: jnp.zeros((L, *s.shape), s.dtype), entry)
    if cfg.moe and cfg.moe.first_dense_layers:
        d0 = mla_cache_struct(cfg, batch, max_seq, dtype)
        c["dense0"] = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), d0)
    return c


def mla_cache_struct(cfg, B, S, dtype):
    m = cfg.mla
    return (jax.ShapeDtypeStruct((B, S, m.kv_lora_rank), dtype),
            jax.ShapeDtypeStruct((B, S, m.rope_head_dim), dtype))


def ssd_cache_struct(cfg, B, dtype):
    ss = cfg.ssm
    di = ss.d_inner(cfg.d_model)
    nh = ss.n_heads(cfg.d_model)
    GN = ss.n_groups * ss.d_state
    W = ss.conv_width
    conv = (jax.ShapeDtypeStruct((B, W - 1, di), dtype),
            jax.ShapeDtypeStruct((B, W - 1, GN), dtype),
            jax.ShapeDtypeStruct((B, W - 1, GN), dtype))
    ssm = jax.ShapeDtypeStruct((B, nh, ss.head_dim, ss.d_state), jnp.float32)
    return (conv, ssm)


def kv_cache_struct(cfg, B, S, dtype):
    return (jax.ShapeDtypeStruct((B, S, cfg.n_kv_heads, cfg.head_dim), dtype),
            jax.ShapeDtypeStruct((B, S, cfg.n_kv_heads, cfg.head_dim), dtype))


def layer_cache_struct(cfg: ArchConfig, B: int, S: int, dtype):
    fam = cfg.family
    if fam == "ssm":
        return ssd_cache_struct(cfg, B, dtype)
    if fam == "hybrid":
        return (kv_cache_struct(cfg, B, S, dtype),
                ssd_cache_struct(cfg, B, dtype))
    if cfg.mla:
        return mla_cache_struct(cfg, B, S, dtype)
    return kv_cache_struct(cfg, B, S, dtype)


def _decode_layer(p, x, cache, cache_len, cfg: ArchConfig, *, window,
                  prefix_len: int):
    fam = cfg.family
    if fam == "ssm":
        (conv, ssm) = cache
        y, conv, ssm = blocks.ssd_decode(p["ssd"], x, conv, ssm, cfg)
        return x + y, (conv, ssm)
    if fam == "hybrid":
        (k, v), (conv, ssm) = cache
        y, k, v, conv, ssm = blocks.hybrid_decode(
            p["mix"], x, k, v, conv, ssm, cache_len, cfg, window=window)
        x = x + y
        x = x + blocks.mlp_fwd(p["mlp"], x, cfg)
        return x, ((k, v), (conv, ssm))
    if cfg.mla:
        c, kr = cache
        y, c, kr = blocks.mla_decode(p["attn"], x, c, kr, cache_len, cfg)
        cache = (c, kr)
    else:
        k, v = cache
        y, k, v = blocks.attn_decode(p["attn"], x, k, v, cache_len, cfg,
                                     window=window, prefix_len=prefix_len)
        cache = (k, v)
    x = x + y
    if "moe" in p:
        y, _ = blocks.moe_fwd(p["moe"], x, cfg)
        x = x + y
    else:
        x = x + blocks.mlp_fwd(p["mlp"], x, cfg)
    return x, cache


def decode_step(params, cache: dict, batch: dict, cfg: ArchConfig, *,
                dtype=jnp.bfloat16):
    """One decode step. batch: {"tokens": (B,1) int32, "cache_len": ()}.

    Returns (logits (B,1,V), new_cache). For VLM archs the image prefix is
    assumed to live in cache slots [0, frontend_seq).
    """
    params = cast_tree(params, dtype)
    cache_len = batch["cache_len"]
    prefix_len = cfg.frontend_seq if cfg.frontend == "vision" else 0
    emb = params["embed"].astype(dtype)
    x = emb[batch["tokens"]]
    if cfg.frontend == "vision":
        x = x * jnp.asarray(cfg.d_model ** 0.5, dtype)
    x = constrain(x, "batch", None, "embed")

    # decode positions: SSM states don't use positions; attention uses
    # cache_len as the rope position/causal boundary.
    seq_hint = 0
    for leaf in jax.tree.leaves(cache["layers"]):
        if leaf.ndim >= 3:
            seq_hint = max(seq_hint, leaf.shape[2] if leaf.ndim > 3
                           else leaf.shape[2])
    warr = registry.window_array(cfg, seq_hint)

    if "dense0" in cache:
        c, kr = cache["dense0"]
        y, c, kr = blocks.mla_decode(params["dense0"]["attn"], x, c, kr,
                                     cache_len, cfg)
        x = x + y
        x = x + blocks.mlp_fwd(params["dense0"]["mlp"], x, cfg)
        new_dense0 = (c, kr)
    else:
        new_dense0 = None

    def body(x, xs):
        if warr is not None:
            p_layer, cache_slice, w = xs
        else:
            (p_layer, cache_slice), w = xs, None
        x, new_slice = _decode_layer(p_layer, x, cache_slice, cache_len, cfg,
                                     window=w, prefix_len=prefix_len)
        return x, new_slice

    layers = jax.tree.map(lambda a: a.astype(dtype)
                          if jnp.issubdtype(a.dtype, jnp.floating) else a,
                          params["layers"])
    xs = ((layers, cache["layers"], warr) if warr is not None
          else (layers, cache["layers"]))
    x, new_layers = jax.lax.scan(body, x, xs)

    logits = unembed(params, x, cfg)
    new_cache = {"layers": new_layers}
    if new_dense0 is not None:
        new_cache["dense0"] = new_dense0
    return logits, new_cache


def prefill_step(params, batch: dict, cfg: ArchConfig, *,
                 remat: str = "full", dtype=jnp.bfloat16):
    """Prefill: build the KV/state cache for a prompt, return last logits."""
    return forward(params, batch, cfg, prefill=True, remat=remat,
                   dtype=dtype)
