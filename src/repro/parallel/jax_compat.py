"""Version-compat shims for the jax mesh/sharding API drift.

The execution plane targets the post-0.5 "explicit mesh" API
(``jax.sharding.AxisType``, ``jax.sharding.get_abstract_mesh``,
``jax.set_mesh``, top-level ``jax.shard_map``).  The pinned CI floor is
jax 0.4.37, where none of those exist yet: meshes carry no axis types,
the active mesh lives in the pjit resource env
(``thread_resources.env.physical_mesh``), and ``shard_map`` sits in
``jax.experimental`` with ``check_rep`` instead of ``check_vma``.

Everything the repo needs from that surface funnels through this module
so model/launch code stays version-agnostic:

* ``make_mesh(shape, axes)``        — ``axis_types`` when supported;
* ``set_mesh(mesh)``                — context manager activating a mesh
  for GSPMD sharding constraints (``jax.set_mesh`` or legacy
  ``with mesh:`` resource env);
* ``get_abstract_mesh()``           — the active mesh or ``None``
  (never raises, unlike the drifting attribute lookups);
* ``mesh_axis_sizes(mesh)``         — ``{axis: size}`` for either a new
  AbstractMesh or a legacy physical Mesh;
* ``shard_map(...)``                — replication-check kwarg spelled
  per version.
"""
from __future__ import annotations

import contextlib
from typing import Optional

import jax

# ----------------------------------------------------------------------
# feature detection (done once at import; cheap attribute probes only)
# ----------------------------------------------------------------------

HAS_AXIS_TYPE = hasattr(jax.sharding, "AxisType")
HAS_ABSTRACT_MESH = hasattr(jax.sharding, "get_abstract_mesh")
HAS_SET_MESH = hasattr(jax, "set_mesh")
HAS_TOPLEVEL_SHARD_MAP = hasattr(jax, "shard_map")


def make_mesh(shape, axes, *, devices=None):
    """``jax.make_mesh`` with Auto axis types when the API has them."""
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    if HAS_AXIS_TYPE:
        kwargs["axis_types"] = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, **kwargs)


@contextlib.contextmanager
def set_mesh(mesh):
    """Activate ``mesh`` for sharding constraints inside jit.

    New jax: ``jax.set_mesh`` (abstract-mesh context). Old jax: enter the
    mesh's own context manager, which installs it in the pjit resource
    env — ``with_sharding_constraint`` then accepts bare PartitionSpecs.
    """
    if HAS_SET_MESH:
        with jax.set_mesh(mesh):
            yield mesh
    else:
        with mesh:
            yield mesh


def get_abstract_mesh() -> Optional[object]:
    """The mesh active for GSPMD lowering, or ``None`` when unset/empty.

    Callers treat ``None`` as "single device, skip constraints", which
    keeps smoke tests mesh-free on every jax version.
    """
    if HAS_ABSTRACT_MESH:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or mesh.empty:
            return None
        return mesh
    # 0.4.x: the active mesh is the resource-env physical mesh
    try:
        from jax._src import mesh as mesh_lib
        mesh = mesh_lib.thread_resources.env.physical_mesh
    except Exception:  # pragma: no cover - internal layout drift
        return None
    if mesh is None or mesh.empty:
        return None
    return mesh


def mesh_axis_sizes(mesh) -> dict[str, int]:
    """``{axis_name: size}`` for abstract and physical meshes alike."""
    if mesh is None:
        return {}
    sizes = getattr(mesh, "axis_sizes", None)
    if sizes is not None:
        return dict(zip(mesh.axis_names, sizes))
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def shard_map(f, *, mesh, in_specs, out_specs, check: bool = False):
    """``shard_map`` with the replication/varying-manual-axes check kwarg
    spelled for the running jax (``check_vma`` new, ``check_rep`` old)."""
    if HAS_TOPLEVEL_SHARD_MAP:
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=check)


def sweep_mesh(wl: int = 1, knob: int = 1, *, devices=None):
    """Mesh for the multi-device sweep plane (ISSUE 5), axes named the
    way ``policies._evaluate_batch_backend`` dispatches on them:

    * ``wl``   — shards the stacked per-op axis (GSPMD when it is the
      only axis; inside the ``shard_map`` program otherwise);
    * ``knob`` — presence selects the explicit ``shard_map`` path and
      shards the unique-width / (width, delay)-pair / knob axes.

    So ``sweep_mesh(wl=8)`` is the pure-GSPMD data-sharding mesh (no
    knob axis is added), while any ``knob >= 1`` request — including
    the degenerate ``(wl=1, knob=1)`` the in-process tests use to
    cover the shard_map program on one device — yields a
    ``("wl", "knob")`` mesh and the explicit SPMD path.
    ``wl * knob`` must not exceed the available device count.
    """
    if knob == 1 and wl > 1:
        return make_mesh((wl,), ("wl",), devices=devices)
    return make_mesh((wl, knob), ("wl", "knob"), devices=devices)
