"""Logical-axis sharding rules (MaxText-style).

Model code names tensor dimensions with *logical* axes ("vocab", "mlp",
"batch", ...). A ``ShardingRules`` object maps logical axes to mesh axes; the
mapping degrades gracefully (an axis whose size does not divide the mesh axis
is left unsharded), which is what makes one model implementation serve
qwen3-32b (64 heads) and hymba (25 heads) on the same 16-way model axis.

Hillclimb variants are just different rule tables (see ``RULE_VARIANTS``).
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Sequence, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.parallel.jax_compat import get_abstract_mesh, mesh_axis_sizes

AxisTarget = Union[None, str, tuple[str, ...]]


@dataclass(frozen=True)
class ShardingRules:
    """logical axis -> mesh axis (or axis tuple)."""

    name: str
    param_rules: dict[str, AxisTarget]
    act_rules: dict[str, AxisTarget]

    def with_updates(self, name: str, param_updates=None, act_updates=None):
        pr = dict(self.param_rules)
        pr.update(param_updates or {})
        ar = dict(self.act_rules)
        ar.update(act_updates or {})
        return ShardingRules(name, pr, ar)


def _mesh_axis_sizes(mesh_shape: dict[str, int], target: AxisTarget) -> int:
    if target is None:
        return 1
    if isinstance(target, str):
        return mesh_shape.get(target, 1)
    n = 1
    for t in target:
        n *= mesh_shape.get(t, 1)
    return n


def _resolve(rules: dict[str, AxisTarget], axes: Sequence[Optional[str]],
             shape: Sequence[int], mesh_shape: dict[str, int]) -> P:
    """Map logical axes to a PartitionSpec with divisibility + dedup checks."""
    used: set[str] = set()
    out = []
    for dim, ax in zip(shape, axes):
        tgt = rules.get(ax) if ax is not None else None
        if tgt is None:
            out.append(None)
            continue
        names = (tgt,) if isinstance(tgt, str) else tuple(tgt)
        names = tuple(n for n in names if n in mesh_shape and n not in used)
        size = _mesh_axis_sizes(mesh_shape, names)
        if not names or size <= 1 or dim % size != 0:
            out.append(None)
            continue
        used.update(names)
        out.append(names[0] if len(names) == 1 else names)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def param_pspec(rules: ShardingRules, axes, shape, mesh_shape) -> P:
    return _resolve(rules.param_rules, axes, shape, mesh_shape)


def act_pspec(rules: ShardingRules, axes, shape, mesh_shape) -> P:
    return _resolve(rules.act_rules, axes, shape, mesh_shape)


def param_shardings(rules: ShardingRules, specs, mesh: Mesh):
    """NamedSharding tree for a ParamSpec tree."""
    from repro.models.param import is_spec

    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    return jax.tree.map(
        lambda s: NamedSharding(
            mesh, param_pspec(rules, s.axes, s.shape, mesh_shape)),
        specs, is_leaf=is_spec)


# --------------------------------------------------------------------------
# Rule tables. "baseline" is the paper-faithful starting point used for every
# cell; hillclimb variants are recorded in EXPERIMENTS.md §Perf.
# --------------------------------------------------------------------------

_FSDP = ("data",)           # parameter sharding over the data axis (FSDP)
_FSDP_POD = ("pod", "data")  # multi-pod FSDP
_BATCH = ("pod", "data")     # activation batch sharding

BASELINE = ShardingRules(
    name="baseline",
    param_rules={
        "vocab": "model",
        "embed": _FSDP_POD,
        "q_heads": "model",      # combined H*head_dim dim
        "kv_heads": "model",     # combined Hkv*head_dim dim
        "mlp": "model",
        "experts": "model",      # expert-parallelism
        "expert_mlp": None,
        "ssm_inner": "model",
        "state": None,
        "conv": None,
        "kv_lora": None,
        "q_lora": None,
        "heads": "model",        # per-head param dims (qk_norm scales)
        "frontend": None,
        "layers": None,
    },
    act_rules={
        "batch": _BATCH,
        "seq": None,
        "kv_seq": None,
        "embed": None,
        "vocab": "model",
        "heads": "model",
        "kv_heads": "model",
        "mlp": "model",
        "experts": "model",
        "ssm_inner": "model",
        "state": None,
        "kv_lora": None,
    },
)

# Sequence-parallel variant: shards the sequence dim of activations over the
# model axis in the norm/residual region (Megatron-SP analogue).
SEQ_PARALLEL = BASELINE.with_updates(
    "seq_parallel", act_updates={"seq": "model"})

# Long-context decode variant: shard the KV cache over its sequence dim
# (flash-decoding semantics: GSPMD lowers softmax over the sharded axis to
# partial reductions + all-reduce).
KV_SEQ = BASELINE.with_updates(
    "kv_seq", act_updates={"kv_seq": "model"},
    param_updates={})

# MoE hillclimb (fine-grained experts, e.g. granite's 0.5M-param experts):
# REPLICATE the expert bank instead of expert-parallelism. Dispatch becomes
# local to each data shard — the per-group buffer all-reduces disappear and
# only the usual FSDP weight all-gather remains. Wrong trade for big
# experts (deepseek); see EXPERIMENTS.md §Perf.
MOE_REPLICATED = BASELINE.with_updates(
    "moe_replicated",
    param_updates={"experts": ("data",)},  # FSDP-sharded storage, no EP
    act_updates={"experts": None})

RULE_VARIANTS: dict[str, ShardingRules] = {
    r.name: r for r in [BASELINE, SEQ_PARALLEL, KV_SEQ, MOE_REPLICATED]
}


# --------------------------------------------------------------------------
# Context: model code calls constrain(x, axes...) without threading rules.
# --------------------------------------------------------------------------

_CURRENT: contextvars.ContextVar[Optional[ShardingRules]] = \
    contextvars.ContextVar("sharding_rules", default=None)


@contextlib.contextmanager
def use_rules(rules: Optional[ShardingRules]):
    tok = _CURRENT.set(rules)
    try:
        yield
    finally:
        _CURRENT.reset(tok)


def current_rules() -> Optional[ShardingRules]:
    return _CURRENT.get()


def constrain(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """Apply a sharding constraint by logical activation axes (no-op when no
    rules are active or no mesh is set — keeps smoke tests single-device)."""
    rules = _CURRENT.get()
    if rules is None:
        return x
    mesh = get_abstract_mesh()
    if mesh is None:
        return x
    ps = act_pspec(rules, axes, x.shape, mesh_axis_sizes(mesh))
    return jax.lax.with_sharding_constraint(x, ps)
