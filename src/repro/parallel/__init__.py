from repro.parallel.sharding import (
    BASELINE, KV_SEQ, RULE_VARIANTS, SEQ_PARALLEL, ShardingRules, act_pspec,
    constrain, current_rules, param_pspec, param_shardings, use_rules)

__all__ = [
    "BASELINE", "KV_SEQ", "RULE_VARIANTS", "SEQ_PARALLEL", "ShardingRules",
    "act_pspec", "constrain", "current_rules", "param_pspec",
    "param_shardings", "use_rules",
]
