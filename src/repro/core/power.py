"""McPAT/NeuroMeter-style per-component power model (paper §4.4).

Calibration strategy (documented, since the paper's exact coefficients are
not published):

* **Static (leakage) power.** Chip static power at idle temperature is the
  published idle wattage (validated for TPUv2/v3 in the paper). Leakage at
  busy-die temperature is higher; we apply a technology-dependent thermal
  uplift. Busy static power is distributed over components with per-
  generation shares calibrated to reproduce the paper's Fig 3 breakdown
  (SA 8–14%, VU 1.9–5.6%, SRAM 15.4–24.4%, HBM 9–22.4%, ICI 5.3–12%,
  other 39.1–45.8%).
* **Dynamic power.** Max dynamic power = TDP − busy static; distributed by
  a fixed activity mix and scaled by per-component utilization.

The emergent quantities the benchmarks check against the paper: busy-chip
static energy fraction 30–72% (Fig 3), ReGate-Full savings 8.5–32.8%
(Fig 17), <0.5% perf overhead (Fig 19).
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.hw import NPUSpec

COMPONENTS = ("sa", "vu", "sram", "hbm", "ici", "other")

# per-generation static-power shares (calibrated to paper Fig 3 ranges)
STATIC_SHARES: dict[str, dict[str, float]] = {
    "NPU-A": {"sa": 0.080, "vu": 0.019, "sram": 0.154, "hbm": 0.224,
              "ici": 0.120, "other": 0.403},
    "NPU-B": {"sa": 0.090, "vu": 0.025, "sram": 0.170, "hbm": 0.200,
              "ici": 0.100, "other": 0.415},
    "NPU-C": {"sa": 0.100, "vu": 0.035, "sram": 0.220, "hbm": 0.120,
              "ici": 0.080, "other": 0.445},
    "NPU-D": {"sa": 0.110, "vu": 0.045, "sram": 0.220, "hbm": 0.100,
              "ici": 0.067, "other": 0.458},
    "NPU-E": {"sa": 0.140, "vu": 0.056, "sram": 0.244, "hbm": 0.090,
              "ici": 0.053, "other": 0.417},
}

# dynamic activity mix at full load
DYN_SHARES = {"sa": 0.50, "vu": 0.12, "sram": 0.12, "hbm": 0.16,
              "ici": 0.04, "other": 0.06}

# leakage thermal uplift idle-temp -> busy-temp, by node
_TEMP_UPLIFT = {16: 1.35, 7: 1.65, 4: 1.85}


@dataclass(frozen=True)
class PowerModel:
    npu: NPUSpec

    @property
    def static_busy_w(self) -> float:
        return self.npu.idle_w * _TEMP_UPLIFT[self.npu.tech_nm]

    @property
    def static_w(self) -> dict[str, float]:
        shares = STATIC_SHARES[self.npu.name]
        tot = self.static_busy_w
        return {c: tot * shares[c] for c in COMPONENTS}

    @property
    def dyn_max_w(self) -> dict[str, float]:
        tot = max(10.0, self.npu.tdp_w - self.static_busy_w)
        return {c: tot * DYN_SHARES[c] for c in COMPONENTS}

    @property
    def idle_chip_w(self) -> float:
        """Powered-on, out-of-duty-cycle chip (cool die)."""
        return self.npu.idle_w

    def idle_chip_gated_w(self, gated_components=("sa", "vu", "sram", "hbm",
                                                  "ici"),
                          deep_idle_other_leak: float = 0.2) -> float:
        """Idle chip with ReGate gating everything gateable (SRAM off).

        Out of the duty cycle no program is loaded, so the core control
        plane / datapaths ("other") can also be quiesced down to the
        management island (``deep_idle_other_leak`` of their static power)
        — during busy intervals "other" is never gated (paper §3)."""
        g = self.npu.gating
        shares = STATIC_SHARES[self.npu.name]
        w = 0.0
        for c in COMPONENTS:
            if c in gated_components:
                leak = (g.leak_sram_off if c == "sram" else g.leak_off_logic)
            elif c == "other":
                leak = deep_idle_other_leak
            else:
                leak = 1.0
            w += self.npu.idle_w * shares[c] * leak
        return w
