"""Program-plane lowering: workload traces -> per-unit cycle timelines.

The closed-form policy engine (``repro.core.policies``) and the ``setpm``
ISA plane (``repro.core.isa`` / ``repro.core.passes``) model the same
§4.2–4.4 software-managed gating decisions at two abstraction levels.
This module bridges them at workload scale:

1. ``lower_workload`` lowers a compiled ``TraceArrays`` into per-unit
   ``SlotUse`` streams (SA / VU / DMA / ICI) on a back-to-back cycle
   schedule, plus a per-instance SRAM-demand timeline.
2. The §4.3 passes run over the full-length program:
   ``analyze_vu_idleness`` + ``instrument_setpm`` place the VU ``setpm``
   pairs; SRAM dead intervals are analyzed per segment *band* (segments
   between two adjacent distinct demand values share one busy pattern,
   so the exact per-segment interval math vectorizes over ~tens of
   bands instead of ~32k segments — ``sram_band_gating``).
3. ``execute_program`` runs the instrumented program on the event-driven
   ``EventTimeline`` executor and folds in the closed-form intra-op VU
   burst model (shared with the policy engine: per-burst holes are
   sub-cycle-schedule detail in both planes).
4. ``crossval_record`` compares the resulting per-component gated-cycle
   fractions and setpm counts against ``policies.evaluate``'s
   ``ReGate-Full`` (sw) report. Tolerances are stated in EXPERIMENTS.md
   §Program-plane; the deviations are the transition-edge accounting
   (executor gates ``gap - delay`` where the closed form charges
   ``gap - 2*delay``) and merged within-op slack on the hw-managed
   components.

Scheduling model (mirrors the policy engine's timing semantics): ops run
back-to-back; per op, each component is busy for its own service time at
op start — except the VU, which bursts across the WHOLE duration of a
mixed op (paper Fig 15), so VU idle intervals visible to the compiler
pass are exactly the runs of VU-free ops.
"""
from __future__ import annotations

import weakref
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.core.hw import NPUSpec, SRAM_SEGMENT_BYTES, get_npu, \
    with_sa_width
from repro.core.isa import (EventTimeline, ExecResult, Instr, PMode,
                            expand_events, setpm)
from repro.core.opgen import TraceArrays, Workload, compile_trace
from repro.core.passes import (IdleInterval, SetpmPlacement, SlotUse,
                               analyze_vu_idleness, instrument_setpm,
                               should_gate)
from repro.core.policies import (PolicyKnobs, _component_policies,
                                 _fine_grained_vu_vec, evaluate,
                                 knob_columns, trace_times)

# component -> (unit name, FU kind) in the lowered program
UNIT_OF = {"sa": ("sa0", "sa"), "vu": ("vu0", "vu"),
           "hbm": ("dma0", "hbm"), "ici": ("ici0", "ici")}
COMP_OF_UNIT = {u: c for c, (u, _) in UNIT_OF.items()}

# the ReGate-Full machine the lowered programs execute on: SA wakes at
# PE granularity + hw idle detection, VU software-managed (initial ON,
# driven by the instrumented setpm), DMA/ICI hw idle detection. The
# perf gate (benchmarks/perf_timeline_executor.py) and the executor
# equality tests run THIS config — one definition, no drift.
REGATE_FULL_TIMELINE = dict(
    n_sa=1, n_vu=1, hw_auto_gating=True,
    extra_units={"dma0": "hbm", "ici0": "ici"},
    delay_keys={"sa": "sa_pe"},
    initial_modes={"vu0": PMode.ON},
)


@dataclass
class LoweredProgram:
    """A workload lowered onto the cycle-accurate program plane."""
    workload: str
    npu: NPUSpec
    horizon: int                       # nominal schedule length, cycles
    uses: dict[str, list[SlotUse]]     # unit -> sorted scheduled uses
    op_start: np.ndarray               # per-instance start cycle (i8)
    op_end: np.ndarray                 # per-instance end cycle (i8)
    inst_op: np.ndarray                # instance -> op row of the trace
    demand: np.ndarray                 # per-instance SRAM demand (bytes)
    tr: TraceArrays = field(repr=False)
    tm: dict = field(repr=False)

    @property
    def n_instances(self) -> int:
        return int(self.inst_op.size)


# Identity-keyed lowering cache, the ``compile_trace`` convention:
# (id(workload), id(npu spec)) -> the lowered program. NPU specs are
# module-level singletons (or ``with_sa_width`` memoized variants) and
# the cached value holds a strong ref to the spec, so its id stays
# valid for the entry's lifetime; the workload side is a weak ref with
# a finalizer, so ids can never be observed after reuse. This is what
# lets ``crossval_record`` / the batched program plane sweep the same
# suite repeatedly without re-lowering every call.
_LOWER_CACHE: dict[tuple[int, int],
                   tuple["weakref.ref", "LoweredProgram"]] = {}


def lower_workload(wl: Workload, npu: NPUSpec | str = "NPU-D") \
        -> LoweredProgram:
    """Expand the op stream (counts included) onto a back-to-back cycle
    schedule and emit per-unit SlotUse streams. Cached by (workload,
    npu-spec) identity, like ``compile_trace``."""
    npu = get_npu(npu) if isinstance(npu, str) else npu
    key = (id(wl), id(npu))
    hit = _LOWER_CACHE.get(key)
    if hit is not None and hit[0]() is wl and hit[1].npu is npu:
        return hit[1]
    tr = compile_trace(wl)
    tm = trace_times(tr, npu)
    inst_op = np.repeat(np.arange(tr.n_ops), tr.count.astype(np.int64))
    dur_s = tm["dur"][inst_op]
    # cumulative rounding: per-instance edges drift < 1 cycle from the
    # seconds-domain schedule over the whole program
    edges = np.round(np.concatenate(([0.0], np.cumsum(dur_s)))
                     * npu.freq_hz).astype(np.int64)
    op_start, op_end = edges[:-1], edges[1:]
    dur_cy = op_end - op_start

    uses: dict[str, list[SlotUse]] = {u: [] for u, _ in UNIT_OF.values()}
    for comp, (unit, _) in UNIT_OF.items():
        t_c = tm[comp][inst_op]
        active = (t_c > 0) & (dur_cy > 0)
        if comp == "vu":
            # VU bursts span the whole mixed-op duration (Fig 15); the
            # intra-op holes are the closed-form burst model's domain
            a_cy = dur_cy
        else:
            a_cy = np.minimum(
                dur_cy, np.maximum(1, np.round(t_c * npu.freq_hz)
                                   .astype(np.int64)))
        starts = op_start[active]
        lens = a_cy[active]
        uses[unit] = [SlotUse(int(s), unit, "op", int(d))
                      for s, d in zip(starts, lens)]
    prog = LoweredProgram(
        workload=wl.name, npu=npu, horizon=int(edges[-1]), uses=uses,
        op_start=op_start, op_end=op_end, inst_op=inst_op,
        demand=tr.sram_demand[inst_op], tr=tr, tm=tm)
    _LOWER_CACHE[key] = (weakref.ref(
        wl, lambda _: _LOWER_CACHE.pop(key, None)), prog)
    return prog


def rescale_program(prog: LoweredProgram, target_horizon: int) \
        -> LoweredProgram:
    """Compress a lowered program's schedule to ``target_horizon`` cycles
    (gap/duration ratios kept; same-unit uses whose scaled cycles
    collide keep the first use, so heavy compression thins the stream).

    Real suite programs span billions of cycles — far beyond what the
    dense cycle-stepper reference can step through — so the executor
    equality tests and the timeline perf gate run on compressed
    schedules. Compression can make same-unit uses overlap; both
    executors resolve that identically through the structural-hazard
    stall rule, so exact equality is unaffected.
    """
    f = target_horizon / max(1, prog.horizon)
    if f >= 1.0:
        return prog
    uses = {}
    for unit, us in prog.uses.items():
        seen: dict[int, SlotUse] = {}
        for u in us:
            c = int(u.cycle * f)
            if c not in seen:  # same-cycle collision: keep the first
                seen[c] = SlotUse(c, unit, u.opcode,
                                  max(1, int(u.duration * f)))
        uses[unit] = [seen[c] for c in sorted(seen)]
    start = np.floor(prog.op_start * f).astype(np.int64)
    end = np.maximum(np.floor(prog.op_end * f).astype(np.int64), start)
    return LoweredProgram(
        workload=prog.workload, npu=prog.npu, horizon=int(target_horizon),
        uses=uses, op_start=start, op_end=end, inst_op=prog.inst_op,
        demand=prog.demand, tr=prog.tr, tm=prog.tm)


# --------------------------------------------------------------------------
# §4.3 passes over the full-length program
# --------------------------------------------------------------------------

# instrumentation re-placement cache: the placements depend only on the
# program identity and the delay scale (BETs and wake delays move
# together under the §6.5 knob), so a (program, delay_scale) pair is
# computed once per sweep no matter how many window/leak knob points
# share it. Strong ref to the program keeps its id valid; a small FIFO
# bound keeps ad-hoc knob grids from growing the cache without limit.
_INSTR_CACHE: dict[tuple[int, float],
                   tuple[LoweredProgram, list[SetpmPlacement]]] = {}
_INSTR_CACHE_MAX = 256


def instrument_program(prog: LoweredProgram,
                       delay_scale: float = 1.0) -> list[SetpmPlacement]:
    """Run the VU idleness analysis + BET-based setpm insertion over the
    lowered program (the software-managed unit under ReGate-Full).
    ``delay_scale`` applies the §6.5 knob (BETs scale with the wake
    delays); results are cached per (program, delay_scale)."""
    key = (id(prog), float(delay_scale))
    hit = _INSTR_CACHE.get(key)
    if hit is not None and hit[0] is prog:
        return hit[1]
    vu_uses = prog.uses[UNIT_OF["vu"][0]]
    if not vu_uses:
        # VU never used: one whole-program gate
        idle = {UNIT_OF["vu"][0]:
                [IdleInterval(UNIT_OF["vu"][0], 0, prog.horizon)]}
    else:
        idle = analyze_vu_idleness(vu_uses, horizon=prog.horizon,
                                   include_leading=True)
    placements = instrument_setpm(idle, prog.npu, "vu",
                                  delay_scale=delay_scale)
    if len(_INSTR_CACHE) >= _INSTR_CACHE_MAX:
        _INSTR_CACHE.pop(next(iter(_INSTR_CACHE)))
    _INSTR_CACHE[key] = (prog, placements)
    return placements


def build_events(prog: LoweredProgram,
                 placements: Optional[list[SetpmPlacement]] = None) \
        -> list[tuple[int, dict[str, Instr]]]:
    """Merge per-unit uses + setpm placements into a sparse event list
    for ``EventTimeline`` (one bundle per cycle that carries anything).

    Colliding misc-slot setpms with the same (fu_type, mode) merge their
    bitmaps; a remaining collision slips one cycle later (the VLIW has a
    single misc slot per cycle)."""
    bundles: dict[int, dict[str, Instr]] = {}
    for unit, us in prog.uses.items():
        for u in us:
            bundles.setdefault(u.cycle, {})[unit] = \
                Instr(u.opcode, unit, u.duration)
    for p in sorted(placements or [], key=lambda p: p.cycle):
        c = max(0, p.cycle)
        ins = p.instr
        while True:
            b = bundles.setdefault(c, {})
            m = b.get("misc")
            if m is None:
                b["misc"] = ins
                break
            if (m.pm_fu_type == ins.pm_fu_type
                    and m.pm_mode == ins.pm_mode
                    and m.pm_range is None and ins.pm_range is None):
                b["misc"] = setpm(m.pm_fu_type,
                                  m.pm_bitmap | ins.pm_bitmap, m.pm_mode)
                break
            c += 1  # single misc slot per cycle: slip
    return sorted(bundles.items())


# --------------------------------------------------------------------------
# SRAM segment-band lifetime analysis
# --------------------------------------------------------------------------

def sram_band_gating(prog: LoweredProgram,
                     delay_scale: float = 1.0) -> dict:
    """Exact per-segment dead-interval gating, vectorized over segment
    bands.

    A segment at byte threshold T is live during instance i iff
    ``demand_i > T`` (buffers are stack-allocated from address 0, the
    paper's Fig 7 tile model). All segments whose thresholds fall
    between two adjacent distinct demand values therefore share one busy
    pattern, so the per-segment interval analysis runs once per band.
    Dead intervals gate under the same §4.3 rule as the closed-form sw
    policy (``should_gate``; transition cost 2x the on/off delay);
    contiguous segments of a band share one range-setpm pair (Fig 14
    variant 1).

    Returns gated segment-cycles, busy segment-cycles, range-setpm
    count, and the dead-segment count (never-used capacity).
    ``delay_scale`` scales BET and transition cost together (the
    closed-form engine's §6.5 convention).
    """
    npu = prog.npu
    n_seg = npu.sram_segments
    seg = SRAM_SEGMENT_BYTES
    horizon = int(prog.horizon)
    bet = npu.gating.bet["sram_off"] * delay_scale
    delay = npu.gating.on_off_delay["sram_off"] * delay_scale
    d = np.minimum(prog.demand, n_seg * seg)
    out = {"gated_segcycles": 0.0, "busy_segcycles": 0.0,
           "setpm": 0.0, "dead_segments": 0, "n_segments": n_seg,
           "capacity_cycles": float(n_seg) * horizon}
    if prog.n_instances == 0 or horizon == 0:
        return out
    vals = np.unique(d)
    # band j: thresholds in [lo_j, hi_j) are busy iff demand >= hi_j;
    # the final band [max_demand, capacity) is never busy
    lows = np.concatenate(([0.0], vals))
    highs = np.concatenate((vals, [float(n_seg) * seg]))
    # gated dead intervals dedup by (start, end): bands sharing a dead
    # interval collapse into one range-setpm pair (Fig 14 variant 1 +
    # the single misc slot, exactly like instrument_setpm's bitmaps)
    gap_keys: set[tuple[int, int]] = set()
    any_dead_band = False
    for lo, hi in zip(lows, highs):
        s0 = int(np.ceil(lo / seg))
        s1 = min(int(np.ceil(hi / seg)), n_seg)
        width = s1 - s0
        if width <= 0:
            continue
        if hi > vals[-1]:  # dead band: never used, one range-off setpm
            out["gated_segcycles"] += float(width) * horizon
            out["dead_segments"] += width
            any_dead_band = True
            continue
        busy = d >= hi
        idx = np.flatnonzero(busy)
        if idx.size == 0:
            out["gated_segcycles"] += float(width) * horizon
            any_dead_band = True
            continue
        starts = prog.op_start[idx]
        ends = prog.op_end[idx]
        out["busy_segcycles"] += float(width) * float(
            (ends - starts).sum())
        # merged dead intervals: leading + inter-use + trailing
        bounds_s = np.concatenate(([0], ends))
        bounds_e = np.concatenate((starts, [horizon]))
        gaps = (bounds_e - bounds_s).astype(np.float64)
        gate = should_gate(gaps, bet, delay)
        if gate.any():
            out["gated_segcycles"] += float(width) * float(
                (gaps[gate] - 2 * delay).sum())
            for s, e in zip(bounds_s[gate], bounds_e[gate]):
                gap_keys.add((int(s), int(e)))
    out["setpm"] = 2.0 * len(gap_keys) + (1.0 if any_dead_band else 0.0)
    return out


# --------------------------------------------------------------------------
# execution + cross-validation against the closed-form policy engine
# --------------------------------------------------------------------------

@dataclass
class ProgramPlaneSummary:
    workload: str
    npu: str
    horizon: int
    cycles: int                      # executed length incl. stalls
    n_events: int
    stall_cycles: int
    setpm_isa: dict[str, float]      # per component
    gated_cycles: dict[str, float]   # per component (sram: seg-cycle
    #                                  equivalent, capacity-normalized)
    gated_frac: dict[str, float]
    wake_events: dict[str, float]
    exec_result: ExecResult = field(repr=False)


def execute_program(prog: LoweredProgram,
                    placements: Optional[list[SetpmPlacement]] = None,
                    use_reference: bool = False,
                    knobs: Optional[PolicyKnobs] = None) \
        -> ProgramPlaneSummary:
    """Run the instrumented program (ReGate-Full semantics: SA at PE
    wake granularity + hw idle detection, VU software-managed via the
    inserted setpm pairs, DMA/ICI hw idle detection) and fold in the
    closed-form intra-op VU burst model and the SRAM band analysis.

    ``use_reference`` executes on the dense cycle-stepper instead of the
    event-driven executor (equality checks; O(cycles), so keep the
    program small). ``knobs`` threads the §6.5 delay/window scales
    through instrumentation, executor, and the closed-form folds
    (``knobs.sa_width`` must already be applied to ``prog``'s spec by
    lowering on the ``with_sa_width`` variant)."""
    npu = prog.npu
    knobs = knobs if knobs is not None else PolicyKnobs()
    if placements is None:
        placements = instrument_program(prog,
                                        delay_scale=knobs.delay_scale)
    events = build_events(prog, placements)
    tl_kw = dict(npu=npu, delay_scale=knobs.delay_scale,
                 window_scale=knobs.window_scale, **REGATE_FULL_TIMELINE)
    if use_reference:
        from repro.core.isa import VLIWTimeline
        res = VLIWTimeline(**tl_kw).run(
            expand_events(events, prog.horizon))
    else:
        res = EventTimeline(**tl_kw).run(events, horizon=prog.horizon)

    gated = {c: float(res.fu_gated_cycles[u])
             for c, (u, _) in UNIT_OF.items()}
    wakes = {c: float(res.wake_events[u]) for c, (u, _) in UNIT_OF.items()}
    setpm_isa = {c: 0.0 for c in UNIT_OF}
    for p in placements:
        setpm_isa[p.instr.pm_fu_type] = setpm_isa.get(
            p.instr.pm_fu_type, 0.0) + 1.0

    # intra-op VU bursts: closed form shared with the policy engine
    leak = knobs.leak_off_logic if knobs.leak_off_logic is not None \
        else npu.gating.leak_off_logic
    fv = _fine_grained_vu_vec(
        prog.tm, prog.tr, npu, _component_policies("ReGate-Full")["vu"],
        1.0, leak, knobs)
    gated["vu"] += fv["gated_s"] * npu.freq_hz
    setpm_isa["vu"] += fv["setpm"]
    wakes["vu"] += fv["wakes"]

    # SRAM segment bands
    sb = sram_band_gating(prog, delay_scale=knobs.delay_scale)
    gated["sram"] = sb["gated_segcycles"] / max(1, sb["n_segments"])
    setpm_isa["sram"] = sb["setpm"]

    cycles = max(1, res.cycles)
    frac = {c: gated[c] / cycles for c in gated}
    return ProgramPlaneSummary(
        workload=prog.workload, npu=npu.name, horizon=prog.horizon,
        cycles=res.cycles, n_events=len(events),
        stall_cycles=res.stall_cycles, setpm_isa=setpm_isa,
        gated_cycles=gated, gated_frac=frac, wake_events=wakes,
        exec_result=res)


def plane_record(workload: str, npu: NPUSpec, knobs: PolicyKnobs,
                 knob_idx: int, prog: dict, policy: dict) -> dict:
    """Assemble one program-plane sweep record from scalar inputs.

    The single schema shared by the per-cell oracle
    (``crossval_record``) and the batched plane
    (``repro.core.program_plane``), so record-for-record comparison is
    a key-by-key equality. ``prog`` carries the executor-side scalars
    (cycles, stall_cycles, n_events, per-component gated cycles / wake
    events, setpm counts); ``policy`` the closed-form side (runtime_s,
    per-component gated_s, setpm counts). Every ``KnobGrid`` column is
    emitted unconditionally (the PR-7 contract: ``with_savings`` /
    ``group_by`` consumers key on them)."""
    rt_cy = npu.cycles(policy["runtime_s"])
    cycles = max(1, int(prog["cycles"]))
    rec = {
        "workload": workload, "npu": npu.name,
        "policy": "ReGate-Full",
        **knob_columns(knobs, knob_idx),
        "prog_cycles": int(prog["cycles"]), "policy_cycles": rt_cy,
        "runtime_rel_err": abs(prog["cycles"] - rt_cy) / max(1.0, rt_cy),
        "n_events": int(prog["n_events"]),
        "stall_cycles": int(prog["stall_cycles"]),
    }
    for c in ("sa", "vu", "hbm", "ici", "sram"):
        pol_frac = policy["gated_s"][c] / max(1e-30, policy["runtime_s"])
        frac = prog["gated_cycles"][c] / cycles
        rec[f"gated_frac_policy_{c}"] = pol_frac
        rec[f"gated_frac_prog_{c}"] = frac
        rec[f"gated_frac_absdiff_{c}"] = abs(frac - pol_frac)
        rec[f"gated_s_prog_{c}"] = prog["gated_cycles"][c] / npu.freq_hz
    for c in ("sa", "vu", "hbm", "ici"):
        rec[f"wakes_prog_{c}"] = prog["wake_events"][c]
    for c in ("vu", "sram"):  # the sw-managed components emit setpm
        rec[f"setpm_policy_{c}"] = policy["setpm_by"][c]
        rec[f"setpm_prog_{c}"] = prog["setpm_isa"][c]
    return rec


def crossval_record(wl: Workload, npu: NPUSpec | str = "NPU-D",
                    knobs: Optional[PolicyKnobs] = None,
                    knob_idx: int = 0) -> dict:
    """One flat record comparing the program plane against the
    closed-form ``ReGate-Full`` (sw) policy evaluation, at one knob
    point (lowering, instrumentation, and trace compilation all ride
    their identity caches, so repeated sweeps stop re-lowering)."""
    npu = get_npu(npu) if isinstance(npu, str) else npu
    knobs = knobs if knobs is not None else PolicyKnobs()
    rep = evaluate(wl, npu, "ReGate-Full", knobs)
    prog = lower_workload(wl, with_sa_width(npu, knobs.sa_width))
    summ = execute_program(prog, knobs=knobs)
    return plane_record(
        wl.name, npu, knobs, knob_idx,
        prog={"cycles": summ.cycles, "n_events": summ.n_events,
              "stall_cycles": summ.stall_cycles,
              "gated_cycles": summ.gated_cycles,
              "wake_events": summ.wake_events,
              "setpm_isa": summ.setpm_isa},
        policy={"runtime_s": rep.runtime_s, "gated_s": rep.gated_s,
                "setpm_by": rep.setpm_by})
