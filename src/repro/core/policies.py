"""Power-gating policy engine — the simulator backend (paper §4, §6).

Evaluates a Workload trace on an NPUSpec under one of five designs:

* ``NoPG``        — no power gating (baseline).
* ``ReGate-Base`` — conventional HW idle-detection at component granularity
                    (detection window = BET/3); the SA gates as a whole;
                    SRAM can only SLEEP (hardware can't prove a segment
                    dead); exposed wake-up delays extend the runtime.
* ``ReGate-HW``   — + PE-level spatial SA gating (row/col zero-weight
                    prefix bitmaps + diagonal PE_on propagation): SA static
                    follows ``sa_gating.gating_stats`` occupancy, exposed SA
                    wake drops to a single PE delay.
* ``ReGate-Full`` — + SW-managed VU & SRAM via ``setpm``: exact idle
                    intervals (no detection window waste), wakes hidden by
                    the compiler, unused SRAM segments fully OFF.
* ``Ideal``       — zero leakage when gated, zero delays, every idle cycle
                    gated (roofline).

Timing model: per op, each component is active for its own service time;
op duration = max over components (perfect overlap); ops run back-to-back.
Idle intervals per component are the within-op slack plus whole ops where
the component is unused, merged across op boundaries.

Two engines share these semantics:

* ``evaluate`` — columnar: the workload is compiled once into
  ``TraceArrays`` (struct-of-arrays), per-component service times and the
  SA-occupancy math are batched over the whole op stream, idle-gap
  merging is a segmented reduction, and ``_gated_idle_energy`` is applied
  as a piecewise-vectorized closed form. This is the production path.
* ``evaluate_reference`` — the original pure-Python per-op loop, kept as
  the oracle; the equivalence tests hold the two to ≤1e-9 relative on
  every EnergyReport field.

A third layer batches whole design-space sweeps:

* ``evaluate_batch`` — the sweep plane: stacks every workload trace into
  one ragged super-trace (``opgen.stack_traces``), reuses per-(trace,
  NPU) service times across the policy × knob axes, carries the knob
  grid as a trailing array dimension, and memoizes per-component
  results across policies that share a component configuration. One
  call covers the full (workload × npu × policy × knob) cross product
  in a handful of array passes; cell-for-cell ≤1e-9 relative to
  ``evaluate``. Via ``backend="jax"`` the same sweep runs as one
  ``jax.jit``-compiled float64 program (``repro.core.backend``): gap
  chunking moves to a host-built fixed-shape index, per-NPU numbers
  enter as traced arrays so one compiled program serves every
  generation, and — since ISSUE 5 — the per-op service times and SA
  PE-occupancy math are *traced* too (``bk.sa_occupancy``; SA width is
  a real ``PolicyKnobs.sa_width`` knob axis). Heavy O(n_ops) work is
  vmapped over the unique SA widths and the unique (width, delay)
  pairs with the leakage knobs folded in linearly afterwards. A
  ``jax_mesh`` scales the program out across devices — GSPMD op-axis
  sharding on a ``("wl",)`` mesh, or an explicit ``shard_map`` SPMD
  program when the mesh has a ``"knob"`` axis — record-for-record
  ≤1e-9 against the numpy path, which stays the oracle.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Optional, Sequence

import numpy as np

from repro.core import backend as backend_mod
from repro.core.backend import gap_index, get_backend
from repro.core.hw import NPUSpec, get_npu, with_sa_width
from repro.core.opgen import (Op, StackedTrace, TraceArrays, Workload,
                              compile_trace, segment_sum, segmented_gaps,
                              stack_traces)
from repro.core.power import COMPONENTS, PowerModel
from repro.core.sa_gating import SAStats, gating_stats, gating_stats_batch

POLICIES = ("NoPG", "ReGate-Base", "ReGate-HW", "ReGate-Full", "Ideal")

GATEABLE = ("sa", "vu", "sram", "hbm", "ici")


@dataclass(frozen=True)
class PolicyKnobs:
    """Sensitivity-analysis overrides (paper §6.5).

    ``sa_width`` overrides the NPU's systolic-array width (``None`` →
    native). It is a real knob axis: the scalar engines evaluate on a
    memoized ``hw.with_sa_width`` variant spec, the numpy batched plane
    groups the knob grid by effective width, and the jax sweep kernel
    carries the width as a *traced* scalar so one compiled program
    serves the whole width axis. Note SA peak FLOP/s is derived from
    the width, so this axis moves throughput and occupancy together —
    the paper's §6.5 width sensitivity, without per-width NPU variants.
    """
    leak_off_logic: Optional[float] = None
    leak_sram_sleep: Optional[float] = None
    leak_sram_off: Optional[float] = None
    delay_scale: float = 1.0  # scales wake-up delays and BETs
    sa_width: Optional[int] = None
    # Scales ONLY the HW idle-detection window (paper default BET/3),
    # leaving wake-up delays and BETs alone — the genuine detection-
    # threshold axis for the jitter-plane robustness sweep.
    window_scale: float = 1.0


def _knob_axis(name: str, values) -> tuple:
    """Coerce one ``KnobGrid`` axis to a validated tuple. A bare scalar
    (including ``None``) is a one-point axis."""
    if values is None or np.isscalar(values):
        values = (values,)
    axis = tuple(values)
    if not axis:
        raise ValueError(f"KnobGrid axis {name!r} must be non-empty")
    for v in axis:
        if name in ("delay_scale", "window_scale"):
            if v is None or not (np.isfinite(v) and v > 0):
                raise ValueError(
                    f"KnobGrid axis {name!r}: values must be finite and "
                    f"> 0, got {v!r}")
        elif name == "sa_width":
            if v is not None and not (float(v).is_integer()
                                      and int(v) >= 1):
                raise ValueError(
                    f"KnobGrid axis {name!r}: values must be None or "
                    f"an integer >= 1, got {v!r}")
        else:  # leakage fractions
            if v is not None and not (np.isfinite(v) and v >= 0):
                raise ValueError(
                    f"KnobGrid axis {name!r}: values must be None or "
                    f"finite and >= 0, got {v!r}")
    return axis


@dataclass(frozen=True)
class KnobGrid:
    """The §6.5 sensitivity axes as one first-class object (ISSUE 7).

    Replaces the six parallel kwargs that used to be repeated across
    ``knob_product`` / ``sweep_grid`` / ``sweep_robustness``: each field
    is one axis (a bare scalar is a one-point axis; ``None`` entries
    mean the per-NPU Table 3 default, and ``sa_width=None`` the
    generation's native width), validated at construction, and
    ``product()`` crosses them into the flat ``PolicyKnobs`` grid in
    the canonical knob ordering — ``sa_width`` outermost, then
    ``window_scale``, then ``delay_scale``, ``leak_off_logic``,
    ``leak_sram_sleep``, ``leak_sram_off`` innermost (byte-identical to
    the legacy ``knob_product`` ordering, so record tables and
    ``knob_idx`` values are unchanged). All sweep entry points
    (``sweep`` / ``sweep_grid`` / ``evaluate_batch`` / ``sweep_fleet``)
    accept a ``KnobGrid`` wherever they accept a knob sequence.
    """

    delay_scale: Sequence[float] = (1.0,)
    leak_off_logic: Sequence[Optional[float]] = (None,)
    leak_sram_sleep: Sequence[Optional[float]] = (None,)
    leak_sram_off: Sequence[Optional[float]] = (None,)
    sa_width: Sequence[Optional[int]] = (None,)
    window_scale: Sequence[float] = (1.0,)

    #: record-table column names for the knob axes (with ``knob_idx``
    #: these are the columns every sweep record carries unconditionally)
    COLUMNS = ("delay_scale", "leak_off_logic", "leak_sram_sleep",
               "leak_sram_off", "sa_width", "window_scale")

    def __post_init__(self):
        for name in self.COLUMNS:
            object.__setattr__(self, name,
                               _knob_axis(name, getattr(self, name)))

    @classmethod
    def columns(cls) -> tuple[str, ...]:
        """The knob column names emitted into every sweep record."""
        return cls.COLUMNS

    @property
    def size(self) -> int:
        n = 1
        for name in self.COLUMNS:
            n *= len(getattr(self, name))
        return n

    def product(self) -> list[PolicyKnobs]:
        """Cross the axes into the flat knob grid (canonical order)."""
        return [PolicyKnobs(delay_scale=d, leak_off_logic=lo,
                            leak_sram_sleep=ls, leak_sram_off=lf,
                            sa_width=sw, window_scale=w)
                for sw in self.sa_width for w in self.window_scale
                for d in self.delay_scale
                for lo in self.leak_off_logic
                for ls in self.leak_sram_sleep
                for lf in self.leak_sram_off]


def as_knob_tuple(knob_grid) -> tuple[PolicyKnobs, ...]:
    """Normalize any accepted knob-grid spelling — ``None`` (the single
    default knob point), a ``KnobGrid``, or a sequence of
    ``PolicyKnobs`` — to the flat tuple the batched engines consume."""
    if knob_grid is None:
        return (PolicyKnobs(),)
    if isinstance(knob_grid, KnobGrid):
        return tuple(knob_grid.product())
    return tuple(knob_grid)


def knob_columns(knobs: PolicyKnobs, knob_idx: int) -> dict:
    """The knob columns of one sweep record (``knob_idx`` + every
    ``KnobGrid.columns()`` entry, emitted unconditionally so record
    consumers like ``sweep.with_savings``/``sweep.group_by`` never see
    a missing axis)."""
    rec = {"knob_idx": int(knob_idx)}
    for name in KnobGrid.COLUMNS:
        rec[name] = getattr(knobs, name)
    return rec


@dataclass
class EnergyReport:
    workload: str
    policy: str
    npu: str
    runtime_s: float
    static_j: dict[str, float]
    dynamic_j: dict[str, float]
    setpm_count: float = 0.0
    wake_events: dict[str, float] = field(default_factory=dict)
    # per-component time spent power-gated, in seconds (sram: unused-
    # capacity-weighted seconds, i.e. capacity_fraction x time integral);
    # temporal gating only — SA spatial PE-gating is tracked separately
    # through sa_gating occupancy
    gated_s: dict[str, float] = field(default_factory=dict)
    # per-component setpm instruction counts (sums to setpm_count)
    setpm_by: dict[str, float] = field(default_factory=dict)

    @property
    def total_j(self) -> float:
        return sum(self.static_j.values()) + sum(self.dynamic_j.values())

    @property
    def avg_power_w(self) -> float:
        return self.total_j / max(1e-12, self.runtime_s)

    @property
    def static_frac(self) -> float:
        return sum(self.static_j.values()) / max(1e-12, self.total_j)

    def setpm_per_1k_cycles(self, npu: NPUSpec) -> float:
        return self.setpm_count / max(1.0, npu.cycles(self.runtime_s)) * 1e3


# --------------------------------------------------------------------------
# per-op component service times
# --------------------------------------------------------------------------

def op_times(op: Op, npu: NPUSpec) -> dict[str, float]:
    eff = 1.0
    stats: Optional[SAStats] = None
    if op.flops_sa > 0 and op.matmul_dims is not None:
        stats = gating_stats(*op.matmul_dims, npu.sa_width)
        # achieved throughput scales with ON-PE occupancy
        flops_cycles = op.matmul_dims[0] * op.matmul_dims[1] \
            * op.matmul_dims[2] / (npu.sa_width ** 2)
        eff = min(1.0, flops_cycles / max(1e-9, stats.duration_cycles))
        eff = max(eff, 1e-3)
    t = {
        "sa": op.flops_sa / (npu.sa_flops * eff) if op.flops_sa else 0.0,
        "vu": op.flops_vu / npu.vu_flops if op.flops_vu else 0.0,
        "hbm": op.bytes_hbm / npu.hbm_bw if op.bytes_hbm else 0.0,
        "ici": op.bytes_ici / npu.ici_bw if op.bytes_ici else 0.0,
    }
    dur = max(max(t.values()), 1e-12)
    t["sram"] = dur  # SRAM serves whoever is active
    t["other"] = dur
    t["_dur"] = dur
    t["_sa_eff"] = eff
    return t


# --------------------------------------------------------------------------
# policy semantics per component
# --------------------------------------------------------------------------

def _gated_idle_energy(gap_s: float, p_static: float, *, mode: str,
                       bet_s: float, delay_s: float, window_s: float,
                       leak: float) \
        -> tuple[float, float, float, float, float]:
    """Energy spent during one idle interval of length ``gap_s``.

    Returns (energy_J, exposed_wake_s, wake_events, setpm_count,
    gated_s). mode: "none" | "hw" | "sw" | "ideal".
    """
    if gap_s <= 0:
        return 0.0, 0.0, 0.0, 0.0, 0.0
    if mode == "none":
        return p_static * gap_s, 0.0, 0.0, 0.0, 0.0
    if mode == "ideal":
        return 0.0, 0.0, 0.0, 0.0, gap_s
    if mode == "hw":
        # observe for the detection window, then gate if still idle;
        # next use pays the exposed wake-up delay.
        if gap_s <= window_s:
            return p_static * gap_s, 0.0, 0.0, 0.0, 0.0
        gated = gap_s - window_s
        e = p_static * window_s + leak * p_static * gated \
            + p_static * delay_s  # transition energy (on/off ramp)
        return e, delay_s, 1.0, 0.0, gated
    # sw: compiler knows the interval; gate only if profitable & hideable
    if gap_s >= max(bet_s, 2.0 * delay_s):
        e = leak * p_static * (gap_s - 2 * delay_s) \
            + p_static * 2 * delay_s
        # setpm off + setpm on; 2x delay held at full power (transition)
        return e, 0.0, 1.0, 2.0, gap_s - 2 * delay_s
    return p_static * gap_s, 0.0, 0.0, 0.0, 0.0


@dataclass(frozen=True)
class _CompPolicy:
    mode: str          # none | hw | sw | ideal
    delay_key: str     # key into gating tables
    spatial_sa: bool = False
    sram_state: str = "on"  # on | sleep | off | ideal (unused-capacity)


def _component_policies(policy: str) -> dict[str, _CompPolicy]:
    if policy == "NoPG":
        return {c: _CompPolicy("none", "") for c in COMPONENTS}
    if policy == "Ideal":
        d = {c: _CompPolicy("ideal", "", spatial_sa=True,
                            sram_state="ideal") for c in COMPONENTS}
        d["other"] = _CompPolicy("none", "")
        return d
    base = {
        "sa": _CompPolicy("hw", "sa_full"),
        "vu": _CompPolicy("hw", "vu"),
        "hbm": _CompPolicy("hw", "hbm"),
        "ici": _CompPolicy("hw", "ici"),
        "sram": _CompPolicy("hw", "sram_sleep", sram_state="sleep"),
        "other": _CompPolicy("none", ""),
    }
    if policy == "ReGate-Base":
        return base
    if policy == "ReGate-HW":
        base["sa"] = _CompPolicy("hw", "sa_pe", spatial_sa=True)
        return base
    if policy == "ReGate-Full":
        base["sa"] = _CompPolicy("hw", "sa_pe", spatial_sa=True)
        base["vu"] = _CompPolicy("sw", "vu")
        base["sram"] = _CompPolicy("sw", "sram_off", sram_state="off")
        return base
    raise KeyError(policy)


# --------------------------------------------------------------------------
# evaluation — scalar reference engine (original per-op loop)
# --------------------------------------------------------------------------

def evaluate_reference(wl: Workload, npu: NPUSpec | str = "NPU-D",
                       policy: str = "ReGate-Full",
                       knobs: PolicyKnobs = PolicyKnobs()) -> EnergyReport:
    npu = get_npu(npu) if isinstance(npu, str) else npu
    npu = with_sa_width(npu, knobs.sa_width)
    pm = PowerModel(npu)
    g = npu.gating
    cp = _component_policies(policy)

    leak_logic = knobs.leak_off_logic if knobs.leak_off_logic is not None \
        else g.leak_off_logic
    leak_sleep = knobs.leak_sram_sleep if knobs.leak_sram_sleep is not None \
        else g.leak_sram_sleep
    leak_off = knobs.leak_sram_off if knobs.leak_sram_off is not None \
        else g.leak_sram_off

    def delay_s(key: str) -> float:
        return g.on_off_delay.get(key, 0) * knobs.delay_scale / npu.freq_hz

    def bet_s(key: str) -> float:
        return g.bet.get(key, 0) * knobs.delay_scale / npu.freq_hz

    static_w = pm.static_w
    dyn_w = pm.dyn_max_w

    static_j = {c: 0.0 for c in COMPONENTS}
    dynamic_j = {c: 0.0 for c in COMPONENTS}
    runtime = 0.0
    overhead = 0.0
    setpm_by = {c: 0.0 for c in COMPONENTS}
    gated = {c: 0.0 for c in COMPONENTS}
    wakes = {c: 0.0 for c in COMPONENTS}

    # pending idle gap per component (merged across ops)
    pending = {c: 0.0 for c in COMPONENTS}

    def close_gap(c: str):
        nonlocal overhead
        gap = pending[c]
        pending[c] = 0.0
        if gap <= 0:
            return
        pol = cp[c]
        # HBM auto-refresh is a FLOOR: the DRAM refresh burn does not
        # shrink when the logic threshold voltage changes (paper §6.5)
        leak = max(leak_logic, g.leak_hbm_refresh) if c == "hbm" \
            else leak_logic
        e, exposed, nw, sp, gs = _gated_idle_energy(
            gap, static_w[c], mode=pol.mode, bet_s=bet_s(pol.delay_key),
            delay_s=delay_s(pol.delay_key),
            window_s=bet_s(pol.delay_key) * g.detection_window_frac
            * knobs.window_scale,
            leak=leak)
        static_j[c] += e
        overhead_local = exposed
        if c in ("hbm", "ici"):
            # wake overlapped with the long DMA issue latency half the time
            overhead_local *= 0.5
        nonlocal_overhead(overhead_local)
        setpm_by[c] += sp
        gated[c] += gs
        wakes[c] += nw

    def nonlocal_overhead(x: float):
        nonlocal overhead
        overhead += x

    def fine_grained_vu(t_vu: float, dur: float, n: int):
        """VU slack inside a mixed op is fragmented into per-burst gaps
        (paper Fig 15): HW detection mostly cannot exploit them, SW setpm
        can. Returns nothing; mutates accumulators."""
        pol = cp["vu"]
        slack = dur - t_vu
        if slack <= 0:
            return
        active_cy = max(1.0, npu.cycles(t_vu))
        n_bursts = max(1.0, active_cy / g.vu_burst_cycles)
        gap_cy = npu.cycles(slack) / n_bursts
        bet_cy = g.bet["vu"] * knobs.delay_scale
        delay_cy = g.on_off_delay["vu"] * knobs.delay_scale
        window_cy = bet_cy * g.detection_window_frac * knobs.window_scale
        p = static_w["vu"]
        if pol.mode == "none":
            static_j["vu"] += p * slack * n
        elif pol.mode == "ideal":
            gated["vu"] += slack * n
        elif pol.mode == "hw":
            if gap_cy > bet_cy:
                gated_frac = max(0.0, (gap_cy - window_cy) / gap_cy)
                static_j["vu"] += p * slack * n * (
                    (1 - gated_frac) + leak_logic * gated_frac)
                gated["vu"] += slack * n * gated_frac
                # exposed wake per burst: Base/HW hardware cannot pre-wake
                nonlocal_overhead(n_bursts * delay_cy / npu.freq_hz * n)
                wakes["vu"] += n_bursts * n
            else:
                static_j["vu"] += p * slack * n
        else:  # sw
            if gap_cy >= max(bet_cy, 2 * delay_cy):
                trans = 2 * delay_cy / gap_cy
                static_j["vu"] += p * slack * n * (
                    trans + leak_logic * (1 - trans))
                gated["vu"] += slack * n * (1 - trans)
                setpm_by["vu"] += 2 * n_bursts * n
                wakes["vu"] += n_bursts * n
            else:
                static_j["vu"] += p * slack * n

    prev_used: Optional[float] = None  # sram setpm boundary tracking
    for op in wl.ops:
        t = op_times(op, npu)
        dur = t["_dur"]
        n = op.count
        for c in COMPONENTS:
            a = t[c] if c in t else 0.0
            if c in ("sram", "other"):
                a = dur  # handled below
            if a > 0:
                close_gap(c)

        # --- active-time static & dynamic energy (xN instances) ---
        for c in ("sa", "vu", "hbm", "ici"):
            a = t[c]
            if a <= 0:
                pending[c] += dur * n
                continue
            pol = cp[c]
            # dynamic: proportional to useful work
            if c == "sa":
                dynamic_j[c] += dyn_w[c] * (op.flops_sa / npu.sa_flops) * n
            else:
                dynamic_j[c] += dyn_w[c] * a * n
            # static during the active portion
            if c == "sa" and pol.spatial_sa and op.matmul_dims is not None:
                st = gating_stats(*op.matmul_dims, npu.sa_width)
                occ = (st.frac_on + g.leak_pe_weight_on * st.frac_w_on
                       + leak_logic * st.frac_off)
                if pol.mode == "ideal":
                    occ = st.frac_on
                static_j[c] += static_w[c] * occ * a * n
            else:
                static_j[c] += static_w[c] * a * n
            # within-op slack
            if c == "vu":
                fine_grained_vu(a, dur, n)
                continue
            slack = dur - a
            if slack > 0:
                leak = max(leak_logic, g.leak_hbm_refresh) if c == "hbm" \
                    else leak_logic
                e, exposed, nw, sp, gs = _gated_idle_energy(
                    slack, static_w[c], mode=pol.mode,
                    bet_s=bet_s(pol.delay_key),
                    delay_s=delay_s(pol.delay_key),
                    window_s=bet_s(pol.delay_key)
                    * g.detection_window_frac * knobs.window_scale,
                    leak=leak)
                static_j[c] += e * n
                ov = exposed * n
                if c in ("hbm", "ici"):
                    ov *= 0.5
                nonlocal_overhead(ov)
                setpm_by[c] += sp * n
                gated[c] += gs * n
                wakes[c] += nw * n

        # --- SRAM: capacity-proportional static, demand-gated remainder ---
        pol = cp["sram"]
        used = min(1.0, op.sram_demand / npu.sram_bytes)
        unused = 1.0 - used
        if pol.sram_state == "on":
            sram_leak_unused = 1.0
        elif pol.sram_state == "sleep":
            sram_leak_unused = leak_sleep
        elif pol.sram_state == "off":
            sram_leak_unused = leak_off
        else:  # ideal
            sram_leak_unused = 0.0
        static_j["sram"] += static_w["sram"] * dur * n * (
            used + unused * sram_leak_unused)
        if pol.sram_state != "on":
            gated["sram"] += unused * dur * n
        if pol.sram_state in ("sleep", "off") and pol.mode == "sw":
            # one range-setpm pair per demand-CHANGE boundary (Fig 14
            # variant 1 collapses contiguous segments; a boundary where
            # the footprint is unchanged needs no instruction), plus the
            # initial gate of the above-demand range
            if (used < 1.0 if prev_used is None else used != prev_used):
                setpm_by["sram"] += 2.0
        prev_used = used
        dynamic_j["sram"] += dyn_w["sram"] * max(
            t["sa"], t["vu"], t["hbm"], t["ici"]) * 0.5 * n

        # --- other: never gated ---
        static_j["other"] += static_w["other"] * dur * n
        dynamic_j["other"] += dyn_w["other"] * dur * 0.3 * n

        runtime += dur * n

    # close trailing gaps
    for c in COMPONENTS:
        close_gap(c)

    runtime += overhead
    return EnergyReport(
        workload=wl.name, policy=policy, npu=npu.name,
        runtime_s=runtime, static_j=static_j, dynamic_j=dynamic_j,
        setpm_count=sum(setpm_by.values()), wake_events=wakes,
        gated_s=gated, setpm_by=setpm_by)


# --------------------------------------------------------------------------
# evaluation — columnar vectorized engine
# --------------------------------------------------------------------------

def trace_times(tr: TraceArrays, npu: NPUSpec) -> dict[str, np.ndarray]:
    """Per-op service-time arrays for one NPU (the columnar ``op_times``).

    Cached on the trace, keyed by NPUSpec identity (ad-hoc ``replace()``d
    specs may reuse a registry name with different hardware): times and
    SA-occupancy fractions depend only on the hardware, not on policy or
    knobs, so one computation serves every cell of a (policy × knobs)
    sweep.
    """
    hit = tr._derived.get(id(npu))
    if hit is not None and hit[0] is npu:
        return hit[1]
    n = tr.n_ops
    eff = np.ones(n)
    frac_on = np.zeros(n)
    frac_w_on = np.zeros(n)
    frac_off = np.zeros(n)
    mm = tr.has_mm
    if mm.any():
        st = gating_stats_batch(tr.mm_m[mm], tr.mm_k[mm], tr.mm_n[mm],
                                npu.sa_width)
        frac_on[mm] = st.frac_on
        frac_w_on[mm] = st.frac_w_on
        frac_off[mm] = st.frac_off
        sa_mm = mm & (tr.flops_sa > 0)
        flops_cycles = (tr.mm_m * tr.mm_k).astype(np.float64) * tr.mm_n \
            / (npu.sa_width ** 2)
        dur_cy = np.ones(n)
        dur_cy[mm] = st.duration_cycles
        e = np.minimum(1.0, flops_cycles / np.maximum(1e-9, dur_cy))
        eff[sa_mm] = np.maximum(e[sa_mm], 1e-3)
    t_sa = np.where(tr.flops_sa > 0, tr.flops_sa / (npu.sa_flops * eff), 0.0)
    t_vu = np.where(tr.flops_vu > 0, tr.flops_vu / npu.vu_flops, 0.0)
    t_hbm = np.where(tr.bytes_hbm > 0, tr.bytes_hbm / npu.hbm_bw, 0.0)
    t_ici = np.where(tr.bytes_ici > 0, tr.bytes_ici / npu.ici_bw, 0.0)
    max4 = np.maximum(np.maximum(t_sa, t_vu), np.maximum(t_hbm, t_ici))
    out = {
        "sa": t_sa, "vu": t_vu, "hbm": t_hbm, "ici": t_ici,
        "max4": max4, "dur": np.maximum(max4, 1e-12), "sa_eff": eff,
        "frac_on": frac_on, "frac_w_on": frac_w_on, "frac_off": frac_off,
    }
    tr._derived[id(npu)] = (npu, out)
    return out


def _merged_gaps(active: np.ndarray, idle: np.ndarray) -> np.ndarray:
    """Idle-gap lengths per maximal run of inactive ops.

    ``idle`` holds dur*count where the component is inactive, 0 where
    active. Returns one gap per active op (the merged idle time since the
    previous active op) plus one trailing gap — exactly the intervals the
    scalar engine's ``close_gap`` sees. Segment sums are accumulated
    left-to-right via ``np.add.reduceat``, matching the scalar's
    sequential ``pending +=`` order.
    """
    idx = np.flatnonzero(active)
    if idx.size == 0:
        return np.array([idle.sum()])
    idle2 = np.append(idle, 0.0)
    bounds = np.concatenate(([0], idx + 1))
    return np.add.reduceat(idle2, bounds)


def _gated_idle_energy_vec(gap: np.ndarray, p_static: float, *, mode: str,
                           bet_s: float, delay_s: float, window_s: float,
                           leak: float):
    """Piecewise-vectorized ``_gated_idle_energy`` over an array of gaps.

    Returns (energy_J, exposed_wake_s, wake_events, setpm, gated_s)
    arrays.
    """
    pos = gap > 0
    zeros = np.zeros_like(gap)
    ungated = np.where(pos, p_static * gap, 0.0)
    if mode == "none":
        return ungated, zeros, zeros, zeros, zeros
    if mode == "ideal":
        return zeros, zeros, zeros, zeros, np.where(pos, gap, 0.0)
    if mode == "hw":
        g = pos & (gap > window_s)
        e = np.where(g, p_static * window_s
                     + leak * p_static * (gap - window_s)
                     + p_static * delay_s, ungated)
        gs = np.where(g, gap - window_s, 0.0)
        return e, np.where(g, delay_s, 0.0), g.astype(np.float64), zeros, gs
    # sw
    g = pos & (gap >= max(bet_s, 2.0 * delay_s))
    e = np.where(g, leak * p_static * (gap - 2 * delay_s)
                 + p_static * 2 * delay_s, ungated)
    gf = g.astype(np.float64)
    return e, zeros, gf, 2.0 * gf, np.where(g, gap - 2 * delay_s, 0.0)


def evaluate(wl: Workload, npu: NPUSpec | str = "NPU-D",
             policy: str = "ReGate-Full",
             knobs: PolicyKnobs = PolicyKnobs()) -> EnergyReport:
    """Columnar engine; semantics identical to ``evaluate_reference``."""
    npu = get_npu(npu) if isinstance(npu, str) else npu
    npu = with_sa_width(npu, knobs.sa_width)
    tr = compile_trace(wl)
    tm = trace_times(tr, npu)
    pm = PowerModel(npu)
    g = npu.gating
    cp = _component_policies(policy)

    leak_logic = knobs.leak_off_logic if knobs.leak_off_logic is not None \
        else g.leak_off_logic
    leak_sleep = knobs.leak_sram_sleep if knobs.leak_sram_sleep is not None \
        else g.leak_sram_sleep
    leak_off = knobs.leak_sram_off if knobs.leak_sram_off is not None \
        else g.leak_sram_off

    static_w = pm.static_w
    dyn_w = pm.dyn_max_w
    cnt = tr.count
    dur = tm["dur"]
    durn = dur * cnt

    static_j = {c: 0.0 for c in COMPONENTS}
    dynamic_j = {c: 0.0 for c in COMPONENTS}
    wakes = {c: 0.0 for c in COMPONENTS}
    gated = {c: 0.0 for c in COMPONENTS}
    setpm_by = {c: 0.0 for c in COMPONENTS}
    overhead = 0.0

    for c in ("sa", "vu", "hbm", "ici"):
        pol = cp[c]
        a = tm[c]
        active = a > 0
        p = static_w[c]
        leak = max(leak_logic, g.leak_hbm_refresh) if c == "hbm" \
            else leak_logic
        bet_s = g.bet.get(pol.delay_key, 0) * knobs.delay_scale / npu.freq_hz
        delay_s = g.on_off_delay.get(pol.delay_key, 0) * knobs.delay_scale \
            / npu.freq_hz
        window_s = bet_s * g.detection_window_frac * knobs.window_scale

        # merged cross-op idle gaps (each closed once, not per instance)
        gaps = _merged_gaps(active, np.where(active, 0.0, durn))
        e, exposed, nw, sp, gs = _gated_idle_energy_vec(
            gaps, p, mode=pol.mode, bet_s=bet_s, delay_s=delay_s,
            window_s=window_s, leak=leak)
        sj = float(e.sum())
        ov = float(exposed.sum())
        wk = float(nw.sum())
        gt = float(gs.sum())
        setpm_by[c] += float(sp.sum())

        an = a[active]
        cn = cnt[active]
        # dynamic: proportional to useful work
        if c == "sa":
            dynamic_j[c] = dyn_w[c] * float(
                (tr.flops_sa[active] / npu.sa_flops * cn).sum())
        else:
            dynamic_j[c] = dyn_w[c] * float((an * cn).sum())
        # static during the active portion (SA: PE-occupancy weighted)
        if c == "sa" and pol.spatial_sa:
            occ = tm["frac_on"] + g.leak_pe_weight_on * tm["frac_w_on"] \
                + leak_logic * tm["frac_off"]
            if pol.mode == "ideal":
                occ = tm["frac_on"]
            occ = np.where(tr.has_mm, occ, 1.0)
            sj += p * float((occ[active] * an * cn).sum())
        else:
            sj += p * float((an * cn).sum())
        # within-op slack (per executed instance)
        if c == "vu":
            fv = _fine_grained_vu_vec(tm, tr, npu, pol, static_w["vu"],
                                      leak_logic, knobs)
            sj += fv["static_j"]
            ov += fv["overhead"]
            wk += fv["wakes"]
            gt += fv["gated_s"]
            setpm_by[c] += fv["setpm"]
        else:
            slack = np.where(active, dur - a, 0.0)
            e2, exp2, nw2, sp2, gs2 = _gated_idle_energy_vec(
                slack, p, mode=pol.mode, bet_s=bet_s, delay_s=delay_s,
                window_s=window_s, leak=leak)
            sj += float((e2 * cnt).sum())
            ov += float((exp2 * cnt).sum())
            wk += float((nw2 * cnt).sum())
            gt += float((gs2 * cnt).sum())
            setpm_by[c] += float((sp2 * cnt).sum())
        if c in ("hbm", "ici"):
            # wake overlapped with the long DMA issue latency half the time
            ov *= 0.5
        static_j[c] = sj
        wakes[c] = wk
        gated[c] = gt
        overhead += ov

    # --- SRAM: capacity-proportional static, demand-gated remainder ---
    pol = cp["sram"]
    used = np.minimum(1.0, tr.sram_demand / npu.sram_bytes)
    sram_leak_unused = {"on": 1.0, "sleep": leak_sleep,
                        "off": leak_off}.get(pol.sram_state, 0.0)
    static_j["sram"] = static_w["sram"] * float(
        (durn * (used + (1.0 - used) * sram_leak_unused)).sum())
    if pol.sram_state != "on":
        gated["sram"] = float((durn * (1.0 - used)).sum())
    if pol.sram_state in ("sleep", "off") and pol.mode == "sw" \
            and tr.n_ops:
        # one range-setpm pair per demand-CHANGE boundary (matches the
        # reference engine's prev_used tracking)
        changes = int(np.count_nonzero(used[1:] != used[:-1]))
        setpm_by["sram"] = 2.0 * (changes + (1 if used[0] < 1.0 else 0))
    dynamic_j["sram"] = dyn_w["sram"] * 0.5 * float(
        (tm["max4"] * cnt).sum())

    # --- other: never gated ---
    static_j["other"] = static_w["other"] * float(durn.sum())
    dynamic_j["other"] = dyn_w["other"] * 0.3 * float(durn.sum())

    runtime = float(durn.sum()) + overhead
    return EnergyReport(
        workload=wl.name, policy=policy, npu=npu.name,
        runtime_s=runtime, static_j=static_j, dynamic_j=dynamic_j,
        setpm_count=sum(setpm_by.values()), wake_events=wakes,
        gated_s=gated, setpm_by=setpm_by)


def _fine_grained_vu_vec(tm: dict, tr: TraceArrays, npu: NPUSpec,
                         pol: _CompPolicy, p: float, leak_logic: float,
                         knobs: PolicyKnobs) -> dict[str, float]:
    """Vectorized ``fine_grained_vu``: per-burst VU slack inside mixed ops
    (paper Fig 15) — HW detection mostly cannot exploit it, SW setpm can."""
    t_vu = tm["vu"]
    sel = t_vu > 0
    slack = np.where(sel, tm["dur"] - t_vu, 0.0)
    sel = sel & (slack > 0)
    if not sel.any():
        return {"static_j": 0.0, "overhead": 0.0, "wakes": 0.0,
                "setpm": 0.0, "gated_s": 0.0}
    g = npu.gating
    slack = slack[sel]
    n = tr.count[sel]
    active_cy = np.maximum(1.0, npu.cycles(t_vu[sel]))
    n_bursts = np.maximum(1.0, active_cy / g.vu_burst_cycles)
    gap_cy = npu.cycles(slack) / n_bursts
    bet_cy = g.bet["vu"] * knobs.delay_scale
    delay_cy = g.on_off_delay["vu"] * knobs.delay_scale
    window_cy = bet_cy * g.detection_window_frac * knobs.window_scale
    psn = p * slack * n
    if pol.mode == "none":
        return {"static_j": float(psn.sum()), "overhead": 0.0,
                "wakes": 0.0, "setpm": 0.0, "gated_s": 0.0}
    if pol.mode == "ideal":
        return {"static_j": 0.0, "overhead": 0.0, "wakes": 0.0,
                "setpm": 0.0, "gated_s": float((slack * n).sum())}
    if pol.mode == "hw":
        gated = gap_cy > bet_cy
        gated_frac = np.maximum(0.0, (gap_cy - window_cy) / gap_cy)
        e = np.where(gated, psn * ((1 - gated_frac)
                                   + leak_logic * gated_frac), psn)
        gs = np.where(gated, slack * n * gated_frac, 0.0)
        # exposed wake per burst: Base/HW hardware cannot pre-wake
        ov = np.where(gated, n_bursts * delay_cy / npu.freq_hz * n, 0.0)
        wk = np.where(gated, n_bursts * n, 0.0)
        return {"static_j": float(e.sum()), "overhead": float(ov.sum()),
                "wakes": float(wk.sum()), "setpm": 0.0,
                "gated_s": float(gs.sum())}
    # sw
    gated = gap_cy >= np.maximum(bet_cy, 2 * delay_cy)
    trans = np.where(gap_cy > 0, 2 * delay_cy / gap_cy, 0.0)
    e = np.where(gated, psn * (trans + leak_logic * (1 - trans)), psn)
    gs = np.where(gated, slack * n * (1 - trans), 0.0)
    sp = np.where(gated, 2 * n_bursts * n, 0.0)
    wk = np.where(gated, n_bursts * n, 0.0)
    return {"static_j": float(e.sum()), "overhead": 0.0,
            "wakes": float(wk.sum()), "setpm": float(sp.sum()),
            "gated_s": float(gs.sum())}


# --------------------------------------------------------------------------
# evaluation — batched sweep plane (stacked traces × npu × policy × knobs)
# --------------------------------------------------------------------------

@dataclass
class BatchResult:
    """Dense result cube of ``evaluate_batch``: every EnergyReport field
    as a float64 array of shape (workload, npu, policy, knob).

    ``records()`` flattens the cube into the sweep record table
    (workload-major, then NPU, then policy, then knob index — the same
    deterministic ordering the loop sweep emits); ``report()`` rebuilds a
    single ``EnergyReport`` for one cell.
    """

    workloads: tuple[str, ...]
    npus: tuple[NPUSpec, ...]
    policies: tuple[str, ...]
    knob_grid: tuple[PolicyKnobs, ...]
    runtime_s: np.ndarray                    # (W, A, P, K)
    static_j: dict[str, np.ndarray]          # component -> (W, A, P, K)
    dynamic_j: dict[str, np.ndarray]
    wake_events: dict[str, np.ndarray]
    gated_s: dict[str, np.ndarray]
    setpm_by: dict[str, np.ndarray]

    @property
    def shape(self) -> tuple[int, int, int, int]:
        return self.runtime_s.shape

    @property
    def setpm_count(self) -> np.ndarray:
        out = np.zeros(self.shape)
        for c in COMPONENTS:
            out += self.setpm_by[c]
        return out

    def report(self, w: int, a: int, p: int, k: int = 0) -> EnergyReport:
        i = (w, a, p, k)
        return EnergyReport(
            workload=self.workloads[w], policy=self.policies[p],
            npu=self.npus[a].name,
            runtime_s=float(self.runtime_s[i]),
            static_j={c: float(self.static_j[c][i]) for c in COMPONENTS},
            dynamic_j={c: float(self.dynamic_j[c][i]) for c in COMPONENTS},
            setpm_count=sum(float(self.setpm_by[c][i]) for c in COMPONENTS),
            wake_events={c: float(self.wake_events[c][i])
                         for c in COMPONENTS},
            gated_s={c: float(self.gated_s[c][i]) for c in COMPONENTS},
            setpm_by={c: float(self.setpm_by[c][i]) for c in COMPONENTS})

    def records(self) -> list[dict]:
        """Flat sweep record table (same fields, values, and ordering as
        the loop path's per-cell ``_flatten``)."""
        static_tot = np.zeros(self.shape)
        dynamic_tot = np.zeros(self.shape)
        wake_tot = np.zeros(self.shape)
        for c in COMPONENTS:
            static_tot += self.static_j[c]
            dynamic_tot += self.dynamic_j[c]
            wake_tot += self.wake_events[c]
        total = static_tot + dynamic_tot
        setpm = self.setpm_count
        static_frac = static_tot / np.maximum(1e-12, total)
        avg_power = total / np.maximum(1e-12, self.runtime_s)
        freq = np.array([n.freq_hz for n in self.npus])
        setpm_1k = setpm / np.maximum(
            1.0, self.runtime_s * freq[None, :, None, None]) * 1e3

        def col(arr):
            return arr.reshape(-1).tolist()

        cols = [col(self.runtime_s), col(total), col(static_tot),
                col(dynamic_tot), col(static_frac), col(avg_power),
                col(setpm), col(setpm_1k), col(wake_tot)]
        comp_cols = [(f"static_j_{c}", col(self.static_j[c])) for c in
                     COMPONENTS] + [(f"dynamic_j_{c}",
                                     col(self.dynamic_j[c]))
                                    for c in COMPONENTS]
        knobs_meta = [(ki, kn.delay_scale, kn.leak_off_logic,
                       kn.leak_sram_sleep, kn.leak_sram_off, kn.sa_width,
                       kn.window_scale)
                      for ki, kn in enumerate(self.knob_grid)]
        recs = []
        i = 0
        for wname in self.workloads:
            for npu in self.npus:
                for policy in self.policies:
                    for ki, dsc, lol, lss, lso, saw, wsc in knobs_meta:
                        rec = {
                            "workload": wname, "npu": npu.name,
                            "policy": policy, "knob_idx": ki,
                            "delay_scale": dsc, "leak_off_logic": lol,
                            "leak_sram_sleep": lss, "leak_sram_off": lso,
                            "sa_width": saw, "window_scale": wsc,
                            "runtime_s": cols[0][i], "total_j": cols[1][i],
                            "static_total_j": cols[2][i],
                            "dynamic_total_j": cols[3][i],
                            "static_frac": cols[4][i],
                            "avg_power_w": cols[5][i],
                            "setpm_count": cols[6][i],
                            "setpm_per_1k_cycles": cols[7][i],
                            "wake_events": cols[8][i],
                        }
                        for name, cc in comp_cols:
                            rec[name] = cc[i]
                        recs.append(rec)
                        i += 1
        return recs


def _batch_ctx(st: StackedTrace, npu: NPUSpec) -> dict:
    """Per-(stacked trace, NPU) arrays shared by every (policy, knob)
    cell: stacked service times, merged idle-gap structures, and the
    knob-independent segment sums. Cached on the stack (spec-identity
    keyed, same convention as ``trace_times``)."""
    hit = st._derived.get(id(npu))
    if hit is not None and hit[0] is npu:
        return hit[1]
    offs = st.offsets
    tms = [trace_times(tr, npu) for tr in st.traces]

    def cat(key):
        if not tms:
            return np.zeros(0)
        return np.concatenate([tm[key] for tm in tms])

    tm = {k: cat(k) for k in ("sa", "vu", "hbm", "ici", "dur", "max4",
                              "frac_on", "frac_w_on", "frac_off")}
    pm = PowerModel(npu)
    static_w = pm.static_w
    dyn_w = pm.dyn_max_w
    g = npu.gating
    cnt = st.count
    dur = tm["dur"]
    durn = dur * cnt
    D_seg = segment_sum(durn, offs)

    comp: dict[str, dict] = {}
    for c in ("sa", "vu", "hbm", "ici"):
        a = tm[c]
        active = a > 0
        gv, gofs = segmented_gaps(active, np.where(active, 0.0, durn), offs)
        slack = np.where(active, dur - a, 0.0)
        scnt = slack * cnt
        acnt = a * cnt
        comp[c] = {
            "gap_vals": gv, "gap_offsets": gofs,
            "S_gap": segment_sum(gv, gofs),
            "slack": slack, "scnt": scnt, "S_slk": segment_sum(scnt, offs),
            "acnt": acnt, "AN": segment_sum(acnt, offs),
        }
        if c != "sa":  # SA dynamic is work-proportional, not time-based
            comp[c]["dyn_seg"] = dyn_w[c] * comp[c]["AN"]
    comp["sa"]["dyn_seg"] = dyn_w["sa"] * segment_sum(
        st.flops_sa / npu.sa_flops * cnt, offs)
    # SA spatial-occupancy ingredients (Ideal's occupancy is knob-free)
    occ_ideal = np.where(st.has_mm, tm["frac_on"], 1.0)
    comp["sa"]["occ_ideal_AN"] = segment_sum(occ_ideal * comp["sa"]["acnt"],
                                             offs)
    # VU fine-grained burst structure (knob-independent parts)
    vu = comp["vu"]
    sel = (tm["vu"] > 0) & (vu["slack"] > 0)
    active_cy = np.maximum(1.0, npu.cycles(tm["vu"]))
    n_bursts = np.maximum(1.0, active_cy / g.vu_burst_cycles)
    gap_cy = np.zeros_like(n_bursts)
    gap_cy[sel] = npu.cycles(vu["slack"][sel]) / n_bursts[sel]
    inv_gap = np.zeros_like(gap_cy)
    inv_gap[sel] = 1.0 / gap_cy[sel]
    psn = static_w["vu"] * vu["slack"] * cnt
    vu.update(sel=sel, nbn=n_bursts * cnt, gap_cy=gap_cy, inv_gap=inv_gap,
              psn=psn, PSN_seg=segment_sum(psn, offs))
    # SRAM capacity model (knob- and policy-independent parts)
    used = np.minimum(1.0, st.sram_demand / npu.sram_bytes)
    n = st.n_ops
    changes = np.zeros(st.n_segments)
    first = np.zeros(st.n_segments)
    if n:
        b = (used[1:] != used[:-1]) & (st.seg_ids[1:] == st.seg_ids[:-1])
        changes = np.bincount(st.seg_ids[1:][b],
                              minlength=st.n_segments).astype(np.float64)
        nonempty = offs[1:] > offs[:-1]
        first[nonempty] = used[offs[:-1][nonempty]] < 1.0
    ctx = {
        "W": st.n_segments, "offsets": offs, "tm": tm, "cnt": cnt,
        "durn": durn, "D_seg": D_seg, "comp": comp,
        "static_w": static_w, "dyn_w": dyn_w, "gating": g,
        "freq": npu.freq_hz, "has_mm": st.has_mm,
        "sram_used": used,
        "sram_U_seg": segment_sum(durn * used, offs),
        "sram_GU_seg": segment_sum(durn * (1.0 - used), offs),
        "sram_setpm_seg": 2.0 * (changes + first),
        "sram_dyn_seg": dyn_w["sram"] * 0.5 * segment_sum(tm["max4"] * cnt,
                                                          offs),
    }
    st._derived[id(npu)] = (npu, ctx)
    return ctx


def _comp_cell(ctx: dict, c: str, pol: _CompPolicy, kp: dict) -> dict:
    """Batched per-component evaluation of one ``_CompPolicy`` over the
    knob axis: (W, K) arrays for static energy, exposed-wake overhead,
    wake events, setpm count, and gated seconds.

    The gated-idle energy model is piecewise linear in the gap length
    with knob-dependent thresholds, so instead of materializing per-gap
    energies per knob, the cell reduces the masked gap sums/counts per
    segment and assembles every quantity in closed form — identical
    values to ``_gated_idle_energy_vec`` summed per workload.
    """
    cc = ctx["comp"][c]
    offs = ctx["offsets"]
    W, K = ctx["W"], kp["K"]
    p = ctx["static_w"][c]
    g = ctx["gating"]
    leak = kp["leak_logic"]
    if c == "hbm":
        # HBM auto-refresh floor (paper §6.5)
        leak = np.maximum(leak, g.leak_hbm_refresh)
    bet = g.bet.get(pol.delay_key, 0) * kp["dscale"] / ctx["freq"]
    delay = g.on_off_delay.get(pol.delay_key, 0) * kp["dscale"] / ctx["freq"]
    window = bet * g.detection_window_frac * kp["wscale"]

    static = np.zeros((W, K))
    overhead = np.zeros((W, K))
    wakes = np.zeros((W, K))
    setpm = np.zeros((W, K))
    gated = np.zeros((W, K))
    S = cc["S_gap"][:, None]

    # --- merged cross-op idle gaps (each closed once, not per instance) ---
    if pol.mode == "none":
        static += p * S
    elif pol.mode == "ideal":
        gated += S
    elif pol.mode == "hw":
        gv = cc["gap_vals"]
        mask = gv[:, None] > window[None, :]
        GM = segment_sum(np.where(mask, gv[:, None], 0.0),
                         cc["gap_offsets"])
        C = segment_sum(mask.astype(np.float64), cc["gap_offsets"])
        static += p * (S - GM) + (p * window) * C \
            + (leak * p) * (GM - window * C) + (p * delay) * C
        overhead += delay * C
        wakes += C
        gated += GM - window * C
    else:  # sw
        thresh = np.maximum(bet, 2.0 * delay)
        gv = cc["gap_vals"]
        mask = (gv[:, None] >= thresh[None, :]) & (gv > 0)[:, None]
        GM = segment_sum(np.where(mask, gv[:, None], 0.0),
                         cc["gap_offsets"])
        C = segment_sum(mask.astype(np.float64), cc["gap_offsets"])
        static += p * (S - GM) + (leak * p) * (GM - 2.0 * delay * C) \
            + (p * 2.0 * delay) * C
        wakes += C
        setpm += 2.0 * C
        gated += GM - 2.0 * delay * C

    # --- active-portion static (SA: PE-occupancy weighted) ---
    if c == "sa" and pol.spatial_sa:
        if pol.mode == "ideal":
            static += p * cc["occ_ideal_AN"][:, None]
        else:
            tm = ctx["tm"]
            occ = tm["frac_on"][:, None] \
                + g.leak_pe_weight_on * tm["frac_w_on"][:, None] \
                + kp["leak_logic"][None, :] * tm["frac_off"][:, None]
            occ = np.where(ctx["has_mm"][:, None], occ, 1.0)
            static += p * segment_sum(occ * cc["acnt"][:, None], offs)
    else:
        static += p * cc["AN"][:, None]

    # --- within-op slack (per executed instance) ---
    if c == "vu":
        _vu_fine_cell(ctx, pol, kp, leak, static, overhead, wakes, setpm,
                      gated)
    else:
        Ss = cc["S_slk"][:, None]
        if pol.mode == "none":
            static += p * Ss
        elif pol.mode == "ideal":
            gated += Ss
        else:
            slack = cc["slack"]
            if pol.mode == "hw":
                mask = slack[:, None] > window[None, :]
                lo, hi = window, delay
            else:  # sw
                thresh = np.maximum(bet, 2.0 * delay)
                mask = (slack[:, None] >= thresh[None, :]) \
                    & (slack > 0)[:, None]
                lo = hi = 2.0 * delay
            SM = segment_sum(np.where(mask, cc["scnt"][:, None], 0.0), offs)
            CM = segment_sum(np.where(mask, ctx["cnt"][:, None], 0.0), offs)
            if pol.mode == "hw":
                static += p * (Ss - SM) + (p * lo) * CM \
                    + (leak * p) * (SM - lo * CM) + (p * hi) * CM
                overhead += hi * CM
            else:
                static += p * (Ss - SM) + (leak * p) * (SM - lo * CM) \
                    + (p * lo) * CM
                setpm += 2.0 * CM
            wakes += CM
            gated += SM - lo * CM

    if c in ("hbm", "ici"):
        # wake overlapped with the long DMA issue latency half the time
        overhead *= 0.5
    return {"static": static, "overhead": overhead, "wakes": wakes,
            "setpm": setpm, "gated": gated}


def _vu_fine_cell(ctx, pol, kp, leak, static, overhead, wakes, setpm,
                  gated):
    """Knob-axis-batched ``_fine_grained_vu_vec``: per-burst VU slack
    inside mixed ops (paper Fig 15). Mutates the (W, K) accumulators."""
    cc = ctx["comp"]["vu"]
    offs = ctx["offsets"]
    g = ctx["gating"]
    if pol.mode == "none":
        static += cc["PSN_seg"][:, None]
        return
    if pol.mode == "ideal":
        gated += cc["S_slk"][:, None]
        return
    bet_cy = g.bet["vu"] * kp["dscale"]
    delay_cy = g.on_off_delay["vu"] * kp["dscale"]
    gap_cy = cc["gap_cy"]
    psn = cc["psn"][:, None]
    if pol.mode == "hw":
        window_cy = bet_cy * g.detection_window_frac * kp["wscale"]
        gm = gap_cy[:, None] > bet_cy[None, :]
        gf = np.maximum(0.0, 1.0 - window_cy[None, :]
                        * cc["inv_gap"][:, None])
        e = np.where(gm, psn * ((1.0 - gf) + leak * gf), psn)
        static += segment_sum(e, offs)
        gated += segment_sum(np.where(gm, cc["scnt"][:, None] * gf, 0.0),
                             offs)
        NB = segment_sum(np.where(gm, cc["nbn"][:, None], 0.0), offs)
        # exposed wake per burst: Base/HW hardware cannot pre-wake
        overhead += delay_cy / ctx["freq"] * NB
        wakes += NB
        return
    # sw
    gm = cc["sel"][:, None] & (
        gap_cy[:, None] >= np.maximum(bet_cy, 2.0 * delay_cy)[None, :])
    trans = 2.0 * delay_cy[None, :] * cc["inv_gap"][:, None]
    e = np.where(gm, psn * (trans + leak * (1.0 - trans)), psn)
    static += segment_sum(e, offs)
    gated += segment_sum(
        np.where(gm, cc["scnt"][:, None] * (1.0 - trans), 0.0), offs)
    NB = segment_sum(np.where(gm, cc["nbn"][:, None], 0.0), offs)
    setpm += 2.0 * NB
    wakes += NB


# --------------------------------------------------------------------------
# evaluation — backend-neutral sweep kernel (numpy or one jitted jax program)
# --------------------------------------------------------------------------

_BK_COMPS = ("sa", "vu", "hbm", "ici")


def _cell_id(c: str, pol: _CompPolicy) -> str:
    """String key for a distinct (component, policy-cell): pytree dict
    keys must sort, so the frozen ``_CompPolicy`` is flattened."""
    return f"{c}|{pol.mode}|{pol.delay_key}|{int(pol.spatial_sa)}"


def _distinct_cells(policies) -> dict[str, tuple[str, _CompPolicy]]:
    out: dict[str, tuple[str, _CompPolicy]] = {}
    for p in policies:
        cp = _component_policies(p)
        for c in _BK_COMPS:
            out.setdefault(_cell_id(c, cp[c]), (c, cp[c]))
    return out


def _sram_states(policies) -> tuple[str, ...]:
    return tuple(dict.fromkeys(
        _component_policies(p)["sram"].sram_state for p in policies))


def _sweep_kernel(data, knobs, policies, bk, wl_axis=None, knob_axis=None):
    """The whole sweep — service times, SA occupancy, gap merges, and
    the policy/knob assembly — as one pure, backend-neutral program
    over fixed-shape arrays.

    ``data`` carries the *raw* per-op columns (FLOPs, bytes, matmul
    dims), the host-built fixed-shape gap index (``backend.gap_index``
    — chunk ownership replaces the data-dependent ``reduceat`` of
    ``segmented_gaps``), and per-NPU scalars as 0-d arrays so one
    compiled program serves every NPU generation. Unlike the PR-4
    kernel, the per-op service times and the SA PE-occupancy closed
    form (``bk.sa_occupancy``) are computed *inside* the traced
    program: the SA width ``saw`` enters as a traced scalar, which is
    what turns ``sa_width`` into a real knob axis (ISSUE 5). Distinct
    ``_CompPolicy`` cells are computed once and shared across policies
    (same memoization as the numpy path, applied at trace time).

    The knob axis is factored: the O(n_ops)-sized work — occupancy,
    service times, gap merges, masked threshold merges — depends only
    on ``(sa_width, delay_scale, window_scale)``, and every leakage
    knob enters *linearly after* the segmented reductions. So the
    heavy passes run through ``bk.vmap_knobs`` over the **unique**
    (saw, delay-scale, window-scale) triples
    (``knobs["pair_saw_idx"]/["pair_dscale"]/["pair_wscale"]``) and
    the full knob grid is assembled from those primitives with
    O(W × K) linear algebra. A crossed width × threshold × leakage
    grid therefore costs ``len(unique triples)`` heavy passes, not
    ``K``.

    Under ``shard_map`` (the multi-device path) the op axis may be
    sharded over the ``wl_axis`` mesh axis — every op-axis segment sum
    is then completed with a ``psum`` — and the pair + knob axes over
    ``knob_axis``: each device runs the heavy passes for its local
    pairs, ``all_gather``s the (small) per-segment primitives, and
    assembles only its local knob slice.

    Returns a dict of (K, W) arrays: per-cell quantities (``cells``),
    SRAM static per state (``sram``), and the per-knob context
    (``D_seg``, ``dyn``, ``sram_GU``, ``sram_dyn``) the host assembly
    broadcasts from.
    """
    xp = bk.xp
    op = data["op"]
    offsets = data["offsets"]
    scal = data["scal"]
    w = offsets.shape[0] - 1
    seg = op["seg_ids"]
    cnt = op["cnt"]

    def opsum(v, ids, num):
        """Segment sum over the (possibly device-sharded) op axis."""
        s = bk.segment_sum(v, ids, num)
        return bk.psum(s, wl_axis) if wl_axis else s

    def segsum(v):
        return opsum(v, seg, w)

    cells = _distinct_cells(policies)
    states = _sram_states(policies)
    used = op["sram_used"]

    def per_saw(kd):
        """Everything that depends on the SA width alone: traced
        service times + PE occupancy (``trace_times``, bitwise-equal
        float64 ops), the per-op gap/slack structures, and the
        per-segment base sums the leakage knobs assemble from
        linearly. Vmapped over the UNIQUE widths only — a pure delay/
        leakage grid computes all of this exactly once."""
        saw = kd["saw"]
        has_mm = op["has_mm"]
        occ = bk.sa_occupancy(op["mm_m"], op["mm_k"], op["mm_n"], saw)
        frac_on = xp.where(has_mm, occ["frac_on"], 0.0)
        frac_w_on = xp.where(has_mm, occ["frac_w_on"], 0.0)
        frac_off = xp.where(has_mm, occ["frac_off"], 0.0)
        sa_flops = saw * saw * 2.0 * scal["n_sa"] * scal["freq"]
        flops_cycles = op["mm_m"] * op["mm_k"] * op["mm_n"] / (saw * saw)
        dur_cy = xp.where(has_mm, occ["duration_cycles"], 1.0)
        e = xp.minimum(1.0, flops_cycles / xp.maximum(1e-9, dur_cy))
        eff = xp.where(has_mm & (op["flops_sa"] > 0),
                       xp.maximum(e, 1e-3), 1.0)
        t = {"sa": xp.where(op["flops_sa"] > 0,
                            op["flops_sa"] / (sa_flops * eff), 0.0),
             "vu": xp.where(op["flops_vu"] > 0,
                            op["flops_vu"] / scal["vu_flops"], 0.0),
             "hbm": xp.where(op["bytes_hbm"] > 0,
                             op["bytes_hbm"] / scal["hbm_bw"], 0.0),
             "ici": xp.where(op["bytes_ici"] > 0,
                             op["bytes_ici"] / scal["ici_bw"], 0.0)}
        max4 = xp.maximum(xp.maximum(t["sa"], t["vu"]),
                          xp.maximum(t["hbm"], t["ici"]))
        dur = xp.maximum(max4, 1e-12)
        durn = dur * cnt

        base = {"D_seg": segsum(durn)}
        comp: dict[str, dict] = {}
        for c in _BK_COMPS:
            a = t[c]
            active = a > 0
            gseg = data["gap_seg"][c]
            gap_vals = opsum(xp.where(active, 0.0, durn),
                             op[f"chunk_{c}"], gseg.shape[0])
            slack = xp.where(active, dur - a, 0.0)
            comp[c] = {"gap_vals": gap_vals, "slack": slack,
                       "scnt": slack * cnt}
            # gap_vals is already globally summed (and so replicated
            # across wl shards): its per-segment merges need no psum
            base[f"S_gap_{c}"] = bk.segment_sum(gap_vals, gseg, w)
            base[f"S_slk_{c}"] = segsum(slack * cnt)
            base[f"AN_{c}"] = segsum(a * cnt)
            acnt = a * cnt
            if c == "sa":
                sa_acnt = acnt
        for c in ("vu", "hbm", "ici"):
            base[f"dyn_{c}"] = scal[f"dyn_w_{c}"] * base[f"AN_{c}"]
        base["dyn_sa"] = scal["dyn_w_sa"] * segsum(
            op["flops_sa"] / sa_flops * cnt)
        # SA spatial occupancy is linear in leak_logic with
        # width-dependent segment sums: occ = A + leak_logic * B per op
        base["occ_ideal_AN"] = segsum(
            xp.where(has_mm, frac_on, 1.0) * sa_acnt)
        base["sa_occ_an_a"] = segsum(xp.where(
            has_mm, frac_on + scal["leak_pe_weight_on"] * frac_w_on,
            1.0) * sa_acnt)
        base["sa_occ_an_b"] = segsum(
            xp.where(has_mm, frac_off, 0.0) * sa_acnt)
        # VU fine-grained burst structure (paper Fig 15)
        vu = comp["vu"]
        sel = (t["vu"] > 0) & (vu["slack"] > 0)
        active_cy = xp.maximum(1.0, scal["freq"] * t["vu"])
        n_bursts = xp.maximum(1.0, active_cy / scal["vu_burst_cycles"])
        gap_raw = scal["freq"] * vu["slack"] / n_bursts
        psn = scal["static_w_vu"] * vu["slack"] * cnt
        vu.update(sel=sel, nbn=n_bursts * cnt,
                  gap_cy=xp.where(sel, gap_raw, 0.0),
                  inv_gap=xp.where(sel, 1.0 / xp.where(sel, gap_raw, 1.0),
                                   0.0),
                  psn=psn)
        base["PSN_seg"] = segsum(psn)
        # SRAM capacity model (the demand pattern is width-independent;
        # the setpm boundary count is knob-free and counted host-side)
        base["sram_U"] = segsum(durn * used)
        base["sram_GU"] = segsum(durn * (1.0 - used))
        base["sram_dyn"] = scal["dyn_w_sram"] * 0.5 * segsum(max4 * cnt)
        return {"base": base, "comp": comp}

    sb = bk.vmap_knobs(per_saw, {"saw": knobs["saw_unique"]})
    if knob_axis:
        # the unique-width axis is device-sharded too: gather the
        # per-saw structures (small: (S, n) per-op columns and (S, W)
        # sums) so every device can run its local pairs and knobs
        sb = bk.all_gather(sb, knob_axis)

    def per_pair(kd):
        """The masked threshold merges for ONE (saw, delay-scale) pair;
        the width-dependent structures are gathered from the stacked
        per-saw pass by index."""
        si, d, ws = kd["si"], kd["dscale"], kd["wscale"]
        comp = {c: {q: arr[si] for q, arr in cd.items()}
                for c, cd in sb["comp"].items()}
        prims = {}
        for cid, (c, pol) in cells.items():
            if pol.mode not in ("hw", "sw"):
                continue  # none/ideal need no masked primitives
            cc = comp[c]
            bet = scal[f"bet_{pol.delay_key}"] * d / scal["freq"]
            delay = scal[f"delay_{pol.delay_key}"] * d / scal["freq"]
            window = bet * scal["window_frac"] * ws
            gv = cc["gap_vals"]
            if pol.mode == "hw":
                gmask = gv > window
            else:
                gmask = (gv >= xp.maximum(bet, 2.0 * delay)) & (gv > 0)
            gseg = data["gap_seg"][c]
            o = {"GM": bk.segment_sum(xp.where(gmask, gv, 0.0), gseg, w),
                 "GC": bk.segment_sum(xp.where(gmask, 1.0, 0.0),
                                      gseg, w)}
            if c == "vu":
                # fine-grained burst slack: static energy is
                # VA + leak * VB; VG is gated seconds, NB burst count
                bet_cy = scal["bet_vu"] * d
                delay_cy = scal["delay_vu"] * d
                gap_cy = cc["gap_cy"]
                psn_ = cc["psn"]
                if pol.mode == "hw":
                    window_cy = bet_cy * scal["window_frac"] * ws
                    gm = gap_cy > bet_cy
                    gf = xp.maximum(0.0, 1.0 - window_cy * cc["inv_gap"])
                    o["VA"] = segsum(xp.where(gm, psn_ * (1.0 - gf), psn_))
                    o["VB"] = segsum(xp.where(gm, psn_ * gf, 0.0))
                    o["VG"] = segsum(xp.where(gm, cc["scnt"] * gf, 0.0))
                else:
                    gm = cc["sel"] & (
                        gap_cy >= xp.maximum(bet_cy, 2.0 * delay_cy))
                    trans = 2.0 * delay_cy * cc["inv_gap"]
                    o["VA"] = segsum(xp.where(gm, psn_ * trans, psn_))
                    o["VB"] = segsum(
                        xp.where(gm, psn_ * (1.0 - trans), 0.0))
                    o["VG"] = segsum(
                        xp.where(gm, cc["scnt"] * (1.0 - trans), 0.0))
                o["NB"] = segsum(xp.where(gm, cc["nbn"], 0.0))
            else:
                slack = cc["slack"]
                if pol.mode == "hw":
                    smask = slack > window
                else:
                    smask = (slack >= xp.maximum(bet, 2.0 * delay)) \
                        & (slack > 0)
                o["SM"] = segsum(xp.where(smask, cc["scnt"], 0.0))
                o["SC"] = segsum(xp.where(smask, cnt, 0.0))
            prims[cid] = o
        return prims

    all_prims = bk.vmap_knobs(per_pair, {"si": knobs["pair_saw_idx"],
                                         "dscale": knobs["pair_dscale"],
                                         "wscale": knobs["pair_wscale"]})
    if knob_axis:
        # pairs are device-sharded: gather the (U, W)-sized primitives
        # so every device can assemble its local knob slice
        all_prims = bk.all_gather(all_prims, knob_axis)
    inv = knobs["pair_inv"]
    # per-knob base sums: (K, W) via the knob -> unique-width index
    base = {k: v[knobs["saw_inv"]] for k, v in sb["base"].items()}

    # ---- full-knob assembly: O(W × K) linear algebra on the primitives
    k_full = knobs["dscale"].shape[0]
    dscale = knobs["dscale"][:, None]          # (K, 1)
    wscale = knobs["wscale"][:, None]          # (K, 1)
    leak_logic = knobs["leak_logic"][:, None]

    def cell(c, pol):
        """(K, W) closed-form assembly of one ``_comp_cell``."""
        p = scal[f"static_w_{c}"]
        leak = leak_logic
        if c == "hbm":
            # HBM auto-refresh floor (paper §6.5)
            leak = xp.maximum(leak, scal["leak_hbm_refresh"])
        acc = {q: xp.zeros((k_full, w)) for q in
               ("static", "overhead", "wakes", "setpm", "gated")}
        s_gap = base[f"S_gap_{c}"]
        gating = pol.mode in ("hw", "sw")
        if gating:
            pr = {q: a[inv]
                  for q, a in all_prims[_cell_id(c, pol)].items()}
            bet = scal[f"bet_{pol.delay_key}"] * dscale / scal["freq"]
            delay = scal[f"delay_{pol.delay_key}"] * dscale / scal["freq"]
            window = bet * scal["window_frac"] * wscale

        # --- merged cross-op idle gaps (each closed once) ---
        if pol.mode == "none":
            acc["static"] = acc["static"] + p * s_gap
        elif pol.mode == "ideal":
            acc["gated"] = acc["gated"] + s_gap
        else:
            gm, gc = pr["GM"], pr["GC"]
            if pol.mode == "hw":
                acc["static"] = acc["static"] + p * (s_gap - gm) \
                    + (p * window) * gc + (leak * p) * (gm - window * gc) \
                    + (p * delay) * gc
                acc["overhead"] = acc["overhead"] + delay * gc
                acc["gated"] = acc["gated"] + gm - window * gc
            else:
                acc["static"] = acc["static"] + p * (s_gap - gm) \
                    + (leak * p) * (gm - 2.0 * delay * gc) \
                    + (p * 2.0 * delay) * gc
                acc["setpm"] = acc["setpm"] + 2.0 * gc
                acc["gated"] = acc["gated"] + gm - 2.0 * delay * gc
            acc["wakes"] = acc["wakes"] + gc

        # --- active-portion static (SA: PE-occupancy weighted) ---
        if c == "sa" and pol.spatial_sa:
            if pol.mode == "ideal":
                acc["static"] = acc["static"] + p * base["occ_ideal_AN"]
            else:
                acc["static"] = acc["static"] + p * (
                    base["sa_occ_an_a"] + leak_logic * base["sa_occ_an_b"])
        else:
            acc["static"] = acc["static"] + p * base[f"AN_{c}"]

        # --- within-op slack (per executed instance) ---
        if c == "vu":
            if pol.mode == "none":
                acc["static"] = acc["static"] + base["PSN_seg"]
            elif pol.mode == "ideal":
                acc["gated"] = acc["gated"] + base["S_slk_vu"]
            else:
                acc["static"] = acc["static"] + pr["VA"] + leak * pr["VB"]
                acc["gated"] = acc["gated"] + pr["VG"]
                nb = pr["NB"]
                if pol.mode == "hw":
                    # exposed wake per burst: HW cannot pre-wake
                    acc["overhead"] = acc["overhead"] \
                        + (scal["delay_vu"] * dscale / scal["freq"]) * nb
                else:
                    acc["setpm"] = acc["setpm"] + 2.0 * nb
                acc["wakes"] = acc["wakes"] + nb
        else:
            s_slk = base[f"S_slk_{c}"]
            if pol.mode == "none":
                acc["static"] = acc["static"] + p * s_slk
            elif pol.mode == "ideal":
                acc["gated"] = acc["gated"] + s_slk
            else:
                sm, cm = pr["SM"], pr["SC"]
                if pol.mode == "hw":
                    lo, hi = window, delay
                    acc["static"] = acc["static"] + p * (s_slk - sm) \
                        + (p * lo) * cm + (leak * p) * (sm - lo * cm) \
                        + (p * hi) * cm
                    acc["overhead"] = acc["overhead"] + hi * cm
                else:
                    lo = 2.0 * delay
                    acc["static"] = acc["static"] + p * (s_slk - sm) \
                        + (leak * p) * (sm - lo * cm) + (p * lo) * cm
                    acc["setpm"] = acc["setpm"] + 2.0 * cm
                acc["wakes"] = acc["wakes"] + cm
                acc["gated"] = acc["gated"] + sm - lo * cm

        if c in ("hbm", "ici"):
            # wake overlapped with the long DMA issue latency half the time
            acc["overhead"] = acc["overhead"] * 0.5
        return acc

    out_cells = {cid: cell(c, pol) for cid, (c, pol) in cells.items()}
    out_sram = {}
    for state in states:
        lk = {"on": xp.ones((k_full, 1)),
              "sleep": knobs["leak_sleep"][:, None],
              "off": knobs["leak_off"][:, None]}.get(
                  state, xp.zeros((k_full, 1)))
        out_sram[state] = scal["static_w_sram"] * (
            base["sram_U"] + lk * base["sram_GU"])
    return {"cells": out_cells, "sram": out_sram,
            "D_seg": base["D_seg"],
            "dyn": {c: base[f"dyn_{c}"] for c in _BK_COMPS},
            "sram_GU": base["sram_GU"], "sram_dyn": base["sram_dyn"]}


# jitted sweep kernels cached per (backend, occupancy impl): the jax
# program compiles once per (stack shape, knob count, policies) and is
# reused across NPU generations and repeated sweeps
_KERNELS: dict[tuple, object] = {}


def _backend_kernel(bk):
    """The (possibly jitted) single-device sweep kernel for one
    backend + occupancy-impl selection."""
    key = (bk.name, bk.sa_occupancy_impl)
    fn = _KERNELS.get(key)
    if fn is None:
        def kern(data, knobs, policies):
            return _sweep_kernel(data, knobs, policies, bk)
        fn = bk.jit(kern, static_argnames=("policies",))
        _KERNELS[key] = fn
    return fn


# shard_map sweep programs, keyed by (backend, occupancy impl, mesh
# identity, policies, axes); the value keeps a strong ref to the mesh
# so its id cannot be reused while the entry lives
_SHARD_KERNELS: dict[tuple, tuple] = {}


def _shard_kernel(bk, mesh, policies, wl_axis, knob_axis):
    """One SPMD sweep program over ``mesh``: op columns sharded over
    ``wl_axis`` (completed by in-kernel psums), unique (saw, delay)
    pairs and the knob grid sharded over ``knob_axis``; everything
    else replicated. Inputs must be padded to the axis sizes
    (``_sharded_backend_data`` / ``_knob_arrays(pad_to=...)``)."""
    key = (bk.name, bk.sa_occupancy_impl, id(mesh), policies,
           wl_axis, knob_axis)
    hit = _SHARD_KERNELS.get(key)
    if hit is not None and hit[0] is mesh:
        return hit[1]
    pspec = bk.pspec
    data_spec = {"op": pspec(wl_axis) if wl_axis else pspec(),
                 "gap_seg": pspec(), "offsets": pspec(), "scal": pspec()}
    # every knob-array axis (knobs, pairs, unique widths) is sharded
    # over the knob mesh axis; the kernel gathers what it must share
    knob_spec = pspec(knob_axis)

    def body(data, knobs):
        return _sweep_kernel(data, knobs, policies, bk,
                             wl_axis=wl_axis, knob_axis=knob_axis)

    fn = bk.shard_map_kernel(body, mesh,
                             in_specs=(data_spec, knob_spec),
                             out_specs=pspec(knob_axis))
    _SHARD_KERNELS[key] = (mesh, fn)
    return fn


def _gap_indices(st: StackedTrace) -> dict[str, tuple]:
    """Fixed-shape gap-chunk indices per component — depend only on the
    activity pattern and segmentation, so one set per stack serves every
    NPU generation (cached on the stack)."""
    hit = st._derived.get("gap_index")
    if hit is None:
        cols = {"sa": st.flops_sa, "vu": st.flops_vu,
                "hbm": st.bytes_hbm, "ici": st.bytes_ici}
        hit = {c: gap_index(cols[c] > 0, st.offsets) for c in _BK_COMPS}
        st._derived["gap_index"] = hit
    return hit


def _mm_columns(st: StackedTrace) -> tuple[np.ndarray, ...]:
    """Concatenated float64 matmul-dim columns (NPU-independent; the
    kernel consumes them as exact-integer floats so the traced
    occupancy math stays bitwise equal to the int64 host path)."""
    hit = st._derived.get("mm_columns")
    if hit is None:
        def cat(attr):
            if not st.traces:
                return np.zeros(0)
            return np.concatenate(
                [getattr(tr, attr) for tr in st.traces]).astype(np.float64)
        hit = (cat("mm_m"), cat("mm_k"), cat("mm_n"))
        st._derived["mm_columns"] = hit
    return hit


def _host_columns(st: StackedTrace, npu: NPUSpec) -> tuple[dict,
                                                           np.ndarray]:
    """Host-side kernel input pytree for one (stack, NPU) plus the
    knob-free SRAM setpm boundary counts (W,).

    Only *raw* trace columns and per-NPU scalars — no service times, no
    occupancy: those are traced inside the kernel now, which is what
    lets ``sa_width`` ride the knob axis. Per-NPU scalars enter as 0-d
    arrays so swapping generations never retraces the compiled
    program. Cached on the stack (spec-identity keyed)."""
    key = ("host_columns", id(npu))
    hit = st._derived.get(key)
    if hit is not None and hit[0] is npu:
        return hit[1], hit[2]
    gidx = _gap_indices(st)
    mm_m, mm_k, mm_n = _mm_columns(st)
    pm = PowerModel(npu)
    g = npu.gating
    used = np.minimum(1.0, st.sram_demand / npu.sram_bytes)
    op = {
        "seg_ids": st.seg_ids, "cnt": st.count,
        "flops_sa": st.flops_sa, "flops_vu": st.flops_vu,
        "bytes_hbm": st.bytes_hbm, "bytes_ici": st.bytes_ici,
        "has_mm": st.has_mm, "mm_m": mm_m, "mm_k": mm_k, "mm_n": mm_n,
        "sram_used": used,
    }
    for c in _BK_COMPS:
        op[f"chunk_{c}"] = gidx[c][0]
    scal = {"freq": npu.freq_hz, "n_sa": float(npu.n_sa),
            "vu_flops": npu.vu_flops, "hbm_bw": npu.hbm_bw,
            "ici_bw": npu.ici_bw,
            "window_frac": g.detection_window_frac,
            "leak_hbm_refresh": g.leak_hbm_refresh,
            "leak_pe_weight_on": g.leak_pe_weight_on,
            "vu_burst_cycles": float(g.vu_burst_cycles)}
    for c, v in pm.static_w.items():
        scal[f"static_w_{c}"] = v
    for c, v in pm.dyn_max_w.items():
        scal[f"dyn_w_{c}"] = v
    for k, v in g.bet.items():
        scal[f"bet_{k}"] = float(v)
    for k, v in g.on_off_delay.items():
        scal[f"delay_{k}"] = float(v)
    # SRAM setpm: one range-setpm pair per demand-CHANGE boundary
    # (knob- and width-free → counted here, off the traced path)
    w = st.n_segments
    changes = np.zeros(w)
    first = np.zeros(w)
    if st.n_ops:
        b = (used[1:] != used[:-1]) & (st.seg_ids[1:] == st.seg_ids[:-1])
        changes = np.bincount(st.seg_ids[1:][b],
                              minlength=w).astype(np.float64)
        starts = st.offsets[:-1]
        nonempty = st.offsets[1:] > starts
        first[nonempty] = used[starts[nonempty]] < 1.0
    sram_setpm = 2.0 * (changes + first)
    host = {"op": op, "gap_seg": {c: gidx[c][1] for c in _BK_COMPS},
            "offsets": st.offsets, "scal": scal}
    st._derived[key] = (npu, host, sram_setpm)
    return host, sram_setpm


def _put_tree(tree, bk):
    if isinstance(tree, dict):
        return {k: _put_tree(v, bk) for k, v in tree.items()}
    return bk.asarray(tree)


def _backend_data(st: StackedTrace, npu: NPUSpec, bk) \
        -> tuple[dict, np.ndarray]:
    """``_host_columns`` transferred to the backend once and cached on
    the stack (spec-identity keyed, same convention as ``_batch_ctx``)."""
    key = ("backend_data", bk.name, id(npu))
    hit = st._derived.get(key)
    if hit is not None and hit[0] is npu:
        return hit[1], hit[2]
    host, sram_setpm = _host_columns(st, npu)
    data = _put_tree(host, bk)
    st._derived[key] = (npu, data, sram_setpm)
    return data, sram_setpm


def _sharded_backend_data(st: StackedTrace, npu: NPUSpec, bk,
                          wl_size: int) -> tuple[dict, np.ndarray]:
    """``_backend_data`` with the op axis padded to a multiple of the
    ``wl`` mesh-axis size so ``shard_map`` can split it evenly.

    Padded ops are inert by construction: count 0, no FLOPs/bytes (so
    never active, zero duration), sentinel 1×1×1 matmul dims with
    ``has_mm`` False, and segment/chunk ids pinned to the LAST id —
    keeping the ids sorted (the jax segment sums rely on it) while the
    zero weights contribute nothing to any segment."""
    key = ("backend_data_sharded", bk.name, id(npu), wl_size)
    hit = st._derived.get(key)
    if hit is not None and hit[0] is npu:
        return hit[1], hit[2]
    host, sram_setpm = _host_columns(st, npu)
    op = dict(host["op"])
    n = len(op["seg_ids"])
    pad = (-n) % wl_size
    if pad:
        fill = {"seg_ids": st.n_segments - 1, "has_mm": False,
                "mm_m": 1.0, "mm_k": 1.0, "mm_n": 1.0}
        for k, a in op.items():
            if k.startswith("chunk_"):
                v = max(len(host["gap_seg"][k[6:]]) - 1, 0)
            else:
                v = fill.get(k, 0.0)
            op[k] = np.concatenate([a, np.full(pad, v, a.dtype)])
    data = _put_tree({**host, "op": op}, bk)
    st._derived[key] = (npu, data, sram_setpm)
    return data, sram_setpm


def knob_pairs(knob_grid) -> "tuple[list[tuple], np.ndarray]":
    """Unique (sa_width, delay_scale, window_scale) triples of a knob
    grid and the knob -> triple inverse map — the axes the executors
    actually see (leak knobs are post-hoc linear and never change
    machine behavior). The host-side twin of ``_knob_arrays``'s
    unique-pair dedup, shared with the batched program plane
    (``repro.core.program_plane``): knob points differing only in leak
    ratios map onto one executor row."""
    trips: list[tuple] = []
    index: dict[tuple, int] = {}
    inv = np.empty(len(knob_grid), np.int64)
    for i, k in enumerate(knob_grid):
        key = (k.sa_width, float(k.delay_scale), float(k.window_scale))
        if key not in index:
            index[key] = len(trips)
            trips.append(key)
        inv[i] = index[key]
    return trips, inv


def _knob_arrays(knob_grid, npu: NPUSpec, bk, pad_to: int = 0) -> dict:
    """Knob-grid arrays for the kernel: the full per-knob columns plus
    the unique (sa_width, delay_scale, window_scale) triples the heavy
    passes vmap over, with the inverse index mapping them back onto
    the grid.
    ``pad_to`` pads the knob and pair axes to a multiple (repeating
    entry 0) so ``shard_map`` can split them evenly — the host slices
    the padded tail off the outputs."""
    g = npu.gating
    ds = np.array([k.delay_scale for k in knob_grid], np.float64)
    ws = np.array([k.window_scale for k in knob_grid], np.float64)
    saw = np.array([float(k.sa_width) if k.sa_width is not None
                    else float(npu.sa_width) for k in knob_grid])
    leak_logic = np.array(
        [k.leak_off_logic if k.leak_off_logic is not None
         else g.leak_off_logic for k in knob_grid], np.float64)
    leak_sleep = np.array(
        [k.leak_sram_sleep if k.leak_sram_sleep is not None
         else g.leak_sram_sleep for k in knob_grid], np.float64)
    leak_off = np.array(
        [k.leak_sram_off if k.leak_sram_off is not None
         else g.leak_sram_off for k in knob_grid], np.float64)
    saw_unique, saw_inv = np.unique(saw, return_inverse=True)
    saw_inv = saw_inv.reshape(-1).astype(np.int64)
    pairs = np.stack([saw, ds, ws], axis=1)
    uniq, inv = np.unique(pairs, axis=0, return_inverse=True)
    inv = inv.reshape(-1).astype(np.int64)
    pair_saw_idx = np.searchsorted(saw_unique, uniq[:, 0]).astype(np.int64)
    pair_ds = uniq[:, 1].copy()
    pair_ws = uniq[:, 2].copy()

    def padded(a, m):
        p = (-len(a)) % m
        return a if p == 0 else np.concatenate([a, np.repeat(a[:1], p)])

    if pad_to:
        ds, ws, leak_logic, leak_sleep, leak_off, inv, saw_inv = (
            padded(a, pad_to)
            for a in (ds, ws, leak_logic, leak_sleep, leak_off, inv,
                      saw_inv))
        # pair and unique-width axes are device-sharded as well; pads
        # repeat entry 0 / width 0 (inert duplicates — the inverse
        # indices never point at them, padding sits at the END)
        pair_saw_idx, pair_ds, pair_ws, saw_unique = (
            padded(a, pad_to)
            for a in (pair_saw_idx, pair_ds, pair_ws, saw_unique))
    return {
        "dscale": bk.asarray(ds),
        "wscale": bk.asarray(ws),
        "leak_logic": bk.asarray(leak_logic),
        "leak_sleep": bk.asarray(leak_sleep),
        "leak_off": bk.asarray(leak_off),
        # the width-dependent base pass runs once per distinct width
        # (replicated under shard_map); the heavy masked merges once per
        # distinct (width, delay) pair; the inverse indices map both
        # back onto the full grid
        "saw_unique": bk.asarray(saw_unique),
        "saw_inv": bk.asarray(saw_inv),
        "pair_saw_idx": bk.asarray(pair_saw_idx),
        "pair_dscale": bk.asarray(pair_ds),
        "pair_wscale": bk.asarray(pair_ws),
        "pair_inv": bk.asarray(inv),
    }


def _evaluate_batch_backend(workloads, npu_specs, policies, knob_grid,
                            bk, mesh=None) -> BatchResult:
    """``evaluate_batch`` through the backend-neutral kernel.

    On the jax backend the whole per-NPU evaluation is one jitted
    program. A ``parallel.jax_compat`` mesh selects the multi-device
    path: a mesh with a ``"knob"`` axis (optionally crossed with
    ``"wl"``) runs the explicit ``shard_map`` program — pairs + knobs
    sharded over ``"knob"``, op columns over ``"wl"`` — while a pure
    ``("wl",)`` mesh keeps the GSPMD path (sharded ``device_put`` into
    the ordinary jitted kernel).
    """
    st = stack_traces(workloads)
    policies = tuple(policies)
    w, a_n, p_n, k_n = st.n_segments, len(npu_specs), len(policies), \
        len(knob_grid)
    shape = (w, a_n, p_n, k_n)
    runtime = np.zeros(shape)
    static_j = {c: np.zeros(shape) for c in COMPONENTS}
    dynamic_j = {c: np.zeros(shape) for c in COMPONENTS}
    wake_events = {c: np.zeros(shape) for c in COMPONENTS}
    gated_s = {c: np.zeros(shape) for c in COMPONENTS}
    setpm_by = {c: np.zeros(shape) for c in COMPONENTS}
    result = BatchResult(
        workloads=tuple(st.names), npus=tuple(npu_specs),
        policies=policies, knob_grid=tuple(knob_grid),
        runtime_s=runtime, static_j=static_j, dynamic_j=dynamic_j,
        wake_events=wake_events, gated_s=gated_s, setpm_by=setpm_by)
    if w == 0:
        return result
    wl_axis = knob_axis = None
    wl_size = knob_size = 1
    if mesh is not None:
        sizes = bk.mesh_axis_sizes(mesh)
        if "knob" in sizes:
            knob_axis, knob_size = "knob", sizes["knob"]
            if "wl" in sizes:
                wl_axis, wl_size = "wl", sizes["wl"]
    with bk.compute_scope():
        for ai, npu in enumerate(npu_specs):
            if knob_axis is not None:
                data, sram_setpm = _sharded_backend_data(st, npu, bk,
                                                         wl_size)
                knobs = _knob_arrays(knob_grid, npu, bk,
                                     pad_to=knob_size)
                kern = _shard_kernel(bk, mesh, policies, wl_axis,
                                     knob_axis)
                vm = bk.block(kern(data, knobs))
            else:
                data, sram_setpm = _backend_data(st, npu, bk)
                if mesh is not None:
                    data = bk.shard_data(data, mesh)
                knobs = _knob_arrays(knob_grid, npu, bk)
                kern = _backend_kernel(bk)
                vm = bk.block(kern(data, knobs, policies))

            def harvest(arr):
                # (K_pad, W) -> (W, K); drop any shard padding
                return bk.to_numpy(arr)[:k_n].T

            cells = {cid: {q: harvest(arr) for q, arr in d.items()}
                     for cid, d in vm["cells"].items()}
            sram_static = {s: harvest(arr)
                           for s, arr in vm["sram"].items()}
            d_seg = harvest(vm["D_seg"])
            dyn = {c: harvest(vm["dyn"][c]) for c in _BK_COMPS}
            sram_gu = harvest(vm["sram_GU"])
            sram_dyn = harvest(vm["sram_dyn"])
            pm = PowerModel(npu)
            for pi, policy in enumerate(policies):
                cp = _component_policies(policy)
                ov_total = np.zeros((w, k_n))
                for c in _BK_COMPS:
                    cl = cells[_cell_id(c, cp[c])]
                    static_j[c][:, ai, pi, :] = cl["static"]
                    wake_events[c][:, ai, pi, :] = cl["wakes"]
                    setpm_by[c][:, ai, pi, :] = cl["setpm"]
                    gated_s[c][:, ai, pi, :] = cl["gated"]
                    dynamic_j[c][:, ai, pi, :] = dyn[c]
                    ov_total += cl["overhead"]
                pol = cp["sram"]
                static_j["sram"][:, ai, pi, :] = \
                    sram_static[pol.sram_state]
                if pol.sram_state != "on":
                    gated_s["sram"][:, ai, pi, :] = sram_gu
                if pol.sram_state in ("sleep", "off") and pol.mode == "sw":
                    setpm_by["sram"][:, ai, pi, :] = sram_setpm[:, None]
                dynamic_j["sram"][:, ai, pi, :] = sram_dyn
                static_j["other"][:, ai, pi, :] = \
                    pm.static_w["other"] * d_seg
                dynamic_j["other"][:, ai, pi, :] = \
                    pm.dyn_max_w["other"] * 0.3 * d_seg
                runtime[:, ai, pi, :] = d_seg + ov_total
    return result


def _validate_knob_grid(knob_grid) -> None:
    """Reject knob values that would silently corrupt the sweep:
    non-positive / non-finite delay scales flip gating inequalities,
    negative leak fractions produce negative energies, and a
    non-positive SA width breaks the occupancy model."""
    for i, k in enumerate(knob_grid):
        if not (np.isfinite(k.delay_scale) and k.delay_scale > 0):
            raise ValueError(
                f"knob {i}: delay_scale must be finite and > 0, got "
                f"{k.delay_scale!r}")
        if not (np.isfinite(k.window_scale) and k.window_scale > 0):
            raise ValueError(
                f"knob {i}: window_scale must be finite and > 0, got "
                f"{k.window_scale!r}")
        for fld in ("leak_off_logic", "leak_sram_sleep",
                    "leak_sram_off"):
            v = getattr(k, fld)
            if v is not None and not (np.isfinite(v) and v >= 0):
                raise ValueError(
                    f"knob {i}: {fld} must be finite and >= 0, got "
                    f"{v!r}")
        if k.sa_width is not None and int(k.sa_width) < 1:
            raise ValueError(
                f"knob {i}: sa_width must be >= 1, got {k.sa_width!r}")


def evaluate_batch(workloads, npus=("NPU-D",), policies=POLICIES,
                   knob_grid=None, *, backend: Optional[str] = None,
                   jax_mesh=None) -> BatchResult:
    """Batched ``evaluate`` over the full design-space cross product.

    The workloads are stacked into one ragged super-trace; per-(trace,
    NPU) service times and idle-gap structures are computed once and
    reused across every (policy, knob) cell; component results are
    memoized per distinct ``_CompPolicy`` (ReGate-HW and ReGate-Full
    share the SA cell, ReGate-Base and ReGate-HW share VU/HBM/ICI/SRAM,
    …); the knob axis rides along as a trailing array dimension.
    Cell-for-cell equivalent to looping ``evaluate`` to ≤1e-9 relative.

    ``backend`` selects the array substrate: ``"numpy"`` (default — the
    eager production oracle) or ``"jax"`` (one jitted program per stack
    shape, float64, reused across NPU generations; ≤1e-9 equivalent to
    the numpy path record-for-record). ``None`` resolves to the session
    default (``repro.core.backend.set_default_backend``). ``jax_mesh``
    scales the jax path across devices (``parallel.jax_compat``; e.g.
    ``jax_compat.sweep_mesh``): a pure ``("wl",)`` mesh shards the
    stacked per-op arrays under GSPMD, while a mesh with a ``"knob"``
    axis — optionally crossed with ``"wl"`` — runs the explicit
    ``shard_map`` program that also shards the unique-width /
    (width, delay)-pair / knob axes (jax backend only).

    ``knob_grid`` accepts a ``KnobGrid`` (crossed via ``product()``), a
    flat sequence of ``PolicyKnobs``, or ``None`` (the single default
    point). ``backend=None`` / ``jax_mesh=None`` resolve through the
    active ``repro.core.session.SweepSession`` (the session mesh is
    only consulted when the effective backend is jax).
    """
    if isinstance(workloads, Workload):
        workloads = [workloads]
    workloads = list(workloads)
    npu_specs = tuple(get_npu(n) if isinstance(n, str) else n for n in npus)
    policies = tuple(policies)
    knob_grid = as_knob_tuple(knob_grid)
    _validate_knob_grid(knob_grid)
    backend = backend_mod.default_backend() if backend is None else backend
    if jax_mesh is None and backend != "numpy":
        from repro.core import session
        jax_mesh = session.resolve("jax_mesh")
    if backend != "numpy" or jax_mesh is not None:
        if jax_mesh is not None and backend == "numpy":
            raise ValueError("jax_mesh requires backend='jax'")
        return _evaluate_batch_backend(workloads, npu_specs, policies,
                                       knob_grid, get_backend(backend),
                                       mesh=jax_mesh)
    st = stack_traces(workloads)
    W, A, P, K = len(workloads), len(npu_specs), len(policies), \
        len(knob_grid)
    shape = (W, A, P, K)
    runtime = np.zeros(shape)
    static_j = {c: np.zeros(shape) for c in COMPONENTS}
    dynamic_j = {c: np.zeros(shape) for c in COMPONENTS}
    wake_events = {c: np.zeros(shape) for c in COMPONENTS}
    gated_s = {c: np.zeros(shape) for c in COMPONENTS}
    setpm_by = {c: np.zeros(shape) for c in COMPONENTS}

    for ai, base_npu in enumerate(npu_specs):
        # group the knob grid by effective SA width: each group runs on
        # a memoized width-variant spec (the scalar engines' oracle
        # semantics), scattering its columns back into the knob axis
        saw_of = [k.sa_width if k.sa_width is not None
                  else base_npu.sa_width for k in knob_grid]
        for saw in dict.fromkeys(saw_of):
            idx = np.flatnonzero(np.array(saw_of) == saw)
            sub_grid = [knob_grid[i] for i in idx]
            npu = with_sa_width(base_npu, saw)
            ctx = _batch_ctx(st, npu)
            g = ctx["gating"]
            kp = {
                "K": len(sub_grid),
                "dscale": np.array([k.delay_scale for k in sub_grid]),
                "wscale": np.array([k.window_scale for k in sub_grid]),
                "leak_logic": np.array(
                    [k.leak_off_logic if k.leak_off_logic is not None
                     else g.leak_off_logic for k in sub_grid]),
                "leak_sleep": np.array(
                    [k.leak_sram_sleep if k.leak_sram_sleep is not None
                     else g.leak_sram_sleep for k in sub_grid]),
                "leak_off": np.array(
                    [k.leak_sram_off if k.leak_sram_off is not None
                     else g.leak_sram_off for k in sub_grid]),
            }
            cell_cache: dict = {}
            for pi, policy in enumerate(policies):
                cp = _component_policies(policy)
                ov_total = np.zeros((W, len(sub_grid)))
                for c in ("sa", "vu", "hbm", "ici"):
                    key = (c, cp[c])
                    cell = cell_cache.get(key)
                    if cell is None:
                        cell = _comp_cell(ctx, c, cp[c], kp)
                        cell_cache[key] = cell
                    static_j[c][:, ai, pi, idx] = cell["static"]
                    wake_events[c][:, ai, pi, idx] = cell["wakes"]
                    setpm_by[c][:, ai, pi, idx] = cell["setpm"]
                    gated_s[c][:, ai, pi, idx] = cell["gated"]
                    dynamic_j[c][:, ai, pi, idx] = \
                        ctx["comp"][c]["dyn_seg"][:, None]
                    ov_total += cell["overhead"]

                # --- SRAM: capacity-proportional static, gated rest ---
                pol = cp["sram"]
                lk = {"on": np.ones(len(sub_grid)),
                      "sleep": kp["leak_sleep"],
                      "off": kp["leak_off"]}.get(pol.sram_state,
                                                 np.zeros(len(sub_grid)))
                static_j["sram"][:, ai, pi, idx] = \
                    ctx["static_w"]["sram"] * (
                        ctx["sram_U_seg"][:, None]
                        + lk[None, :] * ctx["sram_GU_seg"][:, None])
                if pol.sram_state != "on":
                    gated_s["sram"][:, ai, pi, idx] = \
                        ctx["sram_GU_seg"][:, None]
                if pol.sram_state in ("sleep", "off") \
                        and pol.mode == "sw":
                    setpm_by["sram"][:, ai, pi, idx] = \
                        ctx["sram_setpm_seg"][:, None]
                dynamic_j["sram"][:, ai, pi, idx] = \
                    ctx["sram_dyn_seg"][:, None]

                # --- other: never gated ---
                static_j["other"][:, ai, pi, idx] = \
                    (ctx["static_w"]["other"] * ctx["D_seg"])[:, None]
                dynamic_j["other"][:, ai, pi, idx] = \
                    (ctx["dyn_w"]["other"] * 0.3 * ctx["D_seg"])[:, None]

                runtime[:, ai, pi, idx] = ctx["D_seg"][:, None] + ov_total

    return BatchResult(
        workloads=tuple(st.names), npus=npu_specs, policies=policies,
        knob_grid=knob_grid, runtime_s=runtime, static_j=static_j,
        dynamic_j=dynamic_j, wake_events=wake_events, gated_s=gated_s,
        setpm_by=setpm_by)


def evaluate_all(wl: Workload, npu="NPU-D",
                 knobs: PolicyKnobs = PolicyKnobs()) \
        -> dict[str, EnergyReport]:
    """All five policies for one workload — a thin wrapper over the
    batched plane (one stacked pass instead of five engine calls)."""
    res = evaluate_batch(wl, (npu,), POLICIES, (knobs,))
    return {p: res.report(0, 0, pi, 0) for pi, p in enumerate(POLICIES)}


def savings_vs_nopg(reports: dict[str, EnergyReport]) -> dict[str, float]:
    base = reports["NoPG"].total_j
    return {p: 1.0 - r.total_j / base for p, r in reports.items()}
