"""Compiler passes for software-managed power gating (paper §4.3).

Runs after instruction scheduling and SRAM allocation:

* ``analyze_vu_idleness``  — distances (cycles) between consecutive
  instructions in each VU slot; a DMA between two VU instructions makes the
  distance effectively infinite (HBM latency >> VU BET).
* ``analyze_sram_lifetimes`` — per-4KB-segment idle intervals from buffer
  (start, end, addr, size) lifetimes out of the allocator.
* ``instrument_setpm`` — BET-based policy: gate an interval iff it is
  longer than BET *and* longer than 2x the on/off delay; insert
  ``setpm off`` at interval start and ``setpm on`` ``delay`` cycles before
  the next use so the wake-up is hidden.

Both passes are linear in program length (paper §4.4).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Optional

from repro.core.hw import NPUSpec, SRAM_SEGMENT_BYTES, get_npu
from repro.core.isa import Instr, PMode, scaled_delay, setpm, unit_index

INF = float("inf")


@dataclass(frozen=True)
class SlotUse:
    """One scheduled use of a functional-unit slot."""
    cycle: int
    unit: str          # e.g. "vu0"
    opcode: str = "op"
    duration: int = 1


@dataclass(frozen=True)
class IdleInterval:
    unit: str
    start: int         # first idle cycle
    end: float         # first busy cycle again (inf = never)
    # a DMA issues inside the interval: the HBM round-trip dominates, so
    # the gate decision treats the length as unbounded even though the
    # wake still has to land before ``end`` (paper §4.3)
    unbounded: bool = False

    @property
    def length(self) -> float:
        return self.end - self.start


def analyze_vu_idleness(uses: list[SlotUse],
                        dma_cycles: Optional[list[int]] = None,
                        horizon: Optional[int] = None,
                        include_leading: bool = False) \
        -> dict[str, list[IdleInterval]]:
    """Idle intervals per VU slot. ``dma_cycles``: cycles at which a DMA
    issues — an interval containing one is marked ``unbounded`` (the DMA
    latency dominates the gate decision). ``include_leading`` also emits
    the [0, first_use) interval, which the workload-scale lowering needs
    to mirror the policy engine's merged-gap accounting."""
    dma_cycles = sorted(dma_cycles or [])
    by_unit: dict[str, list[SlotUse]] = {}
    for u in sorted(uses, key=lambda s: s.cycle):
        by_unit.setdefault(u.unit, []).append(u)
    out: dict[str, list[IdleInterval]] = {}
    for unit, us in by_unit.items():
        ivs = []
        if include_leading and us and us[0].cycle > 0:
            ivs.append(IdleInterval(unit, 0, us[0].cycle))
        for a, b in zip(us, us[1:]):
            start = a.cycle + a.duration
            end: float = b.cycle
            if end <= start:
                continue
            unbounded = any(start <= d < end for d in dma_cycles)
            ivs.append(IdleInterval(unit, start, end, unbounded=unbounded))
        if horizon is not None and us:
            tail = us[-1].cycle + us[-1].duration
            if horizon > tail:
                ivs.append(IdleInterval(unit, tail, horizon))
        out[unit] = ivs
    return out


@dataclass(frozen=True)
class BufferLifetime:
    """Output of the SRAM allocation pass for one buffer."""
    start_cycle: int
    end_cycle: int
    addr: int
    size: int


def analyze_sram_lifetimes(bufs: list[BufferLifetime], sram_bytes: int,
                           horizon: int) -> list[tuple[int, list]]:
    """Per-segment busy intervals -> [(segment_index, [(start, end), ...])].
    Segments with no buffer at all have an empty list (always idle)."""
    n_seg = sram_bytes // SRAM_SEGMENT_BYTES
    seg_busy: list[list[tuple[int, int]]] = [[] for _ in range(n_seg)]
    for b in bufs:
        s0 = b.addr // SRAM_SEGMENT_BYTES
        s1 = (b.addr + b.size - 1) // SRAM_SEGMENT_BYTES
        for s in range(s0, min(s1 + 1, n_seg)):
            seg_busy[s].append((b.start_cycle, b.end_cycle))
    out = []
    for s in range(n_seg):
        ivs = sorted(seg_busy[s])
        merged: list[tuple[int, int]] = []
        for st, en in ivs:
            if merged and st <= merged[-1][1]:
                merged[-1] = (merged[-1][0], max(merged[-1][1], en))
            else:
                merged.append((st, en))
        out.append((s, merged))
    return out


@dataclass(frozen=True)
class SetpmPlacement:
    cycle: int
    instr: Instr
    reason: str


def should_gate(interval_len, bet: int, delay: int):
    """Paper §4.3: gate iff idle > BET AND idle > 2x on/off delay.

    Accepts a scalar (returns bool) or a numpy array of interval
    lengths (returns a bool mask) — the one definition of the rule for
    both the per-interval passes and the vectorized segment-band path.
    """
    return (interval_len > bet) & (interval_len > 2 * delay)


def instrument_setpm(vu_idle: dict[str, list[IdleInterval]],
                     npu: NPUSpec | str = "NPU-D", fu_type: str = "vu",
                     bet_key: Optional[str] = None,
                     delay_key: Optional[str] = None,
                     delay_scale: float = 1.0) -> list[SetpmPlacement]:
    """BET-based setpm insertion for one FU family (default VU). Adjacent
    slots gated by the same interval share one setpm via the fu bitmap
    (paper: one misc slot per cycle, bitmap amortizes). ``bet_key`` /
    ``delay_key`` override the Table-3 row (default: the fu type);
    ``delay_scale`` applies the §6.5 knob — BETs scale with the delays
    (the closed-form engine's convention) and the pre-wake placement
    uses the integer delay the scaled executor wakes with
    (``isa.scaled_delay``), so the hidden-wake alignment is preserved
    at every scale."""
    npu = get_npu(npu) if isinstance(npu, str) else npu
    bet = npu.gating.bet[bet_key or fu_type] * delay_scale
    delay = scaled_delay(npu.gating, delay_key or fu_type, delay_scale)
    # group intervals by (start, end) so one bitmap covers multiple units
    groups: dict[tuple, int] = {}
    for unit, ivs in vu_idle.items():
        idx = unit_index(unit)
        for iv in ivs:
            profitable = should_gate(iv.length, bet, delay)
            # a DMA-unbounded interval still needs room for the wake to
            # land strictly after the gate — below that, gating would
            # invert the off/on sequence and expose the full delay
            if profitable or (iv.unbounded and iv.length > delay):
                key = (iv.start, iv.end, profitable)
                groups[key] = groups.get(key, 0) | (1 << idx)
    out = []
    for (start, end, profitable), bitmap in sorted(groups.items()):
        reason = (f"idle {end - start:.0f} > bet {bet:g}" if profitable
                  else "dma-unbounded idle")
        out.append(SetpmPlacement(
            int(start), setpm(fu_type, bitmap, PMode.OFF), reason))
        if end != INF:
            wake_at = int(end) - delay
            out.append(SetpmPlacement(
                wake_at, setpm(fu_type, bitmap, PMode.ON),
                "pre-wake (hidden delay)"))
    return out


def sram_setpm_plan(seg_intervals: list[tuple[int, list]], horizon: int,
                    npu: NPUSpec | str = "NPU-D") -> list[SetpmPlacement]:
    """Whole-range OFF setpm for segments never used plus gap gating for
    segments with long dead intervals. Contiguous segment ranges collapse
    into single range-setpm instructions (paper Fig 14 variant 1)."""
    npu = get_npu(npu) if isinstance(npu, str) else npu
    bet = npu.gating.bet["sram_off"]
    delay = npu.gating.on_off_delay["sram_off"]
    dead: list[int] = [s for s, ivs in seg_intervals if not ivs]
    out: list[SetpmPlacement] = []
    # collapse contiguous dead segments into ranges
    i = 0
    while i < len(dead):
        j = i
        while j + 1 < len(dead) and dead[j + 1] == dead[j] + 1:
            j += 1
        lo = dead[i] * SRAM_SEGMENT_BYTES
        hi = (dead[j] + 1) * SRAM_SEGMENT_BYTES
        out.append(SetpmPlacement(
            0, setpm("sram", 0, PMode.OFF, (lo, hi)), "never used"))
        i = j + 1
    # per-segment gaps
    for s, ivs in seg_intervals:
        if not ivs:
            continue
        for (a_s, a_e), (b_s, _) in zip(ivs, ivs[1:]):
            if should_gate(b_s - a_e, bet, delay):
                rng = (s * SRAM_SEGMENT_BYTES, (s + 1) * SRAM_SEGMENT_BYTES)
                out.append(SetpmPlacement(
                    a_e, setpm("sram", 0, PMode.OFF, rng), "dead interval"))
                out.append(SetpmPlacement(
                    b_s - delay, setpm("sram", 0, PMode.ON, rng), "pre-wake"))
        tail = ivs[-1][1]
        if should_gate(horizon - tail, bet, delay):
            rng = (s * SRAM_SEGMENT_BYTES, (s + 1) * SRAM_SEGMENT_BYTES)
            out.append(SetpmPlacement(
                tail, setpm("sram", 0, PMode.OFF, rng), "tail dead"))
    return out
