"""SLO-constrained configuration search + cross-generation energy
efficiency (paper §3, Fig 2, Table 4).

The paper's methodology: profile each workload at the default batch on
the minimum number of NPU-D chips; 1/5 of that performance is the 1xSLO;
for every NPU generation, sweep (chips, batch) and keep the most
energy-efficient SLO-compliant configuration. We reproduce the sweep with
the op-level simulator: performance = tokens/s (train, decode) or
requests/s (prefill); energy efficiency = useful work per joule.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

import numpy as np

from repro.core.hw import NPUS, get_npu
from repro.core.opgen import Workload, llm_workload
from repro.core.sweep import group_by, sweep


@dataclass(frozen=True)
class SweepPoint:
    npu: str
    n_chips: int
    batch: int
    perf: float           # work units / s (all chips together)
    energy_j: float       # J per workload invocation (all chips)
    work: float           # work units per invocation

    @property
    def efficiency(self) -> float:
        return self.work / self.energy_j  # work per J


def _work_units(phase: str, batch: int) -> float:
    if phase == "train":
        return batch * 4096.0          # tokens per step
    return float(batch)                # requests (prefill) / tokens (decode)


def _config_workloads(model: str, phase: str,
                      configs: list[tuple[int, int]]) -> list[Workload]:
    wls = []
    for n_chips, batch in configs:
        tp = min(n_chips, 8)
        dp = max(1, n_chips // tp)
        wls.append(llm_workload(model, phase, batch=batch, n_chips=n_chips,
                                tp=tp, dp=dp))
    return wls


def _points(recs: list[dict], configs: list[tuple[int, int]],
            phase: str, npu: str) -> list[SweepPoint]:
    out = []
    for (n_chips, batch), rec in zip(configs, recs):
        work = _work_units(phase, batch)
        out.append(SweepPoint(npu, n_chips, batch,
                              work / rec["runtime_s"],
                              rec["total_j"] * n_chips, work))
    return out


def _measure_batch(model: str, phase: str, npu: str,
                   configs: list[tuple[int, int]],
                   backend: Optional[str] = None) -> list[SweepPoint]:
    """Evaluate all (n_chips, batch) candidates through one batched
    sweep() call (one stacked trace, one set of array passes)."""
    wls = _config_workloads(model, phase, configs)
    recs = sweep(wls, npus=(npu,), policies=("NoPG",), backend=backend)
    return _points(recs, configs, phase, npu)


def _measure(model: str, phase: str, npu: str, n_chips: int,
             batch: int, backend: Optional[str] = None) -> SweepPoint:
    return _measure_batch(model, phase, npu, [(n_chips, batch)],
                          backend)[0]


def hbm_fits(model: str, npu: str, n_chips: int, batch: int,
             phase: str) -> bool:
    """Coarse capacity check: weights (+optimizer for train) + KV cache."""
    from repro.core.opgen import LLAMA
    c = LLAMA[model]
    n_params = c.L * (c.d * (c.d + 2 * c.Hkv * (c.d // c.H) + c.d)
                      + 3 * c.d * c.ff) + 2 * c.d * c.vocab
    spec = get_npu(npu)
    bytes_needed = n_params * (16.0 if phase == "train" else 2.0)
    if phase != "train":
        kv = c.L * batch * 4608 * 2 * c.Hkv * (c.d // c.H) * 2.0
        bytes_needed += kv
    return bytes_needed <= spec.hbm_gb * 1e9 * n_chips * 0.9


def runtime_violation_rate(runtimes, baselines,
                           slo_relax: float = 1.1) -> float:
    """Fraction of cells whose runtime exceeds ``slo_relax`` x baseline.

    The jitter-plane SLO metric (``sweep.sweep_robustness``): each
    perturbed cell's baseline is the clean-trace runtime of the same
    (workload, npu, policy, threshold) cell, so the rate measures how
    often jitter alone pushes a configuration past its relaxed SLO.
    Shapes must match element-for-element; empty input has rate 0.
    """
    if slo_relax <= 0:
        raise ValueError(f"slo_relax must be > 0, got {slo_relax}")
    r = np.asarray(runtimes, np.float64)
    b = np.asarray(baselines, np.float64)
    if r.shape != b.shape:
        raise ValueError(
            f"runtimes {r.shape} and baselines {b.shape} must match")
    if r.size == 0:
        return 0.0
    return float(np.mean(r > slo_relax * b))


@dataclass(frozen=True)
class Hysteresis:
    """Anti-thrash parameters for the stateful ``retune_knobs`` governor.

    ``cooldown_epochs``: minimum epochs between retunes of one row.
    ``min_improvement``: an opportunistic (deployed-still-feasible)
    retune needs the cheapest feasible knob to save at least this
    fraction of the deployed knob's energy. ``backoff_base`` /
    ``backoff_cap``: after each *forced* retune in an unbroken run of
    SLO violations the row's cooldown multiplies by ``backoff_base``
    (capped at ``backoff_cap`` epochs) — repeated violations mean the
    environment is flapping faster than retuning can help, so the
    governor backs off exponentially instead of chasing it.
    """

    cooldown_epochs: int = 2
    min_improvement: float = 0.02
    backoff_base: float = 2.0
    backoff_cap: int = 16

    def __post_init__(self):
        if not (isinstance(self.cooldown_epochs, (int, np.integer))
                and self.cooldown_epochs >= 0):
            raise ValueError(f"cooldown_epochs must be >= 0, "
                             f"got {self.cooldown_epochs!r}")
        if not (isinstance(self.min_improvement, (int, float))
                and np.isfinite(self.min_improvement)
                and 0.0 <= self.min_improvement < 1.0):
            raise ValueError(f"min_improvement must be in [0, 1), "
                             f"got {self.min_improvement!r}")
        if not (isinstance(self.backoff_base, (int, float))
                and np.isfinite(self.backoff_base)
                and self.backoff_base >= 1.0):
            raise ValueError(f"backoff_base must be >= 1, "
                             f"got {self.backoff_base!r}")
        if not (isinstance(self.backoff_cap, (int, np.integer))
                and self.backoff_cap >= 1):
            raise ValueError(f"backoff_cap must be >= 1, "
                             f"got {self.backoff_cap!r}")


@dataclass
class GovernorState:
    """Per-row mutable state threaded through epochs of stateful
    ``retune_knobs`` calls. ``retunes`` accumulates the per-row switch
    count (the anti-thrash metric)."""

    since_retune: np.ndarray   # epochs since the row last switched
    cooldown: np.ndarray       # current required gap before switching
    forced_streak: np.ndarray  # consecutive forced retunes (backoff)
    retunes: np.ndarray        # cumulative switches

    @classmethod
    def init(cls, n: int, hysteresis: "Hysteresis") -> "GovernorState":
        if not (isinstance(n, (int, np.integer)) and n >= 0):
            raise ValueError(f"n must be >= 0, got {n!r}")
        big = np.iinfo(np.int64).max // 2
        return cls(
            since_retune=np.full(n, big, np.int64),
            cooldown=np.full(n, int(hysteresis.cooldown_epochs),
                             np.int64),
            forced_streak=np.zeros(n, np.int64),
            retunes=np.zeros(n, np.int64))


def retune_knobs(energy, runtime, slo_runtime, deployed=None, *,
                 hysteresis: Optional[Hysteresis] = None,
                 state: Optional[GovernorState] = None) -> np.ndarray:
    """The SLO-constrained knob re-tune rule, vectorized over rows.

    This is the operator policy shared by the jitter plane
    (``sweep.sweep_robustness``) and the fleet governor
    (``fleet.sweep_fleet``): given per-row knob candidates with
    ``energy`` and ``runtime`` of shape (N, K) and an SLO runtime bound
    ``slo_runtime`` (broadcastable to (N, K)), keep the ``deployed``
    knob (default: the per-row energy argmin) while it meets the bound;
    once it violates, re-tune to the cheapest (lowest-energy) feasible
    knob; when no knob is feasible, fall back to the least-violating
    one (smallest runtime/bound ratio). Ties resolve to the lowest knob
    index. Returns the chosen knob index per row, shape (N,).

    With ``hysteresis`` (which then requires ``state`` and an explicit
    ``deployed``), the rule becomes the stateful anti-thrash governor:
    a row only switches when its cooldown has elapsed, forced switches
    (deployed violating) grow the cooldown exponentially while the
    violation streak lasts, and opportunistic switches additionally
    need a ``min_improvement`` energy saving. In a piecewise-constant
    environment the chosen knob is a fixed point of the stateless rule
    immediately after any switch (cheapest-feasible stays cheapest;
    least-violating stays least-violating), so the governor retunes at
    most once per fault transition — the bound ``tests/test_chaos.py``
    asserts. Stateless calls (``hysteresis=None``) are byte-for-byte
    the historical behavior.
    """
    e = np.asarray(energy, np.float64)
    r = np.asarray(runtime, np.float64)
    b = np.broadcast_to(np.asarray(slo_runtime, np.float64), r.shape)
    if e.shape != r.shape or e.ndim != 2:
        raise ValueError(
            f"energy {e.shape} and runtime {r.shape} must be equal 2-D")
    n = e.shape[0]
    rows = np.arange(n)
    if deployed is None:
        if hysteresis is not None:
            raise ValueError(
                "hysteresis requires an explicit deployed vector (the "
                "governor tracks what is currently running)")
        deployed = np.argmin(e, axis=1)
    deployed = np.asarray(deployed, np.int64)
    feas = r <= b
    any_feas = feas.any(axis=1)
    cheapest = np.argmin(np.where(feas, e, np.inf), axis=1)
    least_viol = np.argmin(r / np.maximum(b, 1e-300), axis=1)
    chosen = deployed.copy()
    need = ~feas[rows, deployed]
    chosen[need & any_feas] = cheapest[need & any_feas]
    chosen[need & ~any_feas] = least_viol[need & ~any_feas]
    if hysteresis is None:
        return chosen

    if state is None:
        raise ValueError("hysteresis requires a GovernorState "
                         "(GovernorState.init(n, hysteresis))")
    if state.since_retune.shape != (n,):
        raise ValueError(
            f"GovernorState is for {state.since_retune.shape[0]} rows, "
            f"got {n}")
    ready = state.since_retune >= state.cooldown
    # forced: deployed violates and the stateless target differs
    forced = need & ready & (chosen != deployed)
    # opportunistic: deployed feasible, cheapest feasible saves enough
    cheap_e = np.where(any_feas, e[rows, cheapest], np.inf)
    oppo = (~need & ready & (cheapest != deployed) & any_feas
            & (cheap_e <= (1.0 - hysteresis.min_improvement)
               * e[rows, deployed]))
    switch = forced | oppo
    target = np.where(need, chosen, cheapest)
    out = np.where(switch, target, deployed).astype(np.int64)
    # state update: streak counts back-to-back forced switches and
    # resets the moment the deployed knob is feasible again
    state.forced_streak = np.where(
        forced, state.forced_streak + 1,
        np.where(~need, 0, state.forced_streak))
    base_cd = max(1, int(hysteresis.cooldown_epochs))
    backoff = np.minimum(
        float(hysteresis.backoff_cap),
        base_cd * np.power(hysteresis.backoff_base,
                           np.minimum(state.forced_streak - 1, 40)))
    state.cooldown = np.where(
        forced, np.maximum(1, backoff.astype(np.int64)),
        np.where(oppo, int(hysteresis.cooldown_epochs),
                 state.cooldown))
    state.retunes = state.retunes + switch.astype(np.int64)
    state.since_retune = np.where(
        switch, 0, np.minimum(state.since_retune + 1,
                              np.iinfo(np.int64).max // 2))
    return out


def slo_sweep(model: str, phase: str, *, slo_relax: float = 5.0,
              gens=("NPU-A", "NPU-B", "NPU-C", "NPU-D", "NPU-E"),
              batches=(1, 4, 8, 32, 128, 512),
              chip_counts=(1, 2, 4, 8, 16, 32, 64),
              backend: Optional[str] = None) -> dict:
    """Returns {gen: best SweepPoint or None, "_slo": value}.

    ``backend`` selects the sweep array substrate (``"numpy"`` /
    ``"jax"``; ``None`` = session default) for the one batched
    (config × generation) evaluation the search rides on.
    """
    # reference: default batch, minimum NPU-D chips that fit
    ref_batch = {"train": 32, "prefill": 4, "decode": 8}[phase]
    ref = None
    for n in chip_counts:
        if hbm_fits(model, "NPU-D", n, ref_batch, phase):
            ref = _measure(model, phase, "NPU-D", n, ref_batch, backend)
            break
    if ref is None:
        return {"_slo": None}
    # per-chip normalized SLO (1/5 of reference performance per chip)
    slo_perf_per_chip = ref.perf / ref.n_chips / slo_relax

    out: dict = {"_slo": slo_perf_per_chip}
    # all generations ride ONE batched sweep: build each (chips, batch)
    # candidate workload once (instead of per generation) and evaluate
    # the full (config × generation) grid in a single stacked pass;
    # per-generation HBM-capacity filtering happens on the records.
    fits = {gen: {(n, b) for n in chip_counts for b in batches
                  if hbm_fits(model, gen, n, b, phase)} for gen in gens}
    union = [(n, b) for n in chip_counts for b in batches
             if any((n, b) in fits[gen] for gen in gens)]
    wls = _config_workloads(model, phase, union)
    recs = sweep(wls, npus=gens, policies=("NoPG",), backend=backend)
    by_gen = group_by(recs, "npu")  # workload-major order within each gen
    for gen in gens:
        gen_recs = by_gen.get((get_npu(gen).name,), [])
        best: Optional[SweepPoint] = None
        for cfg, pt in zip(union, _points(gen_recs, union, phase, gen)):
            if cfg not in fits[gen]:
                continue
            if pt.perf / pt.n_chips < slo_perf_per_chip:
                continue
            if best is None or pt.efficiency > best.efficiency:
                best = pt
        out[gen] = best
    return out
