"""Operator-trace generator — the simulator frontend (paper §4.4).

Lowers a workload description (the paper's Table 1 suite, or one of our
assigned architecture configs x input shapes) into a per-operator trace:
SA/VU FLOPs, HBM/ICI bytes, SRAM tile demand, and matmul dims for the SA
spatial-gating model. The backend (``repro.core.policies``) turns the trace
into per-component times and energies under each power-gating design.

The same role as the paper artifact's ``llm_ops_generator``.
"""
from __future__ import annotations

import math
import weakref
from dataclasses import dataclass, field, replace
from typing import Optional

import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig


@dataclass(frozen=True)
class Op:
    name: str
    flops_sa: float = 0.0          # MXU-mapped FLOPs
    flops_vu: float = 0.0          # vector FLOPs
    bytes_hbm: float = 0.0
    bytes_ici: float = 0.0
    sram_demand: int = 0           # resident bytes needed (tile working set)
    matmul_dims: Optional[tuple[int, int, int]] = None  # (M, K, N) per SA op
    count: int = 1                 # consecutive repetitions (e.g. layers)
    collective: bool = False       # uses ICI

    def scaled(self, n: int) -> "Op":
        return replace(self, count=self.count * n)


@dataclass(frozen=True)
class Workload:
    name: str
    kind: str                      # train | prefill | decode
    ops: tuple[Op, ...]
    n_chips: int = 1
    note: str = ""

    def total(self, attr: str) -> float:
        return sum(getattr(o, attr) * o.count for o in self.ops)


# --------------------------------------------------------------------------
# Columnar trace compilation (struct-of-arrays backend representation)
# --------------------------------------------------------------------------

@dataclass(frozen=True, eq=False)
class TraceArrays:
    """Struct-of-arrays view of a Workload's op stream.

    One entry per Op (NOT per executed instance — ``count`` carries the
    repetition factor, matching the scalar engine's per-op accounting).
    ``matmul_dims`` is split into ``mm_m/mm_k/mm_n`` with ``has_mm``
    masking the rows where it was None (sentinel dims are 1).

    The ``_derived`` dict caches per-NPU service-time arrays computed by
    the policy engine; it is keyed by quantities that do not depend on
    gating knobs, so one compiled trace serves every (policy, knobs) cell
    of a sweep.
    """

    n_ops: int
    flops_sa: np.ndarray       # f8 (n_ops,)
    flops_vu: np.ndarray       # f8
    bytes_hbm: np.ndarray      # f8
    bytes_ici: np.ndarray      # f8
    sram_demand: np.ndarray    # f8
    count: np.ndarray          # f8 — repetitions per op
    collective: np.ndarray     # bool
    has_mm: np.ndarray         # bool
    mm_m: np.ndarray           # i8 (1 where has_mm is False)
    mm_k: np.ndarray           # i8
    mm_n: np.ndarray           # i8
    names: tuple[str, ...]
    _derived: dict = field(default_factory=dict, compare=False, repr=False)

    @property
    def n_instances(self) -> float:
        """Executed op-stream length (counts expanded)."""
        return float(self.count.sum())

    def total(self, attr: str) -> float:
        return float((getattr(self, attr) * self.count).sum())


# Identity-keyed: hashing a Workload walks its full op tuple (~11k frozen
# dataclasses for the paper suite), which costs more than the vectorized
# evaluation itself. Weak refs keep the cache from pinning workloads
# alive; the finalizer drops an entry when its workload is collected, so
# ids can never be observed after reuse.
_TRACE_CACHE: dict[int, tuple["weakref.ref", "TraceArrays"]] = {}


def compile_trace(wl: Workload) -> TraceArrays:
    """Lower a Workload's op tuple into cached columnar arrays."""
    hit = _TRACE_CACHE.get(id(wl))
    if hit is not None and hit[0]() is wl:
        return hit[1]
    tr = _compile_trace(wl)
    key = id(wl)
    _TRACE_CACHE[key] = (weakref.ref(wl, lambda _: _TRACE_CACHE.pop(key,
                                                                    None)),
                         tr)
    return tr


def _validate_trace(wl: Workload, cols: dict[str, np.ndarray],
                    has_mm: np.ndarray, dims: np.ndarray) -> None:
    """Reject malformed op streams before they reach the policy engine.

    Negative or non-finite service-time carriers (flops / bytes /
    counts) would silently corrupt durations, idle gaps, and energy
    totals downstream — raise a ``ValueError`` naming the workload, op,
    and field instead. Zero-dim matmuls are equally rejected (the SA
    occupancy model divides by them).
    """
    for fld, a in cols.items():
        bad = ~np.isfinite(a)
        kind = "non-finite"
        if not bad.any():
            bad = a < 0
            kind = "negative"
        if bad.any():
            i = int(np.flatnonzero(bad)[0])
            raise ValueError(
                f"workload {wl.name!r}: {kind} {fld}={a[i]!r} at op "
                f"{i} ({wl.ops[i].name!r}) — would corrupt service "
                f"times/energy silently")
    if has_mm.any() and (dims[has_mm] < 1).any():
        i = int(np.flatnonzero(has_mm & (dims < 1).any(axis=1))[0])
        raise ValueError(
            f"workload {wl.name!r}: matmul_dims must be >= 1, got "
            f"{wl.ops[i].matmul_dims} at op {i} ({wl.ops[i].name!r})")


def _compile_trace(wl: Workload) -> TraceArrays:
    ops = wl.ops
    n = len(ops)
    mm = [o.matmul_dims for o in ops]
    has_mm = np.array([d is not None for d in mm], bool)
    dims = np.array([d if d is not None else (1, 1, 1) for d in mm],
                    np.int64).reshape(n, 3) if n else np.zeros((0, 3),
                                                               np.int64)
    cols = {
        "flops_sa": np.array([o.flops_sa for o in ops], np.float64),
        "flops_vu": np.array([o.flops_vu for o in ops], np.float64),
        "bytes_hbm": np.array([o.bytes_hbm for o in ops], np.float64),
        "bytes_ici": np.array([o.bytes_ici for o in ops], np.float64),
        "sram_demand": np.array([o.sram_demand for o in ops],
                                np.float64),
        "count": np.array([o.count for o in ops], np.float64),
    }
    _validate_trace(wl, cols, has_mm, dims)
    return TraceArrays(
        n_ops=n,
        flops_sa=cols["flops_sa"],
        flops_vu=cols["flops_vu"],
        bytes_hbm=cols["bytes_hbm"],
        bytes_ici=cols["bytes_ici"],
        sram_demand=cols["sram_demand"],
        count=cols["count"],
        collective=np.array([o.collective for o in ops], bool),
        has_mm=has_mm,
        mm_m=dims[:, 0], mm_k=dims[:, 1], mm_n=dims[:, 2],
        names=tuple(o.name for o in ops),
    )


# --------------------------------------------------------------------------
# Ragged trace stacking (the batched sweep plane's super-trace)
# --------------------------------------------------------------------------

@dataclass(frozen=True, eq=False)
class StackedTrace:
    """Ragged stack of per-workload ``TraceArrays``: one concatenated op
    stream plus segment bookkeeping.

    ``offsets`` (W+1,) holds the op-range of workload ``w`` as
    ``[offsets[w], offsets[w+1])``; ``seg_ids`` (N,) maps each op back to
    its workload. The batched policy engine
    (``repro.core.policies.evaluate_batch``) runs its array passes over
    the full stack and recovers per-workload results with segmented
    reductions, so gap merging and every other cross-op accumulation is
    bounded by the segment — idle intervals never leak across workload
    boundaries.

    ``_derived`` caches per-NPU stacked service times and idle-gap
    structures (keyed by spec identity, same convention as
    ``TraceArrays._derived``).
    """

    traces: tuple[TraceArrays, ...]
    names: tuple[str, ...]         # workload names, one per segment
    n_ops: int
    offsets: np.ndarray            # i8 (W+1,) op-range starts, last = n_ops
    seg_ids: np.ndarray            # i8 (N,) workload index per op
    flops_sa: np.ndarray           # f8 (N,) concatenated columns
    flops_vu: np.ndarray
    bytes_hbm: np.ndarray
    bytes_ici: np.ndarray
    sram_demand: np.ndarray
    count: np.ndarray
    collective: np.ndarray         # bool
    has_mm: np.ndarray             # bool
    _derived: dict = field(default_factory=dict, compare=False, repr=False)

    @property
    def n_segments(self) -> int:
        return len(self.names)


# Keyed by the tuple of compiled-trace ids. The cached StackedTrace holds
# strong references to its traces, so the ids stay valid for exactly as
# long as the entry exists; a small FIFO bound keeps ad-hoc sweeps from
# growing the cache without limit.
_STACK_CACHE: dict[tuple[int, ...], "StackedTrace"] = {}
_STACK_CACHE_MAX = 64


def stack_traces(workloads) -> StackedTrace:
    """Stack the compiled traces of ``workloads`` into one super-trace.

    Accepts a single Workload or a sequence; results are cached by the
    identity tuple of the compiled traces (compilation itself is cached
    per workload), so repeated sweeps over the same suite stack once.
    """
    if isinstance(workloads, Workload):
        workloads = [workloads]
    workloads = list(workloads)
    for i, wl in enumerate(workloads):
        if not isinstance(wl, Workload):
            raise ValueError(
                f"stack_traces expects Workload instances, got "
                f"{type(wl).__name__} at index {i}")
    # compile_trace validates each op stream (negative / non-finite
    # carriers raise), so a malformed trace can never enter the stack
    traces = tuple(compile_trace(wl) for wl in workloads)
    # a key hit implies identity: the entry holds strong refs to exactly
    # the traces whose ids form its key, so those ids cannot be reused
    key = tuple(id(tr) for tr in traces)
    hit = _STACK_CACHE.get(key)
    if hit is not None:
        return hit
    lengths = np.array([tr.n_ops for tr in traces], np.int64)
    offsets = np.zeros(len(traces) + 1, np.int64)
    np.cumsum(lengths, out=offsets[1:])
    n = int(offsets[-1])
    seg_ids = np.repeat(np.arange(len(traces), dtype=np.int64), lengths)

    def cat(attr, dtype):
        if not traces:
            return np.zeros(0, dtype)
        return np.concatenate([getattr(tr, attr) for tr in traces])

    st = StackedTrace(
        traces=traces, names=tuple(wl.name for wl in workloads),
        n_ops=n, offsets=offsets, seg_ids=seg_ids,
        flops_sa=cat("flops_sa", np.float64),
        flops_vu=cat("flops_vu", np.float64),
        bytes_hbm=cat("bytes_hbm", np.float64),
        bytes_ici=cat("bytes_ici", np.float64),
        sram_demand=cat("sram_demand", np.float64),
        count=cat("count", np.float64),
        collective=cat("collective", bool),
        has_mm=cat("has_mm", bool),
    )
    if len(_STACK_CACHE) >= _STACK_CACHE_MAX:
        _STACK_CACHE.pop(next(iter(_STACK_CACHE)))
    _STACK_CACHE[key] = st
    return st


def segment_sum(arr: np.ndarray, offsets: np.ndarray) -> np.ndarray:
    """Per-segment row sums: ``arr`` (N, ...) -> (W, ...) for the ragged
    segmentation ``offsets`` (W+1,).

    Empty segments sum to zero (``np.add.reduceat`` alone mishandles
    degenerate bounds). Within a segment the accumulation is
    left-to-right, matching the scalar engines' sequential ``+=`` order.
    ``offsets`` is coerced to int64, so callers may pass any integral
    dtype (or a Python list) without tripping ``reduceat``.
    """
    offsets = np.asarray(offsets, np.int64)
    n_seg = len(offsets) - 1
    out = np.zeros((n_seg,) + arr.shape[1:], np.float64)
    if n_seg == 0 or arr.shape[0] == 0:
        return out
    starts = offsets[:-1]
    nonempty = offsets[1:] > starts
    if nonempty.any():
        # empty segments span zero rows, so chunks between consecutive
        # non-empty starts cover exactly one segment each
        out[nonempty] = np.add.reduceat(arr, starts[nonempty], axis=0)
    return out


def segmented_gaps(active: np.ndarray, idle: np.ndarray,
                   offsets: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Merged idle-gap lengths per segment — the stacked counterpart of
    the policy engine's per-workload ``_merged_gaps``.

    ``active``/``idle`` are per-op over the whole stack (idle holds
    dur*count where the component is inactive, 0 where active). Each
    segment contributes one gap per active op (the merged idle time since
    the previous active op *within the segment*) plus one trailing gap;
    segment boundaries always break a gap, so idle time never merges
    across workloads. Returns ``(gap_vals, gap_offsets)`` where
    ``gap_offsets`` (W+1,) slices ``gap_vals`` per segment.

    Empty (zero-op) segments own zero gaps — their slice of
    ``gap_vals`` is empty and neighbouring segments keep their own
    trailing/leading gaps, so a zero-op workload in a stack contributes
    exactly nothing. (``repro.core.backend.gap_index`` is the
    fixed-shape counterpart used under ``jit``.)
    """
    offsets = np.asarray(offsets, np.int64)
    n_seg = len(offsets) - 1
    idx = np.flatnonzero(active)
    # a bound both ends the previous gap and starts the next one; segment
    # starts are always bounds, so chunks never span two workloads
    bounds = np.union1d(offsets[:-1], idx + 1)
    idle2 = np.append(idle, 0.0)
    if bounds.size == 0:
        return np.zeros(0), np.zeros(n_seg + 1, np.int64)
    gap_vals = np.add.reduceat(idle2, bounds)
    # chunk ownership: the segment containing the chunk's starting bound
    gseg = np.minimum(np.searchsorted(offsets, bounds, side="right") - 1,
                      n_seg - 1)
    gap_offsets = np.searchsorted(gseg, np.arange(n_seg + 1))
    return gap_vals, gap_offsets


# --------------------------------------------------------------------------
# Helpers
# --------------------------------------------------------------------------

BF16 = 2
F32 = 4


def _matmul(name, M, K, N, *, bytes_w=BF16, bytes_act=BF16, n_chips=1,
            count=1, sram_tile=None, reread=1.0) -> Op:
    """A [M,K]x[K,N] matmul; weights + activations stream from HBM.

    SRAM demand follows the paper's Fig 7 methodology: the minimum tile
    size that maximizes on-chip reuse. Compute-bound shapes (large M) want
    the weight tile resident plus double-buffered activations; memory-bound
    shapes (small M — decode GEMVs) gain nothing from large tiles and only
    need enough to hide HBM latency.
    """
    flops = 2.0 * M * K * N
    b = (K * N * bytes_w + M * K * bytes_act * reread + M * N * bytes_act)
    if sram_tile is None:
        if M >= 512:  # compute-bound: weight-stationary large tiles
            sram_tile = min(int(0.75 * 128 * 2 ** 20),
                            K * N * bytes_w + 2 * 512 * K * bytes_act
                            + 512 * N * F32)
        else:  # streaming: latency-hiding double buffers only
            sram_tile = min(8 << 20, b)
    # VU post-processes SA outputs (accumulate/cast/activation): fine-
    # grained interleaved work, 1 VU-op per output element (paper Fig 15)
    return Op(name, flops_sa=flops / n_chips,
              flops_vu=M * N * 2.0 / n_chips,
              bytes_hbm=b / n_chips,
              sram_demand=int(sram_tile), matmul_dims=(M, K, N),
              count=count)


def _vector(name, elems, flops_per_elem=2.0, bytes_per_elem=2 * BF16,
            n_chips=1, count=1, sram_tile=4 << 20) -> Op:
    return Op(name, flops_vu=elems * flops_per_elem / n_chips,
              bytes_hbm=elems * bytes_per_elem / n_chips,
              sram_demand=sram_tile, count=count)


def _collective(name, bytes_per_chip, count=1, sram_tile=8 << 20) -> Op:
    return Op(name, bytes_ici=bytes_per_chip, count=count,
              sram_demand=sram_tile, collective=True)


# --------------------------------------------------------------------------
# Paper Table 1 workloads (LLM train/prefill/decode, DLRM, diffusion)
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class LLMCfg:
    name: str
    L: int
    d: int
    H: int
    Hkv: int
    ff: int
    vocab: int


LLAMA = {
    "llama3-8b": LLMCfg("llama3-8b", 32, 4096, 32, 8, 14336, 128256),
    "llama2-13b": LLMCfg("llama2-13b", 40, 5120, 40, 40, 13824, 32000),
    "llama3-70b": LLMCfg("llama3-70b", 80, 8192, 64, 8, 28672, 128256),
    "llama3.1-405b": LLMCfg("llama3.1-405b", 126, 16384, 128, 8, 53248,
                            128256),
}


def llm_layer_ops(c: LLMCfg, T: int, *, n_chips: int, kv_len: int,
                  decode: bool, tp: int) -> list[Op]:
    """One transformer layer processing T tokens (per-chip amounts).

    tp: tensor-parallel ways (weights divided; activations all-reduced).
    """
    hd = c.d // c.H
    ops: list[Op] = []
    kv_dim = c.Hkv * hd
    # qkv + out projections (weights sharded tp-ways)
    ops.append(_matmul("qkv_proj", T, c.d, (c.d + 2 * kv_dim) // tp))
    if decode:
        # attention against KV cache: small M -> mapped to VU when tiny
        att_flops = 2.0 * T * kv_len * hd * c.H / tp * 2
        ops.append(Op("attn_decode", flops_vu=att_flops,
                      bytes_hbm=kv_len * kv_dim * BF16 * 2 / tp * max(1, T // 8),
                      sram_demand=8 << 20))
    else:
        # flash attention, scores+av on the SA
        att = 2.0 * T * kv_len * hd * 2 * (c.H / tp)
        ops.append(Op("attention", flops_sa=att,
                      bytes_hbm=3 * T * c.d * BF16 / tp,
                      matmul_dims=(T, hd, kv_len), sram_demand=24 << 20))
    ops.append(_matmul("out_proj", T, c.d // tp, c.d))
    ops.append(_collective("ar_attn", 2 * T * c.d * BF16 * (tp - 1) / tp)
               if tp > 1 else _vector("residual1", T * c.d))
    ops.append(_vector("rmsnorm1", T * c.d, flops_per_elem=4))
    ops.append(_matmul("mlp_up", T, c.d, 2 * c.ff // tp))
    ops.append(_vector("swiglu", T * c.ff / tp, flops_per_elem=3,
                       bytes_per_elem=0.5))
    ops.append(_matmul("mlp_down", T, c.ff // tp, c.d))
    ops.append(_collective("ar_mlp", 2 * T * c.d * BF16 * (tp - 1) / tp)
               if tp > 1 else _vector("residual2", T * c.d))
    ops.append(_vector("rmsnorm2", T * c.d, flops_per_elem=4))
    return ops


def llm_workload(model: str, phase: str, *, batch: int, seq: int = 4096,
                 out_seq: int = 512, n_chips: int = 1, tp: int = 1,
                 dp: int = 1) -> Workload:
    c = LLAMA[model]
    ops: list[Op] = []
    if phase == "train":
        T = batch * seq // dp
        layer = llm_layer_ops(c, T, n_chips=n_chips, kv_len=seq,
                              decode=False, tp=tp)
        # fwd + bwd (2x matmuls in bwd), layer sequences interleaved so the
        # per-component idle-gap structure matches real execution order
        ops += list(layer) * c.L
        bwd = [replace(o, name=o.name + "_bwd",
                       flops_sa=o.flops_sa * 2, flops_vu=o.flops_vu * 2,
                       bytes_hbm=o.bytes_hbm * 2) for o in layer]
        ops += list(bwd) * c.L
        ops.append(_matmul("lm_head", T, c.d, c.vocab // tp))
        n_params = c.L * (c.d * (c.d + 2 * c.Hkv * (c.d // c.H))
                          + c.d * c.d + 3 * c.d * c.ff) + c.d * c.vocab
        ops.append(_collective("grad_allreduce",
                               2 * n_params / (tp * dp) * BF16))
        ops.append(_vector("adam_update", n_params / (tp * dp),
                           flops_per_elem=12, bytes_per_elem=16))
    elif phase == "prefill":
        T = batch * seq
        layer = llm_layer_ops(c, T, n_chips=n_chips, kv_len=seq,
                              decode=False, tp=tp)
        ops += list(layer) * c.L
        ops.append(_matmul("lm_head", batch, c.d, c.vocab // tp))
    else:  # decode
        T = batch
        layer = llm_layer_ops(c, T, n_chips=n_chips, kv_len=seq + out_seq // 2,
                              decode=True, tp=tp)
        ops += list(layer) * c.L
        ops.append(_matmul("lm_head", T, c.d, c.vocab // tp))
    return Workload(f"{model}-{phase}", phase, tuple(ops), n_chips=n_chips)


def dlrm_workload(size: str, *, batch: int = 1024, n_chips: int = 8) \
        -> Workload:
    """DLRM: embedding-gather bound + small MLPs (paper: S/M/L tables)."""
    table_gb = {"S": 20, "M": 45, "L": 98}[size]
    n_tables, emb_dim = 64, 128
    lookups = 80
    bottom = [512, 256, 128]
    top = [1024, 1024, 512, 256, 1]
    ops: list[Op] = []
    # embedding gathers: HBM-random-access bound, tiny SRAM demand
    gather_bytes = batch * n_tables * lookups * emb_dim * F32 / n_chips
    ops.append(Op("emb_gather", bytes_hbm=gather_bytes,
                  flops_vu=batch * n_tables * lookups * emb_dim / n_chips,
                  sram_demand=4 << 20))
    # all-to-all to exchange embedding shards (model-parallel tables)
    ops.append(_collective("emb_alltoall",
                           batch * n_tables * emb_dim * F32 / n_chips,
                           sram_tile=4 << 20))
    prev = 13
    for i, w in enumerate(bottom):
        ops.append(_matmul(f"bot_mlp{i}", batch, prev, w, sram_tile=2 << 20))
        prev = w
    inter = n_tables + 1
    ops.append(_vector("interaction", batch * inter * inter * emb_dim / 64,
                       sram_tile=2 << 20))
    prev = inter * (inter - 1) // 2 + 128
    for i, w in enumerate(top):
        ops.append(_matmul(f"top_mlp{i}", batch, prev, w, sram_tile=2 << 20))
        prev = w
    return Workload(f"dlrm-{size}", "decode", tuple(ops), n_chips=n_chips,
                    note=f"tables={table_gb}GB")


def diffusion_workload(model: str, *, batch: int = 8, n_chips: int = 4) \
        -> Workload:
    ops: list[Op] = []
    if model == "dit-xl":
        L, d, H, ff, T = 28, 1152, 16, 4608, 1024
        hd = 72  # paper: head size 72 < SA width 128 -> spatial underuse
        steps = 4  # denoising steps folded into op counts
        Tb = T * batch
        for _ in range(1):
            layer = [
                _matmul("qkv", Tb, d, 3 * d),
                Op("attention", flops_sa=2.0 * Tb * T * hd * 2 * H,
                   bytes_hbm=3 * Tb * d * BF16,
                   matmul_dims=(Tb, hd, T), sram_demand=16 << 20),
                _matmul("proj", Tb, d, d),
                _vector("adaln", Tb * d, flops_per_elem=6),
                _matmul("mlp1", Tb, d, ff),
                _vector("gelu", Tb * ff, flops_per_elem=4, bytes_per_elem=0),
                _matmul("mlp2", Tb, ff, d),
            ]
            ops += [o.scaled(L * steps) for o in layer]
    else:  # gligen (U-Net): conv stages with shrinking spatial dims
        steps = 4
        res, ch = 64, 320
        for stage in range(4):
            r = res >> stage
            c_in = ch * (2 ** min(stage, 2))
            T = r * r * batch
            # conv as implicit GEMM: M=T, K=9*c_in, N=c_out
            ops.append(_matmul(f"conv{stage}", T, 9 * c_in, c_in,
                               count=6 * steps))
            if stage >= 1:  # attention blocks at lower res; head dim shrinks
                hd = max(40, 160 >> stage)
                ops.append(Op(f"attn{stage}",
                              flops_sa=2.0 * T * T / batch * hd * 2 * 8,
                              bytes_hbm=3 * T * c_in * BF16,
                              matmul_dims=(T, hd, T // batch),
                              sram_demand=16 << 20, count=2 * steps))
            ops.append(_vector(f"groupnorm{stage}", T * c_in,
                               flops_per_elem=6, count=6 * steps))
    return Workload(model, "prefill", tuple(ops), n_chips=n_chips)


# --------------------------------------------------------------------------
# Assigned-architecture workloads (execution plane -> power plane bridge)
# --------------------------------------------------------------------------

def arch_workload(cfg: ArchConfig, shape: ShapeConfig, *, n_chips: int = 256,
                  tp: int = 16) -> Workload:
    """Analytic operator trace for one of our (arch x shape) cells.

    Used when HLO statistics are not available (and cross-checked against
    the dry-run numbers in the benchmarks).
    """
    ops: list[Op] = []
    decode = shape.kind == "decode"
    B, S = shape.global_batch, shape.seq_len
    dp = max(1, n_chips // tp)
    T = (B if decode else B * S) // dp
    T = max(1, T)
    D = cfg.d_model
    kv_len = S
    train = shape.kind == "train"

    def add_layer(ops_layer, L):
        mult = 3 if train else 1  # fwd + 2x bwd
        seq_ops = [replace(o, flops_sa=o.flops_sa * mult,
                           flops_vu=o.flops_vu * mult,
                           bytes_hbm=o.bytes_hbm * mult)
                   for o in ops_layer]
        ops.extend(seq_ops * L)

    if cfg.family == "ssm":
        ss = cfg.ssm
        di = ss.d_inner(D)
        nh = ss.n_heads(D)
        layer = [
            _matmul("in_proj", T, D, 2 * di // tp),
            _vector("conv+act", T * di / tp, flops_per_elem=10),
            Op("ssd", flops_vu=T * nh * ss.head_dim * ss.d_state * 6 / tp,
               flops_sa=(0 if decode else
                         2.0 * T * ss.chunk * ss.head_dim * nh * 2 / tp),
               bytes_hbm=T * di * BF16 * 3 / tp,
               matmul_dims=None if decode else (T, ss.head_dim, ss.chunk),
               sram_demand=16 << 20),
            _matmul("out_proj", T, di // tp, D),
        ]
        add_layer(layer, cfg.n_layers)
    else:
        H = max(1, cfg.n_heads)
        hd = max(1, cfg.head_dim)
        layer = [
            _matmul("qkv", T, D, (H + 2 * cfg.n_kv_heads) * hd // tp)]
        if decode:
            layer.append(Op(
                "attn_decode",
                flops_vu=2.0 * T * kv_len * hd * 2 * H / tp,
                bytes_hbm=kv_len * cfg.n_kv_heads * hd * BF16 * 2
                * max(1, T // 8) / tp,
                sram_demand=8 << 20))
        else:
            layer.append(Op(
                "attention", flops_sa=2.0 * T * kv_len * hd * 2 * H / tp,
                bytes_hbm=3 * T * D * BF16 / tp,
                matmul_dims=(T, hd, kv_len), sram_demand=24 << 20))
        layer.append(_matmul("out_proj", T, H * hd // tp, D))
        if cfg.moe:
            mo = cfg.moe
            layer.append(_collective(
                "moe_a2a", 2 * T * D * BF16 * (tp - 1) / tp, sram_tile=8 << 20))
            layer.append(_matmul("experts", T * mo.top_k, D,
                                 3 * mo.d_ff_expert))
        elif cfg.d_ff:
            layer.append(_matmul("mlp_up", T, D, 2 * cfg.d_ff // tp))
            layer.append(_matmul("mlp_down", T, cfg.d_ff // tp, D))
        if tp > 1:
            layer.append(_collective("ar_layer",
                                     2 * T * D * BF16 * (tp - 1) / tp))
        layer.append(_vector("norms", T * D, flops_per_elem=8))
        add_layer(layer, cfg.n_layers)

    ops.append(_matmul("lm_head", T if not train else T,
                       D, cfg.vocab_padded // tp))
    if train:
        from repro.models.registry import count_params
        n_params = count_params(cfg)
        ops.append(_collective("grad_allreduce",
                               2 * n_params * BF16 / (tp * dp)))
        ops.append(_vector("adam", n_params / (tp * dp), flops_per_elem=12,
                           bytes_per_elem=16))
    return Workload(f"{cfg.name}-{shape.name}", shape.kind, tuple(ops),
                    n_chips=n_chips)


# --------------------------------------------------------------------------
# The paper's benchmark suite (Table 1 / Table 4 -like configs on NPU-D)
# --------------------------------------------------------------------------

def paper_suite() -> list[Workload]:
    """The suite workloads are immutable and identical across calls, so
    they are built once; repeated calls return the same Workload objects
    and therefore hit the compiled-trace cache."""
    return list(_paper_suite())


def _paper_suite() -> tuple[Workload, ...]:
    global _PAPER_SUITE
    if _PAPER_SUITE is None:
        _PAPER_SUITE = tuple(_build_paper_suite())
    return _PAPER_SUITE


_PAPER_SUITE: Optional[tuple[Workload, ...]] = None


def _build_paper_suite() -> list[Workload]:
    return [
        llm_workload("llama3-8b", "train", batch=32, n_chips=4, tp=4),
        llm_workload("llama2-13b", "train", batch=32, n_chips=4, tp=4),
        llm_workload("llama3-70b", "train", batch=32, n_chips=8, tp=8),
        llm_workload("llama3.1-405b", "train", batch=32, n_chips=16, tp=16),
        llm_workload("llama3-8b", "prefill", batch=4, n_chips=1),
        llm_workload("llama2-13b", "prefill", batch=4, n_chips=1),
        llm_workload("llama3-70b", "prefill", batch=8, n_chips=4, tp=4),
        llm_workload("llama3.1-405b", "prefill", batch=8, n_chips=8, tp=8),
        llm_workload("llama3-8b", "decode", batch=8, n_chips=1),
        llm_workload("llama2-13b", "decode", batch=4, n_chips=1),
        llm_workload("llama3-70b", "decode", batch=32, n_chips=4, tp=4),
        llm_workload("llama3.1-405b", "decode", batch=64, n_chips=8, tp=8),
        dlrm_workload("S"), dlrm_workload("M"), dlrm_workload("L"),
        diffusion_workload("dit-xl"), diffusion_workload("gligen"),
    ]
