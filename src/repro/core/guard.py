"""Guard plane (ISSUE 9): a resilient execution runtime around the
batched sweep plane.

The stack simulates chip/link faults end-to-end (the chaos plane), but
until now the *harness itself* could not survive a SIGKILL
mid-campaign, a hung jit compile, or a NaN escaping the sweep kernel.
This module gives campaign entry points (``fleet.sweep_fleet`` /
``fleet.sweep_chaos``) the same retry / checkpoint / failover
discipline the NPUs get:

* **Crash-consistent campaign checkpointing** —
  :class:`CampaignCheckpoint` publishes epoch-granular JSON snapshots
  with the write-to-tmp + ``os.replace`` + write-``manifest``-last +
  ``wait()`` discipline of ``checkpoint/manager.py`` (whose
  :func:`atomic_write_json` it shares). A :class:`RunManifest` (seeds,
  knob-grid digest, backend, severity ladder, scenario digest) pins
  the checkpoint to one campaign; resuming with anything else is a
  named ``ValueError``, never silent garbage. Because every stochastic
  input in the fleet plane is recomputed from explicit seeded
  generators with a fixed draw order (the ``perturb.py`` /
  ``faults.py`` contract), a resumed campaign replays the remaining
  epochs bit-for-bit: the final report is **bit-identical** to an
  uninterrupted run (JSON round-trips float64 exactly via shortest
  repr).

* **Backend failover ladder with retry/backoff** —
  :class:`GuardedRunner` executes each ``evaluate_batch`` under a
  deadline watchdog (worker thread + timed join; a wedged attempt is
  abandoned, not waited on). On timeout / compile failure / device
  loss it retries with exponential backoff + deterministic seeded
  jitter, then escalates down ``backend.failover_rungs``: jax-mesh →
  jax single-device → the numpy oracle. Every escalation lands in a
  structured :class:`GuardReport` event with a named reason —
  mirroring the fleet plane's own degradation ladder, but for the
  harness.

* **Numerical quarantine** — every result cube is finite-checked. If
  any cell is NaN/Inf, the poisoned cells are quarantined and
  re-evaluated per-cell on the numpy oracle, and every surviving cell
  must match a full oracle re-run to ``oracle_tol`` (≤1e-9) — silent
  corruption becomes a loud, attributable :class:`GuardError` or a
  recorded quarantine event, never a wrong BET frontier.

Determinism contract: the guard machinery never changes *what* is
computed, only *where* and *how many times*. Backoff jitter draws come
from ``np.random.default_rng((seed, _GUARD_PLANE, step))`` — their own
child stream, so retries can never shift an arrival or fault draw.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import math
import os
import queue
import signal
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Sequence

import numpy as np

__all__ = [
    "GuardError", "GuardPolicy", "GuardReport", "GuardedRunner",
    "RunManifest", "CampaignCheckpoint", "atomic_write_json",
    "digest_of",
]

# child-stream tag for guard-plane jitter draws (perturb.py uses small
# plane indices for trace jitter; this one is reserved for the guard)
_GUARD_PLANE = 9


def _check(ok: bool, msg: str) -> None:
    if not ok:
        raise ValueError(msg)


class GuardError(RuntimeError):
    """The guard exhausted its ladder or found unexplainable results."""


# --------------------------------------------------------------------------
# policy + report data model
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class GuardPolicy:
    """How hard the harness fights before giving up.

    ``timeout_s``       — per-attempt deadline on one ``evaluate_batch``
                          (watchdog; a hung jit compile counts as a
                          failure, not a hang).
    ``max_retries``     — extra attempts per ladder rung after the
                          first (0 = one attempt per rung).
    ``backoff_base_s``  — first retry delay; attempt ``i`` waits
                          ``backoff_base_s * backoff_factor**i *
                          (1 + backoff_jitter * u)`` with ``u`` drawn
                          from the seeded guard stream (deterministic).
    ``oracle_tol``      — max relative error a surviving cell may show
                          vs the numpy oracle during quarantine.
    ``checkpoint_every``— epochs between published snapshots (the
                          final epoch always publishes).
    """

    timeout_s: float = 30.0
    max_retries: int = 2
    backoff_base_s: float = 0.05
    backoff_factor: float = 2.0
    backoff_jitter: float = 0.1
    oracle_tol: float = 1e-9
    checkpoint_every: int = 1

    def __post_init__(self):
        _check(isinstance(self.timeout_s, (int, float))
               and not isinstance(self.timeout_s, bool)
               and math.isfinite(self.timeout_s) and self.timeout_s > 0,
               f"timeout_s must be finite and > 0, got "
               f"{self.timeout_s!r}")
        _check(isinstance(self.max_retries, (int, np.integer))
               and not isinstance(self.max_retries, bool)
               and self.max_retries >= 0,
               f"max_retries must be an int >= 0, got "
               f"{self.max_retries!r}")
        _check(isinstance(self.backoff_base_s, (int, float))
               and math.isfinite(self.backoff_base_s)
               and self.backoff_base_s > 0,
               f"backoff_base_s must be finite and > 0, got "
               f"{self.backoff_base_s!r}")
        _check(isinstance(self.backoff_factor, (int, float))
               and math.isfinite(self.backoff_factor)
               and self.backoff_factor >= 1.0,
               f"backoff_factor must be finite and >= 1, got "
               f"{self.backoff_factor!r}")
        _check(isinstance(self.backoff_jitter, (int, float))
               and 0.0 <= self.backoff_jitter < 1.0,
               f"backoff_jitter must be in [0, 1), got "
               f"{self.backoff_jitter!r}")
        _check(isinstance(self.oracle_tol, (int, float))
               and math.isfinite(self.oracle_tol)
               and self.oracle_tol > 0,
               f"oracle_tol must be finite and > 0, got "
               f"{self.oracle_tol!r}")
        _check(isinstance(self.checkpoint_every, (int, np.integer))
               and not isinstance(self.checkpoint_every, bool)
               and self.checkpoint_every >= 1,
               f"checkpoint_every must be an int >= 1, got "
               f"{self.checkpoint_every!r}")

    def backoff_delay(self, attempt: int,
                      rng: np.random.Generator) -> float:
        """Deterministic delay before retry ``attempt`` (0-based),
        consuming exactly one uniform from ``rng``."""
        u = float(rng.random())
        return float(self.backoff_base_s
                     * self.backoff_factor ** attempt
                     * (1.0 + self.backoff_jitter * u))


@dataclass
class GuardReport:
    """Structured log of every escalation the guard took.

    One dict per event, each with a ``kind`` (``retry`` / ``failover``
    / ``quarantine`` / ``oracle_recheck``) and a named human-readable
    ``reason`` — the harness-side mirror of the fleet plane's
    degradation-ladder bookkeeping.
    """

    events: list[dict] = field(default_factory=list)

    def add(self, kind: str, reason: str, **extra) -> dict:
        ev = {"kind": kind, "reason": reason, **extra}
        self.events.append(ev)
        return ev

    def count(self, kind: str) -> int:
        return sum(1 for e in self.events if e["kind"] == kind)

    @property
    def retries(self) -> int:
        return self.count("retry")

    @property
    def failovers(self) -> int:
        return self.count("failover")

    @property
    def quarantined_cells(self) -> int:
        return self.count("quarantine")

    def to_dict(self) -> dict:
        return {"events": list(self.events),
                "retries": self.retries,
                "failovers": self.failovers,
                "quarantined_cells": self.quarantined_cells}

    @classmethod
    def from_dict(cls, d: dict) -> "GuardReport":
        return cls(events=[dict(e) for e in d.get("events", [])])


# --------------------------------------------------------------------------
# canonical digests + the run manifest
# --------------------------------------------------------------------------

def _canon(obj):
    """json.dumps fallback: canonicalize dataclasses / numpy values so
    ``digest_of`` is stable across processes."""
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return {type(obj).__name__: dataclasses.asdict(obj)}
    if isinstance(obj, np.ndarray):
        return [str(obj.dtype), obj.tolist()]
    if isinstance(obj, (np.integer, np.floating, np.bool_)):
        return obj.item()
    return repr(obj)


def digest_of(obj: Any) -> str:
    """Short stable content digest (sha256 prefix) of any mix of
    dataclasses / tuples / numpy arrays / scalars."""
    blob = json.dumps(obj, sort_keys=True, default=_canon)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


@dataclass(frozen=True)
class RunManifest:
    """Identity of one checkpointed campaign.

    A checkpoint directory belongs to exactly one (scenario, knob
    grid, backend, severity ladder) tuple; ``check`` raises a named
    ``ValueError`` on the first differing field, so a resume can never
    silently splice two different campaigns together.
    """

    kind: str                       # "fleet" | "chaos"
    seed: int
    n_epochs: int
    backend: str
    knob_digest: str
    scenario_digest: str
    severity_levels: tuple = ()     # the scenario's severity ladder
    fault_severities: tuple = ()    # chaos campaigns: the fault ladder
    policies: tuple = ()

    def __post_init__(self):
        _check(isinstance(self.kind, str) and bool(self.kind),
               f"kind must be a non-empty str, got {self.kind!r}")
        _check(isinstance(self.seed, (int, np.integer))
               and not isinstance(self.seed, bool),
               f"seed must be an int, got {self.seed!r}")
        _check(isinstance(self.n_epochs, (int, np.integer))
               and not isinstance(self.n_epochs, bool)
               and self.n_epochs >= 1,
               f"n_epochs must be an int >= 1, got {self.n_epochs!r}")
        _check(isinstance(self.backend, str) and bool(self.backend),
               f"backend must be a non-empty str, got {self.backend!r}")
        _check(isinstance(self.knob_digest, str) and bool(self.knob_digest),
               f"knob_digest must be a non-empty str, got "
               f"{self.knob_digest!r}")
        _check(isinstance(self.scenario_digest, str)
               and bool(self.scenario_digest),
               f"scenario_digest must be a non-empty str, got "
               f"{self.scenario_digest!r}")
        object.__setattr__(self, "severity_levels",
                           tuple(float(s) for s in self.severity_levels))
        object.__setattr__(self, "fault_severities",
                           tuple(float(s) for s in self.fault_severities))
        object.__setattr__(self, "policies", tuple(self.policies))

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "RunManifest":
        return cls(**{f.name: d[f.name]
                      for f in dataclasses.fields(cls)})

    def check(self, other: "RunManifest") -> None:
        """Raise a named ValueError on the first differing field."""
        for f in dataclasses.fields(self):
            a, b = getattr(self, f.name), getattr(other, f.name)
            if a != b:
                raise ValueError(
                    f"checkpoint manifest mismatch on {f.name}: "
                    f"checkpoint has {b!r}, this campaign has {a!r} — "
                    f"refusing to resume a different campaign")


# --------------------------------------------------------------------------
# atomic JSON publish (the checkpoint/manager.py discipline, jax-free)
# --------------------------------------------------------------------------

def atomic_write_json(path: str, obj: Any) -> None:
    """Write ``obj`` as JSON to ``path`` via write-to-tmp +
    ``os.replace`` — a crash mid-write can never corrupt ``path``
    (same publish discipline as ``checkpoint/manager.py``)."""
    tmp = f"{path}.tmp"
    with open(tmp, "w") as f:
        json.dump(obj, f)
    os.replace(tmp, path)


# --------------------------------------------------------------------------
# kill hook: self-fault-injection for the harness
# --------------------------------------------------------------------------
# REPRO_GUARD_KILL="boundary:<epoch>" SIGKILLs the process right after
# snapshot <epoch> is published; "mid:<epoch>" kills while epoch
# <epoch> is being processed (before its snapshot exists). This is the
# chaos plane turned on the harness itself — the kill–resume tests and
# examples/chaos_day.py --checkpoint use it to prove the bit-identical
# resume invariant against real SIGKILLs.

_KILL_SPEC = os.environ.get("REPRO_GUARD_KILL", "")


def _kill_armed(phase: str, step: int) -> bool:
    if not _KILL_SPEC:
        return False
    p, _, s = _KILL_SPEC.partition(":")
    return p == phase and s == str(step)


def maybe_kill(phase: str, step: int) -> None:
    """SIGKILL the current process if REPRO_GUARD_KILL targets this
    (phase, step). No-op (one string compare) otherwise."""
    if _kill_armed(phase, step):
        os.kill(os.getpid(), signal.SIGKILL)


# --------------------------------------------------------------------------
# campaign checkpoints
# --------------------------------------------------------------------------

class CampaignCheckpoint:
    """Epoch-granular atomic snapshots for a campaign run.

    Layout inside ``directory``::

        manifest.json   — RunManifest, written (atomically) first
        epoch_<e>.json  — loop state after epoch e completed
        final.json      — the full report once the run finished

    ``save_epoch`` snapshots synchronously (shallow list copies — the
    fleet loop only ever *appends* records) and serializes + publishes
    on a background thread, joined by ``wait()`` before the next save
    and at close — the async-save discipline of
    ``checkpoint/manager.py``. Retention keeps the newest ``keep``
    epoch snapshots, deleting older ones only after a successful
    publish.
    """

    def __init__(self, directory, manifest: RunManifest, *,
                 keep: int = 2):
        _check(isinstance(directory, (str, os.PathLike)),
               f"checkpoint must be a directory path (str or "
               f"os.PathLike), got {type(directory).__name__}")
        _check(isinstance(manifest, RunManifest),
               f"manifest must be a RunManifest, got {type(manifest)}")
        _check(isinstance(keep, (int, np.integer)) and keep >= 1,
               f"keep must be an int >= 1, got {keep!r}")
        self.dir = os.fspath(directory)
        self.manifest = manifest
        self.keep = int(keep)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        os.makedirs(self.dir, exist_ok=True)
        mpath = os.path.join(self.dir, "manifest.json")
        if os.path.exists(mpath):
            with open(mpath) as f:
                manifest.check(RunManifest.from_dict(json.load(f)))
        else:
            atomic_write_json(mpath, manifest.to_dict())

    # ---------------------------------------------------------- save
    def save_epoch(self, epoch: int, state: dict) -> None:
        """Publish the post-epoch snapshot (async), then honor an armed
        boundary kill (after the publish is fully on disk)."""
        self.wait()
        path = os.path.join(self.dir, f"epoch_{epoch}.json")

        def _write():
            try:
                atomic_write_json(path, state)
                self._gc()
            except BaseException as e:   # surfaced at next wait()
                self._error = e

        if _kill_armed("boundary", epoch):
            _write()
            self._raise_pending()
            maybe_kill("boundary", epoch)
        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def save_final(self, report: dict) -> None:
        self.wait()
        atomic_write_json(os.path.join(self.dir, "final.json"), report)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_pending()

    close = wait

    def _raise_pending(self) -> None:
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError("async campaign snapshot failed") from err

    def _gc(self) -> None:
        for e in self.epochs()[:-self.keep]:
            try:
                os.remove(os.path.join(self.dir, f"epoch_{e}.json"))
            except OSError:
                pass

    # ------------------------------------------------------- restore
    def epochs(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("epoch_") and name.endswith(".json"):
                try:
                    out.append(int(name[len("epoch_"):-len(".json")]))
                except ValueError:
                    pass
        return sorted(out)

    def load_epoch(self) -> Optional[dict]:
        """Latest restorable epoch snapshot, or None for a fresh run."""
        self.wait()
        for e in reversed(self.epochs()):
            path = os.path.join(self.dir, f"epoch_{e}.json")
            try:
                with open(path) as f:
                    return json.load(f)
            except (OSError, json.JSONDecodeError):   # pragma: no cover
                continue   # publish is atomic; tolerate stray files
        return None

    def load_final(self) -> Optional[dict]:
        self.wait()
        path = os.path.join(self.dir, "final.json")
        if not os.path.exists(path):
            return None
        with open(path) as f:
            return json.load(f)


# --------------------------------------------------------------------------
# the guarded runner: watchdog + retry/backoff + failover + quarantine
# --------------------------------------------------------------------------

class _Timeout(Exception):
    pass


class _Watchdog:
    """Deadline execution on ONE persistent daemon worker.

    A fresh thread per call costs ~10% wall on the clean path (GIL
    handoff + cold scheduling for every epoch's ``evaluate_batch``);
    a single long-lived worker is within noise of main-thread
    execution. On a deadline miss the wedged worker is abandoned with
    its queue (daemon — its late result lands in a dead box, and it
    cannot block interpreter exit) and a replacement is spawned, so
    the caller escalates instead of hanging on a wedged jit compile.
    """

    def __init__(self):
        self._spawn()

    def _spawn(self) -> None:
        self._q: queue.SimpleQueue = queue.SimpleQueue()
        self._t = threading.Thread(target=self._loop, args=(self._q,),
                                   daemon=True)
        self._t.start()

    @staticmethod
    def _loop(q: "queue.SimpleQueue") -> None:
        while True:
            item = q.get()
            if item is None:   # retired replacement worker
                return
            fn, box, done = item
            try:
                box["value"] = fn()
            except BaseException as e:
                box["error"] = e
            finally:
                done.set()

    def run(self, fn: Callable[[], Any], timeout_s: float):
        box: dict = {}
        done = threading.Event()
        self._q.put((fn, box, done))
        if not done.wait(timeout_s):
            self._spawn()   # abandon the wedged worker + its queue
            raise _Timeout(f"deadline {timeout_s:g}s exceeded")
        if "error" in box:
            raise box["error"]
        return box["value"]

    def close(self) -> None:
        self._q.put(None)


def _result_fields(res) -> list[tuple[str, np.ndarray]]:
    """Every (name, cube) pair of a BatchResult, for finite checks and
    oracle comparison."""
    out = [("runtime_s", res.runtime_s)]
    for group in ("static_j", "dynamic_j", "wake_events", "gated_s",
                  "setpm_by"):
        for c, arr in getattr(res, group).items():
            out.append((f"{group}[{c}]", arr))
    return out


def _rel_err(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    return np.abs(a - b) / np.maximum(np.abs(b), 1e-300)


class GuardedRunner:
    """Executes ``evaluate_batch`` calls under the guard policy.

    ``rungs`` defaults to ``backend.failover_rungs`` for the session's
    (backend, mesh); tests may inject a custom ladder plus a stub
    ``runner`` (same signature as ``policies.evaluate_batch`` with a
    leading rung name) and a stub ``oracle``. ``report`` accumulates
    every escalation across calls.
    """

    def __init__(self, policy: Optional[GuardPolicy] = None, *,
                 backend: Optional[str] = None, jax_mesh=None,
                 seed: int = 0,
                 rungs: Optional[Sequence[tuple]] = None,
                 runner: Optional[Callable] = None,
                 oracle: Optional[Callable] = None):
        if policy is None:
            policy = GuardPolicy()
        _check(isinstance(policy, GuardPolicy),
               f"policy must be a GuardPolicy, got {type(policy)}")
        self.policy = policy
        self.seed = int(seed)
        self.report = GuardReport()
        if rungs is None:
            from repro.core.backend import failover_rungs
            rungs = failover_rungs(backend, jax_mesh)
        _check(len(rungs) >= 1, "rungs must be non-empty")
        self.rungs = tuple((str(n), m) for n, m in rungs)
        self._runner = runner if runner is not None \
            else self._default_runner
        self._oracle = oracle if oracle is not None \
            else self._default_oracle
        self._watchdog: Optional[_Watchdog] = None

    @staticmethod
    def _default_runner(rung: str, workloads, npus, policies, knobs, *,
                        jax_mesh=None):
        from repro.core.policies import evaluate_batch
        backend = "numpy" if rung == "numpy" else "jax"
        return evaluate_batch(workloads, npus, policies, knobs,
                              backend=backend, jax_mesh=jax_mesh)

    @staticmethod
    def _default_oracle(workloads, npus, policies, knobs):
        from repro.core.policies import evaluate_batch
        return evaluate_batch(workloads, npus, policies, knobs,
                              backend="numpy")

    # -------------------------------------------------------- execute
    def evaluate_batch(self, workloads, npus, policies, knobs, *,
                       step: int = 0):
        """One guarded batched-sweep call: ladder x (1 + max_retries)
        attempts, each under the deadline watchdog, then finite-check /
        quarantine. ``step`` tags events (0 = calibration, e + 1 =
        epoch e in the fleet plane) and keys the jitter stream."""
        pol = self.policy
        if self._watchdog is None:
            self._watchdog = _Watchdog()
        rng = None   # lazily seeded: only failures draw jitter
        last_reason = ""
        for ri, (rung, mesh) in enumerate(self.rungs):
            for attempt in range(pol.max_retries + 1):
                try:
                    res = self._watchdog.run(
                        lambda: self._runner(rung, workloads, npus,
                                             policies, knobs,
                                             jax_mesh=mesh),
                        pol.timeout_s)
                except _Timeout as e:
                    last_reason = f"timeout: {e}"
                except Exception as e:
                    last_reason = (f"error: {type(e).__name__}: {e}")
                else:
                    return self._quarantine(res, workloads, npus,
                                            policies, knobs,
                                            rung=rung, step=step)
                if attempt < pol.max_retries:
                    if rng is None:
                        rng = np.random.default_rng(
                            (self.seed, _GUARD_PLANE, int(step)))
                    delay = pol.backoff_delay(attempt, rng)
                    self.report.add(
                        "retry", last_reason, step=int(step),
                        rung=rung, attempt=attempt,
                        delay_s=delay)
                    time.sleep(delay)
            if ri + 1 < len(self.rungs):
                self.report.add(
                    "failover",
                    f"rung {rung!r} exhausted after "
                    f"{pol.max_retries + 1} attempts ({last_reason}); "
                    f"downgrading to {self.rungs[ri + 1][0]!r}",
                    step=int(step), rung=rung,
                    next_rung=self.rungs[ri + 1][0])
        raise GuardError(
            f"all {len(self.rungs)} backend rungs exhausted at step "
            f"{step} ({last_reason})")

    # ----------------------------------------------------- quarantine
    def _quarantine(self, res, workloads, npus, policies, knobs, *,
                    rung: str, step: int):
        fields = _result_fields(res)
        bad = np.zeros(res.shape, bool)
        for _, arr in fields:
            bad |= ~np.isfinite(arr)
        if not bad.any():
            return res

        tol = self.policy.oracle_tol
        # names for attributable events
        wl_names = [getattr(w, "name", str(w)) for w in workloads]
        cells = list(zip(*np.nonzero(bad)))
        for (w, a, p, k) in cells:
            poisoned = [name for name, arr in fields
                        if not np.isfinite(arr[w, a, p, k])]
            self.report.add(
                "quarantine",
                f"non-finite {','.join(poisoned)} from rung {rung!r} "
                f"at cell (workload={wl_names[w]}, npu={a}, "
                f"policy={policies[p]}, knob={k}); re-evaluated on "
                f"the numpy oracle",
                step=int(step), rung=rung,
                cell=[int(w), int(a), int(p), int(k)],
                fields=poisoned)

        # full oracle cube: survivors must be explainable ≤ oracle_tol
        ora = self._oracle(workloads, npus, policies, knobs)
        ora_fields = dict(_result_fields(ora))
        worst = 0.0
        patched = {}
        for name, arr in fields:
            oarr = ora_fields[name]
            if not np.isfinite(oarr).all():
                w, a, p, k = [int(i[0]) for i in
                              np.nonzero(~np.isfinite(oarr))]
                raise GuardError(
                    f"numpy oracle itself is non-finite in {name} at "
                    f"cell (workload={wl_names[w]}, npu={a}, policy="
                    f"{policies[p]}, knob={k}) — the model, not the "
                    f"backend, is poisoned")
            ok = ~bad
            err = _rel_err(arr, oarr)[ok]
            if err.size and float(err.max()) > tol:
                worst_ix = np.zeros(res.shape, bool)
                worst_ix[ok] = _rel_err(arr, oarr)[ok] == err.max()
                w, a, p, k = [int(i[0]) for i in np.nonzero(worst_ix)]
                raise GuardError(
                    f"surviving cell disagrees with the numpy oracle "
                    f"beyond {tol:g}: {name} at (workload="
                    f"{wl_names[w]}, npu={a}, policy={policies[p]}, "
                    f"knob={k}) rel err {float(err.max()):.3e} — rung "
                    f"{rung!r} results are not trustworthy")
            worst = max(worst, float(err.max()) if err.size else 0.0)
            patched[name] = np.where(bad, oarr, arr)

        # per-cell oracle re-evaluation of the poisoned cells: each is
        # recomputed in isolation and must agree with the full oracle
        # cube (stacking must not change a cell's value)
        for (w, a, p, k) in cells:
            cell = self._oracle([workloads[w]], (npus[a],),
                                (policies[p],), (knobs[k],))
            for name, arr in _result_fields(cell):
                ref = float(ora_fields[name][w, a, p, k])
                err = float(_rel_err(np.asarray(arr[0, 0, 0, 0]),
                                     np.asarray(ref)))
                if err > tol:
                    raise GuardError(
                        f"per-cell oracle re-evaluation disagrees with "
                        f"the batched oracle: {name} at (workload="
                        f"{wl_names[w]}, npu={a}, policy={policies[p]},"
                        f" knob={k}) rel err {err:.3e}")

        self.report.add(
            "oracle_recheck",
            f"quarantined {len(cells)} cell(s) from rung {rung!r}; "
            f"survivors match the numpy oracle to "
            f"{max(worst, 0.0):.3e} (tol {tol:g})",
            step=int(step), rung=rung, n_quarantined=len(cells),
            max_survivor_rel_err=worst)

        def split(prefix):
            return {c: patched[f"{prefix}[{c}]"]
                    for c in getattr(res, prefix)}

        return dataclasses.replace(
            res, runtime_s=patched["runtime_s"],
            static_j=split("static_j"), dynamic_j=split("dynamic_j"),
            wake_events=split("wake_events"), gated_s=split("gated_s"),
            setpm_by=split("setpm_by"))
