"""Post-optimization HLO text analyzer.

``cost_analysis()`` counts while-loop bodies ONCE (verified empirically:
a 10-iteration scanned matmul reports ~1x the matmul FLOPs), so scan-over-
layers models would under-report by ~n_layers. This module parses
``compiled.as_text()``, builds the computation call graph, multiplies every
computation's costs by its execution count (while trip counts come from the
``known_trip_count`` backend_config XLA attaches to scan-derived loops),
and extracts:

* dot FLOPs (exact, from contracting/batch dims);
* per-collective-type bytes (operand sizes of all-reduce / all-gather /
  reduce-scatter / all-to-all / collective-permute, per device);
* an HBM traffic estimate: operand+output bytes of top-level instructions
  at fusion granularity (fusion internals are on-chip and not counted).

Methodology notes recorded in EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Optional

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1, "token": 0,
    "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

# ops whose operands/outputs plausibly move through HBM at fusion
# granularity (conservative, consistent across variants)
MEMORY_OPS = {"fusion", "dot", "convolution", "copy", "dynamic-slice",
              "dynamic-update-slice", "slice", "concatenate", "transpose",
              "reshape", "reduce", "sort", "gather", "scatter", "pad",
              "broadcast", "iota", "select-and-scatter", "reduce-window",
              "cholesky", "triangular-solve", "rng", "convert",
              "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
              "collective-permute"}


def shape_bytes(shape_str: str) -> int:
    """Total bytes of a shape string (handles tuples by summing tokens)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


@dataclass
class HloInstr:
    name: str
    opcode: str
    shape: str
    operands: list[str]
    attrs: str
    is_root: bool = False

    @property
    def out_bytes(self) -> int:
        return shape_bytes(self.shape)


@dataclass
class HloComputation:
    name: str
    instrs: list[HloInstr] = field(default_factory=list)
    by_name: dict[str, HloInstr] = field(default_factory=dict)

    def operand_bytes(self, instr: HloInstr) -> int:
        total = 0
        for op in instr.operands:
            d = self.by_name.get(op)
            if d is not None:
                total += d.out_bytes
        return total


@dataclass
class HloModule:
    computations: dict[str, HloComputation]
    entry: str
    # computation name -> execution count relative to one module execution
    multipliers: dict[str, float] = field(default_factory=dict)
    fusion_bodies: set = field(default_factory=set)


_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->")
_NAME_EQ = re.compile(r"^\s*(ROOT\s+)?%?([\w.\-]+)\s*=\s*")
_SCALAR_SHAPE = re.compile(r"[\w\[\],]+(?:\{[^}]*\})*")
_OPCODE = re.compile(r"([\w\-]+)\(")


def _parse_instr_line(line: str):
    """Parse one instruction line; robust to tuple shapes containing
    /*index=N*/ comments (which break naive regexes on '=')."""
    m = _NAME_EQ.match(line)
    if not m:
        return None
    is_root, name = m.groups()
    rest = line[m.end():]
    if rest.startswith("("):  # tuple shape: bracket-match
        depth = 0
        end = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        shape = rest[:end + 1]
        rest2 = rest[end + 1:].lstrip()
    else:
        sm = _SCALAR_SHAPE.match(rest)
        if not sm:
            return None
        shape = sm.group(0)
        rest2 = rest[sm.end():].lstrip()
    om = _OPCODE.match(rest2)
    if not om:
        return None
    opcode = om.group(1)
    operands, attrs = _split_operands(rest2[om.end():])
    return HloInstr(name=name, opcode=opcode, shape=shape,
                    operands=operands, attrs=attrs, is_root=bool(is_root))
_CALLS = re.compile(r"calls=%?([\w.\-]+)")
_TO_APPLY = re.compile(r"to_apply=%?([\w.\-]+)")
_WHILE_BODY = re.compile(r"body=%?([\w.\-]+)")
_WHILE_COND = re.compile(r"condition=%?([\w.\-]+)")
_TRIP = re.compile(r'known_trip_count[^0-9]*?(\d+)')
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_COND_TF = re.compile(r"(?:true|false)_computation=%?([\w.\-]+)")


def _split_operands(argstr: str) -> tuple[list[str], str]:
    """Split the '(...)' operand list from the instruction tail; returns
    (operand names, attrs-after-close-paren)."""
    depth = 1
    i = 0
    while i < len(argstr) and depth > 0:
        ch = argstr[i]
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        i += 1
    inner = argstr[:i - 1]
    attrs = argstr[i:]
    ops = []
    d = 0
    cur = ""
    for ch in inner:
        if ch in "([{":
            d += 1
        elif ch in ")]}":
            d -= 1
        if ch == "," and d == 0:
            ops.append(cur.strip())
            cur = ""
        else:
            cur += ch
    if cur.strip():
        ops.append(cur.strip())
    names = []
    for o in ops:
        o = re.sub(r"/\*.*?\*/", "", o).strip()  # strip /*index=N*/ comments
        if "%" in o:
            # typed operand form "f32[64,64]{1,0} %name" (older XLA text)
            # or bare "%name": the %-prefixed token is the value name
            tail = o[o.index("%") + 1:]
            names.append(tail.split(" ")[0].split(")")[0])
        else:
            m = re.match(r"%?([\w.\-]+)", o)
            if m:
                names.append(m.group(1))
    return names, attrs


def parse_hlo(text: str) -> HloModule:
    comps: dict[str, HloComputation] = {}
    entry = ""
    cur: Optional[HloComputation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if line.endswith("{") and ("->" in line):
            m = _COMP_HDR.match(line.strip())
            if m:
                name = m.group(2)
                cur = HloComputation(name)
                comps[name] = cur
                if m.group(1):
                    entry = name
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        ins = _parse_instr_line(line)
        if ins is None:
            continue
        cur.instrs.append(ins)
        cur.by_name[ins.name] = ins

    mod = HloModule(comps, entry)
    _compute_multipliers(mod)
    return mod


def _compute_multipliers(mod: HloModule) -> None:
    mult: dict[str, float] = {c: 0.0 for c in mod.computations}
    if mod.entry not in mod.computations:
        # fall back: the last computation is usually ENTRY
        mod.entry = next(reversed(mod.computations))
    fusion_bodies: set = set()
    todo = [(mod.entry, 1.0)]
    seen_edges = 0
    while todo:
        name, m = todo.pop()
        if name not in mod.computations:
            continue
        mult[name] += m
        comp = mod.computations[name]
        for ins in comp.instrs:
            if ins.opcode == "while":
                trip = 1.0
                tm = _TRIP.search(ins.attrs)
                if tm:
                    trip = float(tm.group(1))
                bm = _WHILE_BODY.search(ins.attrs)
                cm = _WHILE_COND.search(ins.attrs)
                if bm:
                    todo.append((bm.group(1), m * trip))
                if cm:
                    todo.append((cm.group(1), m * (trip + 1)))
            elif ins.opcode == "conditional":
                for b in _BRANCHES.findall(ins.attrs):
                    for nm in b.split(","):
                        todo.append((nm.strip().lstrip("%"), m))
                for nm in _COND_TF.findall(ins.attrs):
                    todo.append((nm, m))
            else:
                cm = _CALLS.search(ins.attrs)
                if cm:
                    todo.append((cm.group(1), m))
                    if ins.opcode == "fusion":
                        fusion_bodies.add(cm.group(1))
                am = _TO_APPLY.search(ins.attrs)
                if am:
                    fusion_bodies.add(am.group(1))
        seen_edges += 1
        if seen_edges > 100000:
            break
    mod.multipliers = mult
    mod.fusion_bodies = fusion_bodies


# --------------------------------------------------------------------------
# cost extraction
# --------------------------------------------------------------------------

_DIMS = re.compile(r"(\w+)\[([\d,]*)\]")
_CDIMS = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_BDIMS = re.compile(r"lhs_batch_dims=\{([\d,]*)\}")


def _dims_of(shape: str) -> list[int]:
    m = _DIMS.search(shape)
    if not m or not m.group(2):
        return []
    return [int(x) for x in m.group(2).split(",")]


def dot_flops(comp: HloComputation, ins: HloInstr) -> float:
    """2 * batch * M * N * K from the lhs shape and dim numbers."""
    if len(ins.operands) < 2:
        return 0.0
    lhs = comp.by_name.get(ins.operands[0])
    if lhs is None:
        return 0.0
    ldims = _dims_of(lhs.shape)
    odims = _dims_of(ins.shape)
    cm = _CDIMS.search(ins.attrs)
    bm = _BDIMS.search(ins.attrs)
    cidx = [int(x) for x in cm.group(1).split(",")] if cm and cm.group(1) \
        else []
    bidx = [int(x) for x in bm.group(1).split(",")] if bm and bm.group(1) \
        else []
    k = 1
    for i in cidx:
        if i < len(ldims):
            k *= ldims[i]
    out = 1
    for d in odims:
        out *= d
    return 2.0 * out * k


@dataclass
class HloCosts:
    flops: float = 0.0
    memory_bytes: float = 0.0
    collective_bytes: dict[str, float] = field(default_factory=dict)
    dots: int = 0
    unscaled_flops: float = 0.0

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


# ops that only touch a slice of their big operand: charge slice-sized
# traffic, not the whole buffer (scan iterations would otherwise be charged
# the full carry/xs array each step)
_SLICE_READS = {"dynamic-slice", "slice", "gather"}
_SLICE_WRITES = {"dynamic-update-slice", "scatter", "scatter-add"}


# fusion bodies containing one of these are real materialization points;
# pure-elementwise fusions would be fused into their producers by the TPU
# backend and are charged at output size only (the write). This models the
# TPU fusion behavior on top of the CPU-lowered HLO, which fuses far less.
_HEAVY_BODY = {"dot", "convolution", "reduce", "scatter",
               "dynamic-update-slice", "dynamic-slice", "gather", "sort",
               "concatenate", "reduce-window", "select-and-scatter"}


_LAYOUT_OPS = {"convert", "bitcast", "copy", "transpose", "reshape"}


def _is_layout_fusion(ins: HloInstr, mod: "HloModule") -> bool:
    """A fusion that only converts/copies/transposes: a CPU-backend
    artifact (the CPU dot emitter upcasts bf16 operands to f32); the TPU
    backend fuses these into the consuming dot. Charged zero."""
    m = _CALLS.search(ins.attrs)
    body = mod.computations.get(m.group(1)) if m else None
    if body is None:
        return False
    ops = {bi.opcode for bi in body.instrs} - {"parameter", "constant"}
    return bool(ops) and ops <= _LAYOUT_OPS


def _source_bytes(comp: HloComputation, name: str, mod: "HloModule",
                  depth: int = 0) -> int:
    """Smallest byte size along the layout/convert chain producing
    ``name`` (the size the TPU dot would actually read)."""
    d = comp.by_name.get(name)
    if d is None or depth > 6:
        return 0
    size = d.out_bytes
    if d.opcode in _LAYOUT_OPS and d.operands:
        return min(size, _source_bytes(comp, d.operands[0], mod, depth + 1))
    if d.opcode == "fusion" and _is_layout_fusion(d, mod) and d.operands:
        return min(size, _source_bytes(comp, d.operands[0], mod, depth + 1))
    return size


def _instr_bytes(comp: HloComputation, ins: HloInstr,
                 mod: "HloModule") -> float:
    op = ins.opcode
    if op in ("dot", "convolution"):
        b = sum(_source_bytes(comp, o, mod) for o in ins.operands)
        return b + ins.out_bytes
    if op in _SLICE_READS:
        return 2.0 * ins.out_bytes           # read slice + write out
    if op in _SLICE_WRITES:
        upd = 0
        if len(ins.operands) >= 2:
            d = comp.by_name.get(ins.operands[1])
            if d is not None:
                upd = d.out_bytes
        return 2.0 * upd                     # in-place region read+write
    if op == "fusion":
        if _is_layout_fusion(ins, mod):
            return 0.0                       # fused into consumer on TPU
        return _fusion_bytes(comp, ins, mod)
    if op in ("transpose", "broadcast", "iota", "convert", "reshape",
              "copy"):
        return 0.0                           # fused into consumer on TPU
    return comp.operand_bytes(ins) + ins.out_bytes


_PASSTHROUGH = {"convert", "bitcast", "copy", "reshape", "transpose",
                "get-tuple-element", "tuple"}


def _fusion_bytes(comp: HloComputation, ins: HloInstr,
                  mod: "HloModule") -> float:
    """Fusion traffic with slice-awareness: a fusion parameter whose only
    body uses are slice-reads (or as the in-place target of a
    dynamic-update-slice) is charged at slice granularity; a fusion whose
    root (through converts/bitcasts) is a dynamic-update-slice writes only
    the update region (XLA aliases the big operand in place)."""
    m = _CALLS.search(ins.attrs)
    body = mod.computations.get(m.group(1)) if m else None
    if body is None:
        return comp.operand_bytes(ins) + ins.out_bytes
    if not any(bi.opcode in _HEAVY_BODY for bi in body.instrs):
        return ins.out_bytes                 # elementwise: write only
    # aliased DUS targets: trace DUS operand 0 back through passthrough
    # ops to a parameter (XLA updates that buffer in place)
    dus_targets: dict[str, int] = {}   # param name -> update bytes
    for bi in body.instrs:
        if bi.opcode == "dynamic-update-slice" and bi.operands:
            upd = 0
            if len(bi.operands) >= 2:
                d2 = body.by_name.get(bi.operands[1])
                if d2 is not None:
                    upd = d2.out_bytes
            tgt = body.by_name.get(bi.operands[0])
            hops = 0
            while (tgt is not None and tgt.opcode in _PASSTHROUGH
                   and tgt.operands and hops < 8):
                tgt = body.by_name.get(tgt.operands[0])
                hops += 1
            if tgt is not None and tgt.opcode == "parameter":
                dus_targets[tgt.name] = max(dus_targets.get(tgt.name, 0),
                                            upd)
    param_names = {}
    consumers: dict[str, list] = {}   # value name -> consumer instrs
    for bi in body.instrs:
        if bi.opcode == "parameter":
            idx = int(bi.operands[0]) if (bi.operands and
                                          bi.operands[0].isdigit()) else None
            param_names[bi.name] = idx
        for o in bi.operands:
            consumers.setdefault(o, []).append(bi)

    def terminal_uses(name: str, depth: int = 0):
        """Non-passthrough consumers reachable through passthrough chains."""
        out = []
        if depth > 8:
            return out
        for c in consumers.get(name, []):
            if c.opcode in _PASSTHROUGH:
                out.extend(terminal_uses(c.name, depth + 1))
            else:
                out.append(c)
        return out

    total = 0.0
    for pname, idx in param_names.items():
        if idx is None or idx >= len(ins.operands):
            continue
        d = comp.by_name.get(ins.operands[idx])
        size = d.out_bytes if d is not None else 0
        term = terminal_uses(pname)
        if pname in dus_targets:
            size = min(size, 2 * dus_targets[pname])
        elif term and all(t.opcode in _SLICE_READS for t in term):
            sl = max((t.out_bytes for t in term), default=size)
            size = min(size, sl)
        total += size
    # trace root through passthrough ops to detect in-place slice writes
    root = next((bi for bi in body.instrs if bi.is_root), None)
    seen = 0
    while (root is not None and root.opcode in _PASSTHROUGH
           and root.operands and seen < 8):
        root = body.by_name.get(root.operands[0])
        seen += 1
    if root is not None and root.opcode in _SLICE_WRITES:
        upd = 0
        if len(root.operands) >= 2:
            d2 = body.by_name.get(root.operands[1])
            if d2 is not None:
                upd = d2.out_bytes
        total += 2.0 * upd                   # in-place region write
    else:
        total += ins.out_bytes
    return total


def analyze(text: str) -> HloCosts:
    mod = parse_hlo(text)
    costs = HloCosts()
    costs.collective_bytes = {c: 0.0 for c in COLLECTIVES}
    for name, comp in mod.computations.items():
        m = mod.multipliers.get(name, 0.0)
        if m <= 0:
            continue
        is_fusion_body = name in mod.fusion_bodies
        for ins in comp.instrs:
            if ins.opcode in ("dot", "convolution"):
                f = dot_flops(comp, ins)
                costs.flops += m * f
                costs.unscaled_flops += f
                costs.dots += 1
            if is_fusion_body:
                continue  # bytes accounted at the fusion call site
            if ins.opcode in COLLECTIVES:
                costs.collective_bytes[ins.opcode] += \
                    m * comp.operand_bytes(ins)
            if ins.opcode in MEMORY_OPS:
                costs.memory_bytes += m * _instr_bytes(comp, ins, mod)
    return costs
