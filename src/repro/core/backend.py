"""Pluggable array backend for the batched sweep plane (ISSUE 4).

The batched policy engine (``repro.core.policies.evaluate_batch``) is a
handful of segmented array passes over a stacked super-trace. This
module abstracts the array substrate those passes run on so the same
backend-neutral kernel executes either on

* **numpy** — eager, always available, and the production oracle; or
* **jax**   — one ``jax.jit``-compiled program (knob axis via ``vmap``,
  segmented reductions via ``jax.ops.segment_sum``), reused across NPU
  generations because every per-generation quantity enters as a traced
  array, never as a Python constant baked into the trace.

The contract each backend provides:

* ``xp``                    — the array namespace (``numpy`` /
  ``jax.numpy``);
* ``segment_sum(data, seg_ids, num_segments)`` — 1-D segmented sum with
  sorted segment ids (empty segments sum to zero);
* ``jit(fn, static_argnames)`` / ``vmap_knobs(fn, knobs)`` — compile and
  knob-axis-map hooks (identity / Python loop on numpy);
* ``scan(f, init, xs, length)`` — carry-only sequential loop over the
  leading axis of the ``xs`` pytree (``lax.scan`` on jax): the
  program-plane event kernel's spine (``repro.core.program_plane``);
* ``asarray`` / ``to_numpy`` / ``compute_scope()`` — transfer in/out and
  the dtype discipline scope (jax: float64 via x64);
* ``sa_occupancy(...)`` — the in-program SA PE-occupancy pass
  (ISSUE 5): the backend-neutral closed form, or on jax optionally the
  Pallas ``kernels/sa_occupancy.py`` tile kernel
  (``set_sa_occupancy_impl``) — either way traced, so SA width rides
  the knob axis;
* ``psum`` / ``all_gather`` / ``pspec`` / ``shard_map_kernel`` — the
  collective surface the multi-device ``shard_map`` sweep program is
  built from (jax only; resolved through ``parallel.jax_compat``).

Ragged gap merging (``opgen.segmented_gaps``) is data-dependent-shape
and cannot run under ``jit``; ``gap_index`` builds the equivalent
fixed-shape structure on the host once per stack — each op is assigned
the id of the idle-gap chunk that owns it, so the gap *values* become a
plain ``segment_sum`` over per-op idle time and the per-knob threshold
masking stays shape-stable inside the compiled program.

The jax backend requires float64 (the ≤1e-9 record equivalence against
the numpy oracle is meaningless at f32): entry points run inside
``compute_scope()`` which enables x64 locally when jax supports the
scoped switch, and otherwise raises a clear error telling the caller to
enable ``jax_enable_x64`` globally.
"""
from __future__ import annotations

import contextlib
from typing import Callable, Optional

import numpy as np

from repro.core import session

_X64_HELP = (
    "the jax sweep backend requires float64 (x64). Enable it globally "
    "before jax is first used — `import jax; "
    "jax.config.update('jax_enable_x64', True)` or set the environment "
    "variable JAX_ENABLE_X64=1 — or upgrade to a jax with the scoped "
    "`jax.experimental.enable_x64` context manager."
)


def _tree_stack(items: list):
    """Stack a list of identically-structured dict/array pytrees along a
    new leading axis (the numpy stand-in for ``vmap`` output batching)."""
    first = items[0]
    if isinstance(first, dict):
        return {k: _tree_stack([it[k] for it in items]) for k in first}
    return np.stack(items, axis=0)


class NumpyBackend:
    """Eager numpy instantiation of the backend contract (the oracle)."""

    name = "numpy"
    xp = np
    sa_occupancy_impl = "xp"

    @staticmethod
    def sa_occupancy(mm_m, mm_k, mm_n, saw, weight_load_cycles=None):
        """Per-op SA PE-occupancy stats (closed form, ``sa_gating``)."""
        from repro.core.sa_gating import gating_stats_batch_xp
        return gating_stats_batch_xp(mm_m, mm_k, mm_n, saw,
                                     weight_load_cycles, xp=np)

    @staticmethod
    def asarray(x):
        a = np.asarray(x)
        if a.dtype == np.float32:
            a = a.astype(np.float64)
        return a

    @staticmethod
    def to_numpy(x) -> np.ndarray:
        return np.asarray(x)

    @staticmethod
    def segment_sum(data, seg_ids, num_segments: int):
        return np.bincount(seg_ids, weights=np.asarray(data, np.float64),
                           minlength=num_segments)[:num_segments]

    @staticmethod
    def jit(fn: Callable, static_argnames=()) -> Callable:
        return fn

    @staticmethod
    def vmap_knobs(fn: Callable, knobs: dict) -> dict:
        k = len(next(iter(knobs.values())))
        return _tree_stack([fn({key: v[i] for key, v in knobs.items()})
                            for i in range(k)])

    @staticmethod
    @contextlib.contextmanager
    def compute_scope():
        yield

    @staticmethod
    def block(tree):
        return tree

    @staticmethod
    def scan(f, init, xs, length: int):
        """Sequential carry loop (the numpy stand-in for ``lax.scan``).

        ``f(carry, x) -> carry`` with ``x`` the per-step slice of the
        ``xs`` pytree along its leading axis; returns the final carry.
        The program-plane event kernel is a scan over the event axis
        with the (stream, unit) axes vectorized inside the carry."""
        carry = init
        for i in range(length):
            carry = f(carry, {k: v[i] for k, v in xs.items()})
        return carry


class JaxBackend:
    """``jax.numpy`` instantiation: jit + vmap + x64 compute scope.

    jax is imported lazily so ``repro.core`` keeps zero import-time jax
    dependence; constructing the backend on a machine without jax raises
    a clear error instead of poisoning module import.
    """

    name = "jax"

    def __init__(self):
        try:
            import jax
            import jax.numpy as jnp
        except ImportError as e:  # pragma: no cover - jax ships in CI
            raise RuntimeError(
                "the 'jax' sweep backend needs jax installed; use "
                "backend='numpy' or install jax") from e
        self._jax = jax
        self.xp = jnp
        try:
            from jax.experimental import enable_x64
            self._x64_ctx: Optional[Callable] = enable_x64
        except ImportError:  # pragma: no cover - future jax drift
            self._x64_ctx = None

    @property
    def sa_occupancy_impl(self) -> str:
        """SA occupancy pass inside the jitted sweep kernel: "jnp" (the
        pure-jnp closed form, the oracle) or "pallas" (the
        kernels/sa_occupancy.py tile kernel, interpret=True on CPU).
        Session-scoped state (``repro.core.session``): switch via
        ``set_sa_occupancy_impl`` or ``SweepSession(sa_occupancy_impl=)``;
        the sweep kernel cache keys on it so flipping recompiles
        cleanly."""
        return session.resolve("sa_occupancy_impl")

    # -- x64 discipline ------------------------------------------------
    def x64_enabled(self) -> bool:
        return bool(self._jax.config.jax_enable_x64)

    @contextlib.contextmanager
    def compute_scope(self):
        """All transfers, traces, and executions of the jax sweep path
        run inside this scope so arrays stay float64 end-to-end."""
        if self.x64_enabled():
            yield
        elif self._x64_ctx is not None:
            with self._x64_ctx():
                if not self.x64_enabled():  # pragma: no cover
                    raise RuntimeError(_X64_HELP)
                yield
        else:
            raise RuntimeError(_X64_HELP)

    # -- array contract ------------------------------------------------
    def asarray(self, x):
        return self.xp.asarray(x)

    def to_numpy(self, x) -> np.ndarray:
        return np.asarray(x)

    def segment_sum(self, data, seg_ids, num_segments: int):
        import jax.ops
        return jax.ops.segment_sum(data, seg_ids,
                                   num_segments=num_segments,
                                   indices_are_sorted=True)

    def jit(self, fn: Callable, static_argnames=()) -> Callable:
        return self._jax.jit(fn, static_argnames=static_argnames)

    def vmap_knobs(self, fn: Callable, knobs: dict):
        return self._jax.vmap(fn)(knobs)

    def block(self, tree):
        """Wait for async dispatch so wall-clock timings are honest."""
        return self._jax.block_until_ready(tree)

    def scan(self, f, init, xs, length: int):
        """``lax.scan`` with a carry-only body (no stacked outputs): the
        jit'd form of the numpy backend's sequential loop, used by the
        program-plane event kernel."""
        carry, _ = self._jax.lax.scan(
            lambda c, x: (f(c, x), None), init, xs, length=length)
        return carry

    def sa_occupancy(self, mm_m, mm_k, mm_n, saw, weight_load_cycles=None):
        """Per-op SA PE-occupancy stats, computed *inside* the traced
        sweep program (``saw`` may be a traced scalar — the SA-width
        knob axis). Routes to the pure-jnp closed form or the Pallas
        tile kernel per ``sa_occupancy_impl``."""
        if self.sa_occupancy_impl == "pallas":
            from repro.kernels.sa_occupancy import sa_occupancy_p
            return sa_occupancy_p(mm_m, mm_k, mm_n, saw,
                                  weight_load_cycles)
        from repro.core.sa_gating import gating_stats_batch_xp
        return gating_stats_batch_xp(mm_m, mm_k, mm_n, saw,
                                     weight_load_cycles, xp=self.xp)

    # -- optional multi-device sharding --------------------------------
    def op_axis_sharding(self, mesh):
        """NamedSharding pair (shard-over-ops, replicated) for placing
        the stacked-trace data on a ``jax_compat`` mesh. The op axis is
        the workload axis of the stack (segments are spans of ops), so
        sharding it spreads the per-op work across devices while the
        (W,)-sized segmented outputs stay replicated."""
        from jax.sharding import NamedSharding, PartitionSpec
        return (NamedSharding(mesh, PartitionSpec("wl")),
                NamedSharding(mesh, PartitionSpec()))

    def shard_data(self, data: dict, mesh) -> dict:
        """Device-put a prepared data pytree: ``data["op"]`` leaves are
        sharded along the op axis, everything else replicated."""
        shard, repl = self.op_axis_sharding(mesh)
        jax = self._jax

        def put(tree, sh):
            if isinstance(tree, dict):
                return {k: put(v, sh) for k, v in tree.items()}
            return jax.device_put(tree, sh)

        return {k: put(v, shard if k == "op" else repl)
                for k, v in data.items()}

    # -- shard_map execution path (ISSUE 5) ----------------------------
    @staticmethod
    def mesh_axis_sizes(mesh) -> dict[str, int]:
        from repro.parallel import jax_compat
        return jax_compat.mesh_axis_sizes(mesh)

    @staticmethod
    def pspec(*names):
        """``PartitionSpec`` constructor exposed through the contract so
        the policy engine never imports jax directly."""
        from jax.sharding import PartitionSpec
        return PartitionSpec(*names)

    def psum(self, tree, axis_name: str):
        """Cross-device sum over a mesh axis (inside ``shard_map``)."""
        return self._jax.lax.psum(tree, axis_name)

    def all_gather(self, tree, axis_name: str):
        """Gather shards along leading axis (inside ``shard_map``)."""
        return self._jax.lax.all_gather(tree, axis_name, axis=0,
                                        tiled=True)

    def shard_map_kernel(self, body: Callable, mesh, in_specs,
                         out_specs) -> Callable:
        """Compile ``body`` as one SPMD program over ``mesh`` via the
        version-spanning ``jax_compat.shard_map`` (replication checks
        off: the kernel's psums make every unmentioned-axis output
        genuinely replicated)."""
        from repro.parallel import jax_compat
        return self._jax.jit(jax_compat.shard_map(
            body, mesh=mesh, in_specs=in_specs, out_specs=out_specs))


_BACKENDS: dict[str, object] = {}

BACKEND_NAMES = ("numpy", "jax")


def get_backend(name: Optional[str] = None):
    """Resolve a backend by name (``None`` → the session default).

    Instances are cached: the jax backend holds jitted-program caches
    that must survive across sweep calls for compile-once reuse.
    """
    if name is None:
        name = session.resolve("backend")
    bk = _BACKENDS.get(name)
    if bk is not None:
        return bk
    if name == "numpy":
        bk = NumpyBackend()
    elif name == "jax":
        bk = JaxBackend()
    else:
        raise KeyError(f"unknown array backend {name!r}; "
                       f"have {BACKEND_NAMES}")
    _BACKENDS[name] = bk
    return bk


def set_default_backend(name: str) -> str:
    """Set the process default (what ``backend=None`` resolves to);
    returns the previous default. Delegates to the root
    ``repro.core.session`` layer — an active ``SweepSession`` that pins
    ``backend`` shadows the new default until it exits. Prefer
    ``with SweepSession(backend=...)`` for scoped overrides."""
    if name not in BACKEND_NAMES:
        raise KeyError(f"unknown array backend {name!r}; "
                       f"have {BACKEND_NAMES}")
    return session.set_root(backend=name)["backend"]


def default_backend() -> str:
    """The effective session default backend name."""
    return session.resolve("backend")


def failover_rungs(name: Optional[str] = None, jax_mesh=None) \
        -> tuple[tuple[str, object], ...]:
    """The guard plane's backend-downgrade ladder for a requested
    (backend, mesh): each rung is ``(rung_name, mesh)``, ordered from
    the requested substrate down to the numpy oracle —
    ``jax-mesh`` → ``jax`` (single device) → ``numpy``. A numpy
    request has nowhere to fall, so its ladder is just itself.
    ``None`` resolves through the active session, mirroring
    ``get_backend``."""
    if name is None:
        name = session.resolve("backend")
    if name not in BACKEND_NAMES:
        raise KeyError(f"unknown array backend {name!r}; "
                       f"have {BACKEND_NAMES}")
    if name == "numpy":
        return (("numpy", None),)
    if jax_mesh is None:
        jax_mesh = session.resolve("jax_mesh")
    rungs: list[tuple[str, object]] = []
    if jax_mesh is not None:
        rungs.append(("jax-mesh", jax_mesh))
    rungs.append(("jax", None))
    rungs.append(("numpy", None))
    return tuple(rungs)


SA_OCCUPANCY_IMPLS = ("jnp", "pallas")


def set_sa_occupancy_impl(name: str) -> str:
    """Select the jax backend's in-program SA occupancy pass: ``"jnp"``
    (pure-jnp closed form, the default and oracle) or ``"pallas"`` (the
    ``kernels/sa_occupancy.py`` tile kernel, interpret-mode on CPU).
    Returns the previous selection. The sweep-kernel cache keys on this,
    so flipping it mid-session recompiles instead of reusing a stale
    program."""
    if name not in SA_OCCUPANCY_IMPLS:
        raise KeyError(f"unknown sa_occupancy impl {name!r}; "
                       f"have {SA_OCCUPANCY_IMPLS}")
    prev = session.resolve("sa_occupancy_impl")
    session.set_root(sa_occupancy_impl=name)
    return prev


# --------------------------------------------------------------------------
# fixed-shape gap indexing (host-side; replaces data-dependent reduceat)
# --------------------------------------------------------------------------

def gap_index(active: np.ndarray, offsets: np.ndarray) \
        -> tuple[np.ndarray, np.ndarray]:
    """Fixed-shape equivalent of ``opgen.segmented_gaps``'s chunking.

    Returns ``(chunk_of_op, gap_seg)``: each op's owning idle-gap chunk
    id (N,), and each chunk's segment id (G,). Chunks are delimited
    exactly like ``segmented_gaps`` — a bound after every active op and
    at every segment start, so idle runs never merge across workload
    boundaries and empty segments own zero chunks. With this index the
    per-chunk gap values are ``segment_sum(idle, chunk_of_op, G)`` —
    shape-stable under ``jit`` — and per-(segment, knob) masked merges
    are ``segment_sum`` over ``gap_seg``.

    Depends only on the activity *pattern* (which ops use the
    component), not on service times, so one index per (stack,
    component) serves every NPU generation.
    """
    offsets = np.asarray(offsets, np.int64)
    n_seg = len(offsets) - 1
    idx = np.flatnonzero(active)
    bounds = np.union1d(offsets[:-1], idx + 1)
    if bounds.size == 0:  # no ops and no segments
        return np.zeros(0, np.int64), np.zeros(0, np.int64)
    n = len(active)
    chunk_of_op = np.searchsorted(bounds, np.arange(n), side="right") - 1
    gap_seg = np.minimum(np.searchsorted(offsets, bounds, side="right") - 1,
                         max(n_seg - 1, 0))
    return chunk_of_op.astype(np.int64), gap_seg.astype(np.int64)
