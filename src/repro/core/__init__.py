"""The paper's contribution: ReGate power-gating co-design.

hw/power      — NPU-A..E specs (Table 2/3) + calibrated power model
sa_gating     — PE-level spatial SA gating (Figs 10-13)
isa/passes    — setpm ISA extension + compiler passes (Figs 14-15, §4.3)
opgen/policies— operator traces, columnar trace compilation, and the five
                designs (§6): vectorized ``evaluate`` + scalar
                ``evaluate_reference`` oracle
sweep         — batched design-space sweeps (workloads × npus × policies
                × knob grids): one ``evaluate_batch`` pass over the
                stacked super-trace; ``sweep_reference`` loop oracle;
                ``sweep_grid`` fine-knob §6.5 grids (100k-cell scale)
backend       — pluggable array substrate for the batched plane: numpy
                (oracle) or one jitted float64 jax program (≤1e-9
                equivalent, reused across NPU generations)
carbon        — operational/embodied carbon (Figs 24-25)
slo           — SLO-constrained config sweep (Fig 2)
hlo/roofline  — compiled-HLO cost extraction for the dry-run
ici_topology  — ring / 2-D-mesh collective schedules lowered onto the
                op-level trace (per-step ICI busy/idle timelines)
perturb       — seeded fault injection + adversarial perturbation
                (jitter plane): burst compression, link degradation,
                stragglers, idle fragmentation, clock jitter; the
                ISA differential fuzz harness
"""
from repro.core.backend import (default_backend, get_backend,
                                set_default_backend)
from repro.core.hw import NPUS, TARGET, get_npu
from repro.core.opgen import compile_trace, stack_traces
from repro.core.policies import POLICIES, evaluate, evaluate_all, \
    evaluate_batch, evaluate_reference, savings_vs_nopg
from repro.core.sweep import knob_product, sweep, sweep_grid, \
    sweep_reference, sweep_robustness

__all__ = ["NPUS", "TARGET", "get_npu", "POLICIES", "compile_trace",
           "stack_traces", "evaluate", "evaluate_all", "evaluate_batch",
           "evaluate_reference", "savings_vs_nopg", "sweep",
           "sweep_grid", "sweep_reference", "sweep_robustness",
           "knob_product", "get_backend", "set_default_backend",
           "default_backend"]
