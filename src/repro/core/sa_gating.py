"""Spatially power-gated systolic array model (paper §4.1, Figs 10–13).

A weight-stationary SAW x SAW systolic array computes [M,K] x [K,N].
K maps to SA rows, N to SA columns, M streams through diagonally.

Three underutilization cases (paper Fig 10):
  * N < SAW — right columns hold zero weights; they still would pass data
    rightward, but nothing to their right is live, so cols >= N are OFF.
  * K < SAW — bottom rows hold zero weights; rows >= K are OFF (prefix-sum
    over the row_nz bitmap keeps rows above live ones ON to pass data).
  * M < SAW — all live PEs must hold weights (W_on), but a PE is fully ON
    only while input data passes through it; the PE_on signal propagates
    diagonally with the dataflow, costing one PE's wake-up delay total.

Implementations (fastest first):
  * ``gating_stats_batch_xp`` — the closed-form 4-category ragged-tile
    math over a backend-neutral ``xp`` namespace (numpy or jax.numpy).
    All intermediates are exact integers in float64 (< 2**53), so it is
    bitwise identical to the int64 batch below — and because ``saw``
    may be a *traced* scalar it is what lets the jitted sweep kernel
    carry SA width as a knob axis (ISSUE 5).
  * ``gating_stats_batch`` — vectorized int64 NumPy batch (the host
    oracle used by ``trace_times``).
  * ``gating_stats`` — LRU-cached scalar closed form (cache size
    configurable via ``set_gating_cache_size`` / ``$REPRO_SA_GATING_CACHE``
    so huge sweeps can bound it); ``gating_stats_reference`` /
    ``gating_stats_batch_reference`` are the uncached oracles, so
    equivalence tests never depend on cache state.
  * ``simulate_pe_grid`` — exact cycle-level simulation of the PE_on
    propagation on a small grid; the property tests check the closed
    forms against it.

The prefix-sum row/col logic (paper Fig 12) is ``prefix_on_bitmap`` and is
shared by the Pallas ``gated_matmul`` / ``sa_occupancy`` kernels'
tile-level analogues.
"""
from __future__ import annotations

import math
import os
from dataclasses import dataclass
from functools import lru_cache as _lru_cache

import numpy as np


def prefix_on_bitmap(nz: np.ndarray) -> np.ndarray:
    """Paper Fig 12: a row/col is ON iff it or anything AFTER it is nonzero.

    (Column 0 must stay ON if column 1 is live, to pass data rightward.)
    ``nz``: bool (W,) — nonzero-weight bitmap. Returns bool (W,).
    """
    nz = np.asarray(nz, bool)
    return np.cumsum(nz[::-1])[::-1] > 0


@dataclass(frozen=True)
class SAStats:
    """PE-state cycle occupancy of one matmul on one SA (per-PE-cycle units,
    normalized by total PE-cycles = SAW*SAW*duration)."""

    duration_cycles: float     # total SA-busy cycles for the op
    frac_on: float             # fraction of PE-cycles fully ON
    frac_w_on: float           # fraction only weight-register powered
    frac_off: float            # fraction fully gated
    wake_events: int           # PE wake fronts (for delay accounting)

    @property
    def active_pe_fraction(self) -> float:
        return self.frac_on


def _tile_cycles(m: int, saw: int) -> float:
    """Cycles to stream m rows through a saw-wide SA (fill + drain)."""
    return m + 2 * saw - 1


def gating_stats_reference(M: int, K: int, N: int, saw: int,
                           weight_load_cycles: int | None = None) -> SAStats:
    """Closed-form PE-state occupancy for [M,K]x[K,N] tiled onto the SA.

    Tiling: ceil(K/saw) x ceil(N/saw) weight tiles; M rows stream per tile.
    Only the LAST tile in each dimension is ragged, so the tile population
    has 4 categories (full, ragged-K, ragged-N, ragged-both) — O(1) math.

    This is the *uncached* scalar oracle; ``gating_stats`` wraps it in a
    configurable LRU.
    """
    if weight_load_cycles is None:
        weight_load_cycles = saw  # weights pushed row by row
    kt = math.ceil(K / saw)
    nt = math.ceil(N / saw)
    k_last = K - (kt - 1) * saw
    n_last = N - (nt - 1) * saw
    cyc = _tile_cycles(M, saw) + weight_load_cycles
    on_per_live = min(M, cyc)           # diagonal ON occupancy per live PE
    won_per_live = max(0.0, cyc - M)

    # (multiplicity, live PEs) per tile category
    cats = (
        ((kt - 1) * (nt - 1), saw * saw),
        ((kt - 1), saw * n_last),
        ((nt - 1), k_last * saw),
        (1, k_last * n_last),
    )
    n_tiles = kt * nt
    live_total = sum(m * live for m, live in cats)
    on = live_total * on_per_live
    w_on = live_total * won_per_live
    duration = n_tiles * cyc
    total_pe_cycles = saw * saw * duration
    off = total_pe_cycles - on - w_on
    return SAStats(
        duration_cycles=duration,
        frac_on=on / total_pe_cycles,
        frac_w_on=w_on / total_pe_cycles,
        frac_off=off / total_pe_cycles,
        wake_events=n_tiles,
    )


# The scalar closed form sits behind an LRU because the execution plane
# calls it per-op; a bounded default keeps huge generated sweeps from
# growing the cache without limit (ISSUE 5). The public ``gating_stats``
# delegates through a module global so resizing never invalidates
# callers that imported the function object directly.
_DEFAULT_CACHE_SIZE = int(os.environ.get("REPRO_SA_GATING_CACHE", 65536))
_cached_gating_stats = _lru_cache(maxsize=_DEFAULT_CACHE_SIZE)(
    gating_stats_reference)


def gating_stats(M: int, K: int, N: int, saw: int,
                 weight_load_cycles: int | None = None) -> SAStats:
    """LRU-cached ``gating_stats_reference`` (see there for the math)."""
    return _cached_gating_stats(M, K, N, saw, weight_load_cycles)


def set_gating_cache_size(maxsize: int | None) -> int | None:
    """Resize the ``gating_stats`` LRU (dropping its contents); returns
    the previous maxsize. ``None`` means unbounded, ``0`` disables
    caching entirely. Huge randomized sweeps can bound their footprint
    with a small cache — correctness never depends on cache state
    (``gating_stats_reference`` / ``gating_stats_batch_reference`` are
    the cache-free oracles the property tests pin against)."""
    global _cached_gating_stats
    prev = _cached_gating_stats.cache_info().maxsize
    _cached_gating_stats = _lru_cache(maxsize=maxsize)(
        gating_stats_reference)
    return prev


def gating_cache_info():
    """``functools.lru_cache`` statistics of the scalar closed form."""
    return _cached_gating_stats.cache_info()


@dataclass(frozen=True)
class SAStatsBatch:
    """``SAStats`` over arrays of matmul shapes (one entry per shape).

    Produced by ``gating_stats_batch``; elementwise identical to calling
    ``gating_stats`` per shape (same integer-exact arithmetic, evaluated
    in float64 — all intermediate PE-cycle counts stay below 2**53).
    """

    duration_cycles: np.ndarray
    frac_on: np.ndarray
    frac_w_on: np.ndarray
    frac_off: np.ndarray
    wake_events: np.ndarray


def gating_stats_batch(M, K, N, saw,
                       weight_load_cycles: int | None = None) -> SAStatsBatch:
    """Vectorized ``gating_stats`` over arrays of (M, K, N).

    ``saw`` may be a scalar or an array broadcastable against the dims.
    """
    M = np.asarray(M, np.int64)
    K = np.asarray(K, np.int64)
    N = np.asarray(N, np.int64)
    saw_a = np.asarray(saw, np.int64)
    wlc = saw_a if weight_load_cycles is None else np.asarray(
        weight_load_cycles, np.int64)
    kt = -(-K // saw_a)
    nt = -(-N // saw_a)
    k_last = K - (kt - 1) * saw_a
    n_last = N - (nt - 1) * saw_a
    cyc = (M + 2 * saw_a - 1) + wlc
    on_per_live = np.minimum(M, cyc).astype(np.float64)
    won_per_live = np.maximum(0.0, (cyc - M).astype(np.float64))
    live_total = ((kt - 1) * (nt - 1) * saw_a * saw_a
                  + (kt - 1) * saw_a * n_last
                  + (nt - 1) * k_last * saw_a
                  + k_last * n_last).astype(np.float64)
    n_tiles = kt * nt
    on = live_total * on_per_live
    w_on = live_total * won_per_live
    duration = n_tiles.astype(np.float64) * cyc
    total = saw_a.astype(np.float64) * saw_a * duration
    off = total - on - w_on
    return SAStatsBatch(
        duration_cycles=duration,
        frac_on=on / total,
        frac_w_on=w_on / total,
        frac_off=off / total,
        wake_events=n_tiles,
    )


def gating_stats_batch_reference(M, K, N, saw,
                                 weight_load_cycles=None) -> SAStatsBatch:
    """Loop-of-scalars oracle for the batch implementations: calls the
    *uncached* closed form per element, so equivalence tests depend on
    neither vectorization nor LRU state."""
    M, K, N, saw_a = np.broadcast_arrays(
        np.asarray(M, np.int64), np.asarray(K, np.int64),
        np.asarray(N, np.int64), np.asarray(saw, np.int64))
    wlc = np.broadcast_to(
        np.asarray(-1 if weight_load_cycles is None else weight_load_cycles,
                   np.int64), M.shape)
    stats = [gating_stats_reference(
        int(m), int(k), int(n), int(s),
        None if w < 0 else int(w))
        for m, k, n, s, w in zip(M.ravel(), K.ravel(), N.ravel(),
                                 saw_a.ravel(), wlc.ravel())]

    def col(attr, dtype=np.float64):
        return np.array([getattr(s, attr) for s in stats],
                        dtype).reshape(M.shape)

    return SAStatsBatch(
        duration_cycles=col("duration_cycles"),
        frac_on=col("frac_on"), frac_w_on=col("frac_w_on"),
        frac_off=col("frac_off"),
        wake_events=col("wake_events", np.int64))


def gating_stats_batch_xp(M, K, N, saw, weight_load_cycles=None, *,
                          xp=np) -> dict:
    """Backend-neutral ``gating_stats_batch``: the same closed-form
    4-category ragged-tile math in pure float64 ``xp`` ops.

    Every input may be a traced (jax) array — including ``saw``, which
    is what lets the jitted sweep kernel carry SA width as a knob axis.
    All intermediate tile counts and PE-cycle totals are exact integers
    in float64 (they stay far below 2**53), so the results are bitwise
    identical to the int64 ``gating_stats_batch`` host path. Degenerate
    rows (K or N zero — never produced by real traces) yield zeros
    instead of dividing by zero, so masked sentinel entries are safe
    under ``xp.where``.

    Returns a plain dict (a jax pytree): ``duration_cycles``,
    ``frac_on``, ``frac_w_on``, ``frac_off``, ``wake_events``.
    """
    f8 = xp.float64
    M = xp.asarray(M, f8)
    K = xp.asarray(K, f8)
    N = xp.asarray(N, f8)
    saw = xp.asarray(saw, f8)
    wlc = saw if weight_load_cycles is None \
        else xp.asarray(weight_load_cycles, f8)
    # ceil(K/saw) on exact float64 integers: the quotient is correctly
    # rounded and 1/saw >= 2**-53 away from the next integer, so floor
    # can never land on the wrong side
    kt = xp.floor((K + saw - 1.0) / saw)
    nt = xp.floor((N + saw - 1.0) / saw)
    k_last = K - (kt - 1.0) * saw
    n_last = N - (nt - 1.0) * saw
    cyc = (M + 2.0 * saw - 1.0) + wlc
    on_per_live = xp.minimum(M, cyc)
    won_per_live = xp.maximum(0.0, cyc - M)
    live_total = ((kt - 1.0) * (nt - 1.0) * saw * saw
                  + (kt - 1.0) * saw * n_last
                  + (nt - 1.0) * k_last * saw
                  + k_last * n_last)
    n_tiles = kt * nt
    on = live_total * on_per_live
    w_on = live_total * won_per_live
    duration = n_tiles * cyc
    total = saw * saw * duration
    off = total - on - w_on
    # total is an exact integer >= 1 for all valid shapes, so the guard
    # only rescues degenerate rows (it never changes a real quotient)
    denom = xp.maximum(total, 1.0)
    return {
        "duration_cycles": duration,
        "frac_on": on / denom,
        "frac_w_on": w_on / denom,
        "frac_off": off / denom,
        "wake_events": n_tiles,
    }


def spatial_efficiency(M: int, K: int, N: int, saw: int) -> float:
    """Achieved/peak FLOPs while the SA is active (paper Fig 5 metric):
    useful MAC-cycles over total PE-cycles of the busy window."""
    st = gating_stats(M, K, N, saw)
    flops_cycles_needed = M * K * N / (saw * saw)  # perfect PE-cycles
    return min(1.0, flops_cycles_needed / max(1e-12, st.duration_cycles))


# --------------------------------------------------------------------------
# Exact cycle-level reference simulation (small grids; used by tests)
# --------------------------------------------------------------------------

def simulate_pe_grid(M: int, K: int, N: int, saw: int) -> dict:
    """Cycle-accurate PE_on propagation for ONE weight tile (K,N <= saw).

    Weight-stationary: weights W[0:K, 0:N] nonzero, rest zero-padded.
    Row r receives input element m at cycle m + r (diagonal skew); PE (r,c)
    is ON at cycle t iff it is processing some input, i.e.
    t - r - c in [0, M). Rows >= K / cols >= N handled by the prefix
    bitmaps. Returns per-state PE-cycle counts.

    NumPy-broadcast: instead of walking the (t, r, c) cube, the number of
    ON cycles of a live PE is the size of the integer interval
    [max(0, r+c), min(total, r+c+M)) — integer-exact, so results are
    bitwise equal to ``simulate_pe_grid_reference``.
    """
    nz_row = prefix_on_bitmap(np.arange(saw) < K)
    nz_col = prefix_on_bitmap(np.arange(saw) < N)
    total_cycles = int(_tile_cycles(M, saw))
    live = nz_row[:, None] & nz_col[None, :]
    rc = np.arange(saw)[:, None] + np.arange(saw)[None, :]
    on_per_pe = np.clip(np.minimum(total_cycles, rc + M)
                        - np.maximum(0, rc), 0, None)
    n_live = int(live.sum())
    on = int(on_per_pe[live].sum())
    w_on = n_live * total_cycles - on
    off = (saw * saw - n_live) * total_cycles
    return {"on": on, "w_on": w_on, "off": off,
            "total": saw * saw * total_cycles}


def simulate_pe_grid_reference(M: int, K: int, N: int, saw: int) -> dict:
    """Original pure-Python triple loop over (t, r, c); O(saw²·cycles).

    Kept as the ground-truth oracle for the vectorized ``simulate_pe_grid``
    (the property tests check them bitwise equal on randomized shapes).
    """
    nz_row = prefix_on_bitmap(np.arange(saw) < K)
    nz_col = prefix_on_bitmap(np.arange(saw) < N)
    total_cycles = _tile_cycles(M, saw)
    on = w_on = off = 0
    for t in range(int(total_cycles)):
        for r in range(saw):
            for c in range(saw):
                if not (nz_row[r] and nz_col[c]):
                    off += 1
                    continue
                if 0 <= t - r - c < M:
                    on += 1
                else:
                    w_on += 1
    return {"on": on, "w_on": w_on, "off": off,
            "total": saw * saw * int(total_cycles)}
