"""NPU power-management ISA extension + VLIW timeline executor (paper §4.2).

``setpm`` (set power mode) — paper Fig 14:
  * variant 1 (SRAM): ``setpm %start, %end, sram, <mode>`` — gates a
    contiguous address range, per 4 KB segment;
  * variants 2/3 (FUs): ``setpm <fu_bitmap>, <sa|vu|hbm|ici>, <mode>`` —
    the bitmap (register or immediate) selects multiple units at once so a
    single misc-slot instruction reconfigures several FUs in one cycle.

The cycle-level executor reproduces the paper's Fig 15 example: it tracks
per-FU power state, enforces the "power-gated component is a structural
hazard" rule (instructions stall until the unit is READY), and accounts
static energy per cycle per state. Used by the microbenchmarks and the
property tests; workload-scale energy uses the op-level engine in
``repro.core.policies``.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field, replace
from typing import Iterable, Optional

from repro.core.hw import NPUSpec, get_npu


class PMode(enum.Enum):
    AUTO = "auto"
    ON = "on"
    OFF = "off"
    SLEEP = "sleep"  # SRAM only


@dataclass(frozen=True)
class Instr:
    """One VLIW slot operation."""
    opcode: str               # push | pop | vadd | vmul | dma | sync | setpm
    unit: str                 # "sa0".."vu3" | "dma" | "ici" | "misc"
    latency: int = 1
    # setpm fields (paper Fig 14)
    pm_fu_type: Optional[str] = None    # sa | vu | sram | hbm | ici
    pm_bitmap: int = 0                  # which FU instances
    pm_mode: Optional[PMode] = None
    pm_range: Optional[tuple[int, int]] = None  # sram [start, end) bytes


def setpm(fu_type: str, bitmap: int, mode: PMode,
          sram_range: Optional[tuple[int, int]] = None) -> Instr:
    return Instr("setpm", "misc", 1, pm_fu_type=fu_type, pm_bitmap=bitmap,
                 pm_mode=mode, pm_range=sram_range)


@dataclass
class FUState:
    kind: str            # sa | vu
    powered: bool = True
    mode: PMode = PMode.AUTO
    ready_at: int = 0    # cycle when wake-up completes
    busy_until: int = 0
    idle_since: int = 0
    on_cycles: int = 0
    gated_cycles: int = 0
    wake_events: int = 0


@dataclass
class ExecResult:
    cycles: int
    fu_on_cycles: dict[str, int]
    fu_gated_cycles: dict[str, int]
    stall_cycles: int
    setpm_executed: int
    wake_events: dict[str, int]

    def static_energy_units(self, leak_off: float = 0.03) -> float:
        """Static energy in (power-unit x cycles), one unit per FU."""
        e = 0.0
        for k in self.fu_on_cycles:
            e += self.fu_on_cycles[k] + leak_off * self.fu_gated_cycles[k]
        return e


class VLIWTimeline:
    """Executes a bundle list. Each cycle may issue one bundle (a dict
    unit->Instr, plus at most one misc-slot setpm)."""

    def __init__(self, npu: NPUSpec | str = "NPU-D", n_sa: int = 2,
                 n_vu: int = 2, hw_auto_gating: bool = True):
        self.npu = get_npu(npu) if isinstance(npu, str) else npu
        self.fus: dict[str, FUState] = {}
        for i in range(n_sa):
            self.fus[f"sa{i}"] = FUState("sa")
        for i in range(n_vu):
            self.fus[f"vu{i}"] = FUState("vu")
        self.hw_auto = hw_auto_gating
        self.g = self.npu.gating

    def _delay(self, kind: str) -> int:
        return self.g.on_off_delay["sa_full" if kind == "sa" else "vu"]

    def _window(self, kind: str) -> int:
        key = "sa_full" if kind == "sa" else "vu"
        return max(8, int(self.g.bet[key] * self.g.detection_window_frac))

    def run(self, bundles: Iterable[dict[str, Instr]]) -> ExecResult:
        t = 0
        stalls = 0
        n_setpm = 0
        for bundle in bundles:
            # 1) apply setpm from the misc slot (takes effect this cycle)
            m = bundle.get("misc")
            if m is not None and m.opcode == "setpm":
                n_setpm += 1
                for name, fu in self.fus.items():
                    if fu.kind != m.pm_fu_type:
                        continue
                    idx = int(name[2:])
                    if not (m.pm_bitmap >> idx) & 1:
                        continue
                    fu.mode = m.pm_mode
                    if m.pm_mode == PMode.OFF:
                        fu.powered = False
                    elif m.pm_mode == PMode.ON and not fu.powered:
                        fu.powered = True
                        fu.ready_at = t + self._delay(fu.kind)
                        fu.wake_events += 1

            # 2) structural hazards: wait for every referenced unit
            need = [i for u, i in bundle.items() if u != "misc"]
            start = t
            for ins in need:
                fu = self.fus.get(ins.unit)
                if fu is None:
                    continue
                if not fu.powered:  # auto-wake on dispatch
                    if fu.mode == PMode.OFF:
                        # sw said OFF: dispatch overrides (hazard + wake)
                        pass
                    fu.powered = True
                    fu.ready_at = max(t, fu.busy_until) + self._delay(fu.kind)
                    fu.wake_events += 1
                start = max(start, fu.ready_at, fu.busy_until)
            stalls += start - t

            # 3) issue
            for ins in need:
                fu = self.fus.get(ins.unit)
                if fu is None:
                    continue
                fu.busy_until = start + ins.latency
                fu.idle_since = fu.busy_until
            t = start + 1

            # 4) hardware auto idle-detection gating
            if self.hw_auto:
                for fu in self.fus.values():
                    if (fu.powered and fu.mode == PMode.AUTO
                            and t - fu.idle_since >= self._window(fu.kind)
                            and fu.busy_until <= t):
                        fu.powered = False

            # 5) accounting
            for fu in self.fus.values():
                if fu.powered:
                    fu.on_cycles += 1
                else:
                    fu.gated_cycles += 1

        end = max([t] + [f.busy_until for f in self.fus.values()])
        for fu in self.fus.values():  # drain accounting
            extra = end - t
            if fu.powered:
                fu.on_cycles += extra
            else:
                fu.gated_cycles += extra
        return ExecResult(
            cycles=end,
            fu_on_cycles={k: f.on_cycles for k, f in self.fus.items()},
            fu_gated_cycles={k: f.gated_cycles for k, f in self.fus.items()},
            stall_cycles=stalls,
            setpm_executed=n_setpm,
            wake_events={k: f.wake_events for k, f in self.fus.items()},
        )


def fig15_program(n_periods: int = 4, *, with_setpm: bool,
                  push_cycles: int = 8, vadd_cycles: int = 1,
                  n_sa: int = 2, n_vu: int = 2) -> list[dict[str, Instr]]:
    """The paper's Fig 15 pattern: 2 SAs push for 8 cycles each (staggered),
    VUs post-process for ~2 cycles out of every 16; the compiler setpm-gates
    the VUs in the 10-cycle holes."""
    bundles: list[dict[str, Instr]] = []
    vu_mask = (1 << n_vu) - 1
    for p in range(n_periods):
        for i in range(push_cycles):
            b: dict[str, Instr] = {
                "sa0": Instr("push", "sa0", 1),
            }
            if i == 0 and with_setpm and p > 0:
                b["misc"] = setpm("vu", vu_mask, PMode.ON)  # pre-wake
            bundles.append(b)
        for i in range(push_cycles):
            b = {"sa1": Instr("push", "sa1", 1)}
            if i < 2:  # VUs consume the SA0 outputs
                b[f"vu{i % n_vu}"] = Instr("vadd", f"vu{i % n_vu}",
                                           vadd_cycles)
            if i == 2 and with_setpm:
                b["misc"] = setpm("vu", vu_mask, PMode.OFF)
            bundles.append(b)
    return bundles
