"""NPU power-management ISA extension + VLIW timeline executors (paper §4.2).

``setpm`` (set power mode) — paper Fig 14:
  * variant 1 (SRAM): ``setpm %start, %end, sram, <mode>`` — gates a
    contiguous address range, per 4 KB segment;
  * variants 2/3 (FUs): ``setpm <fu_bitmap>, <sa|vu|hbm|ici>, <mode>`` —
    the bitmap (register or immediate) selects multiple units at once so a
    single misc-slot instruction reconfigures several FUs in one cycle.

Two executors share one machine model (per-FU power state, the
"power-gated component is a structural hazard" rule, per-cycle static
accounting):

* ``VLIWTimeline`` — the cycle-stepper reference: one bundle per cycle,
  O(cycles). Reproduces the paper's Fig 15 example and anchors the
  property tests.
* ``EventTimeline`` — the event-driven (interval-based) executor for
  workload-scale programs: the program is a SPARSE list of
  ``(cycle, bundle)`` events; gaps between events are closed-form
  (idle-detection crossings computed analytically per FU), so cost is
  O(events), not O(cycles). ``tests/test_event_executor.py`` holds it to
  exact equality against the cycle-stepper on the microbenchmarks and on
  sampled workload-scale programs (see ``expand_events``).

Workload-scale programs come out of ``repro.core.lowering``; energy at
that scale cross-validates against the closed-form engine in
``repro.core.policies``.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field, replace
from typing import Iterable, Optional

import numpy as np

from repro.core.hw import NPUSpec, get_npu


class PMode(enum.Enum):
    AUTO = "auto"
    ON = "on"
    OFF = "off"
    SLEEP = "sleep"  # SRAM only


@dataclass(frozen=True)
class Instr:
    """One VLIW slot operation."""
    opcode: str               # push | pop | vadd | vmul | dma | sync | setpm
    unit: str                 # "sa0".."vu3" | "dma0" | "ici0" | "misc"
    latency: int = 1
    # setpm fields (paper Fig 14)
    pm_fu_type: Optional[str] = None    # sa | vu | sram | hbm | ici
    pm_bitmap: int = 0                  # which FU instances
    pm_mode: Optional[PMode] = None
    pm_range: Optional[tuple[int, int]] = None  # sram [start, end) bytes


def setpm(fu_type: str, bitmap: int, mode: PMode,
          sram_range: Optional[tuple[int, int]] = None) -> Instr:
    return Instr("setpm", "misc", 1, pm_fu_type=fu_type, pm_bitmap=bitmap,
                 pm_mode=mode, pm_range=sram_range)


def unit_index(name: str) -> int:
    """Bitmap index of a FU instance: its trailing digits ("vu2" -> 2,
    "dma0"/"dma" -> 0)."""
    i = len(name)
    while i > 0 and name[i - 1].isdigit():
        i -= 1
    return int(name[i:]) if i < len(name) else 0


@dataclass
class FUState:
    kind: str            # sa | vu | hbm | ici
    powered: bool = True
    mode: PMode = PMode.AUTO
    ready_at: int = 0    # cycle when wake-up completes
    busy_until: int = 0
    idle_since: int = 0
    on_cycles: int = 0
    gated_cycles: int = 0
    wake_events: int = 0


@dataclass
class ExecResult:
    cycles: int
    fu_on_cycles: dict[str, int]
    fu_gated_cycles: dict[str, int]
    stall_cycles: int
    setpm_executed: int
    wake_events: dict[str, int]

    def static_energy_units(self, leak_off: float = 0.03) -> float:
        """Static energy in (power-unit x cycles), one unit per FU."""
        e = 0.0
        for k in self.fu_on_cycles:
            e += self.fu_on_cycles[k] + leak_off * self.fu_gated_cycles[k]
        return e


# gating-parameter table keys per FU kind (paper Table 3)
DELAY_KEYS = {"sa": "sa_full", "vu": "vu", "hbm": "hbm", "ici": "ici"}


def scaled_delay(g, key: str, delay_scale: float = 1.0) -> int:
    """Integer wake delay under the §6.5 ``delay_scale`` knob.

    The single rounding rule shared by the executors and the batched
    program-plane kernel (``repro.core.program_plane``): both sides must
    land on the SAME integer or machine times diverge. ``scale=1.0``
    reproduces the raw Table 3 value exactly."""
    return int(round(g.on_off_delay[key] * delay_scale))


def scaled_window(g, key: str, delay_scale: float = 1.0,
                  window_scale: float = 1.0) -> int:
    """Integer idle-detection window under the delay/window knobs.

    ``delay_scale`` rides through the BET (the closed-form engine's
    convention: window = BET x detection_window_frac, and the knob
    scales BETs with the delays); ``window_scale`` scales only the
    window. The 8-cycle floor and the int truncation reproduce the
    unscaled executor formula bit-for-bit at scales of 1.0."""
    return max(8, int(g.bet[key] * delay_scale
                      * g.detection_window_frac * window_scale))


class VLIWTimeline:
    """Cycle-stepper reference executor. Each cycle may issue one bundle
    (a dict unit->Instr, plus at most one misc-slot setpm)."""

    def __init__(self, npu: NPUSpec | str = "NPU-D", n_sa: int = 2,
                 n_vu: int = 2, hw_auto_gating: bool = True,
                 extra_units: Optional[dict[str, str]] = None,
                 delay_keys: Optional[dict[str, str]] = None,
                 initial_modes: Optional[dict[str, PMode]] = None,
                 delay_scale: float = 1.0, window_scale: float = 1.0):
        """``extra_units``: name -> kind for units beyond the SA/VU files
        (e.g. {"dma0": "hbm", "ici0": "ici"}). ``delay_keys`` overrides
        the kind -> gating-table key map (e.g. sa -> "sa_pe" when the
        SA gates at PE granularity). ``initial_modes``: per-unit initial
        power mode — software-managed units start in ON (hardware
        idle-detection disabled; setpm drives them). ``delay_scale`` /
        ``window_scale`` apply the §6.5 sensitivity knobs with the
        integer rounding of ``scaled_delay`` / ``scaled_window`` (the
        program-plane kernel uses the identical integers)."""
        self.npu = get_npu(npu) if isinstance(npu, str) else npu
        self.fus: dict[str, FUState] = {}
        for i in range(n_sa):
            self.fus[f"sa{i}"] = FUState("sa")
        for i in range(n_vu):
            self.fus[f"vu{i}"] = FUState("vu")
        for name, kind in (extra_units or {}).items():
            self.fus[name] = FUState(kind)
        for name, mode in (initial_modes or {}).items():
            self.fus[name].mode = mode
        self.hw_auto = hw_auto_gating
        self.g = self.npu.gating
        self.delay_keys = dict(DELAY_KEYS)
        if delay_keys:
            self.delay_keys.update(delay_keys)
        self.delay_scale = float(delay_scale)
        self.window_scale = float(window_scale)
        self._stalls = 0
        self._n_setpm = 0

    def _delay(self, kind: str) -> int:
        return scaled_delay(self.g, self.delay_keys[kind],
                            self.delay_scale)

    def _window(self, kind: str) -> int:
        return scaled_window(self.g, self.delay_keys[kind],
                             self.delay_scale, self.window_scale)

    # ------------------------------------------------------------------
    # one-bundle machine step (shared by both executors)
    # ------------------------------------------------------------------

    def _step(self, bundle: dict[str, Instr], t: int) -> int:
        """Execute one bundle at machine time ``t``; returns the new
        machine time (t + 1 + any dispatch stall)."""
        # 1) apply setpm from the misc slot (takes effect this cycle)
        m = bundle.get("misc")
        if m is not None and m.opcode == "setpm":
            self._n_setpm += 1
            for name, fu in self.fus.items():
                if fu.kind != m.pm_fu_type:
                    continue
                if not (m.pm_bitmap >> unit_index(name)) & 1:
                    continue
                fu.mode = m.pm_mode
                if m.pm_mode == PMode.OFF:
                    fu.powered = False
                elif m.pm_mode == PMode.ON and not fu.powered:
                    fu.powered = True
                    fu.ready_at = t + self._delay(fu.kind)
                    fu.wake_events += 1

        # 2) structural hazards: wait for every referenced unit
        need = [i for u, i in bundle.items() if u != "misc"]
        start = t
        for ins in need:
            fu = self.fus.get(ins.unit)
            if fu is None:
                continue
            if not fu.powered:  # auto-wake on dispatch
                if fu.mode == PMode.OFF:
                    # sw said OFF: dispatch overrides (hazard + wake)
                    pass
                fu.powered = True
                fu.ready_at = max(t, fu.busy_until) + self._delay(fu.kind)
                fu.wake_events += 1
            start = max(start, fu.ready_at, fu.busy_until)
        self._stalls += start - t

        # 3) issue
        for ins in need:
            fu = self.fus.get(ins.unit)
            if fu is None:
                continue
            fu.busy_until = start + ins.latency
            fu.idle_since = fu.busy_until
        t = start + 1

        # 4) hardware auto idle-detection gating
        if self.hw_auto:
            for fu in self.fus.values():
                if (fu.powered and fu.mode == PMode.AUTO
                        and t - fu.idle_since >= self._window(fu.kind)
                        and fu.busy_until <= t):
                    fu.powered = False

        # 5) accounting
        for fu in self.fus.values():
            if fu.powered:
                fu.on_cycles += 1
            else:
                fu.gated_cycles += 1
        return t

    def _finish(self, t: int) -> ExecResult:
        end = max([t] + [f.busy_until for f in self.fus.values()])
        for fu in self.fus.values():  # drain accounting
            extra = end - t
            if fu.powered:
                fu.on_cycles += extra
            else:
                fu.gated_cycles += extra
        return ExecResult(
            cycles=end,
            fu_on_cycles={k: f.on_cycles for k, f in self.fus.items()},
            fu_gated_cycles={k: f.gated_cycles for k, f in self.fus.items()},
            stall_cycles=self._stalls,
            setpm_executed=self._n_setpm,
            wake_events={k: f.wake_events for k, f in self.fus.items()},
        )

    def run(self, bundles: Iterable[dict[str, Instr]]) -> ExecResult:
        self._stalls = 0
        self._n_setpm = 0
        t = 0
        for bundle in bundles:
            t = self._step(bundle, t)
        return self._finish(t)


class EventTimeline(VLIWTimeline):
    """Event-driven executor: processes only the cycles that carry an
    instruction and jumps over the empty stretches in closed form.

    The program is a sorted list of ``(cycle_index, bundle)`` events —
    semantically identical to the dense program that has ``bundle`` at
    that index and an empty bundle everywhere else (``expand_events``
    materializes exactly that program for the equality tests). Gap
    handling replicates the cycle-stepper's per-cycle semantics: a
    powered AUTO unit crosses its idle-detection window at
    ``max(idle_since + window, busy_until)`` and is accounted gated from
    that cycle on, so the two executors agree cycle-for-cycle.
    """

    def _gap(self, n: int, t: int) -> None:
        """Advance through ``n`` empty cycles starting at machine time
        ``t`` (closed form; mutates FU accounting/state)."""
        for fu in self.fus.values():
            if not fu.powered:
                fu.gated_cycles += n
            elif not (self.hw_auto and fu.mode == PMode.AUTO):
                fu.on_cycles += n
            else:
                # first empty cycle accounts at t+1, last at t+n; the FU
                # counts gated from the cycle it crosses the window
                g = max(fu.idle_since + self._window(fu.kind),
                        fu.busy_until)
                on = min(max(g - t - 1, 0), n)
                fu.on_cycles += on
                if n > on:
                    fu.gated_cycles += n - on
                    fu.powered = False

    def run(self, events: Iterable[tuple[int, dict[str, Instr]]],
            horizon: Optional[int] = None) -> ExecResult:
        self._stalls = 0
        self._n_setpm = 0
        t = 0
        prev = -1
        for idx, bundle in events:
            if idx <= prev:
                raise ValueError(
                    f"events must be strictly increasing (got {idx} "
                    f"after {prev})")
            gap = idx - prev - 1
            if gap:
                self._gap(gap, t)
                t += gap
            t = self._step(bundle, t)
            prev = idx
        if horizon is not None and horizon > prev + 1:
            tail = horizon - prev - 1
            self._gap(tail, t)
            t += tail
        return self._finish(t)


def merge_events(events: Iterable[tuple[int, dict[str, Instr]]]) \
        -> list[tuple[int, dict[str, Instr]]]:
    """Canonicalize a raw event list into a valid sparse program: sort by
    cycle and merge same-cycle events into one bundle.

    On a slot collision (two instructions for the same unit — or two
    misc-slot setpms — at the same cycle) the later entry wins, the VLIW
    rule for double-written slots. The result satisfies ``EventTimeline``'s
    strictly-increasing contract, so pathological generators (the
    ``repro.core.perturb`` fuzz harness) can emit colliding raw streams
    and still produce a well-formed program.
    """
    merged: dict[int, dict[str, Instr]] = {}
    for cycle, bundle in events:
        merged.setdefault(int(cycle), {}).update(bundle)
    return sorted(merged.items())


# power-mode codes for the columnar event form (``events_to_arrays``) —
# the batched program-plane kernel consumes these
PM_NONE, PM_ON, PM_OFF, PM_AUTO = 0, 1, 2, 3
_PM_CODE = {PMode.ON: PM_ON, PMode.OFF: PM_OFF, PMode.AUTO: PM_AUTO}


def events_to_arrays(events: Iterable[tuple[int, dict[str, Instr]]],
                     units: tuple[str, ...]) -> dict[str, np.ndarray]:
    """Columnar form of a sparse event program for the batched kernel.

    ``units`` fixes the unit-axis order. Returns int64/int8 arrays:

    * ``cycle`` (E,)    — event cycle indices, strictly increasing;
    * ``lat``   (E, U)  — per-unit issue latency, 0 where the bundle
      does not reference the unit;
    * ``pm``    (E, U)  — misc-slot setpm effect on each unit
      (``PM_NONE``/``PM_ON``/``PM_OFF``/``PM_AUTO``), decoded from the
      fu-type + bitmap addressing exactly like the executors.

    SRAM range setpms have no FU-state footprint in the timeline machine
    (no unit of kind "sram" exists) and are rejected: the program plane
    accounts SRAM analytically (``lowering.sram_band_gating``).
    """
    events = list(events)
    uix = {u: i for i, u in enumerate(units)}
    kind = {u: ("hbm" if u.startswith("dma") else
                "ici" if u.startswith("ici") else u[:2]) for u in units}
    cycle = np.empty(len(events), np.int64)
    lat = np.zeros((len(events), len(units)), np.int64)
    pm = np.zeros((len(events), len(units)), np.int8)
    prev = -1
    for e, (idx, bundle) in enumerate(events):
        if idx <= prev:
            raise ValueError(
                f"events must be strictly increasing (got {idx} "
                f"after {prev})")
        prev = idx
        cycle[e] = idx
        for slot, ins in bundle.items():
            if slot == "misc":
                if ins.opcode != "setpm":
                    continue
                if ins.pm_range is not None:
                    raise ValueError(
                        "range setpm has no timeline unit; SRAM gating "
                        "is analytic (sram_band_gating)")
                code = _PM_CODE[ins.pm_mode]
                for u, i in uix.items():
                    if (kind[u] == ins.pm_fu_type
                            and (ins.pm_bitmap >> unit_index(u)) & 1):
                        pm[e, i] = code
            elif slot in uix:
                lat[e, uix[slot]] = ins.latency
    return {"cycle": cycle, "lat": lat, "pm": pm}


def expand_events(events: Iterable[tuple[int, dict[str, Instr]]],
                  horizon: Optional[int] = None) \
        -> list[dict[str, Instr]]:
    """Dense bundle list equivalent to a sparse event program (the
    reference cycle-stepper's input for the equality tests)."""
    events = list(events)
    length = max([horizon or 0] + [i + 1 for i, _ in events])
    dense: list[dict[str, Instr]] = [{} for _ in range(length)]
    for idx, bundle in events:
        dense[idx] = bundle
    return dense


def fig15_program(n_periods: int = 4, *, with_setpm: bool,
                  push_cycles: int = 8, vadd_cycles: int = 1,
                  n_sa: int = 2, n_vu: int = 2) -> list[dict[str, Instr]]:
    """The paper's Fig 15 pattern: 2 SAs push for 8 cycles each (staggered),
    VUs post-process for ~2 cycles out of every 16; the compiler setpm-gates
    the VUs in the 10-cycle holes."""
    bundles: list[dict[str, Instr]] = []
    vu_mask = (1 << n_vu) - 1
    for p in range(n_periods):
        for i in range(push_cycles):
            b: dict[str, Instr] = {
                "sa0": Instr("push", "sa0", 1),
            }
            if i == 0 and with_setpm and p > 0:
                b["misc"] = setpm("vu", vu_mask, PMode.ON)  # pre-wake
            bundles.append(b)
        for i in range(push_cycles):
            b = {"sa1": Instr("push", "sa1", 1)}
            if i < 2:  # VUs consume the SA0 outputs
                b[f"vu{i % n_vu}"] = Instr("vadd", f"vu{i % n_vu}",
                                           vadd_cycles)
            if i == 2 and with_setpm:
                b["misc"] = setpm("vu", vu_mask, PMode.OFF)
            bundles.append(b)
    return bundles
