"""Session-scoped sweep configuration (ISSUE 7).

The sweep substrate used to be configured through four independent
module-level switches threaded ad hoc through every entry point:
``backend.set_default_backend`` (what ``backend=None`` resolves to),
``backend.set_sa_occupancy_impl`` (the jax kernel's occupancy pass),
a ``jax_mesh=`` kwarg repeated on each call, and
``sa_gating.set_gating_cache_size``. ``SweepSession`` consolidates them
into one context object::

    with SweepSession(backend="jax", jax_mesh=mesh):
        recs = sweep_grid(suite, grid=grid)   # rides the session

A session is a *layer*: fields left at ``UNSET`` inherit from the
enclosing session (ultimately the root session, which holds the
process-wide defaults the legacy setters mutate). Sessions nest — an
inner ``SweepSession(backend="numpy")`` temporarily pins the backend
while still inheriting the outer session's mesh — and restore the
previous state on exit, exception-safe.

Compatibility contract:

* ``backend.default_backend()`` / ``backend.set_default_backend`` and
  ``backend.set_sa_occupancy_impl`` now read/write the ROOT session, so
  old call sites keep working; while a session that pins the same field
  is active, the session wins (the setter still records the new root
  default, visible once the session exits).
* ``gating_cache_size`` is applied on ``__enter__`` via
  ``sa_gating.set_gating_cache_size`` (the LRU itself stays the single
  source of truth) and the previous size is restored on ``__exit__``.
* ``jax_mesh`` is consulted by ``policies.evaluate_batch`` whenever its
  ``jax_mesh=`` argument is ``None`` — but only when the effective
  backend is jax, so a numpy sweep inside a mesh session stays valid.
"""
from __future__ import annotations

import threading
from typing import Any, Optional


class _Unset:
    """Sentinel: 'inherit this field from the enclosing session'."""

    _instance: Optional["_Unset"] = None

    def __new__(cls):
        if cls._instance is None:
            cls._instance = super().__new__(cls)
        return cls._instance

    def __repr__(self) -> str:  # pragma: no cover - repr cosmetics
        return "<inherit>"


UNSET = _Unset()

_FIELDS = ("backend", "jax_mesh", "sa_occupancy_impl",
           "gating_cache_size", "guard")


class SweepSession:
    """One configuration layer for the sweep substrate.

    Parameters all default to ``UNSET`` (inherit). ``backend`` must be
    one of ``backend.BACKEND_NAMES``; ``sa_occupancy_impl`` one of
    ``backend.SA_OCCUPANCY_IMPLS``; ``gating_cache_size`` a cache size
    accepted by ``sa_gating.set_gating_cache_size`` (``None`` =
    unbounded); ``guard`` a ``guard.GuardPolicy`` (or ``None``) that
    campaign entry points (``sweep_fleet`` / ``sweep_chaos``) pick up
    when their ``guard=`` argument is left unset — scoping the guard
    plane's watchdog/failover/quarantine machinery exactly like the
    backend. Use as a context manager; re-entering an already-active
    session raises.
    """

    def __init__(self, backend: Any = UNSET, jax_mesh: Any = UNSET,
                 sa_occupancy_impl: Any = UNSET,
                 gating_cache_size: Any = UNSET, guard: Any = UNSET):
        if backend is not UNSET:
            _check_backend(backend)
        if sa_occupancy_impl is not UNSET:
            _check_impl(sa_occupancy_impl)
        if guard is not UNSET:
            _check_guard(guard)
        self.backend = backend
        self.jax_mesh = jax_mesh
        self.sa_occupancy_impl = sa_occupancy_impl
        self.gating_cache_size = gating_cache_size
        self.guard = guard
        self._active = False
        self._prev_cache: Any = UNSET

    def __repr__(self) -> str:
        parts = [f"{f}={getattr(self, f)!r}" for f in _FIELDS
                 if getattr(self, f) is not UNSET]
        return f"SweepSession({', '.join(parts)})"

    # -- context management -------------------------------------------
    def __enter__(self) -> "SweepSession":
        if self._active:
            raise RuntimeError("SweepSession is not re-entrant; "
                               "construct a new one per `with` block")
        _stack().append(self)
        self._active = True
        if self.gating_cache_size is not UNSET:
            from repro.core import sa_gating
            self._prev_cache = sa_gating.set_gating_cache_size(
                self.gating_cache_size)
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        stack = _stack()
        if not self._active or stack[-1] is not self:
            raise RuntimeError(
                "SweepSession exited out of order (not the innermost "
                "active session)")
        if self._prev_cache is not UNSET:
            from repro.core import sa_gating
            sa_gating.set_gating_cache_size(self._prev_cache)
            self._prev_cache = UNSET
        stack.pop()
        self._active = False


def _check_backend(name: str) -> str:
    from repro.core.backend import BACKEND_NAMES
    if name not in BACKEND_NAMES:
        raise KeyError(f"unknown array backend {name!r}; "
                       f"have {BACKEND_NAMES}")
    return name


def _check_impl(name: str) -> str:
    from repro.core.backend import SA_OCCUPANCY_IMPLS
    if name not in SA_OCCUPANCY_IMPLS:
        raise KeyError(f"unknown sa_occupancy impl {name!r}; "
                       f"have {SA_OCCUPANCY_IMPLS}")
    return name


def _check_guard(value: Any) -> Any:
    from repro.core.guard import GuardPolicy
    if value is not None and not isinstance(value, GuardPolicy):
        raise ValueError(f"guard must be a guard.GuardPolicy or None, "
                         f"got {type(value)}")
    return value


# -----------------------------------------------------------------------
# the session stack: [root, outer, ..., innermost]
# -----------------------------------------------------------------------

def _root() -> SweepSession:
    """The process-wide defaults layer (what the legacy setters mutate).

    The gating-cache size intentionally stays UNSET at the root: the
    LRU in ``sa_gating`` is its own source of truth and sessions scope
    it by save/restore rather than by resolution.
    """
    # bypass __init__ validation: the root is built at import time and
    # validation would import repro.core.backend mid-initialization
    s = object.__new__(SweepSession)
    s.backend = "numpy"
    s.jax_mesh = None
    s.sa_occupancy_impl = "jnp"
    s.gating_cache_size = UNSET
    s.guard = None
    s._active = True  # the root never exits
    s._prev_cache = UNSET
    return s


_LOCAL = threading.local()


def _stack() -> list:
    st = getattr(_LOCAL, "stack", None)
    if st is None:
        st = [_ROOT]
        _LOCAL.stack = st
    return st


_ROOT = _root()


def resolve(field: str) -> Any:
    """Innermost non-UNSET value for ``field`` (walks the stack down to
    the root, which always holds a concrete value for resolvable
    fields)."""
    if field not in _FIELDS:
        raise KeyError(f"unknown session field {field!r}; have {_FIELDS}")
    for layer in reversed(_stack()):
        v = getattr(layer, field)
        if v is not UNSET:
            return v
    return None  # gating_cache_size: root holds UNSET by design


def current() -> dict:
    """Resolved view of the active session state (one value per field)."""
    return {f: resolve(f) for f in _FIELDS}


def set_root(**fields: Any) -> dict:
    """Mutate the root (process-default) layer; returns the previous
    root values. This is what the legacy module-level setters delegate
    to — an active session that pins the same field still shadows the
    new root value until it exits."""
    prev = {}
    for name, value in fields.items():
        if name not in _FIELDS:
            raise KeyError(f"unknown session field {name!r}; "
                           f"have {_FIELDS}")
        if name == "backend":
            _check_backend(value)
        elif name == "sa_occupancy_impl":
            _check_impl(value)
        elif name == "guard":
            _check_guard(value)
        prev[name] = getattr(_ROOT, name)
        setattr(_ROOT, name, value)
    return prev
