"""NPU hardware specifications (paper Table 2) and power-gating circuit
parameters (paper Table 3), plus the roofline constants of the TPU-v5e-class
target chip used by the execution plane.

NPU-A/B/C/D derive from TPUv2/3/4/5p; NPU-E is the projected generation.
Parameters marked inferred in the paper are reproduced as published.
"""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class GatingParams:
    """Per-component power-gating circuit parameters (paper Table 3)."""

    on_off_delay: dict[str, int] = field(default_factory=lambda: {
        "sa_pe": 1, "sa_full": 10, "vu": 2, "hbm": 60, "ici": 60,
        "sram_sleep": 4, "sram_off": 10,
    })
    bet: dict[str, int] = field(default_factory=lambda: {
        "sa_pe": 47, "sa_full": 469, "vu": 32, "hbm": 412, "ici": 459,
        "sram_sleep": 41, "sram_off": 82,
    })
    # leakage power in gated state, as a fraction of active-state static
    # power (paper §6.1 defaults; varied in the sensitivity analysis)
    leak_off_logic: float = 0.03
    leak_sram_sleep: float = 0.25
    leak_sram_off: float = 0.002
    # HBM low-power auto-refresh: PHY standby + DRAM refresh keep burning
    leak_hbm_refresh: float = 0.25
    # VU fine-grained duty pattern: burst length while draining SA output
    vu_burst_cycles: int = 16
    # PE W_on mode: only the weight register powered (our synthesis estimate)
    leak_pe_weight_on: float = 0.15
    detection_window_frac: float = 1 / 3  # idle-detection window = BET/3


@dataclass(frozen=True)
class NPUSpec:
    name: str
    year: int
    tech_nm: int
    freq_mhz: int
    sa_width: int
    n_sa: int
    n_vu: int
    sram_mb: int
    hbm_gbps: float
    hbm_gb: int
    ici_gbps_link: float
    ici_links: int
    # chip power envelope (W). idle_w/tdp_w for A/B validated against
    # published TPUv2/v3 data (paper §4.4: within 9%/5%); C from TPUv4i
    # literature; D/E inferred/projected (*).
    idle_w: float = 60.0
    tdp_w: float = 250.0
    # share of busy-chip energy that is static at typical utilization —
    # rises with newer nodes (paper Fig 3: 30–72%)
    static_frac_busy: float = 0.45
    gating: GatingParams = field(default_factory=GatingParams)

    # ---------- derived ----------
    @property
    def freq_hz(self) -> float:
        return self.freq_mhz * 1e6

    @property
    def sa_flops(self) -> float:
        """Peak MatMul FLOP/s (MAC = 2 FLOPs). Derivation reproduces the
        published peaks: A=46T, B=123T, C=275T, D=459T."""
        return self.sa_width ** 2 * 2 * self.n_sa * self.freq_hz

    @property
    def vu_flops(self) -> float:
        """Peak vector FLOP/s: 8x128 SIMD lanes x 2 (FMA) per VU."""
        return self.n_vu * 8 * 128 * 2 * self.freq_hz

    @property
    def hbm_bw(self) -> float:
        return self.hbm_gbps * 1e9

    @property
    def ici_bw(self) -> float:
        return self.ici_gbps_link * self.ici_links * 1e9

    @property
    def sram_bytes(self) -> int:
        return self.sram_mb * 2 ** 20

    @property
    def sram_segments(self) -> int:
        return self.sram_bytes // SRAM_SEGMENT_BYTES

    def cycles(self, seconds: float) -> float:
        return seconds * self.freq_hz


SRAM_SEGMENT_BYTES = 4 * 1024  # paper: segment size == vector register size

NPUS: dict[str, NPUSpec] = {
    s.name: s for s in [
        NPUSpec("NPU-A", 2017, 16, 700, 128, 2, 4, 32, 600, 16, 62, 4,
                idle_w=53, tdp_w=280, static_frac_busy=0.30),
        NPUSpec("NPU-B", 2018, 16, 940, 128, 4, 4, 32, 900, 32, 70, 4,
                idle_w=84, tdp_w=450, static_frac_busy=0.33),
        NPUSpec("NPU-C", 2020, 7, 1050, 128, 8, 4, 128, 1200, 32, 50, 6,
                idle_w=55, tdp_w=192, static_frac_busy=0.48),
        NPUSpec("NPU-D", 2023, 7, 1750, 128, 8, 6, 128, 2765, 95, 100, 6,
                idle_w=90, tdp_w=500, static_frac_busy=0.52),
        NPUSpec("NPU-E", 2026, 4, 2000, 256, 8, 8, 256, 7400, 192, 150, 6,
                idle_w=130, tdp_w=700, static_frac_busy=0.60),
    ]
}


def get_npu(name: str) -> NPUSpec:
    if name in NPUS:
        return NPUS[name]
    short = f"NPU-{name[-1].upper()}"
    if short in NPUS:
        return NPUS[short]
    raise KeyError(f"unknown NPU {name!r}; have {sorted(NPUS)}")


# SA-width variants memoized by (base spec identity, width): the policy
# engine's derived-trace caches (``trace_times``, ``_batch_ctx``,
# ``_backend_data``) are keyed by spec identity, so the knob axis must
# hand back the SAME variant object on every call or each sweep would
# re-derive and re-transfer its arrays. The value keeps a strong ref to
# the base spec so its id cannot be reused. The variant keeps the base
# *name* — every name-keyed table (power shares, figures) applies
# unchanged, and sweep records carry the width in their own
# ``sa_width`` knob column instead of a mangled spec name.
_SAW_VARIANTS: dict[tuple[int, int], tuple["NPUSpec", "NPUSpec"]] = {}


def with_sa_width(spec: "NPUSpec", width: "int | None") -> "NPUSpec":
    """``spec`` with its systolic-array width replaced (memoized).

    ``None`` or the native width returns ``spec`` itself. Note
    ``sa_flops`` is *derived* (saw² · 2 · n_sa · freq), so widening the
    array also raises peak matmul throughput, exactly like a real
    generation variant would."""
    if width is None or width == spec.sa_width:
        return spec
    hit = _SAW_VARIANTS.get((id(spec), width))
    if hit is not None and hit[0] is spec:
        return hit[1]
    from dataclasses import replace
    var = replace(spec, sa_width=int(width))
    _SAW_VARIANTS[(id(spec), width)] = (spec, var)
    return var


# --------------------------------------------------------------------------
# Execution-plane roofline target (the chip the dry-run "runs" on).
# Constants fixed by the assignment: 197 TFLOP/s bf16, 819 GB/s HBM,
# ~50 GB/s/link ICI.
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class RooflineTarget:
    name: str = "tpu-v5e-class"
    peak_flops: float = 197e12
    hbm_bw: float = 819e9
    ici_bw_link: float = 50e9
    ici_links: int = 4  # 2D torus: +/-x, +/-y
    hbm_gb: float = 16.0
    vmem_mb: float = 128.0 / 8  # ~16 MB VMEM per core


TARGET = RooflineTarget()
