"""Carbon-efficiency analysis (paper §6.6, Figs 24–25).

Operational carbon = electricity x carbon intensity x PUE, with a 60%
duty cycle: during the idle 40% the chip still burns idle power (NoPG) or
the deeply-gated idle power (ReGate). Embodied carbon amortizes over the
device lifespan; the optimal lifespan trades embodied savings (keep chips
longer) against the worsening operational efficiency of old generations.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

from repro.core.hw import NPUS, NPUSpec, get_npu
from repro.core.power import PowerModel

CARBON_INTENSITY = 0.0624   # kgCO2e/kWh (paper: Google 2024 report)
PUE = 1.1
DUTY_CYCLE = 0.60
HOURS_PER_YEAR = 8766.0
# industrial electricity price used by the fleet plane's cost roll-up
# (US EIA industrial average, $/kWh at the meter — PUE applied on top)
USD_PER_KWH = 0.08

# embodied carbon per chip+share of system, kgCO2e (from the cradle-to-grave
# TPU study the paper cites [75]; interpolated for A/B/E)
EMBODIED_KG = {"NPU-A": 90.0, "NPU-B": 120.0, "NPU-C": 150.0,
               "NPU-D": 180.0, "NPU-E": 220.0}


def joules_to_kwh(j: float) -> float:
    return j / 3.6e6


@dataclass(frozen=True)
class CarbonReport:
    workload: str
    npu: str
    policy: str
    operational_kg_per_year: float
    idle_kg_per_year: float

    @property
    def total_kg_per_year(self) -> float:
        return self.operational_kg_per_year + self.idle_kg_per_year


def yearly_carbon(avg_busy_power_w: float, npu: NPUSpec | str,
                  gated_idle: bool, *, duty: float = DUTY_CYCLE,
                  workload: str = "", policy: str = "") -> CarbonReport:
    npu = get_npu(npu) if isinstance(npu, str) else npu
    pm = PowerModel(npu)
    idle_w = pm.idle_chip_gated_w() if gated_idle else pm.idle_chip_w
    busy_kwh = avg_busy_power_w * duty * HOURS_PER_YEAR / 1000.0
    idle_kwh = idle_w * (1 - duty) * HOURS_PER_YEAR / 1000.0
    return CarbonReport(
        workload=workload, npu=npu.name, policy=policy,
        operational_kg_per_year=busy_kwh * PUE * CARBON_INTENSITY,
        idle_kg_per_year=idle_kwh * PUE * CARBON_INTENSITY)


@dataclass(frozen=True)
class FleetRollup:
    """Fleet-level energy accounting for one policy over one scenario
    window: chip joules → facility kWh (×PUE) → kgCO2e and USD."""
    chip_j: float           # sum of per-chip energies (busy + idle)
    chip_kwh: float         # the same energy in kWh (no PUE)
    facility_kwh: float     # at the meter: chip_kwh x PUE
    co2_kg: float           # facility_kwh x CARBON_INTENSITY
    cost_usd: float         # facility_kwh x USD_PER_KWH


def fleet_rollup(total_chip_j: float, *, pue: float = PUE,
                 carbon_intensity: float = CARBON_INTENSITY,
                 usd_per_kwh: float = USD_PER_KWH) -> FleetRollup:
    """Roll a fleet's summed per-chip joules up to facility-level
    kWh / operational CO2 / electricity cost (ISSUE 7 fleet plane).

    The input is the exact sum of per-chip energies the fleet simulator
    accumulated (busy invocation energy + idle/gated-idle energy across
    every chip and epoch); the roll-up is pure arithmetic on that sum,
    so fleet reports reconcile with their per-record energies to float
    round-off (the ≤1e-9 acceptance bound). Embodied carbon is out of
    scope here — ``optimal_lifespan`` covers it.
    """
    if not (math.isfinite(total_chip_j) and total_chip_j >= 0):
        raise ValueError(
            f"total_chip_j must be finite and >= 0, got {total_chip_j}")
    chip_kwh = joules_to_kwh(total_chip_j)
    facility_kwh = chip_kwh * pue
    return FleetRollup(
        chip_j=total_chip_j, chip_kwh=chip_kwh,
        facility_kwh=facility_kwh,
        co2_kg=facility_kwh * carbon_intensity,
        cost_usd=facility_kwh * usd_per_kwh)


def optimal_lifespan(per_year_kg_gen0: float, *, horizon_years: int = 10,
                     efficiency_ratio: float = None,
                     embodied_kg: float = EMBODIED_KG["NPU-D"],
                     max_lifespan: int = 10) -> dict[int, float]:
    """Total carbon over ``horizon_years`` for each candidate lifespan.

    Each upgrade buys a new generation whose operational carbon improves by
    ``efficiency_ratio`` per year (paper: the NPU-D over NPU-C per-year
    ratio). Returns {lifespan_years: total_kg}; min() gives the optimum.
    """
    if efficiency_ratio is None:
        # the paper's Fig 2 trend: newer generations are ~1.5x more
        # energy-efficient per 3-year generation at the WORKLOAD level
        # (larger HBM -> fewer chips, better nodes); chip-level TDP ratios
        # alone do not capture this, so we use the observed ~13%/yr.
        efficiency_ratio = 0.87
    out: dict[int, float] = {}
    for life in range(1, max_lifespan + 1):
        total = 0.0
        year = 0
        gen_start = 0
        while year < horizon_years:
            # chip bought at gen_start has per-year op carbon scaled by
            # the fleet-efficiency of its purchase year
            op = per_year_kg_gen0 * (efficiency_ratio ** gen_start)
            total += op
            year += 1
            if (year - gen_start) >= life and year < horizon_years:
                total += embodied_kg
                gen_start = year
        total += embodied_kg  # the initial purchase
        out[life] = total
    return out


def _d_over_c_yearly_ratio() -> float:
    """Per-year operational-carbon ratio from the NPU-C -> NPU-D
    energy-efficiency trend, measured with the simulator on the paper
    suite (the paper's own assumption for Fig 25). Falls back to the
    industry-typical ~13%/yr improvement if the simulator is unavailable."""
    try:
        from repro.core.opgen import llm_workload
        from repro.core.policies import evaluate
        wls = [llm_workload("llama3-8b", "train", batch=32, n_chips=4,
                            tp=4),
               llm_workload("llama3-8b", "decode", batch=8, n_chips=1)]
        ratio = 1.0
        for wl in wls:
            e_c = evaluate(wl, "NPU-C", "NoPG").total_j
            e_d = evaluate(wl, "NPU-D", "NoPG").total_j
            ratio *= (e_d / e_c) ** (1.0 / len(wls))
        years = NPUS["NPU-D"].year - NPUS["NPU-C"].year
        r = ratio ** (1.0 / years)
        return min(max(r, 0.75), 0.98)
    except Exception:  # pragma: no cover
        return 0.87
