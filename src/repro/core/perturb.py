"""Seeded fault injection + adversarial perturbation (jitter plane, ISSUE 6).

ReGate's HW idle-detection threshold is tuned against *smooth* idle
intervals; datacenter NPUs see bursty collectives, link flaps, and
stragglers. This module injects exactly that variability:

* **Perturbations** — pure trace -> trace transforms on a ``Workload``'s
  op columns, each driven by an explicit ``numpy.random.Generator`` (no
  global seed anywhere): burst arrival compression, link-degradation
  windows (rate cut for a stretch of the op stream), straggler chips
  pacing ring collectives, idle-interval fragmentation (one long gap
  becomes many short ones — the adversary of HW idle detection), and
  cycle-level clock jitter. A perturbed workload is an ordinary
  ``Workload``, so perturbed stacks compile and sweep through the
  batched/jax ``_sweep_kernel`` unchanged.
* **Severity axis** — ``severity_plan`` maps a scalar severity in [0, 1+]
  onto a canonical composition of the five transforms (0 = identity);
  ``perturb_suite`` applies a plan across a workload list with
  deterministic per-workload child generators.
* **Adversarial ISA fuzzing** — ``adversarial_events`` generates
  pathological sparse programs (zero-length gaps, same-cycle bundle
  collisions, gaps exactly at the idle-detection window, window-straddling
  bursts, setpm during an exposed wake); ``differential_fuzz`` runs them
  through ``EventTimeline`` vs the ``VLIWTimeline`` cycle-stepper and
  demands exact equality — the jitter plane's executor hardening harness.

Determinism contract: every entry point takes either a ``Generator`` or
an integer seed; the same seed always reproduces the same perturbed
trace / fuzz corpus bit-for-bit (property-tested in
``tests/test_perturb.py``).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, replace
from typing import Iterable, Optional, Sequence

import numpy as np

from repro.core.isa import (EventTimeline, Instr, PMode, VLIWTimeline,
                            expand_events, merge_events, setpm)
from repro.core.opgen import Op, Workload

# the per-op quantities that carry service time (and hence idle structure)
_CARRIERS = ("flops_sa", "flops_vu", "bytes_hbm", "bytes_ici")


def _require_rng(rng) -> np.random.Generator:
    if not isinstance(rng, np.random.Generator):
        raise TypeError(
            "perturbations require an explicit numpy.random.Generator "
            f"(got {type(rng).__name__}); pass numpy.random.default_rng("
            "seed) — global seeding is not supported")
    return rng


class Perturbation:
    """A pure, seeded transform on a workload's op columns.

    ``apply`` receives a dict of fresh per-op arrays (the ``_CARRIERS``
    plus ``count`` f8 and ``collective`` bool) and the explicit
    ``Generator``; it mutates/replaces columns and returns the dict.
    Implementations must draw from ``rng`` the same number of variates
    regardless of data values, so composed plans stay deterministic.
    """

    def apply(self, cols: dict[str, np.ndarray],
              rng: np.random.Generator) -> dict[str, np.ndarray]:
        raise NotImplementedError


@dataclass(frozen=True)
class BurstCompression(Perturbation):
    """Compress each maximal run of ICI-active ops by ``factor``.

    A run of L active ops keeps its leading ``ceil(L/factor)`` ops
    carrying traffic; the rest go silent and their bytes move onto the
    kept ops (equal per executed instance). Total wire bytes are
    conserved per run; the idle gaps between bursts get longer and the
    bursts denser — the bursty-arrival half of the jitter model.
    ``factor=1`` is the identity.
    """

    factor: float = 2.0

    def __post_init__(self):
        if not (math.isfinite(self.factor) and self.factor >= 1.0):
            raise ValueError(f"factor must be >= 1, got {self.factor}")

    def apply(self, cols, rng):
        _require_rng(rng)
        b, cnt = cols["bytes_ici"], cols["count"]
        active = b > 0
        if self.factor == 1.0 or not active.any():
            return cols
        out = b.copy()
        n = len(b)
        i = 0
        while i < n:
            if not active[i]:
                i += 1
                continue
            j = i
            while j < n and active[j]:
                j += 1
            run = slice(i, j)
            keep = max(1, math.ceil((j - i) / self.factor))
            total = float((b[run] * cnt[run]).sum())
            kept_instances = float(cnt[i:i + keep].sum())
            out[run] = 0.0
            out[i:i + keep] = total / kept_instances
            i = j
        cols["bytes_ici"] = out
        return cols


@dataclass(frozen=True)
class LinkDegradation(Perturbation):
    """Link-flap events: for ``n_events`` windows of the op stream the
    ICI link runs at ``rate`` of nominal, so the same payload takes
    ``1/rate`` longer on the wire (modeled as a bytes_ici stretch over
    the window). Window starts are drawn from ``rng``; windows may
    overlap (stacking multiplicatively, like consecutive flaps)."""

    rate: float = 0.5
    n_events: int = 2
    window_frac: float = 0.10

    def __post_init__(self):
        if not (0.0 < self.rate <= 1.0):
            raise ValueError(f"rate must be in (0, 1], got {self.rate}")
        if self.n_events < 0:
            raise ValueError(f"n_events must be >= 0, got {self.n_events}")
        if not (0.0 < self.window_frac <= 1.0):
            raise ValueError(
                f"window_frac must be in (0, 1], got {self.window_frac}")

    def apply(self, cols, rng):
        _require_rng(rng)
        b = cols["bytes_ici"]
        n = len(b)
        # fixed draw count regardless of data (determinism under
        # composition): always consume n_events starts
        starts = rng.integers(0, max(1, n), size=self.n_events)
        if n == 0 or self.rate == 1.0 or not (b > 0).any():
            return cols
        w = max(1, int(round(self.window_frac * n)))
        scale = np.ones(n)
        for s in starts:
            scale[int(s):int(s) + w] /= self.rate
        cols["bytes_ici"] = b * scale
        return cols


@dataclass(frozen=True)
class Straggler(Perturbation):
    """Straggler chips: ring collectives are paced by their slowest
    participant, so each affected collective op's wire time stretches by
    ``slowdown``. A fraction ``frac`` of the collective ops is hit
    (membership drawn from ``rng`` — a straggler hurts the collectives
    it participates in, not every one)."""

    slowdown: float = 1.5
    frac: float = 1.0

    def __post_init__(self):
        if not (math.isfinite(self.slowdown) and self.slowdown >= 1.0):
            raise ValueError(
                f"slowdown must be >= 1, got {self.slowdown}")
        if not (0.0 <= self.frac <= 1.0):
            raise ValueError(f"frac must be in [0, 1], got {self.frac}")

    def apply(self, cols, rng):
        _require_rng(rng)
        b = cols["bytes_ici"]
        draw = rng.random(len(b))  # fixed draw count (determinism)
        hit = cols["collective"] & (b > 0) & (draw < self.frac)
        cols["bytes_ici"] = np.where(hit, b * self.slowdown, b)
        return cols


@dataclass(frozen=True)
class ClockJitter(Perturbation):
    """Cycle-level clock jitter: each op's duration carriers (SA/VU flops,
    HBM/ICI bytes) all stretch by one multiplicative lognormal factor
    ``exp(sigma * z)`` with ``z ~ N(0, 1)`` clipped to ±4 — component
    ratios within an op are preserved, the op boundary wobbles."""

    sigma: float = 0.02

    def __post_init__(self):
        if not (math.isfinite(self.sigma) and self.sigma >= 0.0):
            raise ValueError(f"sigma must be >= 0, got {self.sigma}")

    def apply(self, cols, rng):
        _require_rng(rng)
        n = len(cols["count"])
        z = np.clip(rng.standard_normal(n), -4.0, 4.0)
        if self.sigma == 0.0:
            return cols
        f = np.exp(self.sigma * z)
        for c in _CARRIERS:
            cols[c] = cols[c] * f
        return cols


@dataclass(frozen=True)
class IdleFragmentation(Perturbation):
    """Fragment op instances: ``count *= factor``, carriers ``/= factor``.

    Totals (flops x count, bytes x count) are conserved, but each
    executed instance — and its within-op idle slack — shrinks by
    ``factor``, so one long idle interval becomes ``factor`` short ones,
    each separately detected and separately paying the wake-up delay.
    This is the adversarial half of the jitter model for HW
    idle-detection: fragmentation drives per-instance slack down toward
    the detection window, where an aggressively small window gates
    every fragment (paying ``delay`` per wake for little gated time)
    while a conservative window skips them. A fraction ``frac`` of the
    multi-instance ops is hit (membership drawn from ``rng``).
    """

    factor: int = 4
    frac: float = 1.0

    def __post_init__(self):
        if int(self.factor) != self.factor or self.factor < 1:
            raise ValueError(
                f"factor must be an integer >= 1, got {self.factor}")
        if not (0.0 <= self.frac <= 1.0):
            raise ValueError(f"frac must be in [0, 1], got {self.frac}")

    def apply(self, cols, rng):
        _require_rng(rng)
        n = len(cols["count"])
        draw = rng.random(n)  # fixed draw count (determinism)
        if self.factor == 1:
            return cols
        busy = np.zeros(n, bool)
        for c in _CARRIERS:
            busy |= cols[c] > 0
        hit = busy & (draw < self.frac)
        f = float(self.factor)
        cols["count"] = np.where(hit, cols["count"] * f, cols["count"])
        for c in _CARRIERS:
            cols[c] = np.where(hit, cols[c] / f, cols[c])
        return cols


def severity_plan(severity: float) -> tuple[Perturbation, ...]:
    """Canonical severity axis for ``sweep.sweep_robustness``.

    Maps a scalar severity (0 = clean, 1 = severe; >1 allowed) onto a
    composition of all four transforms with monotonically harsher
    parameters. Severity 0 returns the empty plan (exact identity).
    """
    if not (math.isfinite(severity) and severity >= 0.0):
        raise ValueError(f"severity must be >= 0, got {severity}")
    if severity == 0.0:
        return ()
    s = float(severity)
    return (
        BurstCompression(factor=1.0 + 2.0 * s),
        LinkDegradation(rate=max(0.2, 1.0 - 0.6 * min(s, 1.0)),
                        n_events=1 + int(3 * s),
                        window_frac=min(1.0, 0.05 + 0.10 * s)),
        Straggler(slowdown=1.0 + 0.5 * s,
                  frac=min(1.0, 0.5 + 0.5 * s)),
        IdleFragmentation(factor=1 + int(round(32.0 * s * s)),
                          frac=min(1.0, 0.3 + 0.4 * s)),
        ClockJitter(sigma=0.05 * s),
    )


def fault_severity(chip_down_frac: float,
                   link_rates=None,
                   pg_fault: bool = False) -> float:
    """Map an epoch's fault state onto the ``severity_plan`` axis.

    The chaos plane (``core.faults``) keys its perturbation severity off
    the injected fault state rather than an exogenous knob: a drained or
    failing fleet runs the survivors hotter and burstier, and degraded
    or down links inject exactly the retransmission/pacing jitter
    ``LinkDegradation``/``Straggler`` model. Monotone in both inputs,
    0 for a clean epoch (so the clean path stays the exact identity),
    and continuous so the severity hint interpolates a scenario's
    ``severity_levels`` ladder sensibly.
    """
    f = float(chip_down_frac)
    if not (math.isfinite(f) and 0.0 <= f <= 1.0):
        raise ValueError(
            f"chip_down_frac must be in [0, 1], got {chip_down_frac}")
    s = 1.5 * f
    if link_rates is not None:
        lr = np.asarray(link_rates, np.float64)
        if lr.size:
            if not np.isfinite(lr).all() or (lr < 0).any() \
                    or (lr > 1).any():
                raise ValueError(
                    "link_rates must be finite and in [0, 1]")
            s += 2.0 * float((1.0 - lr).mean())
            if (lr <= 0.0).any():
                s += 0.5
    if pg_fault:
        s += 0.25
    return min(s, 3.0)


def perturb_workload(wl: Workload,
                     perturbations: Sequence[Perturbation],
                     rng: np.random.Generator, *,
                     name: Optional[str] = None) -> Workload:
    """Apply a perturbation plan to one workload: pure trace -> trace.

    Returns a NEW ``Workload`` (ops rebuilt from the transformed
    columns; ``matmul_dims``/``sram_demand`` structure kept) so the
    identity-cached compile/stack/sweep pipeline treats it as a
    distinct trace. The empty plan returns a renamed copy with
    bit-identical columns.
    """
    _require_rng(rng)
    cols = {
        "flops_sa": np.array([o.flops_sa for o in wl.ops], np.float64),
        "flops_vu": np.array([o.flops_vu for o in wl.ops], np.float64),
        "bytes_hbm": np.array([o.bytes_hbm for o in wl.ops], np.float64),
        "bytes_ici": np.array([o.bytes_ici for o in wl.ops], np.float64),
        "count": np.array([o.count for o in wl.ops], np.float64),
        "collective": np.array([o.collective for o in wl.ops], bool),
    }
    for p in perturbations:
        cols = p.apply(cols, rng)
    # direct positional construction — dataclasses.replace costs ~10x
    # per op and dominates suite-scale perturbation otherwise
    fs, fv, bh, bi = (cols["flops_sa"], cols["flops_vu"],
                      cols["bytes_hbm"], cols["bytes_ici"])
    ct = np.rint(cols["count"]).astype(np.int64)
    ops = tuple(
        Op(op.name, float(fs[i]), float(fv[i]), float(bh[i]),
           float(bi[i]), op.sram_demand, op.matmul_dims, int(ct[i]),
           op.collective)
        for i, op in enumerate(wl.ops))
    return Workload(name if name is not None else f"{wl.name}~jit",
                    wl.kind, ops, n_chips=wl.n_chips, note=wl.note)


def perturb_suite(workloads: Sequence[Workload],
                  perturbations: Sequence[Perturbation], *,
                  seed: int, stream: int = 0,
                  names: Optional[Sequence[str]] = None) \
        -> list[Workload]:
    """Apply one plan across a workload list.

    Each workload gets its own child generator derived from the seed
    tuple ``(seed, stream, index)`` (``numpy`` SeedSequence spawning),
    so results are independent of list length and order-stable —
    deleting workload 3 does not change workload 4's perturbation.
    ``stream`` separates severity levels (or repeats) sharing a seed.
    """
    out = []
    for i, wl in enumerate(workloads):
        rng = np.random.default_rng((int(seed), int(stream), i))
        nm = names[i] if names is not None else None
        out.append(perturb_workload(wl, perturbations, rng, name=nm))
    return out


def severity_variants(workloads: Sequence[Workload],
                      severities: Sequence[float], *,
                      seed: int) -> dict[float, list[Workload]]:
    """Pre-built trace variants per severity level — the fleet plane's
    traffic-variability hook (ISSUE 7, the ROADMAP follow-up that lets
    fleet scenarios draw their variability from the same perturbation
    plans as the jitter plane).

    For each level ``severities[si]`` the whole workload list is run
    through ``severity_plan(level)`` with ``stream=si`` (children seeded
    ``(seed, si, workload_index)``), so a fleet epoch can select its
    congestion level by indexing the returned dict instead of
    re-perturbing per epoch — the variant *objects* are stable, which
    keeps the identity-cached stack/compile pipeline warm across
    epochs. Severity 0 yields renamed but bit-identical traces; every
    variant preserves op counts (stable stack shapes → the jitted sweep
    program is reused across all levels).
    """
    out: dict[float, list[Workload]] = {}
    for si, sev in enumerate(severities):
        sev = float(sev)
        if sev in out:
            raise ValueError(f"duplicate severity level {sev}")
        out[sev] = perturb_suite(
            list(workloads), severity_plan(sev), seed=seed, stream=si,
            names=[f"{wl.name}@sev{si}" for wl in workloads])
    return out


# --------------------------------------------------------------------------
# Adversarial ISA programs + differential fuzz harness
# --------------------------------------------------------------------------

# the fuzz machine: 1 SA (PE-granular gating), 2 VUs, HBM + ICI movers
FUZZ_UNITS = (("sa0", "sa"), ("vu0", "vu"), ("vu1", "vu"),
              ("dma0", "hbm"), ("ici0", "ici"))
FUZZ_KW = dict(n_sa=1, n_vu=2,
               extra_units={"dma0": "hbm", "ici0": "ici"},
               delay_keys={"sa": "sa_pe"},
               initial_modes={"vu1": PMode.ON})


def adversarial_events(rng: np.random.Generator, *, n_events: int = 40,
                       npu: str = "NPU-D") \
        -> tuple[list[tuple[int, dict[str, Instr]]], int]:
    """One pathological sparse program for the differential harness.

    Stresses every closed-form edge of ``EventTimeline._gap``:

    * zero-length gaps (back-to-back cycles) and same-cycle collisions
      (raw duplicate cycles, canonicalized via ``merge_events``);
    * gaps of exactly ``window - 1`` / ``window`` / ``window + 1`` per FU
      kind (the idle-detection boundary) and window-straddling bursts
      (repeated sub-window gaps, then one at the boundary);
    * wake-delay-sized latencies and setpm issued 1..delay-1 cycles after
      a wake — i.e. during the exposed wake window;
    * setpm on every FU family, both modes, random bitmaps.

    Returns ``(events, horizon)`` with ``events`` already canonical.
    """
    _require_rng(rng)
    probe = VLIWTimeline(npu=npu, **FUZZ_KW)
    kinds = sorted({k for _, k in FUZZ_UNITS})
    win = {k: probe._window(k) for k in kinds}
    dly = {k: probe._delay(k) for k in kinds}
    raw: list[tuple[int, dict[str, Instr]]] = []
    c = 0
    for _ in range(n_events):
        kind = kinds[int(rng.integers(0, len(kinds)))]
        w, d = win[kind], dly[kind]
        # pathological gap menu: collisions (0), zero-length gaps (1),
        # the exact detection boundary, straddlers, wake-delay offsets
        gaps = (0, 1, 1, 2, w - 1, w, w + 1, max(1, w - 1), d,
                max(1, d - 1), d + 1, w + d, 3 * w + 7)
        c += int(gaps[int(rng.integers(0, len(gaps)))])
        b: dict[str, Instr] = {}
        for u, uk in FUZZ_UNITS:
            if rng.random() < 0.35:
                lat = (1, 2, 5, win[uk], dly[uk], dly[uk] + 1,
                       30)[int(rng.integers(0, 7))]
                b[u] = Instr("op", u, max(1, int(lat)))
        if rng.random() < 0.35:
            k2 = kinds[int(rng.integers(0, len(kinds)))]
            b["misc"] = setpm(
                k2, int(rng.integers(1, 4)),
                PMode.ON if rng.random() < 0.5 else PMode.OFF)
        if b:
            raw.append((c, b))
        if rng.random() < 0.25 and b:
            # setpm inside the exposed wake of whatever just dispatched:
            # 1..delay-1 cycles after the bundle
            k2 = kinds[int(rng.integers(0, len(kinds)))]
            off = 1 + int(rng.integers(0, max(1, dly[k2] - 1)))
            raw.append((c + off, {"misc": setpm(
                k2, int(rng.integers(1, 4)),
                PMode.OFF if rng.random() < 0.5 else PMode.ON)}))
    events = merge_events(raw)
    last = events[-1][0] if events else 0
    horizon = last + int(rng.integers(0, 2 * max(win.values())))
    return events, horizon


def _exec_mismatch(a, b) -> Optional[str]:
    if a.cycles != b.cycles:
        return f"cycles {a.cycles} != {b.cycles}"
    if a.stall_cycles != b.stall_cycles:
        return f"stalls {a.stall_cycles} != {b.stall_cycles}"
    if a.setpm_executed != b.setpm_executed:
        return f"setpm {a.setpm_executed} != {b.setpm_executed}"
    for fld in ("fu_on_cycles", "fu_gated_cycles", "wake_events"):
        if getattr(a, fld) != getattr(b, fld):
            return f"{fld} {getattr(a, fld)} != {getattr(b, fld)}"
    return None


def differential_fuzz(n_programs: int = 200, seed: int = 0, *,
                      n_events: int = 40, npu: str = "NPU-D") -> dict:
    """Differential fuzz: ``EventTimeline`` vs the ``VLIWTimeline``
    cycle-stepper on ``n_programs`` adversarial programs, each run with
    hardware auto-gating off and on.

    Raises ``AssertionError`` naming the seed / program index / first
    divergent counter on any mismatch (ExecResult counters are integers,
    so the check is exact). Returns corpus stats on success.
    """
    rng = np.random.default_rng(seed)
    stats = {"programs": 0, "runs": 0, "events": 0, "cycles": 0,
             "mismatches": 0, "seed": seed}
    for p in range(n_programs):
        events, horizon = adversarial_events(rng, n_events=n_events,
                                             npu=npu)
        stats["programs"] += 1
        stats["events"] += len(events)
        for hw_auto in (False, True):
            kw = dict(FUZZ_KW, hw_auto_gating=hw_auto,
                      initial_modes=dict(FUZZ_KW["initial_modes"]))
            ref = VLIWTimeline(npu=npu, **kw).run(
                expand_events(events, horizon))
            got = EventTimeline(npu=npu, **kw).run(events,
                                                   horizon=horizon)
            diff = _exec_mismatch(ref, got)
            if diff is not None:
                stats["mismatches"] += 1
                raise AssertionError(
                    f"executor divergence: seed={seed} program={p} "
                    f"hw_auto={hw_auto}: {diff}")
            stats["runs"] += 1
            stats["cycles"] += ref.cycles
    return stats


def main(argv=None) -> int:
    """CLI smoke entry: ``python -m repro.core.perturb --fuzz N``."""
    import argparse
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fuzz", type=int, default=80,
                    help="number of adversarial programs")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--events", type=int, default=40,
                    help="events per program")
    args = ap.parse_args(argv)
    stats = differential_fuzz(args.fuzz, args.seed, n_events=args.events)
    print(f"fuzz ok: {stats['programs']} programs, {stats['runs']} runs, "
          f"{stats['events']} events, {stats['cycles']} ref cycles, "
          f"0 mismatches (seed={stats['seed']})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
