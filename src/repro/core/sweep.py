"""Batched design-space sweeps over the columnar policy engine.

``sweep`` evaluates the cross product ``workloads × npus × policies ×
knob_grid`` and returns a flat record table (one dict per cell) — the
common substrate for the figure benchmarks (Figs 17–23), the SLO
configuration search, and CompPow-style what-if exploration. The whole
grid runs through ``policies.evaluate_batch``: the workload traces are
stacked into one ragged super-trace, per-(trace, npu) service times are
reused across the policy/knob axes, and the records fall out of a
handful of segmented array passes — no per-cell Python round-trips.

``sweep_reference`` keeps the original one-``evaluate``-call-per-cell
loop as the oracle; ``benchmarks/perf_sweep.py`` gates the batched path
≥10× faster with record-for-record ≤1e-9 relative equivalence.

``sweep_grid`` crosses the §6.5 sensitivity axes (wake-delay scale,
gated leakage ratios, SRAM sleep/off leakage, SA width) into a single
fine-grid ``evaluate_batch`` call; with ``backend="jax"`` the grid runs
as one jitted float64 program reused across NPU generations
(``benchmarks/perf_sweep_jax.py`` gates ≥3× over the numpy batched path
on a ≥100k-cell grid, record-for-record ≤1e-9).

Records are emitted in deterministic order: workload-major, then NPU,
then policy, then knob index (both paths, byte-identical ordering).
"""
from __future__ import annotations

from typing import Iterable, Optional, Sequence

from repro.core.hw import NPUSpec, get_npu
from repro.core.opgen import Workload, compile_trace
from repro.core.policies import (POLICIES, BatchResult, EnergyReport,
                                 PolicyKnobs, evaluate, evaluate_batch)
from repro.core.power import COMPONENTS


def _flatten(rep: EnergyReport, knobs: PolicyKnobs, knob_idx: int,
             npu: NPUSpec) -> dict:
    rec = {
        "workload": rep.workload,
        "npu": rep.npu,
        "policy": rep.policy,
        "knob_idx": knob_idx,
        "delay_scale": knobs.delay_scale,
        "leak_off_logic": knobs.leak_off_logic,
        "leak_sram_sleep": knobs.leak_sram_sleep,
        "leak_sram_off": knobs.leak_sram_off,
        "sa_width": knobs.sa_width,
        "runtime_s": rep.runtime_s,
        "total_j": rep.total_j,
        "static_total_j": sum(rep.static_j.values()),
        "dynamic_total_j": sum(rep.dynamic_j.values()),
        "static_frac": rep.static_frac,
        "avg_power_w": rep.avg_power_w,
        "setpm_count": rep.setpm_count,
        "setpm_per_1k_cycles": rep.setpm_per_1k_cycles(npu),
        "wake_events": sum(rep.wake_events.values()),
    }
    for c in COMPONENTS:
        rec[f"static_j_{c}"] = rep.static_j[c]
        rec[f"dynamic_j_{c}"] = rep.dynamic_j[c]
    return rec


def sweep(workloads: Sequence[Workload] | Workload,
          npus: Iterable[NPUSpec | str] = ("NPU-D",),
          policies: Iterable[str] = POLICIES,
          knob_grid: Optional[Sequence[PolicyKnobs]] = None,
          backend: Optional[str] = None) -> list[dict]:
    """Evaluate every (workload, npu, policy, knobs) cell in one batched
    pass; flat records. ``backend`` selects the array substrate
    (``"numpy"`` / ``"jax"`` / ``None`` for the session default)."""
    if isinstance(workloads, Workload):
        workloads = [workloads]
    if knob_grid is None:
        knob_grid = [PolicyKnobs()]
    npu_specs = [get_npu(n) if isinstance(n, str) else n for n in npus]
    return evaluate_batch(workloads, npu_specs, tuple(policies),
                          tuple(knob_grid), backend=backend).records()


def knob_product(delay_scale: Sequence[float] = (1.0,),
                 leak_off_logic: Sequence[Optional[float]] = (None,),
                 leak_sram_sleep: Sequence[Optional[float]] = (None,),
                 leak_sram_off: Sequence[Optional[float]] = (None,),
                 sa_width: Sequence[Optional[int]] = (None,)) \
        -> list[PolicyKnobs]:
    """Cross product of the §6.5 sensitivity knobs into a flat knob
    grid: ``sa_width`` outermost, then delay-major as before
    (``delay_scale``, ``leak_off_logic``, ``leak_sram_sleep``,
    ``leak_sram_off`` innermost). ``None`` leaves a knob at the per-NPU
    Table 3 default (``sa_width=None`` → the generation's native
    width)."""
    return [PolicyKnobs(delay_scale=d, leak_off_logic=lo,
                        leak_sram_sleep=ls, leak_sram_off=lf,
                        sa_width=sw)
            for sw in sa_width for d in delay_scale
            for lo in leak_off_logic for ls in leak_sram_sleep
            for lf in leak_sram_off]


def sweep_grid(workloads: Sequence[Workload] | Workload,
               npus: Iterable[NPUSpec | str] = ("NPU-D",),
               policies: Iterable[str] = POLICIES, *,
               delay_scale: Sequence[float] = (1.0,),
               leak_off_logic: Sequence[Optional[float]] = (None,),
               leak_sram_sleep: Sequence[Optional[float]] = (None,),
               leak_sram_off: Sequence[Optional[float]] = (None,),
               sa_width: Sequence[Optional[int]] = (None,),
               backend: Optional[str] = None, jax_mesh=None,
               as_records: bool = True):
    """Fine-grid design-space sweep: the §6.5 sensitivity axes crossed
    into one ``evaluate_batch`` call (CompPow-style component × knob
    exploration at 100k-cell scale).

    All five axes (``sa_width × delay_scale × leak_off_logic ×
    leak_sram_sleep × leak_sram_off``) become the knob grid via
    ``knob_product`` — since ISSUE 5, ``sa_width`` is a real knob
    (``PolicyKnobs.sa_width``) rather than a set of renamed NPU
    variants: records carry it in their ``sa_width`` column with the
    NPU name untouched, and the jax kernel traces it, so a width axis
    costs extra vmapped (width, delay) pairs, not extra compiled
    programs.

    On the jax backend the whole grid runs as one jitted program that
    compiles once and is reused across every NPU generation (and across
    repeated calls with the same stack/grid shape). ``jax_mesh``
    selects the multi-device path: a ``("wl",)`` mesh shards the
    stacked op axis under GSPMD, while a mesh with a ``"knob"`` axis
    (optionally ``("wl", "knob")``) runs the explicit ``shard_map``
    program that shards the knob/pair axes too — the right shape for
    small-suite, huge-grid sweeps. Returns flat records, or the
    ``BatchResult`` cube when ``as_records=False``.
    """
    if isinstance(workloads, Workload):
        workloads = [workloads]
    if sa_width is None:  # the pre-ISSUE-5 "no width axis" spelling
        sa_width = (None,)
    knob_grid = knob_product(delay_scale, leak_off_logic,
                             leak_sram_sleep, leak_sram_off, sa_width)
    npu_specs = [get_npu(n) if isinstance(n, str) else n for n in npus]
    res: BatchResult = evaluate_batch(
        workloads, npu_specs, tuple(policies), tuple(knob_grid),
        backend=backend, jax_mesh=jax_mesh)
    return res.records() if as_records else res


def sweep_reference(workloads: Sequence[Workload] | Workload,
                    npus: Iterable[NPUSpec | str] = ("NPU-D",),
                    policies: Iterable[str] = POLICIES,
                    knob_grid: Optional[Sequence[PolicyKnobs]] = None) \
        -> list[dict]:
    """The original loop sweep — one ``evaluate`` round-trip per cell.

    Kept as the oracle for the batched path: same records, same
    deterministic ordering; ``tests/test_sweep_batch.py`` holds the two
    to ≤1e-9 relative on every record field.
    """
    if isinstance(workloads, Workload):
        workloads = [workloads]
    if knob_grid is None:
        knob_grid = [PolicyKnobs()]
    npu_specs = [get_npu(n) if isinstance(n, str) else n for n in npus]
    records: list[dict] = []
    for wl in workloads:
        compile_trace(wl)  # compile once up front (cached by identity)
        for npu in npu_specs:
            for policy in policies:
                for ki, knobs in enumerate(knob_grid):
                    rep = evaluate(wl, npu, policy, knobs)
                    records.append(_flatten(rep, knobs, ki, npu))
    return records


def sweep_program_plane(workloads: Sequence[Workload] | Workload,
                        npus: Iterable[NPUSpec | str] = ("NPU-D",)) \
        -> list[dict]:
    """Cross-validation sweep: lower every (workload, npu) cell onto the
    program plane (``repro.core.lowering``), execute it on the
    event-driven ISA executor, and emit one flat record per cell
    comparing gated-cycle fractions and setpm counts against the
    closed-form ``ReGate-Full`` evaluation. Record order is
    workload-major, then NPU (same convention as ``sweep``)."""
    from repro.core.lowering import crossval_record
    if isinstance(workloads, Workload):
        workloads = [workloads]
    npu_specs = [get_npu(n) if isinstance(n, str) else n for n in npus]
    return [crossval_record(wl, npu)
            for wl in workloads for npu in npu_specs]


def with_savings(records: list[dict], baseline: str = "NoPG") -> list[dict]:
    """Attach ``savings`` (1 - total_j/baseline_total_j) to each record,
    in one bulk pass over the batched record table.

    A record's baseline is the ``baseline``-policy row of the same
    (workload, npu, knob_idx) cell. When that exact cell is missing,
    the un-gated ``NoPG`` baseline may fall back to the single knob
    point it was evaluated at — e.g. a knob grid that only evaluates
    the baseline at knob 0, which is sound because NoPG never gates
    and so no *gating* knob can change its energy. ``sa_width`` is the
    exception (it moves service times and therefore NoPG energy too),
    so the fallback additionally requires the record's ``sa_width`` to
    match the baseline row's — a width-mismatched denominator would be
    silently wrong, like any gating baseline. Gating baselines get no
    fallback at all. Baseline rows get savings 0.0; cells with no
    resolvable baseline get savings None.
    """
    def eff_width(r):
        """Record's effective SA width: ``None`` (native) and the
        explicitly spelled native width are the same configuration."""
        w = r.get("sa_width")
        if w is not None:
            return w
        try:
            return get_npu(r["npu"]).sa_width
        except KeyError:  # ad-hoc spec name: compare the raw value
            return None

    base: dict[tuple, float] = {}
    per_cell: dict[tuple, list[tuple]] = {}
    for r in records:
        if r["policy"] == baseline:
            base[(r["workload"], r["npu"], r["knob_idx"])] = r["total_j"]
            per_cell.setdefault((r["workload"], r["npu"]), []) \
                .append((r["total_j"], eff_width(r)))
    fallback = {k: v[0] for k, v in per_cell.items()
                if len(v) == 1} if baseline == "NoPG" else {}
    out = []
    for r in records:
        b = base.get((r["workload"], r["npu"], r["knob_idx"]))
        if b is None:
            fb = fallback.get((r["workload"], r["npu"]))
            if fb is not None and fb[1] == eff_width(r):
                b = fb[0]
        r = dict(r)
        r["savings"] = None if b is None else 1.0 - r["total_j"] / b
        out.append(r)
    return out


def group_by(records: list[dict], *keys: str) -> dict[tuple, list[dict]]:
    """Group records by the given columns, preserving record order."""
    out: dict[tuple, list[dict]] = {}
    for r in records:
        out.setdefault(tuple(r[k] for k in keys), []).append(r)
    return out
