"""Batched design-space sweeps over the columnar policy engine.

``sweep`` evaluates the cross product ``workloads × npus × policies ×
knob_grid`` and returns a flat record table (one dict per cell) — the
common substrate for the figure benchmarks (Figs 17–23), the SLO
configuration search, and CompPow-style what-if exploration. The whole
grid runs through ``policies.evaluate_batch``: the workload traces are
stacked into one ragged super-trace, per-(trace, npu) service times are
reused across the policy/knob axes, and the records fall out of a
handful of segmented array passes — no per-cell Python round-trips.

``sweep_reference`` keeps the original one-``evaluate``-call-per-cell
loop as the oracle; ``benchmarks/perf_sweep.py`` gates the batched path
≥10× faster with record-for-record ≤1e-9 relative equivalence.

``sweep_grid`` crosses the §6.5 sensitivity axes (wake-delay scale,
gated leakage ratios, SRAM sleep/off leakage, SA width) into a single
fine-grid ``evaluate_batch`` call; with ``backend="jax"`` the grid runs
as one jitted float64 program reused across NPU generations
(``benchmarks/perf_sweep_jax.py`` gates ≥3× over the numpy batched path
on a ≥100k-cell grid, record-for-record ≤1e-9).

Records are emitted in deterministic order: workload-major, then NPU,
then policy, then knob index (both paths, byte-identical ordering).
"""
from __future__ import annotations

from typing import Iterable, Optional, Sequence

import numpy as np

from repro.core.hw import NPUSpec, get_npu
from repro.core.opgen import Workload, compile_trace
from repro.core.policies import (POLICIES, BatchResult, EnergyReport,
                                 KnobGrid, PolicyKnobs, evaluate,
                                 evaluate_batch, knob_columns)
from repro.core.guard import (GuardPolicy,  # noqa: F401  (re-export)
                              GuardReport)
from repro.core.power import COMPONENTS
from repro.core.session import SweepSession  # noqa: F401  (re-export)


def _flatten(rep: EnergyReport, knobs: PolicyKnobs, knob_idx: int,
             npu: NPUSpec) -> dict:
    rec = {
        "workload": rep.workload,
        "npu": rep.npu,
        "policy": rep.policy,
        # every knob column, unconditionally (KnobGrid.columns()):
        # record consumers (with_savings / group_by) key on these
        **knob_columns(knobs, knob_idx),
        "runtime_s": rep.runtime_s,
        "total_j": rep.total_j,
        "static_total_j": sum(rep.static_j.values()),
        "dynamic_total_j": sum(rep.dynamic_j.values()),
        "static_frac": rep.static_frac,
        "avg_power_w": rep.avg_power_w,
        "setpm_count": rep.setpm_count,
        "setpm_per_1k_cycles": rep.setpm_per_1k_cycles(npu),
        "wake_events": sum(rep.wake_events.values()),
    }
    for c in COMPONENTS:
        rec[f"static_j_{c}"] = rep.static_j[c]
        rec[f"dynamic_j_{c}"] = rep.dynamic_j[c]
    return rec


def sweep(workloads: Sequence[Workload] | Workload,
          npus: Iterable[NPUSpec | str] = ("NPU-D",),
          policies: Iterable[str] = POLICIES,
          knob_grid: Optional[Sequence[PolicyKnobs]] = None,
          backend: Optional[str] = None) -> list[dict]:
    """Evaluate every (workload, npu, policy, knobs) cell in one batched
    pass; flat records. ``backend`` selects the array substrate
    (``"numpy"`` / ``"jax"`` / ``None`` for the session default)."""
    if isinstance(workloads, Workload):
        workloads = [workloads]
    if knob_grid is None:
        knob_grid = [PolicyKnobs()]
    npu_specs = [get_npu(n) if isinstance(n, str) else n for n in npus]
    return evaluate_batch(workloads, npu_specs, tuple(policies),
                          tuple(knob_grid), backend=backend).records()


def knob_product(delay_scale: Sequence[float] = (1.0,),
                 leak_off_logic: Sequence[Optional[float]] = (None,),
                 leak_sram_sleep: Sequence[Optional[float]] = (None,),
                 leak_sram_off: Sequence[Optional[float]] = (None,),
                 sa_width: Sequence[Optional[int]] = (None,),
                 window_scale: Sequence[float] = (1.0,)) \
        -> list[PolicyKnobs]:
    """Thin shim over ``KnobGrid(...).product()`` (the kwargs spelling
    predates ISSUE 7): crosses the §6.5 sensitivity knobs into a flat
    knob grid — ``sa_width`` outermost, then ``window_scale``, then
    delay-major as before (``delay_scale``, ``leak_off_logic``,
    ``leak_sram_sleep``, ``leak_sram_off`` innermost). ``None`` leaves
    a knob at the per-NPU Table 3 default (``sa_width=None`` → the
    generation's native width)."""
    return KnobGrid(delay_scale=delay_scale,
                    leak_off_logic=leak_off_logic,
                    leak_sram_sleep=leak_sram_sleep,
                    leak_sram_off=leak_sram_off, sa_width=sa_width,
                    window_scale=window_scale).product()


def sweep_grid(workloads: Sequence[Workload] | Workload,
               npus: Iterable[NPUSpec | str] = ("NPU-D",),
               policies: Iterable[str] = POLICIES, *,
               grid: Optional[KnobGrid] = None,
               delay_scale: Sequence[float] = (1.0,),
               leak_off_logic: Sequence[Optional[float]] = (None,),
               leak_sram_sleep: Sequence[Optional[float]] = (None,),
               leak_sram_off: Sequence[Optional[float]] = (None,),
               sa_width: Sequence[Optional[int]] = (None,),
               window_scale: Sequence[float] = (1.0,),
               backend: Optional[str] = None, jax_mesh=None,
               as_records: bool = True):
    """Fine-grid design-space sweep: the §6.5 sensitivity axes crossed
    into one ``evaluate_batch`` call (CompPow-style component × knob
    exploration at 100k-cell scale).

    All six axes (``sa_width × window_scale × delay_scale ×
    leak_off_logic × leak_sram_sleep × leak_sram_off``) become the
    knob grid via ``knob_product`` — since ISSUE 5, ``sa_width`` is a real knob
    (``PolicyKnobs.sa_width``) rather than a set of renamed NPU
    variants: records carry it in their ``sa_width`` column with the
    NPU name untouched, and the jax kernel traces it, so a width axis
    costs extra vmapped (width, delay) pairs, not extra compiled
    programs.

    On the jax backend the whole grid runs as one jitted program that
    compiles once and is reused across every NPU generation (and across
    repeated calls with the same stack/grid shape). ``jax_mesh``
    selects the multi-device path: a ``("wl",)`` mesh shards the
    stacked op axis under GSPMD, while a mesh with a ``"knob"`` axis
    (optionally ``("wl", "knob")``) runs the explicit ``shard_map``
    program that shards the knob/pair axes too — the right shape for
    small-suite, huge-grid sweeps. Returns flat records, or the
    ``BatchResult`` cube when ``as_records=False``.

    Since ISSUE 7 the axes are one object: pass ``grid=KnobGrid(...)``.
    The six axis kwargs remain as a thin shim that constructs the same
    ``KnobGrid`` (identical knob ordering and records); mixing ``grid``
    with axis kwargs is rejected.
    """
    if isinstance(workloads, Workload):
        workloads = [workloads]
    if sa_width is None:  # the pre-ISSUE-5 "no width axis" spelling
        sa_width = (None,)
    if grid is None:
        grid = KnobGrid(delay_scale=delay_scale,
                        leak_off_logic=leak_off_logic,
                        leak_sram_sleep=leak_sram_sleep,
                        leak_sram_off=leak_sram_off, sa_width=sa_width,
                        window_scale=window_scale)
    else:
        if not isinstance(grid, KnobGrid):
            raise TypeError(f"grid must be a KnobGrid, got "
                            f"{type(grid).__name__}")
        kwargs_grid = KnobGrid(delay_scale=delay_scale,
                               leak_off_logic=leak_off_logic,
                               leak_sram_sleep=leak_sram_sleep,
                               leak_sram_off=leak_sram_off,
                               sa_width=sa_width,
                               window_scale=window_scale)
        if kwargs_grid != KnobGrid():
            raise ValueError(
                "pass the knob axes either as grid=KnobGrid(...) or as "
                "the legacy axis kwargs, not both")
    npu_specs = [get_npu(n) if isinstance(n, str) else n for n in npus]
    res: BatchResult = evaluate_batch(
        workloads, npu_specs, tuple(policies), grid,
        backend=backend, jax_mesh=jax_mesh)
    return res.records() if as_records else res


def sweep_robustness(workloads: Sequence[Workload] | Workload,
                     npus: Iterable[NPUSpec | str] = ("NPU-D",),
                     policies: Iterable[str] = ("ReGate-HW",), *,
                     severities: Sequence[float] = (0.0, 0.5, 1.0),
                     threshold_scales: Sequence[float] =
                     (0.25, 0.5, 1.0, 2.0, 4.0),
                     seed: int = 0, slo_relax: float = 1.1,
                     topology: bool = True,
                     backend: Optional[str] = None,
                     jax_mesh=None) -> dict:
    """Idle-detection robustness sweep (jitter plane, ISSUE 6).

    Crosses HW idle-detection thresholds (``threshold_scales``, the
    ``window_scale`` knob — it scales ONLY the idle-detection window,
    the paper's BET/3 design point, leaving BETs and wake delays at
    their Table 3 values, so aggressive and conservative detection
    genuinely trade off and a clean-tuned threshold can regret under
    jitter) against perturbation severities (``repro.core.perturb.severity_plan``
    applied with deterministic per-(severity, workload) generators seeded
    from ``seed``) in ONE ``sweep_grid``-style ``evaluate_batch`` pass:
    every (severity x workload) variant is stacked into the super-trace,
    with ``topology=True`` first lowering collectives onto their ring /
    2-D-mesh step schedules (``repro.core.ici_topology``).

    Reports, per (npu, policy, severity):

    * ``worst_exposed_wake_s`` — worst over workloads of the exposed-wake
      overhead (runtime minus the same cell's NoPG runtime) at the
      *deployed* threshold, i.e. the one that minimizes clean-trace
      energy per workload; ``worst_exposed_wake_any_s`` maxes over the
      whole threshold axis too.
    * ``slo_violation_rate`` — via ``slo.runtime_violation_rate``:
      fraction of workloads whose perturbed runtime at the deployed
      threshold exceeds ``slo_relax`` x its clean runtime.
    * ``max_regret_frac`` / ``mean_regret_frac`` — *SLO-constrained
      energy regret* of the clean-tuned threshold under jitter. Total
      energy is monotone in the detection window (per-PE SA gating has
      a 1-cycle wake, so a smaller window always saves energy), which
      pins the clean optimum at the most aggressive threshold; what
      jitter breaks is its *runtime*: fragmented idle makes the
      aggressive window gate every shard of an interval and pay the
      exposed wake delay each time. So regret is measured over the
      SLO-feasible set: if the deployed threshold still meets
      ``slo_relax`` x its clean runtime it is kept (regret relative to
      the unconstrained per-severity optimum — 0 when they coincide);
      once jitter pushes it past the SLO the operator must re-tune to
      the cheapest *feasible* threshold (or the least-violating one if
      none is feasible), and the regret is that configuration's energy
      over the unconstrained optimum — the energy given up to stay
      within SLO. Severity 0 has zero regret by construction.

    Returns ``{"records", "summary", "severities", "threshold_scales"}``
    where ``records`` has one dict per (workload, npu, policy, severity,
    threshold) cell.
    """
    from repro.core.ici_topology import lower_collectives
    from repro.core.perturb import perturb_suite, severity_plan
    from repro.core.slo import retune_knobs, runtime_violation_rate
    if isinstance(workloads, Workload):
        workloads = [workloads]
    workloads = list(workloads)
    severities = [float(s) for s in severities]
    threshold_scales = [float(t) for t in threshold_scales]
    if any(t <= 0 or not np.isfinite(t) for t in threshold_scales):
        raise ValueError(
            f"threshold_scales must be finite and > 0: {threshold_scales}")
    base = [lower_collectives(wl) if topology else wl for wl in workloads]
    w_n, s_n, t_n = len(base), len(severities), len(threshold_scales)
    pol_in = tuple(policies)
    pols = pol_in if "NoPG" in pol_in else pol_in + ("NoPG",)
    npu_specs = [get_npu(n) if isinstance(n, str) else n for n in npus]

    variants: list[Workload] = []
    for si, sev in enumerate(severities):
        variants.extend(perturb_suite(
            base, severity_plan(sev), seed=seed, stream=si,
            names=[f"{wl.name}@s{si}" for wl in base]))
    thr_grid = KnobGrid(window_scale=threshold_scales)
    res: BatchResult = evaluate_batch(
        variants, npu_specs, pols, thr_grid,
        backend=backend, jax_mesh=jax_mesh)
    thr_knobs = thr_grid.product()

    rt = res.runtime_s                       # (S*W, A, P, T)
    tot = np.zeros_like(rt)
    for c in COMPONENTS:
        tot += res.static_j[c] + res.dynamic_j[c]
    nopg_pi = pols.index("NoPG")
    exposed = np.maximum(0.0, rt - rt[:, :, nopg_pi:nopg_pi + 1, :])

    records: list[dict] = []
    summary: list[dict] = []
    for ai, npu in enumerate(npu_specs):
        for pi, policy in enumerate(pol_in):
            # deployed threshold: clean-trace (severity index 0) optimum
            kstar = np.argmin(tot[:w_n, ai, pi, :], axis=1)   # (W,)
            wi_ix = np.arange(w_n)
            for si, sev in enumerate(severities):
                rows = slice(si * w_n, (si + 1) * w_n)
                e_s = tot[rows, ai, pi, :]                     # (W, T)
                r_s = rt[rows, ai, pi, :]
                x_s = exposed[rows, ai, pi, :]
                opt = e_s.min(axis=1)
                # SLO-feasible set per workload: perturbed runtime vs
                # the SAME threshold's clean runtime
                r_clean = rt[:w_n, ai, pi, :]                  # (W, T)
                # chosen threshold: the deployed one while feasible;
                # past the SLO, the cheapest feasible (or the
                # least-violating when nothing is feasible) — the
                # shared operator rule (slo.retune_knobs, also the
                # fleet governor)
                kchos = retune_knobs(e_s, r_s, slo_relax * r_clean,
                                     deployed=kstar)
                regret = e_s[wi_ix, kchos] - opt
                regret_frac = regret / np.maximum(opt, 1e-300)
                viol = runtime_violation_rate(
                    r_s[wi_ix, kstar],
                    r_clean[wi_ix, kstar], slo_relax)
                summary.append({
                    "npu": npu.name, "policy": policy,
                    "severity": sev,
                    "worst_exposed_wake_s":
                        float(x_s[wi_ix, kstar].max(initial=0.0)),
                    "worst_exposed_wake_any_s":
                        float(x_s.max(initial=0.0)),
                    "slo_violation_rate": viol,
                    "max_regret_frac":
                        float(regret_frac.max(initial=0.0)),
                    "mean_regret_frac":
                        float(regret_frac.mean()) if w_n else 0.0,
                })
                for wi, wl in enumerate(workloads):
                    for ki, ts in enumerate(threshold_scales):
                        records.append({
                            "workload": wl.name, "npu": npu.name,
                            "policy": policy, "severity": sev,
                            # full knob columns (knob_idx + every
                            # KnobGrid axis) so these records feed
                            # with_savings/group_by like any sweep's
                            **knob_columns(thr_knobs[ki], ki),
                            "runtime_s": float(r_s[wi, ki]),
                            "total_j": float(e_s[wi, ki]),
                            "exposed_wake_s": float(x_s[wi, ki]),
                            "deployed": bool(ki == kstar[wi]),
                            "chosen": bool(ki == kchos[wi]),
                        })
    return {"records": records, "summary": summary,
            "severities": severities,
            "threshold_scales": threshold_scales}


def sweep_reference(workloads: Sequence[Workload] | Workload,
                    npus: Iterable[NPUSpec | str] = ("NPU-D",),
                    policies: Iterable[str] = POLICIES,
                    knob_grid: Optional[Sequence[PolicyKnobs]] = None) \
        -> list[dict]:
    """The original loop sweep — one ``evaluate`` round-trip per cell.

    Kept as the oracle for the batched path: same records, same
    deterministic ordering; ``tests/test_sweep_batch.py`` holds the two
    to ≤1e-9 relative on every record field.
    """
    if isinstance(workloads, Workload):
        workloads = [workloads]
    if knob_grid is None:
        knob_grid = [PolicyKnobs()]
    npu_specs = [get_npu(n) if isinstance(n, str) else n for n in npus]
    records: list[dict] = []
    for wl in workloads:
        compile_trace(wl)  # compile once up front (cached by identity)
        for npu in npu_specs:
            for policy in policies:
                for ki, knobs in enumerate(knob_grid):
                    rep = evaluate(wl, npu, policy, knobs)
                    records.append(_flatten(rep, knobs, ki, npu))
    return records


def sweep_program_plane(workloads: Sequence[Workload] | Workload,
                        npus: Iterable[NPUSpec | str] = ("NPU-D",),
                        knob_grid=None, *, backend: Optional[str] = None,
                        jax_mesh=None) -> list[dict]:
    """Cross-validation sweep over the batched program plane (ISSUE 10):
    lower every (workload, npu) cell, re-place the §4.3 ``setpm``
    instrumentation once per unique delay scale, and execute ALL cells
    through the ``repro.core.program_plane`` array kernel on the
    selected backend. One flat record per (workload, npu, knob) cell
    compares gated-cycle fractions and setpm counts against the
    closed-form ``ReGate-Full`` evaluation (``evaluate_batch`` on the
    same substrate); every ``KnobGrid`` column is emitted
    unconditionally. Record order is workload-major, then NPU, then
    knob index (the ``sweep_grid`` convention).

    ``knob_grid`` accepts a ``KnobGrid`` (crossed), a flat sequence of
    ``PolicyKnobs``, or ``None`` (the single default point — the
    original two-axis sweep). ``backend``/``jax_mesh`` resolve through
    the active ``SweepSession`` exactly like ``sweep_grid``; cell-for-
    cell the records match the per-cell oracle
    (``sweep_program_plane_reference``) to <=1e-9 relative, executor
    integers exactly."""
    from repro.core.policies import as_knob_tuple
    from repro.core.program_plane import program_plane_batch
    return program_plane_batch(
        workloads, npus, as_knob_tuple(knob_grid),
        backend=backend, jax_mesh=jax_mesh).records()


def sweep_program_plane_reference(workloads: Sequence[Workload] | Workload,
                                  npus: Iterable[NPUSpec | str]
                                  = ("NPU-D",),
                                  knob_grid=None) -> list[dict]:
    """The per-cell host oracle for ``sweep_program_plane``: one
    ``lowering.crossval_record`` (event-driven ``EventTimeline`` +
    closed-form ``evaluate``) per (workload, npu, knob) cell, same
    record order. This is the pre-ISSUE-10 evaluation path, kept as the
    equivalence baseline for the tests and the perf gate."""
    from repro.core.lowering import crossval_record
    from repro.core.policies import as_knob_tuple
    if isinstance(workloads, Workload):
        workloads = [workloads]
    npu_specs = [get_npu(n) if isinstance(n, str) else n for n in npus]
    grid = as_knob_tuple(knob_grid)
    return [crossval_record(wl, npu, knobs=kn, knob_idx=ki)
            for wl in workloads for npu in npu_specs
            for ki, kn in enumerate(grid)]


def sweep_fleet(scenario, knob_grid=None, **kw):
    """Fleet serving plane (ISSUE 7): simulate a chip fleet serving
    seeded request-arrival traces, one batched ``evaluate_batch`` call
    per epoch, with the online SLO governor switching ``PolicyKnobs``
    and ``core.carbon`` rolling per-chip joules up to fleet
    kWh/CO2/cost. The guard plane (ISSUE 9) rides along via
    ``guard=GuardPolicy(...)`` (watchdog + backend failover + NaN
    quarantine) and ``checkpoint=<dir>`` (crash-consistent
    epoch-granular snapshots with bit-identical resume). Thin
    re-export of ``repro.core.fleet.sweep_fleet``
    (imported lazily — ``fleet`` builds on this module's substrate);
    see that module for the scenario/report data model."""
    from repro.core.fleet import sweep_fleet as impl
    return impl(scenario, knob_grid, **kw)


def sweep_chaos(scenario, knob_grid=None, **kw):
    """Chaos plane (ISSUE 8): the fault-injection campaign — seeded
    chip/link fault timelines (``core.faults``) × fault severities ×
    policies through the fleet simulator under the anti-thrash
    hysteresis governor, reporting worst-case SLO-constrained regret,
    recovery time after repair, and retune counts (vs the stateless
    thrash baseline). Accepts the guard plane's ``guard=`` /
    ``checkpoint=`` kwargs (ISSUE 9): a SIGKILLed campaign resumes
    from its checkpoint directory bit-identically. Thin re-export of
    ``repro.core.fleet.sweep_chaos`` (imported lazily — ``fleet``
    builds on this module's substrate)."""
    from repro.core.fleet import sweep_chaos as impl
    return impl(scenario, knob_grid, **kw)


def with_savings(records: list[dict], baseline: str = "NoPG") -> list[dict]:
    """Attach ``savings`` (1 - total_j/baseline_total_j) to each record,
    in one bulk pass over the batched record table.

    A record's baseline is the ``baseline``-policy row of the same
    (workload, npu, knob_idx) cell. When that exact cell is missing,
    the un-gated ``NoPG`` baseline may fall back to the single knob
    point it was evaluated at — e.g. a knob grid that only evaluates
    the baseline at knob 0, which is sound because NoPG never gates
    and so no *gating* knob can change its energy. ``sa_width`` is the
    exception (it moves service times and therefore NoPG energy too),
    so the fallback additionally requires the record's ``sa_width`` to
    match the baseline row's — a width-mismatched denominator would be
    silently wrong, like any gating baseline. Gating baselines get no
    fallback at all. Baseline rows get savings 0.0; cells with no
    resolvable baseline get savings None.
    """
    def eff_width(r):
        """Record's effective SA width: ``None`` (native) and the
        explicitly spelled native width are the same configuration."""
        w = r["sa_width"]
        if w is not None:
            return w
        try:
            return get_npu(r["npu"]).sa_width
        except KeyError:  # ad-hoc spec name: compare the raw value
            return None

    _require_knob_columns(records, "with_savings")

    base: dict[tuple, float] = {}
    per_cell: dict[tuple, list[tuple]] = {}
    for r in records:
        if r["policy"] == baseline:
            base[(r["workload"], r["npu"], r["knob_idx"])] = r["total_j"]
            per_cell.setdefault((r["workload"], r["npu"]), []) \
                .append((r["total_j"], eff_width(r)))
    fallback = {k: v[0] for k, v in per_cell.items()
                if len(v) == 1} if baseline == "NoPG" else {}
    out = []
    for r in records:
        b = base.get((r["workload"], r["npu"], r["knob_idx"]))
        if b is None:
            fb = fallback.get((r["workload"], r["npu"]))
            if fb is not None and fb[1] == eff_width(r):
                b = fb[0]
        r = dict(r)
        r["savings"] = None if b is None else 1.0 - r["total_j"] / b
        out.append(r)
    return out


def _require_knob_columns(records: list[dict], caller: str) -> None:
    """Record-table consumers key on the knob columns; a record missing
    one (e.g. hand-built before ISSUE 7 unified emission) would silently
    mis-baseline or mis-group, so fail loudly naming the gap."""
    need = ("knob_idx",) + KnobGrid.columns()
    for i, r in enumerate(records):
        missing = [k for k in need if k not in r]
        if missing:
            raise ValueError(
                f"{caller}: record {i} "
                f"({r.get('workload')!r}/{r.get('policy')!r}) is "
                f"missing knob column(s) {missing}; every sweep record "
                f"carries {need} — rebuild the table with a "
                f"post-ISSUE-7 sweep, or fill the defaults explicitly")


def group_by(records: list[dict], *keys: str) -> dict[tuple, list[dict]]:
    """Group records by the given columns, preserving record order.
    A record missing one of ``keys`` fails loudly (records from any
    sweep entry point carry every knob column unconditionally)."""
    out: dict[tuple, list[dict]] = {}
    for i, r in enumerate(records):
        try:
            out.setdefault(tuple(r[k] for k in keys), []).append(r)
        except KeyError as e:
            raise KeyError(
                f"group_by: record {i} ({r.get('workload')!r}/"
                f"{r.get('policy')!r}) has no column {e.args[0]!r}; "
                f"available: {sorted(r)}") from None
    return out
