"""Batched program plane (ISSUE 10): software-managed gating on arrays.

The software-managed half of ReGate (§5.3/Fig 14: compiler-placed
``setpm`` driving the VU, plus SRAM segment bands) used to be evaluated
one (workload, npu) cell at a time on the host event-driven executor —
``sweep_program_plane`` was a bare Python double loop over
``crossval_record``. This module lowers the instrumented programs into
one ragged columnar stack and executes ALL cells in lock-step through
the array backend, so the program plane rides numpy *and* jax exactly
like ``policies.evaluate_batch``:

* ``build_program_arrays`` compiles each lowered program
  (``lowering.lower_workload`` SlotUse timelines + the §4.3
  ``instrument_setpm`` placements, merged by ``lowering.build_events``)
  into a ``ProgramArrays`` stack — concatenated per-event columns
  (cycle index, per-unit issue latencies, per-unit setpm effects) with
  ``offsets``/``seg_ids`` per the ``opgen.StackedTrace`` convention.
  Instrumentation re-placement happens once per unique
  ``delay_scale`` (the PR-4 unique-pair trick): window/leak knob
  points sharing a delay scale share event streams.
* ``_exec_kernel`` is the batched executor: one backend-neutral
  ``scan`` over the padded event axis whose carry holds the whole
  (row, unit) machine state — power, mode, ready/busy/idle cycles and
  the on/gated accounting. Each scan step replays ``EventTimeline``'s
  closed-form gap handling plus the bundle step (setpm, structural
  hazards with auto-wake, issue, idle-detection window crossing) as
  pure integer array ops, so the batched results equal the event-driven
  executor's EXACTLY, including the cross-unit stall coupling — per
  cell, bit for bit, on numpy and on jitted jax (int64 under the x64
  scope). The BET/window knobs enter as per-row integer delay/window
  parameters computed by the same ``isa.scaled_delay`` /
  ``isa.scaled_window`` helpers the executors use.
* ``program_plane_batch`` assembles the full (workload x npu x knob)
  cube: kernel outputs, the closed-form intra-op VU burst fold and the
  SRAM band analysis (both once per unique knob pair), and the
  closed-form ``ReGate-Full`` policy side via one ``evaluate_batch``
  call. ``sweep_program_plane`` (``repro.core.sweep``) is a thin
  wrapper emitting one ``lowering.plane_record`` per cell.

With ``jax_mesh`` (a mesh with a ``"wl"`` axis) the dense event stack
is device_put sharded along the row axis — rows are independent, so
GSPMD splits the scan across devices with no cross-device traffic;
inert padding rows (horizon 0, no events) make the row count divisible.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterable, Optional, Sequence

import numpy as np

from repro.core import session
from repro.core.backend import get_backend
from repro.core.hw import NPUSpec, get_npu, with_sa_width
from repro.core.isa import events_to_arrays, scaled_delay, scaled_window
from repro.core.lowering import (COMP_OF_UNIT, REGATE_FULL_TIMELINE,
                                 UNIT_OF, LoweredProgram, build_events,
                                 instrument_program, lower_workload,
                                 plane_record, sram_band_gating)
from repro.core.opgen import Workload
from repro.core.policies import (BatchResult, PolicyKnobs,
                                 _component_policies,
                                 _fine_grained_vu_vec, evaluate_batch,
                                 knob_pairs)

# fixed kernel unit order; component order follows UNIT_OF
UNITS = tuple(u for u, _ in UNIT_OF.values())          # sa0 vu0 dma0 ici0
COMPS = tuple(COMP_OF_UNIT[u] for u in UNITS)          # sa  vu  hbm  ici
# gating-table key per unit under the ReGate-Full machine (the
# delay_keys override in REGATE_FULL_TIMELINE: SA wakes at PE grain)
_TABLE_KEY = {"sa": "sa_pe", "vu": "vu", "hbm": "hbm", "ici": "ici"}
_KEYS = tuple(_TABLE_KEY[c] for c in COMPS)
# initial power modes (mode codes: 0 AUTO, 1 ON, 2 OFF): the
# software-managed VU starts ON, everything else under hw detection
_MODE0 = tuple(1 if UNITS[i] in REGATE_FULL_TIMELINE["initial_modes"]
               else 0 for i in range(len(UNITS)))


@dataclass
class ProgramArrays:
    """Ragged columnar stack of instrumented event programs.

    Stream ``s`` owns rows ``offsets[s]:offsets[s+1]`` of the
    concatenated event columns (the ``StackedTrace`` convention);
    ``seg_ids`` is the equivalent per-event stream id."""
    units: tuple[str, ...]
    cycle: np.ndarray          # (N,)  event cycle indices, int64
    lat: np.ndarray            # (N,U) per-unit issue latency (0 unused)
    pm: np.ndarray             # (N,U) setpm effect codes (isa.PM_*)
    offsets: np.ndarray        # (S+1,)
    horizon: np.ndarray        # (S,)
    setpm_vu: np.ndarray       # (S,) §4.3 placement count (VU)

    @property
    def n_streams(self) -> int:
        return len(self.offsets) - 1

    @property
    def lengths(self) -> np.ndarray:
        return self.offsets[1:] - self.offsets[:-1]

    @property
    def seg_ids(self) -> np.ndarray:
        return np.repeat(np.arange(self.n_streams, dtype=np.int64),
                         self.lengths)


# per-(program, delay_scale) columnar event stream, FIFO-bounded like
# the instrumentation cache (strong prog ref keeps the id valid)
_STREAM_CACHE: dict[tuple[int, float], tuple[LoweredProgram, dict]] = {}
_STREAM_CACHE_MAX = 256


def _stream_arrays(prog: LoweredProgram, dscale: float) -> dict:
    key = (id(prog), float(dscale))
    hit = _STREAM_CACHE.get(key)
    if hit is not None and hit[0] is prog:
        return hit[1]
    placements = instrument_program(prog, delay_scale=dscale)
    events = build_events(prog, placements)
    arr = events_to_arrays(events, UNITS)
    arr["horizon"] = int(prog.horizon)
    arr["setpm_vu"] = float(len(placements))
    if len(_STREAM_CACHE) >= _STREAM_CACHE_MAX:
        _STREAM_CACHE.pop(next(iter(_STREAM_CACHE)))
    _STREAM_CACHE[key] = (prog, arr)
    return arr


def build_program_arrays(progs: Sequence[LoweredProgram],
                         dscales: Sequence[float]) -> ProgramArrays:
    """Stack one instrumented event stream per (program, delay_scale)
    pair into a ragged ``ProgramArrays``."""
    streams = [_stream_arrays(p, d) for p, d in zip(progs, dscales)]
    lengths = np.array([len(s["cycle"]) for s in streams], np.int64)
    offsets = np.zeros(len(streams) + 1, np.int64)
    np.cumsum(lengths, out=offsets[1:])
    u = len(UNITS)
    return ProgramArrays(
        units=UNITS,
        cycle=np.concatenate([s["cycle"] for s in streams])
        if streams else np.zeros(0, np.int64),
        lat=np.concatenate([s["lat"] for s in streams])
        if streams else np.zeros((0, u), np.int64),
        pm=np.concatenate([s["pm"] for s in streams])
        if streams else np.zeros((0, u), np.int8),
        offsets=offsets,
        horizon=np.array([s["horizon"] for s in streams], np.int64),
        setpm_vu=np.array([s["setpm_vu"] for s in streams], np.float64))


# --------------------------------------------------------------------------
# the batched executor kernel
# --------------------------------------------------------------------------

def _pack_dense(pa: ProgramArrays, stream_of_row: np.ndarray,
                window: np.ndarray, delay: np.ndarray,
                horizon: np.ndarray) -> dict:
    """Gather the ragged stack into the kernel's dense (E, R[, U])
    layout; padded events carry cycle -1 (the in-kernel no-op mask)."""
    u = len(pa.units)
    lens = pa.lengths[stream_of_row]
    r = len(stream_of_row)
    e_max = int(lens.max()) if r else 0
    cycle = np.full((e_max, r), -1, np.int64)
    lat = np.zeros((e_max, r, u), np.int64)
    pm = np.zeros((e_max, r, u), np.int8)
    for ri, s in enumerate(stream_of_row):
        lo, hi = pa.offsets[s], pa.offsets[s + 1]
        n = hi - lo
        cycle[:n, ri] = pa.cycle[lo:hi]
        lat[:n, ri] = pa.lat[lo:hi]
        pm[:n, ri] = pa.pm[lo:hi]
    return {"cycle": cycle, "lat": lat, "pm": pm,
            "delay": delay.astype(np.int64),
            "window": window.astype(np.int64),
            "mode0": np.broadcast_to(
                np.array(_MODE0, np.int64), (r, u)).copy(),
            "horizon": horizon.astype(np.int64)}


def _kernel_body(data, xp):
    """The lock-step event executor: ``EventTimeline`` semantics with
    the (row, unit) axes vectorized. Integer arithmetic throughout —
    results are exactly the per-cell executor's."""
    delay, window = data["delay"], data["window"]
    r, u = delay.shape
    zeros = xp.zeros((r, u), xp.int64)

    def gap_account(st, n):
        """Closed-form ``_gap(n, t)``: powered AUTO units cross their
        idle-detection window mid-gap and count gated from there."""
        powered, auto = st["powered"], st["mode"] == 0
        g = xp.maximum(st["idle"] + window, st["busy"])
        n_u = n[:, None]
        on_gap = xp.clip(g - st["t"][:, None] - 1, 0, n_u)
        on_add = xp.where(powered, xp.where(auto, on_gap, n_u), 0)
        gate_add = n_u - on_add
        crossed = auto & powered & (gate_add > 0)
        return dict(st, powered=powered & ~crossed,
                    on=st["on"] + on_add,
                    gated=st["gated"] + gate_add, t=st["t"] + n)

    def step(st, x):
        cyc, lat, pm = x["cycle"], x["lat"], x["pm"]
        valid = cyc >= 0
        g1 = gap_account(st, xp.maximum(cyc - st["prev"] - 1, 0))
        t1 = g1["t"]
        t1_u = t1[:, None]
        # misc-slot setpm applies first (takes effect this cycle)
        powered, mode = g1["powered"], g1["mode"]
        ready, wakes = g1["ready"], g1["wakes"]
        is_on, is_off, is_auto = pm == 1, pm == 2, pm == 3
        wake_pm = is_on & ~powered
        ready = xp.where(wake_pm, t1_u + delay, ready)
        wakes = wakes + wake_pm
        powered = (powered | wake_pm) & ~is_off
        mode = xp.where(is_on, 1, xp.where(is_off, 2,
                                           xp.where(is_auto, 0, mode)))
        nsetpm_add = (pm > 0).any(axis=1)
        # structural hazards: auto-wake on dispatch, wait for ready/busy
        ref = lat > 0
        wake_d = ref & ~powered
        ready = xp.where(wake_d, xp.maximum(t1_u, g1["busy"]) + delay,
                         ready)
        wakes = wakes + wake_d
        powered = powered | wake_d
        need = xp.where(ref, xp.maximum(ready, g1["busy"]), 0)
        start = xp.maximum(t1, need.max(axis=1))
        # issue
        busy = xp.where(ref, start[:, None] + lat, g1["busy"])
        idle = xp.where(ref, busy, g1["idle"])
        t2 = start + 1
        t2_u = t2[:, None]
        # hardware idle-detection gating at the post-issue cycle
        gate4 = (powered & (mode == 0) & (t2_u - idle >= window)
                 & (busy <= t2_u))
        powered = powered & ~gate4
        new = dict(
            t=t2, prev=cyc, powered=powered, mode=mode, ready=ready,
            busy=busy, idle=idle, on=g1["on"] + powered,
            gated=g1["gated"] + ~powered, wakes=wakes,
            stalls=g1["stalls"] + (start - t1),
            nsetpm=g1["nsetpm"] + nsetpm_add)
        v_u = valid[:, None]
        return {k: xp.where(valid if v.ndim == 1 else v_u, v, st[k])
                for k, v in new.items()}

    init = dict(
        t=xp.zeros(r, xp.int64), prev=xp.full(r, -1, xp.int64),
        powered=xp.ones((r, u), bool), mode=data["mode0"],
        ready=zeros, busy=zeros, idle=zeros, on=zeros, gated=zeros,
        wakes=zeros, stalls=xp.zeros(r, xp.int64),
        nsetpm=xp.zeros(r, xp.int64))
    return init, gap_account, step


def _full_body(bk):
    """The jit'able whole-stack program: scan over the event axis, then
    the ``run()`` tail gap to the horizon and ``_finish``'s drain."""
    xp = bk.xp

    def body(d):
        init, gap_account, step = _kernel_body(d, xp)
        st = bk.scan(step, init,
                     {"cycle": d["cycle"], "lat": d["lat"],
                      "pm": d["pm"]}, length=d["cycle"].shape[0])
        st = gap_account(st,
                         xp.maximum(d["horizon"] - st["prev"] - 1, 0))
        end = xp.maximum(st["t"], st["busy"].max(axis=1))
        extra = (end - st["t"])[:, None]
        return {"cycles": end, "stall_cycles": st["stalls"],
                "on": st["on"] + xp.where(st["powered"], extra, 0),
                "gated": st["gated"] + xp.where(st["powered"], 0, extra),
                "wakes": st["wakes"], "setpm_executed": st["nsetpm"]}

    return body


def _compiled(bk):
    fn = _KERNELS.get(bk.name)
    if fn is None:
        fn = bk.jit(_full_body(bk))
        _KERNELS[bk.name] = fn
    return fn


def _run_kernel(data: dict, bk) -> dict[str, np.ndarray]:
    """Execute the packed event stack on the backend; returns host
    numpy outputs per row."""
    fn = _compiled(bk)
    with bk.compute_scope():
        out = bk.block(fn({k: bk.asarray(v) for k, v in data.items()}))
    return {k: bk.to_numpy(v) for k, v in out.items()}


_KERNELS: dict[str, object] = {}


def _mesh_pad(data: dict, n_dev: int) -> tuple[dict, int]:
    """Pad the row axis to a multiple of the mesh size with inert rows
    (horizon 0, no events) so the sharded axes divide evenly."""
    r = data["horizon"].shape[0]
    pad = (-r) % n_dev
    if pad == 0:
        return data, r
    out = {}
    for k, v in data.items():
        axis = 1 if k in ("cycle", "lat", "pm") else 0
        widths = [(0, 0)] * v.ndim
        widths[axis] = (0, pad)
        fill = -1 if k == "cycle" else 0
        out[k] = np.pad(v, widths, constant_values=fill)
    return out, r


def _run_kernel_mesh(data: dict, bk, mesh) -> dict[str, np.ndarray]:
    """Mesh path: device_put the dense stack sharded along the row axis
    of a ``("wl",)`` mesh; rows are independent, so GSPMD executes the
    scan shard-locally."""
    n_dev = int(np.prod(list(bk.mesh_axis_sizes(mesh).values())))
    padded, r = _mesh_pad(data, n_dev)
    fn = _compiled(bk)
    with bk.compute_scope():
        from jax.sharding import NamedSharding
        put = {}
        for k, v in padded.items():
            spec = (bk.pspec(None, "wl") if k in ("cycle", "lat", "pm")
                    else bk.pspec("wl"))
            put[k] = bk._jax.device_put(
                bk.asarray(v), NamedSharding(mesh, spec))
        out = bk.block(fn(put))
    return {k: bk.to_numpy(v)[:r] for k, v in out.items()}


# --------------------------------------------------------------------------
# the batched plane: cube assembly + records
# --------------------------------------------------------------------------

@dataclass
class ProgramPlaneBatch:
    """The full (workload x npu x knob) program-plane cube.

    Executor-side arrays are indexed (W, A, T) over the unique knob
    triples; ``records()`` expands to the full knob axis via ``inv``
    and assembles one ``lowering.plane_record`` per cell."""
    workloads: tuple[str, ...]
    npus: tuple[NPUSpec, ...]
    knob_grid: tuple[PolicyKnobs, ...]
    triples: list[tuple]
    inv: np.ndarray                       # (K,) knob -> triple index
    cycles: np.ndarray                    # (W, A, T) int64
    stall_cycles: np.ndarray              # (W, A, T) int64
    n_events: np.ndarray                  # (W, A, T) int64
    gated_cycles: dict[str, np.ndarray]   # comp -> (W, A, T) float64
    wake_events: dict[str, np.ndarray]    # comp -> (W, A, T) float64
    setpm_isa: dict[str, np.ndarray]      # vu/sram -> (W, A, T)
    policy: BatchResult = field(repr=False)

    def records(self) -> list[dict]:
        """Flat records, workload-major then NPU then knob index — the
        sweep convention, one record per (workload, npu, knob) cell."""
        recs = []
        pol = self.policy
        for wi, wl in enumerate(self.workloads):
            for ai, npu in enumerate(self.npus):
                for ki, knobs in enumerate(self.knob_grid):
                    ti = int(self.inv[ki])
                    c = (wi, ai, ti)
                    recs.append(plane_record(
                        wl, npu, knobs, ki,
                        prog={
                            "cycles": int(self.cycles[c]),
                            "n_events": int(self.n_events[c]),
                            "stall_cycles": int(self.stall_cycles[c]),
                            "gated_cycles": {
                                k: float(v[c])
                                for k, v in self.gated_cycles.items()},
                            "wake_events": {
                                k: float(v[c])
                                for k, v in self.wake_events.items()},
                            "setpm_isa": {
                                k: float(v[c])
                                for k, v in self.setpm_isa.items()}},
                        policy={
                            "runtime_s":
                                float(pol.runtime_s[wi, ai, 0, ki]),
                            "gated_s": {
                                k: float(v[wi, ai, 0, ki])
                                for k, v in pol.gated_s.items()},
                            "setpm_by": {
                                k: float(v[wi, ai, 0, ki])
                                for k, v in pol.setpm_by.items()}}))
        return recs


def program_plane_batch(workloads: Sequence[Workload] | Workload,
                        npus: Iterable[NPUSpec | str] = ("NPU-D",),
                        knob_grid: Optional[Sequence[PolicyKnobs]] = None,
                        backend: Optional[str] = None,
                        jax_mesh=None) -> ProgramPlaneBatch:
    """Evaluate the program plane for every (workload, npu, knob) cell
    through the batched executor kernel + the closed-form folds.

    Matches the per-cell ``lowering.crossval_record`` record-for-record:
    executor integers exactly, closed-form folds bit-identically (same
    host functions), the policy side within ``evaluate_batch``'s
    documented <=1e-9 of per-cell ``evaluate``."""
    if isinstance(workloads, Workload):
        workloads = [workloads]
    workloads = list(workloads)
    npu_specs = [get_npu(n) if isinstance(n, str) else n for n in npus]
    grid = tuple(knob_grid) if knob_grid is not None else (PolicyKnobs(),)
    bk = get_backend(backend)
    if jax_mesh is None and bk.name == "jax":
        jax_mesh = session.resolve("jax_mesh")

    triples, inv = knob_pairs(grid)
    w_n, a_n, t_n = len(workloads), len(npu_specs), len(triples)

    # one lowered program per (workload, effective npu); one event
    # stream per (program, delay_scale) — all identity-cached
    stream_index: dict[tuple, int] = {}
    progs: list[LoweredProgram] = []
    dscales: list[float] = []
    stream_of_row = np.empty(w_n * a_n * t_n, np.int64)
    window = np.empty((w_n * a_n * t_n, len(UNITS)), np.int64)
    delay = np.empty_like(window)
    horizon = np.empty(w_n * a_n * t_n, np.int64)
    for wi, wl in enumerate(workloads):
        for ai, npu in enumerate(npu_specs):
            for ti, (saw, dsc, wsc) in enumerate(triples):
                npu_eff = with_sa_width(npu, saw)
                prog = lower_workload(wl, npu_eff)
                skey = (id(prog), float(dsc))
                si = stream_index.get(skey)
                if si is None:
                    si = len(progs)
                    stream_index[skey] = si
                    progs.append(prog)
                    dscales.append(float(dsc))
                ri = (wi * a_n + ai) * t_n + ti
                stream_of_row[ri] = si
                horizon[ri] = prog.horizon
                g = npu_eff.gating
                for ui, key in enumerate(_KEYS):
                    delay[ri, ui] = scaled_delay(g, key, dsc)
                    window[ri, ui] = scaled_window(g, key, dsc, wsc)

    pa = build_program_arrays(progs, dscales)
    data = _pack_dense(pa, stream_of_row, window, delay, horizon)
    if jax_mesh is not None and bk.name == "jax" \
            and "wl" in bk.mesh_axis_sizes(jax_mesh):
        out = _run_kernel_mesh(data, bk, jax_mesh)
    else:
        out = _run_kernel(data, bk)

    shape = (w_n, a_n, t_n)
    cycles = out["cycles"].reshape(shape)
    stalls = out["stall_cycles"].reshape(shape)
    gated_u = out["gated"].reshape(shape + (len(UNITS),))
    wakes_u = out["wakes"].reshape(shape + (len(UNITS),))
    n_events = pa.lengths[stream_of_row].reshape(shape)

    gated = {c: gated_u[..., ui].astype(np.float64)
             for ui, c in enumerate(COMPS)}
    wakes = {c: wakes_u[..., ui].astype(np.float64)
             for ui, c in enumerate(COMPS)}
    setpm_isa = {"vu": pa.setpm_vu[stream_of_row].reshape(shape).copy(),
                 "sram": np.zeros(shape)}
    gated["sram"] = np.zeros(shape)

    # closed-form folds, once per unique (workload, npu, triple) —
    # identical host calls to execute_program's, so bit-identical; the
    # SRAM band analysis is window-independent, so it further dedups to
    # one call per (program, delay_scale)
    pol_vu = _component_policies("ReGate-Full")["vu"]
    sram_memo: dict[tuple[int, float], dict] = {}
    for wi, wl in enumerate(workloads):
        for ai, npu in enumerate(npu_specs):
            for ti, (saw, dsc, wsc) in enumerate(triples):
                npu_eff = with_sa_width(npu, saw)
                prog = lower_workload(wl, npu_eff)
                kn = PolicyKnobs(delay_scale=dsc, window_scale=wsc,
                                 sa_width=saw)
                fv = _fine_grained_vu_vec(
                    prog.tm, prog.tr, npu_eff, pol_vu, 1.0,
                    npu_eff.gating.leak_off_logic, kn)
                gated["vu"][wi, ai, ti] = (
                    gated["vu"][wi, ai, ti]
                    + fv["gated_s"] * npu_eff.freq_hz)
                setpm_isa["vu"][wi, ai, ti] += fv["setpm"]
                wakes["vu"][wi, ai, ti] += fv["wakes"]
                skey = (id(prog), float(dsc))
                sb = sram_memo.get(skey)
                if sb is None:
                    sb = sram_band_gating(prog, delay_scale=dsc)
                    sram_memo[skey] = sb
                gated["sram"][wi, ai, ti] = (
                    sb["gated_segcycles"] / max(1, sb["n_segments"]))
                setpm_isa["sram"][wi, ai, ti] = sb["setpm"]

    # the policy columns ride the same backend; the mesh is applied to
    # the kernel only (its row axis pads to divide the mesh — the
    # closed-form engine's op axis has no such padding and resolves its
    # own session mesh like every other sweep entry point)
    policy = evaluate_batch(workloads, npu_specs, ("ReGate-Full",),
                            grid, backend=backend)
    return ProgramPlaneBatch(
        workloads=tuple(wl.name for wl in workloads),
        npus=tuple(npu_specs), knob_grid=grid, triples=triples,
        inv=inv, cycles=cycles, stall_cycles=stalls, n_events=n_events,
        gated_cycles=gated, wake_events=wakes, setpm_isa=setpm_isa,
        policy=policy)
