"""Fleet serving plane (ISSUE 7): a datacenter of simulated NPUs on
top of the batched sweep kernel.

The per-chip sweep evaluates static traces; production fleets see
diurnal, bursty, multi-tenant traffic where the idle structure — and
therefore the power-gating opportunity — is set by the *arrival
process* (Jouppi et al.'s TPU datacenter analysis; CompPow's
time-varying-utilization argument, PAPERS.md). This module simulates
that: seeded request-arrival traces drive time-varying workload mixes
across thousands of chips, an online SLO governor re-tunes
``PolicyKnobs`` per epoch, and ``core.carbon`` rolls per-chip joules up
to fleet kWh / CO2 / cost.

Design, layer by layer:

* **Arrivals** — ``ArrivalSpec`` + ``arrival_counts``: Poisson /
  diurnal / bursty generators following the ``core.perturb`` contract
  (explicit ``numpy.random.Generator``, fixed call order; each class
  owns its own ``(seed, class_index)`` stream so composed scenarios
  stay deterministic class by class), plus
  ``replay`` of recorded arrival timestamps binned with the
  continuous-batching rule of ``launch/serve.py`` (a request joins at
  the next epoch boundary).
* **Traffic variability** — ``perturb.severity_variants`` pre-builds
  one trace variant set per congestion level from the same
  ``severity_plan`` compositions as the jitter plane; each epoch picks
  its level from the fleet-wide demand (busier epoch → harsher
  variant), so epochs are genuinely time-varying while the variant
  *objects* stay identity-stable and the compile/stack caches stay
  warm.
* **One batched call per epoch** — every epoch evaluates its active
  (workload-mix × npu × policy × knob) grid through exactly ONE
  ``policies.evaluate_batch`` call (the ``sweep_grid`` kernel; jax
  backend → one jitted program reused across all epochs, since
  perturbations preserve op counts and therefore stack shapes).
* **SLO governor** — the shared operator rule ``slo.retune_knobs``
  (also ``sweep.sweep_robustness``): deploy the energy-optimal knob,
  keep it while its load-inflated runtime meets ``slo_relax`` × the
  calibrated reference, otherwise re-tune to the cheapest feasible
  knob, falling back to the least-violating one. Violation accounting
  reuses ``slo.runtime_violation_rate``.
* **Energy & carbon** — busy energy is ``served invocations ×
  per-chip total_j × chips per invocation`` (the sweep's per-record
  energy semantics); idle chips burn ``PowerModel.idle_chip_w`` under
  ``NoPG`` and the deeply-gated ``idle_chip_gated_w()`` under ReGate
  policies; ``carbon.fleet_rollup`` turns the summed joules into
  facility kWh / kgCO2e / USD. Summary totals reconcile with the sum
  of per-record energies to float round-off (≤1e-9 relative — tested).

``sweep.sweep_fleet`` re-exports :func:`sweep_fleet`;
``examples/fleet_day.py`` is the "day in the life of a 4k-chip fleet"
study (millions of requests in seconds of wall-clock, because each
epoch is one batched sweep call over cached stacks).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Optional, Sequence

import numpy as np

from repro.core.carbon import FleetRollup, fleet_rollup
from repro.core.hw import NPUSpec, get_npu
from repro.core.opgen import Workload
from repro.core.perturb import _require_rng, severity_variants
from repro.core.policies import (POLICIES, BatchResult, PolicyKnobs,
                                 as_knob_tuple, evaluate_batch,
                                 knob_columns)
from repro.core.power import COMPONENTS, PowerModel
from repro.core.slo import retune_knobs, runtime_violation_rate

ARRIVAL_KINDS = ("poisson", "diurnal", "bursty", "replay")


# --------------------------------------------------------------------------
# request-arrival traces
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class ArrivalSpec:
    """One workload class's arrival process.

    ``poisson``  — homogeneous Poisson at ``rate_rps``.
    ``diurnal``  — Poisson with a sinusoidal day curve: rate(t) =
                   ``rate_rps`` × (1 + ``peak_frac`` ×
                   sin(2π (t + ``phase_s``) / ``period_s``)), clipped
                   at 0 (``peak_frac`` > 1 models overnight troughs
                   that go fully quiet).
    ``bursty``   — Poisson whose epoch rate is boosted ×``burst_factor``
                   with probability ``burst_prob`` per epoch (flash
                   crowds).
    ``replay``   — recorded arrival timestamps (``times_s``, seconds
                   from scenario start), binned with the
                   continuous-batching rule; consumes no random draws.

    Draw contract (the ``core.perturb`` discipline of explicit
    generators in a fixed call order): poisson/diurnal draw
    ``n_epochs`` Poisson variates, bursty draws ``n_epochs`` uniforms
    *then* ``n_epochs`` Poisson variates, replay draws none. The
    variate count is fixed, but the underlying bit-stream consumption
    of a Poisson variate is rate-dependent (rejection sampling), so
    trace isolation comes from ``sweep_fleet`` giving every class its
    own generator seeded ``(scenario.seed, class_index)`` — re-tuning
    one class's traffic can never move another class's trace.
    """

    kind: str = "poisson"
    rate_rps: float = 1.0
    peak_frac: float = 0.5
    period_s: float = 86400.0
    phase_s: float = 0.0
    burst_prob: float = 0.1
    burst_factor: float = 8.0
    times_s: Optional[tuple] = None

    def __post_init__(self):
        if self.kind not in ARRIVAL_KINDS:
            raise ValueError(f"unknown arrival kind {self.kind!r}; "
                             f"have {ARRIVAL_KINDS}")
        if self.kind == "replay":
            if self.times_s is None:
                raise ValueError("replay arrivals need times_s")
            object.__setattr__(self, "times_s",
                               tuple(float(t) for t in self.times_s))
        else:
            if not (math.isfinite(self.rate_rps) and self.rate_rps >= 0):
                raise ValueError(
                    f"rate_rps must be finite and >= 0, got "
                    f"{self.rate_rps!r}")
        if self.kind == "diurnal":
            if not (math.isfinite(self.period_s) and self.period_s > 0):
                raise ValueError(f"period_s must be > 0, got "
                                 f"{self.period_s!r}")
            if self.peak_frac < 0:
                raise ValueError(f"peak_frac must be >= 0, got "
                                 f"{self.peak_frac!r}")
        if self.kind == "bursty":
            if not 0.0 <= self.burst_prob <= 1.0:
                raise ValueError(f"burst_prob must be in [0, 1], got "
                                 f"{self.burst_prob!r}")
            if self.burst_factor < 1.0:
                raise ValueError(f"burst_factor must be >= 1, got "
                                 f"{self.burst_factor!r}")


def epoch_rates(spec: ArrivalSpec, n_epochs: int,
                epoch_s: float) -> np.ndarray:
    """Deterministic mean request rate (req/s) per epoch — the Poisson
    intensity before any stochastic draws (replay: the empirical
    per-epoch rate)."""
    if spec.kind == "replay":
        counts = bin_requests(np.asarray(spec.times_s), n_epochs, epoch_s)
        return counts / epoch_s
    t_mid = (np.arange(n_epochs) + 0.5) * epoch_s
    if spec.kind == "diurnal":
        mod = 1.0 + spec.peak_frac * np.sin(
            2.0 * np.pi * (t_mid + spec.phase_s) / spec.period_s)
        return spec.rate_rps * np.maximum(0.0, mod)
    return np.full(n_epochs, spec.rate_rps)


def arrival_counts(spec: ArrivalSpec, n_epochs: int, epoch_s: float,
                   rng: Optional[np.random.Generator] = None) \
        -> np.ndarray:
    """Per-epoch request counts (int64, shape (n_epochs,)).

    Stochastic kinds require an explicit ``numpy.random.Generator`` and
    honor the fixed-draw-count contract (see ``ArrivalSpec``); replay
    ignores ``rng`` entirely.
    """
    if n_epochs < 1:
        raise ValueError(f"n_epochs must be >= 1, got {n_epochs}")
    if spec.kind == "replay":
        return bin_requests(np.asarray(spec.times_s), n_epochs, epoch_s)
    _require_rng(rng)
    lam = epoch_rates(spec, n_epochs, epoch_s) * epoch_s
    if spec.kind == "bursty":
        boosted = rng.random(n_epochs) < spec.burst_prob
        lam = lam * np.where(boosted, spec.burst_factor, 1.0)
    return rng.poisson(lam).astype(np.int64)


def bin_requests(times_s: np.ndarray, n_epochs: int,
                 epoch_s: float) -> np.ndarray:
    """Bin arrival timestamps into serving epochs with the
    continuous-batching rule of ``launch/serve.py``: a request joins
    the batch at the *next* epoch boundary (an arrival strictly inside
    epoch e is served in epoch e+1; one exactly on a boundary joins the
    epoch that starts there). Arrivals in the final epoch clamp into
    the final epoch — the fleet has no epoch e+1 to defer to."""
    t = np.asarray(times_s, np.float64)
    if t.size and (not np.isfinite(t).all() or (t < 0).any()):
        raise ValueError("replay times_s must be finite and >= 0")
    if t.size and (t > n_epochs * epoch_s).any():
        raise ValueError(
            f"replay times_s exceed the scenario window "
            f"({n_epochs} x {epoch_s}s)")
    idx = np.minimum(np.ceil(t / epoch_s).astype(np.int64), n_epochs - 1)
    return np.bincount(idx, minlength=n_epochs).astype(np.int64)


# --------------------------------------------------------------------------
# scenario data model
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class WorkloadClass:
    """One tenant / traffic class: a workload trace (one *invocation* —
    e.g. a decode step over a batch) fed by an arrival process.
    ``requests_per_invocation`` converts request counts to invocation
    demand (a batch=8 decode trace serves 8 requests per invocation)."""

    name: str
    workload: Workload
    arrivals: ArrivalSpec
    requests_per_invocation: float = 1.0

    def __post_init__(self):
        if not (math.isfinite(self.requests_per_invocation)
                and self.requests_per_invocation > 0):
            raise ValueError(
                f"class {self.name!r}: requests_per_invocation must be "
                f"> 0, got {self.requests_per_invocation!r}")


@dataclass(frozen=True)
class FleetScenario:
    """A fleet simulation: classes × chips × policies × time window.

    ``severity_levels`` are the congestion levels traffic variability
    is drawn at (``perturb.severity_plan`` compositions, pre-built once
    via ``perturb.severity_variants``); each epoch selects the level
    whose demand quantile it falls in (single level → every epoch
    identical traces). ``slo_relax`` is the governor's relaxed-SLO
    factor over the calibrated clean reference runtime.
    """

    classes: tuple[WorkloadClass, ...]
    n_chips: int = 4096
    npu: NPUSpec | str = "NPU-D"
    policies: tuple[str, ...] = ("NoPG", "ReGate-HW", "ReGate-Full")
    duration_s: float = 86400.0
    epoch_s: float = 900.0
    slo_relax: float = 1.2
    seed: int = 0
    severity_levels: tuple[float, ...] = (0.0,)

    def __post_init__(self):
        object.__setattr__(self, "classes", tuple(self.classes))
        object.__setattr__(self, "policies", tuple(self.policies))
        object.__setattr__(self, "severity_levels",
                           tuple(float(s) for s in self.severity_levels))
        if not self.classes:
            raise ValueError("FleetScenario needs at least one class")
        names = [c.name for c in self.classes]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate class names: {names}")
        if not self.policies:
            raise ValueError("FleetScenario needs at least one policy")
        if not (math.isfinite(self.epoch_s) and self.epoch_s > 0):
            raise ValueError(f"epoch_s must be > 0, got {self.epoch_s!r}")
        if self.duration_s < self.epoch_s:
            raise ValueError("duration_s must cover at least one epoch")
        if self.n_chips < 1:
            raise ValueError(f"n_chips must be >= 1, got {self.n_chips}")
        if self.slo_relax <= 0:
            raise ValueError(f"slo_relax must be > 0, got "
                             f"{self.slo_relax!r}")
        if not self.severity_levels:
            raise ValueError("severity_levels must be non-empty")

    @property
    def n_epochs(self) -> int:
        return int(math.ceil(self.duration_s / self.epoch_s))


@dataclass
class FleetReport:
    """Everything ``sweep_fleet`` measured.

    ``records`` — one dict per (epoch, class, policy): the governor's
    chosen knob (full knob columns), demand/served/backlog invocations,
    allocated chips, busy/idle/total joules (summed over that class's
    chips), runtime and load-inflated effective runtime, the SLO bound,
    and the ``slo_violated`` / ``feasible_exists`` governor flags.
    ``epoch_summary`` — one dict per (epoch, policy) adding the
    unallocated-chip idle energy and fleet totals. ``summary`` — one
    dict per policy over the whole window, including the
    ``carbon.fleet_rollup`` fields; its ``total_j`` equals the sum of
    its records' ``total_j`` plus unallocated idle to float round-off.
    """

    n_epochs: int
    epoch_s: float
    n_chips: int
    npu: str
    policies: tuple[str, ...]
    class_names: tuple[str, ...]
    severity_levels: tuple[float, ...]
    severity_by_epoch: list[float]
    requests_total: int
    records: list[dict] = field(default_factory=list)
    epoch_summary: list[dict] = field(default_factory=list)
    summary: list[dict] = field(default_factory=list)
    # (workload variants, severity level) per epoch — populated only
    # with keep_epoch_inputs=True so tests can replay one epoch as a
    # hand-built sweep_grid/evaluate_batch call
    epoch_inputs: Optional[list] = None

    def policy_summary(self, policy: str) -> dict:
        for s in self.summary:
            if s["policy"] == policy:
                return s
        raise KeyError(policy)

    def rollup(self, policy: str) -> FleetRollup:
        return fleet_rollup(self.policy_summary(policy)["total_j"])


# --------------------------------------------------------------------------
# the fleet simulator
# --------------------------------------------------------------------------

def _allocate_chips(n_chips: int, demand_chip_s: np.ndarray) \
        -> np.ndarray:
    """Largest-remainder apportionment of ``n_chips`` proportional to
    per-class demand chip-seconds. Zero-demand classes get zero;
    every positive-demand class gets at least one chip when enough
    chips exist (a tiny tenant sharded next to huge ones must not be
    starved to zero capacity — that would make its queue diverge no
    matter what knob the governor picks)."""
    demand_chip_s = np.asarray(demand_chip_s, np.float64)
    pos = demand_chip_s > 0.0
    n_pos = int(pos.sum())
    alloc = np.zeros(len(demand_chip_s), np.int64)
    if n_pos == 0:
        return alloc
    if n_chips <= n_pos:
        # not enough chips for one each: largest demands first
        order = np.argsort(-demand_chip_s, kind="stable")
        alloc[order[:n_chips]] += 1
        return alloc
    alloc[pos] = 1
    rest = n_chips - n_pos
    quota = rest * demand_chip_s / float(demand_chip_s.sum())
    extra = np.floor(quota).astype(np.int64)
    alloc += extra
    leftover = rest - int(extra.sum())
    if leftover > 0:
        order = np.argsort(-(quota - extra), kind="stable")
        alloc[order[:leftover]] += 1
    return alloc


def _severity_index(demand: np.ndarray, n_levels: int) -> np.ndarray:
    """Per-epoch severity-level index from fleet-wide demand: epochs
    are ranked into ``n_levels`` equal quantile bands (busiest band →
    harshest level). Deterministic; single level → all zeros."""
    if n_levels == 1:
        return np.zeros(len(demand), np.int64)
    order = np.argsort(np.argsort(demand, kind="stable"), kind="stable")
    return (order * n_levels // max(1, len(demand))).astype(np.int64)


def _idle_power_w(pm: PowerModel, policy: str) -> float:
    """Out-of-epoch-load idle power per chip: NoPG chips sit at full
    idle power, ReGate chips deep-idle with everything gateable gated,
    Ideal is the zero-leakage bound (paper §3 / §6.6 idle story)."""
    if policy == "NoPG":
        return pm.idle_chip_w
    if policy == "Ideal":
        return 0.0
    return pm.idle_chip_gated_w()


def sweep_fleet(scenario: FleetScenario, knob_grid=None, *,
                backend: Optional[str] = None, jax_mesh=None,
                keep_epoch_inputs: bool = False) -> FleetReport:
    """Run the fleet simulation; see the module docstring for the
    model. ``knob_grid`` accepts a ``KnobGrid``, a flat sequence of
    ``PolicyKnobs``, or ``None`` (the single default point) —
    identical semantics to every other sweep entry point. ``backend``
    / ``jax_mesh`` resolve through the active ``SweepSession`` when
    ``None``. Deterministic: the same scenario (same seed) produces a
    bit-identical report.
    """
    knobs = as_knob_tuple(knob_grid)
    n_k = len(knobs)
    npu = get_npu(scenario.npu) if isinstance(scenario.npu, str) \
        else scenario.npu
    pols = scenario.policies
    classes = scenario.classes
    n_w, n_p = len(classes), len(pols)
    n_e, dt = scenario.n_epochs, float(scenario.epoch_s)
    pm = PowerModel(npu)
    idle_w = np.array([_idle_power_w(pm, p) for p in pols])

    # --- arrivals: per-class counts, (W, E) --------------------------
    counts = np.zeros((n_w, n_e), np.int64)
    for ci, cls in enumerate(classes):
        rng = np.random.default_rng((int(scenario.seed), ci))
        counts[ci] = arrival_counts(cls.arrivals, n_e, dt, rng)
    requests_total = int(counts.sum())
    rpi = np.array([c.requests_per_invocation for c in classes])
    wl_chips = np.array([max(1, c.workload.n_chips) for c in classes],
                        np.float64)

    # --- traffic variability: one variant set per severity level -----
    base = [c.workload for c in classes]
    levels = scenario.severity_levels
    variants = severity_variants(base, levels, seed=scenario.seed)
    by_level = [variants[lv] for lv in levels]
    sev_ix = _severity_index(counts.sum(axis=0).astype(np.float64),
                             len(levels))

    # --- governor calibration: clean-trace reference runtimes --------
    # (one extra batched call outside the epoch loop; the SLO bound per
    # (class, policy) is slo_relax x the fastest clean knob, fixed for
    # the whole window so the governor chases a stable target)
    cal: BatchResult = evaluate_batch(base, (npu,), pols, knobs,
                                      backend=backend, jax_mesh=jax_mesh)
    rt_cal = cal.runtime_s[:, 0, :, :]                    # (W, P, K)
    slo_bound = scenario.slo_relax * rt_cal.min(axis=2)   # (W, P)

    report = FleetReport(
        n_epochs=n_e, epoch_s=dt, n_chips=scenario.n_chips,
        npu=npu.name, policies=pols,
        class_names=tuple(c.name for c in classes),
        severity_levels=levels,
        severity_by_epoch=[float(levels[i]) for i in sev_ix],
        requests_total=requests_total,
        epoch_inputs=[] if keep_epoch_inputs else None)

    backlog = np.zeros((n_w, n_p))
    eff_hist = np.zeros((n_e, n_w, n_p))
    for e in range(n_e):
        wls = by_level[sev_ix[e]]
        # ONE batched sweep call per epoch: the whole active
        # (workload-mix x npu x policy x knob) grid in one pass
        res: BatchResult = evaluate_batch(wls, (npu,), pols, knobs,
                                          backend=backend,
                                          jax_mesh=jax_mesh)
        if keep_epoch_inputs:
            report.epoch_inputs.append((wls, float(levels[sev_ix[e]])))
        rt = res.runtime_s[:, 0, :, :]                    # (W, P, K)
        tot = np.zeros_like(rt)
        for c in COMPONENTS:
            tot += res.static_j[c][:, 0] + res.dynamic_j[c][:, 0]

        for pi, policy in enumerate(pols):
            e_pk, r_pk = tot[:, pi, :], rt[:, pi, :]      # (W, K)
            deployed = np.argmin(e_pk, axis=1)
            demand_inv = counts[:, e] / rpi + backlog[:, pi]
            wi = np.arange(n_w)
            # allocation: proportional to demand chip-time at the
            # deployed knob (the governor re-tunes knobs after chips
            # are placed — placement reacts to demand, not to knobs)
            dct = demand_inv * r_pk[wi, deployed] * wl_chips
            chips = _allocate_chips(scenario.n_chips, dct)
            # queueing inflation: load factor rho per knob; a class
            # past its capacity stretches completion proportionally
            with np.errstate(divide="ignore", invalid="ignore"):
                rho = demand_inv[:, None] * r_pk * wl_chips[:, None] \
                    / (chips[:, None] * dt)
            rho = np.where(demand_inv[:, None] > 0,
                           np.where(chips[:, None] > 0, rho, np.inf),
                           0.0)
            eff = r_pk * np.maximum(1.0, rho)             # (W, K)
            chosen = retune_knobs(e_pk, eff,
                                  slo_bound[:, pi][:, None],
                                  deployed=deployed)
            feas_any = (eff <= slo_bound[:, pi][:, None]).any(axis=1)
            eff_c = eff[wi, chosen]
            violated = eff_c > slo_bound[:, pi]
            eff_hist[e, :, pi] = eff_c
            # service: capacity at the chosen knob, backlog carries
            r_c = r_pk[wi, chosen]
            cap_inv = np.where(r_c > 0,
                               chips * dt / (r_c * wl_chips), 0.0)
            served = np.minimum(demand_inv, cap_inv)
            backlog[:, pi] = demand_inv - served
            busy_s = np.minimum(served * r_c * wl_chips, chips * dt)
            idle_s = np.maximum(0.0, chips * dt - busy_s)
            busy_j = served * e_pk[wi, chosen] * wl_chips
            idle_j = idle_w[pi] * idle_s
            spare = scenario.n_chips - int(chips.sum())
            unalloc_j = idle_w[pi] * spare * dt
            for ci, cls in enumerate(classes):
                report.records.append({
                    "epoch": e, "class": cls.name,
                    "workload": wls[ci].name, "npu": npu.name,
                    "policy": policy,
                    "severity": float(levels[sev_ix[e]]),
                    **knob_columns(knobs[chosen[ci]],
                                   int(chosen[ci])),
                    "deployed_knob_idx": int(deployed[ci]),
                    "requests": int(counts[ci, e]),
                    "demand_inv": float(demand_inv[ci]),
                    "served_inv": float(served[ci]),
                    "backlog_inv": float(backlog[ci, pi]),
                    "chips": int(chips[ci]),
                    "runtime_s": float(r_c[ci]),
                    # the underlying sweep cell's per-chip energy at
                    # the chosen knob (one invocation) — ties each
                    # fleet record back to the direct sweep_grid
                    # record it was derived from
                    "inv_total_j": float(e_pk[ci, chosen[ci]]),
                    "eff_runtime_s": float(eff_c[ci]),
                    "slo_bound_s": float(slo_bound[ci, pi]),
                    "slo_violated": bool(violated[ci]),
                    "feasible_exists": bool(feas_any[ci]),
                    "retuned": bool(chosen[ci] != deployed[ci]),
                    "utilization": float(busy_s[ci]
                                         / max(chips[ci] * dt, 1e-300))
                    if chips[ci] else 0.0,
                    "busy_j": float(busy_j[ci]),
                    "idle_j": float(idle_j[ci]),
                    "total_j": float(busy_j[ci] + idle_j[ci]),
                })
            report.epoch_summary.append({
                "epoch": e, "policy": policy,
                "severity": float(levels[sev_ix[e]]),
                "requests": int(counts[:, e].sum()),
                "served_inv": float(served.sum()),
                "chips_active": int(chips.sum()),
                "chips_unallocated": spare,
                "unallocated_idle_j": float(unalloc_j),
                "busy_j": float(busy_j.sum()),
                "idle_j": float(idle_j.sum() + unalloc_j),
                "total_j": float(busy_j.sum() + idle_j.sum()
                                 + unalloc_j),
                "violations": int(violated.sum()),
                "retunes": int((chosen != deployed).sum()),
            })

    # --- per-policy window totals + carbon roll-up -------------------
    for pi, policy in enumerate(pols):
        recs = [r for r in report.records if r["policy"] == policy]
        eps = [s for s in report.epoch_summary if s["policy"] == policy]
        total_j = math.fsum(r["total_j"] for r in recs) \
            + math.fsum(s["unallocated_idle_j"] for s in eps)
        ru = fleet_rollup(total_j)
        base_rt = np.broadcast_to(
            (slo_bound[:, pi] / scenario.slo_relax)[None, :],
            (n_e, n_w))
        rpi_of = {c.name: float(r) for c, r in zip(classes, rpi)}
        served_req = math.fsum(r["served_inv"] * rpi_of[r["class"]]
                               for r in recs)
        report.summary.append({
            "policy": policy,
            "requests_total": requests_total,
            "served_requests": served_req,
            "backlog_inv_final": float(backlog[:, pi].sum()),
            "busy_j": math.fsum(r["busy_j"] for r in recs),
            "idle_j": math.fsum(r["idle_j"] for r in recs)
            + math.fsum(s["unallocated_idle_j"] for s in eps),
            "total_j": total_j,
            "chip_kwh": ru.chip_kwh,
            "facility_kwh": ru.facility_kwh,
            "co2_kg": ru.co2_kg,
            "cost_usd": ru.cost_usd,
            "slo_violation_rate": runtime_violation_rate(
                eff_hist[:, :, pi], base_rt, scenario.slo_relax),
            "retunes": sum(s["retunes"] for s in eps),
            "j_per_request": total_j / max(1.0, served_req),
        })
    return report
