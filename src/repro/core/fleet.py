"""Fleet serving plane (ISSUE 7): a datacenter of simulated NPUs on
top of the batched sweep kernel.

The per-chip sweep evaluates static traces; production fleets see
diurnal, bursty, multi-tenant traffic where the idle structure — and
therefore the power-gating opportunity — is set by the *arrival
process* (Jouppi et al.'s TPU datacenter analysis; CompPow's
time-varying-utilization argument, PAPERS.md). This module simulates
that: seeded request-arrival traces drive time-varying workload mixes
across thousands of chips, an online SLO governor re-tunes
``PolicyKnobs`` per epoch, and ``core.carbon`` rolls per-chip joules up
to fleet kWh / CO2 / cost.

Design, layer by layer:

* **Arrivals** — ``ArrivalSpec`` + ``arrival_counts``: Poisson /
  diurnal / bursty generators following the ``core.perturb`` contract
  (explicit ``numpy.random.Generator``, fixed call order; each class
  owns its own ``(seed, class_index)`` stream so composed scenarios
  stay deterministic class by class), plus
  ``replay`` of recorded arrival timestamps binned with the
  continuous-batching rule of ``launch/serve.py`` (a request joins at
  the next epoch boundary).
* **Traffic variability** — ``perturb.severity_variants`` pre-builds
  one trace variant set per congestion level from the same
  ``severity_plan`` compositions as the jitter plane; each epoch picks
  its level from the fleet-wide demand (busier epoch → harsher
  variant), so epochs are genuinely time-varying while the variant
  *objects* stay identity-stable and the compile/stack caches stay
  warm.
* **One batched call per epoch** — every epoch evaluates its active
  (workload-mix × npu × policy × knob) grid through exactly ONE
  ``policies.evaluate_batch`` call (the ``sweep_grid`` kernel; jax
  backend → one jitted program reused across all epochs, since
  perturbations preserve op counts and therefore stack shapes).
* **SLO governor** — the shared operator rule ``slo.retune_knobs``
  (also ``sweep.sweep_robustness``): deploy the energy-optimal knob,
  keep it while its load-inflated runtime meets ``slo_relax`` × the
  calibrated reference, otherwise re-tune to the cheapest feasible
  knob, falling back to the least-violating one. Violation accounting
  reuses ``slo.runtime_violation_rate``.
* **Energy & carbon** — busy energy is ``served invocations ×
  per-chip total_j × chips per invocation`` (the sweep's per-record
  energy semantics); idle chips burn ``PowerModel.idle_chip_w`` under
  ``NoPG`` and the deeply-gated ``idle_chip_gated_w()`` under ReGate
  policies; ``carbon.fleet_rollup`` turns the summed joules into
  facility kWh / kgCO2e / USD. Summary totals reconcile with the sum
  of per-record energies to float round-off (≤1e-9 relative — tested).

``sweep.sweep_fleet`` re-exports :func:`sweep_fleet`;
``examples/fleet_day.py`` is the "day in the life of a 4k-chip fleet"
study (millions of requests in seconds of wall-clock, because each
epoch is one batched sweep call over cached stacks).
"""
from __future__ import annotations

import math
import os
from dataclasses import dataclass, field, fields as dc_fields
from typing import Optional, Sequence

import numpy as np

from repro.core import session as _session
from repro.core.carbon import FleetRollup, fleet_rollup
from repro.core.faults import (FaultSpec, FaultTimeline,
                               build_fault_timeline, fault_plan)
from repro.core.guard import (CampaignCheckpoint, GuardPolicy,
                              GuardedRunner, RunManifest, digest_of,
                              maybe_kill)
from repro.core.hw import NPUSpec, get_npu
from repro.core.ici_topology import (lower_collectives, n_links,
                                     resolve_link_rates, topology_for)
from repro.core.opgen import Workload
from repro.core.perturb import (_require_rng, perturb_suite,
                                severity_plan, severity_variants)
from repro.core.policies import (POLICIES, BatchResult, PolicyKnobs,
                                 as_knob_tuple, evaluate_batch,
                                 knob_columns)
from repro.core.power import COMPONENTS, PowerModel
from repro.core.slo import (GovernorState, Hysteresis, retune_knobs,
                            runtime_violation_rate)

ARRIVAL_KINDS = ("poisson", "diurnal", "bursty", "replay")


# --------------------------------------------------------------------------
# request-arrival traces
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class ArrivalSpec:
    """One workload class's arrival process.

    ``poisson``  — homogeneous Poisson at ``rate_rps``.
    ``diurnal``  — Poisson with a sinusoidal day curve: rate(t) =
                   ``rate_rps`` × (1 + ``peak_frac`` ×
                   sin(2π (t + ``phase_s``) / ``period_s``)), clipped
                   at 0 (``peak_frac`` > 1 models overnight troughs
                   that go fully quiet).
    ``bursty``   — Poisson whose epoch rate is boosted ×``burst_factor``
                   with probability ``burst_prob`` per epoch (flash
                   crowds).
    ``replay``   — recorded arrival timestamps (``times_s``, seconds
                   from scenario start), binned with the
                   continuous-batching rule; consumes no random draws.

    Draw contract (the ``core.perturb`` discipline of explicit
    generators in a fixed call order): poisson/diurnal draw
    ``n_epochs`` Poisson variates, bursty draws ``n_epochs`` uniforms
    *then* ``n_epochs`` Poisson variates, replay draws none. The
    variate count is fixed, but the underlying bit-stream consumption
    of a Poisson variate is rate-dependent (rejection sampling), so
    trace isolation comes from ``sweep_fleet`` giving every class its
    own generator seeded ``(scenario.seed, class_index)`` — re-tuning
    one class's traffic can never move another class's trace.
    """

    kind: str = "poisson"
    rate_rps: float = 1.0
    peak_frac: float = 0.5
    period_s: float = 86400.0
    phase_s: float = 0.0
    burst_prob: float = 0.1
    burst_factor: float = 8.0
    times_s: Optional[tuple] = None

    def __post_init__(self):
        if self.kind not in ARRIVAL_KINDS:
            raise ValueError(f"unknown arrival kind {self.kind!r}; "
                             f"have {ARRIVAL_KINDS}")
        if self.kind == "replay":
            if self.times_s is None:
                raise ValueError("replay arrivals need times_s")
            object.__setattr__(self, "times_s",
                               tuple(float(t) for t in self.times_s))
        else:
            if not (math.isfinite(self.rate_rps) and self.rate_rps >= 0):
                raise ValueError(
                    f"rate_rps must be finite and >= 0, got "
                    f"{self.rate_rps!r}")
        if self.kind == "diurnal":
            if not (math.isfinite(self.period_s) and self.period_s > 0):
                raise ValueError(f"period_s must be > 0, got "
                                 f"{self.period_s!r}")
            if self.peak_frac < 0:
                raise ValueError(f"peak_frac must be >= 0, got "
                                 f"{self.peak_frac!r}")
        if self.kind == "bursty":
            if not 0.0 <= self.burst_prob <= 1.0:
                raise ValueError(f"burst_prob must be in [0, 1], got "
                                 f"{self.burst_prob!r}")
            if self.burst_factor < 1.0:
                raise ValueError(f"burst_factor must be >= 1, got "
                                 f"{self.burst_factor!r}")


def epoch_rates(spec: ArrivalSpec, n_epochs: int,
                epoch_s: float) -> np.ndarray:
    """Deterministic mean request rate (req/s) per epoch — the Poisson
    intensity before any stochastic draws (replay: the empirical
    per-epoch rate)."""
    if spec.kind == "replay":
        counts = bin_requests(np.asarray(spec.times_s), n_epochs, epoch_s)
        return counts / epoch_s
    t_mid = (np.arange(n_epochs) + 0.5) * epoch_s
    if spec.kind == "diurnal":
        mod = 1.0 + spec.peak_frac * np.sin(
            2.0 * np.pi * (t_mid + spec.phase_s) / spec.period_s)
        return spec.rate_rps * np.maximum(0.0, mod)
    return np.full(n_epochs, spec.rate_rps)


def arrival_counts(spec: ArrivalSpec, n_epochs: int, epoch_s: float,
                   rng: Optional[np.random.Generator] = None) \
        -> np.ndarray:
    """Per-epoch request counts (int64, shape (n_epochs,)).

    Stochastic kinds require an explicit ``numpy.random.Generator`` and
    honor the fixed-draw-count contract (see ``ArrivalSpec``); replay
    ignores ``rng`` entirely.
    """
    if n_epochs < 1:
        raise ValueError(f"n_epochs must be >= 1, got {n_epochs}")
    if spec.kind == "replay":
        return bin_requests(np.asarray(spec.times_s), n_epochs, epoch_s)
    _require_rng(rng)
    lam = epoch_rates(spec, n_epochs, epoch_s) * epoch_s
    if spec.kind == "bursty":
        boosted = rng.random(n_epochs) < spec.burst_prob
        lam = lam * np.where(boosted, spec.burst_factor, 1.0)
    return rng.poisson(lam).astype(np.int64)


def bin_requests(times_s: np.ndarray, n_epochs: int, epoch_s: float, *,
                 with_clamped: bool = False):
    """Bin arrival timestamps into serving epochs with the
    continuous-batching rule of ``launch/serve.py``: a request joins
    the batch at the *next* epoch boundary (an arrival strictly inside
    epoch e is served in epoch e+1; one exactly on a boundary joins the
    epoch that starts there). Arrivals in the final epoch clamp into
    the final epoch — the fleet has no epoch e+1 to defer to.

    That clamp used to be silent; with ``with_clamped=True`` the return
    is ``(counts, clamped)`` where ``clamped`` counts the arrivals
    whose next-boundary rule pointed at or past the horizon (i.e. they
    were folded back into the final epoch instead of deferring).
    ``sweep_fleet`` surfaces the total as
    ``FleetReport.clamped_requests``. Timestamps strictly past the
    window still raise.
    """
    t = np.asarray(times_s, np.float64)
    if t.size and (not np.isfinite(t).all() or (t < 0).any()):
        raise ValueError("replay times_s must be finite and >= 0")
    if t.size and (t > n_epochs * epoch_s).any():
        raise ValueError(
            f"replay times_s exceed the scenario window "
            f"({n_epochs} x {epoch_s}s)")
    raw = np.ceil(t / epoch_s).astype(np.int64)
    clamped = int((raw >= n_epochs).sum())
    idx = np.minimum(raw, n_epochs - 1)
    counts = np.bincount(idx, minlength=n_epochs).astype(np.int64)
    return (counts, clamped) if with_clamped else counts


# --------------------------------------------------------------------------
# scenario data model
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class WorkloadClass:
    """One tenant / traffic class: a workload trace (one *invocation* —
    e.g. a decode step over a batch) fed by an arrival process.
    ``requests_per_invocation`` converts request counts to invocation
    demand (a batch=8 decode trace serves 8 requests per invocation)."""

    name: str
    workload: Workload
    arrivals: ArrivalSpec
    requests_per_invocation: float = 1.0

    def __post_init__(self):
        if not (math.isfinite(self.requests_per_invocation)
                and self.requests_per_invocation > 0):
            raise ValueError(
                f"class {self.name!r}: requests_per_invocation must be "
                f"> 0, got {self.requests_per_invocation!r}")


@dataclass(frozen=True)
class FleetScenario:
    """A fleet simulation: classes × chips × policies × time window.

    ``severity_levels`` are the congestion levels traffic variability
    is drawn at (``perturb.severity_plan`` compositions, pre-built once
    via ``perturb.severity_variants``); each epoch selects the level
    whose demand quantile it falls in (single level → every epoch
    identical traces). ``slo_relax`` is the governor's relaxed-SLO
    factor over the calibrated clean reference runtime.
    """

    classes: tuple[WorkloadClass, ...]
    n_chips: int = 4096
    npu: NPUSpec | str = "NPU-D"
    policies: tuple[str, ...] = ("NoPG", "ReGate-HW", "ReGate-Full")
    duration_s: float = 86400.0
    epoch_s: float = 900.0
    slo_relax: float = 1.2
    seed: int = 0
    severity_levels: tuple[float, ...] = (0.0,)
    # graceful-degradation ladder, first rung: when a class's backlog
    # exceeds this multiple of its per-epoch capacity, the excess is
    # SHED (refused) instead of queued — inf (default) never sheds,
    # which keeps the backlog dynamics exactly as before
    shed_backlog_x: float = math.inf

    def __post_init__(self):
        object.__setattr__(self, "classes", tuple(self.classes))
        object.__setattr__(self, "policies", tuple(self.policies))
        object.__setattr__(self, "severity_levels",
                           tuple(float(s) for s in self.severity_levels))
        if not self.classes:
            raise ValueError("FleetScenario needs at least one class")
        names = [c.name for c in self.classes]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate class names: {names}")
        if not self.policies:
            raise ValueError("FleetScenario needs at least one policy")
        if not (math.isfinite(self.epoch_s) and self.epoch_s > 0):
            raise ValueError(f"epoch_s must be > 0, got {self.epoch_s!r}")
        if self.duration_s < self.epoch_s:
            raise ValueError("duration_s must cover at least one epoch")
        if self.n_chips < 1:
            raise ValueError(f"n_chips must be >= 1, got {self.n_chips}")
        if self.slo_relax <= 0:
            raise ValueError(f"slo_relax must be > 0, got "
                             f"{self.slo_relax!r}")
        if not self.severity_levels:
            raise ValueError("severity_levels must be non-empty")
        if math.isnan(self.shed_backlog_x) or self.shed_backlog_x <= 0:
            raise ValueError(f"shed_backlog_x must be > 0 (inf = never "
                             f"shed), got {self.shed_backlog_x!r}")

    @property
    def n_epochs(self) -> int:
        return int(math.ceil(self.duration_s / self.epoch_s))


@dataclass
class FleetReport:
    """Everything ``sweep_fleet`` measured.

    ``records`` — one dict per (epoch, class, policy): the governor's
    chosen knob (full knob columns), demand/served/backlog invocations,
    allocated chips, busy/idle/total joules (summed over that class's
    chips), runtime and load-inflated effective runtime, the SLO bound,
    and the ``slo_violated`` / ``feasible_exists`` governor flags.
    ``epoch_summary`` — one dict per (epoch, policy) adding the
    unallocated-chip idle energy and fleet totals. ``summary`` — one
    dict per policy over the whole window, including the
    ``carbon.fleet_rollup`` fields; its ``total_j`` equals the sum of
    its records' ``total_j`` plus unallocated idle to float round-off.
    """

    n_epochs: int
    epoch_s: float
    n_chips: int
    npu: str
    policies: tuple[str, ...]
    class_names: tuple[str, ...]
    severity_levels: tuple[float, ...]
    severity_by_epoch: list[float]
    requests_total: int
    records: list[dict] = field(default_factory=list)
    epoch_summary: list[dict] = field(default_factory=list)
    summary: list[dict] = field(default_factory=list)
    # replay arrivals folded into the final epoch by the next-boundary
    # rule (see bin_requests) — surfaced, not silently clamped
    clamped_requests: int = 0
    clamped_by_class: dict = field(default_factory=dict)
    # chaos plane: present only when a fault timeline was injected
    fault_summary: Optional[dict] = None
    # guard plane: GuardReport.to_dict() when the run was guarded —
    # every retry / failover / quarantine escalation, with reasons
    guard: Optional[dict] = None
    # (workload variants, severity level) per epoch — populated only
    # with keep_epoch_inputs=True so tests can replay one epoch as a
    # hand-built sweep_grid/evaluate_batch call
    epoch_inputs: Optional[list] = None

    def policy_summary(self, policy: str) -> dict:
        for s in self.summary:
            if s["policy"] == policy:
                return s
        raise KeyError(policy)

    def rollup(self, policy: str) -> FleetRollup:
        return fleet_rollup(self.policy_summary(policy)["total_j"])

    # JSON round-trip for the guard plane's final checkpoint: every
    # field is plain python (floats survive bit-exactly via shortest
    # repr), EXCEPT epoch_inputs, which holds live Workload objects
    def to_dict(self) -> dict:
        if self.epoch_inputs is not None:
            raise ValueError(
                "FleetReport with epoch_inputs (live Workload objects) "
                "cannot be serialized to a checkpoint")
        d = {f.name: getattr(self, f.name) for f in dc_fields(self)}
        for name in ("policies", "class_names", "severity_levels"):
            d[name] = list(d[name])
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "FleetReport":
        kw = {f.name: d.get(f.name) for f in dc_fields(cls)}
        kw["policies"] = tuple(kw["policies"])
        kw["class_names"] = tuple(kw["class_names"])
        kw["severity_levels"] = tuple(float(s)
                                      for s in kw["severity_levels"])
        return cls(**kw)


# --------------------------------------------------------------------------
# the fleet simulator
# --------------------------------------------------------------------------

def _allocate_chips(n_chips: int, demand_chip_s: np.ndarray) \
        -> np.ndarray:
    """Largest-remainder apportionment of ``n_chips`` proportional to
    per-class demand chip-seconds. Zero-demand classes get zero;
    every positive-demand class gets at least one chip when enough
    chips exist (a tiny tenant sharded next to huge ones must not be
    starved to zero capacity — that would make its queue diverge no
    matter what knob the governor picks)."""
    demand_chip_s = np.asarray(demand_chip_s, np.float64)
    pos = demand_chip_s > 0.0
    n_pos = int(pos.sum())
    alloc = np.zeros(len(demand_chip_s), np.int64)
    if n_pos == 0:
        return alloc
    if n_chips <= n_pos:
        # not enough chips for one each: largest demands first
        order = np.argsort(-demand_chip_s, kind="stable")
        alloc[order[:n_chips]] += 1
        return alloc
    alloc[pos] = 1
    rest = n_chips - n_pos
    quota = rest * demand_chip_s / float(demand_chip_s.sum())
    extra = np.floor(quota).astype(np.int64)
    alloc += extra
    leftover = rest - int(extra.sum())
    if leftover > 0:
        order = np.argsort(-(quota - extra), kind="stable")
        alloc[order[:leftover]] += 1
    return alloc


def _severity_index(demand: np.ndarray, n_levels: int) -> np.ndarray:
    """Per-epoch severity-level index from fleet-wide demand: epochs
    are ranked into ``n_levels`` equal quantile bands (busiest band →
    harshest level). Deterministic; single level → all zeros."""
    if n_levels == 1:
        return np.zeros(len(demand), np.int64)
    order = np.argsort(np.argsort(demand, kind="stable"), kind="stable")
    return (order * n_levels // max(1, len(demand))).astype(np.int64)


# cross-call memo for faulted trace variants: value-keyed buckets on
# (class workloads, scenario seed, severity levels), each mapping
# (link-rate row bytes, level index) -> variant list — so replaying
# one timeline through several sweep_fleet calls (chaos campaign
# hysteresis + baseline runs, benchmark repetitions) returns the SAME
# Workload objects and the identity-cached compile/stack pipeline
# stays warm across calls; both levels clear wholesale at the cap
# (distinct link states per campaign number in the dozens)
_FAULT_VARIANTS: dict = {}
_FAULT_VARIANTS_CAP = 4096


def _idle_power_w(pm: PowerModel, policy: str) -> float:
    """Out-of-epoch-load idle power per chip: NoPG chips sit at full
    idle power, ReGate chips deep-idle with everything gateable gated,
    Ideal is the zero-leakage bound (paper §3 / §6.6 idle story)."""
    if policy == "NoPG":
        return pm.idle_chip_w
    if policy == "Ideal":
        return 0.0
    return pm.idle_chip_gated_w()


def sweep_fleet(scenario: FleetScenario, knob_grid=None, *,
                backend: Optional[str] = None, jax_mesh=None,
                keep_epoch_inputs: bool = False,
                faults: Optional[FaultTimeline] = None,
                hysteresis: Optional[Hysteresis] = None,
                guard: Optional[GuardPolicy] = None,
                checkpoint=None) -> FleetReport:
    """Run the fleet simulation; see the module docstring for the
    model. ``knob_grid`` accepts a ``KnobGrid``, a flat sequence of
    ``PolicyKnobs``, or ``None`` (the single default point) —
    identical semantics to every other sweep entry point. ``backend``
    / ``jax_mesh`` resolve through the active ``SweepSession`` when
    ``None``. Deterministic: the same scenario (same seed) produces a
    bit-identical report.

    ``faults`` injects a ``core.faults.FaultTimeline`` (chaos plane):
    per epoch, ``chips_down`` shrinks the allocatable fleet (failover
    re-runs the largest-remainder apportionment over the survivors,
    backlog carries through the capacity dip), link faults re-lower
    every class's collectives onto fault-paced step schedules
    (``ici_topology.collective_schedule`` with the epoch's link-rate
    row, partition-resolved via ``resolve_link_rates``), the epoch's
    ``severity_hint`` escalates the traffic-severity ladder, and
    ``pg_fault`` epochs drop gated policies to their NoPG-equivalent
    evaluation (the degradation ladder's last rung: gating logic
    can't be trusted, so nothing gates and idle burns ungated). The
    all-clean timeline is an exact no-op. ``scenario.shed_backlog_x``
    (finite) adds the shed rung: backlog beyond that multiple of an
    epoch's capacity is refused, not queued.

    ``hysteresis`` switches the governor to the stateful anti-thrash
    rule (``slo.retune_knobs`` with a ``GovernorState`` per policy):
    knobs persist across epochs, retunes respect cooldown/backoff, and
    the per-policy retune count is bounded by the number of fault
    transitions in piecewise-constant scenarios.

    ``guard`` (a ``guard.GuardPolicy``; ``None`` resolves through the
    active ``SweepSession``) runs every batched call through the
    ``GuardedRunner`` — deadline watchdog, retry/backoff, backend
    failover, NaN quarantine — and attaches the escalation log as
    ``report.guard``. ``checkpoint`` (a directory path) enables
    crash-consistent campaign checkpointing: atomic epoch-granular
    snapshots under a ``RunManifest``, so a killed run resumes from
    the last published epoch and yields a **bit-identical** final
    report (every stochastic input replays from explicit seeded
    streams; the loop state itself — backlog, governor state, records
    — round-trips exactly through JSON). A finished run's directory
    short-circuits to the stored final report.
    """
    knobs = as_knob_tuple(knob_grid)
    n_k = len(knobs)
    npu = get_npu(scenario.npu) if isinstance(scenario.npu, str) \
        else scenario.npu
    pols = scenario.policies
    classes = scenario.classes
    n_w, n_p = len(classes), len(pols)
    n_e, dt = scenario.n_epochs, float(scenario.epoch_s)
    pm = PowerModel(npu)
    idle_w = np.array([_idle_power_w(pm, p) for p in pols])

    ft = faults
    if ft is not None:
        if not isinstance(ft, FaultTimeline):
            raise ValueError(
                f"faults must be a core.faults.FaultTimeline, "
                f"got {type(ft)}")
        if int(ft.n_epochs) != n_e:
            raise ValueError(
                f"fault timeline covers {ft.n_epochs} epochs, scenario "
                f"has {n_e}")
        if int(ft.n_chips) != int(scenario.n_chips):
            raise ValueError(
                f"fault timeline was built for {ft.n_chips} chips, "
                f"scenario has {scenario.n_chips}")
    if hysteresis is not None and not isinstance(hysteresis, Hysteresis):
        raise ValueError(
            f"hysteresis must be a slo.Hysteresis, got {type(hysteresis)}")

    # --- guard plane: guarded runner + campaign checkpoint -----------
    if guard is None:
        guard = _session.resolve("guard")
    if guard is not None and not isinstance(guard, GuardPolicy):
        raise ValueError(
            f"guard must be a guard.GuardPolicy, got {type(guard)}")
    gp = guard
    ck = None
    if checkpoint is not None:
        if not isinstance(checkpoint, (str, os.PathLike)):
            raise ValueError(
                f"checkpoint must be a directory path (str or "
                f"os.PathLike), got {type(checkpoint).__name__}")
        if keep_epoch_inputs:
            raise ValueError(
                "checkpoint cannot be combined with keep_epoch_inputs "
                "(epoch inputs hold live Workload objects and are not "
                "serializable)")
        gp = guard if guard is not None else GuardPolicy()
        bk_name = backend if backend is not None \
            else _session.resolve("backend")
        manifest = RunManifest(
            kind="fleet", seed=int(scenario.seed), n_epochs=n_e,
            backend=str(bk_name), knob_digest=digest_of(knobs),
            scenario_digest=digest_of((scenario, ft, hysteresis)),
            severity_levels=scenario.severity_levels, policies=pols)
        ck = CampaignCheckpoint(checkpoint, manifest, keep=2)
        fin = ck.load_final()
        if fin is not None:
            return FleetReport.from_dict(fin)
    runner = None
    if gp is not None:
        runner = GuardedRunner(gp, backend=backend, jax_mesh=jax_mesh,
                               seed=int(scenario.seed))

    def _eval(wls, eval_pols_, step) -> BatchResult:
        if runner is None:
            return evaluate_batch(wls, (npu,), eval_pols_, knobs,
                                  backend=backend, jax_mesh=jax_mesh)
        return runner.evaluate_batch(wls, (npu,), eval_pols_, knobs,
                                     step=step)

    # --- arrivals: per-class counts, (W, E) --------------------------
    counts = np.zeros((n_w, n_e), np.int64)
    clamped_by_class: dict[str, int] = {}
    for ci, cls in enumerate(classes):
        rng = np.random.default_rng((int(scenario.seed), ci))
        counts[ci] = arrival_counts(cls.arrivals, n_e, dt, rng)
        if cls.arrivals.kind == "replay":
            _, ncl = bin_requests(np.asarray(cls.arrivals.times_s),
                                  n_e, dt, with_clamped=True)
            if ncl:
                clamped_by_class[cls.name] = ncl
    requests_total = int(counts.sum())
    rpi = np.array([c.requests_per_invocation for c in classes])
    wl_chips = np.array([max(1, c.workload.n_chips) for c in classes],
                        np.float64)

    # --- traffic variability: one variant set per severity level -----
    # With link faults anywhere in the window, ALL epochs (clean ones
    # too) run on topology-lowered traces, so faulted epochs differ
    # from clean ones purely by their link-rate pacing — and a
    # timeline with no link events changes nothing at all.
    base = [c.workload for c in classes]
    levels = scenario.severity_levels
    chaos_links = ft is not None and ft.has_link_faults
    if chaos_links:
        topos = [topology_for(max(1, wl.n_chips)) for wl in base]
        for cls, tp in zip(classes, topos):
            need = n_links(tp)
            if need > int(ft.n_links):
                raise ValueError(
                    f"fault timeline has {ft.n_links} links but class "
                    f"{cls.name!r} ({tp.kind}{tp.shape}) needs {need}")
        base = [lower_collectives(wl, tp)
                for wl, tp in zip(base, topos)]
    variants = severity_variants(base, levels, seed=scenario.seed)
    by_level = [variants[lv] for lv in levels]
    sev_ix = _severity_index(counts.sum(axis=0).astype(np.float64),
                             len(levels))
    if ft is not None and len(levels) > 1:
        # fault-state severity escalation: the epoch's severity hint
        # (0 clean, ~1 severe) lifts it at least that far up the
        # scenario's level ladder — clean epochs are untouched
        hint_ix = np.ceil(np.minimum(ft.severity_hint, 1.0)
                          * (len(levels) - 1)).astype(np.int64)
        sev_ix = np.maximum(sev_ix, hint_ix)
    # per-epoch faulted trace variants, cached by (link-rate row,
    # severity level) so flapping timelines revisit cached objects and
    # the identity-keyed compile/stack pipeline stays warm; a
    # value-keyed second level (_FAULT_VARIANTS) survives across
    # sweep_fleet calls, so a chaos campaign replaying the same
    # timeline (hysteresis run + thrash baseline, bench repetitions)
    # re-lowers and re-compiles each distinct link state only once
    fault_variants: dict = {}
    if chaos_links:
        # ONE value-keyed (hence Workload-hashing) lookup per call;
        # per-epoch lookups below then key on cheap bytes tuples only
        if len(_FAULT_VARIANTS) >= _FAULT_VARIANTS_CAP:
            _FAULT_VARIANTS.clear()
        shared = _FAULT_VARIANTS.setdefault(
            (tuple(c.workload for c in classes), int(scenario.seed),
             tuple(levels)), {})

    def epoch_workloads(e: int) -> list[Workload]:
        si = int(sev_ix[e])
        if not (chaos_links and ft.link_faulty(e)):
            return by_level[si]
        key = (ft.link_rates[e].tobytes(), si)
        wls = fault_variants.get(key)
        if wls is None:
            wls = shared.get(key)
        if wls is None:
            low = [lower_collectives(
                wl, tp, link_rates=resolve_link_rates(
                    ft.link_rates[e][:n_links(tp)], tp))
                for wl, tp in zip([c.workload for c in classes], topos)]
            # same (seed, stream=si, index) children as
            # severity_variants: a faulted epoch's jitter draws match
            # its clean sibling draw-for-draw, so the only delta is
            # the link pacing itself
            wls = perturb_suite(
                low, severity_plan(float(levels[si])),
                seed=scenario.seed, stream=si,
                names=[f"{wl.name}@sev{si}" for wl in low])
            if len(shared) >= _FAULT_VARIANTS_CAP:
                shared.clear()
            shared[key] = wls
        fault_variants[key] = wls
        return wls

    # --- governor calibration: clean-trace reference runtimes --------
    # (one extra batched call outside the epoch loop; the SLO bound per
    # (class, policy) is slo_relax x the fastest clean knob, fixed for
    # the whole window so the governor chases a stable target)
    cal: BatchResult = _eval(base, pols, 0)
    rt_cal = cal.runtime_s[:, 0, :, :]                    # (W, P, K)
    slo_bound = scenario.slo_relax * rt_cal.min(axis=2)   # (W, P)

    # --- pg-fault fallback: gated policies need the NoPG row ---------
    eval_pols = pols
    if ft is not None and ft.has_pg_faults and "NoPG" not in pols:
        eval_pols = pols + ("NoPG",)
    nopg_ix = eval_pols.index("NoPG") if "NoPG" in eval_pols else None

    # --- stateful governor: deployed knobs persist across epochs -----
    gov_states: Optional[list[GovernorState]] = None
    dep_now: Optional[np.ndarray] = None
    if hysteresis is not None:
        gov_states = [GovernorState.init(n_w, hysteresis) for _ in pols]
        cal_tot = np.zeros((n_w, n_p, n_k))
        for c in COMPONENTS:
            cal_tot += cal.static_j[c][:, 0] + cal.dynamic_j[c][:, 0]
        dep_now = np.argmin(cal_tot, axis=2)              # (W, P)

    report = FleetReport(
        n_epochs=n_e, epoch_s=dt, n_chips=scenario.n_chips,
        npu=npu.name, policies=pols,
        class_names=tuple(c.name for c in classes),
        severity_levels=levels,
        severity_by_epoch=[float(levels[i]) for i in sev_ix],
        requests_total=requests_total,
        clamped_requests=sum(clamped_by_class.values()),
        clamped_by_class=clamped_by_class,
        epoch_inputs=[] if keep_epoch_inputs else None)

    backlog = np.zeros((n_w, n_p))
    eff_hist = np.zeros((n_e, n_w, n_p))
    shed_on = math.isfinite(scenario.shed_backlog_x)

    # --- resume: restore the loop state from the latest snapshot -----
    # (everything NOT restored here — arrivals, severity indices, SLO
    # bounds, trace variants — is a deterministic recomputation from
    # the scenario seed, so replaying the remaining epochs is
    # bit-identical to never having been killed)
    start_e = 0
    if ck is not None:
        snap = ck.load_epoch()
        if snap is not None:
            e0 = int(snap["epoch"])
            if not 0 <= e0 < n_e:
                raise ValueError(
                    f"checkpoint epoch {e0} out of range for a "
                    f"{n_e}-epoch scenario")
            start_e = e0 + 1
            backlog[:] = np.asarray(snap["backlog"], np.float64)
            eff_hist[:e0 + 1] = np.asarray(snap["eff_hist"], np.float64)
            report.records[:] = snap["records"]
            report.epoch_summary[:] = snap["epoch_summary"]
            gov = snap.get("governor")
            if (gov is None) != (gov_states is None):
                raise ValueError(
                    "checkpoint governor state does not match the "
                    "requested hysteresis mode")
            if gov_states is not None:
                dep_now[:] = np.asarray(gov["dep_now"], np.int64)
                for st, d in zip(gov_states, gov["states"]):
                    st.since_retune[:] = np.asarray(d["since_retune"],
                                                    np.int64)
                    st.cooldown[:] = np.asarray(d["cooldown"], np.int64)
                    st.forced_streak[:] = np.asarray(d["forced_streak"],
                                                     np.int64)
                    st.retunes[:] = np.asarray(d["retunes"], np.int64)
            if runner is not None:
                runner.report.events[:] = snap.get("guard_events", [])

    for e in range(start_e, n_e):
        if ck is not None:
            maybe_kill("mid", e)
        wls = epoch_workloads(e)
        # ONE batched sweep call per epoch: the whole active
        # (workload-mix x npu x policy x knob) grid in one pass
        res: BatchResult = _eval(wls, eval_pols, e + 1)
        if keep_epoch_inputs:
            report.epoch_inputs.append((wls, float(levels[sev_ix[e]])))
        rt = res.runtime_s[:, 0, :, :]                    # (W, P', K)
        tot = np.zeros_like(rt)
        for c in COMPONENTS:
            tot += res.static_j[c][:, 0] + res.dynamic_j[c][:, 0]
        down = int(ft.chips_down[e]) if ft is not None else 0
        avail = max(0, scenario.n_chips - down)
        pg_now = ft is not None and bool(ft.pg_fault[e])
        link_now = chaos_links and ft.link_faulty(e)

        for pi, policy in enumerate(pols):
            # pg-fault ladder rung: a gated policy's power-gating
            # control logic is compromised this epoch — it runs (and
            # idles) at the ungated NoPG operating point
            pg_fb = pg_now and policy not in ("NoPG", "Ideal")
            src = nopg_ix if pg_fb else pi
            e_pk, r_pk = tot[:, src, :], rt[:, src, :]    # (W, K)
            idle_w_pi = pm.idle_chip_w if pg_fb else idle_w[pi]
            deployed = np.argmin(e_pk, axis=1) if dep_now is None \
                else dep_now[:, pi]
            demand_inv = counts[:, e] / rpi + backlog[:, pi]
            wi = np.arange(n_w)
            # allocation: proportional to demand chip-time at the
            # deployed knob (the governor re-tunes knobs after chips
            # are placed — placement reacts to demand, not to knobs);
            # failed/draining chips are out of the pool, so failover
            # re-apportions the survivors with the no-starvation floor
            dct = demand_inv * r_pk[wi, deployed] * wl_chips
            chips = _allocate_chips(avail, dct)
            # queueing inflation: load factor rho per knob; a class
            # past its capacity stretches completion proportionally
            with np.errstate(divide="ignore", invalid="ignore"):
                rho = demand_inv[:, None] * r_pk * wl_chips[:, None] \
                    / (chips[:, None] * dt)
            rho = np.where(demand_inv[:, None] > 0,
                           np.where(chips[:, None] > 0, rho, np.inf),
                           0.0)
            eff = r_pk * np.maximum(1.0, rho)             # (W, K)
            if gov_states is None:
                chosen = retune_knobs(e_pk, eff,
                                      slo_bound[:, pi][:, None],
                                      deployed=deployed)
            else:
                chosen = retune_knobs(e_pk, eff,
                                      slo_bound[:, pi][:, None],
                                      deployed=deployed,
                                      hysteresis=hysteresis,
                                      state=gov_states[pi])
                dep_now[:, pi] = chosen
            feas = eff <= slo_bound[:, pi][:, None]
            feas_any = feas.any(axis=1)
            eff_c = eff[wi, chosen]
            violated = eff_c > slo_bound[:, pi]
            eff_hist[e, :, pi] = eff_c
            # SLO-constrained regret: chosen knob's invocation energy
            # vs the cheapest feasible knob this epoch (cheapest
            # overall when nothing is feasible)
            opt_j = np.where(
                feas_any,
                np.min(np.where(feas, e_pk, np.inf), axis=1),
                e_pk.min(axis=1))
            regret = e_pk[wi, chosen] / np.maximum(opt_j, 1e-300) - 1.0
            # service: capacity at the chosen knob, backlog carries
            r_c = r_pk[wi, chosen]
            cap_inv = np.where(r_c > 0,
                               chips * dt / (r_c * wl_chips), 0.0)
            served = np.minimum(demand_inv, cap_inv)
            backlog[:, pi] = demand_inv - served
            shed = np.zeros(n_w)
            if shed_on:
                # degradation ladder, first rung: refuse backlog
                # beyond shed_backlog_x x this epoch's capacity
                limit = scenario.shed_backlog_x * cap_inv
                shed = np.maximum(0.0, backlog[:, pi] - limit)
                backlog[:, pi] -= shed
            busy_s = np.minimum(served * r_c * wl_chips, chips * dt)
            idle_s = np.maximum(0.0, chips * dt - busy_s)
            busy_j = served * e_pk[wi, chosen] * wl_chips
            idle_j = idle_w_pi * idle_s
            spare = avail - int(chips.sum())
            unalloc_j = idle_w_pi * spare * dt
            for ci, cls in enumerate(classes):
                report.records.append({
                    "epoch": e, "class": cls.name,
                    "workload": wls[ci].name, "npu": npu.name,
                    "policy": policy,
                    "severity": float(levels[sev_ix[e]]),
                    **knob_columns(knobs[chosen[ci]],
                                   int(chosen[ci])),
                    "deployed_knob_idx": int(deployed[ci]),
                    "requests": int(counts[ci, e]),
                    "demand_inv": float(demand_inv[ci]),
                    "served_inv": float(served[ci]),
                    "backlog_inv": float(backlog[ci, pi]),
                    "shed_inv": float(shed[ci]),
                    "chips": int(chips[ci]),
                    "runtime_s": float(r_c[ci]),
                    # the underlying sweep cell's per-chip energy at
                    # the chosen knob (one invocation) — ties each
                    # fleet record back to the direct sweep_grid
                    # record it was derived from
                    "inv_total_j": float(e_pk[ci, chosen[ci]]),
                    "inv_opt_j": float(opt_j[ci]),
                    "regret_frac": float(regret[ci]),
                    "eff_runtime_s": float(eff_c[ci]),
                    "slo_bound_s": float(slo_bound[ci, pi]),
                    "slo_violated": bool(violated[ci]),
                    "feasible_exists": bool(feas_any[ci]),
                    "retuned": bool(chosen[ci] != deployed[ci]),
                    "pg_fallback": bool(pg_fb),
                    "utilization": float(busy_s[ci]
                                         / max(chips[ci] * dt, 1e-300))
                    if chips[ci] else 0.0,
                    "busy_j": float(busy_j[ci]),
                    "idle_j": float(idle_j[ci]),
                    "total_j": float(busy_j[ci] + idle_j[ci]),
                })
            report.epoch_summary.append({
                "epoch": e, "policy": policy,
                "severity": float(levels[sev_ix[e]]),
                "requests": int(counts[:, e].sum()),
                "served_inv": float(served.sum()),
                "shed_inv": float(shed.sum()),
                "chips_active": int(chips.sum()),
                "chips_down": down,
                "chips_unallocated": spare,
                "pg_fallback": bool(pg_fb),
                "link_faulted": bool(link_now),
                "unallocated_idle_j": float(unalloc_j),
                "busy_j": float(busy_j.sum()),
                "idle_j": float(idle_j.sum() + unalloc_j),
                "total_j": float(busy_j.sum() + idle_j.sum()
                                 + unalloc_j),
                "violations": int(violated.sum()),
                "retunes": int((chosen != deployed).sum()),
            })

        # epoch boundary: publish the crash-consistent snapshot (async
        # write behind an atomic rename; shallow list copies suffice —
        # the loop only ever appends, never mutates, past records)
        if ck is not None and ((e + 1) % gp.checkpoint_every == 0
                               or e == n_e - 1):
            gov_snap = None
            if gov_states is not None:
                gov_snap = {
                    "dep_now": dep_now.tolist(),
                    "states": [
                        {"since_retune": st.since_retune.tolist(),
                         "cooldown": st.cooldown.tolist(),
                         "forced_streak": st.forced_streak.tolist(),
                         "retunes": st.retunes.tolist()}
                        for st in gov_states]}
            ck.save_epoch(e, {
                "epoch": e,
                "backlog": backlog.tolist(),
                "eff_hist": eff_hist[:e + 1].tolist(),
                "records": list(report.records),
                "epoch_summary": list(report.epoch_summary),
                "governor": gov_snap,
                "guard_events": list(runner.report.events),
            })

    # --- per-policy window totals + carbon roll-up -------------------
    for pi, policy in enumerate(pols):
        recs = [r for r in report.records if r["policy"] == policy]
        eps = [s for s in report.epoch_summary if s["policy"] == policy]
        total_j = math.fsum(r["total_j"] for r in recs) \
            + math.fsum(s["unallocated_idle_j"] for s in eps)
        ru = fleet_rollup(total_j)
        base_rt = np.broadcast_to(
            (slo_bound[:, pi] / scenario.slo_relax)[None, :],
            (n_e, n_w))
        rpi_of = {c.name: float(r) for c, r in zip(classes, rpi)}
        served_req = math.fsum(r["served_inv"] * rpi_of[r["class"]]
                               for r in recs)
        report.summary.append({
            "policy": policy,
            "requests_total": requests_total,
            "served_requests": served_req,
            "backlog_inv_final": float(backlog[:, pi].sum()),
            "busy_j": math.fsum(r["busy_j"] for r in recs),
            "idle_j": math.fsum(r["idle_j"] for r in recs)
            + math.fsum(s["unallocated_idle_j"] for s in eps),
            "total_j": total_j,
            "chip_kwh": ru.chip_kwh,
            "facility_kwh": ru.facility_kwh,
            "co2_kg": ru.co2_kg,
            "cost_usd": ru.cost_usd,
            "slo_violation_rate": runtime_violation_rate(
                eff_hist[:, :, pi], base_rt, scenario.slo_relax),
            "retunes": sum(s["retunes"] for s in eps),
            "j_per_request": total_j / max(1.0, served_req),
            "shed_inv_total": math.fsum(r["shed_inv"] for r in recs),
            "worst_regret_frac": max(
                (r["regret_frac"] for r in recs), default=0.0),
            "pg_fallback_epochs": sum(
                1 for s in eps if s["pg_fallback"]),
        })
    if ft is not None:
        af = ft.any_fault()
        report.fault_summary = {
            "n_transitions": int(ft.n_transitions),
            "faulted_epochs": int(af.sum()),
            "chip_fault_epochs": int((ft.chips_down > 0).sum()),
            "link_fault_epochs": int(
                (ft.link_rates != 1.0).any(axis=1).sum()),
            "pg_fault_epochs": int(ft.pg_fault.sum()),
            "chips_down_max": int(ft.chips_down.max()),
            "repair_epochs": ft.repair_epochs(),
        }
    if runner is not None:
        report.guard = runner.report.to_dict()
    if ck is not None:
        ck.save_final(report.to_dict())
        ck.close()
    return report


# --------------------------------------------------------------------------
# the chaos campaign runner
# --------------------------------------------------------------------------

def _recovery_times(report: FleetReport, timeline: FaultTimeline,
                    policy: str, regret_tol: float) -> list[int]:
    """Epochs-to-recover after each repair (fleet returns to fully
    clean): the first epoch at/after the repair where none of the
    policy's class records violates the SLO and every record's
    SLO-constrained regret is within ``regret_tol`` — i.e. the
    governor is back on (near-)optimal knobs with the queue drained
    enough to meet the bound. A window that never recovers is censored
    at the remaining epoch count.
    """
    ok = np.ones(report.n_epochs, bool)
    for r in report.records:
        if r["policy"] != policy:
            continue
        if r["slo_violated"] or r["regret_frac"] > regret_tol:
            ok[r["epoch"]] = False
    out = []
    for r0 in timeline.repair_epochs():
        rec = next((e for e in range(r0, report.n_epochs) if ok[e]),
                   None)
        out.append((rec - r0) if rec is not None
                   else report.n_epochs - r0)
    return out


def sweep_chaos(scenario: FleetScenario, knob_grid=None, *,
                fault_severities: Sequence[float] = (0.0, 1.0, 2.0),
                hysteresis: Optional[Hysteresis] = None,
                thrash_baseline: bool = True,
                recovery_regret_tol: float = 0.05,
                backend: Optional[str] = None, jax_mesh=None,
                guard: Optional[GuardPolicy] = None,
                checkpoint=None) -> dict:
    """The chaos campaign: seeded fault scenarios × severities ×
    policies through the fleet simulator.

    For each severity the canonical ``faults.fault_plan`` spec is
    realized into a timeline seeded ``(scenario.seed, bits(severity))``
    (the severity's own float64 bit pattern, NOT its list position) —
    per-(chip, link) child streams inside — so scenarios never share
    or shift each other's fault draws: adding or removing a severity
    from the campaign leaves every other severity's timeline
    bit-identical, and ``sweep_fleet`` replays it
    under the anti-thrash hysteresis governor (each epoch still
    exactly one ``evaluate_batch`` call). With ``thrash_baseline``
    (default) every faulted scenario is also run under the stateless
    governor, the thrashing control the anti-thrash invariant is
    measured against.

    Returns ``{"summary": [per (severity, policy) rows], "reports",
    "baseline_reports", "timelines", ...}`` where each summary row
    carries the campaign metrics: worst/mean SLO-constrained regret,
    recovery time after repair (see ``_recovery_times``), retune
    counts vs the fault-transition bound and vs the thrash baseline,
    violation rate, shed volume, and energy/carbon totals.
    Deterministic: same scenario seed → bit-identical campaign.

    ``guard`` / ``checkpoint`` thread the guard plane through every
    fleet run of the campaign (see ``sweep_fleet``). A chaos
    checkpoint directory holds a campaign-level ``RunManifest`` plus
    one sub-run checkpoint per (severity, governor) leg
    (``run<i>_hyst`` / ``run<i>_base``); a SIGKILLed campaign resumes
    mid-leg from that leg's last epoch snapshot, finished legs
    short-circuit to their stored final reports, and the summary rows
    are rebuilt deterministically — the resumed campaign is
    bit-identical to an uninterrupted one.
    """
    sevs = tuple(float(s) for s in fault_severities)
    if not sevs:
        raise ValueError("fault_severities must be non-empty")
    if len(set(sevs)) != len(sevs):
        raise ValueError(f"duplicate fault severities: {sevs}")
    if not (math.isfinite(recovery_regret_tol)
            and recovery_regret_tol >= 0):
        raise ValueError(f"recovery_regret_tol must be >= 0, got "
                         f"{recovery_regret_tol!r}")
    hys = hysteresis if hysteresis is not None else Hysteresis()
    if not isinstance(hys, Hysteresis):
        raise ValueError(f"hysteresis must be a slo.Hysteresis, "
                         f"got {type(hys)}")
    ck = None
    if checkpoint is not None:
        if not isinstance(checkpoint, (str, os.PathLike)):
            raise ValueError(
                f"checkpoint must be a directory path (str or "
                f"os.PathLike), got {type(checkpoint).__name__}")
        bk_name = backend if backend is not None \
            else _session.resolve("backend")
        manifest = RunManifest(
            kind="chaos", seed=int(scenario.seed),
            n_epochs=scenario.n_epochs, backend=str(bk_name),
            knob_digest=digest_of(as_knob_tuple(knob_grid)),
            scenario_digest=digest_of((scenario, hys,
                                       bool(thrash_baseline))),
            severity_levels=scenario.severity_levels,
            fault_severities=sevs, policies=scenario.policies)
        ck = CampaignCheckpoint(checkpoint, manifest, keep=1)
    # the link plane covers the largest per-class topology; smaller
    # classes read a prefix of each epoch's link-rate row
    lmax = max(n_links(topology_for(max(1, c.workload.n_chips)))
               for c in scenario.classes)
    out: dict = {"fault_severities": sevs, "policies": scenario.policies,
                 "seed": int(scenario.seed), "hysteresis": hys,
                 "summary": [], "reports": {}, "baseline_reports": {},
                 "timelines": {}}
    for si, sev in enumerate(sevs):
        sev_key = int(np.float64(sev + 0.0).view(np.uint64))
        tl = build_fault_timeline(
            fault_plan(sev), n_epochs=scenario.n_epochs,
            n_chips=scenario.n_chips, n_links=lmax,
            seed=(int(scenario.seed), sev_key))
        sub_h = sub_b = None
        if ck is not None:
            sub_h = os.path.join(ck.dir, f"run{si}_hyst")
            sub_b = os.path.join(ck.dir, f"run{si}_base")
        rep = sweep_fleet(scenario, knob_grid, backend=backend,
                          jax_mesh=jax_mesh, faults=tl, hysteresis=hys,
                          guard=guard, checkpoint=sub_h)
        out["reports"][sev] = rep
        out["timelines"][sev] = tl
        base = None
        if thrash_baseline:
            base = sweep_fleet(scenario, knob_grid, backend=backend,
                               jax_mesh=jax_mesh, faults=tl,
                               hysteresis=None, guard=guard,
                               checkpoint=sub_b)
            out["baseline_reports"][sev] = base
        for policy in scenario.policies:
            ps = rep.policy_summary(policy)
            recs = [r for r in rep.records if r["policy"] == policy]
            rts = _recovery_times(rep, tl, policy, recovery_regret_tol)
            row = {
                "fault_severity": sev, "policy": policy,
                "n_transitions": int(tl.n_transitions),
                "faulted_epochs": int(tl.any_fault().sum()),
                "retunes": int(ps["retunes"]),
                "worst_regret_frac": float(ps["worst_regret_frac"]),
                "mean_regret_frac": float(
                    np.mean([r["regret_frac"] for r in recs])),
                "slo_violation_rate": float(ps["slo_violation_rate"]),
                "recovery_epochs": rts,
                "recovery_epochs_max": max(rts, default=0),
                "shed_inv_total": float(ps["shed_inv_total"]),
                "pg_fallback_epochs": int(ps["pg_fallback_epochs"]),
                "total_j": float(ps["total_j"]),
                "j_per_request": float(ps["j_per_request"]),
            }
            if base is not None:
                row["baseline_retunes"] = int(
                    base.policy_summary(policy)["retunes"])
            out["summary"].append(row)
    return out
