"""Topology-level ICI traffic model (jitter plane, ISSUE 6).

The workload generators emit each collective as ONE op carrying its total
per-chip wire bytes — a smooth, coarse idle-interval structure that
flatters idle-detection gating. Real collectives run as step schedules
over a chip topology: an all-reduce on an N-chip ring is 2(N-1)
send/receive steps, a 2-D mesh runs a ring phase per axis. This module
lowers collective ops onto such schedules so the ICI busy/idle timeline
seen by the policy engine has the step-level granularity the perturbation
engine (``repro.core.perturb``) then distorts.

Topology shapes mirror ``repro.launch.mesh.make_production_mesh``: small
jobs run a single ring over ``n_chips``; larger jobs a near-square 2-D
mesh (the production ``(16, 16)`` "data" x "model" shape, factored down
to the job size). Everything stays on the ``opgen`` trace plane: the
lowered workload compiles through ``compile_trace`` / ``stack_traces``
and rides the batched/jax sweep kernels unchanged.

Each schedule step is a wire transfer followed by its local staging
work — the HBM read/write of the chunk and (for reduce steps) the VU
add — so the ICI sits genuinely idle between transfers and the lowered
timeline has the step-granular busy/idle alternation the detection
model gates on. Total wire bytes are conserved exactly (NoPG ICI
dynamic energy is invariant); the staging traffic is *added* — the
algorithmic overhead a single fused collective op idealizes away.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, replace
from typing import Optional, Sequence

import numpy as np

from repro.core.hw import NPUSpec, get_npu
from repro.core.opgen import (Op, Workload, compile_trace, segmented_gaps)


@dataclass(frozen=True)
class Topology:
    """A chip interconnect shape: ``("ring", (N,))`` or
    ``("mesh2d", (rows, cols))`` (torus links along each axis)."""

    kind: str                      # "ring" | "mesh2d"
    shape: tuple[int, ...]

    def __post_init__(self):
        if self.kind not in ("ring", "mesh2d"):
            raise ValueError(f"unknown topology kind {self.kind!r}")
        want = 1 if self.kind == "ring" else 2
        if len(self.shape) != want or any(s < 1 for s in self.shape):
            raise ValueError(
                f"{self.kind} topology needs {want} positive dims, "
                f"got {self.shape}")

    @property
    def n_chips(self) -> int:
        return math.prod(self.shape)


def topology_for(n_chips: int, kind: Optional[str] = None) -> Topology:
    """Default topology for an ``n_chips`` job.

    Mirrors the ``launch.mesh`` conventions: up to 8 chips is a single
    ring (one ICI ring per pod slice); beyond that, the most-square 2-D
    factorization — 256 chips gives the production ``(16, 16)`` mesh.
    """
    if n_chips < 1:
        raise ValueError(f"n_chips must be >= 1, got {n_chips}")
    if kind is None:
        kind = "ring" if n_chips <= 8 else "mesh2d"
    if kind == "ring":
        return Topology("ring", (n_chips,))
    r = 1
    for cand in range(math.isqrt(n_chips), 0, -1):
        if n_chips % cand == 0:
            r = cand
            break
    return Topology("mesh2d", (r, n_chips // r))


def n_links(topo: Topology) -> int:
    """Number of directed ICI links in a topology's fault plane.

    Ring of ``n`` chips: ``n`` wrap-around links (chip ``i`` → ``i+1``),
    none when ``n == 1``. 2-D mesh ``(r, c)``: a torus ring per column
    along axis 0 (``r`` links each, ``c`` rings) and per row along
    axis 1 — degenerate axes (size 1) contribute none. The link index
    order is the contract ``collective_schedule`` link-event traces are
    written in: axis-0 rings first (ring-major: ``col*r + pos``), then
    axis-1 (``row*c + pos``).
    """
    if topo.kind == "ring":
        n = topo.shape[0]
        return n if n > 1 else 0
    r, c = topo.shape
    return (r * c if r > 1 else 0) + (r * c if c > 1 else 0)


def _axis_rings(topo: Topology) -> list[list[np.ndarray]]:
    """Per schedule axis, the list of link-index arrays of its parallel
    rings (the ``n_links`` layout). Degenerate axes get no rings."""
    if topo.kind == "ring":
        n = topo.shape[0]
        return [[np.arange(n)] if n > 1 else []]
    r, c = topo.shape
    base = r * c if r > 1 else 0
    ax0 = [j * r + np.arange(r) for j in range(c)] if r > 1 else []
    ax1 = [base + i * c + np.arange(c) for i in range(r)] if c > 1 \
        else []
    return [ax0, ax1]


def _ring_pacing(rates: np.ndarray) -> float:
    """Wire-time stretch of one ring step under per-link rates.

    Every chip forwards its chunk one hop per step, so the step is
    paced by the slowest transfer. A healthy link at ``rate`` takes
    ``1/rate`` of nominal; a down link (rate 0) forces its chunk the
    long way around — store-and-forward over every surviving link of
    the ring (the ring-detour reroute), which is only possible while
    the ring has a single cut. Two simultaneous down links partition
    the ring; no schedule exists, so that raises.
    """
    down = rates <= 0.0
    nd = int(down.sum())
    if nd == 0:
        return float(1.0 / rates.min())
    if nd >= 2:
        raise ValueError(
            f"ring partitioned: {nd} links down simultaneously (a ring "
            f"detour survives one cut; resolve the trace with "
            f"resolve_link_rates first)")
    return float((1.0 / rates[~down]).sum())


def resolve_link_rates(link_rates: np.ndarray, topo: Topology, *,
                       floor: float = 0.05) -> np.ndarray:
    """Make a link-event trace schedulable: within each ring, keep only
    the first (lowest-index) down link down and lift any further down
    links to ``floor`` — LinkGuardian-style, the retransmission/FEC
    path catches the later faults at a crawl before they hard-down, so
    the ring keeps a single cut and the detour reroute stays valid.
    Accepts ``(L,)`` or ``(S, L)`` traces; returns a float64 copy.
    """
    if not (0.0 < floor <= 1.0):
        raise ValueError(f"floor must be in (0, 1], got {floor}")
    r = np.array(link_rates, np.float64, copy=True)
    flat = r.reshape(1, -1) if r.ndim == 1 else r
    for rings in _axis_rings(topo):
        for ring in rings:
            sub = flat[:, ring]
            down = sub <= 0.0
            extra = down & (np.cumsum(down, axis=1) > 1)
            sub[extra] = floor
            flat[:, ring] = sub
    return r


def schedule_kind(op_name: str) -> str:
    """Collective algorithm implied by an op's name (the workload
    generators' naming convention: ``ar_*``/``*_allreduce`` ring
    all-reduce, ``*alltoall``/``*a2a`` all-to-all, ``ag_*``/
    ``*allgather`` all-gather)."""
    n = op_name.lower()
    if "alltoall" in n or "a2a" in n:
        return "all_to_all"
    if "allgather" in n or n.startswith("ag_") or "_ag" in n:
        return "all_gather"
    return "all_reduce"


def _phase_steps(kind: str, n: int) -> int:
    """Ring steps for one phase over ``n`` participants."""
    if n <= 1:
        return 0
    if kind == "all_reduce":
        return 2 * (n - 1)          # reduce-scatter + all-gather
    return n - 1                    # all-gather / all-to-all


def collective_schedule(kind: str, topo: Topology,
                        link_rates: Optional[np.ndarray] = None
                        ) -> np.ndarray:
    """Per-step fractions of a collective op's total per-chip wire bytes.

    Ring: equal steps (``2(N-1)`` for all-reduce, ``N-1`` otherwise).
    2-D mesh: a ring phase along each axis; each axis-``n`` step carries
    ``1/n`` of the buffer, so phase weights are proportional to
    ``steps/n`` and the fractions are normalized to sum to exactly 1.
    Degenerate axes (size 1) contribute no steps; a 1-chip topology has
    no schedule (empty array).

    ``link_rates`` injects a measured link-event trace (LinkGuardian
    style): shape ``(n_links(topo),)`` — or ``(S, n_links)`` for a
    per-step trace — with rate 1 for a healthy link, a value in (0, 1)
    for a degraded one, and 0 for a down link. Each step's weight is
    stretched by the worst ``_ring_pacing`` over that axis's parallel
    rings (slowest transfer paces the step; down links detour the long
    way around the ring), and the result is normalized by the *clean*
    weight sum — an all-ones trace reproduces the clean fractions
    exactly, and fractions under faults sum to >1, the wire-time
    inflation the timeline inherits. Two down links in one ring
    partition it: ``ValueError`` (pre-clean the trace with
    ``resolve_link_rates`` when that must not happen).
    """
    if kind not in ("all_reduce", "all_gather", "all_to_all"):
        raise ValueError(f"unknown collective kind {kind!r}")
    axes = topo.shape if topo.kind == "mesh2d" else (topo.n_chips,)
    weights: list[float] = []
    step_axis: list[int] = []
    for ai, n in enumerate(axes):
        k = _phase_steps(kind, n)
        weights.extend([1.0 / n] * k)
        step_axis.extend([ai] * k)
    w = np.asarray(weights, np.float64)
    if w.size == 0 or link_rates is None:
        return w / w.sum() if w.size else w
    rates = np.asarray(link_rates, np.float64)
    nl = n_links(topo)
    if rates.ndim == 1:
        rates = np.broadcast_to(rates, (w.size, rates.shape[0]))
    if rates.ndim != 2 or rates.shape != (w.size, nl):
        raise ValueError(
            f"link_rates must have shape ({nl},) or ({w.size}, {nl}) "
            f"for {topo.kind}{topo.shape} {kind}, got "
            f"{np.asarray(link_rates).shape}")
    if not np.isfinite(rates).all() or (rates < 0).any() \
            or (rates > 1).any():
        raise ValueError("link_rates must be finite and in [0, 1]")
    rings = _axis_rings(topo)
    clean_sum = w.sum()
    out = w.copy()
    for s in range(w.size):
        pace = max(_ring_pacing(rates[s][ring])
                   for ring in rings[step_axis[s]])
        out[s] *= pace
    return out / clean_sum


def lower_collectives(wl: Workload, topo: Optional[Topology] = None, *,
                      staging: bool = True,
                      link_rates: Optional[np.ndarray] = None
                      ) -> Workload:
    """Expand each collective op into its topology step schedule.

    Pure trace -> trace: returns a NEW ``Workload`` (name suffixed
    ``+topo``) whose collective ops are replaced by per-step pairs —
    the wire transfer (``name/s<j>``, ``bytes_ici`` split by
    ``collective_schedule``) and its local staging op (``name/c<j>``:
    HBM read+write of the chunk, plus the VU reduction add on
    all-reduce steps) during which the ICI idles. Non-collective ops
    pass through untouched. Per-chip wire bytes are conserved exactly
    per op; ``staging=False`` drops the staging ops (pure byte split,
    timeline-equivalent to the fused op). Workloads on one chip (or a
    degenerate topology) are returned re-wrapped but otherwise
    unchanged.

    ``link_rates`` (a ``collective_schedule`` link-event trace) makes
    the step split non-uniform and inflates total wire time by the
    fault pacing; the lowered name gains a ``!`` so faulted variants
    never alias clean ones in identity caches or reports.
    """
    if topo is None:
        topo = topology_for(max(1, wl.n_chips))
    out: list[Op] = []
    for op in wl.ops:
        kind = schedule_kind(op.name)
        frac = (collective_schedule(kind, topo, link_rates)
                if op.collective and op.bytes_ici > 0 else np.zeros(0))
        if frac.size <= 1:
            out.append(op)
            continue
        for j, f in enumerate(frac):
            step = op.bytes_ici * float(f)
            out.append(replace(op, name=f"{op.name}/s{j}",
                               bytes_ici=step))
            if staging:
                out.append(replace(
                    op, name=f"{op.name}/c{j}", bytes_ici=0.0,
                    collective=False, bytes_hbm=2.0 * step,
                    flops_vu=(0.5 * step
                              if kind == "all_reduce" else 0.0)))
    suffix = "+topo" if link_rates is None else "+topo!"
    return Workload(f"{wl.name}{suffix}", wl.kind, tuple(out),
                    n_chips=wl.n_chips,
                    note=f"{wl.note} [{topo.kind}{topo.shape}]".strip())


def ici_busy_idle(wl: Workload, npu: NPUSpec | str = "NPU-D") -> dict:
    """Per-op ICI busy/idle timeline of a workload on one NPU.

    Uses the compiled ``TraceArrays`` service times (the exact arrays the
    policy engine sweeps over): returns ``{"busy_s", "dur_s", "idle_s",
    "gaps_s"}`` where ``busy_s``/``dur_s`` are per-op (count-folded) ICI
    busy time and op duration, ``idle_s`` the per-op ICI idle time, and
    ``gaps_s`` the merged idle-gap lengths (one per ICI-active op plus a
    trailing gap) — the intervals the idle-detection model gates on.
    """
    from repro.core.policies import trace_times
    npu = get_npu(npu) if isinstance(npu, str) else npu
    tr = compile_trace(wl)
    tt = trace_times(tr, npu)
    busy = tt["ici"] * tr.count
    dur = tt["dur"] * tr.count
    idle = np.where(tt["ici"] > 0, 0.0, dur)
    offsets = np.array([0, tr.n_ops], np.int64)
    gaps, _ = segmented_gaps(tt["ici"] > 0, idle, offsets)
    return {"busy_s": busy, "dur_s": dur, "idle_s": idle, "gaps_s": gaps}
