"""Seeded fault-injection timelines (chaos plane, ISSUE 8).

The jitter plane distorts *execution* (op columns); this module injects
*availability* faults — the failure modes the TPU datacenter literature
(Jouppi et al.) and LinkGuardian-style link telemetry report — as
explicit, seeded timelines the fleet simulator replays:

* **chip plane** — MTBF fail/repair cycles per chip plus scheduled
  maintenance drains: per epoch, how many chips are out of service and
  whether any failed chip took its power-gating control logic with it
  (a ``pg_fault`` epoch, during which gated policies must fall back to
  NoPG-equivalent behavior — the graceful-degradation ladder's last
  rung);
* **link plane** — per-ICI-link event traces (flap / degrade / down,
  each with a duration) in the ``ici_topology.collective_schedule``
  link-rate convention: 1 healthy, (0, 1) degraded, 0 down.

Stream discipline follows ``perturb.py`` exactly: every sampler takes
an explicit seed, each chip and each link gets its OWN child stream
(``np.random.default_rng((seed, plane, index))``), and each stream
draws a FIXED count of uniforms (2 per chip-epoch, 3 per link-epoch)
regardless of what the draws decide — so adding chips or links, or
changing one entity's spec, never shifts any other entity's fault
draws, and two timelines built from the same seed are bit-identical.

``fault_plan(severity)`` is the canonical severity axis (mirroring
``perturb.severity_plan``): 0 is the exact no-fault spec, larger values
shorten MTBFs, lengthen repairs, and raise link event rates. The
module is also a CLI (``python -m repro.core.faults --fuzz N``) running
the faults-seeded differential fuzz: the adversarial ISA corpus of
``perturb.differential_fuzz``, but with each program's event count and
stream keyed off one epoch of a fault timeline.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.core.perturb import fault_severity

__all__ = [
    "ChipFaultSpec", "LinkFaultSpec", "FaultSpec", "FaultTimeline",
    "fault_plan", "build_fault_timeline", "chaos_fuzz",
]


def _check(ok: bool, msg: str) -> None:
    if not ok:
        raise ValueError(msg)


def _check_prob(name: str, v: float) -> None:
    _check(isinstance(v, (int, float)) and math.isfinite(v)
           and 0.0 <= v <= 1.0, f"{name} must be in [0, 1], got {v!r}")


def _check_epochs(name: str, v: int) -> None:
    _check(isinstance(v, (int, np.integer)) and v >= 1,
           f"{name} must be a positive integer epoch count, got {v!r}")


@dataclass(frozen=True)
class ChipFaultSpec:
    """Chip-level fault process.

    ``mtbf_epochs`` is the mean epochs between failures of ONE chip
    (per-epoch failure hazard ``1/mtbf``; ``inf`` disables failures).
    A failed chip is out for ``repair_epochs`` epochs.  Every
    ``drain_every`` epochs (0 disables) a maintenance drain takes
    ``drain_frac`` of the fleet out for ``drain_epochs`` — drains are
    scheduled, so they are deterministic, not drawn.  Each failure
    independently corrupts the chip's power-gating control logic with
    probability ``pg_fault_prob``; while any such chip is down the
    epoch is flagged ``pg_fault``.
    """

    mtbf_epochs: float = math.inf
    repair_epochs: int = 4
    drain_every: int = 0
    drain_frac: float = 0.0
    drain_epochs: int = 1
    pg_fault_prob: float = 0.0

    def __post_init__(self):
        _check(isinstance(self.mtbf_epochs, (int, float))
               and not math.isnan(self.mtbf_epochs)
               and self.mtbf_epochs > 0,
               f"mtbf_epochs must be > 0 (inf allowed), "
               f"got {self.mtbf_epochs!r}")
        _check_epochs("repair_epochs", self.repair_epochs)
        _check(isinstance(self.drain_every, (int, np.integer))
               and self.drain_every >= 0,
               f"drain_every must be >= 0, got {self.drain_every!r}")
        _check_prob("drain_frac", self.drain_frac)
        _check_epochs("drain_epochs", self.drain_epochs)
        _check_prob("pg_fault_prob", self.pg_fault_prob)


@dataclass(frozen=True)
class LinkFaultSpec:
    """Per-ICI-link event process (flap / degrade / down).

    Each healthy link draws, per epoch: a hard *down* (rate 0 for
    ``down_epochs``) with probability ``down_prob``; else a *degrade*
    (rate ``degrade_rate`` for ``degrade_epochs``); else a *flap* —
    a short outage (rate 0 for ``flap_epochs``, typically 1). An
    in-event link draws nothing new until it recovers (durations are
    deterministic, so the draw count per link-epoch is fixed anyway).
    """

    flap_prob: float = 0.0
    flap_epochs: int = 1
    degrade_prob: float = 0.0
    degrade_rate: float = 0.5
    degrade_epochs: int = 2
    down_prob: float = 0.0
    down_epochs: int = 4

    def __post_init__(self):
        _check_prob("flap_prob", self.flap_prob)
        _check_epochs("flap_epochs", self.flap_epochs)
        _check_prob("degrade_prob", self.degrade_prob)
        _check(isinstance(self.degrade_rate, (int, float))
               and math.isfinite(self.degrade_rate)
               and 0.0 < self.degrade_rate < 1.0,
               f"degrade_rate must be in (0, 1), "
               f"got {self.degrade_rate!r}")
        _check_epochs("degrade_epochs", self.degrade_epochs)
        _check_prob("down_prob", self.down_prob)
        _check_epochs("down_epochs", self.down_epochs)


@dataclass(frozen=True)
class FaultSpec:
    """A chip-plane plus link-plane fault process."""

    chip: ChipFaultSpec = field(default_factory=ChipFaultSpec)
    link: LinkFaultSpec = field(default_factory=LinkFaultSpec)

    def __post_init__(self):
        if not isinstance(self.chip, ChipFaultSpec):
            raise ValueError(
                f"chip must be a ChipFaultSpec, got {type(self.chip)}")
        if not isinstance(self.link, LinkFaultSpec):
            raise ValueError(
                f"link must be a LinkFaultSpec, got {type(self.link)}")


def fault_plan(severity: float) -> FaultSpec:
    """Canonical fault-severity axis for ``sweep_chaos`` (the chaos
    analogue of ``perturb.severity_plan``).

    Maps a scalar severity (0 = clean, 1 = severe; >1 allowed) onto a
    ``FaultSpec`` with monotonically harsher parameters: shorter chip
    MTBF, longer repairs, scheduled drains from severity 1 up, and
    rising link flap/degrade/down rates. Severity 0 returns the exact
    no-fault spec (all probabilities zero, infinite MTBF).
    """
    if not (isinstance(severity, (int, float))
            and math.isfinite(severity) and severity >= 0.0):
        raise ValueError(f"severity must be >= 0, got {severity!r}")
    if severity == 0.0:
        return FaultSpec()
    s = float(severity)
    return FaultSpec(
        chip=ChipFaultSpec(
            mtbf_epochs=max(16.0, 600.0 / s),
            repair_epochs=2 + int(round(2.0 * min(s, 4.0))),
            drain_every=24 if s >= 1.0 else 0,
            drain_frac=min(0.5, 0.05 * s),
            drain_epochs=2,
            pg_fault_prob=min(1.0, 0.25 * s)),
        link=LinkFaultSpec(
            flap_prob=min(1.0, 0.03 * s),
            flap_epochs=1,
            degrade_prob=min(1.0, 0.02 * s),
            degrade_rate=max(0.25, 1.0 - 0.5 * min(s, 1.0)),
            degrade_epochs=2,
            down_prob=min(1.0, 0.01 * s),
            down_epochs=3))


@dataclass(frozen=True)
class FaultTimeline:
    """A realized fault timeline over ``n_epochs`` epochs.

    ``chips_down[e]`` counts chips out of service (failed + draining,
    capped at ``n_chips``); ``link_rates[e]`` is the ``(n_links,)``
    link-rate row for epoch ``e`` in the ``collective_schedule``
    convention; ``pg_fault[e]`` flags epochs where a failed chip's
    power-gating logic is corrupted; ``severity_hint[e]`` is the
    ``perturb.fault_severity`` value of the epoch's fault state (0 on
    clean epochs).
    """

    n_epochs: int
    n_chips: int
    n_links: int
    chips_down: np.ndarray       # (E,) int64
    link_rates: np.ndarray       # (E, L) float64 in [0, 1]
    pg_fault: np.ndarray         # (E,) bool
    severity_hint: np.ndarray    # (E,) float64

    def __post_init__(self):
        _check_epochs("n_epochs", self.n_epochs)
        _check(isinstance(self.n_chips, (int, np.integer))
               and self.n_chips >= 1,
               f"n_chips must be >= 1, got {self.n_chips!r}")
        _check(isinstance(self.n_links, (int, np.integer))
               and self.n_links >= 0,
               f"n_links must be >= 0, got {self.n_links!r}")
        e, l = int(self.n_epochs), int(self.n_links)
        cd = np.asarray(self.chips_down)
        _check(cd.shape == (e,), f"chips_down must have shape ({e},), "
               f"got {cd.shape}")
        _check(bool((cd >= 0).all() and (cd <= self.n_chips).all()),
               f"chips_down must be in [0, n_chips={self.n_chips}]")
        lr = np.asarray(self.link_rates)
        _check(lr.shape == (e, l), f"link_rates must have shape "
               f"({e}, {l}), got {lr.shape}")
        _check(bool(np.isfinite(lr).all() and (lr >= 0).all()
                    and (lr <= 1).all()),
               "link_rates must be finite and in [0, 1]")
        pg = np.asarray(self.pg_fault)
        _check(pg.shape == (e,) and pg.dtype == np.bool_,
               f"pg_fault must be a ({e},) bool array")
        sh = np.asarray(self.severity_hint)
        _check(sh.shape == (e,) and bool(np.isfinite(sh).all()
                                         and (sh >= 0).all()),
               f"severity_hint must be a finite ({e},) array >= 0")

    @classmethod
    def empty(cls, n_epochs: int, n_chips: int,
              n_links: int = 0) -> "FaultTimeline":
        """The all-clean timeline (exact no-op for ``sweep_fleet``)."""
        e, l = int(n_epochs), int(n_links)
        return cls(e, int(n_chips), l,
                   chips_down=np.zeros(e, np.int64),
                   link_rates=np.ones((e, l), np.float64),
                   pg_fault=np.zeros(e, np.bool_),
                   severity_hint=np.zeros(e, np.float64))

    @property
    def has_chip_faults(self) -> bool:
        return bool(self.chips_down.any())

    @property
    def has_link_faults(self) -> bool:
        return bool((self.link_rates != 1.0).any())

    @property
    def has_pg_faults(self) -> bool:
        return bool(self.pg_fault.any())

    def link_faulty(self, e: int) -> bool:
        return bool((self.link_rates[e] != 1.0).any())

    def any_fault(self) -> np.ndarray:
        """(E,) bool: epoch has any chip, link, or pg fault."""
        return ((self.chips_down > 0) | self.pg_fault
                | (self.link_rates != 1.0).any(axis=1))

    @property
    def n_transitions(self) -> int:
        """Distinct fault-state transitions: epoch boundaries where the
        (chips_down, link_rates row, pg_fault) state changes, counting
        entry into epoch 0 if it is already faulted. The anti-thrash
        bound: a hysteresis governor retunes at most once per
        transition in a piecewise-constant environment."""
        cd, pg, lr = self.chips_down, self.pg_fault, self.link_rates
        n = 1 if self.any_fault()[0] else 0
        for e in range(1, int(self.n_epochs)):
            if (cd[e] != cd[e - 1] or pg[e] != pg[e - 1]
                    or (lr[e] != lr[e - 1]).any()):
                n += 1
        return n

    def repair_epochs(self) -> list[int]:
        """Epochs where the fleet returns to fully clean after at least
        one faulted epoch — the recovery-time measurement anchors."""
        af = self.any_fault()
        return [e for e in range(1, int(self.n_epochs))
                if af[e - 1] and not af[e]]


def _check_seed(seed) -> tuple:
    """Timeline seeds are ints or int tuples — the spawnable key form
    ``np.random.default_rng`` hashes via SeedSequence. A Generator is
    rejected by name: child streams must be derived per (chip, link)
    from the key, not split off one shared stream (that would break
    the independent-streams contract)."""
    if isinstance(seed, (int, np.integer)) and not isinstance(seed, bool):
        return (int(seed),)
    if isinstance(seed, tuple) and seed and all(
            isinstance(s, (int, np.integer)) and not isinstance(s, bool)
            for s in seed):
        return tuple(int(s) for s in seed)
    raise ValueError(
        f"seed must be an int or a non-empty tuple of ints (a "
        f"np.random.Generator is not accepted here: per-(chip, link) "
        f"child streams are keyed off the seed), got {seed!r}")


# stream-plane tags: (seed, plane, index) keys one child Generator per
# entity, so chip i's draws never depend on how many links exist and
# link j's draws never depend on any other link's spec or state
_PLANE_CHIP, _PLANE_LINK, _PLANE_FUZZ = 0, 1, 3


def build_fault_timeline(spec: FaultSpec, *, n_epochs: int,
                         n_chips: int, n_links: int = 0,
                         seed=0) -> FaultTimeline:
    """Realize a ``FaultSpec`` into a seeded ``FaultTimeline``.

    Draw contract (the ``perturb.py`` discipline): chip ``i`` draws
    exactly ``2*n_epochs`` uniforms from ``default_rng((*seed, 0, i))``
    (failure draw + pg-corruption draw per epoch) and link ``j`` draws
    exactly ``3*n_epochs`` from ``default_rng((*seed, 1, j))`` (down /
    degrade / flap per epoch), ALWAYS — whether or not the entity is
    mid-event and regardless of any spec parameter. Durations and
    drains are deterministic. Hence: same seed => bit-identical
    timeline, and each entity's trace is invariant to every other
    entity and to ``n_chips``/``n_links`` growth.
    """
    if not isinstance(spec, FaultSpec):
        raise ValueError(f"spec must be a FaultSpec, got {type(spec)}")
    _check_epochs("n_epochs", n_epochs)
    _check(isinstance(n_chips, (int, np.integer)) and n_chips >= 1,
           f"n_chips must be >= 1, got {n_chips!r}")
    _check(isinstance(n_links, (int, np.integer)) and n_links >= 0,
           f"n_links must be >= 0, got {n_links!r}")
    key = _check_seed(seed)
    e_n, c_n, l_n = int(n_epochs), int(n_chips), int(n_links)
    cs, ls = spec.chip, spec.link

    # chip plane — bulk-draw each chip's full uniform budget up front
    # (fixed call sequence), then scan the fail/repair state over epochs
    u_fail = np.empty((c_n, e_n))
    u_pg = np.empty((c_n, e_n))
    for i in range(c_n):
        rng = np.random.default_rng((*key, _PLANE_CHIP, i))
        u_fail[i] = rng.random(e_n)
        u_pg[i] = rng.random(e_n)
    p_fail = 0.0 if math.isinf(cs.mtbf_epochs) \
        else min(1.0, 1.0 / cs.mtbf_epochs)
    rem = np.zeros(c_n, np.int64)          # epochs of repair remaining
    pg_live = np.zeros(c_n, np.bool_)      # pg logic corrupted while down
    n_drain = int(round(cs.drain_frac * c_n))
    chips_down = np.zeros(e_n, np.int64)
    pg_fault = np.zeros(e_n, np.bool_)
    for e in range(e_n):
        fails = (rem == 0) & (u_fail[:, e] < p_fail)
        rem[fails] = int(cs.repair_epochs)
        pg_live[fails] = u_pg[fails, e] < cs.pg_fault_prob
        draining = (cs.drain_every > 0 and n_drain > 0 and e > 0
                    and (e % cs.drain_every) < cs.drain_epochs)
        down = int((rem > 0).sum()) + (n_drain if draining else 0)
        chips_down[e] = min(down, c_n)
        pg_fault[e] = bool((pg_live & (rem > 0)).any())
        rem = np.maximum(rem - 1, 0)

    # link plane — same shape: 3 bulk draws per link, then a state scan
    u_down = np.empty((l_n, e_n))
    u_deg = np.empty((l_n, e_n))
    u_flap = np.empty((l_n, e_n))
    for j in range(l_n):
        rng = np.random.default_rng((*key, _PLANE_LINK, j))
        u_down[j] = rng.random(e_n)
        u_deg[j] = rng.random(e_n)
        u_flap[j] = rng.random(e_n)
    link_rates = np.ones((e_n, l_n))
    if l_n:
        l_rem = np.zeros(l_n, np.int64)
        l_rate = np.ones(l_n)
        for e in range(e_n):
            free = l_rem == 0
            dn = free & (u_down[:, e] < ls.down_prob)
            dg = free & ~dn & (u_deg[:, e] < ls.degrade_prob)
            fl = free & ~dn & ~dg & (u_flap[:, e] < ls.flap_prob)
            l_rem[dn], l_rate[dn] = int(ls.down_epochs), 0.0
            l_rem[dg], l_rate[dg] = (int(ls.degrade_epochs),
                                     float(ls.degrade_rate))
            l_rem[fl], l_rate[fl] = int(ls.flap_epochs), 0.0
            link_rates[e] = np.where(l_rem > 0, l_rate, 1.0)
            l_rem = np.maximum(l_rem - 1, 0)

    hint = np.array([
        fault_severity(chips_down[e] / c_n, link_rates[e],
                       pg_fault=bool(pg_fault[e]))
        for e in range(e_n)])
    return FaultTimeline(e_n, c_n, l_n, chips_down=chips_down,
                         link_rates=link_rates, pg_fault=pg_fault,
                         severity_hint=hint)


def chaos_fuzz(n_programs: int = 50, seed: int = 0, *,
               n_events: int = 40, npu: str = "NPU-D") -> dict:
    """Faults-seeded differential ISA fuzz.

    Same exact-agreement harness as ``perturb.differential_fuzz``
    (``EventTimeline`` vs ``VLIWTimeline``, hardware auto-gating off
    and on) but the corpus is steered by a fault timeline: program
    ``p`` runs on its own child stream ``(seed, 3, p)`` with its event
    count inflated by epoch ``p``'s ``severity_hint`` — faultier
    epochs fuzz with denser pathological programs, biasing the corpus
    toward the irregular idle structure faulted schedules produce.
    Raises ``AssertionError`` on any divergence; returns corpus stats.
    """
    from repro.core import perturb as pt
    if not (isinstance(n_programs, (int, np.integer)) and n_programs >= 1):
        raise ValueError(f"n_programs must be >= 1, got {n_programs!r}")
    key = _check_seed(seed)
    tl = build_fault_timeline(
        fault_plan(2.0), n_epochs=int(n_programs), n_chips=64,
        n_links=16, seed=(*key, _PLANE_FUZZ))
    stats = {"programs": 0, "runs": 0, "events": 0, "cycles": 0,
             "faulted_programs": int(tl.any_fault().sum()),
             "mismatches": 0, "seed": seed}
    for p in range(int(n_programs)):
        rng = np.random.default_rng((*key, _PLANE_FUZZ, p))
        n_ev = int(round(n_events * (1.0 + tl.severity_hint[p])))
        events, horizon = pt.adversarial_events(rng, n_events=n_ev,
                                                npu=npu)
        stats["programs"] += 1
        stats["events"] += len(events)
        for hw_auto in (False, True):
            kw = dict(pt.FUZZ_KW, hw_auto_gating=hw_auto,
                      initial_modes=dict(pt.FUZZ_KW["initial_modes"]))
            ref = pt.VLIWTimeline(npu=npu, **kw).run(
                pt.expand_events(events, horizon))
            got = pt.EventTimeline(npu=npu, **kw).run(events,
                                                      horizon=horizon)
            diff = pt._exec_mismatch(ref, got)
            if diff is not None:
                stats["mismatches"] += 1
                raise AssertionError(
                    f"executor divergence: seed={seed} program={p} "
                    f"hw_auto={hw_auto}: {diff}")
            stats["runs"] += 1
            stats["cycles"] += ref.cycles
    return stats


def main(argv=None) -> int:
    """CLI smoke entry: ``python -m repro.core.faults --fuzz N``."""
    import argparse
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fuzz", type=int, default=40,
                    help="number of fault-seeded adversarial programs")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--events", type=int, default=40,
                    help="base events per program (scaled by fault "
                         "severity)")
    args = ap.parse_args(argv)
    stats = chaos_fuzz(args.fuzz, args.seed, n_events=args.events)
    print(f"chaos fuzz ok: {stats['programs']} programs "
          f"({stats['faulted_programs']} fault-steered), "
          f"{stats['runs']} runs, {stats['events']} events, "
          f"{stats['cycles']} ref cycles, 0 mismatches "
          f"(seed={stats['seed']})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
