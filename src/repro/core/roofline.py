"""Roofline-term derivation from the compiled dry-run artifact.

Per (arch x shape x mesh) cell:

  compute_s    = HLO_FLOPs / (chips x peak_FLOP/s)
  memory_s     = HLO_bytes / (chips x HBM_bw)
  collective_s = collective_bytes / (chips x link_bw x links)

HLO_FLOPs / bytes / collective bytes come from ``repro.core.hlo.analyze``
on ``compiled.as_text()`` (while-body costs scaled by trip count — XLA's
own cost_analysis counts loop bodies once). All quantities are PER DEVICE
(the HLO is the per-partition program), so the "/ chips" division is
already implicit and the terms below use per-chip peaks directly.
"""
from __future__ import annotations

import json
from dataclasses import asdict, dataclass, field
from typing import Optional

from repro.core.hlo import HloCosts, analyze
from repro.core.hw import TARGET, RooflineTarget


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    n_chips: int
    # per-device quantities from the compiled HLO
    flops: float
    memory_bytes: float
    collective_bytes: dict[str, float]
    # the three terms, seconds
    compute_s: float
    memory_s: float
    collective_s: float
    # analytic model FLOPs (6ND etc.), whole-job, for the usefulness ratio
    model_flops: float = 0.0
    bytes_per_device: float = 0.0      # from memory_analysis (peak usage)
    xla_cost_flops: float = 0.0        # unscaled cross-check
    notes: str = ""

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Perfect-overlap lower bound on step time."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute fraction of the modeled step time: the score.
        (model_flops / chips / peak) / max-term."""
        if self.step_time_s <= 0 or self.model_flops <= 0:
            return 0.0
        ideal = self.model_flops / self.n_chips / TARGET.peak_flops
        return ideal / self.step_time_s

    @property
    def flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPS (per-device-normalized): remat waste."""
        if self.flops <= 0:
            return 0.0
        return (self.model_flops / self.n_chips) / self.flops

    def to_json(self) -> dict:
        d = asdict(self)
        d.update(dominant=self.dominant, step_time_s=self.step_time_s,
                 roofline_fraction=self.roofline_fraction,
                 flops_ratio=self.flops_ratio)
        return d


def report_from_hlo(text: str, *, arch: str, shape: str, mesh: str,
                    n_chips: int, model_flops: float = 0.0,
                    bytes_per_device: float = 0.0,
                    xla_cost_flops: float = 0.0,
                    target: RooflineTarget = TARGET,
                    notes: str = "") -> RooflineReport:
    c: HloCosts = analyze(text)
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh, n_chips=n_chips,
        flops=c.flops, memory_bytes=c.memory_bytes,
        collective_bytes=c.collective_bytes,
        compute_s=c.flops / target.peak_flops,
        memory_s=c.memory_bytes / target.hbm_bw,
        collective_s=c.total_collective_bytes
        / (target.ici_bw_link * target.ici_links),
        model_flops=model_flops,
        bytes_per_device=bytes_per_device,
        xla_cost_flops=xla_cost_flops,
        notes=notes)


def model_flops_estimate(cfg, shape) -> float:
    """Whole-job useful FLOPs: 6ND train, 2ND decode/prefill (MoE: active).
    Attention flops added explicitly (they are not in the 6ND rule)."""
    from repro.models.registry import count_params
    n_active = count_params(cfg, active_only=True)
    n_embed = cfg.vocab_padded * cfg.d_model
    n_body = n_active - n_embed * (1 if cfg.tie_embeddings else 2)
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        tokens = B  # one step
        mult = 2.0
        attn = 2.0 * B * S * cfg.n_layers * (
            0 if cfg.family == "ssm" else
            max(1, cfg.n_heads) * max(1, cfg.head_dim)) * 2
    else:
        tokens = B * S
        mult = 6.0 if shape.kind == "train" else 2.0
        # causal attention: S/2 average context
        attn_per_layer = 2.0 * tokens * (S / 2) * max(1, cfg.n_heads) \
            * max(1, cfg.head_dim) * 2
        if cfg.family == "ssm":
            attn_per_layer = 0.0
        attn = attn_per_layer * cfg.n_layers * (3 if shape.kind == "train"
                                                else 1)
    # lm_head + embed
    head = 2.0 * tokens * n_embed * (3 if shape.kind == "train" else 1)
    return mult * tokens * n_body + attn + head
