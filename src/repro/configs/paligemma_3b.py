"""paligemma-3b — VLM: SigLIP vision tower (STUB) + gemma decoder backbone.

[arXiv:2407.07726; hf] 18L d_model=2048 8H (GQA kv=1, MQA) d_ff=16384
vocab=257216. The SigLIP patch frontend is a STUB: input_specs() provides
precomputed patch embeddings (B, 256 patches, frontend_dim) that are
projected and prepended to the text sequence.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=257216,
    tie_embeddings=True,
    act="gelu_glu",
    frontend="vision",
    frontend_dim=1152,  # SigLIP-So400m embedding width (stubbed)
    frontend_seq=256,   # 224x224 / 14x14 patches
    source="[arXiv:2407.07726; hf]",
))
