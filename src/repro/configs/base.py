"""Architecture & shape configuration system.

Every assigned architecture is a frozen ``ArchConfig``; every input-shape
cell is a ``ShapeConfig``. The dry-run, benchmarks, and the power plane all
consume these objects, so the exact published dimensions live in exactly one
place (``src/repro/configs/<id>.py``).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell (seq_len x global_batch)."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


# The four assigned LM shapes. ``decode_*``/``long_*`` lower ``serve_step``
# (one new token against a KV cache of ``seq_len``), not ``train_step``.
SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    n_shared_experts: int = 0
    first_dense_layers: int = 0  # leading layers that use a dense MLP instead
    capacity_factor: float = 2.0
    group_size: int = 1024  # GShard dispatch group size (tokens)


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    n_groups: int = 1
    chunk: int = 256  # SSD chunk length

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 multi-head latent attention."""

    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    # attention features
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    # block families
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    mla: Optional[MLAConfig] = None
    # hybrid (hymba): sliding-window attention everywhere except global layers
    sliding_window: int = 0  # 0 => full attention
    n_global_layers: int = 0  # leading/middle/trailing full-attention layers
    # structure
    encoder_only: bool = False
    tie_embeddings: bool = True
    act: str = "silu"  # mlp activation: silu(SwiGLU) | gelu (plain 2-layer)
    norm_eps: float = 1e-6
    # modality frontend stub: input_specs() provides precomputed embeddings
    frontend: Optional[str] = None  # None | "audio" | "vision"
    frontend_dim: int = 0  # embedding dim produced by the stub frontend
    frontend_seq: int = 0  # frontend tokens prepended (vlm patches)
    source: str = ""  # provenance note [source; verified-tier]

    # ---- derived ----
    @property
    def vocab_padded(self) -> int:
        """Vocab rounded up for 16-way TP divisibility (loss masks padding)."""
        return _round_up(self.vocab_size, 256)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def uses_full_attention_only(self) -> bool:
        """True if the arch has quadratic attention with no sub-quadratic path."""
        return (not self.is_attention_free) and self.sliding_window == 0

    @property
    def q_dim(self) -> int:
        if self.mla:
            return self.n_heads * (self.mla.nope_head_dim + self.mla.rope_head_dim)
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        if self.mla:
            return self.kv_lora_dim
        return self.n_kv_heads * self.head_dim

    @property
    def kv_lora_dim(self) -> int:
        assert self.mla is not None
        return self.mla.kv_lora_rank + self.mla.rope_head_dim

    def param_count(self) -> int:
        """Analytic parameter count (exact for our implementation)."""
        from repro.models.registry import count_params

        return count_params(self)

    def active_param_count(self) -> int:
        from repro.models.registry import count_params

        return count_params(self, active_only=True)

    def supported_shapes(self) -> dict[str, str]:
        """shape name -> "ok" or "SKIP(<reason>)" for the 4-cell row."""
        out = {}
        for s in SHAPES.values():
            if s.is_decode and self.encoder_only:
                out[s.name] = "SKIP(encoder-only: no decode step)"
            elif s.name == "long_500k" and self.uses_full_attention_only:
                out[s.name] = "SKIP(full-attention arch: 500k needs sub-quadratic)"
            else:
                out[s.name] = "ok"
        return out

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        kw: dict = dict(
            n_layers=2,
            d_model=64,
            n_heads=4,
            n_kv_heads=max(1, min(self.n_kv_heads, 2)),
            d_ff=128,
            vocab_size=256,
            head_dim=16,
            frontend_dim=32 if self.frontend else 0,
            frontend_seq=4 if self.frontend == "vision" else 0,
        )
        if self.moe:
            kw["moe"] = dataclasses.replace(
                self.moe, n_experts=4, top_k=2, d_ff_expert=32,
                group_size=32, first_dense_layers=min(1, self.moe.first_dense_layers),
            )
        if self.ssm:
            kw["ssm"] = dataclasses.replace(
                self.ssm, d_state=16, head_dim=16, chunk=8)
        if self.mla:
            kw["mla"] = MLAConfig(kv_lora_rank=32, q_lora_rank=48,
                                  rope_head_dim=8, nope_head_dim=16, v_head_dim=16)
        if self.sliding_window:
            kw["sliding_window"] = 16
        return dataclasses.replace(self, **kw)


_REGISTRY: dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ArchConfig:
    if not _REGISTRY:
        _load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs() -> list[str]:
    if not _REGISTRY:
        _load_all()
    return sorted(_REGISTRY)


ALL_ARCH_MODULES = [
    "mamba2_780m", "qwen3_32b", "qwen2_5_14b", "qwen2_5_3b", "qwen1_5_4b",
    "hymba_1_5b", "hubert_xlarge", "granite_moe_1b", "deepseek_v2_236b",
    "paligemma_3b",
]


def _load_all() -> None:
    import importlib

    for mod in ALL_ARCH_MODULES:
        importlib.import_module(f"repro.configs.{mod}")
