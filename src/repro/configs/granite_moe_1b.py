"""granite-moe-1b-a400m — fine-grained MoE, 32 experts top-8.

[hf:ibm-granite/granite-3.0-1b-a400m-base; hf] 24L d_model=1024 16H (GQA kv=8)
d_ff=512 per expert, vocab=49155.
"""
from repro.configs.base import ArchConfig, MoEConfig, register

CONFIG = register(ArchConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49155,
    moe=MoEConfig(n_experts=32, top_k=8, d_ff_expert=512, n_shared_experts=0,
                  capacity_factor=2.0, group_size=1024),
    tie_embeddings=True,
    act="silu",
    source="[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]",
))
