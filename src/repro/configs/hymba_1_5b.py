"""hymba-1.5b — hybrid: parallel attention + mamba heads in each block.

[arXiv:2411.13676; hf] 32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001
ssm_state=16. Sliding-window attention except 3 global (full-attention)
layers (first/middle/last), per the Hymba paper.
"""
from repro.configs.base import ArchConfig, SSMConfig, register

CONFIG = register(ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    ssm=SSMConfig(d_state=16, head_dim=64, expand=2, conv_width=4, n_groups=1,
                  chunk=256),
    sliding_window=2048,
    n_global_layers=3,
    tie_embeddings=True,
    act="silu",
    source="[arXiv:2411.13676; hf]",
))
