"""deepseek-v2-236b — MLA + fine-grained MoE (2 shared + 160 routed, top-6).

[arXiv:2405.04434; hf] 60L d_model=5120 128H, MLA kv_lora=512 (rope_dim=64,
nope_dim=128, v_dim=128, q_lora=1536), d_ff=1536 per routed expert,
vocab=102400. First layer uses a dense MLP (d_ff=12288), per the paper.
"""
from repro.configs.base import ArchConfig, MLAConfig, MoEConfig, register

CONFIG = register(ArchConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    head_dim=128,
    d_ff=12288,  # dense-MLP width for the leading dense layer
    vocab_size=102400,
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536, rope_head_dim=64,
                  nope_head_dim=128, v_head_dim=128),
    moe=MoEConfig(n_experts=160, top_k=6, d_ff_expert=1536, n_shared_experts=2,
                  first_dense_layers=1, capacity_factor=2.0, group_size=1024),
    tie_embeddings=False,
    act="silu",
    source="[arXiv:2405.04434; hf]",
))
