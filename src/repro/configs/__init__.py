from repro.configs.base import (
    ALL_ARCH_MODULES, SHAPES, ArchConfig, MLAConfig, MoEConfig, ShapeConfig,
    SSMConfig, get_arch, list_archs, register,
)

__all__ = [
    "ALL_ARCH_MODULES", "SHAPES", "ArchConfig", "MLAConfig", "MoEConfig",
    "ShapeConfig", "SSMConfig", "get_arch", "list_archs", "register",
]
