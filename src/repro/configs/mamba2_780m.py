"""mamba2-780m — SSD (state-space duality), attention-free.

[arXiv:2405.21060; unverified] 48L d_model=1536 d_ff=0 vocab=50280 ssm_state=128.
Mamba-2 block: expand=2 (d_inner=3072), headdim=64 -> 48 SSD heads, ngroups=1.
"""
from repro.configs.base import ArchConfig, SSMConfig, register

CONFIG = register(ArchConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    head_dim=0,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, conv_width=4, n_groups=1,
                  chunk=256),
    tie_embeddings=True,
    act="silu",
    source="[arXiv:2405.21060; unverified]",
))
