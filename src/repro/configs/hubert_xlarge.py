"""hubert-xlarge — encoder-only audio transformer (w2v2-style backbone).

[arXiv:2106.07447; unverified] 48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504.
The convolutional waveform frontend is a STUB: input_specs() provides
precomputed frame embeddings (B, T, frontend_dim).
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab_size=504,
    encoder_only=True,
    tie_embeddings=False,
    act="gelu",
    frontend="audio",
    frontend_dim=512,  # conv feature extractor output dim (stubbed)
    source="[arXiv:2106.07447; unverified]",
))
