"""Gradient compression for the DP reduction path.

Two codecs (selected by ``make_train_step(grad_compression=...)``):

* ``"bf16"``  — stateless cast (2 bytes/grad on the wire).
* ``"int8"``  — per-tensor symmetric int8 quantization WITH error
  feedback: the quantization residual is carried in the optimizer-adjacent
  state and added back before the next step's quantization, so the
  compression error telescopes instead of accumulating (1 byte/grad on
  the wire; standard deep-gradient-compression practice).

The decompressed gradients feed the normal fp32 AdamW math.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp


def quantize_int8(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def init_error_feedback(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_grads(grads, codec: Optional[str], ef_state=None):
    """Returns (decompressed_grads, new_ef_state). With pjit the reduction
    collective operates on the compressed representation's dtype."""
    if codec is None:
        return grads, ef_state
    if codec == "bf16":
        out = jax.tree.map(
            lambda g: g.astype(jnp.bfloat16).astype(jnp.float32), grads)
        return out, ef_state
    if codec == "int8":
        assert ef_state is not None, "int8 codec needs error feedback"
        flat_g, treedef = jax.tree.flatten(grads)
        flat_e = jax.tree.leaves(ef_state)
        outs, errs = [], []
        for g, e in zip(flat_g, flat_e):
            corrected = g.astype(jnp.float32) + e
            q, scale = quantize_int8(corrected)
            deq = dequantize_int8(q, scale)
            outs.append(deq)
            errs.append(corrected - deq)
        return (jax.tree.unflatten(treedef, outs),
                jax.tree.unflatten(treedef, errs))
    raise ValueError(f"unknown codec {codec!r}")
