"""Sharded AdamW with global-norm clipping and a cosine schedule.

Pure-JAX (no optax dependency). Moment dtype is configurable: ``bfloat16``
moments halve optimizer-state HBM for the very large configs (deepseek-v2
needs it to fit 16 GB/chip on the single-pod mesh — see EXPERIMENTS.md
§Dry-run); fp32 params remain the source of truth.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr_peak: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: Any = jnp.float32


def cosine_lr(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(1.0, cfg.warmup_steps)
    frac = (step - cfg.warmup_steps) / jnp.maximum(
        1.0, cfg.total_steps - cfg.warmup_steps)
    frac = jnp.clip(frac, 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    lr = jnp.where(step < cfg.warmup_steps, warm, 0.1 + 0.9 * cos)
    return cfg.lr_peak * lr


def adamw_init(params, cfg: AdamWConfig):
    zeros = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(grads, opt_state, params, cfg: AdamWConfig):
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    lr = cosine_lr(cfg, step)

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))

    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        mh = m32 / c1
        vh = v32 / c2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return newp, m32.astype(cfg.moment_dtype), v32.astype(cfg.moment_dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, {
        "lr": lr, "grad_norm": gnorm}
