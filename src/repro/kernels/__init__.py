"""Pallas TPU kernels (interpret=True on CPU) + jnp oracles.

gated_matmul     — zero-tile skipping (the paper's SA gating, TPU-native)
sa_occupancy     — per-op SA PE-occupancy closed form (the sweep plane's
                   on-device ``gating_stats_batch``; traced SA width)
flash_attention  — causal block-skipping online-softmax attention
ssd_scan         — chunked SSD with VMEM-carried state
decode_attention — single-token attention, cache_len block skipping
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
