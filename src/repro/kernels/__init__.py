"""Pallas TPU kernels (interpret=True on CPU) + jnp oracles.

gated_matmul     — zero-tile skipping (the paper's SA gating, TPU-native)
flash_attention  — causal block-skipping online-softmax attention
ssd_scan         — chunked SSD with VMEM-carried state
decode_attention — single-token attention, cache_len block skipping
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
