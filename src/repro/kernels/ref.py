"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ref_sa_occupancy(mm_m, mm_k, mm_n, saw, weight_load_cycles=None) \
        -> dict:
    """Pure-jnp SA PE-occupancy closed form — the oracle for the Pallas
    ``sa_occupancy`` kernel, and the default on-device occupancy pass of
    the jax sweep backend. Delegates to the backend-neutral
    ``core.sa_gating.gating_stats_batch_xp`` with ``xp=jnp``."""
    from repro.core.sa_gating import gating_stats_batch_xp
    return gating_stats_batch_xp(mm_m, mm_k, mm_n, saw,
                                 weight_load_cycles, xp=jnp)


def ref_matmul(x: jax.Array, w: jax.Array) -> jax.Array:
    """[M,K] x [K,N] in f32 accumulation."""
    return jnp.dot(x.astype(jnp.float32),
                   w.astype(jnp.float32)).astype(x.dtype)


def ref_attention(q, k, v, *, causal: bool = True,
                  scale=None) -> jax.Array:
    """q/k/v: (B, S, H, D) (same head count); plain softmax attention."""
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    scale = scale if scale is not None else D ** -0.5
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32) * scale,
                   k.astype(jnp.float32))
    if causal:
        mask = jnp.arange(Sk)[None, :] <= jnp.arange(Sq)[:, None]
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)


def ref_ssd(x, dt, A, B, C) -> tuple[jax.Array, jax.Array]:
    """Naive sequential SSD recurrence (the ground truth).

    x: (BH, S, P); dt: (BH, S); A: (BH,); B/C: (BH, S, N).
    h_{t} = exp(dt_t A) h_{t-1} + dt_t * B_t (outer) x_t ;  y_t = C_t . h_t
    Returns y: (BH, S, P) and final state (BH, P, N).
    """
    BH, S, P = x.shape
    N = B.shape[-1]

    def step(h, inp):
        xt, dtt, bt, ct = inp
        decay = jnp.exp(dtt * A)  # (BH,)
        h = h * decay[:, None, None] + (dtt[:, None] * xt)[:, :, None] \
            * bt[:, None, :]
        y = jnp.einsum("bpn,bn->bp", h, ct)
        return h, y

    h0 = jnp.zeros((BH, P, N), jnp.float32)
    xs = (x.astype(jnp.float32).transpose(1, 0, 2),
          dt.astype(jnp.float32).T,
          B.astype(jnp.float32).transpose(1, 0, 2),
          C.astype(jnp.float32).transpose(1, 0, 2))
    h, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2).astype(x.dtype), h
