"""Single-token (decode) attention kernel against a paged KV cache.

The decode cells are HBM-bound: the step reads the whole KV cache once.
This kernel is the decode-side analogue of ReGate's N/K-underutilization
gating (paper Fig 10): cache blocks BEYOND ``cache_len`` are never
touched — ``@pl.when`` skips the block's loads and MACs entirely, the
same way the SA's prefix bitmaps power off dead columns. The pure-JAX
path masks them instead (full cache read every step).

Layout: q (BH, D); k/v caches (BH, S, D); grid (BH, S/bk) with the kv
dim sequential; running softmax state in VMEM scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            n_k: int, bk: int, scale: float):
    ki = pl.program_id(1)
    cache_len = len_ref[0]

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # block skip: the whole block is beyond the live cache
    @pl.when(ki * bk <= cache_len)
    def _block():
        q = q_ref[0].astype(jnp.float32) * scale            # (1, D)
        k = k_ref[0].astype(jnp.float32)                    # (bk, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
        s = s + jnp.where(k_pos <= cache_len, 0.0, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == n_k - 1)
    def _done():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bk", "interpret"))
def decode_attention_p(q: jax.Array, k_cache: jax.Array,
                       v_cache: jax.Array, cache_len: jax.Array, *,
                       bk: int = 512, interpret: bool = True) -> jax.Array:
    """q: (BH, D); caches: (BH, S, D); cache_len: () int32.

    Attends to cache positions [0, cache_len]. Returns (BH, D)."""
    BH, D = q.shape
    S = k_cache.shape[1]
    assert S % bk == 0, (S, bk)
    nk = S // bk
    scale = D ** -0.5
    lens = jnp.broadcast_to(cache_len.astype(jnp.int32), (1,))
    out = pl.pallas_call(
        functools.partial(_kernel, n_k=nk, bk=bk, scale=scale),
        grid=(BH, nk),
        in_specs=[
            pl.BlockSpec((1,), lambda b, ki: (0,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, D), lambda b, ki: (b, 0, 0)),
            pl.BlockSpec((1, bk, D), lambda b, ki: (b, ki, 0)),
            pl.BlockSpec((1, bk, D), lambda b, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, D), lambda b, ki: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, 1, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, D), jnp.float32),
        ],
        interpret=interpret,
    )(lens, q[:, None, :], k_cache, v_cache)
    return out[:, 0, :]
