"""Blocked causal attention kernel with causal block skipping.

The prefill cells are attention-dominated; the pure-JAX flash path scans
the full (q_block x kv_block) rectangle and relies on masking, paying ~2x
the useful FLOPs for causal attention. This kernel predicates each kv
block with ``@pl.when(block is not fully masked)`` — the MXU never sees
the upper triangle. (On-chip this is the dynamic-energy/latency analogue
of ReGate's SA gating: work that the mask would zero is never issued.)

Layout: q/k/v (BH, S, D) — batch x heads pre-flattened by ops.py.
Grid (BH, nq, nk); nk innermost and sequential; online-softmax running
state (m, l, acc) in VMEM scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            causal: bool, n_k: int, bq: int, bk: int, scale: float,
            seq_len: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal block skip: all q positions < all kv positions => fully masked
    run = True
    if causal:
        run = ki * bk <= qi * bq + bq - 1

    @pl.when(run)
    def _block():
        q = q_ref[0].astype(jnp.float32) * scale          # (bq, D)
        k = k_ref[0].astype(jnp.float32)                  # (bk, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)           # (bq, bk)
        q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        ok = k_pos < seq_len
        if causal:
            ok = ok & (k_pos <= q_pos)
        s = s + jnp.where(ok, 0.0, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == n_k - 1)
    def _done():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "bq", "bk", "interpret"))
def flash_attention_p(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True, bq: int = 128, bk: int = 128,
                      interpret: bool = True) -> jax.Array:
    """q/k/v: (BH, S, D). Returns (BH, S, D)."""
    BH, S, D = q.shape
    Sk = k.shape[1]
    assert S % bq == 0 and Sk % bk == 0, (S, Sk, bq, bk)
    nq, nk = S // bq, Sk // bk
    scale = D ** -0.5
    return pl.pallas_call(
        functools.partial(_kernel, causal=causal, n_k=nk, bq=bq, bk=bk,
                          scale=scale, seq_len=Sk),
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, bk, D), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, bk, D), lambda b, qi, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
