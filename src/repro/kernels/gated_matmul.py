"""Gated matmul — the TPU-native analogue of ReGate's spatial SA gating.

The paper powers off SA rows/columns holding only zero weights (detected
by the col_nz/row_nz prefix bitmaps, Fig 12). Software on a real TPU cannot
gate PEs, but it CAN skip the MXU work and VMEM traffic of weight tiles
that are entirely zero — converting the paper's *static*-power saving into
a dynamic-energy + latency saving, which is the only lever software has.

The kernel takes a per-(K-tile, N-tile) nonzero bitmap (computed once per
weight tensor by ``ops.gated_matmul``) and predicates the dot with
``@pl.when``. N/K-underutilized matmuls that a compiler would zero-pad to
the 128-lane grid (the paper's Fig 10 cases 2 and 3) skip the padded tiles
entirely.

Grid: (M/bm, N/bn, K/bk), K innermost; f32 accumulator in VMEM scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(bitmap_ref, x_ref, w_ref, o_ref, acc_ref, *, n_k: int):
    ki = pl.program_id(2)
    ni = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    nz = bitmap_ref[ki, ni]

    @pl.when(nz != 0)
    def _compute():
        acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                                preferred_element_type=jnp.float32)

    @pl.when(ki == n_k - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def gated_matmul_p(x: jax.Array, w: jax.Array, bitmap: jax.Array, *,
                   bm: int = 128, bn: int = 128, bk: int = 128,
                   interpret: bool = True) -> jax.Array:
    """x: (M, K); w: (K, N); bitmap: (K/bk, N/bn) int32 tile-nonzero map."""
    M, K = x.shape
    K2, N = w.shape
    assert K == K2
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K)
    n_k = K // bk
    grid = (M // bm, N // bn, n_k)
    return pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((K // bk, N // bn), lambda mi, ni, ki: (0, 0)),
            pl.BlockSpec((bm, bk), lambda mi, ni, ki: (mi, ki)),
            pl.BlockSpec((bk, bn), lambda mi, ni, ki: (ki, ni)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda mi, ni, ki: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(bitmap, x, w)
