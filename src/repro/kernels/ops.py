"""jit'd public wrappers around the Pallas kernels.

On this CPU container the kernels run with ``interpret=True`` (the kernel
body executes in Python, validating the BlockSpec tiling and predication
logic); on a real TPU set ``interpret=False`` (the default flips on TPU
backends automatically).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention_p
from repro.kernels.gated_matmul import gated_matmul_p
from repro.kernels.ssd_scan import ssd_scan_p


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def tile_nonzero_bitmap(w: jax.Array, bk: int, bn: int) -> jax.Array:
    """Per-(K-tile, N-tile) any-nonzero map — the tile-level analogue of
    the paper's col_nz/row_nz PE bitmaps (Fig 12)."""
    K, N = w.shape
    t = w.reshape(K // bk, bk, N // bn, bn)
    return (jnp.abs(t).max(axis=(1, 3)) > 0).astype(jnp.int32)


def gated_matmul(x: jax.Array, w: jax.Array, *, bm: int = 128,
                 bn: int = 128, bk: int = 128,
                 interpret: bool | None = None) -> jax.Array:
    """[M,K] x [K,N] matmul that skips all-zero weight tiles."""
    if interpret is None:
        interpret = _default_interpret()
    bitmap = tile_nonzero_bitmap(w, bk, bn)
    return gated_matmul_p(x, w, bitmap, bm=bm, bn=bn, bk=bk,
                          interpret=interpret)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, bq: int = 128, bk: int = 128,
                    interpret: bool | None = None) -> jax.Array:
    """q/k/v: (B, S, H, D) with equal head counts (broadcast GQA first).
    Returns (B, S, H, D)."""
    if interpret is None:
        interpret = _default_interpret()
    B, S, H, D = q.shape
    fold = lambda t: t.transpose(0, 2, 1, 3).reshape(B * H, t.shape[1], D)
    o = flash_attention_p(fold(q), fold(k), fold(v), causal=causal,
                          bq=bq, bk=bk, interpret=interpret)
    return o.reshape(B, H, S, D).transpose(0, 2, 1, 3)


def ssd_scan(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
             C: jax.Array, *, chunk: int = 128,
             interpret: bool | None = None):
    """Chunked SSD. x: (BH, S, P); dt: (BH, S); A: (BH,); B/C: (BH, S, N).
    Returns (y, final_state)."""
    if interpret is None:
        interpret = _default_interpret()
    return ssd_scan_p(x, dt, A, B, C, chunk=chunk, interpret=interpret)
