"""Chunked SSD (state-space duality) kernel for the mamba2/hymba cells.

Implements the Mamba-2 chunked algorithm: the intra-chunk part in its
quadratic "dual" form (MXU-friendly (Q x Q) x (Q x P) matmuls), the
inter-chunk part as a sequential state recurrence carried in VMEM scratch
across the chunk grid dimension. The state never round-trips to HBM
between chunks — the kernel's whole point on TPU.

Layout: x (BH, S, P), dt (BH, S), A (BH, 1), B/C (BH, S, N).
Grid (BH, n_chunks); chunks sequential (innermost).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, h_ref, state_ref, *,
            n_chunks: int, Q: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    a = a_ref[0, 0].astype(jnp.float32)
    dt = dt_ref[0].astype(jnp.float32)                    # (Q,)
    xq = x_ref[0].astype(jnp.float32)                     # (Q, P)
    bq = b_ref[0].astype(jnp.float32)                     # (Q, N)
    cq = c_ref[0].astype(jnp.float32)                     # (Q, N)

    dA = dt * a                                           # (Q,) negative
    cum = jnp.cumsum(dA)                                  # (Q,)
    # intra-chunk dual form; mask the log BEFORE exp (overflow safety)
    li = cum[:, None] - cum[None, :]                      # (Qi, Qj)
    iq = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jq = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    lmat = jnp.exp(jnp.where(iq >= jq, li, -1e30))
    scores = jax.lax.dot_general(cq, bq, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    dx = dt[:, None] * xq                                 # (Q, P)
    y_intra = jax.lax.dot_general(scores * lmat, dx,
                                  (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    # inter-chunk: carried state h (P, N)
    y_inter = jax.lax.dot_general(
        cq * jnp.exp(cum)[:, None], state_ref[...],
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)               # (Q, P)
    y_ref[0] = (y_intra + y_inter).astype(y_ref.dtype)
    # state update: h' = exp(cum[-1]) h + sum_j decay_j dx_j^T b_j
    decay_end = jnp.exp(cum[Q - 1] - cum)                 # (Q,)
    s_chunk = jax.lax.dot_general(
        dx * decay_end[:, None], bq, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)               # (P, N)
    state_ref[...] = state_ref[...] * jnp.exp(cum[Q - 1]) + s_chunk

    @pl.when(ci == n_chunks - 1)
    def _done():
        h_ref[0] = state_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_p(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
               C: jax.Array, *, chunk: int = 128,
               interpret: bool = True):
    """x: (BH, S, P); dt: (BH, S); A: (BH,); B/C: (BH, S, N).

    Returns (y (BH, S, P), final state (BH, P, N))."""
    BH, S, P = x.shape
    N = B.shape[-1]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    a2 = A.reshape(BH, 1)
    return pl.pallas_call(
        functools.partial(_kernel, n_chunks=nc, Q=chunk),
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, P), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, chunk), lambda b, ci: (b, ci)),
            pl.BlockSpec((1, 1), lambda b, ci: (b, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, ci: (b, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, P), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, P, N), lambda b, ci: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, S, P), x.dtype),
            jax.ShapeDtypeStruct((BH, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, a2, B, C)
