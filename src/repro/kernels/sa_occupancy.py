"""SA PE-occupancy closed form as a Pallas kernel (paper §4.1, Fig 10).

The sweep plane needs per-op PE-state occupancy fractions for every
matmul in the trace; ``core.sa_gating.gating_stats_batch_xp`` is the
closed-form 4-category ragged-tile math. This kernel evaluates that
exact math tile-by-tile over the op stream so the occupancy pass can
run on-device next to ``gated_matmul`` — the ROADMAP's "the whole jax
sweep program stays on-device" step. It shares the ``prefix_on_bitmap``
semantics with ``gated_matmul``: the closed form *is* the analytic
integral of the prefix row/col bitmaps plus the diagonal PE_on front,
so the two kernels agree on which PEs a ragged tile leaves dark.

``saw`` (the SA width) enters as a traced scalar operand — the sweep
kernel vmaps over unique (saw, delay-scale) pairs — and the weight-load
cycle count rides in the same scalar params vector with a ``-1``
"default to saw" sentinel, so one compiled kernel serves the whole knob
axis.

On this CPU container the kernel runs with ``interpret=True`` (same
convention as the other kernels in this package); on a real TPU the
1-D op stream should be fed in lane-aligned (block multiple of 128)
blocks, which the default block size already is.

The jnp oracle is ``kernels.ref.ref_sa_occupancy`` and the selection
between oracle and kernel is a backend-contract switch
(``core.backend.set_sa_occupancy_impl``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.sa_gating import gating_stats_batch_xp

STAT_KEYS = ("duration_cycles", "frac_on", "frac_w_on", "frac_off",
             "wake_events")


def _kernel(params_ref, m_ref, k_ref, n_ref, dur_ref, on_ref, won_ref,
            off_ref, wake_ref):
    saw = params_ref[0]
    wlc_raw = params_ref[1]
    wlc = jnp.where(wlc_raw < 0.0, saw, wlc_raw)
    st = gating_stats_batch_xp(m_ref[...], k_ref[...], n_ref[...], saw,
                               wlc, xp=jnp)
    dur_ref[...] = st["duration_cycles"]
    on_ref[...] = st["frac_on"]
    won_ref[...] = st["frac_w_on"]
    off_ref[...] = st["frac_off"]
    wake_ref[...] = st["wake_events"]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def sa_occupancy_p(mm_m: jax.Array, mm_k: jax.Array, mm_n: jax.Array,
                   saw: jax.Array, weight_load_cycles=None, *,
                   block: int = 512, interpret: bool = True) -> dict:
    """Per-op SA occupancy stats for ``[M,K]x[K,N]`` matmul streams.

    ``mm_m/mm_k/mm_n``: (n,) matmul dims (float64 exact integers);
    ``saw``: scalar SA width (may be traced);
    ``weight_load_cycles``: optional scalar override (``None`` → saw).
    Returns the ``gating_stats_batch_xp`` dict of (n,) float64 arrays.
    """
    n = mm_m.shape[0]
    f8 = jnp.float64
    wlc = jnp.asarray(-1.0 if weight_load_cycles is None
                      else weight_load_cycles, f8)
    params = jnp.stack([jnp.asarray(saw, f8), wlc])
    if n == 0:
        z = jnp.zeros(0, f8)
        return dict(zip(STAT_KEYS, (z, z, z, z, z)))
    pad = (-n) % block
    # pad with benign 1x1x1 tiles; sliced away below
    dims = [jnp.pad(jnp.asarray(a, f8), (0, pad), constant_values=1.0)
            for a in (mm_m, mm_k, mm_n)]
    npad = n + pad
    grid = (npad // block,)
    shp = jax.ShapeDtypeStruct((npad,), f8)
    blk = pl.BlockSpec((block,), lambda i: (i,))
    outs = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((2,), lambda i: (0,)), blk, blk, blk],
        out_specs=[blk] * 5,
        out_shape=[shp] * 5,
        interpret=interpret,
    )(params, *dims)
    return dict(zip(STAT_KEYS, (o[:n] for o in outs)))
