from repro.train.steps import TrainState, make_serve_step, make_train_step

__all__ = ["TrainState", "make_train_step", "make_serve_step"]
