"""jit-able train / serve steps.

``make_train_step`` builds the canonical fused step:
  microbatched value_and_grad (lax.scan accumulation) -> optional gradient
  compression (bf16 cast on the DP all-reduce path, with fp32 re-expansion)
  -> AdamW update. Under pjit the DP gradient all-reduce is implicit in the
  sharding propagation; compressing the grads halves its bytes (visible in
  the dry-run collective table — see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import model as M
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.compression import compress_grads, init_error_feedback

jax.tree_util.register_dataclass  # (py3.13 / jax>=0.4.27)


@jax.tree_util.register_dataclass
@dataclass
class TrainState:
    params: Any
    opt_state: Any
    step: jax.Array

    @staticmethod
    def create(params, opt_cfg: AdamWConfig,
               grad_compression: Optional[str] = None) -> "TrainState":
        opt = adamw_init(params, opt_cfg)
        if grad_compression == "int8":
            opt["ef"] = init_error_feedback(params)  # error feedback
        return TrainState(params=params, opt_state=opt,
                          step=jnp.zeros((), jnp.int32))


def _split_microbatches(batch: dict, n: int) -> dict:
    def r(x):
        b = x.shape[0]
        assert b % n == 0, (b, n)
        return x.reshape(n, b // n, *x.shape[1:])
    return jax.tree.map(r, batch)


def make_train_step(cfg: ArchConfig, opt_cfg: AdamWConfig, *,
                    microbatches: int = 1, remat: str = "full",
                    grad_compression: Optional[str] = None,
                    accum_dtype=jnp.float32,
                    dtype=jnp.bfloat16) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics).

    ``accum_dtype``: gradient-accumulation carry dtype. bf16 halves the
    accumulator HBM (the floor for very large models — deepseek-v2 on
    16 GB chips needs it); each microbatch grad is pre-scaled by 1/M so
    bf16 range is never an issue, and the optimizer math stays fp32.
    """

    def loss(params, mb):
        return M.loss_fn(params, mb, cfg, remat=remat, dtype=dtype)

    def train_step(state: TrainState, batch: dict):
        if microbatches == 1:
            (l, metrics), grads = jax.value_and_grad(
                loss, has_aux=True)(state.params, batch)
        else:
            mbs = _split_microbatches(batch, microbatches)
            inv = 1.0 / microbatches

            def acc(carry, mb):
                g_acc, m_acc = carry
                (_, m), g = jax.value_and_grad(loss, has_aux=True)(
                    state.params, mb)
                g_acc = jax.tree.map(
                    lambda a, x: a + (x * inv).astype(accum_dtype),
                    g_acc, g)
                m_acc = jax.tree.map(lambda a, x: a + x * inv, m_acc, m)
                return (g_acc, m_acc), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, accum_dtype),
                              state.params)
            m0 = {"loss": 0.0, "ce": 0.0, "aux": 0.0, "z": 0.0}
            m0 = jax.tree.map(jnp.float32, m0)
            (grads, msum), _ = jax.lax.scan(acc, (g0, m0), mbs)
            metrics = msum

        ef = state.opt_state.get("ef") if isinstance(state.opt_state, dict) \
            else None
        grads, new_ef = compress_grads(grads, grad_compression, ef)

        opt_in = {k: v for k, v in state.opt_state.items() if k != "ef"}
        new_params, new_opt, opt_metrics = adamw_update(
            grads, opt_in, state.params, opt_cfg)
        if new_ef is not None and ef is not None:
            new_opt["ef"] = new_ef
        metrics = {**metrics, **opt_metrics}
        return TrainState(new_params, new_opt, state.step + 1), metrics

    return train_step


def make_serve_step(cfg: ArchConfig, *, dtype=jnp.bfloat16) -> Callable:
    """Returns serve_step(params, cache, batch) -> (logits, new_cache)."""

    def serve_step(params, cache, batch):
        return M.decode_step(params, cache, batch, cfg, dtype=dtype)

    return serve_step


def make_prefill_step(cfg: ArchConfig, *, remat: str = "full",
                      dtype=jnp.bfloat16) -> Callable:
    def prefill_step(params, batch):
        return M.prefill_step(params, batch, cfg, remat=remat, dtype=dtype)

    return prefill_step
