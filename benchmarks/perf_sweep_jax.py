"""JAX-backend sweep-plane throughput benchmark (ISSUE 4).

Runs the fine-knob design-space grid — ``paper_suite()`` × all 5 NPU
generations × all 5 policies × the §6.5 sensitivity cross product
(6 delay scales × 5 logic leakages × 4 SRAM sleep × 2 SRAM off =
240 knobs) = **102 000 cells** — through ``sweep_grid`` on both array
backends:

* jax:   one jitted float64 program (knob primitives vmapped over the
  unique delay scales, leakage knobs assembled linearly), compiled once
  and reused across the NPU generations and repeated calls. Steady
  state is best-of-N after the compile call; compile time is excluded
  from the gate but reported (``jax_compile_wall_s``).
* numpy: the eager batched path (PR 3), same grid, best-of-N with warm
  trace/stack caches.

Also verifies the acceptance contract on a knob-subsampled grid (every
16th knob → 6 375 cells): record-for-record relative equivalence ≤1e-9
on every numeric field with byte-identical ordering against the numpy
batched path. Writes ``BENCH_sweep_jax.json``; the gate is
speedup >= 3x AND equivalence, enforced in CI together with
``check_regression.py``.

  PYTHONPATH=src python -m benchmarks.perf_sweep_jax [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import time

from benchmarks.perf_sweep import _max_rel_dev
from repro.core.hw import NPUS
from repro.core.opgen import paper_suite
from repro.core.policies import POLICIES
from repro.core.sweep import sweep_grid

RTOL = 1e-9
MIN_SPEEDUP = 3.0

GRID = dict(
    delay_scale=(0.25, 0.5, 1.0, 2.0, 4.0, 8.0),
    leak_off_logic=(0.01, 0.03, 0.1, 0.2, 0.4),
    leak_sram_sleep=(0.1, 0.25, 0.4, 0.6),
    leak_sram_off=(0.002, 0.02),
)
EQUIV_SUBSAMPLE = 16  # every 16th knob of the flat 240-point grid


def _subsampled_grid() -> list:
    """Every Nth knob of the flat delay-major grid — the equivalence
    check covers every policy/NPU cell but thins the knob axis so the
    loop-free comparison stays cheap in CI."""
    from repro.core.sweep import knob_product
    return knob_product(**GRID)[::EQUIV_SUBSAMPLE]


def run(out_path: str = "BENCH_sweep_jax.json", reps: int = 3) -> dict:
    suite = paper_suite()
    npus = tuple(NPUS)
    n_knobs = 1
    for axis in GRID.values():
        n_knobs *= len(axis)
    n_cells = len(suite) * len(npus) * len(POLICIES) * n_knobs

    def run_grid(backend):
        return sweep_grid(suite, npus=npus, policies=POLICIES,
                          backend=backend, as_records=False, **GRID)

    # --- jax: first call compiles; steady state reuses the program ---
    t0 = time.perf_counter()
    run_grid("jax")
    t_first = time.perf_counter() - t0
    t_jax = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        res_jax = run_grid("jax")
        t_jax = min(t_jax, time.perf_counter() - t0)
    assert res_jax.shape == (len(suite), len(npus), len(POLICIES),
                             n_knobs)

    # --- numpy batched, same grid (warm caches after the jax pass
    # compiled traces; best-of-N for steady state) ---
    t_np = float("inf")
    for _ in range(max(2, reps - 1)):
        t0 = time.perf_counter()
        res_np = run_grid("numpy")
        t_np = min(t_np, time.perf_counter() - t0)

    # --- equivalence on the knob-subsampled grid, full record compare ---
    sub = _subsampled_grid()
    from repro.core.sweep import sweep as _sweep
    ref = _sweep(suite, npus=npus, policies=POLICIES, knob_grid=sub,
                 backend="numpy")
    got = _sweep(suite, npus=npus, policies=POLICIES, knob_grid=sub,
                 backend="jax")
    key = ("workload", "npu", "policy", "knob_idx")
    ordering_ok = [tuple(r[k] for k in key) for r in ref] \
        == [tuple(r[k] for k in key) for r in got]
    max_dev = _max_rel_dev(ref, got)

    result = {
        "workloads": len(suite),
        "npus": len(npus),
        "policies": len(POLICIES),
        "knob_settings": n_knobs,
        "sweep_cells": n_cells,
        "equiv_cells": len(ref),
        "jax_wall_s": round(t_jax, 4),
        "jax_compile_wall_s": round(t_first - t_jax, 4),
        "numpy_wall_s": round(t_np, 4),
        "cells_per_sec_jax": round(n_cells / t_jax),
        "cells_per_sec_numpy": round(n_cells / t_np),
        "speedup": round(t_np / t_jax, 2),
        "max_rel_dev": max_dev,
        "ordering_identical": ordering_ok,
    }
    with open(out_path, "w") as f:
        json.dump(result, f, indent=1)
    return result


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_sweep_jax.json")
    args = ap.parse_args(argv)
    r = run(args.out)
    for k, v in r.items():
        print(f"{k}: {v}")
    ok = (r["speedup"] >= MIN_SPEEDUP and r["max_rel_dev"] <= RTOL
          and r["ordering_identical"])
    print(f"gate(speedup>={MIN_SPEEDUP:g}x & rel_dev<={RTOL:g} & "
          f"same order): {'PASS' if ok else 'FAIL'}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
