"""One benchmark per paper table/figure. Each returns rows of
(name, value, derived-note); benchmarks/run.py prints them as CSV.

The figure benchmarks run on the batched sweep plane: per-workload
service times come from ``compile_trace`` + ``trace_times``, and each
sweep-backed figure (Figs 17–23 and the knob-sensitivity studies) is a
single ``repro.core.sweep.sweep`` call — one ``evaluate_batch`` pass
over the stacked suite super-trace, no per-cell Python round-trips.
The SLO search behind Fig 2 batches its (chips × batch × generation)
candidate grid the same way.
"""
from __future__ import annotations

import statistics
from typing import Callable

import numpy as np

from repro.core.carbon import (EMBODIED_KG, optimal_lifespan, yearly_carbon)
from repro.core.hw import NPUS, get_npu
from repro.core.isa import VLIWTimeline, fig15_program
from repro.core.opgen import (compile_trace, diffusion_workload,
                              dlrm_workload, llm_workload, paper_suite)
from repro.core.policies import (POLICIES, PolicyKnobs, evaluate,
                                 evaluate_all, trace_times)
from repro.core.power import PowerModel
from repro.core.sa_gating import gating_stats, spatial_efficiency
from repro.core.sweep import (group_by, sweep, sweep_program_plane,
                              with_savings)

Row = tuple  # (name, value, note)

REGISTRY: dict[str, Callable[[], list[Row]]] = {}


def bench(fn):
    REGISTRY[fn.__name__] = fn
    return fn


@bench
def table2_specs() -> list[Row]:
    """Paper Table 2: derived peaks must match published TPU numbers."""
    out = []
    for name, n in NPUS.items():
        out.append((f"{name}_sa_tflops", round(n.sa_flops / 1e12, 1),
                    "derived: saw^2*2*n_sa*freq"))
        out.append((f"{name}_hbm_gbps", n.hbm_gbps, "table2"))
    return out


@bench
def fig2_energy_efficiency() -> list[Row]:
    """Cross-generation energy efficiency at the most efficient
    SLO-compliant config (paper §3 methodology)."""
    from repro.core.slo import slo_sweep
    out = []
    for model, phase in (("llama3-8b", "decode"), ("llama3-8b", "train"),
                         ("llama2-13b", "prefill")):
        res = slo_sweep(model, phase, batches=(1, 8, 32, 128),
                        chip_counts=(1, 2, 4, 8, 16))
        for gen, pt in res.items():
            if gen == "_slo":
                continue
            if pt is None:
                out.append((f"fig2/{model}-{phase}/{gen}", "no-SLO-config",
                            "paper: old gens shown at relaxed SLO"))
            else:
                out.append((f"fig2/{model}-{phase}/{gen}",
                            round(pt.efficiency, 2),
                            f"work/J @ {pt.n_chips} chips batch {pt.batch}"))
    return out


@bench
def fig3_energy_breakdown() -> list[Row]:
    """Static-energy fraction of busy-chip energy per workload (30-72%)."""
    out = []
    for wl in paper_suite():
        r = evaluate(wl, "NPU-D", "NoPG")
        out.append((f"static_frac/{wl.name}", round(r.static_frac, 3),
                    "NoPG busy"))
    vals = [v for _, v, _ in out]
    out.append(("static_frac/range", f"{min(vals):.2f}-{max(vals):.2f}",
                "paper: 0.30-0.72"))
    return out


@bench
def fig4_sa_temporal_utilization() -> list[Row]:
    out = []
    npu = get_npu("NPU-D")
    for wl in paper_suite():
        tr = compile_trace(wl)
        tm = trace_times(tr, npu)
        busy = float((tm["sa"] * tr.count).sum())
        tot = float((tm["dur"] * tr.count).sum())
        out.append((f"sa_util/{wl.name}", round(busy / tot, 3),
                    "active cycles / total"))
    return out


@bench
def fig5_sa_spatial_utilization() -> list[Row]:
    """Achieved/peak FLOPs during SA-active time (prefill & diffusion)."""
    cases = [
        ("llm_prefill_4k", 4096 * 4, 4096, 4096),
        ("dit_xl_head72", 8192, 72, 1024),     # head size 72 < 128
        ("gligen_head40", 4096, 40, 256),
        ("decode_gemv", 8, 4096, 4096),
    ]
    return [(f"sa_spatial/{n}", round(spatial_efficiency(m, k, nn, 128), 3),
             f"[{m}x{k}]x[{k}x{nn}] on 128x128")
            for n, m, k, nn in cases]


@bench
def fig6_vu_utilization() -> list[Row]:
    out = []
    npu = get_npu("NPU-D")
    for wl in paper_suite():
        tr = compile_trace(wl)
        tm = trace_times(tr, npu)
        busy = float((tm["vu"] * tr.count).sum())
        tot = float((tm["dur"] * tr.count).sum())
        out.append((f"vu_util/{wl.name}", round(busy / tot, 3),
                    "paper: <60% everywhere"))
    return out


@bench
def fig7_sram_demand() -> list[Row]:
    """Percentiles over the EXECUTED op stream: each op weighted by its
    repetition count (the columnar trace makes the expansion trivial)."""
    out = []
    for wl in paper_suite():
        tr = compile_trace(wl)
        dem = np.repeat(tr.sram_demand, tr.count.astype(np.int64))
        mx = float(dem.max()) / 2 ** 20
        med = float(np.median(dem)) / 2 ** 20
        out.append((f"sram_mb/{wl.name}",
                    f"med={med:.0f} max={mx:.0f}",
                    "paper: DLRM <= 8MB, compute-bound large"))
    return out


@bench
def fig8_ici_utilization() -> list[Row]:
    out = []
    npu = get_npu("NPU-D")
    for wl in paper_suite():
        tr = compile_trace(wl)
        tm = trace_times(tr, npu)
        durn = tm["dur"] * tr.count
        coll = float(durn[tr.collective].sum())
        tot = float(durn.sum())
        out.append((f"ici_noncollective_frac/{wl.name}",
                    round(1 - coll / tot, 3), "paper: 1-100%, avg 67%"))
    return out


@bench
def fig9_hbm_utilization() -> list[Row]:
    out = []
    npu = get_npu("NPU-D")
    for wl in paper_suite():
        tr = compile_trace(wl)
        tm = trace_times(tr, npu)
        busy = float((tm["hbm"] * tr.count).sum())
        tot = float((tm["dur"] * tr.count).sum())
        out.append((f"hbm_idle_frac/{wl.name}", round(1 - busy / tot, 3),
                    "paper: 64-99% idle for compute-bound"))
    return out


@bench
def fig17_energy_savings() -> list[Row]:
    out = []
    recs = with_savings(sweep(paper_suite()))
    per_policy: dict[str, list[float]] = {p: [] for p in POLICIES}
    for r in recs:
        if r["policy"] == "NoPG":
            continue
        per_policy[r["policy"]].append(r["savings"])
        out.append((f"save/{r['workload']}/{r['policy']}",
                    round(r["savings"], 4), ""))
    for p in POLICIES[1:]:
        out.append((f"save/avg/{p}", round(statistics.mean(per_policy[p]), 4),
                    "paper Full: 0.085-0.328 avg 0.155"))
    return out


@bench
def fig18_power() -> list[Row]:
    out = []
    recs = sweep(paper_suite(), policies=("NoPG", "ReGate-Full"))
    for (wl_name,), rows in group_by(recs, "workload").items():
        by_p = {r["policy"]: r for r in rows}
        base = by_p["NoPG"]["avg_power_w"]
        full = by_p["ReGate-Full"]["avg_power_w"]
        out.append((f"avg_power_w/{wl_name}",
                    f"nopg={base:.0f} full={full:.0f}",
                    f"-{(1-full/base)*100:.1f}%"))
    return out


@bench
def fig19_perf_overhead() -> list[Row]:
    out = []
    worst = {p: 0.0 for p in POLICIES}
    recs = sweep(paper_suite())
    for (wl_name,), rows in group_by(recs, "workload").items():
        by_p = {r["policy"]: r for r in rows}
        base = by_p["NoPG"]["runtime_s"]
        for p in ("ReGate-Base", "ReGate-HW", "ReGate-Full"):
            worst[p] = max(worst[p], by_p[p]["runtime_s"] / base - 1)
    for p in ("ReGate-Base", "ReGate-HW", "ReGate-Full"):
        out.append((f"overhead_max/{p}", round(worst[p], 5),
                    "paper: Base<=4.6% HW<=0.6% Full<=0.44%"))
    return out


@bench
def fig20_setpm_rate() -> list[Row]:
    out = []
    for r in sweep(paper_suite(), policies=("ReGate-Full",)):
        out.append((f"setpm_per_1k/{r['workload']}",
                    round(r["setpm_per_1k_cycles"], 2),
                    "bound: 31 (=1000/BET_vu)"))
    # instruction-level (paper Fig 15 pattern)
    prog = fig15_program(8, with_setpm=True)
    res = VLIWTimeline(n_sa=2, n_vu=2, hw_auto_gating=False).run(prog)
    out.append(("setpm_per_1k/fig15_micro",
                round(res.setpm_executed / res.cycles * 1e3, 1),
                "VLIW timeline"))
    return out


@bench
def program_plane_crossval() -> list[Row]:
    """Program plane vs closed-form sw policy (ISSUE 2 tentpole): the
    suite lowered to per-unit cycle timelines, §4.3-instrumented, run on
    the event-driven executor; per-workload worst deviation of the
    per-component gated-cycle fractions on NPU-D (all generations are
    covered by tests/test_program_plane_crossval.py)."""
    out = []
    worst = 0.0
    for r in sweep_program_plane(paper_suite(), npus=("NPU-D",)):
        dev = max(r[f"gated_frac_absdiff_{c}"]
                  for c in ("sa", "vu", "hbm", "ici", "sram"))
        worst = max(worst, dev, r["runtime_rel_err"])
        out.append((
            f"crossval/{r['workload']}", round(dev, 6),
            f"max |d gated_frac|; rt_err {r['runtime_rel_err']:.1e}; "
            f"setpm vu {r['setpm_prog_vu']:.0f}/"
            f"{r['setpm_policy_vu']:.0f} "
            f"sram {r['setpm_prog_sram']:.0f}/"
            f"{r['setpm_policy_sram']:.0f} (prog/policy); "
            f"{r['n_events']} events"))
    out.append(("crossval/suite_max_dev", round(worst, 6),
                "tolerance 0.005 — EXPERIMENTS.md §Program-plane"))
    return out


@bench
def fig21_leakage_sensitivity() -> list[Row]:
    out = []
    leaks = (0.03, 0.1, 0.2)
    grid = [PolicyKnobs(leak_off_logic=leak,
                        leak_sram_sleep=max(0.25, leak * 2),
                        leak_sram_off=leak / 10) for leak in leaks]
    recs = with_savings(sweep(paper_suite(),
                              policies=("NoPG", "ReGate-Full"),
                              knob_grid=grid))
    for (ki,), rows in group_by(recs, "knob_idx").items():
        vals = [r["savings"] for r in rows if r["policy"] == "ReGate-Full"]
        out.append((f"save_full_avg/leak={leaks[ki]}",
                    round(statistics.mean(vals), 4),
                    "paper: 4.6-16.4% at worst setting"))
    return out


@bench
def fig22_delay_sensitivity() -> list[Row]:
    out = []
    scales = (0.5, 1.0, 2.0, 4.0)
    grid = [PolicyKnobs(delay_scale=s) for s in scales]
    recs = with_savings(sweep(paper_suite(),
                              policies=("NoPG", "ReGate-Full"),
                              knob_grid=grid))
    for (ki,), rows in group_by(recs, "knob_idx").items():
        full = [r for r in rows if r["policy"] == "ReGate-Full"]
        nopg = {r["workload"]: r for r in rows if r["policy"] == "NoPG"}
        sv = [r["savings"] for r in full]
        ov = [r["runtime_s"] / nopg[r["workload"]]["runtime_s"] - 1
              for r in full]
        out.append((f"delay_x{scales[ki]}",
                    f"save={statistics.mean(sv):.4f} "
                    f"ov={statistics.mean(ov):.5f}",
                    "longer delays: fewer gating opportunities"))
    return out


@bench
def fig23_generations() -> list[Row]:
    out = []
    recs = with_savings(sweep(paper_suite(), npus=tuple(NPUS),
                              policies=("NoPG", "ReGate-Full")))
    for (gen,), rows in group_by(recs, "npu").items():
        vals = [r["savings"] for r in rows if r["policy"] == "ReGate-Full"]
        out.append((f"save_full_avg/{gen}", round(statistics.mean(vals), 4),
                    "paper: larger units on E -> larger savings"))
    return out


def evaluate_all_gen(w, npu):
    return evaluate_all(w, npu)


@bench
def fig24_carbon() -> list[Row]:
    out = []
    for wl in paper_suite()[:6] + paper_suite()[8:12]:
        reps = evaluate_all(wl)
        nopg = yearly_carbon(reps["NoPG"].avg_power_w, "NPU-D",
                             gated_idle=False, workload=wl.name,
                             policy="NoPG")
        full = yearly_carbon(reps["ReGate-Full"].avg_power_w, "NPU-D",
                             gated_idle=True, workload=wl.name,
                             policy="ReGate-Full")
        red = 1 - full.total_kg_per_year / nopg.total_kg_per_year
        out.append((f"carbon_reduction/{wl.name}", round(red, 3),
                    "paper: 31.1-62.9% (incl. gated idle 40%)"))
    return out


@bench
def fig25_lifespan() -> list[Row]:
    out = []
    wl = llm_workload("llama3.1-405b", "decode", batch=64, n_chips=8, tp=8)
    reps = evaluate_all(wl)
    for policy, gated in (("NoPG", False), ("ReGate-Full", True)):
        per_year = yearly_carbon(reps[policy].avg_power_w, "NPU-D",
                                 gated_idle=gated).total_kg_per_year
        curve = optimal_lifespan(per_year)
        best = min(curve, key=curve.get)
        out.append((f"optimal_lifespan_yr/{policy}", best,
                    "paper: ReGate extends 4-8yr -> 5-9yr"))
    return out
