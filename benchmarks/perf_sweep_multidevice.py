"""Multi-device shard_map sweep benchmark (ISSUE 5).

Runs a ≥100k-cell design-space grid — 16 synthetic 2.5k-op workloads ×
5 NPU generations × 5 policies × 256 knobs (8 delay scales × 4 SA
widths × 4 logic leakages × 2 SRAM sleeps; 32 unique (width, delay)
pairs) — through the jax sweep twice inside ONE subprocess running
under ``--xla_force_host_platform_device_count=8``:

* **1-device**: the plain jitted kernel (no mesh), steady state;
* **8-device**: the ``shard_map`` program on a ``("wl", "knob")``
  mesh — op columns sharded over ``wl`` (psum-completed segment sums),
  unique pairs + knob grid sharded over ``knob``.

Equivalence is a hard gate everywhere: an NPU × thinned-knob subsample
of the grid must match the numpy oracle record-for-record ≤1e-9.

The ≥2x speedup gate arms only when the host has at least one core per
virtual device (``os.cpu_count() >= 8``): 8 virtual CPU devices
time-slice the physical cores, so on the 2-core container this repo is
grown on the strong-scaling ceiling is cores/1 ≈ 2x *before* overhead
— the run still measures and records the scaling honestly
(``speedup_gate_armed: false`` in the JSON), and CI-class machines arm
the gate. ``check_regression.py`` tracks the recorded speedup against
the committed baseline either way, so a scaling regression on the same
machine class fails the PR.

  PYTHONPATH=src python -m benchmarks.perf_sweep_multidevice [--out P]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

RTOL = 1e-9
MIN_SPEEDUP = 2.0
N_DEVICES = 8

N_WORKLOADS = 16
OPS_PER_WORKLOAD = 2500
GRID = dict(
    delay_scale=(0.25, 0.5, 0.7, 1.0, 2.0, 4.0, 8.0, 16.0),
    sa_width=(None, 64, 256, 512),
    leak_off_logic=(0.01, 0.03, 0.1, 0.4),
    leak_sram_sleep=(0.1, 0.4),
)
EQUIV_SUBSAMPLE = 32  # every 32nd knob of the flat 256-point grid


def _synth_suite():
    """Deterministic synthetic suite with a large stacked op axis (the
    ``wl``-sharding regime: tens of thousands of ops, modest W)."""
    import numpy as np

    from repro.core.opgen import Op, Workload
    rng = np.random.default_rng(42)
    wls = []
    for i in range(N_WORKLOADS):
        ops = []
        for j in range(OPS_PER_WORKLOAD):
            f = float(rng.uniform(1e9, 5e12)) if rng.random() < 0.5 else 0.0
            mm = (int(rng.integers(1, 4096)), int(rng.integers(1, 512)),
                  int(rng.integers(1, 4096))) if f else None
            ops.append(Op(
                f"op{j}", flops_sa=f,
                flops_vu=float(rng.uniform(1e8, 5e11))
                if rng.random() < 0.5 else 0.0,
                bytes_hbm=float(rng.uniform(1e6, 1e10))
                if rng.random() < 0.6 else 0.0,
                bytes_ici=float(rng.uniform(1e6, 1e9))
                if rng.random() < 0.15 else 0.0,
                sram_demand=int(rng.integers(0, 256 << 20)),
                matmul_dims=mm, count=int(rng.integers(1, 4))))
        wls.append(Workload(f"synth-{i}", "prefill", tuple(ops)))
    return wls


def _inner(out_path: str, reps: int) -> None:
    """Runs inside the 8-virtual-device subprocess."""
    import jax
    assert len(jax.devices()) == N_DEVICES, jax.devices()
    from repro.core.hw import NPUS
    from repro.core.policies import POLICIES, evaluate_batch
    from repro.core.sweep import knob_product, sweep
    from repro.parallel import jax_compat

    wls = _synth_suite()
    grid = knob_product(**GRID)
    npus = tuple(NPUS)
    n_cells = len(wls) * len(npus) * len(POLICIES) * len(grid)
    mesh = jax_compat.sweep_mesh(wl=4, knob=2)

    def run(m):
        return evaluate_batch(wls, npus, POLICIES, grid, backend="jax",
                              jax_mesh=m)

    # first calls compile; steady state reuses the programs
    t0 = time.perf_counter()
    run(None)
    compile_1dev = time.perf_counter() - t0
    t0 = time.perf_counter()
    run(mesh)
    compile_8dev = time.perf_counter() - t0
    t_1dev = t_8dev = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        res = run(None)
        t_1dev = min(t_1dev, time.perf_counter() - t0)
    assert res.shape == (len(wls), len(npus), len(POLICIES), len(grid))
    for _ in range(reps):
        t0 = time.perf_counter()
        run(mesh)
        t_8dev = min(t_8dev, time.perf_counter() - t0)

    # --- equivalence vs the numpy oracle on a thinned subsample ---
    sub = grid[::EQUIV_SUBSAMPLE]
    ref = sweep(wls, ("NPU-D",), POLICIES, sub, backend="numpy")
    got = evaluate_batch(wls, ("NPU-D",), POLICIES, sub, backend="jax",
                         jax_mesh=mesh).records()
    key = ("workload", "npu", "policy", "knob_idx")
    ordering_ok = [tuple(r[k] for k in key) for r in ref] \
        == [tuple(r[k] for k in key) for r in got]
    from benchmarks.perf_sweep import _max_rel_dev
    max_dev = _max_rel_dev(ref, got)

    host_cpus = os.cpu_count() or 1
    result = {
        "devices": N_DEVICES,
        "mesh": "wl=4 x knob=2",
        "host_cpus": host_cpus,
        "workloads": len(wls),
        "stacked_ops": sum(len(w.ops) for w in wls),
        "knob_settings": len(grid),
        "sweep_cells": n_cells,
        "equiv_cells": len(ref),
        "wall_1dev_s": round(t_1dev, 4),
        "wall_8dev_s": round(t_8dev, 4),
        "compile_1dev_s": round(compile_1dev - t_1dev, 4),
        "compile_8dev_s": round(compile_8dev - t_8dev, 4),
        "speedup": round(t_1dev / t_8dev, 3),
        "speedup_gate_armed": host_cpus >= N_DEVICES,
        "max_rel_dev": max_dev,
        "ordering_identical": ordering_ok,
    }
    with open(out_path, "w") as f:
        json.dump(result, f, indent=1)


def run(out_path: str = "BENCH_sweep_multidevice.json",
        reps: int = 3) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        f" --xla_force_host_platform_device_count="
                        f"{N_DEVICES}").strip()
    env.setdefault("JAX_PLATFORMS", "cpu")
    env["PYTHONPATH"] = "src" + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.perf_sweep_multidevice",
         "--inner", "--out", out_path, "--reps", str(reps)],
        env=env, timeout=1800)
    if r.returncode != 0:
        raise RuntimeError(f"inner benchmark failed ({r.returncode})")
    with open(out_path) as f:
        return json.load(f)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_sweep_multidevice.json")
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--inner", action="store_true")
    args = ap.parse_args(argv)
    if args.inner:
        _inner(args.out, args.reps)
        return 0
    r = run(args.out, args.reps)
    for k, v in r.items():
        print(f"{k}: {v}")
    equiv_ok = r["max_rel_dev"] <= RTOL and r["ordering_identical"]
    if r["speedup_gate_armed"]:
        ok = equiv_ok and r["speedup"] >= MIN_SPEEDUP
        print(f"gate(equiv<=1e-9 & speedup>={MIN_SPEEDUP:g}x on "
              f"{r['host_cpus']} cpus): {'PASS' if ok else 'FAIL'}")
    else:
        ok = equiv_ok
        print(f"gate(equiv<=1e-9; speedup gate unarmed — "
              f"{r['host_cpus']} cpus < {N_DEVICES} devices, scaling "
              f"recorded as {r['speedup']}x): "
              f"{'PASS' if ok else 'FAIL'}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
