"""Guard-plane overhead benchmark (ISSUE 9).

Runs the fleet-day scenario of ``perf_fleet`` (4096 chips, 4 tenant
classes, 96 epochs, 24-knob grid) twice — plain and under a
``GuardedRunner`` (deadline watchdog + finite-check/quarantine scan on
every epoch cube) — and gates the guard's **clean-path overhead at
<= 5%**: resilience must be effectively free when nothing goes wrong.
Both sides take the min over ``reps`` repetitions; the guarded run
must also be record-for-record identical to the plain one (the guard
never changes *what* is computed).

Writes ``BENCH_guard.json`` (registered in ``check_regression``;
``speedup`` = plain/guarded wall ratio, so the 30% regression margin
doubles as a backstop on guard-overhead creep).

  PYTHONPATH=src python -m benchmarks.perf_guard [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import time

from repro.core.fleet import sweep_fleet
from repro.core.guard import GuardPolicy
from benchmarks.perf_fleet import GRID, build_scenario

MAX_OVERHEAD = 0.05

# a deadline far above any epoch's wall time: the watchdog thread is
# exercised on every call, but never trips
POLICY = GuardPolicy(timeout_s=600.0)


def run(out_path: str = "BENCH_guard.json", reps: int = 5) -> dict:
    sc = build_scenario()

    plain = sweep_fleet(sc, GRID)   # warm-up: trace/compile caches
    t_plain = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        rep = sweep_fleet(sc, GRID)
        t_plain = min(t_plain, time.perf_counter() - t0)
    assert rep.records == plain.records

    t_guard = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        grep = sweep_fleet(sc, GRID, guard=POLICY)
        t_guard = min(t_guard, time.perf_counter() - t0)
    assert grep.records == plain.records       # guard is a no-op
    assert grep.epoch_summary == plain.epoch_summary
    assert grep.guard is not None and grep.guard["events"] == []

    overhead = t_guard / t_plain - 1.0
    result = {
        "n_chips": plain.n_chips,
        "classes": len(sc.classes),
        "policies": len(sc.policies),
        "knob_settings": len(tuple(GRID.product())),
        "epochs": plain.n_epochs,
        "plain_wall_s": round(t_plain, 4),
        "guarded_wall_s": round(t_guard, 4),
        "epochs_per_sec_plain": round(plain.n_epochs / t_plain, 2),
        "epochs_per_sec_guarded": round(plain.n_epochs / t_guard, 2),
        "overhead_frac": round(overhead, 4),
        "speedup": round(t_plain / t_guard, 3),
    }
    with open(out_path, "w") as f:
        json.dump(result, f, indent=1)
    return result


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_guard.json")
    ap.add_argument("--reps", type=int, default=5)
    args = ap.parse_args(argv)
    r = run(args.out, reps=args.reps)
    for k, v in r.items():
        print(f"{k}: {v}")
    ok = r["overhead_frac"] <= MAX_OVERHEAD
    print(f"gate(guarded clean-path overhead <= {MAX_OVERHEAD:.0%}): "
          f"{'PASS' if ok else 'FAIL'}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
