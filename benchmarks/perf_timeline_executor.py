"""Timeline-executor throughput microbenchmark.

Times the event-driven ``EventTimeline`` against the dense cycle-stepper
``VLIWTimeline`` reference on a 1M-cycle workload-scale program: the
llama3.1-405b training trace lowered by ``repro.core.lowering``,
schedule-compressed to 1,000,000 cycles (same-unit uses whose scaled
cycles collide are thinned to the first; ~3.5k events survive, incl.
the §4.3-inserted setpm stream), then executed by both. Results are
asserted identical before timing counts.

Writes ``BENCH_timeline_executor.json``; the acceptance gate is
speedup >= 20x (ISSUE 2). CI compares the committed baseline against a
fresh run via ``benchmarks.check_regression``.

  PYTHONPATH=src python -m benchmarks.perf_timeline_executor [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import time

from repro.core.isa import EventTimeline, VLIWTimeline, expand_events
from repro.core.lowering import (REGATE_FULL_TIMELINE, build_events,
                                 instrument_program, lower_workload,
                                 rescale_program)
from repro.core.opgen import llm_workload

TARGET_CYCLES = 1_000_000
SPEEDUP_GATE = 20.0

TL_KWARGS = dict(npu="NPU-D", **REGATE_FULL_TIMELINE)


def build_program():
    wl = llm_workload("llama3.1-405b", "train", batch=32, n_chips=16,
                      tp=16)
    prog = rescale_program(lower_workload(wl, "NPU-D"), TARGET_CYCLES)
    events = build_events(prog, instrument_program(prog))
    return prog, events


def run(out_path: str = "BENCH_timeline_executor.json",
        reps_event: int = 5) -> dict:
    prog, events = build_program()

    # --- event-driven executor (best of N) ---
    t_event = float("inf")
    res_event = None
    for _ in range(reps_event):
        tl = EventTimeline(**TL_KWARGS)
        t0 = time.perf_counter()
        res_event = tl.run(events, horizon=prog.horizon)
        t_event = min(t_event, time.perf_counter() - t0)

    # --- dense cycle-stepper reference, single pass ---
    dense = expand_events(events, prog.horizon)
    ref_tl = VLIWTimeline(**TL_KWARGS)
    t0 = time.perf_counter()
    res_ref = ref_tl.run(dense)
    t_ref = time.perf_counter() - t0

    assert res_event == res_ref, "executor mismatch — not benchmarking"

    result = {
        "program": prog.workload,
        "horizon_cycles": prog.horizon,
        "executed_cycles": res_event.cycles,
        "n_events": len(events),
        "n_setpm": res_event.setpm_executed,
        "event_wall_s": round(t_event, 5),
        "reference_wall_s": round(t_ref, 4),
        "cycles_per_sec_event": round(res_event.cycles / t_event),
        "cycles_per_sec_reference": round(res_ref.cycles / t_ref),
        "speedup": round(t_ref / t_event, 2),
        "results_equal": True,
    }
    with open(out_path, "w") as f:
        json.dump(result, f, indent=1)
    return result


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_timeline_executor.json")
    args = ap.parse_args(argv)
    r = run(args.out)
    for k, v in r.items():
        print(f"{k}: {v}")
    ok = r["speedup"] >= SPEEDUP_GATE
    print(f"gate(speedup>={SPEEDUP_GATE:.0f}x): {'PASS' if ok else 'FAIL'}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
