"""Bench regression gate for CI.

Compares freshly produced ``BENCH_*.json`` artifacts against the
committed baselines and fails when a metric regresses by more than the
allowed fraction (default 30%) — the speedup gates stop being
upload-only artifacts and start failing PRs.

  python -m benchmarks.check_regression \
      BENCH_policy_engine.json:BENCH_policy_engine.new.json \
      BENCH_timeline_executor.json:BENCH_timeline_executor.new.json \
      [--metric speedup] [--max-regression 0.30]

Each positional argument is ``baseline:fresh``. With no positional
arguments the registered ``DEFAULT_PAIRS`` are checked (every
benchmark that commits a baseline registers itself there).
Improvements always pass; a missing baseline file is an error (commit
one with the PR that introduces the benchmark).
"""
from __future__ import annotations

import argparse
import json
import sys

# every committed BENCH_*.json baseline and its fresh CI counterpart;
# new benchmarks register here so `python -m benchmarks.check_regression`
# with no arguments covers the full set
DEFAULT_PAIRS = [
    "BENCH_policy_engine.json:BENCH_policy_engine.new.json",
    "BENCH_timeline_executor.json:BENCH_timeline_executor.new.json",
    "BENCH_program_plane.json:BENCH_program_plane.new.json",
    "BENCH_sweep.json:BENCH_sweep.new.json",
    "BENCH_sweep_jax.json:BENCH_sweep_jax.new.json",
    "BENCH_sweep_multidevice.json:BENCH_sweep_multidevice.new.json",
    "BENCH_perturb.json:BENCH_perturb.new.json",
    "BENCH_fleet.json:BENCH_fleet.new.json",
    "BENCH_chaos.json:BENCH_chaos.new.json",
    "BENCH_guard.json:BENCH_guard.new.json",
]


def check_pair(baseline_path: str, fresh_path: str, metric: str,
               max_regression: float) -> tuple[bool, str]:
    with open(baseline_path) as f:
        base = json.load(f)
    with open(fresh_path) as f:
        fresh = json.load(f)
    if metric not in base or metric not in fresh:
        return False, (f"{baseline_path}: metric {metric!r} missing "
                       f"(baseline has it: {metric in base}, "
                       f"fresh has it: {metric in fresh})")
    b, n = float(base[metric]), float(fresh[metric])
    floor = b * (1.0 - max_regression)
    ok = n >= floor
    verdict = "OK" if ok else "REGRESSION"
    return ok, (f"{verdict}: {baseline_path} {metric} baseline={b:g} "
                f"fresh={n:g} floor={floor:g} "
                f"({(n / b - 1.0) * 100:+.1f}%)")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("pairs", nargs="*", metavar="BASELINE:FRESH",
                    default=DEFAULT_PAIRS,
                    help="baseline and fresh JSON paths, colon-separated "
                         "(default: the registered DEFAULT_PAIRS)")
    ap.add_argument("--metric", default="speedup")
    ap.add_argument("--max-regression", type=float, default=0.30,
                    help="allowed fractional drop vs baseline")
    args = ap.parse_args(argv)
    failed = 0
    for pair in args.pairs:
        if ":" not in pair:
            print(f"bad pair (need BASELINE:FRESH): {pair}")
            failed += 1
            continue
        baseline, fresh = pair.split(":", 1)
        try:
            ok, msg = check_pair(baseline, fresh, args.metric,
                                 args.max_regression)
        except (OSError, ValueError, json.JSONDecodeError) as e:
            ok, msg = False, f"{pair}: {type(e).__name__}: {e}"
        print(msg)
        failed += 0 if ok else 1
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
