"""Batched program-plane sweep throughput benchmark (ISSUE 10).

Times ``sweep_program_plane`` — the full paper suite x two NPU
generations x a 4-point BET/window knob grid, executed through the
``repro.core.program_plane`` array kernel — against the per-cell host
oracle ``sweep_program_plane_reference`` (one ``EventTimeline`` run +
one closed-form ``evaluate`` per cell, the pre-ISSUE-10 path).

Records are compared cell-for-cell BEFORE timing counts: executor-side
fields (cycles, stalls, wakes, setpm counts) must match exactly,
everything else to <=1e-9 relative — a speedup over wrong answers is
not a speedup. The acceptance gate is speedup >= 10x on the best
backend (jax when available — the scan kernel jit-compiles once and is
reused; the numpy scan is also reported).

Writes ``BENCH_program_plane.json``; CI compares the committed baseline
against a fresh run via ``benchmarks.check_regression``.

  PYTHONPATH=src python -m benchmarks.perf_program_plane [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import time

from repro.core.opgen import paper_suite
from repro.core.policies import KnobGrid
from repro.core.sweep import (sweep_program_plane,
                              sweep_program_plane_reference)

SPEEDUP_GATE = 10.0
NPUS = ("NPU-B", "NPU-D")
# 4 BET/window points x 2 leak points: the executor re-runs only per
# unique (sa_width, delay_scale, window_scale) triple (leak knobs never
# move program-plane statistics), so the leak axis rides the batched
# path at near-zero marginal cost — the per-cell oracle pays full price
GRID = KnobGrid(delay_scale=(1.0, 4.0), window_scale=(1.0, 0.5),
                leak_off_logic=(None, 0.1))


def _check_records(got: list[dict], ref: list[dict]) -> None:
    assert len(got) == len(ref), (len(got), len(ref))
    for i, (x, y) in enumerate(zip(ref, got)):
        assert set(x) == set(y), i
        for k in x:
            a, b = x[k], y[k]
            if a is None or isinstance(a, str):
                assert a == b, (i, k, a, b)
            elif k.startswith(("prog_", "n_events", "stall_",
                               "wakes_prog", "setpm_prog")):
                assert float(a) == float(b), (i, k, a, b)
            else:
                assert abs(float(a) - float(b)) \
                    <= 1e-9 * max(1.0, abs(float(a))), (i, k, a, b)


def _time_best(fn, reps: int) -> tuple[float, list[dict]]:
    best, out = float("inf"), None
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return best, out


def run(out_path: str = "BENCH_program_plane.json", reps: int = 3) -> dict:
    wls = paper_suite()
    grid = tuple(GRID.product())

    def reference():
        return sweep_program_plane_reference(wls, npus=NPUS,
                                             knob_grid=grid)

    def batched(backend):
        return lambda: sweep_program_plane(wls, npus=NPUS, knob_grid=grid,
                                           backend=backend)

    # warm every path once: caches (lowering / instrumentation / event
    # streams) and the jit compile are one-time costs both sides share
    ref_recs = reference()
    backends = ["numpy"]
    try:
        import jax  # noqa: F401
        backends.append("jax")
    except ImportError:  # pragma: no cover - jax ships in CI
        pass
    wall = {}
    for b in backends:
        batched(b)()  # warm (jit compile on jax)
        wall[b], recs = _time_best(batched(b), reps)
        _check_records(recs, ref_recs)

    t_ref, _ = _time_best(reference, reps)
    best = min(backends, key=lambda b: wall[b])
    result = {
        "n_workloads": len(wls),
        "n_npus": len(NPUS),
        "n_knobs": len(grid),
        "n_cells": len(ref_recs),
        "reference_wall_s": round(t_ref, 4),
        "batched_wall_s_numpy": round(wall["numpy"], 4),
        **({"batched_wall_s_jax": round(wall["jax"], 4)}
           if "jax" in wall else {}),
        "best_backend": best,
        "cells_per_sec": round(len(ref_recs) / wall[best], 1),
        "speedup_numpy": round(t_ref / wall["numpy"], 2),
        "speedup": round(t_ref / wall[best], 2),
        "records_equal": True,
    }
    with open(out_path, "w") as f:
        json.dump(result, f, indent=1)
    return result


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_program_plane.json")
    args = ap.parse_args(argv)
    r = run(args.out)
    for k, v in r.items():
        print(f"{k}: {v}")
    ok = r["speedup"] >= SPEEDUP_GATE
    print(f"gate(speedup>={SPEEDUP_GATE:.0f}x): {'PASS' if ok else 'FAIL'}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
