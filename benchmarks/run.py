"""Benchmark harness: one function per paper table/figure plus the
dry-run roofline table. Prints ``name,value,derived`` CSV.

  PYTHONPATH=src python -m benchmarks.run [--only fig17,fig19] [--list]
"""
from __future__ import annotations

import argparse
import sys
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated substring filters")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args(argv)

    from benchmarks.figures import REGISTRY
    from benchmarks import arch_power, roofline_table

    benches = dict(REGISTRY)
    benches["roofline_table"] = roofline_table.main
    benches["arch_power_table"] = arch_power.arch_power_table
    benches["regate_on_dryrun_cells"] = arch_power.regate_on_dryrun_cells

    if args.list:
        for name in benches:
            print(name)
        return 0

    filters = args.only.split(",") if args.only else None
    print("name,value,derived")
    failures = 0
    for name, fn in benches.items():
        if filters and not any(f in name for f in filters):
            continue
        t0 = time.time()
        try:
            for row in fn():
                key, val, note = (list(row) + ["", ""])[:3]
                print(f"{key},{val},{note}")
            print(f"_timing/{name},{time.time()-t0:.2f}s,")
        except Exception as e:  # noqa
            failures += 1
            print(f"_error/{name},{type(e).__name__}: {e},")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
