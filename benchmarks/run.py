"""Benchmark harness: one function per paper table/figure plus the
dry-run roofline table. Prints ``name,value,derived`` CSV.

  PYTHONPATH=src python -m benchmarks.run [--only fig17,fig19] [--list]
                                          [--json BENCH_figures.json]
                                          [--backend numpy|jax]

``--json`` additionally writes a machine-readable artifact with every
row plus per-benchmark wall times, so the perf trajectory of the
simulator itself lands in version-controlled ``BENCH_*.json`` files.
``--backend`` scopes the whole run inside a
``repro.core.session.SweepSession`` so every batched sweep a figure
runs — without threading a flag through each function — executes on the
chosen substrate.
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated substring filters")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--json", default=None, metavar="OUT",
                    help="also write rows + timings to this JSON file")
    ap.add_argument("--backend", default=None, choices=("numpy", "jax"),
                    help="session array backend for all sweeps")
    args = ap.parse_args(argv)

    if args.backend:
        from repro.core.session import SweepSession
        with SweepSession(backend=args.backend):
            return _run(args)
    return _run(args)


def _run(args) -> int:
    from benchmarks.figures import REGISTRY
    from benchmarks import arch_power, roofline_table

    benches = dict(REGISTRY)
    benches["roofline_table"] = roofline_table.main
    benches["arch_power_table"] = arch_power.arch_power_table
    benches["regate_on_dryrun_cells"] = arch_power.regate_on_dryrun_cells

    if args.list:
        for name in benches:
            print(name)
        return 0

    filters = args.only.split(",") if args.only else None
    print("name,value,derived")
    failures = 0
    t_start = time.time()
    artifact: dict = {"benchmarks": {}, "errors": {}}
    for name, fn in benches.items():
        if filters and not any(f in name for f in filters):
            continue
        t0 = time.time()
        try:
            rows = []
            for row in fn():
                key, val, note = (list(row) + ["", ""])[:3]
                print(f"{key},{val},{note}")
                rows.append({"name": key, "value": val, "note": note})
            dt = time.time() - t0
            print(f"_timing/{name},{dt:.2f}s,")
            artifact["benchmarks"][name] = {"wall_s": round(dt, 4),
                                            "rows": rows}
        except Exception as e:  # noqa
            failures += 1
            print(f"_error/{name},{type(e).__name__}: {e},")
            artifact["errors"][name] = f"{type(e).__name__}: {e}"
    artifact["total_wall_s"] = round(time.time() - t_start, 4)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(artifact, f, indent=1)
        print(f"_json/{args.json},written,")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
