"""Cross-plane integration: ReGate's energy analysis applied to OUR ten
assigned architectures (the execution plane's workloads), two ways:

* ``arch_power_table`` — the analytic operator traces
  (``opgen.arch_workload``) through the five power-gating designs;
* ``regate_on_dryrun_cells`` — the COMPILED dry-run statistics (HLO FLOPs
  / HBM bytes / collective bytes per device) folded into a trace and
  evaluated, so the energy numbers correspond to the program XLA actually
  built for the production mesh.
"""
from __future__ import annotations

import glob
import json
import os

from repro.configs import SHAPES, get_arch, list_archs
from repro.core.opgen import Op, Workload, arch_workload
from repro.core.policies import evaluate_all, savings_vs_nopg

RESULTS = os.environ.get("DRYRUN_RESULTS", "results/dryrun")


def arch_power_table() -> list[tuple]:
    out = []
    for arch in list_archs():
        cfg = get_arch(arch)
        for sname, status in cfg.supported_shapes().items():
            if status != "ok":
                continue
            wl = arch_workload(cfg, SHAPES[sname])
            reps = evaluate_all(wl, "NPU-D")
            sv = savings_vs_nopg(reps)
            out.append((
                f"arch_save/{arch}/{sname}",
                f"full={sv['ReGate-Full']*100:.1f}% "
                f"base={sv['ReGate-Base']*100:.1f}%",
                f"static_frac={reps['NoPG'].static_frac:.2f}"))
    return out


def _dryrun_workload(r: dict) -> Workload:
    """Fold a dry-run cell's per-device HLO statistics into a 3-phase
    trace: compute+memory overlapped per layer, collectives between."""
    layers = max(1, int(r.get("n_layers", 32)))
    coll = sum(r["collective_bytes"].values())
    ops = []
    per = Op("layer_compute",
             flops_sa=r["flops"] * 0.92 / layers,
             flops_vu=r["flops"] * 0.08 / layers,
             bytes_hbm=r["memory_bytes"] / layers,
             sram_demand=96 << 20 if r["shape"] == "train_4k" else 8 << 20,
             matmul_dims=None)
    cop = Op("layer_collective", bytes_ici=coll / layers, collective=True,
             sram_demand=8 << 20)
    for _ in range(layers):
        ops.append(per)
        ops.append(cop)
    return Workload(f"{r['arch']}-{r['shape']}-dryrun", "train",
                    tuple(ops), n_chips=r["n_chips"])


def regate_on_dryrun_cells() -> list[tuple]:
    out = []
    for path in sorted(glob.glob(os.path.join(RESULTS, "singlepod",
                                              "*.json"))):
        r = json.load(open(path))
        if str(r.get("status")) != "ok" or r.get("tag"):
            continue
        cfg = get_arch(r["arch"])
        r["n_layers"] = cfg.n_layers
        wl = _dryrun_workload(r)
        sv = savings_vs_nopg(evaluate_all(wl, "NPU-D"))
        out.append((f"dryrun_save/{r['arch']}/{r['shape']}",
                    f"full={sv['ReGate-Full']*100:.1f}%",
                    "energy model on compiled-HLO stats"))
    return out
