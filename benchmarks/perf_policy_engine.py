"""Policy-engine throughput microbenchmark.

Times the batched design-space sweep path — ``paper_suite()`` × all 5
policies × a 4-point knob grid on NPU-D — on both engines:

* vectorized: ``repro.core.sweep.sweep`` over the columnar engine
  (trace compilation is excluded from the timing: the identity cache is
  warm after the first pass and best-of-N takes the minimum — in
  production one compile serves every sweep cell);
* reference:  the original scalar ``evaluate_reference`` per-op loop.

Throughput is executed op-instances per second (trace length with
repetition counts expanded, summed over every sweep cell). Writes
``BENCH_policy_engine.json``; the acceptance gate is speedup >= 10x.

  PYTHONPATH=src python -m benchmarks.perf_policy_engine [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import time

from repro.core.hw import get_npu
from repro.core.opgen import compile_trace, paper_suite
from repro.core.policies import (POLICIES, PolicyKnobs, evaluate_reference)
from repro.core.sweep import sweep

KNOB_GRID = [
    PolicyKnobs(),
    PolicyKnobs(delay_scale=2.0),
    PolicyKnobs(delay_scale=4.0),
    PolicyKnobs(leak_off_logic=0.2, leak_sram_sleep=0.4,
                leak_sram_off=0.02),
]


def run(out_path: str = "BENCH_policy_engine.json",
        reps_vectorized: int = 3) -> dict:
    suite = paper_suite()
    n_cells = len(suite) * len(POLICIES) * len(KNOB_GRID)

    # --- vectorized sweep path (best of N passes; compile cost lands on
    # the first pass only and is excluded by the min) ---
    t_vec = float("inf")
    for _ in range(reps_vectorized):
        t0 = time.perf_counter()
        records = sweep(suite, npus=("NPU-D",), policies=POLICIES,
                        knob_grid=KNOB_GRID)
        t_vec = min(t_vec, time.perf_counter() - t0)
    assert len(records) == n_cells

    # --- scalar reference engine, same cells, single pass ---
    npu = get_npu("NPU-D")
    t0 = time.perf_counter()
    for wl in suite:
        for policy in POLICIES:
            for knobs in KNOB_GRID:
                evaluate_reference(wl, npu, policy, knobs)
    t_ref = time.perf_counter() - t0

    ops_per_pass = sum(compile_trace(wl).n_instances for wl in suite) \
        * len(POLICIES) * len(KNOB_GRID)
    result = {
        "workloads": len(suite),
        "policies": len(POLICIES),
        "knob_settings": len(KNOB_GRID),
        "sweep_cells": n_cells,
        "op_instances_per_pass": ops_per_pass,
        "vectorized_wall_s": round(t_vec, 4),
        "reference_wall_s": round(t_ref, 4),
        "ops_per_sec_vectorized": round(ops_per_pass / t_vec),
        "ops_per_sec_reference": round(ops_per_pass / t_ref),
        "speedup": round(t_ref / t_vec, 2),
    }
    with open(out_path, "w") as f:
        json.dump(result, f, indent=1)
    return result


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_policy_engine.json")
    args = ap.parse_args(argv)
    r = run(args.out)
    for k, v in r.items():
        print(f"{k}: {v}")
    ok = r["speedup"] >= 10.0
    print(f"gate(speedup>=10x): {'PASS' if ok else 'FAIL'}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
