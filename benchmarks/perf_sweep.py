"""Batched sweep-plane throughput benchmark.

Times the full design-space grid — ``paper_suite()`` × all 5 NPU
generations × all 5 policies × a 4-point knob grid (1700 cells) — on
both sweep paths:

* batched:   ``repro.core.sweep.sweep`` → one ``evaluate_batch`` pass
  over the stacked super-trace (best of N; the stacked/per-NPU derived
  caches are warm after the first pass, matching production where one
  compile serves every sweep);
* reference: ``repro.core.sweep.sweep_reference`` — the original loop,
  one columnar ``evaluate`` round-trip per cell.

Also verifies the acceptance contract: record-for-record relative
equivalence ≤1e-9 on every numeric field and byte-identical record
ordering. Writes ``BENCH_sweep.json``; the gate is speedup >= 10x AND
equivalence, enforced in CI together with ``check_regression.py``.

  PYTHONPATH=src python -m benchmarks.perf_sweep [--out PATH]
                                                 [--backend numpy|jax]

``--backend jax`` runs the batched side on the jitted jax backend
(``benchmarks/perf_sweep_jax.py`` is the dedicated jax gate on the
100k-cell fine grid; this flag is for ad-hoc A/B on the 1700-cell
grid).
"""
from __future__ import annotations

import argparse
import json
import time

from repro.core.hw import NPUS
from repro.core.opgen import paper_suite
from repro.core.policies import POLICIES, PolicyKnobs
from repro.core.sweep import sweep, sweep_reference

RTOL = 1e-9

KNOB_GRID = [
    PolicyKnobs(),
    PolicyKnobs(delay_scale=2.0),
    PolicyKnobs(delay_scale=4.0),
    PolicyKnobs(leak_off_logic=0.2, leak_sram_sleep=0.4,
                leak_sram_off=0.02),
]


def _max_rel_dev(ref: list[dict], bat: list[dict]) -> float:
    """Worst relative deviation over every numeric field of every
    record; raises if orderings or field sets differ."""
    assert len(ref) == len(bat), (len(ref), len(bat))
    worst = 0.0
    for a, b in zip(ref, bat):
        assert set(a) == set(b), set(a) ^ set(b)
        for k, va in a.items():
            vb = b[k]
            if isinstance(va, (str, type(None))) or k == "knob_idx":
                assert va == vb, (k, va, vb)
                continue
            worst = max(worst,
                        abs(va - vb) / max(1e-30, abs(va), abs(vb)))
    return worst


def run(out_path: str = "BENCH_sweep.json",
        reps_batched: int = 3, backend: str = "numpy") -> dict:
    suite = paper_suite()
    npus = tuple(NPUS)
    n_cells = len(suite) * len(npus) * len(POLICIES) * len(KNOB_GRID)

    # --- batched sweep plane (best of N; trace/stack caches warm after
    # the first pass, so the min measures the steady-state sweep cost;
    # on --backend jax the first pass also compiles the program) ---
    t_bat = float("inf")
    for _ in range(reps_batched):
        t0 = time.perf_counter()
        batched = sweep(suite, npus=npus, policies=POLICIES,
                        knob_grid=KNOB_GRID, backend=backend)
        t_bat = min(t_bat, time.perf_counter() - t0)
    assert len(batched) == n_cells

    # --- loop oracle, same grid, single pass ---
    t0 = time.perf_counter()
    reference = sweep_reference(suite, npus=npus, policies=POLICIES,
                                knob_grid=KNOB_GRID)
    t_ref = time.perf_counter() - t0

    order_ref = [(r["workload"], r["npu"], r["policy"], r["knob_idx"])
                 for r in reference]
    order_bat = [(r["workload"], r["npu"], r["policy"], r["knob_idx"])
                 for r in batched]
    max_dev = _max_rel_dev(reference, batched)

    result = {
        "backend": backend,
        "workloads": len(suite),
        "npus": len(npus),
        "policies": len(POLICIES),
        "knob_settings": len(KNOB_GRID),
        "sweep_cells": n_cells,
        "batched_wall_s": round(t_bat, 4),
        "reference_wall_s": round(t_ref, 4),
        "cells_per_sec_batched": round(n_cells / t_bat),
        "cells_per_sec_reference": round(n_cells / t_ref),
        "speedup": round(t_ref / t_bat, 2),
        "max_rel_dev": max_dev,
        "ordering_identical": order_ref == order_bat,
    }
    with open(out_path, "w") as f:
        json.dump(result, f, indent=1)
    return result


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None,
                    help="output JSON (default BENCH_sweep.json; a "
                         "non-numpy backend defaults to "
                         "BENCH_sweep.<backend>.json so an ad-hoc A/B "
                         "run cannot dirty the committed numpy "
                         "baseline)")
    ap.add_argument("--backend", default="numpy",
                    choices=("numpy", "jax"),
                    help="array backend for the batched path (the loop "
                         "oracle always runs eager numpy)")
    args = ap.parse_args(argv)
    out = args.out if args.out is not None else (
        "BENCH_sweep.json" if args.backend == "numpy"
        else f"BENCH_sweep.{args.backend}.json")
    r = run(out, backend=args.backend)
    for k, v in r.items():
        print(f"{k}: {v}")
    # the >=10x contract is the numpy batched plane's CI gate; on the
    # small 1700-cell grid the jax backend is dominated by fixed
    # per-call dispatch/transfer cost, so the ad-hoc --backend jax run
    # only sanity-gates >=2x here — its real gate is
    # benchmarks/perf_sweep_jax.py at 100k-cell scale
    min_speedup = 10.0 if args.backend == "numpy" else 2.0
    ok = (r["speedup"] >= min_speedup and r["max_rel_dev"] <= RTOL
          and r["ordering_identical"])
    print(f"gate(speedup>={min_speedup:g}x & rel_dev<={RTOL:g} & "
          f"same order): {'PASS' if ok else 'FAIL'}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
