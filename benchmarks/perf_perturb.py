"""Jitter-plane throughput benchmark (ISSUE 6).

Times the idle-detection robustness sweep — topology-lowered decode
suite × perturbation severities × a 5-point threshold grid through
``sweep_robustness`` — against a clean (severity-free) sweep of the
same lowered suite over the same threshold grid. The perturbed pass
stacks ``len(severities)``× the workload variants AND pays the
perturbation-engine cost (seeded transform chains per variant), so the
gate is per-cell throughput: the robustness sweep must stay within 2×
of the clean sweep plane (``speedup`` = perturbed/clean cells-per-sec
ratio, gate ``>= 0.5``).

Also runs the differential fuzz harness as a smoke (EventTimeline vs
VLIWTimeline on adversarial sparse programs) and fails on any
mismatch. Writes ``BENCH_perturb.json``; CI enforces the gate together
with ``check_regression.py``.

  PYTHONPATH=src python -m benchmarks.perf_perturb [--out PATH]
                                                   [--fuzz N]
"""
from __future__ import annotations

import argparse
import json
import time

from repro.core.ici_topology import lower_collectives
from repro.core.opgen import paper_suite
from repro.core.perturb import differential_fuzz
from repro.core.policies import PolicyKnobs, evaluate_batch
from repro.core.sweep import sweep_robustness

SEVERITIES = (0.0, 1.0, 2.0)
THRESHOLDS = (0.25, 0.5, 1.0, 2.0, 4.0)
POLS = ("ReGate-HW", "NoPG")
GATE_MIN_SPEEDUP = 0.5          # perturbed within 2x of clean


def run(out_path: str = "BENCH_perturb.json", reps: int = 3,
        fuzz_programs: int = 50) -> dict:
    suite = paper_suite()[8:12]          # the decode serving suite
    grid = tuple(PolicyKnobs(window_scale=t) for t in THRESHOLDS)

    # --- clean sweep plane: lowered suite x threshold grid. The
    # lowering + trace compile runs inside the timed region (fresh
    # Workload objects, cold compile cache) because the robustness
    # sweep pays exactly that cost per variant — the gate compares
    # per-cell throughput at equal cache temperature ---
    t_clean = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        lowered = [lower_collectives(wl) for wl in suite]
        evaluate_batch(lowered, ("NPU-D",), POLS, grid,
                       backend="numpy")
        t_clean = min(t_clean, time.perf_counter() - t0)
    cells_clean = len(suite) * 1 * len(POLS) * len(THRESHOLDS)

    # --- robustness sweep: same suite crossed with the severity axis,
    # including per-variant perturbation generation + regret assembly ---
    t_pert = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        rob = sweep_robustness(suite, ("NPU-D",), ("ReGate-HW",),
                               severities=SEVERITIES,
                               threshold_scales=THRESHOLDS, seed=0,
                               backend="numpy")
        t_pert = min(t_pert, time.perf_counter() - t0)
    # NoPG rides along for the exposed-wake baseline, so the perturbed
    # stack evaluates the same (policy x threshold) plane per variant
    cells_pert = (len(suite) * len(SEVERITIES) * 1 * len(POLS)
                  * len(THRESHOLDS))

    thr_clean = cells_clean / t_clean
    thr_pert = cells_pert / t_pert
    speedup = thr_pert / thr_clean

    # --- differential fuzz smoke: adversarial ISA programs must agree
    # exactly across executors (any mismatch fails the benchmark) ---
    fuzz = differential_fuzz(fuzz_programs, seed=0)
    assert fuzz["mismatches"] == 0, fuzz

    s2 = next(s for s in rob["summary"] if s["severity"] == 2.0)
    result = {
        "workloads": len(suite),
        "severities": len(SEVERITIES),
        "thresholds": len(THRESHOLDS),
        "clean_cells": cells_clean,
        "perturbed_cells": cells_pert,
        "clean_wall_s": round(t_clean, 4),
        "perturbed_wall_s": round(t_pert, 4),
        "cells_per_sec_clean": round(thr_clean),
        "cells_per_sec_perturbed": round(thr_pert),
        "speedup": round(speedup, 3),
        "gate_min_speedup": GATE_MIN_SPEEDUP,
        "slo_violation_rate_s2": s2["slo_violation_rate"],
        "max_regret_frac_s2": round(s2["max_regret_frac"], 6),
        "fuzz_programs": fuzz["programs"],
        "fuzz_runs": fuzz["runs"],
        "fuzz_mismatches": fuzz["mismatches"],
    }
    with open(out_path, "w") as f:
        json.dump(result, f, indent=1)
    return result


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_perturb.json")
    ap.add_argument("--fuzz", type=int, default=50,
                    help="differential-fuzz program count for the "
                         "smoke (CI runs the full 200 in the test "
                         "suite)")
    args = ap.parse_args(argv)
    r = run(out_path=args.out, fuzz_programs=args.fuzz)
    print(json.dumps(r, indent=1))
    if r["speedup"] < GATE_MIN_SPEEDUP:
        print(f"FAIL: perturbed sweep throughput ratio "
              f"{r['speedup']} < {GATE_MIN_SPEEDUP}")
        return 1
    if r["fuzz_mismatches"]:
        print("FAIL: differential fuzz mismatches")
        return 1
    print(f"OK: perturbed/clean throughput ratio {r['speedup']} "
          f">= {GATE_MIN_SPEEDUP}; fuzz clean over "
          f"{r['fuzz_runs']} runs")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
