"""Roofline table assembled from the dry-run JSON artifacts.

Reads results/dryrun/{singlepod,multipod}/*.json (produced by
``python -m repro.launch.dryrun --all [--multi-pod]``) and emits the
per-cell three-term roofline rows for EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

import glob
import json
import os

RESULTS = os.environ.get("DRYRUN_RESULTS", "results/dryrun")


def load(pod: str = "singlepod") -> list[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(RESULTS, pod, "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def rows(pod: str = "singlepod") -> list[tuple]:
    out = []
    for r in load(pod):
        cell = f"{r['arch']}/{r['shape']}"
        if r.get("tag"):
            cell += f"#{r['tag']}"
        status = str(r.get("status", ""))
        if status != "ok":
            out.append((f"roofline/{cell}", status, ""))
            continue
        out.append((
            f"roofline/{cell}",
            f"compute={r['compute_s']*1e3:.1f}ms "
            f"memory={r['memory_s']*1e3:.1f}ms "
            f"collective={r['collective_s']*1e3:.1f}ms",
            f"dom={r['dominant']} hbm={r['hbm_gb_per_device']}GiB "
            f"flops_ratio={r['flops_ratio']:.2f}"))
    return out


def main() -> list[tuple]:
    out = rows("singlepod")
    mp = rows("multipod")
    if mp:
        out.append(("roofline/multipod_cells", len(mp), "2x16x16 mesh"))
    return out
