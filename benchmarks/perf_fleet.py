"""Fleet-plane throughput benchmark (ISSUE 7).

Simulates a 24h day on a 4096-chip fleet — 4 tenant classes, ~2.3M
requests, 96 serving epochs, a 24-point knob grid, 3 congestion levels —
and gates the one-batched-call-per-epoch design: ``sweep_fleet``'s
epoch rate must be >= 10x a per-cell reference that evaluates the same
epochs through the original ``evaluate`` loop (one policy-engine
round-trip per (workload, policy, knob) cell, the ``sweep_reference``
discipline). The reference only replays ``REF_EPOCHS`` epochs — at
per-cell speed the full day would dominate CI — and is scaled to an
epochs/sec rate on identical epoch inputs (``keep_epoch_inputs``), so
both sides price exactly the same evaluation work.

Writes ``BENCH_fleet.json`` (registered in ``check_regression``).

  PYTHONPATH=src python -m benchmarks.perf_fleet [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import time

from repro.core.fleet import (ArrivalSpec, FleetScenario, WorkloadClass,
                              sweep_fleet)
from repro.core.hw import get_npu
from repro.core.opgen import dlrm_workload, llm_workload
from repro.core.policies import KnobGrid, evaluate

MIN_SPEEDUP = 10.0
MIN_REQUESTS = 1_000_000
REF_EPOCHS = 3

GRID = KnobGrid(window_scale=(0.25, 0.5, 1.0, 2.0),
                delay_scale=(1.0, 2.0, 4.0),
                leak_off_logic=(None, 0.2))


def build_scenario() -> FleetScenario:
    """The examples/fleet_day.py fleet: diurnal chat decode + prefill,
    a bursty 70B tier, steady DLRM — >=1M requests on 4096 chips."""
    classes = (
        WorkloadClass(
            "chat-decode",
            llm_workload("llama3-8b", "decode", batch=8),
            ArrivalSpec("diurnal", rate_rps=10.0, peak_frac=0.9,
                        period_s=86400.0, phase_s=-21600.0),
            requests_per_invocation=8),
        WorkloadClass(
            "chat-prefill",
            llm_workload("llama3-8b", "prefill", batch=1, seq=4096),
            ArrivalSpec("diurnal", rate_rps=10.0, peak_frac=0.9,
                        period_s=86400.0, phase_s=-21600.0)),
        WorkloadClass(
            "research-70b",
            llm_workload("llama3-70b", "decode", batch=4, n_chips=8,
                         tp=8),
            ArrivalSpec("bursty", rate_rps=1.5, burst_prob=0.15,
                        burst_factor=8.0),
            requests_per_invocation=4),
        WorkloadClass(
            "ranking-dlrm", dlrm_workload("M"),
            ArrivalSpec("poisson", rate_rps=3.0),
            requests_per_invocation=1024),
    )
    return FleetScenario(
        classes=classes, n_chips=4096, npu="NPU-D",
        policies=("NoPG", "ReGate-HW", "ReGate-Full"),
        duration_s=86400.0, epoch_s=900.0, slo_relax=1.2, seed=7,
        severity_levels=(0.0, 0.5, 1.0))


def run(out_path: str = "BENCH_fleet.json", reps: int = 3) -> dict:
    sc = build_scenario()
    knobs = tuple(GRID.product())

    # warm-up run: compiles/caches every trace variant, and captures
    # the epoch inputs the per-cell reference will replay
    warm = sweep_fleet(sc, GRID, keep_epoch_inputs=True)
    assert warm.requests_total >= MIN_REQUESTS
    assert warm.n_chips >= 4096

    t_fleet = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        rep = sweep_fleet(sc, GRID)
        t_fleet = min(t_fleet, time.perf_counter() - t0)
    assert rep.records == warm.records  # determinism, while we're here

    # per-cell reference on identical epoch inputs: one evaluate()
    # round-trip per (workload, policy, knob) cell, REF_EPOCHS epochs
    npu = get_npu(sc.npu)
    ref_inputs = warm.epoch_inputs[:REF_EPOCHS]
    t0 = time.perf_counter()
    cells = 0
    for wls, _sev in ref_inputs:
        for wl in wls:
            for policy in sc.policies:
                for k in knobs:
                    evaluate(wl, npu, policy, k)
                    cells += 1
    t_ref = time.perf_counter() - t0

    eps_fleet = warm.n_epochs / t_fleet
    eps_ref = len(ref_inputs) / t_ref
    result = {
        "n_chips": warm.n_chips,
        "classes": len(sc.classes),
        "policies": len(sc.policies),
        "knob_settings": len(knobs),
        "epochs": warm.n_epochs,
        "requests_total": warm.requests_total,
        "severity_levels": len(sc.severity_levels),
        "fleet_wall_s": round(t_fleet, 4),
        "ref_epochs": len(ref_inputs),
        "ref_cells": cells,
        "ref_wall_s": round(t_ref, 4),
        "epochs_per_sec_fleet": round(eps_fleet, 2),
        "epochs_per_sec_ref": round(eps_ref, 2),
        "requests_per_sec": round(warm.requests_total / t_fleet),
        "speedup": round(eps_fleet / eps_ref, 2),
    }
    with open(out_path, "w") as f:
        json.dump(result, f, indent=1)
    return result


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_fleet.json")
    args = ap.parse_args(argv)
    r = run(args.out)
    for k, v in r.items():
        print(f"{k}: {v}")
    ok = (r["speedup"] >= MIN_SPEEDUP
          and r["requests_total"] >= MIN_REQUESTS
          and r["n_chips"] >= 4096)
    print(f"gate(speedup>={MIN_SPEEDUP:g}x & requests>="
          f"{MIN_REQUESTS:,} & chips>=4096): {'PASS' if ok else 'FAIL'}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
