"""Chaos-plane throughput benchmark (ISSUE 8).

Replays the ``perf_fleet`` 24h / 4096-chip / 24-knob fleet day through
``sweep_chaos`` at fault severity 1 — chip MTBF fail/repair cycles,
maintenance drains, link flap/degrade/down traces that re-lower every
affected class onto detoured ring schedules, pg-fault fallback rows,
and the stateful hysteresis governor — and gates the overhead of all
of that bookkeeping: the faulted campaign's epoch rate must stay
within 2x of the clean ``sweep_fleet`` rate (``speedup`` = chaos
epochs/sec over clean epochs/sec, floor 0.5).

The clean reference runs the same scenario with every class workload
pre-lowered onto its ``ici_topology`` step schedule, because a chaos
run with link faults anywhere in its window lowers ALL epochs (a
ring-8 collective lowers to ~6x the op rows): both sides then price
identical trace shapes, and the ratio isolates what the chaos plane
itself adds — timeline realization, per-link-state variant rebuilds,
fault bookkeeping, and the stateful governor — rather than the
topology model's op-count inflation.

Writes ``BENCH_chaos.json`` (registered in ``check_regression``).

  PYTHONPATH=src python -m benchmarks.perf_chaos [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import time
from dataclasses import replace

from benchmarks.perf_fleet import GRID, build_scenario
from repro.core.fleet import sweep_fleet
from repro.core.ici_topology import lower_collectives, topology_for
from repro.core.sweep import sweep_chaos

MIN_SPEEDUP = 0.5
FAULT_SEVERITY = 1.0


def run(out_path: str = "BENCH_chaos.json", reps: int = 3) -> dict:
    sc = build_scenario()
    # clean reference on pre-lowered traces (see module docstring)
    sc_low = replace(sc, classes=tuple(
        replace(c, workload=lower_collectives(
            c.workload, topology_for(max(1, c.workload.n_chips))))
        for c in sc.classes))

    # warm-up: compiles/caches every clean trace variant
    warm = sweep_fleet(sc_low, GRID)

    t_clean = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        rep = sweep_fleet(sc_low, GRID)
        t_clean = min(t_clean, time.perf_counter() - t0)
    assert rep.records == warm.records

    # chaos campaign: one faulted severity, hysteresis governor, no
    # stateless baseline rerun (the clean run above is the reference).
    # Timed inclusive of timeline realization and per-link-state
    # re-lowering — that bookkeeping IS the overhead under test.
    warm_c = sweep_chaos(sc, GRID, fault_severities=(FAULT_SEVERITY,),
                         thrash_baseline=False)
    t_chaos = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        camp = sweep_chaos(sc, GRID,
                           fault_severities=(FAULT_SEVERITY,),
                           thrash_baseline=False)
        t_chaos = min(t_chaos, time.perf_counter() - t0)
    crep = camp["reports"][FAULT_SEVERITY]
    assert crep.records == warm_c["reports"][FAULT_SEVERITY].records
    tl = camp["timelines"][FAULT_SEVERITY]
    assert tl.any_fault().any(), "severity 1 timeline realized no faults"

    eps_clean = warm.n_epochs / t_clean
    eps_chaos = crep.n_epochs / t_chaos
    result = {
        "n_chips": warm.n_chips,
        "classes": len(sc.classes),
        "policies": len(sc.policies),
        "knob_settings": GRID.size,
        "epochs": warm.n_epochs,
        "fault_severity": FAULT_SEVERITY,
        "faulted_epochs": int(tl.any_fault().sum()),
        "fault_transitions": int(tl.n_transitions),
        "link_fault_epochs": int(
            crep.fault_summary["link_fault_epochs"]),
        "pg_fault_epochs": int(crep.fault_summary["pg_fault_epochs"]),
        "retunes": int(sum(s["retunes"] for s in crep.summary)),
        "clean_wall_s": round(t_clean, 4),
        "chaos_wall_s": round(t_chaos, 4),
        "epochs_per_sec_clean": round(eps_clean, 2),
        "epochs_per_sec_chaos": round(eps_chaos, 2),
        "speedup": round(eps_chaos / eps_clean, 3),
    }
    with open(out_path, "w") as f:
        json.dump(result, f, indent=1)
    return result


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_chaos.json")
    args = ap.parse_args(argv)
    r = run(args.out)
    for k, v in r.items():
        print(f"{k}: {v}")
    ok = r["speedup"] >= MIN_SPEEDUP and r["faulted_epochs"] > 0
    print(f"gate(chaos epoch rate >= {MIN_SPEEDUP:g}x clean fleet "
          f"rate & timeline faulted): {'PASS' if ok else 'FAIL'}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
