"""Topology-level ICI lowering (jitter plane, ISSUE 6).

Ring / 2-D-mesh step schedules, exact wire-byte conservation under
``lower_collectives``, and the NoPG invariance contract: lowering only
reshapes the ICI gap structure, so un-gated energy and runtime are
unchanged to <= 1e-9.
"""
import numpy as np
import pytest

from repro.core.ici_topology import (Topology, collective_schedule,
                                     ici_busy_idle, lower_collectives,
                                     schedule_kind, topology_for)
from repro.core.opgen import dlrm_workload, llm_workload, paper_suite
from repro.core.policies import PolicyKnobs, evaluate, evaluate_batch

from _sweep_equiv import rel


# ------------------------------------------------------------------ topology

def test_topology_for_shapes():
    assert topology_for(1) == Topology("ring", (1,))
    assert topology_for(8) == Topology("ring", (8,))
    assert topology_for(16) == Topology("mesh2d", (4, 4))
    assert topology_for(256) == Topology("mesh2d", (16, 16))
    assert topology_for(512) == Topology("mesh2d", (16, 32))
    # explicit kind override
    assert topology_for(16, kind="ring") == Topology("ring", (16,))


def test_topology_validation():
    with pytest.raises(ValueError):
        topology_for(0)
    with pytest.raises(ValueError):
        Topology("hypercube", (4,))
    with pytest.raises(ValueError):
        Topology("ring", (4, 4))
    with pytest.raises(ValueError):
        Topology("mesh2d", (4, 0))


def test_schedule_kind_naming():
    assert schedule_kind("ar_mlp") == "all_reduce"
    assert schedule_kind("grad_allreduce") == "all_reduce"
    assert schedule_kind("emb_alltoall") == "all_to_all"
    assert schedule_kind("moe_a2a") == "all_to_all"
    assert schedule_kind("ag_params") == "all_gather"


@pytest.mark.parametrize("kind,n,steps", [
    ("all_reduce", 8, 14), ("all_gather", 8, 7), ("all_to_all", 8, 7),
    ("all_reduce", 1, 0),
])
def test_ring_schedule_lengths(kind, n, steps):
    frac = collective_schedule(kind, Topology("ring", (n,)))
    assert len(frac) == steps
    if steps:
        assert rel(frac.sum(), 1.0) <= 1e-12
        assert (frac > 0).all()


def test_mesh_schedule_sums_to_one():
    topo = Topology("mesh2d", (4, 8))
    frac = collective_schedule("all_reduce", topo)
    # 2(4-1) + 2(8-1) steps, one ring phase per axis
    assert len(frac) == 6 + 14
    assert rel(frac.sum(), 1.0) <= 1e-12
    with pytest.raises(ValueError):
        collective_schedule("broadcast", topo)


# ------------------------------------------------------------------ lowering

WL = llm_workload("llama3-70b", "train", batch=32, n_chips=256,
                  tp=8, dp=32)


def test_lower_collectives_conserves_wire_bytes():
    low = lower_collectives(WL)
    assert low.name == WL.name + "+topo"
    assert len(low.ops) > len(WL.ops)
    a = sum(o.bytes_ici * o.count for o in WL.ops)
    b = sum(o.bytes_ici * o.count for o in low.ops)
    assert rel(a, b) <= 1e-9
    # SA flops untouched; staging adds exactly 2x the lowered wire
    # bytes of HBM chunk traffic (read + write per step)
    a = sum(o.flops_sa * o.count for o in WL.ops)
    c = sum(o.flops_sa * o.count for o in low.ops)
    assert rel(a, c) <= 1e-12
    lowered_wire = sum(o.bytes_ici * o.count for o in WL.ops
                       if o.collective and o.bytes_ici > 0)
    h0 = sum(o.bytes_hbm * o.count for o in WL.ops)
    h1 = sum(o.bytes_hbm * o.count for o in low.ops)
    assert rel(h1 - h0, 2.0 * lowered_wire) <= 1e-9


def test_lowering_staging_off_is_pure_split():
    low = lower_collectives(WL, staging=False)
    for f in ("flops_sa", "flops_vu", "bytes_hbm", "bytes_ici"):
        a = sum(getattr(o, f) * o.count for o in WL.ops)
        b = sum(getattr(o, f) * o.count for o in low.ops)
        assert rel(a, b) <= 1e-9, f
    a = evaluate(WL, "NPU-D", "NoPG")
    b = evaluate(low, "NPU-D", "NoPG")
    assert rel(a.runtime_s, b.runtime_s) <= 1e-9
    assert rel(a.total_j, b.total_j) <= 1e-9


def test_lowering_refines_ici_gap_structure():
    low = lower_collectives(WL)
    g0 = ici_busy_idle(WL)["gaps_s"]
    g1 = ici_busy_idle(low)["gaps_s"]
    assert rel(ici_busy_idle(WL)["busy_s"].sum(),
               ici_busy_idle(low)["busy_s"].sum()) <= 1e-9
    assert len(g1) >= len(g0)  # steps split the busy runs


def test_nopg_wire_energy_invariant_under_lowering():
    """Wire bytes are conserved, so the un-gated ICI dynamic energy is
    invariant; the staging overhead stays a small runtime perturbation
    (the algorithmic cost a fused collective op idealizes away)."""
    low = lower_collectives(WL)
    a = evaluate(WL, "NPU-D", "NoPG")
    b = evaluate(low, "NPU-D", "NoPG")
    assert rel(a.dynamic_j["ici"], b.dynamic_j["ici"]) <= 1e-9
    assert abs(b.runtime_s - a.runtime_s) <= 0.15 * a.runtime_s
    res = evaluate_batch([WL, low], ("NPU-D",), ("NoPG",),
                         (PolicyKnobs(),), backend="numpy")
    assert rel(float(res.runtime_s[1, 0, 0, 0]), b.runtime_s) <= 1e-9


def test_lowering_changes_gated_energy():
    """The point of the exercise: gated designs DO see the refined
    timeline (step-granular bursts shorten the merged ICI gaps)."""
    low = lower_collectives(WL)
    a = evaluate(WL, "NPU-D", "ReGate-HW")
    b = evaluate(low, "NPU-D", "ReGate-HW")
    assert rel(a.static_j["ici"], b.static_j["ici"]) > 1e-9


def test_single_chip_workload_passthrough():
    wl = llm_workload("llama3-8b", "decode", batch=1, n_chips=1,
                      tp=1, dp=1)
    low = lower_collectives(wl)
    assert [o.name for o in low.ops] == [o.name for o in wl.ops]


def test_lowered_suite_sweeps_through_batched_plane():
    wls = [lower_collectives(w) for w in paper_suite()[8:10]]
    res = evaluate_batch(wls, ("NPU-D",), ("ReGate-HW", "NoPG"),
                         (PolicyKnobs(),), backend="numpy")
    assert np.isfinite(res.runtime_s).all()
    for c in res.static_j:
        assert np.isfinite(res.static_j[c]).all()


def test_dlrm_alltoall_lowering():
    wl = dlrm_workload("M", n_chips=64)
    low = lower_collectives(wl)
    a2a = [o for o in low.ops if "/s" in o.name
           and schedule_kind(o.name) == "all_to_all"]
    assert a2a, "expected lowered all-to-all steps"
