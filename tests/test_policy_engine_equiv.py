"""Equivalence of the columnar policy engine against the scalar oracle.

The vectorized ``evaluate`` must reproduce ``evaluate_reference`` to
<=1e-9 relative on every EnergyReport field, across the full paper suite
x all 5 policies x all NPU generations (plus knob overrides), and the
batched SA-gating math must match its scalar originals on randomized
shapes.
"""
import math

import numpy as np
import pytest

from repro.core.hw import NPUS, get_npu
from repro.core.opgen import compile_trace, llm_workload, paper_suite
from repro.core.policies import (POLICIES, PolicyKnobs, evaluate,
                                 evaluate_reference, trace_times)
from repro.core.power import COMPONENTS
from repro.core.sa_gating import (gating_stats, gating_stats_batch,
                                  simulate_pe_grid,
                                  simulate_pe_grid_reference)
from repro.core.sweep import group_by, sweep, with_savings

RTOL = 1e-9


def _rel(a: float, b: float) -> float:
    return abs(a - b) / max(1e-30, abs(a), abs(b))


def _assert_reports_match(a, b, ctx: str):
    assert _rel(a.runtime_s, b.runtime_s) <= RTOL, (ctx, "runtime")
    assert _rel(a.total_j, b.total_j) <= RTOL, (ctx, "total_j")
    assert _rel(a.setpm_count, b.setpm_count) <= RTOL, (ctx, "setpm")
    for c in COMPONENTS:
        assert _rel(a.static_j[c], b.static_j[c]) <= RTOL, (ctx, c)
        assert _rel(a.dynamic_j[c], b.dynamic_j[c]) <= RTOL, (ctx, c)
        assert _rel(a.wake_events[c], b.wake_events[c]) <= RTOL, (ctx, c)
        assert _rel(a.gated_s[c], b.gated_s[c]) <= RTOL, (ctx, c, "gated")
        assert _rel(a.setpm_by[c], b.setpm_by[c]) <= RTOL, \
            (ctx, c, "setpm_by")


@pytest.mark.parametrize("npu", sorted(NPUS))
@pytest.mark.parametrize("policy", POLICIES)
def test_vectorized_matches_reference_full_suite(npu, policy):
    for wl in paper_suite():
        _assert_reports_match(evaluate(wl, npu, policy),
                              evaluate_reference(wl, npu, policy),
                              f"{wl.name}/{policy}/{npu}")


@pytest.mark.parametrize("knobs", [
    PolicyKnobs(delay_scale=0.5),
    PolicyKnobs(delay_scale=4.0),
    PolicyKnobs(leak_off_logic=0.2, leak_sram_sleep=0.4,
                leak_sram_off=0.02),
    PolicyKnobs(leak_off_logic=0.0, delay_scale=2.0),
])
def test_vectorized_matches_reference_knob_overrides(knobs):
    for wl in paper_suite()[::4]:
        for policy in POLICIES:
            _assert_reports_match(
                evaluate(wl, "NPU-D", policy, knobs),
                evaluate_reference(wl, "NPU-D", policy, knobs),
                f"{wl.name}/{policy}/{knobs}")


def test_gating_stats_batch_matches_scalar_randomized():
    rng = np.random.default_rng(0)
    Ms = np.concatenate([rng.integers(1, 5000, 200), [1, 1, 8, 131072]])
    Ks = np.concatenate([rng.integers(1, 3000, 200), [1, 128, 64, 16384]])
    Ns = np.concatenate([rng.integers(1, 3000, 200), [1, 128, 129, 8016]])
    for saw in (8, 128, 256):
        batch = gating_stats_batch(Ms, Ks, Ns, saw)
        for i, (M, K, N) in enumerate(zip(Ms, Ks, Ns)):
            st = gating_stats(int(M), int(K), int(N), saw)
            assert math.isclose(batch.duration_cycles[i],
                                st.duration_cycles, rel_tol=RTOL)
            assert math.isclose(batch.frac_on[i], st.frac_on, rel_tol=RTOL)
            assert math.isclose(batch.frac_w_on[i], st.frac_w_on,
                                rel_tol=RTOL, abs_tol=1e-15)
            assert math.isclose(batch.frac_off[i], st.frac_off,
                                rel_tol=RTOL, abs_tol=1e-15)
            assert batch.wake_events[i] == st.wake_events


def test_simulate_pe_grid_matches_reference_randomized():
    rng = np.random.default_rng(1)
    for _ in range(25):
        saw = int(rng.choice([4, 8, 12]))
        M = int(rng.integers(1, 30))
        K = int(rng.integers(1, saw + 1))
        N = int(rng.integers(1, saw + 1))
        assert simulate_pe_grid(M, K, N, saw) \
            == simulate_pe_grid_reference(M, K, N, saw)


def test_simulate_pe_grid_vectorized_large_grid():
    """saw=128 is infeasible for the triple loop but cheap vectorized;
    cross-check against the closed form instead."""
    sim = simulate_pe_grid(512, 100, 64, 128)
    st = gating_stats(512, 100, 64, 128, weight_load_cycles=0)
    tot = sim["total"]
    assert math.isclose(st.frac_on, sim["on"] / tot, rel_tol=RTOL)
    assert math.isclose(st.frac_w_on, sim["w_on"] / tot, rel_tol=RTOL)
    assert math.isclose(st.frac_off, sim["off"] / tot, rel_tol=RTOL)


def test_compile_trace_columnar_totals():
    wl = llm_workload("llama3-8b", "decode", batch=8, n_chips=1)
    tr = compile_trace(wl)
    assert tr.n_ops == len(wl.ops)
    for attr in ("flops_sa", "flops_vu", "bytes_hbm", "bytes_ici"):
        assert math.isclose(tr.total(attr), wl.total(attr), rel_tol=RTOL)
    assert tr.n_instances == sum(o.count for o in wl.ops)
    # identity cache: same workload object -> same trace object
    assert compile_trace(wl) is tr
    # matmul dims round-trip
    for i, op in enumerate(wl.ops):
        if op.matmul_dims is not None:
            assert tr.has_mm[i]
            assert (tr.mm_m[i], tr.mm_k[i], tr.mm_n[i]) == op.matmul_dims
        else:
            assert not tr.has_mm[i]


def test_trace_times_cached_per_npu():
    wl = llm_workload("llama3-8b", "prefill", batch=4, n_chips=1)
    tr = compile_trace(wl)
    tm_d = trace_times(tr, get_npu("NPU-D"))
    assert trace_times(tr, get_npu("NPU-D")) is tm_d
    tm_e = trace_times(tr, get_npu("NPU-E"))
    assert tm_e is not tm_d


def test_trace_times_not_stale_for_modified_spec():
    """A replace()-modified spec reusing a registry name must not hit the
    registry spec's cached times (what-if exploration)."""
    from dataclasses import replace
    wl = llm_workload("llama3-8b", "prefill", batch=4, n_chips=1)
    base = get_npu("NPU-D")
    evaluate(wl, base, "NoPG")  # warm the cache for the registry spec
    fat = replace(base, sa_width=256)
    _assert_reports_match(evaluate(wl, fat, "NoPG"),
                          evaluate_reference(wl, fat, "NoPG"),
                          "modified-spec")


def test_sweep_records_match_direct_evaluate():
    wls = paper_suite()[:2]
    recs = with_savings(sweep(wls, npus=("NPU-D",), policies=POLICIES))
    assert len(recs) == len(wls) * len(POLICIES)
    by_cell = {(r["workload"], r["policy"]): r for r in recs}
    for wl in wls:
        base = evaluate(wl, "NPU-D", "NoPG")
        for p in POLICIES:
            rep = evaluate(wl, "NPU-D", p)
            r = by_cell[(wl.name, p)]
            assert _rel(r["total_j"], rep.total_j) <= RTOL
            assert _rel(r["runtime_s"], rep.runtime_s) <= RTOL
            assert math.isclose(r["savings"],
                                1.0 - rep.total_j / base.total_j,
                                rel_tol=RTOL, abs_tol=1e-12)
        grp = group_by([r for r in recs if r["workload"] == wl.name],
                       "policy")
        assert set(grp) == {(p,) for p in POLICIES}


def test_sweep_knob_grid_ordering():
    grid = [PolicyKnobs(), PolicyKnobs(delay_scale=2.0)]
    recs = sweep(paper_suite()[0], npus=("NPU-A", "NPU-D"),
                 policies=("NoPG", "ReGate-Full"), knob_grid=grid)
    assert len(recs) == 2 * 2 * 2
    # deterministic order: npu-major, then policy, then knob index
    assert [(r["npu"], r["policy"], r["knob_idx"]) for r in recs] == [
        (n, p, k) for n in ("NPU-A", "NPU-D")
        for p in ("NoPG", "ReGate-Full") for k in (0, 1)]
    assert recs[1]["delay_scale"] == 2.0
