"""Program plane vs closed-form policy engine (ISSUE 2 acceptance).

The lowered + §4.3-instrumented programs, executed on the event-driven
ISA executor, must reproduce ``policies.evaluate``'s ``ReGate-Full``
(sw) decisions across the paper suite x every NPU generation.

Stated tolerances (derivation in EXPERIMENTS.md §Program-plane):

* runtime: relative difference <= 0.5% (exposed-wake modeling: the
  executor stalls the schedule, the closed form adds overhead at the
  end and halves DMA-overlapped HBM/ICI wakes);
* per-component gated-cycle fraction: absolute difference <= 0.005
  (transition-edge accounting: the executor gates ``gap - delay``
  where the closed form charges ``gap - 2*delay``, plus sub-cycle
  schedule rounding);
* VU setpm count: relative difference <= 1e-6 (the §4.3 pass and the
  sw closed form apply the same BET rule to the same merged gaps);
* SRAM range-setpm count: program plane <= closed form (the BET rule
  and the Fig 14 range collapse only ever REMOVE instructions relative
  to the closed form's one-pair-per-demand-change upper bound).
"""
import numpy as np
import pytest

from repro.core.hw import NPUS, SRAM_SEGMENT_BYTES, get_npu
from repro.core.lowering import (crossval_record, execute_program,
                                 lower_workload, rescale_program,
                                 sram_band_gating)
from repro.core.opgen import Op, Workload, paper_suite
from repro.core.sweep import sweep_program_plane

RT_REL = 0.005
FRAC_ABS = 0.005
VU_SETPM_REL = 1e-6


@pytest.mark.parametrize("npu", sorted(NPUS))
def test_crossval_suite(npu):
    for rec in sweep_program_plane(paper_suite(), npus=(npu,)):
        ctx = (rec["workload"], npu)
        assert rec["runtime_rel_err"] <= RT_REL, (ctx, "runtime")
        for c in ("sa", "vu", "hbm", "ici", "sram"):
            assert rec[f"gated_frac_absdiff_{c}"] <= FRAC_ABS, (ctx, c)
            assert 0.0 <= rec[f"gated_frac_prog_{c}"] <= 1.0, (ctx, c)
        pv, qv = rec["setpm_policy_vu"], rec["setpm_prog_vu"]
        assert abs(pv - qv) <= VU_SETPM_REL * max(1.0, pv, qv), \
            (ctx, "vu setpm", pv, qv)
        assert rec["setpm_prog_sram"] <= rec["setpm_policy_sram"] + 1e-9, \
            (ctx, "sram setpm", rec["setpm_policy_sram"],
             rec["setpm_prog_sram"])


def test_event_and_reference_execution_agree_end_to_end():
    """execute_program on the event executor == on the dense stepper
    (full pipeline including instrumentation), on a compressed
    workload program."""
    wl = paper_suite()[8]  # llama3-8b decode
    prog = rescale_program(lower_workload(wl, "NPU-D"), 150_000)
    a = execute_program(prog)
    b = execute_program(prog, use_reference=True)
    assert a.cycles == b.cycles
    assert a.stall_cycles == b.stall_cycles
    assert a.setpm_isa == b.setpm_isa
    assert a.gated_cycles == b.gated_cycles
    assert a.wake_events == b.wake_events


def _brute_force_sram(prog, npu):
    """Independent per-segment reference: materialize every segment's
    busy pattern over the instance stream and apply the §4.3 rule."""
    n_seg = npu.sram_segments
    seg = SRAM_SEGMENT_BYTES
    bet = npu.gating.bet["sram_off"]
    delay = npu.gating.on_off_delay["sram_off"]
    horizon = prog.horizon
    gated = 0.0
    keys = set()
    dead_any = False
    for s in range(n_seg):
        busy = prog.demand > s * seg
        idx = np.flatnonzero(busy)
        if idx.size == 0:
            gated += horizon
            dead_any = True
            continue
        starts = prog.op_start[idx]
        ends = prog.op_end[idx]
        bs = np.concatenate(([0], ends))
        be = np.concatenate((starts, [horizon]))
        for a, b in zip(bs, be):
            gap = b - a
            if gap > bet and gap > 2 * delay:
                gated += gap - 2 * delay
                keys.add((int(a), int(b)))
    return gated, 2.0 * len(keys) + (1.0 if dead_any else 0.0)


def test_sram_band_gating_matches_per_segment_reference():
    """The band vectorization is exact: same gated segment-cycles and
    setpm count as the brute-force per-segment sweep (small SRAM)."""
    from dataclasses import replace
    npu = replace(get_npu("NPU-D"), sram_mb=1)  # 256 segments
    ops = tuple(
        Op(f"op{i}", flops_vu=1e9 * (1 + i % 3),
           sram_demand=d, count=c)
        for i, (d, c) in enumerate([
            (200 * 1024, 3), (900 * 1024, 1), (64 * 1024, 8),
            (0, 2), (1 << 20, 1), (300 * 1024, 5), (8 * 1024, 40),
        ]))
    wl = Workload("sram-bands", "train", ops)
    prog = lower_workload(wl, npu)
    band = sram_band_gating(prog)
    ref_gated, ref_setpm = _brute_force_sram(prog, npu)
    assert band["gated_segcycles"] == pytest.approx(ref_gated, rel=1e-12)
    assert band["setpm"] == ref_setpm
    assert band["dead_segments"] == 0  # 1 MiB demand covers the top
    cap = band["capacity_cycles"]
    assert 0.0 < band["gated_segcycles"] < cap
    assert band["busy_segcycles"] + band["gated_segcycles"] <= cap + 1e-6


def test_crossval_record_fields():
    rec = crossval_record(paper_suite()[12], "NPU-D")  # dlrm-S
    for c in ("sa", "vu", "hbm", "ici", "sram"):
        assert f"gated_frac_prog_{c}" in rec
    assert rec["n_events"] > 0
    assert rec["prog_cycles"] > 0
