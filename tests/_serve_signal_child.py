"""Child process for the serve drain tests (ISSUE 9 satellite).

Runs ``launch.serve.serve_arrivals`` over a fake (jax-free at the
server level; the module import still pulls jax) server whose
``generate`` sleeps, prints READY, and writes the final report to the
checkpoint path — the parent delivers SIGTERM/SIGINT mid-run and the
drain discipline must still produce the report and exit 0.
"""
import sys
import time

import numpy as np


class _FakeCfg:
    vocab_size = 1000


class FakeServer:
    """The slice of ``serve.Server`` that ``serve_arrivals`` touches."""

    batch = 4
    cfg = _FakeCfg()

    def __init__(self, wave_s: float = 0.05):
        self.wave_s = wave_s
        self.calls = 0

    def generate(self, prompts, n_tokens):
        assert prompts.shape[0] == self.batch
        self.calls += 1
        time.sleep(self.wave_s)
        return np.zeros((self.batch, n_tokens), np.int32)


if __name__ == "__main__":
    from repro.core.fleet import ArrivalSpec
    from repro.launch.serve import serve_arrivals

    checkpoint = sys.argv[1]
    spec = ArrivalSpec("poisson", rate_rps=40.0)
    print("READY", flush=True)
    stats = serve_arrivals(FakeServer(), spec, duration_s=6.0,
                           epoch_s=1.0, prompt_len=4, n_tokens=2,
                           seed=3, checkpoint=checkpoint)
    print(f"DONE {len(stats)}", flush=True)
