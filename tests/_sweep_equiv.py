"""Shared ≤1e-9 sweep-equivalence helpers.

One home for the record/report comparison contract (which fields
compare exactly, the ``knob_idx`` special case, the 1e-30 denominator
floor) so the batched-plane and jax-backend test files cannot silently
diverge. Importable thanks to the tests-dir ``sys.path`` entry in
``conftest.py``.
"""
RTOL = 1e-9


def rel(a: float, b: float) -> float:
    return abs(a - b) / max(1e-30, abs(a), abs(b))


def assert_records_match(ref: list, got: list, rtol: float = RTOL):
    """Flat sweep record tables: same fields, same ordering metadata,
    every numeric field within ``rtol`` relative."""
    assert len(ref) == len(got)
    for a, b in zip(ref, got):
        assert set(a) == set(b)
        for k, va in a.items():
            vb = b[k]
            if isinstance(va, (str, type(None))) or k == "knob_idx":
                assert va == vb, (k, va, vb)
            else:
                assert rel(va, vb) <= rtol, \
                    (a["workload"], a["npu"], a["policy"],
                     a["knob_idx"], k, va, vb)


def assert_reports_match(got, want, ctx, rtol: float = RTOL):
    """Two ``EnergyReport``s: totals and every per-component field
    within ``rtol`` relative."""
    from repro.core.power import COMPONENTS
    assert rel(got.runtime_s, want.runtime_s) <= rtol, ctx
    assert rel(got.total_j, want.total_j) <= rtol, ctx
    assert rel(got.setpm_count, want.setpm_count) <= rtol, ctx
    for c in COMPONENTS:
        for f in ("static_j", "dynamic_j", "wake_events", "gated_s",
                  "setpm_by"):
            assert rel(getattr(got, f)[c], getattr(want, f)[c]) \
                <= rtol, (ctx, f, c)
