"""Serve drain discipline (ISSUE 9 satellite): SIGTERM/SIGINT land
mid-epoch and ``serve_arrivals`` must drain the in-flight wave, record
the partial epoch (``"drained": True``), emit the final report, write
the checkpoint, restore the previous handlers, and exit 0 — instead
of dying mid-epoch. Signal delivery is tested against a real child
process; the clean path and handler restoration in-process.
"""
import json
import os
import signal
import subprocess
import sys
import time

import pytest

CHILD = os.path.join(os.path.dirname(__file__),
                     "_serve_signal_child.py")


def _run_child(checkpoint, sig):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(CHILD), "..", "src"),
         os.path.dirname(CHILD)])
    proc = subprocess.Popen(
        [sys.executable, CHILD, str(checkpoint)], env=env,
        stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True)
    try:
        assert proc.stdout.readline().strip() == "READY"
        time.sleep(0.6)   # land inside an epoch's wave loop
        proc.send_signal(sig)
        out, err = proc.communicate(timeout=120)
    except BaseException:
        proc.kill()
        raise
    return proc.returncode, "READY\n" + out, err


@pytest.mark.parametrize("sig", [signal.SIGTERM, signal.SIGINT],
                         ids=["SIGTERM", "SIGINT"])
def test_signal_drains_epoch_and_reports(sig, tmp_path):
    ck = tmp_path / "serve.json"
    code, out, err = _run_child(ck, sig)
    assert code == 0, err                       # drained, not killed
    assert "DONE" in out, (out, err)            # final report emitted

    rep = json.loads(ck.read_text())
    assert rep["interrupted"] == signal.Signals(sig).name
    epochs = rep["epochs"]
    assert 1 <= len(epochs) < 6                 # ended early...
    assert epochs[-1]["drained"] is True        # ...but drained
    assert all(e["served"] % 4 == 0 for e in epochs)  # whole waves
    assert rep["served_total"] == sum(e["served"] for e in epochs)
    assert int(out.split("DONE")[1]) == len(epochs)


def test_clean_run_in_process(tmp_path):
    from repro.core.fleet import ArrivalSpec
    from repro.launch.serve import serve_arrivals
    from _serve_signal_child import FakeServer

    prev_term = signal.getsignal(signal.SIGTERM)
    prev_int = signal.getsignal(signal.SIGINT)
    ck = tmp_path / "serve.json"
    stats = serve_arrivals(FakeServer(wave_s=0.0),
                           ArrivalSpec("poisson", rate_rps=40.0),
                           duration_s=3.0, epoch_s=1.0, prompt_len=4,
                           n_tokens=2, seed=3, checkpoint=str(ck))
    # full window served, nothing flagged, handlers restored
    assert len(stats) == 3
    assert not any(s.get("drained") for s in stats)
    rep = json.loads(ck.read_text())
    assert rep["interrupted"] is None
    assert rep["epochs"] == stats
    assert signal.getsignal(signal.SIGTERM) is prev_term
    assert signal.getsignal(signal.SIGINT) is prev_int
