"""Per-kernel shape/dtype sweeps, asserted allclose against ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(7)


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


# ---------------------------------------------------------------- gated mm
GM_CASES = [
    # (M, K, N, zero_cols, zero_rows)
    (128, 128, 128, 0, 0),
    (256, 256, 512, 256, 0),     # N-underutilization (paper Fig 10 case 2)
    (384, 512, 256, 0, 256),     # K-underutilization (case 3)
    (128, 256, 384, 128, 128),   # both
    (512, 128, 128, 0, 0),
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("case", GM_CASES)
def test_gated_matmul(case, dtype):
    M, K, N, zn, zk = case
    k1, k2 = jax.random.split(jax.random.fold_in(KEY, hash(case) % 2**30))
    x = _rand(k1, (M, K), dtype)
    w = _rand(k2, (K, N), dtype)
    if zn:
        w = w.at[:, N - zn:].set(0.0)
    if zk:
        w = w.at[K - zk:, :].set(0.0)
    out = ops.gated_matmul(x, w, interpret=True)
    want = ref.ref_matmul(x, w)
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        atol=tol * np.abs(np.asarray(want)).max() + 1e-5, rtol=tol)


def test_gated_matmul_skips_zero_tiles():
    """The bitmap marks exactly the zero tiles (the energy/latency win)."""
    w = jnp.ones((256, 512)).at[:, 256:].set(0.0).at[128:, :].set(0.0)
    bm = ops.tile_nonzero_bitmap(w, 128, 128)
    assert bm.tolist() == [[1, 1, 0, 0], [0, 0, 0, 0]]


# ------------------------------------------------------------------- flash
FA_CASES = [
    (1, 256, 2, 64, True),
    (2, 256, 4, 128, True),
    (1, 512, 2, 64, False),
    (2, 128, 1, 128, True),
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("case", FA_CASES)
def test_flash_attention_kernel(case, dtype):
    B, S, H, D, causal = case
    ks = jax.random.split(jax.random.fold_in(KEY, hash(case) % 2**30), 3)
    q = _rand(ks[0], (B, S, H, D), dtype)
    k = _rand(ks[1], (B, S, H, D), dtype)
    v = _rand(ks[2], (B, S, H, D), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, interpret=True)
    want = ref.ref_attention(q, k, v, causal=causal)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol * 3, rtol=tol)


# --------------------------------------------------------------------- ssd
SSD_CASES = [
    (2, 256, 64, 32, 128),
    (4, 256, 32, 16, 64),
    (1, 512, 64, 64, 128),
]


@pytest.mark.parametrize("case", SSD_CASES)
def test_ssd_scan_kernel(case):
    BH, S, P, N, chunk = case
    ks = jax.random.split(jax.random.fold_in(KEY, hash(case) % 2**30), 5)
    x = _rand(ks[0], (BH, S, P), jnp.float32)
    dt = jax.nn.softplus(_rand(ks[1], (BH, S), jnp.float32))
    A = -jnp.exp(jax.random.uniform(ks[2], (BH,), minval=0.0, maxval=1.5))
    B = _rand(ks[3], (BH, S, N), jnp.float32)
    C = _rand(ks[4], (BH, S, N), jnp.float32)
    y, h = ops.ssd_scan(x, dt, A, B, C, chunk=chunk, interpret=True)
    yr, hr = ref.ref_ssd(x, dt, A, B, C)
    scale = float(jnp.abs(yr).max()) + 1e-6
    np.testing.assert_allclose(np.asarray(y) / scale,
                               np.asarray(yr) / scale, atol=1e-4)
    hscale = float(jnp.abs(hr).max()) + 1e-6
    np.testing.assert_allclose(np.asarray(h) / hscale,
                               np.asarray(hr) / hscale, atol=1e-4)


def test_ssd_kernel_matches_model_path():
    """The Pallas kernel and the model's _ssd_chunk_scan agree."""
    from repro.models.blocks import _ssd_chunk_scan
    ks = jax.random.split(KEY, 5)
    Bz, S, nh, hd, N = 2, 256, 3, 32, 16
    x = _rand(ks[0], (Bz, S, nh, hd), jnp.float32)
    dt = jax.nn.softplus(_rand(ks[1], (Bz, S, nh), jnp.float32))
    A = -jnp.exp(jax.random.uniform(ks[2], (nh,), minval=0.0, maxval=1.5))
    Bm = _rand(ks[3], (Bz, S, nh, N), jnp.float32)
    Cm = _rand(ks[4], (Bz, S, nh, N), jnp.float32)
    y_model, h_model = _ssd_chunk_scan(x, dt, A, Bm, Cm)

    fold = lambda t: t.transpose(0, 2, 1, 3).reshape(Bz * nh, S, -1)
    xk = fold(x)
    dtk = dt.transpose(0, 2, 1).reshape(Bz * nh, S)
    Ak = jnp.tile(A, (Bz,))
    Bk, Ck = fold(Bm), fold(Cm)
    yk, hk = ops.ssd_scan(xk, dtk, Ak, Bk, Ck, chunk=128, interpret=True)
    yk = yk.reshape(Bz, nh, S, hd).transpose(0, 2, 1, 3)
    scale = float(jnp.abs(y_model).max()) + 1e-6
    np.testing.assert_allclose(np.asarray(yk) / scale,
                               np.asarray(y_model) / scale, atol=2e-4)
    hk = hk.reshape(Bz, nh, hd, N)
    hscale = float(jnp.abs(h_model).max()) + 1e-6
    np.testing.assert_allclose(np.asarray(hk) / hscale,
                               np.asarray(h_model) / hscale, atol=2e-4)


# ----------------------------------------------------------- decode attn
DA_CASES = [(4, 1024, 64, 256, 300), (2, 2048, 128, 512, 2047),
            (3, 512, 32, 128, 0)]


@pytest.mark.parametrize("case", DA_CASES)
def test_decode_attention_kernel(case):
    from repro.kernels.decode_attention import decode_attention_p
    BH, S, D, bk, clen = case
    ks = jax.random.split(jax.random.fold_in(KEY, hash(case) % 2**30), 3)
    q = _rand(ks[0], (BH, D), jnp.float32)
    kc = _rand(ks[1], (BH, S, D), jnp.float32)
    vc = _rand(ks[2], (BH, S, D), jnp.float32)
    out = decode_attention_p(q, kc, vc, jnp.int32(clen), bk=bk,
                             interpret=True)
    s = jnp.einsum("bd,bkd->bk", q * D ** -0.5, kc)
    s = jnp.where(jnp.arange(S)[None, :] <= clen, s, -1e30)
    ref = jnp.einsum("bk,bkd->bd", jax.nn.softmax(s, -1), vc)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


# ------------------------------------------------------------ sa occupancy
SA_OCC_CASES = [
    # (n_ops, saw, block) — non-multiple n exercises the pad path
    (777, 128.0, 512),
    (64, 256.0, 64),
    (1, 8.0, 512),
    (513, 1.0, 256),
]


@pytest.mark.parametrize("case", SA_OCC_CASES)
def test_sa_occupancy_kernel_matches_oracle(case):
    """Pallas closed-form occupancy kernel vs the jnp oracle, exact
    (both evaluate the same integer-valued float64 math)."""
    from repro.core.sa_gating import gating_stats_batch
    from repro.kernels.sa_occupancy import sa_occupancy_p

    n, saw, block = case
    rng = np.random.default_rng(int(n + saw))
    m = jnp.asarray(rng.integers(1, 5000, n).astype(np.float64))
    k = jnp.asarray(rng.integers(1, 600, n).astype(np.float64))
    nn = jnp.asarray(rng.integers(1, 5000, n).astype(np.float64))
    with jax.experimental.enable_x64():
        got = sa_occupancy_p(m, k, nn, saw, block=block, interpret=True)
        want = ref.ref_sa_occupancy(m, k, nn, saw)
        for key in got:
            np.testing.assert_allclose(np.asarray(got[key]),
                                       np.asarray(want[key]),
                                       rtol=1e-12, atol=0)
        # and against the int64 host batch (the production oracle)
        b = gating_stats_batch(np.asarray(m, np.int64),
                               np.asarray(k, np.int64),
                               np.asarray(nn, np.int64), int(saw))
        np.testing.assert_allclose(np.asarray(got["frac_on"]),
                                   b.frac_on, rtol=1e-12, atol=0)
        np.testing.assert_allclose(np.asarray(got["frac_off"]),
                                   b.frac_off, rtol=1e-12, atol=0)


def test_sa_occupancy_kernel_vmapped_traced_saw():
    """vmap over the SA width — exactly how the sweep kernel drives the
    pair axis — plus the weight-load-cycle override and empty streams."""
    from repro.core.sa_gating import gating_stats_batch
    from repro.kernels.sa_occupancy import sa_occupancy_p

    rng = np.random.default_rng(5)
    m = jnp.asarray(rng.integers(1, 2000, 200).astype(np.float64))
    k = jnp.asarray(rng.integers(1, 400, 200).astype(np.float64))
    nn = jnp.asarray(rng.integers(1, 2000, 200).astype(np.float64))
    with jax.experimental.enable_x64():
        saws = jnp.asarray([8.0, 128.0, 256.0])
        vm = jax.vmap(lambda s: sa_occupancy_p(m, k, nn, s))(saws)
        for i, saw in enumerate((8, 128, 256)):
            b = gating_stats_batch(np.asarray(m, np.int64),
                                   np.asarray(k, np.int64),
                                   np.asarray(nn, np.int64), saw)
            np.testing.assert_allclose(np.asarray(vm["frac_on"][i]),
                                       b.frac_on, rtol=1e-12, atol=0)
        # wlc override
        got = sa_occupancy_p(m, k, nn, 128.0, weight_load_cycles=0.0)
        b0 = gating_stats_batch(np.asarray(m, np.int64),
                                np.asarray(k, np.int64),
                                np.asarray(nn, np.int64), 128,
                                weight_load_cycles=0)
        np.testing.assert_allclose(np.asarray(got["frac_w_on"]),
                                   b0.frac_w_on, rtol=1e-12, atol=0)
        # empty op stream short-circuits without a pallas_call
        e = sa_occupancy_p(jnp.zeros(0), jnp.zeros(0), jnp.zeros(0),
                           128.0)
        assert all(v.shape == (0,) for v in e.values())
