"""Backend-neutral SA occupancy math (ISSUE 5).

``gating_stats_batch_xp`` is the closed-form 4-category ragged-tile
math over a pluggable ``xp`` namespace — the traced heart of the
on-device sweep. These property tests pin it (and the uncached batch
reference) against the scalar closed form and the exact cycle-level
PE-grid simulation on every shape family the sweep can produce:
ragged-K, ragged-N, ragged-both tiles, M < SAW streams, degenerate
saw=1 arrays, and zero-op (empty) traces. Also: the ``gating_stats``
LRU is resizable and the reference entry points bypass it entirely.
"""
import math

import numpy as np

# Explicit property-test seeds, hoisted so the deterministic streams
# are visible at module scope and changed deliberately, never ad hoc.
SEED_SHAPES = 7      # randomized (M, K, N) shape sweep
SEED_TINY = 3        # tiny-dims fuzz vs the exact PE-grid simulation

from repro.core.sa_gating import (gating_cache_info, gating_stats,
                                  gating_stats_batch,
                                  gating_stats_batch_reference,
                                  gating_stats_batch_xp,
                                  gating_stats_reference,
                                  set_gating_cache_size,
                                  simulate_pe_grid)

RTOL = 1e-12


def _assert_xp_matches_scalar(Ms, Ks, Ns, saw, wlc=None):
    got = gating_stats_batch_xp(Ms, Ks, Ns, saw, wlc, xp=np)
    ref = gating_stats_batch_reference(Ms, Ks, Ns, saw, wlc)
    np.testing.assert_array_equal(got["duration_cycles"],
                                  ref.duration_cycles)
    np.testing.assert_array_equal(got["wake_events"], ref.wake_events)
    for f in ("frac_on", "frac_w_on", "frac_off"):
        np.testing.assert_array_equal(got[f], getattr(ref, f), f)


def test_xp_matches_scalar_ragged_tile_families():
    """Ragged-K / ragged-N / ragged-both / M<SAW, per family."""
    saw = 128
    cases = {
        "full": (4096, 256, 256),
        "ragged_k": (4096, 100, 256),
        "ragged_n": (4096, 256, 100),
        "ragged_both": (4096, 100, 100),
        "m_under": (8, 256, 256),
        "m_under_ragged": (3, 77, 33),
        "single_pe": (1, 1, 1),
    }
    Ms, Ks, Ns = (np.array([c[i] for c in cases.values()])
                  for i in range(3))
    _assert_xp_matches_scalar(Ms, Ks, Ns, saw)
    _assert_xp_matches_scalar(Ms, Ks, Ns, saw, wlc=0)


def test_xp_matches_scalar_randomized_all_widths():
    rng = np.random.default_rng(SEED_SHAPES)
    Ms = np.concatenate([rng.integers(1, 5000, 300), [1, 131072]])
    Ks = np.concatenate([rng.integers(1, 3000, 300), [1, 16384]])
    Ns = np.concatenate([rng.integers(1, 3000, 300), [1, 8016]])
    for saw in (1, 4, 8, 128, 256):
        _assert_xp_matches_scalar(Ms, Ks, Ns, saw)
        # int64 vectorized batch agrees bitwise too
        b = gating_stats_batch(Ms, Ks, Ns, saw)
        x = gating_stats_batch_xp(Ms, Ks, Ns, saw)
        for f in ("frac_on", "frac_w_on", "frac_off", "duration_cycles"):
            np.testing.assert_array_equal(x[f], getattr(b, f), (saw, f))


def test_xp_saw_one_degenerate_width():
    """saw=1: every live 'tile' is a single PE; closed form must stay
    finite and exact."""
    Ms = np.array([1, 2, 17, 1000])
    Ks = np.array([1, 3, 5, 7])
    Ns = np.array([1, 2, 9, 11])
    got = gating_stats_batch_xp(Ms, Ks, Ns, 1, xp=np)
    _assert_xp_matches_scalar(Ms, Ks, Ns, 1)
    # a 1-wide SA has no dead rows/columns: everything is live
    np.testing.assert_allclose(got["frac_on"] + got["frac_w_on"],
                               np.ones(4), rtol=RTOL)


def test_xp_zero_op_trace_empty_arrays():
    """Zero-op traces reach the kernel as empty columns."""
    z = np.zeros(0)
    got = gating_stats_batch_xp(z, z, z, 128, xp=np)
    for f in ("frac_on", "frac_w_on", "frac_off", "duration_cycles",
              "wake_events"):
        assert got[f].shape == (0,)


def test_xp_traced_saw_array_broadcast():
    """saw may itself be an array (the vmapped pair axis feeds a 0-d
    traced scalar; numpy exercises the same broadcast contract)."""
    Ms = np.array([64, 512]); Ks = np.array([30, 200])
    Ns = np.array([40, 100])
    for saw in (np.float64(128.0), np.array(32.0)):
        got = gating_stats_batch_xp(Ms, Ks, Ns, saw, xp=np)
        _assert_xp_matches_scalar(Ms, Ks, Ns, int(saw))
        assert got["frac_on"].shape == (2,)


def test_xp_matches_cycle_simulation_single_tile():
    """Against the exact PE_on propagation sim (one weight tile,
    weight_load_cycles=0), including M<SAW and ragged-both."""
    rng = np.random.default_rng(SEED_TINY)
    for _ in range(30):
        saw = int(rng.choice([2, 4, 8, 12]))
        M = int(rng.integers(1, 3 * saw))
        K = int(rng.integers(1, saw + 1))
        N = int(rng.integers(1, saw + 1))
        sim = simulate_pe_grid(M, K, N, saw)
        got = gating_stats_batch_xp([M], [K], [N], saw, 0, xp=np)
        tot = sim["total"]
        assert math.isclose(got["frac_on"][0], sim["on"] / tot,
                            rel_tol=1e-9, abs_tol=1e-15)
        assert math.isclose(got["frac_w_on"][0], sim["w_on"] / tot,
                            rel_tol=1e-9, abs_tol=1e-15)
        assert math.isclose(got["frac_off"][0], sim["off"] / tot,
                            rel_tol=1e-9, abs_tol=1e-15)


def test_gating_cache_resizable_and_reference_uncached():
    prev = set_gating_cache_size(4)
    try:
        assert gating_cache_info().maxsize == 4
        for m in range(1, 9):
            gating_stats(m, 64, 64, 128)
        assert gating_cache_info().currsize <= 4
        # the reference entry points never touch the cache
        before = gating_cache_info()
        st = gating_stats_reference(12345, 67, 89, 128)
        ref = gating_stats_batch_reference([12345], [67], [89], 128)
        after = gating_cache_info()
        assert (before.hits, before.misses) == (after.hits, after.misses)
        assert ref.frac_on[0] == st.frac_on
        # cached and uncached agree, of course
        assert gating_stats(12345, 67, 89, 128) == st
    finally:
        set_gating_cache_size(prev)
    assert gating_cache_info().maxsize == prev
