"""Attention-path equivalences: flash==plain, decode==forward, hypothesis
sweeps over masks/windows/prefix."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # degrade: property tests skip, rest still run
    from _hypothesis_fallback import given, settings, st

from repro.configs import get_arch
from repro.configs.base import ShapeConfig
from repro.data.specs import make_batch
from repro.models import model as M
from repro.models import registry
from repro.models.common import (decode_attention, flash_attention_jax,
                                 plain_attention)
from repro.models.param import init_params

KEY = jax.random.PRNGKey(3)


@settings(max_examples=12, deadline=None)
@given(st.integers(1, 2), st.sampled_from([64, 96, 128]),
       st.sampled_from([(4, 2), (4, 4), (2, 1)]), st.booleans(),
       st.sampled_from([None, 16, 48]), st.sampled_from([0, 8]))
def test_flash_equals_plain(B, S, heads, causal, window, prefix):
    H, Hkv = heads
    ks = jax.random.split(jax.random.fold_in(KEY, B * S + H), 3)
    q = jax.random.normal(ks[0], (B, S, H, 16), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Hkv, 16), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hkv, 16), jnp.float32)
    ref = plain_attention(q, k, v, causal=causal, window=window,
                          prefix_len=prefix)
    out = flash_attention_jax(q, k, v, causal=causal, window=window,
                              prefix_len=prefix, q_chunk=32, kv_chunk=48)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)


def test_flash_traced_window():
    """Traced (per-layer) window values match static ones (hymba mixing)."""
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 64, 2, 8), jnp.float32)
    k = jax.random.normal(ks[1], (1, 64, 2, 8), jnp.float32)
    v = jax.random.normal(ks[2], (1, 64, 2, 8), jnp.float32)
    a = flash_attention_jax(q, k, v, causal=True, window=16,
                            q_chunk=32, kv_chunk=32)
    b = jax.jit(lambda w: flash_attention_jax(
        q, k, v, causal=True, window=w, q_chunk=32, kv_chunk=32))(
            jnp.int32(16))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


@pytest.mark.parametrize("arch", ["qwen2.5-3b", "mamba2-780m", "hymba-1.5b",
                                  "deepseek-v2-236b", "granite-moe-1b-a400m",
                                  "paligemma-3b"])
def test_decode_matches_forward(arch):
    """prefill(S) + decode(1 token) logits == forward(S+1) last logits.

    The strongest end-to-end consistency check: exercises KV caches, SSM
    states, MLA absorption, prefix-LM, and the scan plumbing together.
    """
    cfg = get_arch(arch).reduced()
    params = init_params(registry.param_specs(cfg), KEY)
    S = 24
    prefix = cfg.frontend_seq if cfg.frontend == "vision" else 0
    # for VLM, seq_len covers image patches + text; we want S+1 TEXT tokens
    shape = ShapeConfig("t", S + 1 + prefix, 2, "prefill")
    batch = make_batch(cfg, shape, seed=9)
    toks = batch["tokens"]
    assert toks.shape[1] == S + 1

    # full forward over S+1 tokens
    fb = dict(batch)
    logits_full, _ = M.forward(params, fb, cfg, dtype=jnp.float32)
    want = logits_full[:, -1]

    # prefill on S tokens, then decode token S
    pb = dict(batch)
    pb["tokens"] = toks[:, :S]
    _, cache = M.prefill_step(params, pb, cfg, dtype=jnp.float32)
    smax = S + 4 + (cfg.frontend_seq if cfg.frontend == "vision" else 0)
    full_cache = M.init_cache(cfg, 2, smax, dtype=jnp.float32)

    def graft(dst, src):
        if dst.shape == src.shape:
            return src.astype(dst.dtype)
        return jax.lax.dynamic_update_slice_in_dim(
            dst, src.astype(dst.dtype), 0, axis=2)

    full_cache = jax.tree.map(graft, full_cache, cache)
    db = {"tokens": toks[:, S:S + 1],
          "cache_len": jnp.asarray(S + prefix, jnp.int32)}
    got, _ = M.decode_step(params, full_cache, db, cfg, dtype=jnp.float32)
    got = got[:, 0]

    w = np.asarray(want, np.float32)
    g = np.asarray(got, np.float32)
    # compare post-softmax distributions (logit shift-invariance)
    pw = jax.nn.softmax(w, axis=-1)
    pg = jax.nn.softmax(g, axis=-1)
    np.testing.assert_allclose(np.asarray(pg), np.asarray(pw), atol=2e-3)


@settings(max_examples=10, deadline=None)
@given(st.sampled_from([96, 128, 256]), st.sampled_from([(4, 2), (2, 2)]),
       st.sampled_from([None, 32, 64]))
def test_triangle_equals_plain(S, heads, window):
    from repro.models.common import flash_attention_triangle
    H, Hkv = heads
    ks = jax.random.split(jax.random.fold_in(KEY, S + H), 3)
    q = jax.random.normal(ks[0], (1, S, H, 16), jnp.float32)
    k = jax.random.normal(ks[1], (1, S, Hkv, 16), jnp.float32)
    v = jax.random.normal(ks[2], (1, S, Hkv, 16), jnp.float32)
    ref = plain_attention(q, k, v, causal=True, window=window)
    out = flash_attention_triangle(q, k, v, causal=True, window=window,
                                   q_chunk=32, kv_chunk=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)


def test_segmented_hymba_matches_scanned():
    """STATIC_WINDOW_SEGMENTS forward == uniform-scan forward."""
    cfg = get_arch("hymba-1.5b").reduced()
    params = init_params(registry.param_specs(cfg), KEY)
    batch = make_batch(cfg, ShapeConfig("t", 32, 2, "train"), seed=5)
    l0, _ = M.forward(params, batch, cfg, dtype=jnp.float32)
    M.STATIC_WINDOW_SEGMENTS["enabled"] = True
    try:
        l1, _ = M.forward(params, batch, cfg, dtype=jnp.float32)
    finally:
        M.STATIC_WINDOW_SEGMENTS["enabled"] = False
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l0), atol=1e-4)
