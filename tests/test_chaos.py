"""Chaos plane (ISSUE 8): fault timelines, faulted collective
schedules, degraded-mode failover, and the anti-thrash governor.

The acceptance invariants asserted here:

* a uniform (all-ones) link-event trace reproduces the clean
  ``collective_schedule`` fractions to <=1e-9;
* the empty fault timeline is an exact no-op for ``sweep_fleet``
  (bit-identical records/summaries);
* fault timelines and ``sweep_chaos`` campaigns are seed-deterministic
  with independent per-(chip, link) streams;
* under a flapping-link scenario the hysteresis governor's retune
  count is bounded by the number of distinct fault transitions while
  the stateless baseline measurably thrashes;
* energy conservation (epoch total_j = fsum of records + unallocated)
  holds in every faulted epoch.
"""
import math

import numpy as np
import pytest

from repro.core.faults import (ChipFaultSpec, FaultSpec, FaultTimeline,
                               LinkFaultSpec, build_fault_timeline,
                               fault_plan)
from repro.core.fleet import (ArrivalSpec, FleetScenario, WorkloadClass,
                              sweep_chaos, sweep_fleet)
from repro.core.ici_topology import (Topology, collective_schedule,
                                     lower_collectives, n_links,
                                     resolve_link_rates, topology_for)
from repro.core.opgen import llm_workload
from repro.core.policies import PolicyKnobs
from repro.core.slo import Hysteresis

RTOL = 1e-9

DECODE = llm_workload("llama2-13b", "decode", batch=8, n_chips=8, tp=8)
PREFILL = llm_workload("llama2-13b", "prefill", batch=4, n_chips=8,
                       tp=8)

TOPOS = (Topology("ring", (8,)), Topology("mesh2d", (4, 8)),
         Topology("mesh2d", (1, 6)))
KINDS = ("all_reduce", "all_gather", "all_to_all")


def _scenario(**kw):
    classes = (
        WorkloadClass("decode", DECODE,
                      ArrivalSpec("diurnal", rate_rps=40.0,
                                  period_s=4 * 3600.0),
                      requests_per_invocation=8),
        WorkloadClass("prefill", PREFILL,
                      ArrivalSpec("poisson", rate_rps=6.0),
                      requests_per_invocation=4),
    )
    base = dict(classes=classes, n_chips=64, duration_s=4 * 3600.0,
                epoch_s=900.0, seed=3,
                policies=("NoPG", "ReGate-Full"))
    base.update(kw)
    return FleetScenario(**base)


KNOBS = (PolicyKnobs(), PolicyKnobs(window_scale=2.0),
         PolicyKnobs(window_scale=0.5))


# --------------------------------------------------------------------------
# faulted collective schedules
# --------------------------------------------------------------------------

@pytest.mark.parametrize("topo", TOPOS, ids=lambda t: f"{t.kind}{t.shape}")
@pytest.mark.parametrize("kind", KINDS)
def test_uniform_trace_reproduces_clean_schedule(topo, kind):
    clean = collective_schedule(kind, topo)
    uni = collective_schedule(kind, topo, np.ones(n_links(topo)))
    assert uni.shape == clean.shape
    if clean.size:
        assert float(np.max(np.abs(uni - clean))) <= RTOL
        assert abs(clean.sum() - 1.0) <= RTOL


def test_degraded_link_inflates_schedule():
    topo = Topology("ring", (8,))
    rates = np.ones(8)
    rates[3] = 0.5
    f = collective_schedule("all_reduce", topo, rates)
    clean = collective_schedule("all_reduce", topo)
    # every step crosses the slow link, paced at 1/0.5
    assert np.all(f > clean)
    assert abs(f.sum() - 2.0) < 1e-9


def test_down_link_detours_the_long_way():
    topo = Topology("ring", (8,))
    rates = np.ones(8)
    rates[2] = 0.0
    f = collective_schedule("all_gather", topo, rates)
    # store-and-forward over the 7 surviving links
    assert abs(f.sum() - 7.0) < 1e-9


def test_partitioned_ring_raises_and_resolve_fixes_it():
    topo = Topology("ring", (8,))
    rates = np.ones(8)
    rates[2] = rates[5] = 0.0
    with pytest.raises(ValueError, match="partition"):
        collective_schedule("all_reduce", topo, rates)
    fixed = resolve_link_rates(rates, topo)
    assert (fixed <= 0).sum() == 1          # one cut survives
    f = collective_schedule("all_reduce", topo, fixed)
    assert np.isfinite(f).all() and f.sum() > 1.0


def test_mesh_per_step_trace_shape_enforced():
    topo = Topology("mesh2d", (4, 8))
    s = collective_schedule("all_reduce", topo).size
    with pytest.raises(ValueError, match="shape"):
        collective_schedule("all_reduce", topo, np.ones(3))
    per_step = np.ones((s, n_links(topo)))
    f = collective_schedule("all_reduce", topo, per_step)
    assert abs(f.sum() - 1.0) <= RTOL


def test_lowered_faulted_variant_is_distinct_and_inflated():
    topo = topology_for(8)
    rates = np.ones(n_links(topo))
    rates[0] = 0.0
    clean = lower_collectives(DECODE, topo)
    faulted = lower_collectives(DECODE, topo, link_rates=rates)
    assert clean.name.endswith("+topo")
    assert faulted.name.endswith("+topo!")
    assert len(clean.ops) == len(faulted.ops)   # stable stack shapes
    wire = sum(o.bytes_ici * o.count for o in clean.ops)
    wire_f = sum(o.bytes_ici * o.count for o in faulted.ops)
    assert wire_f > wire                        # detour pacing


# --------------------------------------------------------------------------
# fault timelines: determinism + stream independence
# --------------------------------------------------------------------------

def test_timeline_seed_determinism():
    spec = fault_plan(1.5)
    a = build_fault_timeline(spec, n_epochs=48, n_chips=32, n_links=8,
                             seed=7)
    b = build_fault_timeline(spec, n_epochs=48, n_chips=32, n_links=8,
                             seed=7)
    assert (a.chips_down == b.chips_down).all()
    assert (a.link_rates == b.link_rates).all()
    assert (a.pg_fault == b.pg_fault).all()
    c = build_fault_timeline(spec, n_epochs=48, n_chips=32, n_links=8,
                             seed=8)
    assert (a.chips_down != c.chips_down).any() \
        or (a.link_rates != c.link_rates).any()


def test_per_link_streams_independent_of_fleet_shape():
    spec = fault_plan(2.0)
    small = build_fault_timeline(spec, n_epochs=48, n_chips=16,
                                 n_links=8, seed=7)
    wide = build_fault_timeline(spec, n_epochs=48, n_chips=16,
                                n_links=24, seed=7)
    # growing the link plane never shifts existing links' draws
    assert (wide.link_rates[:, :8] == small.link_rates).all()
    # ...nor the chip plane's
    assert (wide.chips_down == small.chips_down).all()


def test_chip_streams_independent_of_link_spec():
    base = fault_plan(1.5)
    harsher = FaultSpec(chip=base.chip,
                        link=LinkFaultSpec(flap_prob=0.9, down_prob=0.5))
    a = build_fault_timeline(base, n_epochs=48, n_chips=32, n_links=8,
                             seed=7)
    b = build_fault_timeline(harsher, n_epochs=48, n_chips=32,
                             n_links=8, seed=7)
    assert (a.chips_down == b.chips_down).all()
    assert (a.pg_fault == b.pg_fault).all()


def test_fault_plan_zero_is_clean():
    spec = fault_plan(0.0)
    tl = build_fault_timeline(spec, n_epochs=24, n_chips=16, n_links=8,
                              seed=0)
    assert not tl.any_fault().any()
    assert tl.n_transitions == 0
    assert tl.repair_epochs() == []
    empty = FaultTimeline.empty(24, 16, 8)
    assert (tl.chips_down == empty.chips_down).all()
    assert (tl.link_rates == empty.link_rates).all()


# --------------------------------------------------------------------------
# fleet integration
# --------------------------------------------------------------------------

def test_empty_timeline_is_exact_noop():
    sc = _scenario(severity_levels=(0.0, 0.6))
    clean = sweep_fleet(sc, KNOBS)
    empty = sweep_fleet(sc, KNOBS,
                        faults=FaultTimeline.empty(sc.n_epochs,
                                                   sc.n_chips, 16))
    assert clean.records == empty.records
    assert clean.epoch_summary == empty.epoch_summary
    assert clean.summary == empty.summary


def test_faulted_report_deterministic_and_conserves_energy():
    sc = _scenario()
    tl = build_fault_timeline(fault_plan(2.0), n_epochs=sc.n_epochs,
                              n_chips=sc.n_chips, n_links=16, seed=5)
    assert tl.any_fault().any()
    a = sweep_fleet(sc, KNOBS, faults=tl)
    b = sweep_fleet(sc, KNOBS, faults=tl)
    assert a.records == b.records and a.summary == b.summary
    # energy conservation in EVERY epoch, faulted ones included
    for s in a.epoch_summary:
        recs = [r["total_j"] for r in a.records
                if r["policy"] == s["policy"]
                and r["epoch"] == s["epoch"]]
        rhs = math.fsum(recs) + s["unallocated_idle_j"]
        assert abs(s["total_j"] - rhs) <= RTOL * max(1.0, abs(rhs))
    for pol in sc.policies:
        tot = a.policy_summary(pol)["total_j"]
        rhs = math.fsum(r["total_j"] for r in a.records
                        if r["policy"] == pol) \
            + math.fsum(s["unallocated_idle_j"]
                        for s in a.epoch_summary
                        if s["policy"] == pol)
        assert abs(tot - rhs) <= RTOL * max(1.0, abs(rhs))
    assert a.fault_summary is not None
    assert a.fault_summary["faulted_epochs"] == int(tl.any_fault().sum())


def test_failover_reallocation_over_survivors():
    sc = _scenario()
    n_e = sc.n_epochs
    tl = FaultTimeline(
        n_e, sc.n_chips, 0,
        chips_down=np.where(np.arange(n_e) % 2 == 1, 24, 0
                            ).astype(np.int64),
        link_rates=np.ones((n_e, 0)),
        pg_fault=np.zeros(n_e, np.bool_),
        severity_hint=np.zeros(n_e))
    rep = sweep_fleet(sc, KNOBS, faults=tl)
    for s in rep.epoch_summary:
        avail = sc.n_chips - s["chips_down"]
        assert s["chips_active"] + s["chips_unallocated"] == avail
    # no-starvation floor survives the dip: on faulted epochs every
    # positive-demand class still holds at least one chip
    for s in [s for s in rep.epoch_summary if s["chips_down"] > 0]:
        recs = [r for r in rep.records
                if r["epoch"] == s["epoch"]
                and r["policy"] == s["policy"]]
        for r in recs:
            if r["demand_inv"] > 0:
                assert r["chips"] >= 1


def test_pg_fault_falls_back_to_nopg_point():
    sc = _scenario()
    n_e = sc.n_epochs
    pg = np.zeros(n_e, np.bool_)
    pg[4:8] = True
    tl = FaultTimeline(n_e, sc.n_chips, 0,
                       chips_down=np.zeros(n_e, np.int64),
                       link_rates=np.ones((n_e, 0)),
                       pg_fault=pg,
                       severity_hint=np.zeros(n_e))
    rep = sweep_fleet(sc, KNOBS, faults=tl)
    by = {(r["epoch"], r["class"], r["policy"]): r for r in rep.records}
    for e in range(n_e):
        for cls in rep.class_names:
            rf, np_ = by[(e, cls, "ReGate-Full")], by[(e, cls, "NoPG")]
            if pg[e]:
                # the ladder's last rung: gated policy runs (and
                # idles) at the ungated NoPG operating point
                assert rf["pg_fallback"]
                assert rf["runtime_s"] == np_["runtime_s"]
                assert rf["inv_total_j"] == np_["inv_total_j"]
                assert rf["total_j"] == np_["total_j"]
            else:
                assert not rf["pg_fallback"]
                assert rf["inv_total_j"] < np_["inv_total_j"]
    assert rep.policy_summary("ReGate-Full")["pg_fallback_epochs"] == 4
    assert rep.policy_summary("NoPG")["pg_fallback_epochs"] == 0


def test_shed_ladder_bounds_backlog():
    # swamp a tiny fleet: demand far beyond capacity, shedding caps
    # the backlog at shed_backlog_x x per-epoch capacity
    classes = (WorkloadClass(
        "decode", DECODE, ArrivalSpec("poisson", rate_rps=500.0),
        requests_per_invocation=1),)
    kw = dict(classes=classes, n_chips=8, duration_s=4 * 900.0,
              epoch_s=900.0, seed=0, policies=("ReGate-Full",))
    queued = sweep_fleet(FleetScenario(**kw), KNOBS)
    shed = sweep_fleet(FleetScenario(**kw, shed_backlog_x=1.0), KNOBS)
    assert queued.policy_summary("ReGate-Full")["shed_inv_total"] == 0.0
    s = shed.policy_summary("ReGate-Full")
    assert s["shed_inv_total"] > 0.0
    final_q = queued.policy_summary("ReGate-Full")["backlog_inv_final"]
    assert s["backlog_inv_final"] < final_q
    for r in shed.records:
        cap = r["chips"] * 900.0 / (r["runtime_s"] * 8.0)
        assert r["backlog_inv"] <= 1.0 * cap + 1e-6


def test_severity_hint_escalates_ladder():
    sc = _scenario(severity_levels=(0.0, 0.8))
    n_e = sc.n_epochs
    hint = np.zeros(n_e)
    hint[::2] = 2.0
    tl = FaultTimeline(n_e, sc.n_chips, 0,
                       chips_down=np.zeros(n_e, np.int64),
                       link_rates=np.ones((n_e, 0)),
                       pg_fault=np.zeros(n_e, np.bool_),
                       severity_hint=hint)
    rep = sweep_fleet(sc, KNOBS, faults=tl)
    for e in range(0, n_e, 2):
        assert rep.severity_by_epoch[e] == 0.8


# --------------------------------------------------------------------------
# anti-thrash: the flapping-link scenario
# --------------------------------------------------------------------------

def _flapping_setup():
    """Single decode class on an 8-ring; link 0 flaps down in blocks of
    3 epochs (epochs 3-5, 9-11, 15-17, 21-23). slo_relax=1.03 sits
    between the clean knob spread (~1%) and the detour inflation
    (~5.5%), so during a flap NO knob is feasible and the stateless
    rule switches from the energy argmin to the least-violating knob
    every faulted epoch."""
    classes = (WorkloadClass(
        "decode", DECODE,
        ArrivalSpec("replay", times_s=tuple(
            float(e) * 60.0 for e in range(24) for _ in range(8))),
        requests_per_invocation=8),)
    sc = FleetScenario(classes, n_chips=8, duration_s=24 * 60.0,
                       epoch_s=60.0, seed=0, slo_relax=1.03,
                       policies=("NoPG", "ReGate-Full"))
    n_e = sc.n_epochs
    topo = topology_for(8)
    rates = np.ones((n_e, n_links(topo)))
    flap = np.zeros(n_e, bool)
    for e in range(n_e):
        if (e // 3) % 2 == 1:
            rates[e, 0] = 0.0
            flap[e] = True
    tl = FaultTimeline(n_e, sc.n_chips, n_links(topo),
                       chips_down=np.zeros(n_e, np.int64),
                       link_rates=rates,
                       pg_fault=np.zeros(n_e, np.bool_),
                       severity_hint=np.zeros(n_e))
    return sc, tl, int(flap.sum())


def test_antithrash_bound_vs_thrashing_baseline():
    sc, tl, n_flap_epochs = _flapping_setup()
    trans = tl.n_transitions
    assert n_flap_epochs > trans  # blocks longer than 1 epoch
    knobs = (PolicyKnobs(window_scale=0.25),
             PolicyKnobs(window_scale=2.0),
             PolicyKnobs(delay_scale=8.0), PolicyKnobs())
    gov = sweep_fleet(sc, knobs, faults=tl, hysteresis=Hysteresis())
    base = sweep_fleet(sc, knobs, faults=tl, hysteresis=None)
    g = gov.policy_summary("ReGate-Full")["retunes"]
    b = base.policy_summary("ReGate-Full")["retunes"]
    # the invariant: hysteresis retunes at most once per distinct
    # fault transition; the stateless baseline flips knobs every
    # faulted epoch — measurable thrash
    assert g <= trans, (g, trans)
    assert b >= n_flap_epochs, (b, n_flap_epochs)
    assert b > g
    # during flap epochs nothing is feasible (that is the scenario)
    flap_recs = [r for r in gov.records
                 if r["policy"] == "ReGate-Full"
                 and tl.link_faulty(r["epoch"])]
    assert flap_recs and all(not r["feasible_exists"]
                             for r in flap_recs)


def test_chaos_campaign_deterministic():
    sc = _scenario(n_chips=32, duration_s=8 * 900.0)
    a = sweep_chaos(sc, KNOBS, fault_severities=(0.0, 2.0))
    b = sweep_chaos(sc, KNOBS, fault_severities=(0.0, 2.0))
    assert a["summary"] == b["summary"]
    # severity 0 realizes the clean timeline: no transitions, no
    # faulted epochs, zero recovery backlog
    for row in a["summary"]:
        if row["fault_severity"] == 0.0:
            assert row["n_transitions"] == 0
            assert row["faulted_epochs"] == 0
            assert row["recovery_epochs"] == []
        assert row["retunes"] >= 0
        assert "baseline_retunes" in row
    # independent scenario streams: dropping one severity leaves the
    # other's fault draws (and hence its whole report) unchanged
    solo = sweep_chaos(sc, KNOBS, fault_severities=(2.0,),
                       thrash_baseline=False)
    paired = [r for r in a["summary"] if r["fault_severity"] == 2.0]
    solo_rows = solo["summary"]
    for pr, sr in zip(paired, solo_rows):
        for k in ("retunes", "n_transitions", "worst_regret_frac",
                  "total_j"):
            assert pr[k] == sr[k], (k, pr[k], sr[k])


def test_clamped_replay_surfaced_in_report():
    times = (0.0, 100.0, 1000.0, 1750.0, 1800.0)   # last three clamp
    classes = (WorkloadClass(
        "replayed", DECODE, ArrivalSpec("replay", times_s=times),
        requests_per_invocation=8),)
    sc = FleetScenario(classes, n_chips=8, duration_s=1800.0,
                       epoch_s=900.0, seed=0,
                       policies=("ReGate-Full",))
    rep = sweep_fleet(sc)
    assert rep.clamped_requests == 3
    assert rep.clamped_by_class == {"replayed": 3}
    assert rep.requests_total == 5
