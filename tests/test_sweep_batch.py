"""Batched sweep plane vs the loop oracle.

The batched ``sweep`` (one ``evaluate_batch`` over the stacked
super-trace) must reproduce ``sweep_reference`` record-for-record to
≤1e-9 relative on every numeric field with identical deterministic
ordering, across the paper suite × all 5 NPU generations × all policies
× a multi-point knob grid. A randomized ragged-stacking property test
checks that segment ids never leak idle-gap merging across workload
boundaries (the per-workload engine is the oracle).
"""
import math

import numpy as np
import pytest

from repro.core.hw import NPUS, get_npu
from repro.core.opgen import (Op, Workload, compile_trace, paper_suite,
                              segment_sum, segmented_gaps, stack_traces)
from repro.core.policies import (POLICIES, PolicyKnobs, evaluate,
                                 evaluate_all, evaluate_batch)
from repro.core.power import COMPONENTS
from repro.core.sweep import sweep, sweep_reference, with_savings

from _sweep_equiv import RTOL
from _sweep_equiv import rel as _rel
from _sweep_equiv import assert_records_match as _assert_records_match

# Explicit property-test seeds, hoisted so the deterministic streams
# are visible at module scope and changed deliberately, never ad hoc.
SEED_STACKING = 7    # ragged-stacking gap-leakage property test
SEED_EMPTY = 17      # zero-op segments regression
SEED_ORDER = 21      # stacking order independence
SEED_BACKEND = 29    # numpy-backend kernel oracle

KNOB_GRID = [
    PolicyKnobs(),
    PolicyKnobs(delay_scale=2.0),
    PolicyKnobs(delay_scale=0.5),
    PolicyKnobs(leak_off_logic=0.2, leak_sram_sleep=0.4,
                leak_sram_off=0.02),
]


def test_records_match_reference_full_grid():
    """Suite × all 5 NPUs × all policies × 4-point knob grid: every
    record field ≤1e-9 relative, identical ordering."""
    suite = paper_suite()
    npus = tuple(NPUS)
    ref = sweep_reference(suite, npus, POLICIES, KNOB_GRID)
    bat = sweep(suite, npus, POLICIES, KNOB_GRID)
    assert len(bat) == len(suite) * len(npus) * len(POLICIES) \
        * len(KNOB_GRID)
    key = ("workload", "npu", "policy", "knob_idx")
    assert [tuple(r[k] for k in key) for r in ref] \
        == [tuple(r[k] for k in key) for r in bat]
    _assert_records_match(ref, bat)


def test_deterministic_ordering():
    """Workload-major, then NPU, then policy, then knob index."""
    wls = paper_suite()[:2]
    grid = [PolicyKnobs(), PolicyKnobs(delay_scale=2.0)]
    recs = sweep(wls, npus=("NPU-A", "NPU-D"),
                 policies=("NoPG", "ReGate-Full"), knob_grid=grid)
    expect = [(w.name, n, p, k)
              for w in wls for n in ("NPU-A", "NPU-D")
              for p in ("NoPG", "ReGate-Full") for k in (0, 1)]
    assert [(r["workload"], r["npu"], r["policy"], r["knob_idx"])
            for r in recs] == expect
    assert recs[1]["delay_scale"] == 2.0


# --------------------------------------------------------------------------
# randomized ragged-stacking property test
# --------------------------------------------------------------------------

def _random_workload(rng: np.random.Generator, i: int) -> Workload:
    """Adversarial op stream: per-op random component mix, long idle runs
    (whole components inactive), pure-idle ops, leading/trailing gaps —
    the shapes where cross-workload gap leakage would show up."""
    n_ops = int(rng.integers(1, 40))
    ops = []
    for j in range(n_ops):
        kind = rng.random()
        flops_sa = float(rng.uniform(1e9, 5e12)) if kind < 0.45 else 0.0
        mm = None
        if flops_sa and rng.random() < 0.8:
            mm = (int(rng.integers(1, 4096)), int(rng.integers(1, 512)),
                  int(rng.integers(1, 4096)))
        flops_vu = float(rng.uniform(1e8, 5e11)) \
            if rng.random() < 0.5 else 0.0
        bytes_hbm = float(rng.uniform(1e6, 1e10)) \
            if rng.random() < 0.6 else 0.0
        bytes_ici = float(rng.uniform(1e6, 1e9)) \
            if rng.random() < 0.15 else 0.0
        ops.append(Op(f"op{j}", flops_sa=flops_sa, flops_vu=flops_vu,
                      bytes_hbm=bytes_hbm, bytes_ici=bytes_ici,
                      sram_demand=int(rng.integers(0, 256 << 20)),
                      matmul_dims=mm, count=int(rng.integers(1, 5)),
                      collective=bytes_ici > 0))
    return Workload(f"rand-{i}", "prefill", tuple(ops))


def test_ragged_stacking_no_gap_leakage():
    """evaluate_batch over a random ragged stack must equal per-workload
    evaluate: if gap merging leaked across segment boundaries, the
    hw/sw gated-idle energies would differ."""
    rng = np.random.default_rng(SEED_STACKING)
    wls = [_random_workload(rng, i) for i in range(12)]
    grid = [PolicyKnobs(), PolicyKnobs(delay_scale=3.0),
            PolicyKnobs(leak_off_logic=0.0, delay_scale=0.25)]
    npus = ("NPU-A", "NPU-E")
    res = evaluate_batch(wls, npus, POLICIES, grid)
    for wi, wl in enumerate(wls):
        for ai, npu in enumerate(npus):
            for pi, policy in enumerate(POLICIES):
                for ki, knobs in enumerate(grid):
                    want = evaluate(wl, npu, policy, knobs)
                    got = res.report(wi, ai, pi, ki)
                    ctx = (wl.name, npu, policy, ki)
                    assert _rel(got.runtime_s, want.runtime_s) <= RTOL, ctx
                    assert _rel(got.total_j, want.total_j) <= RTOL, ctx
                    assert _rel(got.setpm_count, want.setpm_count) \
                        <= RTOL, ctx
                    for c in COMPONENTS:
                        assert _rel(got.static_j[c],
                                    want.static_j[c]) <= RTOL, (ctx, c)
                        assert _rel(got.dynamic_j[c],
                                    want.dynamic_j[c]) <= RTOL, (ctx, c)
                        assert _rel(got.wake_events[c],
                                    want.wake_events[c]) <= RTOL, (ctx, c)
                        assert _rel(got.setpm_by[c],
                                    want.setpm_by[c]) <= RTOL, (ctx, c)


def test_empty_trace_in_ragged_stack():
    """Regression (ISSUE 4): zero-op workloads mixed into a randomized
    ragged stack — leading, trailing, and consecutive empty segments —
    must yield exactly-zero records without NaNs and without shifting
    any neighbour's segment alignment (per-workload ``evaluate`` is the
    oracle)."""
    rng = np.random.default_rng(SEED_EMPTY)
    empty = Workload("empty", "prefill", ())
    wls = [empty, _random_workload(rng, 1), empty,
           Workload("also-empty", "prefill", ()),
           _random_workload(rng, 4), _random_workload(rng, 5), empty]
    grid = [PolicyKnobs(), PolicyKnobs(delay_scale=3.0)]
    res = evaluate_batch(wls, ("NPU-A", "NPU-E"), POLICIES, grid)
    for wi, wl in enumerate(wls):
        for ai, npu in enumerate(("NPU-A", "NPU-E")):
            for pi, policy in enumerate(POLICIES):
                for ki, knobs in enumerate(grid):
                    want = evaluate(wl, npu, policy, knobs)
                    got = res.report(wi, ai, pi, ki)
                    ctx = (wl.name, npu, policy, ki)
                    assert _rel(got.runtime_s, want.runtime_s) <= RTOL, ctx
                    assert _rel(got.total_j, want.total_j) <= RTOL, ctx
                    if not wl.ops:
                        assert got.runtime_s == 0.0 and got.total_j == 0.0
                        assert got.setpm_count == 0.0
    for rec in res.records():
        for v in rec.values():
            if isinstance(v, float):
                assert math.isfinite(v)


def test_stack_traces_with_empty_and_no_workloads():
    """Stack bookkeeping around empty traces: offsets must carry the
    zero-length spans, and an all-empty or zero-workload stack must
    produce empty (not misaligned) columns."""
    empty = Workload("e", "prefill", ())
    wls = [empty, paper_suite()[0], empty]
    st = stack_traces(wls)
    n1 = compile_trace(paper_suite()[0]).n_ops
    assert st.offsets.tolist() == [0, 0, n1, n1]
    assert st.n_ops == n1
    assert (st.seg_ids == 1).all()
    st0 = stack_traces([])
    assert st0.n_segments == 0 and st0.n_ops == 0
    assert st0.offsets.tolist() == [0]
    res = evaluate_batch([], ("NPU-D",), POLICIES)
    assert res.shape == (0, 1, len(POLICIES), 1)
    assert res.records() == []


def test_segmented_gaps_empty_segments_alignment():
    """Empty segments must own zero gaps; idle runs butting against an
    empty segment stay in their own workload."""
    # seg0: 2 ops (idle, active); seg1: empty; seg2: 2 ops (idle, idle)
    active = np.array([False, True, False, False])
    idle = np.where(active, 0.0, 1.0)
    offsets = np.array([0, 2, 2, 4])
    gaps, gofs = segmented_gaps(active, idle, offsets)
    # seg0: the gap before op1 (1.0); seg1: no gaps at all; seg2: one
    # merged gap of 2.0 that must NOT bleed into seg0 or seg1
    assert gofs.tolist() == [0, 1, 1, 2]
    assert gaps.tolist() == [1.0, 2.0]


def test_stacking_order_independence():
    """A workload's cell must not depend on its neighbours in the stack
    (pure segment isolation)."""
    rng = np.random.default_rng(SEED_ORDER)
    wls = [_random_workload(rng, i) for i in range(6)]
    a = evaluate_batch(wls, ("NPU-D",), ("ReGate-Full",))
    b = evaluate_batch(list(reversed(wls)), ("NPU-D",), ("ReGate-Full",))
    for wi, wl in enumerate(wls):
        ra = a.report(wi, 0, 0, 0)
        rb = b.report(len(wls) - 1 - wi, 0, 0, 0)
        assert ra.workload == rb.workload == wl.name
        assert _rel(ra.total_j, rb.total_j) <= RTOL
        assert _rel(ra.runtime_s, rb.runtime_s) <= RTOL


# --------------------------------------------------------------------------
# stacking / segment utilities
# --------------------------------------------------------------------------

def test_stack_traces_segments_and_cache():
    wls = paper_suite()[:3]
    st = stack_traces(wls)
    assert st.n_segments == 3
    assert st.names == tuple(w.name for w in wls)
    lengths = [compile_trace(w).n_ops for w in wls]
    assert st.n_ops == sum(lengths)
    assert list(np.diff(st.offsets)) == lengths
    assert (st.seg_ids == np.repeat(np.arange(3), lengths)).all()
    # columns concatenate in segment order
    tr0 = compile_trace(wls[0])
    assert (st.flops_sa[:lengths[0]] == tr0.flops_sa).all()
    # identity cache: same workloads -> same stacked object
    assert stack_traces(wls) is st
    assert stack_traces(wls[:2]) is not st


def test_segment_sum_handles_empty_segments():
    arr = np.arange(6, dtype=np.float64).reshape(6, 1)
    offsets = np.array([0, 2, 2, 5, 6])
    out = segment_sum(arr, offsets)
    assert out.shape == (4, 1)
    assert out[:, 0].tolist() == [1.0, 0.0, 9.0, 5.0]
    assert segment_sum(np.zeros((0, 2)), np.array([0, 0, 0])).shape == (2, 2)


def test_segmented_gaps_respect_boundaries():
    # two segments; idle runs touching the boundary must NOT merge
    active = np.array([False, True, False, False, True, False])
    idle = np.where(active, 0.0, 1.0)
    offsets = np.array([0, 3, 6])
    gaps, gofs = segmented_gaps(active, idle, offsets)
    # seg0: gap before op1 (1.0) + trailing (1.0); seg1: gap before
    # op4 (1.0) + trailing (1.0)
    assert gofs.tolist() == [0, 2, 4]
    assert gaps.tolist() == [1.0, 1.0, 1.0, 1.0]
    # merged view (one segment) WOULD merge the middle run into 2.0
    merged, _ = segmented_gaps(active, idle, np.array([0, 6]))
    assert merged.tolist() == [1.0, 2.0, 1.0]


# --------------------------------------------------------------------------
# backend-neutral kernel: the numpy instantiation must also match
# --------------------------------------------------------------------------

def test_backend_neutral_kernel_numpy_instantiation():
    """The ISSUE-4 kernel is backend-neutral; instantiated with the
    numpy backend (loop vmap, bincount segment_sum — the path the jax
    program mirrors) it must reproduce the production numpy plane.
    This keeps NumpyBackend an exercised oracle, not dead code."""
    from repro.core.backend import get_backend
    from repro.core.policies import _evaluate_batch_backend
    rng = np.random.default_rng(SEED_BACKEND)
    wls = [_random_workload(rng, 0), Workload("empty", "prefill", ()),
           _random_workload(rng, 2)]
    grid = (PolicyKnobs(), PolicyKnobs(delay_scale=2.0),
            PolicyKnobs(leak_off_logic=0.2, leak_sram_sleep=0.4,
                        leak_sram_off=0.02))
    npus = (get_npu("NPU-B"), get_npu("NPU-E"))
    ref = evaluate_batch(wls, npus, POLICIES, grid)
    got = _evaluate_batch_backend(wls, npus, POLICIES, grid,
                                  get_backend("numpy"))
    _assert_records_match(ref.records(), got.records())

def test_evaluate_all_matches_evaluate():
    wl = paper_suite()[8]
    knobs = PolicyKnobs(delay_scale=2.0)
    reps = evaluate_all(wl, "NPU-C", knobs)
    assert set(reps) == set(POLICIES)
    for p, got in reps.items():
        want = evaluate(wl, "NPU-C", p, knobs)
        assert got.workload == want.workload and got.npu == want.npu
        assert _rel(got.total_j, want.total_j) <= RTOL, p
        assert _rel(got.runtime_s, want.runtime_s) <= RTOL, p
        assert _rel(got.setpm_count, want.setpm_count) <= RTOL, p
        for c in COMPONENTS:
            assert _rel(got.static_j[c], want.static_j[c]) <= RTOL, (p, c)
            assert _rel(got.dynamic_j[c], want.dynamic_j[c]) <= RTOL, (p, c)


def test_with_savings_missing_baseline_cell():
    recs = sweep(paper_suite()[0], policies=("ReGate-Full", "Ideal"))
    out = with_savings(recs)
    assert all(r["savings"] is None for r in out)


def test_with_savings_baseline_only_at_knob0():
    """Multi-knob grid where the baseline policy appears only at knob 0:
    the un-gated baseline is knob-insensitive, so its single row must
    serve as the fallback baseline for every knob cell."""
    wl = paper_suite()[0]
    grid = [PolicyKnobs(), PolicyKnobs(delay_scale=2.0),
            PolicyKnobs(delay_scale=4.0)]
    full = sweep(wl, policies=("NoPG", "ReGate-Full"), knob_grid=grid)
    # keep NoPG only at knob 0 (what a thrifty caller would evaluate)
    pruned = [r for r in full
              if r["policy"] != "NoPG" or r["knob_idx"] == 0]
    out = with_savings(pruned)
    base = next(r["total_j"] for r in pruned if r["policy"] == "NoPG")
    for r in out:
        if r["policy"] == "NoPG":
            assert r["savings"] == 0.0
        else:
            assert r["savings"] is not None
            assert math.isclose(r["savings"], 1.0 - r["total_j"] / base,
                                rel_tol=RTOL)
    # NoPG really is knob-insensitive (sanity for the fallback's premise)
    nopg = [r for r in full if r["policy"] == "NoPG"]
    assert all(math.isclose(r["total_j"], nopg[0]["total_j"],
                            rel_tol=RTOL) for r in nopg)


def test_with_savings_no_fallback_for_gating_baseline():
    """A gating baseline IS knob-sensitive, so a missing cell must stay
    None rather than borrow a knob-mismatched denominator."""
    wl = paper_suite()[0]
    grid = [PolicyKnobs(), PolicyKnobs(delay_scale=4.0)]
    full = sweep(wl, policies=("ReGate-Base", "ReGate-Full"),
                 knob_grid=grid)
    pruned = [r for r in full
              if r["policy"] != "ReGate-Base" or r["knob_idx"] == 0]
    out = with_savings(pruned, baseline="ReGate-Base")
    by = {(r["policy"], r["knob_idx"]): r for r in out}
    assert by[("ReGate-Full", 0)]["savings"] is not None
    assert by[("ReGate-Full", 1)]["savings"] is None


def test_with_savings_ambiguous_fallback_stays_none():
    """If the baseline appears at several knob points, a missing exact
    cell must NOT silently pick one of them."""
    wl = paper_suite()[0]
    grid = [PolicyKnobs(), PolicyKnobs(delay_scale=2.0),
            PolicyKnobs(delay_scale=4.0)]
    full = sweep(wl, policies=("NoPG", "ReGate-Full"), knob_grid=grid)
    pruned = [r for r in full
              if r["policy"] != "NoPG" or r["knob_idx"] in (0, 1)]
    out = with_savings(pruned)
    by = {(r["policy"], r["knob_idx"]): r for r in out}
    assert by[("ReGate-Full", 0)]["savings"] is not None
    assert by[("ReGate-Full", 1)]["savings"] is not None
    assert by[("ReGate-Full", 2)]["savings"] is None


def test_single_workload_and_spec_npus():
    """sweep accepts a bare Workload and NPUSpec objects (not names)."""
    wl = paper_suite()[0]
    recs = sweep(wl, npus=(get_npu("NPU-D"),), policies=("NoPG",))
    assert len(recs) == 1
    want = evaluate(wl, "NPU-D", "NoPG")
    assert _rel(recs[0]["total_j"], want.total_j) <= RTOL
    assert _rel(recs[0]["setpm_per_1k_cycles"],
                want.setpm_per_1k_cycles(get_npu("NPU-D"))) <= RTOL


def test_with_savings_fallback_is_sa_width_aware():
    """The single-knob NoPG fallback must NOT cross SA widths: unlike
    the gating knobs, ``sa_width`` moves NoPG's service times and
    energy, so a width-mismatched denominator would be silently wrong
    (ISSUE 5 regression). Matching-width cells keep the fallback;
    mismatched-width cells get savings None."""
    from repro.core.sweep import knob_product
    wl = paper_suite()[4]
    grid = knob_product(delay_scale=(1.0, 2.0), sa_width=(None, 256))
    full = sweep(wl, policies=("NoPG", "ReGate-HW"), knob_grid=grid)
    # NoPG really IS width-sensitive (the premise of this test)
    nopg = [r for r in full if r["policy"] == "NoPG"]
    assert not math.isclose(nopg[0]["total_j"], nopg[-1]["total_j"],
                            rel_tol=1e-6)
    # keep NoPG only at knob 0 (sa_width=None)
    pruned = [r for r in full
              if r["policy"] != "NoPG" or r["knob_idx"] == 0]
    out = with_savings(pruned)
    base = nopg[0]["total_j"]
    for r in out:
        if r["policy"] == "NoPG":
            assert r["savings"] == 0.0
        elif r["sa_width"] is None:  # width matches the baseline row
            assert math.isclose(r["savings"], 1.0 - r["total_j"] / base,
                                rel_tol=RTOL)
        else:  # width-mismatched: no silently-wrong number
            assert r["savings"] is None
