"""KnobGrid / SweepSession API redesign (ISSUE 7).

The six sprawled knob-axis kwargs became one frozen ``KnobGrid`` value
and the four module-level substrate switches became the nestable
``SweepSession`` layer stack. These tests pin the compatibility
contract: the legacy spellings are thin shims over the new objects with
*identical* knob ordering and record tables (≤1e-9 relative,
``_sweep_equiv``), sessions scope and restore correctly, and the
record-table consumers (``with_savings`` / ``group_by``) never again
silently drop records that mix PR-5 (``sa_width``) and PR-6
(``window_scale``) axes — every sweep record carries every knob column
unconditionally, and a hand-built record missing one fails loudly.
"""
import pytest

from repro.core import session
from repro.core.backend import (default_backend, set_default_backend,
                                set_sa_occupancy_impl)
from repro.core.opgen import paper_suite
from repro.core.policies import KnobGrid, PolicyKnobs, as_knob_tuple
from repro.core.sa_gating import gating_cache_info
from repro.core.sweep import (SweepSession, group_by, knob_product,
                              sweep, sweep_grid, sweep_robustness,
                              with_savings)

from _sweep_equiv import assert_records_match as _assert_records_match

AXES = dict(delay_scale=(1.0, 2.0), leak_off_logic=(None, 0.2),
            leak_sram_sleep=(None,), leak_sram_off=(0.002,),
            sa_width=(None, 256), window_scale=(0.5, 1.0))


# --------------------------------------------------------------------------
# KnobGrid: the value object behind every knob-axis spelling
# --------------------------------------------------------------------------

def test_product_matches_knob_product():
    """The legacy kwargs shim and KnobGrid.product() are the same list,
    element for element — same knobs, same canonical ordering."""
    assert KnobGrid(**AXES).product() == knob_product(**AXES)
    assert KnobGrid().product() == [PolicyKnobs()]


def test_canonical_nesting_order():
    """sa_width outermost, then window_scale, then delay_scale, then
    the leak axes innermost — the ordering every sweep's knob_idx
    column has meant since ISSUE 5/6."""
    g = KnobGrid(sa_width=(None, 128), window_scale=(0.5, 1.0),
                 delay_scale=(1.0, 4.0), leak_off_logic=(None, 0.2))
    expect = [PolicyKnobs(sa_width=sw, window_scale=w, delay_scale=d,
                          leak_off_logic=lo)
              for sw in (None, 128) for w in (0.5, 1.0)
              for d in (1.0, 4.0) for lo in (None, 0.2)]
    assert g.product() == expect
    assert g.size == len(expect) == 16


def test_scalar_axes_coerce_to_singletons():
    g = KnobGrid(delay_scale=2.0, sa_width=128, window_scale=0.5,
                 leak_off_logic=0.1)
    assert g.delay_scale == (2.0,)
    assert g.sa_width == (128,)
    assert g.window_scale == (0.5,)
    assert g.leak_off_logic == (0.1,)
    assert g.size == 1


def test_columns_are_the_record_knob_columns():
    assert KnobGrid.columns() == ("delay_scale", "leak_off_logic",
                                  "leak_sram_sleep", "leak_sram_off",
                                  "sa_width", "window_scale")
    rec_keys = sweep(paper_suite()[:1], policies=("NoPG",))[0].keys()
    assert set(KnobGrid.columns()) | {"knob_idx"} <= set(rec_keys)


@pytest.mark.parametrize("bad", [
    dict(delay_scale=(0.0,)), dict(delay_scale=(float("nan"),)),
    dict(window_scale=(-1.0,)), dict(window_scale=()),
    dict(sa_width=(0,)), dict(sa_width=(1.5,)),
    dict(leak_off_logic=(-0.1,)),
    dict(leak_sram_off=(float("inf"),)),
])
def test_axis_validation(bad):
    with pytest.raises((ValueError, TypeError)):
        KnobGrid(**bad)


def test_as_knob_tuple_spellings():
    """None / flat sequence / KnobGrid all normalize to one tuple."""
    assert as_knob_tuple(None) == (PolicyKnobs(),)
    flat = [PolicyKnobs(), PolicyKnobs(delay_scale=2.0)]
    assert as_knob_tuple(flat) == tuple(flat)
    g = KnobGrid(**AXES)
    assert as_knob_tuple(g) == tuple(g.product())


# --------------------------------------------------------------------------
# sweep_grid: grid= vs the legacy axis kwargs
# --------------------------------------------------------------------------

def test_sweep_grid_grid_equals_kwargs():
    """grid=KnobGrid(...) and the six axis kwargs produce the same
    record table — same ordering metadata, every numeric ≤1e-9."""
    wls = paper_suite()[:2]
    pols = ("NoPG", "ReGate-Full")
    legacy = sweep_grid(wls, npus=("NPU-D",), policies=pols, **AXES)
    new = sweep_grid(wls, npus=("NPU-D",), policies=pols,
                     grid=KnobGrid(**AXES))
    key = ("workload", "npu", "policy", "knob_idx")
    assert [tuple(r[k] for k in key) for r in legacy] \
        == [tuple(r[k] for k in key) for r in new]
    _assert_records_match(legacy, new)


def test_sweep_grid_rejects_mixed_spellings():
    wls = paper_suite()[:1]
    with pytest.raises(ValueError, match="not both"):
        sweep_grid(wls, grid=KnobGrid(**AXES), delay_scale=(1.0, 2.0))
    with pytest.raises(TypeError, match="KnobGrid"):
        sweep_grid(wls, grid=[PolicyKnobs()])


# --------------------------------------------------------------------------
# record-table consumers: no silent drops, loud failures
# --------------------------------------------------------------------------

def test_mixed_axes_survive_savings_and_group_by():
    """The ISSUE 7 regression: records from a grid mixing the PR-5
    sa_width axis with the PR-6 window_scale axis used to be silently
    dropped by with_savings/group_by (missing columns). Every record
    must survive both, with a resolvable baseline."""
    wls = paper_suite()[:2]
    recs = sweep_grid(wls, policies=("NoPG", "ReGate-Full"),
                      grid=KnobGrid(sa_width=(None, 256),
                                    window_scale=(0.5, 1.0),
                                    delay_scale=(1.0, 2.0)))
    sv = with_savings(recs)
    assert len(sv) == len(recs) == len(wls) * 2 * 8
    assert all(r["savings"] is not None for r in sv)
    groups = group_by(sv, "sa_width", "window_scale")
    assert set(groups) == {(w, s) for w in (None, 256)
                           for s in (0.5, 1.0)}
    # nothing dropped: the groups partition the table
    assert sum(len(g) for g in groups.values()) == len(sv)


def test_missing_knob_column_fails_loudly():
    recs = sweep(paper_suite()[:1], policies=("NoPG", "ReGate-Full"))
    broken = [dict(r) for r in recs]
    del broken[1]["window_scale"]
    with pytest.raises(ValueError, match="window_scale"):
        with_savings(broken)
    with pytest.raises(KeyError, match="window_scale"):
        group_by(broken, "window_scale")


def test_robustness_records_carry_all_knob_columns():
    """Jitter-plane records feed the same consumers as any sweep's."""
    out = sweep_robustness(paper_suite()[:1], severities=(0.0, 1.0),
                           threshold_scales=(0.5, 1.0), seed=3)
    need = set(KnobGrid.columns()) | {"knob_idx"}
    assert all(need <= set(r) for r in out["records"])
    groups = group_by(out["records"], "window_scale")
    assert set(groups) == {(0.5,), (1.0,)}
    assert sum(len(g) for g in groups.values()) == len(out["records"])


# --------------------------------------------------------------------------
# SweepSession: scoping, nesting, legacy-setter delegation
# --------------------------------------------------------------------------

def test_session_scopes_and_nests():
    assert default_backend() == "numpy"
    with SweepSession(backend="jax") as outer:
        assert default_backend() == "jax"
        assert session.resolve("jax_mesh") is None
        with SweepSession(backend="numpy", sa_occupancy_impl="pallas"):
            assert default_backend() == "numpy"
            assert session.resolve("sa_occupancy_impl") == "pallas"
        assert default_backend() == "jax"
        assert session.resolve("sa_occupancy_impl") == "jnp"
        assert outer is not None
    assert default_backend() == "numpy"


def test_session_exception_safe():
    with pytest.raises(RuntimeError, match="boom"):
        with SweepSession(backend="jax"):
            raise RuntimeError("boom")
    assert default_backend() == "numpy"


def test_legacy_setters_write_the_root_layer():
    """set_default_backend under an active session mutates the root:
    the session keeps winning until it exits, then the new root default
    shows through — old call sites keep working, sessions stay
    strongest."""
    try:
        with SweepSession(backend="numpy"):
            prev = set_default_backend("jax")
            assert prev == "numpy"
            assert default_backend() == "numpy"  # session shadows root
        assert default_backend() == "jax"
    finally:
        set_default_backend("numpy")
    assert default_backend() == "numpy"


def test_sa_occupancy_setter_delegates():
    try:
        prev = set_sa_occupancy_impl("pallas")
        assert prev == "jnp"
        assert session.resolve("sa_occupancy_impl") == "pallas"
    finally:
        set_sa_occupancy_impl("jnp")


def test_gating_cache_size_scoped():
    before = gating_cache_info().maxsize
    with SweepSession(gating_cache_size=128):
        assert gating_cache_info().maxsize == 128
        with SweepSession(gating_cache_size=None):
            assert gating_cache_info().maxsize is None
        assert gating_cache_info().maxsize == 128
    assert gating_cache_info().maxsize == before


def test_session_validation_and_reentrancy():
    with pytest.raises(KeyError, match="unknown array backend"):
        SweepSession(backend="torch")
    with pytest.raises(KeyError, match="sa_occupancy"):
        SweepSession(sa_occupancy_impl="xla")
    s = SweepSession(backend="numpy")
    with s:
        with pytest.raises(RuntimeError, match="not re-entrant"):
            s.__enter__()
    with pytest.raises(KeyError, match="unknown session field"):
        session.set_root(frobnicate=1)
    with pytest.raises(KeyError, match="unknown session field"):
        session.resolve("frobnicate")


def test_sweeps_ride_the_session_backend():
    """A sweep with backend=None inside SweepSession(backend=...) is
    the same computation as passing the backend explicitly."""
    wls = paper_suite()[:1]
    grid = KnobGrid(window_scale=(0.5, 1.0))
    explicit = sweep_grid(wls, policies=("NoPG", "ReGate-HW"),
                          grid=grid, backend="jax")
    with SweepSession(backend="jax"):
        implicit = sweep_grid(wls, policies=("NoPG", "ReGate-HW"),
                              grid=grid)
    _assert_records_match(explicit, implicit)
