"""int8+error-feedback gradient compression and the SLO sweep."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.configs.base import ShapeConfig
from repro.data.specs import make_batch
from repro.models import registry
from repro.models.param import init_params
from repro.optim.adamw import AdamWConfig
from repro.optim.compression import (compress_grads, dequantize_int8,
                                     init_error_feedback, quantize_int8)
from repro.train.steps import TrainState, make_train_step


def test_int8_roundtrip_bounded_error():
    g = jax.random.normal(jax.random.PRNGKey(0), (256,)) * 3.0
    q, s = quantize_int8(g)
    deq = dequantize_int8(q, s)
    assert q.dtype == jnp.int8
    np.testing.assert_allclose(np.asarray(deq), np.asarray(g),
                               atol=float(s) * 0.51)


def test_error_feedback_telescopes():
    """Sum of decompressed grads converges to the sum of true grads —
    the EF residual never grows."""
    key = jax.random.PRNGKey(1)
    p = {"w": jnp.zeros((64,))}
    ef = init_error_feedback(p)
    true_sum = jnp.zeros((64,))
    deq_sum = jnp.zeros((64,))
    for i in range(20):
        g = {"w": jax.random.normal(jax.random.fold_in(key, i), (64,))}
        out, ef = compress_grads(g, "int8", ef)
        true_sum = true_sum + g["w"]
        deq_sum = deq_sum + out["w"]
    # residual bounded by one quantization step, NOT 20 of them
    resid = float(jnp.abs(true_sum - deq_sum).max())
    assert resid < 0.2, resid
    np.testing.assert_allclose(np.asarray(ef["w"]),
                               np.asarray(true_sum - deq_sum), atol=1e-5)


@pytest.mark.parametrize("codec", [None, "bf16", "int8"])
def test_train_step_with_compression(codec):
    cfg = get_arch("qwen2.5-3b").reduced()
    opt = AdamWConfig(total_steps=10, warmup_steps=2)
    params = init_params(registry.param_specs(cfg), jax.random.PRNGKey(0))
    st = TrainState.create(params, opt, grad_compression=codec)
    step = jax.jit(make_train_step(cfg, opt, grad_compression=codec))
    b = make_batch(cfg, ShapeConfig("t", 32, 4, "train"), seed=1)
    st, m = step(st, b)
    st, m = step(st, b)
    assert jnp.isfinite(m["loss"])
    if codec == "int8":
        assert "ef" in st.opt_state


def test_slo_sweep_monotone_generations():
    from repro.core.slo import slo_sweep
    res = slo_sweep("llama3-8b", "decode", batches=(8, 128),
                    chip_counts=(1, 2, 4, 8))
    effs = []
    for gen in ("NPU-A", "NPU-C", "NPU-E"):
        pt = res.get(gen)
        if pt is not None:
            effs.append(pt.efficiency)
    # newer generations are at least as energy-efficient (paper Fig 2)
    assert all(b >= a * 0.95 for a, b in zip(effs, effs[1:])), effs
