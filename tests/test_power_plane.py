"""Property + unit tests for the paper's power-gating plane."""
import math

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # degrade: property tests skip, rest still run
    from _hypothesis_fallback import given, settings, st

from repro.core.hw import NPUS, get_npu
from repro.core.opgen import Op, Workload, llm_workload, paper_suite
from repro.core.policies import (POLICIES, PolicyKnobs, evaluate,
                                 evaluate_all, savings_vs_nopg)
from repro.core.power import COMPONENTS, PowerModel, STATIC_SHARES
from repro.core.sa_gating import (gating_stats, prefix_on_bitmap,
                                  simulate_pe_grid, spatial_efficiency)


# ------------------------------------------------------------ SA gating
def test_prefix_bitmap_paper_example():
    """Paper Fig 12: col_nz=0100 -> col_on=1100."""
    nz = np.array([False, True, False, False])
    assert prefix_on_bitmap(nz).tolist() == [True, True, False, False]


@given(st.lists(st.booleans(), min_size=1, max_size=32))
def test_prefix_bitmap_properties(bits):
    on = prefix_on_bitmap(np.array(bits))
    # ON iff any nonzero at-or-after; monotone (once off, stays off)
    for i in range(len(bits)):
        assert on[i] == any(bits[i:])
    for a, b in zip(on, on[1:]):
        assert a or not b


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 24), st.integers(1, 8), st.integers(1, 8),
       st.sampled_from([4, 8]))
def test_gating_stats_matches_cycle_sim(M, K, N, saw):
    """Closed form == exact cycle-level PE simulation (single tile)."""
    K, N = min(K, saw), min(N, saw)
    sim = simulate_pe_grid(M, K, N, saw)
    st_ = gating_stats(M, K, N, saw, weight_load_cycles=0)
    tot = sim["total"]
    assert math.isclose(st_.frac_on, sim["on"] / tot, rel_tol=1e-9)
    assert math.isclose(st_.frac_w_on, sim["w_on"] / tot, rel_tol=1e-9)
    assert math.isclose(st_.frac_off, sim["off"] / tot, rel_tol=1e-9)


def test_gating_stats_underutilization_cases():
    """Paper Fig 10: all three underutilization cases gate PEs off."""
    saw = 128
    full = gating_stats(4096, 128, 128, saw)
    assert full.frac_off < 1e-9  # all PEs hold live weights
    n_under = gating_stats(4096, 128, 64, saw)
    assert 0.45 < n_under.frac_off < 0.55  # half the columns dead
    k_under = gating_stats(4096, 64, 128, saw)
    assert 0.45 < k_under.frac_off < 0.55
    m_under = gating_stats(8, 128, 128, saw)
    assert m_under.frac_w_on > 0.9  # weights held, data rarely passing


def test_spatial_efficiency_ranges():
    assert spatial_efficiency(4096, 128, 128, 128) > 0.9
    assert spatial_efficiency(1, 128, 128, 128) < 0.05  # decode GEMV


# ------------------------------------------------------------- policies
@pytest.fixture(scope="module")
def wl():
    return llm_workload("llama3-8b", "decode", batch=8, n_chips=1)


def test_policy_ordering(wl):
    """Ideal >= Full >= HW >= Base >= NoPG savings (by construction)."""
    sv = savings_vs_nopg(evaluate_all(wl))
    assert sv["NoPG"] == 0.0
    assert sv["ReGate-Base"] > 0.0
    assert sv["ReGate-HW"] >= sv["ReGate-Base"] - 1e-9
    assert sv["ReGate-Full"] >= sv["ReGate-HW"] - 1e-9
    assert sv["Ideal"] >= sv["ReGate-Full"] - 1e-9
    assert sv["Ideal"] < 1.0


def test_energy_positive_and_conserved(wl):
    for p in POLICIES:
        r = evaluate(wl, "NPU-D", p)
        assert r.total_j > 0
        assert all(v >= 0 for v in r.static_j.values())
        assert all(v >= 0 for v in r.dynamic_j.values())
        # dynamic energy is policy-independent (gating only cuts leakage)
    dyn = [sum(evaluate(wl, "NPU-D", p).dynamic_j.values())
           for p in POLICIES]
    assert max(dyn) - min(dyn) < 1e-9 * max(dyn) + 1e-12


def test_perf_overhead_bounds():
    """Paper Fig 19: Full < 0.5%; Base worst-case bounded."""
    for wl_ in paper_suite():
        reps = evaluate_all(wl_)
        base = reps["NoPG"].runtime_s
        assert reps["ReGate-Full"].runtime_s / base - 1 < 0.005
        assert reps["ReGate-Base"].runtime_s / base - 1 < 0.05
        assert reps["Ideal"].runtime_s == pytest.approx(base)


def test_setpm_rate_below_bound():
    """Paper Fig 20: compiler never exceeds 1000/BET_vu = 31 per 1k cyc."""
    npu = get_npu("NPU-D")
    for wl_ in paper_suite():
        r = evaluate(wl_, npu, "ReGate-Full")
        assert r.setpm_per_1k_cycles(npu) < 31.0


def test_savings_in_paper_band():
    """Fig 17: ReGate-Full savings 8.5-32.8% across the suite (we allow a
    modestly wider calibration band and check the average)."""
    vals = [savings_vs_nopg(evaluate_all(w))["ReGate-Full"]
            for w in paper_suite()]
    assert 0.05 < min(vals) < 0.20
    assert 0.25 < max(vals) < 0.40
    avg = sum(vals) / len(vals)
    assert 0.10 < avg < 0.25


def test_static_fraction_in_paper_band():
    """Fig 3: busy-chip static energy fraction 30-72%."""
    for w in paper_suite():
        sf = evaluate(w, "NPU-D", "NoPG").static_frac
        assert 0.28 < sf < 0.80, (w.name, sf)


@settings(max_examples=20, deadline=None)
@given(st.floats(0.01, 0.5), st.floats(0.5, 4.0))
def test_sensitivity_monotonic(leak, delay_scale):
    """Higher gated leakage and longer delays never increase savings."""
    w = llm_workload("llama3-8b", "decode", batch=8, n_chips=1)
    base = savings_vs_nopg(evaluate_all(w))["ReGate-Full"]
    knobs = PolicyKnobs(leak_off_logic=leak, leak_sram_off=leak,
                        leak_sram_sleep=max(leak, 0.25),
                        delay_scale=delay_scale)
    sv = savings_vs_nopg(evaluate_all(w, knobs=knobs))["ReGate-Full"]
    if leak >= 0.03 and delay_scale >= 1.0:
        assert sv <= base + 1e-6


def test_generational_claims():
    """Derived peak FLOPs reproduce published TPU peaks (paper Table 2)."""
    assert round(NPUS["NPU-A"].sa_flops / 1e12) == 46
    assert round(NPUS["NPU-B"].sa_flops / 1e12) == 123
    assert round(NPUS["NPU-C"].sa_flops / 1e12) == 275
    assert round(NPUS["NPU-D"].sa_flops / 1e12) == 459
    # static shares match paper Fig 3 ranges
    for gen, shares in STATIC_SHARES.items():
        assert 0.08 <= shares["sa"] <= 0.14
        assert 0.019 <= shares["vu"] <= 0.056
        assert 0.154 <= shares["sram"] <= 0.244
        assert 0.09 <= shares["hbm"] <= 0.224
        assert 0.053 <= shares["ici"] <= 0.12
        assert 0.39 <= shares["other"] <= 0.458
        assert abs(sum(shares.values()) - 1.0) < 1e-6
