"""End-to-end system behaviour: train loop with checkpoint/restart and
failure injection; batched serving; HLO analyzer on a live compile."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.train import TrainLoopConfig, run


def test_train_loop_runs_and_improves(tmp_path):
    out = run(TrainLoopConfig(arch="qwen2.5-3b", steps=6, seq_len=32,
                              global_batch=4, ckpt_dir=str(tmp_path),
                              checkpoint_every=3, log_every=100))
    assert len(out["losses"]) == 6
    assert all(np.isfinite(v) for v in out["losses"])


def test_failure_injection_and_bitexact_resume(tmp_path):
    cfg = TrainLoopConfig(arch="mamba2-780m", steps=8, seq_len=32,
                          global_batch=4, ckpt_dir=str(tmp_path),
                          checkpoint_every=2, log_every=100)
    full = run(TrainLoopConfig(**{**vars(cfg), "ckpt_dir": ""}))
    with pytest.raises(RuntimeError, match="injected failure"):
        run(TrainLoopConfig(**{**vars(cfg), "fail_at_step": 5}))
    resumed = run(cfg)  # resumes from step 4 checkpoint
    # the resumed run's tail losses match the uninterrupted run bit-exactly
    np.testing.assert_allclose(resumed["losses"][-3:], full["losses"][-3:],
                               rtol=0, atol=0)


def test_batched_serving():
    from repro.launch.serve import Server
    srv = Server("qwen2.5-3b", batch=2, max_seq=48)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, srv.cfg.vocab_size, (2, 8), dtype=np.int32)
    toks = srv.generate(prompts, 4)
    assert toks.shape == (2, 4)
    assert (toks >= 0).all() and (toks < srv.cfg.vocab_padded).all()


def test_grad_compression_changes_nothing_structural():
    """bf16 grad compression: same convergence direction, different bytes
    on the wire (the dry-run measures the bytes; here we check the step
    still trains)."""
    out = run(TrainLoopConfig(arch="qwen2.5-3b", steps=3, seq_len=32,
                              global_batch=4, grad_compression="bf16",
                              log_every=100))
    assert all(np.isfinite(v) for v in out["losses"])


def test_hlo_analyzer_on_live_compile():
    """Scaled flops from the analyzer == trip count x per-iteration dots."""
    from repro.core.hlo import analyze

    def f(x, ws):
        def body(c, w):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, ws)
        return y.sum()

    comp = jax.jit(f).lower(
        jax.ShapeDtypeStruct((64, 64), jnp.float32),
        jax.ShapeDtypeStruct((7, 64, 64), jnp.float32)).compile()
    costs = analyze(comp.as_text())
    assert costs.flops == pytest.approx(7 * 2 * 64 ** 3, rel=0.01)
    # xla's own cost analysis counts the body once (the bug we fix);
    # jax <= 0.4.x returns a per-program list, newer jax a flat dict
    ca = comp.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    assert ca["flops"] < costs.flops / 3
