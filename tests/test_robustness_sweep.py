"""Idle-detection robustness sweep (jitter plane, ISSUE 6).

Structure and invariants of ``sweep.sweep_robustness`` — record/summary
shape, severity-0 null behavior, deployed/chosen threshold flags,
SLO-constrained regret activation under heavy jitter — plus unit tests
for ``slo.runtime_violation_rate`` and the jax-backend path.
"""
import numpy as np
import pytest

from repro.core.opgen import llm_workload, paper_suite
from repro.core.slo import runtime_violation_rate
from repro.core.sweep import sweep_robustness

WLS = paper_suite()[10:12]          # llama3-70b / llama3.1-405b decode
SEVS = (0.0, 1.0, 2.0)
TS = (0.25, 1.0, 4.0)


@pytest.fixture(scope="module")
def out():
    return sweep_robustness(WLS, severities=SEVS, threshold_scales=TS,
                            seed=0)


def test_output_structure(out):
    assert set(out) == {"records", "summary", "severities",
                        "threshold_scales"}
    assert out["severities"] == list(SEVS)
    assert out["threshold_scales"] == list(TS)
    # one summary row per (npu, policy, severity); one record per cell
    assert len(out["summary"]) == 1 * 1 * len(SEVS)
    assert len(out["records"]) == len(WLS) * 1 * 1 * len(SEVS) * len(TS)
    r = out["records"][0]
    # since ISSUE 7 every sweep record carries the full knob-column set
    # (KnobGrid.columns() + knob_idx) unconditionally
    from repro.core.policies import KnobGrid
    assert set(r) == {"workload", "npu", "policy", "severity",
                      "knob_idx", *KnobGrid.columns(),
                      "runtime_s", "total_j",
                      "exposed_wake_s", "deployed", "chosen"}
    assert r["knob_idx"] == 0 and r["delay_scale"] == 1.0
    assert r["window_scale"] == TS[0]


def test_severity_zero_is_null(out):
    s0 = next(s for s in out["summary"] if s["severity"] == 0.0)
    assert s0["slo_violation_rate"] == 0.0
    assert s0["max_regret_frac"] == 0.0
    assert s0["mean_regret_frac"] == 0.0


def test_records_finite_and_nonnegative(out):
    for r in out["records"]:
        assert np.isfinite(r["runtime_s"]) and r["runtime_s"] > 0
        assert np.isfinite(r["total_j"]) and r["total_j"] > 0
        assert r["exposed_wake_s"] >= 0.0
    for s in out["summary"]:
        assert s["worst_exposed_wake_s"] >= 0.0
        assert s["worst_exposed_wake_any_s"] >= s["worst_exposed_wake_s"]
        assert 0.0 <= s["slo_violation_rate"] <= 1.0
        assert s["max_regret_frac"] >= s["mean_regret_frac"] >= 0.0


def test_deployed_and_chosen_flags(out):
    """Exactly one deployed and one chosen threshold per (workload,
    severity) group; at severity 0 they coincide (nothing violates)."""
    groups = {}
    for r in out["records"]:
        groups.setdefault((r["workload"], r["severity"]), []).append(r)
    assert len(groups) == len(WLS) * len(SEVS)
    for (wl, sev), rows in groups.items():
        assert sum(r["deployed"] for r in rows) == 1
        assert sum(r["chosen"] for r in rows) == 1
        dep = next(r for r in rows if r["deployed"])
        if sev == 0.0:
            assert dep["chosen"]
        # the deployed threshold is the same at every severity
        assert dep["window_scale"] == next(
            r for r in groups[(wl, 0.0)] if r["deployed"])["window_scale"]


def test_regret_activates_under_heavy_jitter(out):
    """The paper-level story: the clean-tuned (most aggressive)
    threshold blows the 1.1x SLO once jitter fragments the idle
    intervals, and re-tuning to a feasible threshold costs energy."""
    s2 = next(s for s in out["summary"] if s["severity"] == 2.0)
    assert s2["slo_violation_rate"] > 0.0
    assert s2["max_regret_frac"] > 0.0
    s0 = next(s for s in out["summary"] if s["severity"] == 0.0)
    assert s2["worst_exposed_wake_s"] > s0["worst_exposed_wake_s"]
    # re-tuning moved the chosen threshold off the deployed one
    moved = [r for r in out["records"]
             if r["severity"] == 2.0 and r["chosen"] and not r["deployed"]]
    assert moved


def test_single_workload_and_no_topology():
    wl = llm_workload("llama3-8b", "decode", batch=8, n_chips=8,
                      tp=8, dp=1)
    out = sweep_robustness(wl, severities=(0.0,), threshold_scales=(1.0,),
                           topology=False)
    assert len(out["records"]) == 1
    assert out["records"][0]["workload"] == wl.name


def test_threshold_scales_validated():
    for bad in ((0.0,), (-1.0,), (float("nan"),)):
        with pytest.raises(ValueError, match="threshold_scales"):
            sweep_robustness(WLS, severities=(0.0,),
                             threshold_scales=bad)


def test_jax_backend_matches_numpy(out):
    pytest.importorskip("jax")
    from repro.core.backend import get_backend
    bk = get_backend("jax")
    if bk._x64_ctx is None and not bk.x64_enabled():
        pytest.skip("this jax has no scoped x64 switch and "
                    "jax_enable_x64 is off")
    oj = sweep_robustness(WLS, severities=SEVS, threshold_scales=TS,
                          seed=0, backend="jax")
    assert len(oj["records"]) == len(out["records"])
    for a, b in zip(out["records"], oj["records"]):
        for k in ("workload", "severity", "window_scale", "deployed",
                  "chosen"):
            assert a[k] == b[k]
        for k in ("runtime_s", "total_j", "exposed_wake_s"):
            assert np.isclose(a[k], b[k], rtol=1e-9, atol=1e-12), (a, k)
    for a, b in zip(out["summary"], oj["summary"]):
        for k, v in a.items():
            if isinstance(v, float):
                assert np.isclose(v, b[k], rtol=1e-9, atol=1e-12), k
            else:
                assert v == b[k]


# --------------------------------------------------- runtime_violation_rate

def test_violation_rate_math():
    r = np.array([1.0, 1.2, 2.0, 1.05])
    b = np.ones(4)
    assert runtime_violation_rate(r, b, slo_relax=1.1) == 0.5
    assert runtime_violation_rate(r, b, slo_relax=2.5) == 0.0
    assert runtime_violation_rate(r, b, slo_relax=0.5) == 1.0


def test_violation_rate_edge_cases():
    assert runtime_violation_rate([], []) == 0.0
    with pytest.raises(ValueError):
        runtime_violation_rate([1.0], [1.0], slo_relax=0.0)
    with pytest.raises(ValueError):
        runtime_violation_rate([1.0, 2.0], [1.0])
