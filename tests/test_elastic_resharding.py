"""Elastic resharding across DIFFERENT mesh shapes (subprocess: device
count must be fixed before jax initializes). A checkpoint saved on a
(2,4) mesh restores bit-exactly onto (4,2) and onto a single device —
the restart path a resized pod needs."""
import subprocess
import sys
import textwrap

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import tempfile
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.checkpoint.manager import CheckpointManager
    from repro.parallel.jax_compat import make_mesh

    def mesh_of(shape):
        return make_mesh(shape, ("data", "model"))

    state = {"w": jax.random.normal(jax.random.PRNGKey(0), (16, 8)),
             "m": jnp.arange(64, dtype=jnp.float32).reshape(16, 4)}

    with tempfile.TemporaryDirectory() as d:
        cm = CheckpointManager(d)
        m1 = mesh_of((2, 4))
        sh1 = {"w": NamedSharding(m1, P("data", "model")),
               "m": NamedSharding(m1, P("data", None))}
        placed = jax.tree.map(jax.device_put, state, sh1)
        cm.save(1, placed, blocking=True)

        # restore on a TRANSPOSED mesh
        m2 = mesh_of((4, 2))
        sh2 = {"w": NamedSharding(m2, P("model", "data")),
               "m": NamedSharding(m2, P(None, "model"))}
        r2, _ = cm.restore(state, shardings=sh2)
        for k in state:
            np.testing.assert_array_equal(np.asarray(r2[k]),
                                          np.asarray(state[k]))
            assert r2[k].sharding == sh2[k]

        # restore unsharded (single-device consumer)
        r3, _ = cm.restore(state)
        for k in state:
            np.testing.assert_array_equal(np.asarray(r3[k]),
                                          np.asarray(state[k]))
    print("ELASTIC_OK")
""")


def test_elastic_resharding_across_meshes():
    r = subprocess.run([sys.executable, "-c", _SCRIPT],
                       capture_output=True, text=True, timeout=300,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root", "JAX_PLATFORMS": "cpu"})
    assert "ELASTIC_OK" in r.stdout, r.stdout + r.stderr
