"""MoE dispatch correctness: capacity semantics, drop handling, and the
shard_map dispatch vs the GSPMD path on a multi-device mesh (subprocess —
the device count must be set before jax initializes)."""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import blocks
from repro.models.param import init_params
from repro.models import registry

KEY = jax.random.PRNGKey(11)


def test_moe_group_routes_topk():
    """With ample capacity every token gets exactly its top-k experts:
    output == manual dense mixture."""
    cfg = get_arch("granite-moe-1b-a400m").reduced()
    specs = registry.layer_specs(cfg)["moe"]
    p = init_params(specs, KEY)
    G, D = 32, cfg.d_model
    tok = jax.random.normal(jax.random.fold_in(KEY, 1), (G, D), jnp.float32)
    y, aux = blocks._moe_group(p, tok, cfg)

    # dense reference: route each token through its top-k experts
    logits = tok @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gv, gi = jax.lax.top_k(probs, cfg.moe.top_k)
    gv = gv / gv.sum(-1, keepdims=True)
    act = jax.nn.silu
    ref = jnp.zeros_like(tok)
    for t in range(G):
        acc = jnp.zeros((D,))
        for j in range(cfg.moe.top_k):
            e = int(gi[t, j])
            h = act(tok[t] @ p["wg"][e]) * (tok[t] @ p["wu"][e])
            acc = acc + gv[t, j] * (h @ p["wd"][e])
        ref = ref.at[t].set(acc)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=2e-4)
    assert float(aux) > 0


def test_moe_capacity_drops_dont_crash():
    import dataclasses
    cfg = get_arch("granite-moe-1b-a400m").reduced()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=0.25))
    specs = registry.layer_specs(cfg)["moe"]
    p = init_params(specs, KEY)
    tok = jax.random.normal(KEY, (64, cfg.d_model), jnp.float32)
    y, aux = blocks._moe_group(p, tok, cfg)
    assert jnp.isfinite(y).all()
    # with drops, output norm is smaller than full routing
    assert float(jnp.abs(y).sum()) > 0


_SMAP_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.configs import get_arch
    from repro.models import blocks, registry
    from repro.models.param import init_params
    from repro.parallel.jax_compat import make_mesh, set_mesh

    cfg = get_arch("granite-moe-1b-a400m").reduced()  # 4 experts top-2
    specs = registry.layer_specs(cfg)["moe"]
    p = init_params(specs, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, cfg.d_model),
                          jnp.float32)
    mesh = make_mesh((4, 2), ("data", "model"))
    from repro.parallel.sharding import BASELINE, use_rules
    with set_mesh(mesh), use_rules(BASELINE):
        blocks.MOE_SHARD_MAP["enabled"] = False
        y0, a0 = jax.jit(lambda p, x: blocks.moe_fwd(p, x, cfg))(p, x)
        blocks.MOE_SHARD_MAP["enabled"] = True
        y1, a1 = jax.jit(lambda p, x: blocks.moe_fwd(p, x, cfg))(p, x)
    # capacity semantics differ (global vs per-shard) only under drops;
    # the reduced config has ample capacity -> identical routing
    err = float(jnp.abs(y0 - y1).max())
    assert err < 2e-4, f"smap vs gspmd mismatch: {err}"
    print("SMAP_OK", err)
""")


def test_moe_shard_map_matches_gspmd():
    r = subprocess.run([sys.executable, "-c", _SMAP_SCRIPT],
                       capture_output=True, text=True, timeout=600,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root", "JAX_PLATFORMS": "cpu"})
    assert "SMAP_OK" in r.stdout, r.stdout + r.stderr
