"""Multi-device shard_map sweep on 8 virtual host devices (subprocess:
device count must be fixed before jax initializes). The ISSUE-5
equivalence contract on a real multi-device topology: the shard_map
program — op columns sharded over ``"wl"`` with in-kernel psums, unique
(saw, delay) pairs + knob grid sharded over ``"knob"`` — must match the
numpy oracle record-for-record ≤1e-9 on every mesh shape, including
axis sizes that do not divide the op/pair/knob counts (padding)."""
import subprocess
import sys
import textwrap

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    assert len(jax.devices()) == 8, jax.devices()
    import sys
    sys.path.insert(0, "tests")
    from _sweep_equiv import assert_records_match
    from repro.core.opgen import paper_suite
    from repro.core.policies import POLICIES, evaluate_batch
    from repro.core.sweep import knob_product, sweep
    from repro.parallel import jax_compat

    wls = paper_suite()[:4]
    grid = knob_product(delay_scale=(0.25, 1.0, 4.0),
                        leak_off_logic=(0.03, 0.2),
                        sa_width=(None, 256, 64))
    ref = sweep(wls, ("NPU-B", "NPU-E"), POLICIES, grid,
                backend="numpy")
    for shape, axes in (((8,), ("knob",)),
                        ((2, 4), ("wl", "knob")),
                        ((8, 1), ("wl", "knob"))):
        mesh = jax_compat.make_mesh(shape, axes)
        got = evaluate_batch(wls, ("NPU-B", "NPU-E"), POLICIES, grid,
                             backend="jax", jax_mesh=mesh).records()
        assert_records_match(ref, got)
        print("mesh", shape, axes, "ok")
    print("MULTIDEVICE_SWEEP_OK")
""")


def test_shard_map_sweep_on_8_virtual_devices():
    r = subprocess.run([sys.executable, "-c", _SCRIPT],
                       capture_output=True, text=True, timeout=600,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root", "JAX_PLATFORMS": "cpu"})
    assert "MULTIDEVICE_SWEEP_OK" in r.stdout, r.stdout + r.stderr


# the ISSUE-10 program plane on the same 8-device topology: the event
# scan kernel's row axis is GSPMD-sharded over "wl" (inert padding rows
# make 20 exec rows divide 8 devices) and must match the single-device
# jax run bit-for-bit
_SCRIPT_PLANE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    assert len(jax.devices()) == 8, jax.devices()
    from repro.core.opgen import paper_suite
    from repro.core.policies import KnobGrid
    from repro.core.sweep import sweep_program_plane
    from repro.parallel import jax_compat

    wls = paper_suite()[:5]
    grid = KnobGrid(delay_scale=(1.0, 4.0), window_scale=(1.0, 0.5))
    npus = ("NPU-B", "NPU-D")
    one = sweep_program_plane(wls, npus=npus, knob_grid=grid,
                              backend="jax")
    mesh = jax_compat.sweep_mesh(wl=8)
    got = sweep_program_plane(wls, npus=npus, knob_grid=grid,
                              backend="jax", jax_mesh=mesh)
    assert len(one) == len(got) == len(wls) * 2 * 4
    for x, y in zip(one, got):
        for k in x:
            assert x[k] == y[k] or (
                isinstance(x[k], float)
                and abs(x[k] - y[k]) <= 1e-9 * max(1.0, abs(x[k]))), \\
                (k, x[k], y[k])
    print("MULTIDEVICE_PLANE_OK")
""")


def test_program_plane_mesh_on_8_virtual_devices():
    r = subprocess.run([sys.executable, "-c", _SCRIPT_PLANE],
                       capture_output=True, text=True, timeout=600,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root", "JAX_PLATFORMS": "cpu"})
    assert "MULTIDEVICE_PLANE_OK" in r.stdout, r.stdout + r.stderr
