"""Multi-device shard_map sweep on 8 virtual host devices (subprocess:
device count must be fixed before jax initializes). The ISSUE-5
equivalence contract on a real multi-device topology: the shard_map
program — op columns sharded over ``"wl"`` with in-kernel psums, unique
(saw, delay) pairs + knob grid sharded over ``"knob"`` — must match the
numpy oracle record-for-record ≤1e-9 on every mesh shape, including
axis sizes that do not divide the op/pair/knob counts (padding)."""
import subprocess
import sys
import textwrap

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    assert len(jax.devices()) == 8, jax.devices()
    import sys
    sys.path.insert(0, "tests")
    from _sweep_equiv import assert_records_match
    from repro.core.opgen import paper_suite
    from repro.core.policies import POLICIES, evaluate_batch
    from repro.core.sweep import knob_product, sweep
    from repro.parallel import jax_compat

    wls = paper_suite()[:4]
    grid = knob_product(delay_scale=(0.25, 1.0, 4.0),
                        leak_off_logic=(0.03, 0.2),
                        sa_width=(None, 256, 64))
    ref = sweep(wls, ("NPU-B", "NPU-E"), POLICIES, grid,
                backend="numpy")
    for shape, axes in (((8,), ("knob",)),
                        ((2, 4), ("wl", "knob")),
                        ((8, 1), ("wl", "knob"))):
        mesh = jax_compat.make_mesh(shape, axes)
        got = evaluate_batch(wls, ("NPU-B", "NPU-E"), POLICIES, grid,
                             backend="jax", jax_mesh=mesh).records()
        assert_records_match(ref, got)
        print("mesh", shape, axes, "ok")
    print("MULTIDEVICE_SWEEP_OK")
""")


def test_shard_map_sweep_on_8_virtual_devices():
    r = subprocess.run([sys.executable, "-c", _SCRIPT],
                       capture_output=True, text=True, timeout=600,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root", "JAX_PLATFORMS": "cpu"})
    assert "MULTIDEVICE_SWEEP_OK" in r.stdout, r.stdout + r.stderr
