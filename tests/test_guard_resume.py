"""Kill–resume acceptance (ISSUE 9): real SIGKILLs against a
checkpointed chaos campaign.

A subprocess runs ``_guard_resume_child.campaign`` with a campaign
checkpoint while ``REPRO_GUARD_KILL`` arms the guard plane's
self-fault-injection hook (``guard.maybe_kill``): ``boundary:<e>``
SIGKILLs right after epoch ``e``'s snapshot is durably published,
``mid:<e>`` SIGKILLs at the top of epoch ``e`` before anything of it
exists on disk. The parent verifies the child really died to SIGKILL,
relaunches it on the same checkpoint directory, and requires the
resumed final report — summary rows and per-epoch records — to be
**bit-identical** (same JSON text) to an uninterrupted run. Boundary
epochs are drawn seeded-randomly; mid-epoch gets its own case.
"""
import json
import os
import signal
import subprocess
import sys

import numpy as np
import pytest

import _guard_resume_child as child

CHILD = os.path.join(os.path.dirname(__file__),
                     "_guard_resume_child.py")

# >=3 seeded-random epoch boundaries + one mid-epoch kill
_BOUNDARY_EPOCHS = sorted(np.random.default_rng(2026).choice(
    child.N_EPOCHS, size=3, replace=False).tolist())
KILL_SPECS = [f"boundary:{e}" for e in _BOUNDARY_EPOCHS] \
    + [f"mid:{child.N_EPOCHS // 2}"]


@pytest.fixture(scope="module")
def reference(tmp_path_factory):
    """Uninterrupted checkpointed run, in-process (same code path the
    child executes), canonically serialized."""
    ck = tmp_path_factory.mktemp("ref_ck")
    return json.dumps(child.campaign(str(ck)), sort_keys=True)


def _launch(ckdir, out, *, kill=None):
    env = dict(os.environ)
    env.pop("REPRO_GUARD_KILL", None)
    if kill is not None:
        env["REPRO_GUARD_KILL"] = kill
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(CHILD), "..", "src"),
         os.path.dirname(CHILD)])
    return subprocess.run(
        [sys.executable, CHILD, str(ckdir), str(out)],
        env=env, capture_output=True, text=True, timeout=300)


@pytest.mark.parametrize("kill", KILL_SPECS)
def test_sigkill_then_resume_is_bit_identical(kill, tmp_path,
                                              reference):
    ckdir = tmp_path / "ck"
    out = tmp_path / "out.json"

    died = _launch(ckdir, out, kill=kill)
    assert died.returncode == -signal.SIGKILL, died.stderr
    assert not out.exists()   # killed before the final report
    phase, _, e = kill.partition(":")
    snaps = sorted(p.name for p in (ckdir / "run0_hyst").glob(
        "epoch_*.json"))
    if phase == "boundary":
        # the boundary kill lands strictly after the durable publish
        assert f"epoch_{e}.json" in snaps, snaps
    assert not (ckdir / "run0_hyst" / "final.json").exists()

    resumed = _launch(ckdir, out)
    assert resumed.returncode == 0, resumed.stderr
    assert out.read_text() == reference


def test_uninterrupted_subprocess_matches_reference(tmp_path,
                                                    reference):
    """The subprocess environment itself introduces no drift."""
    out = tmp_path / "out.json"
    run = _launch(tmp_path / "ck", out)
    assert run.returncode == 0, run.stderr
    assert out.read_text() == reference
