"""Per-architecture smoke tests: REDUCED same-family configs, one forward /
train / prefill / decode step on CPU; output shapes + no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import SHAPES, get_arch, list_archs
from repro.configs.base import ShapeConfig
from repro.data.specs import make_batch
from repro.models import model as M
from repro.models import registry
from repro.models.param import init_params
from repro.optim.adamw import AdamWConfig
from repro.train.steps import TrainState, make_prefill_step, \
    make_serve_step, make_train_step

TRAIN = ShapeConfig("tiny_train", 32, 4, "train")
PREFILL = ShapeConfig("tiny_prefill", 32, 2, "prefill")
DECODE = ShapeConfig("tiny_decode", 32, 2, "decode")
OPT = AdamWConfig(total_steps=10, warmup_steps=2)

ARCHS = list_archs()


@pytest.fixture(scope="module")
def states():
    return {}


def _params(name):
    cfg = get_arch(name).reduced()
    return cfg, init_params(registry.param_specs(cfg), jax.random.PRNGKey(0))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step(arch):
    cfg, params = _params(arch)
    step = make_train_step(cfg, OPT, microbatches=2)
    st = TrainState.create(params, OPT)
    st, m1 = jax.jit(step)(st, make_batch(cfg, TRAIN, seed=1))
    st, m2 = jax.jit(step)(st, make_batch(cfg, TRAIN, seed=2))
    assert jnp.isfinite(m1["loss"]) and jnp.isfinite(m2["loss"])
    assert float(m2["grad_norm"]) > 0
    assert int(st.step) == 2


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes(arch):
    cfg, params = _params(arch)
    batch = make_batch(cfg, TRAIN, seed=3)
    logits, aux = M.forward(params, batch, cfg)
    B = TRAIN.global_batch
    S = TRAIN.seq_len
    if cfg.frontend == "vision":
        S = S + 0  # image prepended internally; logits cover full seq
        assert logits.shape[0] == B
        assert logits.shape[2] == cfg.vocab_padded
    else:
        assert logits.shape == (B, S, cfg.vocab_padded)
    assert jnp.isfinite(logits.astype(jnp.float32)).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode(arch):
    cfg, params = _params(arch)
    if cfg.encoder_only:
        pytest.skip("encoder-only: no decode step")
    logits, cache = jax.jit(make_prefill_step(cfg))(
        params, make_batch(cfg, PREFILL, seed=4))
    assert jnp.isfinite(logits.astype(jnp.float32)).all()
    smax = 32 + (cfg.frontend_seq if cfg.frontend == "vision" else 0)
    full = M.init_cache(cfg, 2, smax)
    serve = make_serve_step(cfg)
    b = make_batch(cfg, DECODE, seed=5)
    lg, full = jax.jit(serve)(params, full, b)
    assert lg.shape == (2, 1, cfg.vocab_padded)
    assert jnp.isfinite(lg.astype(jnp.float32)).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_registered(arch):
    cfg = get_arch(arch)
    n = cfg.param_count()
    assert n > 1e8, f"{arch}: full config suspiciously small ({n})"
    # every arch declares support status for all four shapes
    sup = cfg.supported_shapes()
    assert set(sup) == set(SHAPES)
