"""Child process for the kill–resume tests (ISSUE 9).

Runs a small, fully deterministic chaos campaign with a campaign
checkpoint and writes the final summary JSON to ``argv[2]``. The
parent arms ``REPRO_GUARD_KILL`` to SIGKILL this process at an epoch
boundary (right after a snapshot publishes) or mid-epoch, then
relaunches it with the same checkpoint directory — the resumed output
must be bit-identical to an uninterrupted run.
"""
import json
import sys

from repro.core.fleet import (ArrivalSpec, FleetScenario, WorkloadClass,
                              sweep_chaos)
from repro.core.opgen import llm_workload
from repro.core.policies import KnobGrid
from repro.core.slo import Hysteresis

N_EPOCHS = 6


def campaign(checkpoint=None) -> dict:
    wl = llm_workload("llama2-13b", "decode", batch=8, n_chips=8, tp=8)
    sc = FleetScenario(
        classes=(WorkloadClass(
            "decode", wl,
            ArrivalSpec("diurnal", rate_rps=24.0, period_s=3600.0),
            requests_per_invocation=8),),
        n_chips=32, npu="NPU-D", policies=("NoPG", "ReGate-Full"),
        duration_s=3600.0, epoch_s=600.0, seed=17,
        severity_levels=(0.0, 1.0))
    out = sweep_chaos(sc, KnobGrid(window_scale=(0.5, 1.0)),
                      fault_severities=(0.0, 1.0),
                      hysteresis=Hysteresis(), thrash_baseline=False,
                      checkpoint=checkpoint)
    return {"summary": out["summary"],
            "reports": {repr(sev): rep.to_dict()
                        for sev, rep in out["reports"].items()}}


if __name__ == "__main__":
    ckdir, out_path = sys.argv[1], sys.argv[2]
    res = campaign(ckdir)
    with open(out_path, "w") as f:
        json.dump(res, f, sort_keys=True)
