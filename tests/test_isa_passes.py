"""ISA extension (setpm / VLIW timeline) + compiler pass tests."""
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # degrade: property tests skip, rest still run
    from _hypothesis_fallback import given, settings, st

from repro.core.hw import SRAM_SEGMENT_BYTES, get_npu
from repro.core.isa import (Instr, PMode, VLIWTimeline, fig15_program,
                            setpm)
from repro.core.passes import (BufferLifetime, IdleInterval, SlotUse,
                               analyze_sram_lifetimes, analyze_vu_idleness,
                               instrument_setpm, should_gate,
                               sram_setpm_plan)


# ------------------------------------------------------------- fig 15
def test_fig15_setpm_saves_energy_without_slowdown():
    """Paper Fig 15: compiler-placed setpm gates the VU holes; the pre-wake
    hides the 2-cycle delay, so runtime is unchanged."""
    prog_off = fig15_program(6, with_setpm=False)
    prog_on = fig15_program(6, with_setpm=True)
    r_off = VLIWTimeline(n_sa=2, n_vu=2, hw_auto_gating=False).run(prog_off)
    r_on = VLIWTimeline(n_sa=2, n_vu=2, hw_auto_gating=False).run(prog_on)
    assert r_on.cycles == r_off.cycles  # no performance overhead
    e_off = r_off.static_energy_units()
    e_on = r_on.static_energy_units()
    assert e_on < e_off  # gated VU cycles burn 3% leakage
    assert r_on.setpm_executed > 0
    # VU gated for a meaningful share of the run
    gated = sum(r_on.fu_gated_cycles[k] for k in ("vu0", "vu1"))
    total = gated + sum(r_on.fu_on_cycles[k] for k in ("vu0", "vu1"))
    assert gated / total > 0.3


def test_hw_auto_gating_pays_wakeup():
    """HW idle-detection gates late (window) and exposes the wake delay."""
    prog = fig15_program(6, with_setpm=False)
    r_auto = VLIWTimeline(n_sa=2, n_vu=2, hw_auto_gating=True).run(prog)
    r_none = VLIWTimeline(n_sa=2, n_vu=2, hw_auto_gating=False).run(prog)
    assert r_auto.cycles >= r_none.cycles  # exposed VU wake-ups
    assert sum(r_auto.wake_events.values()) > 0


def test_setpm_bitmap_semantics():
    """One setpm with a bitmap controls multiple units (paper Fig 14)."""
    tl = VLIWTimeline(n_sa=1, n_vu=4, hw_auto_gating=False)
    bundles = [
        {"misc": setpm("vu", 0b1011, PMode.OFF)},
        {"sa0": Instr("push", "sa0", 4)},
    ]
    tl.run(bundles)
    assert not tl.fus["vu0"].powered
    assert not tl.fus["vu1"].powered
    assert tl.fus["vu2"].powered       # bit 2 clear
    assert not tl.fus["vu3"].powered


# -------------------------------------------------------------- passes
def test_vu_idleness_analysis_basic():
    uses = [SlotUse(0, "vu0", duration=2), SlotUse(100, "vu0"),
            SlotUse(0, "vu1"), SlotUse(10, "vu1")]
    idle = analyze_vu_idleness(uses)
    assert idle["vu0"] == [IdleInterval("vu0", 2, 100)]
    assert idle["vu1"] == [IdleInterval("vu1", 1, 10)]


def test_vu_idleness_dma_unbounded():
    """A DMA between two VU instructions makes the gap gate-worthy
    regardless of its nominal length (paper §4.3)."""
    uses = [SlotUse(0, "vu0"), SlotUse(20, "vu0")]
    idle = analyze_vu_idleness(uses, dma_cycles=[5])
    (iv,) = idle["vu0"]
    assert iv.start == 1 and iv.end == 20


def test_instrument_setpm_bet_policy():
    npu = get_npu("NPU-D")
    bet = npu.gating.bet["vu"]
    idle = {
        "vu0": [IdleInterval("vu0", 10, 10 + bet - 1)],   # too short
        "vu1": [IdleInterval("vu1", 10, 10 + bet * 4)],   # gate it
        "vu2": [IdleInterval("vu2", 10, 10 + bet * 4)],   # same interval
    }
    placements = instrument_setpm(idle, npu)
    offs = [p for p in placements if p.instr.pm_mode == PMode.OFF]
    ons = [p for p in placements if p.instr.pm_mode == PMode.ON]
    assert len(offs) == 1 and len(ons) == 1  # bitmap shares one setpm
    assert offs[0].instr.pm_bitmap == 0b110
    # pre-wake scheduled delay cycles before next use
    assert ons[0].cycle == 10 + bet * 4 - npu.gating.on_off_delay["vu"]


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2000), st.integers(1, 500), st.integers(1, 60))
def test_should_gate_policy(length, bet, delay):
    g = should_gate(length, bet, delay)
    assert g == (length > bet and length > 2 * delay)


def test_sram_plan_collapses_ranges():
    """Never-used contiguous segments become a single range setpm."""
    bufs = [BufferLifetime(0, 100, 0, 8192)]  # segments 0-1 used
    seg = analyze_sram_lifetimes(bufs, 64 * 1024, horizon=200)  # 16 segs
    plan = sram_setpm_plan(seg, horizon=200)
    range_offs = [p for p in plan if p.instr.pm_range is not None
                  and p.instr.pm_mode == PMode.OFF and p.cycle == 0]
    assert len(range_offs) == 1
    lo, hi = range_offs[0].instr.pm_range
    assert lo == 2 * SRAM_SEGMENT_BYTES and hi == 16 * SRAM_SEGMENT_BYTES


def test_sram_dead_interval_gating():
    bufs = [BufferLifetime(0, 10, 0, 4096),
            BufferLifetime(5000, 5100, 0, 4096)]
    seg = analyze_sram_lifetimes(bufs, 8192, horizon=6000)
    plan = sram_setpm_plan(seg, horizon=6000)
    kinds = [(p.instr.pm_mode, p.reason) for p in plan
             if p.instr.pm_range == (0, SRAM_SEGMENT_BYTES)]
    assert (PMode.OFF, "dead interval") in kinds
    assert any(m == PMode.ON for m, _ in kinds)


# ------------------------------------------------- edge cases (ISSUE 2)
def test_vu_idleness_zero_length_and_adjacent_intervals():
    """Back-to-back and overlapping uses produce NO idle interval; a
    one-cycle hole produces exactly a length-1 interval."""
    uses = [SlotUse(0, "vu0", duration=5), SlotUse(5, "vu0"),   # adjacent
            SlotUse(6, "vu0", duration=4), SlotUse(8, "vu0"),   # overlap
            SlotUse(9, "vu0"), SlotUse(11, "vu0")]              # 1-gap
    idle = analyze_vu_idleness(uses)
    assert idle["vu0"] == [IdleInterval("vu0", 10, 11)]
    assert idle["vu0"][0].length == 1


def test_vu_idleness_leading_interval():
    uses = [SlotUse(40, "vu0"), SlotUse(100, "vu0")]
    none = analyze_vu_idleness(uses)
    lead = analyze_vu_idleness(uses, include_leading=True)
    assert none["vu0"][0].start == 41
    assert lead["vu0"][0] == IdleInterval("vu0", 0, 40)
    # a unit already busy at cycle 0 gets no leading interval
    assert analyze_vu_idleness([SlotUse(0, "vu0"), SlotUse(9, "vu0")],
                               include_leading=True)["vu0"][0].start == 1


def test_instrument_setpm_interval_open_at_end():
    """end=inf (no next use): gate with an OFF but never schedule a
    pre-wake (there is nothing to wake for)."""
    npu = get_npu("NPU-D")
    idle = {"vu0": [IdleInterval("vu0", 10, float("inf"))]}
    placements = instrument_setpm(idle, npu)
    assert len(placements) == 1
    assert placements[0].instr.pm_mode == PMode.OFF
    assert placements[0].cycle == 10


def test_instrument_setpm_unbounded_dma_interval():
    """A DMA inside a nominally-too-short interval still gates (§4.3:
    the HBM latency dominates), and the pre-wake lands before the next
    use."""
    npu = get_npu("NPU-D")
    bet = npu.gating.bet["vu"]
    delay = npu.gating.on_off_delay["vu"]
    short = bet // 2
    uses = [SlotUse(0, "vu0"), SlotUse(1 + short, "vu0")]
    no_dma = instrument_setpm(analyze_vu_idleness(uses), npu)
    with_dma = instrument_setpm(
        analyze_vu_idleness(uses, dma_cycles=[2]), npu)
    assert no_dma == []  # below BET: not gated
    offs = [p for p in with_dma if p.instr.pm_mode == PMode.OFF]
    ons = [p for p in with_dma if p.instr.pm_mode == PMode.ON]
    assert len(offs) == 1 and len(ons) == 1
    assert ons[0].cycle == 1 + short - delay
    assert offs[0].cycle < ons[0].cycle  # gate strictly before pre-wake
    assert offs[0].reason == "dma-unbounded idle"


def test_instrument_setpm_unbounded_shorter_than_delay_not_gated():
    """A DMA-unbounded interval with no room for the wake to land after
    the gate must NOT be gated — otherwise the pre-wake would precede
    the off and the next use would pay the full exposed delay."""
    npu = get_npu("NPU-D")
    delay = npu.gating.on_off_delay["vu"]
    for length in (1, delay):
        uses = [SlotUse(0, "vu0", duration=1),
                SlotUse(1 + length, "vu0")]
        placements = instrument_setpm(
            analyze_vu_idleness(uses, dma_cycles=[1]), npu)
        assert placements == [], length
    # one cycle of room: gated, in the right order
    uses = [SlotUse(0, "vu0", duration=1), SlotUse(2 + delay, "vu0")]
    placements = instrument_setpm(
        analyze_vu_idleness(uses, dma_cycles=[1]), npu)
    assert [p.instr.pm_mode for p in placements] == [PMode.OFF, PMode.ON]
    assert placements[0].cycle < placements[1].cycle


def test_should_gate_exactly_at_thresholds():
    """BET exactly at threshold does NOT gate (strict >), one cycle over
    does; same for the 2x-delay bound."""
    assert not should_gate(100, bet=100, delay=10)
    assert should_gate(101, bet=100, delay=10)
    assert not should_gate(100, bet=50, delay=50)   # == 2x delay
    assert should_gate(101, bet=50, delay=50)
    assert not should_gate(0, bet=0, delay=0)


def test_sram_overlapping_segment_lifetimes_merge():
    """Overlapping and touching buffer lifetimes on one segment merge
    into a single busy interval; a disjoint later buffer stays
    separate."""
    bufs = [BufferLifetime(0, 100, 0, 4096),
            BufferLifetime(50, 180, 0, 4096),     # overlaps
            BufferLifetime(180, 220, 0, 4096),    # touches
            BufferLifetime(5000, 5100, 0, 4096)]  # disjoint
    seg = analyze_sram_lifetimes(bufs, 4096, horizon=6000)
    (s, merged), = seg
    assert s == 0
    assert merged == [(0, 220), (5000, 5100)]


def test_instrument_setpm_generalized_fu_type():
    """The pass drives any FU family via the Table-3 keys (here: ici)."""
    npu = get_npu("NPU-D")
    bet = npu.gating.bet["ici"]
    idle = {"ici0": [IdleInterval("ici0", 0, bet * 3)]}
    placements = instrument_setpm(idle, npu, fu_type="ici")
    assert placements[0].instr.pm_fu_type == "ici"
    assert placements[0].instr.pm_bitmap == 1
    ons = [p for p in placements if p.instr.pm_mode == PMode.ON]
    assert ons[0].cycle == bet * 3 - npu.gating.on_off_delay["ici"]
