"""AdamW against a numpy reference; synthetic-data pipeline determinism."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # degrade: property tests skip, rest still run
    from _hypothesis_fallback import given, settings, st

from repro.configs import get_arch
from repro.configs.base import ShapeConfig
from repro.data.pipeline import SyntheticDataset
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update, \
    cosine_lr


def _np_adamw(p, g, m, v, step, cfg: AdamWConfig, gnorm):
    scale = min(1.0, cfg.clip_norm / max(gnorm, 1e-9))
    g = g * scale
    m = cfg.b1 * m + (1 - cfg.b1) * g
    v = cfg.b2 * v + (1 - cfg.b2) * g * g
    c1 = 1 - cfg.b1 ** step
    c2 = 1 - cfg.b2 ** step
    delta = (m / c1) / (np.sqrt(v / c2) + cfg.eps)
    if p.ndim >= 2:
        delta = delta + cfg.weight_decay * p
    lr = float(cosine_lr(cfg, jnp.asarray(step)))
    return p - lr * delta, m, v


def test_adamw_matches_numpy_reference():
    cfg = AdamWConfig(lr_peak=1e-2, warmup_steps=0, total_steps=100,
                      weight_decay=0.01)
    rng = np.random.default_rng(0)
    p = {"w": jnp.asarray(rng.standard_normal((4, 3)), jnp.float32),
         "b": jnp.asarray(rng.standard_normal((3,)), jnp.float32)}
    opt = adamw_init(p, cfg)
    m = {k: np.zeros_like(np.asarray(v)) for k, v in p.items()}
    v_ = {k: np.zeros_like(np.asarray(v)) for k, v in p.items()}
    pn = {k: np.asarray(x).copy() for k, x in p.items()}
    for step in range(1, 4):
        g = {k: jnp.asarray(rng.standard_normal(x.shape), jnp.float32)
             for k, x in p.items()}
        p, opt, metrics = adamw_update(g, opt, p, cfg)
        gnorm = float(np.sqrt(sum((np.asarray(x) ** 2).sum()
                                  for x in g.values())))
        for k in pn:
            pn[k], m[k], v_[k] = _np_adamw(
                pn[k], np.asarray(g[k]), m[k], v_[k], step, cfg, gnorm)
        for k in pn:
            np.testing.assert_allclose(np.asarray(p[k]), pn[k], atol=1e-5)


def test_grad_clipping_effective():
    cfg = AdamWConfig(lr_peak=1.0, warmup_steps=0, clip_norm=1.0,
                      weight_decay=0.0)
    p = {"w": jnp.zeros((4,))}
    opt = adamw_init(p, cfg)
    g = {"w": jnp.full((4,), 100.0)}
    _, _, metrics = adamw_update(g, opt, p, cfg)
    assert float(metrics["grad_norm"]) == pytest.approx(200.0)


def test_cosine_schedule_shape():
    cfg = AdamWConfig(lr_peak=1.0, warmup_steps=10, total_steps=110)
    lrs = [float(cosine_lr(cfg, jnp.asarray(s))) for s in
           (0, 5, 10, 60, 110)]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(0.5)
    assert lrs[2] == pytest.approx(1.0)
    assert 0.1 < lrs[3] < 1.0
    assert lrs[4] == pytest.approx(0.1)  # floor at 10% of peak


# ------------------------------------------------------------------ data
def test_batch_determinism():
    cfg = get_arch("qwen2.5-3b").reduced()
    shape = ShapeConfig("t", 16, 2, "train")
    d1 = SyntheticDataset(cfg, shape, seed=4)
    d2 = SyntheticDataset(cfg, shape, seed=4)
    b1, b2 = d1.batch(11), d2.batch(11)
    for k in b1:
        np.testing.assert_array_equal(np.asarray(b1[k]), np.asarray(b2[k]))
    b3 = d1.batch(12)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))


def test_labels_are_shifted_tokens():
    cfg = get_arch("qwen2.5-3b").reduced()
    shape = ShapeConfig("t", 16, 2, "train")
    b = SyntheticDataset(cfg, shape, seed=1).batch(0)
    t = np.asarray(b["tokens"])
    l = np.asarray(b["labels"])
    np.testing.assert_array_equal(l[:, :-1], t[:, 1:])


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_tokens_in_vocab(step):
    cfg = get_arch("granite-moe-1b-a400m").reduced()
    shape = ShapeConfig("t", 8, 2, "train")
    b = SyntheticDataset(cfg, shape, seed=0).batch(step)
    t = np.asarray(b["tokens"])
    assert t.min() >= 0 and t.max() < cfg.vocab_size
