"""Guard plane (ISSUE 9): watchdog/failover/quarantine + checkpoints.

Pins the guard-plane invariants that don't need a real SIGKILL (those
live in ``test_guard_resume.py`` / ``test_serve_signals.py``):

* guarded execution is a no-op on the clean path — a guarded
  ``sweep_fleet`` is bit-identical to a plain one and records zero
  escalations;
* campaign checkpoints resume bit-identically (in-process: truncate
  the snapshot ledger and re-run) and a finished run short-circuits
  to its stored final report;
* a checkpoint directory refuses a different campaign (named
  ``ValueError`` from the RunManifest);
* NaN/Inf cells are quarantined, re-evaluated per-cell on the numpy
  oracle, and patched record-for-record to ≤1e-9, with one named
  quarantine event per poisoned cell;
* a wedged backend trips the deadline watchdog and walks the failover
  ladder jax-mesh → jax → numpy in order, with the deterministic
  seeded backoff schedule;
* exhausting the ladder raises a named ``GuardError``.
"""
import dataclasses
import json

import numpy as np
import pytest

from repro.core.fleet import (ArrivalSpec, FleetReport, FleetScenario,
                              WorkloadClass, sweep_chaos, sweep_fleet)
from repro.core.guard import (CampaignCheckpoint, GuardError,
                              GuardPolicy, GuardReport, GuardedRunner,
                              RunManifest, _GUARD_PLANE, digest_of)
from repro.core.opgen import llm_workload
from repro.core.policies import KnobGrid, PolicyKnobs, evaluate_batch
from repro.core.session import SweepSession

RTOL = 1e-9

GRID = KnobGrid(window_scale=(0.5, 1.0))

WL = llm_workload("llama3-8b", "decode", batch=8, n_chips=8, tp=8)


def _scenario(seed=11, **kw):
    base = dict(
        classes=(WorkloadClass(
            "decode", WL,
            ArrivalSpec("diurnal", rate_rps=12.0, period_s=1800.0),
            requests_per_invocation=8),),
        n_chips=16, npu="NPU-D", policies=("NoPG", "ReGate-Full"),
        duration_s=1800.0, epoch_s=600.0, seed=seed,
        severity_levels=(0.0, 1.0))
    base.update(kw)
    return FleetScenario(**base)


def _core(report: FleetReport) -> str:
    """The result payload (everything except guard bookkeeping),
    canonically serialized for bit-identity comparison."""
    d = report.to_dict()
    d.pop("guard")
    return json.dumps(d, sort_keys=True)


# --------------------------------------------------------------------------
# clean path: the guard never changes what is computed
# --------------------------------------------------------------------------

def test_guarded_fleet_matches_plain():
    sc = _scenario()
    plain = sweep_fleet(sc, GRID)
    guarded = sweep_fleet(sc, GRID, guard=GuardPolicy(timeout_s=300.0))
    assert _core(plain) == _core(guarded)
    assert plain.guard is None
    assert guarded.guard is not None and guarded.guard["events"] == []


def test_session_scopes_guard():
    sc = _scenario()
    with SweepSession(guard=GuardPolicy(timeout_s=300.0)):
        rep = sweep_fleet(sc, GRID)
    assert rep.guard is not None and rep.guard["events"] == []
    assert sweep_fleet(sc, GRID).guard is None   # scope ended


# --------------------------------------------------------------------------
# campaign checkpoints: resume + short-circuit + identity pinning
# --------------------------------------------------------------------------

def test_checkpoint_roundtrip_and_resume(tmp_path):
    sc = _scenario()
    ref = sweep_fleet(sc, GRID, guard=GuardPolicy())  # no checkpoint

    full = sweep_fleet(sc, GRID, checkpoint=str(tmp_path / "a"))
    assert _core(full) == _core(ref)

    # finished run: a re-invocation short-circuits to final.json
    again = sweep_fleet(sc, GRID, checkpoint=str(tmp_path / "a"))
    assert json.dumps(again.to_dict(), sort_keys=True) \
        == json.dumps(full.to_dict(), sort_keys=True)

    # partial run: drop final.json + the newest snapshot, resume from
    # the surviving one — the replay must be bit-identical
    ckdir = tmp_path / "b"
    sweep_fleet(sc, GRID, checkpoint=str(ckdir))
    epochs = sorted(int(p.stem.split("_")[1])
                    for p in ckdir.glob("epoch_*.json"))
    assert len(epochs) == 2   # keep=2 retention
    (ckdir / "final.json").unlink()
    (ckdir / f"epoch_{epochs[-1]}.json").unlink()
    resumed = sweep_fleet(sc, GRID, checkpoint=str(ckdir))
    assert _core(resumed) == _core(ref)


def test_checkpoint_refuses_different_campaign(tmp_path):
    ckdir = str(tmp_path / "ck")
    sweep_fleet(_scenario(seed=11), GRID, checkpoint=ckdir)
    with pytest.raises(ValueError,
                       match="manifest mismatch on seed"):
        sweep_fleet(_scenario(seed=12), GRID, checkpoint=ckdir)
    with pytest.raises(ValueError,
                       match="manifest mismatch on knob_digest"):
        sweep_fleet(_scenario(seed=11),
                    KnobGrid(window_scale=(0.5, 2.0)),
                    checkpoint=ckdir)


def test_chaos_checkpoint_matches_plain(tmp_path):
    sc = _scenario()
    ref = sweep_chaos(sc, GRID, fault_severities=(0.0, 1.0),
                      thrash_baseline=False)
    out = sweep_chaos(sc, GRID, fault_severities=(0.0, 1.0),
                      thrash_baseline=False,
                      checkpoint=str(tmp_path / "c"))
    assert json.dumps(out["summary"], sort_keys=True) \
        == json.dumps(ref["summary"], sort_keys=True)
    for sev in (0.0, 1.0):
        assert _core(out["reports"][sev]) == _core(ref["reports"][sev])


def test_manifest_named_mismatch():
    kw = dict(kind="fleet", seed=1, n_epochs=3, backend="numpy",
              knob_digest="k", scenario_digest="s")
    a = RunManifest(**kw)
    b = RunManifest(**{**kw, "backend": "jax"})
    with pytest.raises(ValueError, match="mismatch on backend"):
        a.check(b)
    a.check(RunManifest(**kw))   # identical manifests pass


def test_digest_of_is_stable_and_sensitive():
    g = KnobGrid(window_scale=(0.5, 1.0))
    assert digest_of(g) == digest_of(KnobGrid(window_scale=(0.5, 1.0)))
    assert digest_of(g) != digest_of(KnobGrid(window_scale=(0.5, 2.0)))
    assert digest_of(np.arange(3)) != digest_of(np.arange(3.0))


# --------------------------------------------------------------------------
# quarantine: poisoned cells, oracle re-evaluation
# --------------------------------------------------------------------------

NPUS = ("NPU-D",)
POLS = ("NoPG", "ReGate-Full")
KNOBS = (PolicyKnobs(), PolicyKnobs(window_scale=2.0))


def _poisoning_runner(rung, workloads, npus, policies, knobs, *,
                      jax_mesh=None):
    """A backend whose cube comes back with a NaN and an Inf cell."""
    res = evaluate_batch(workloads, npus, policies, knobs,
                         backend="numpy")
    rt = res.runtime_s.copy()
    rt[0, 0, 0, 0] = np.nan
    sj = {c: a.copy() for c, a in res.static_j.items()}
    sj["sa"][-1, 0, -1, -1] = np.inf
    return dataclasses.replace(res, runtime_s=rt, static_j=sj)


def test_quarantine_patches_to_oracle():
    wls = [WL, llm_workload("llama3-8b", "prefill", batch=4, n_chips=8,
                            tp=8)]
    runner = GuardedRunner(GuardPolicy(), rungs=[("jax", None)],
                           runner=_poisoning_runner, seed=5)
    got = runner.evaluate_batch(wls, NPUS, POLS, KNOBS, step=3)
    ref = evaluate_batch(wls, NPUS, POLS, KNOBS, backend="numpy")

    # patched record-for-record to the oracle, ≤1e-9 everywhere
    for (name, a), (_, b) in zip(
            _fields(got), _fields(ref)):
        assert np.isfinite(a).all(), name
        err = np.abs(a - b) / np.maximum(np.abs(b), 1e-300)
        assert float(err.max()) <= RTOL, name

    evs = runner.report.events
    q = [e for e in evs if e["kind"] == "quarantine"]
    assert runner.report.quarantined_cells == len(q) == 2
    assert sorted(e["cell"] for e in q) == [[0, 0, 0, 0], [1, 0, 1, 1]]
    assert q[0]["fields"] == ["runtime_s"] and q[0]["step"] == 3
    assert "non-finite runtime_s" in q[0]["reason"]
    assert "numpy oracle" in q[0]["reason"]
    assert q[1]["fields"] == ["static_j[sa]"]
    assert [e["kind"] for e in evs][-1] == "oracle_recheck"
    assert evs[-1]["n_quarantined"] == 2


def _fields(res):
    from repro.core.guard import _result_fields
    return _result_fields(res)


def test_quarantine_rejects_poisoned_oracle():
    def bad_oracle(workloads, npus, policies, knobs):
        return _poisoning_runner("x", workloads, npus, policies, knobs)

    runner = GuardedRunner(GuardPolicy(), rungs=[("jax", None)],
                           runner=_poisoning_runner, oracle=bad_oracle)
    with pytest.raises(GuardError, match="the model, not the backend"):
        runner.evaluate_batch([WL], NPUS, POLS, KNOBS)


def test_quarantine_rejects_untrustworthy_survivors():
    def skewed(rung, workloads, npus, policies, knobs, *, jax_mesh=None):
        res = _poisoning_runner(rung, workloads, npus, policies, knobs)
        return dataclasses.replace(res, runtime_s=res.runtime_s * 1.5)

    runner = GuardedRunner(GuardPolicy(), rungs=[("jax", None)],
                           runner=skewed)
    with pytest.raises(GuardError, match="beyond 1e-09"):
        runner.evaluate_batch([WL], NPUS, POLS, KNOBS)


# --------------------------------------------------------------------------
# watchdog + failover ladder + deterministic backoff
# --------------------------------------------------------------------------

def test_watchdog_walks_the_ladder():
    import time as _time
    calls = []

    def slow(rung, workloads, npus, policies, knobs, *, jax_mesh=None):
        calls.append(rung)
        if rung != "numpy":
            _time.sleep(10.0)   # wedged; abandoned by the watchdog
        return evaluate_batch(workloads, npus, policies, knobs,
                              backend="numpy")

    pol = GuardPolicy(timeout_s=0.05, max_retries=1,
                      backoff_base_s=0.001, backoff_factor=2.0,
                      backoff_jitter=0.1)
    runner = GuardedRunner(
        pol, rungs=[("jax-mesh", "MESH"), ("jax", None),
                    ("numpy", None)],
        runner=slow, seed=7)
    got = runner.evaluate_batch([WL], NPUS, POLS, KNOBS, step=2)
    ref = evaluate_batch([WL], NPUS, POLS, KNOBS, backend="numpy")
    assert float(np.max(np.abs(got.runtime_s - ref.runtime_s))) == 0.0

    assert calls == ["jax-mesh", "jax-mesh", "jax", "jax", "numpy"]
    kinds = [e["kind"] for e in runner.report.events]
    assert kinds == ["retry", "failover", "retry", "failover"]
    fo = [e for e in runner.report.events if e["kind"] == "failover"]
    assert (fo[0]["rung"], fo[0]["next_rung"]) == ("jax-mesh", "jax")
    assert (fo[1]["rung"], fo[1]["next_rung"]) == ("jax", "numpy")
    assert all("timeout" in e["reason"] for e in runner.report.events
               if e["kind"] == "retry")
    assert "exhausted after 2 attempts" in fo[0]["reason"]

    # the backoff schedule is the seeded guard stream, exactly
    rng = np.random.default_rng((7, _GUARD_PLANE, 2))
    expect = [pol.backoff_delay(0, rng), pol.backoff_delay(0, rng)]
    got_delays = [e["delay_s"] for e in runner.report.events
                  if e["kind"] == "retry"]
    assert got_delays == expect
    assert all(pol.backoff_base_s <= d
               <= pol.backoff_base_s * (1 + pol.backoff_jitter)
               for d in got_delays)


def test_ladder_exhaustion_raises_named_guard_error():
    def broken(rung, workloads, npus, policies, knobs, *, jax_mesh=None):
        raise RuntimeError("device lost")

    runner = GuardedRunner(
        GuardPolicy(max_retries=0, backoff_base_s=0.001),
        rungs=[("jax", None), ("numpy", None)], runner=broken)
    with pytest.raises(GuardError,
                       match="all 2 backend rungs exhausted"):
        runner.evaluate_batch([WL], NPUS, POLS, KNOBS, step=1)
    assert [e["kind"] for e in runner.report.events] == ["failover"]
    assert "device lost" in runner.report.events[0]["reason"]


def test_retry_recovers_without_failover():
    state = {"n": 0}

    def flaky(rung, workloads, npus, policies, knobs, *, jax_mesh=None):
        state["n"] += 1
        if state["n"] == 1:
            raise RuntimeError("transient")
        return evaluate_batch(workloads, npus, policies, knobs,
                              backend="numpy")

    runner = GuardedRunner(
        GuardPolicy(max_retries=2, backoff_base_s=0.001),
        rungs=[("jax", None), ("numpy", None)], runner=flaky)
    runner.evaluate_batch([WL], NPUS, POLS, KNOBS)
    assert runner.report.retries == 1
    assert runner.report.failovers == 0


# --------------------------------------------------------------------------
# report + checkpoint plumbing
# --------------------------------------------------------------------------

def test_guard_report_roundtrip():
    r = GuardReport()
    r.add("retry", "timeout: deadline 0.05s exceeded", step=1,
          delay_s=0.0011)
    r.add("quarantine", "non-finite runtime_s", cell=[0, 0, 0, 0])
    d = r.to_dict()
    assert d["retries"] == 1 and d["quarantined_cells"] == 1
    back = GuardReport.from_dict(json.loads(json.dumps(d)))
    assert back.events == r.events


def test_campaign_checkpoint_gc_and_async_wait(tmp_path):
    m = RunManifest(kind="fleet", seed=0, n_epochs=10, backend="numpy",
                    knob_digest="k", scenario_digest="s")
    ck = CampaignCheckpoint(tmp_path / "ck", m, keep=2)
    for e in range(5):
        ck.save_epoch(e, {"epoch": e, "payload": [e] * 3})
    ck.wait()
    assert ck.epochs() == [3, 4]
    assert ck.load_epoch() == {"epoch": 4, "payload": [4, 4, 4]}
    assert ck.load_final() is None
    ck.save_final({"done": True})
    assert ck.load_final() == {"done": True}
    # a second handle over the same directory accepts the manifest
    CampaignCheckpoint(tmp_path / "ck", m, keep=2)
