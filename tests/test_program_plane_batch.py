"""Batched program plane (ISSUE 10): the array kernel is the executor.

Three layers of guarantees:

1. Kernel-vs-``EventTimeline`` EXACT equality (integers, not
   tolerances) on randomized seeded event programs and the edge cases
   the vectorization could plausibly get wrong — empty programs,
   single-bundle programs, setpm at cycle 0, same-cycle setpm
   collisions (the ``build_events`` merge/slip path).
2. ``sweep_program_plane`` (batched, numpy AND jax backends) vs the
   per-cell oracle ``sweep_program_plane_reference``
   record-for-record over a knob grid: executor-side fields exactly,
   everything <=1e-9 relative.
3. Program-plane records are first-class sweep records: every
   ``KnobGrid`` column unconditionally, accepted by
   ``with_savings`` / ``group_by`` (the PR-7 contract).
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core.backend import get_backend
from repro.core.hw import get_npu
from repro.core.isa import (EventTimeline, Instr, PMode, events_to_arrays,
                            setpm)
from repro.core.lowering import (REGATE_FULL_TIMELINE, build_events,
                                 instrument_program, lower_workload)
from repro.core.opgen import paper_suite
from repro.core.passes import SetpmPlacement
from repro.core.policies import KnobGrid, PolicyKnobs
from repro.core.program_plane import (_KEYS, UNITS, ProgramArrays,
                                      _pack_dense, _run_kernel,
                                      knob_pairs, program_plane_batch)
from repro.core.isa import scaled_delay, scaled_window
from repro.core.sweep import (group_by, sweep_program_plane,
                              sweep_program_plane_reference, with_savings)

NPU = get_npu("NPU-D")
_KINDS = ("sa", "vu", "hbm", "ici")


def _pa_from_events(rows: list[list], horizons: list[int]) -> ProgramArrays:
    arrs = [events_to_arrays(ev, UNITS) for ev in rows]
    lengths = np.array([len(a["cycle"]) for a in arrs], np.int64)
    offsets = np.concatenate(([0], np.cumsum(lengths))).astype(np.int64)
    u = len(UNITS)

    def cat(key, shape, dtype):
        if offsets[-1] == 0:
            return np.zeros(shape, dtype)
        return np.concatenate([a[key] for a in arrs])

    return ProgramArrays(
        units=UNITS, cycle=cat("cycle", (0,), np.int64),
        lat=cat("lat", (0, u), np.int64), pm=cat("pm", (0, u), np.int8),
        offsets=offsets, horizon=np.asarray(horizons, np.int64),
        setpm_vu=np.zeros(len(rows)))


def _kernel_rows(rows, horizons, scales, backend="numpy"):
    """Run each (events, horizon, (dscale, wscale)) row through the
    batched kernel; returns host outputs per row."""
    pa = _pa_from_events(rows, horizons)
    g = NPU.gating
    delay = np.array([[scaled_delay(g, k, d) for k in _KEYS]
                      for d, _ in scales], np.int64)
    window = np.array([[scaled_window(g, k, d, w) for k in _KEYS]
                       for d, w in scales], np.int64)
    data = _pack_dense(pa, np.arange(len(rows)), window, delay,
                       np.asarray(horizons, np.int64))
    return _run_kernel(data, get_backend(backend))


def _timeline_rows(rows, horizons, scales):
    """The oracle: one EventTimeline run per row, same machine."""
    outs = []
    for ev, hz, (d, w) in zip(rows, horizons, scales):
        tl = EventTimeline(npu=NPU, delay_scale=d, window_scale=w,
                           **REGATE_FULL_TIMELINE)
        outs.append(tl.run(ev, horizon=hz))
    return outs


def _assert_rows_equal(out, refs):
    for r, res in enumerate(refs):
        assert int(out["cycles"][r]) == res.cycles
        assert int(out["stall_cycles"][r]) == res.stall_cycles
        assert int(out["setpm_executed"][r]) == res.setpm_executed
        for ui, unit in enumerate(UNITS):
            assert int(out["on"][r, ui]) == res.fu_on_cycles[unit], \
                (r, unit)
            assert int(out["gated"][r, ui]) == res.fu_gated_cycles[unit], \
                (r, unit)
            assert int(out["wakes"][r, ui]) == res.wake_events[unit], \
                (r, unit)


def _random_events(rng, n_events: int, horizon: int) -> list:
    cycles = np.sort(rng.choice(horizon, size=n_events, replace=False))
    events = []
    for c in cycles:
        bundle = {}
        for u in UNITS:
            if rng.random() < 0.4:
                bundle[u] = Instr("op", u, int(rng.integers(1, 80)))
        if rng.random() < 0.35:
            kind = _KINDS[int(rng.integers(0, len(_KINDS)))]
            mode = (PMode.ON, PMode.OFF, PMode.AUTO)[
                int(rng.integers(0, 3))]
            bundle["misc"] = setpm(kind, 1, mode)
        if not bundle:
            bundle[UNITS[0]] = Instr("op", UNITS[0], 1)
        events.append((int(c), bundle))
    return events


def test_randomized_programs_match_event_timeline_exactly():
    rng = np.random.default_rng(10)
    rows, horizons, scales = [], [], []
    scale_pool = [(1.0, 1.0), (0.25, 1.0), (4.0, 1.0), (1.0, 0.25),
                  (1.0, 4.0), (2.0, 0.5)]
    for i in range(24):
        horizon = int(rng.integers(200, 4000))
        n = int(rng.integers(1, min(120, horizon)))
        rows.append(_random_events(rng, n, horizon))
        horizons.append(horizon)
        scales.append(scale_pool[i % len(scale_pool)])
    out = _kernel_rows(rows, horizons, scales)
    _assert_rows_equal(out, _timeline_rows(rows, horizons, scales))


def test_empty_and_single_bundle_programs():
    rows = [
        [],                                           # empty, horizon>0
        [(0, {UNITS[0]: Instr("op", UNITS[0], 5)})],  # single, cycle 0
        [(499, {UNITS[3]: Instr("op", UNITS[3], 7)})],  # single, at end
        [],                                           # empty, horizon 0
    ]
    horizons = [700, 500, 500, 0]
    scales = [(1.0, 1.0)] * len(rows)
    out = _kernel_rows(rows, horizons, scales)
    refs = _timeline_rows(rows, horizons, scales)
    _assert_rows_equal(out, refs)
    # the empty row still drains the full horizon: vu0 starts ON (sw
    # managed, never auto-gates), the AUTO units gate after the window
    assert int(out["cycles"][0]) == 700
    assert int(out["on"][0, UNITS.index("vu0")]) == 700


def test_setpm_at_cycle_zero():
    rows = [
        # OFF at cycle 0 for the initially-powered sw-managed VU
        [(0, {"misc": setpm("vu", 1, PMode.OFF)}),
         (50, {"vu0": Instr("op", "vu0", 10)})],     # dispatch-wake
        # ON at cycle 0 for an already-powered unit (mode flip only)
        [(0, {"misc": setpm("sa", 1, PMode.ON)}),
         (600, {"sa0": Instr("op", "sa0", 3)})],
        # AUTO at cycle 0 re-arms the sw-managed VU's idle detection
        [(0, {"misc": setpm("vu", 1, PMode.AUTO)})],
    ]
    horizons = [900, 900, 900]
    scales = [(1.0, 1.0)] * 3
    out = _kernel_rows(rows, horizons, scales)
    _assert_rows_equal(out, _timeline_rows(rows, horizons, scales))
    # row 0: the dispatch at 50 must have auto-woken the OFF VU
    assert int(out["wakes"][0, UNITS.index("vu0")]) == 1
    # row 2: re-armed AUTO detection gates the idle VU eventually
    assert int(out["gated"][2, UNITS.index("vu0")]) > 0


def test_same_cycle_setpm_collisions_merge_and_slip():
    """Colliding placements ride ``build_events``: same (fu_type, mode)
    merges bitmaps; a true collision slips one cycle — the batched
    kernel must agree with the executor on the merged program."""
    prog = lower_workload(paper_suite()[0], NPU)
    base = instrument_program(prog)
    # duplicate an existing placement (merge path) and add a
    # conflicting opposite-mode setpm at the same cycle (slip path)
    c = base[0].cycle
    extra = [
        SetpmPlacement(c, base[0].instr, "dup (merge)"),
        SetpmPlacement(c, setpm("vu", 1,
                                PMode.ON if base[0].instr.pm_mode
                                == PMode.OFF else PMode.OFF), "slip"),
    ]
    events = build_events(prog, list(base) + extra)
    cycles = [cc for cc, _ in events]
    assert len(cycles) == len(set(cycles))  # still a valid program
    rows, horizons, scales = [events], [prog.horizon], [(1.0, 1.0)]
    out = _kernel_rows(rows, horizons, scales)
    _assert_rows_equal(out, _timeline_rows(rows, horizons, scales))


def test_kernel_jax_matches_numpy_exactly():
    pytest.importorskip("jax")
    rng = np.random.default_rng(77)
    rows, horizons, scales = [], [], []
    for i in range(6):
        horizon = int(rng.integers(300, 2500))
        rows.append(_random_events(
            rng, int(rng.integers(1, 90)), horizon))
        horizons.append(horizon)
        scales.append((float(2.0 ** (i % 3 - 1)), 1.0))
    rows.append([])
    horizons.append(1234)
    scales.append((1.0, 1.0))
    a = _kernel_rows(rows, horizons, scales, backend="numpy")
    b = _kernel_rows(rows, horizons, scales, backend="jax")
    for k in a:
        np.testing.assert_array_equal(a[k], b[k], err_msg=k)


GRID = KnobGrid(delay_scale=(1.0, 4.0), window_scale=(1.0, 0.5))


def _compare_records(got, ref, tol=1e-9):
    assert len(got) == len(ref)
    for x, y in zip(ref, got):
        assert set(x) == set(y)
        for k in x:
            a, b = x[k], y[k]
            if a is None or isinstance(a, str):
                assert a == b, (k, a, b)
            elif k.startswith(("prog_", "n_events", "stall_",
                               "wakes_prog", "setpm_prog")):
                assert float(a) == float(b), (k, a, b)  # executor: exact
            else:
                assert abs(float(a) - float(b)) \
                    <= tol * max(1.0, abs(float(a))), (k, a, b)


def test_sweep_matches_reference_over_knob_grid():
    wls = paper_suite()[:4]
    npus = ("NPU-B", "NPU-D")
    got = sweep_program_plane(wls, npus=npus, knob_grid=GRID,
                              backend="numpy")
    ref = sweep_program_plane_reference(wls, npus=npus, knob_grid=GRID)
    assert len(got) == len(wls) * len(npus) * len(tuple(GRID.product()))
    _compare_records(got, ref)


def test_sweep_jax_backend_matches_reference():
    pytest.importorskip("jax")
    wls = paper_suite()[:3]
    got = sweep_program_plane(wls, npus=("NPU-D",), knob_grid=GRID,
                              backend="jax")
    ref = sweep_program_plane_reference(wls, npus=("NPU-D",),
                                        knob_grid=GRID)
    _compare_records(got, ref)


def test_default_call_is_backward_compatible():
    wls = paper_suite()[:2]
    got = sweep_program_plane(wls, npus=("NPU-D",))
    ref = sweep_program_plane_reference(wls, npus=("NPU-D",))
    assert len(got) == 2
    _compare_records(got, ref)


def test_records_are_first_class_sweep_records():
    """Satellite 2: every KnobGrid column unconditionally; with_savings
    and group_by accept program-plane records without special-casing."""
    recs = sweep_program_plane(paper_suite()[:2], npus=("NPU-D",),
                               knob_grid=GRID, backend="numpy")
    need = ("knob_idx",) + KnobGrid.columns()
    for r in recs:
        for k in need:
            assert k in r, k
    # with_savings: no NoPG baseline rows exist on this plane, so every
    # record resolves to savings=None — but the call must not raise
    out = with_savings(recs)
    assert all(r["savings"] is None for r in out)
    # group_by on knob columns partitions the table
    groups = group_by(recs, "npu", "delay_scale", "window_scale")
    assert len(groups) == len(tuple(GRID.product()))
    assert sum(len(v) for v in groups.values()) == len(recs)


def test_knob_pairs_dedup():
    grid = tuple(KnobGrid(delay_scale=(1.0, 2.0),
                          leak_off_logic=(None, 0.1, 0.5)).product())
    trips, inv = knob_pairs(grid)
    assert len(trips) == 2          # leak axes collapse
    assert len(inv) == len(grid)
    for i, k in enumerate(grid):
        assert trips[inv[i]][1] == k.delay_scale


def test_setpm_counts_exact_and_fractions_bounded():
    """ISSUE acceptance: setpm counts exact, gated fractions sane, on a
    >=4-point knob grid through the batched kernel."""
    recs = sweep_program_plane(paper_suite()[:3], npus=("NPU-D",),
                               knob_grid=GRID, backend="numpy")
    ref = sweep_program_plane_reference(paper_suite()[:3],
                                        npus=("NPU-D",), knob_grid=GRID)
    for r, x in zip(recs, ref):
        for c in ("vu", "sram"):
            assert r[f"setpm_prog_{c}"] == x[f"setpm_prog_{c}"]
        for c in ("sa", "vu", "hbm", "ici", "sram"):
            assert 0.0 <= r[f"gated_frac_prog_{c}"] <= 1.0
            assert abs(r[f"gated_frac_prog_{c}"]
                       - x[f"gated_frac_prog_{c}"]) <= 1e-9
