"""Event-driven executor vs the cycle-stepper reference.

The contract (ISSUE 2): EXACT equality on the microbenchmarks (Fig 15
programs, randomized sparse programs) and <=1e-9 relative on sampled
workload-scale programs — the ExecResult counters are integers, so the
workload check is exact equality too.
"""
import numpy as np
import pytest

from repro.core.isa import (EventTimeline, Instr, PMode, VLIWTimeline,
                            expand_events, fig15_program, merge_events,
                            setpm, unit_index)
from repro.core.lowering import (REGATE_FULL_TIMELINE, build_events,
                                 instrument_program, lower_workload,
                                 rescale_program)
from repro.core.opgen import paper_suite

REL = 1e-9


def _as_events(bundles):
    return [(i, b) for i, b in enumerate(bundles) if b]


def _assert_equal(a, b, ctx=""):
    assert a.cycles == b.cycles, (ctx, "cycles", a.cycles, b.cycles)
    assert a.stall_cycles == b.stall_cycles, (ctx, "stalls")
    assert a.setpm_executed == b.setpm_executed, (ctx, "setpm")
    assert a.fu_on_cycles == b.fu_on_cycles, (ctx, "on")
    assert a.fu_gated_cycles == b.fu_gated_cycles, (ctx, "gated")
    assert a.wake_events == b.wake_events, (ctx, "wakes")
    # the stated workload-program bound (trivially implied by equality)
    for k in a.fu_on_cycles:
        num = abs(a.fu_on_cycles[k] - b.fu_on_cycles[k])
        den = max(1, a.fu_on_cycles[k], b.fu_on_cycles[k])
        assert num / den <= REL


@pytest.mark.parametrize("hw_auto", [False, True])
@pytest.mark.parametrize("with_setpm", [False, True])
def test_fig15_exact_equality(hw_auto, with_setpm):
    prog = fig15_program(6, with_setpm=with_setpm)
    ref = VLIWTimeline(n_sa=2, n_vu=2, hw_auto_gating=hw_auto).run(prog)
    ev = EventTimeline(n_sa=2, n_vu=2, hw_auto_gating=hw_auto).run(
        _as_events(prog), horizon=len(prog))
    _assert_equal(ref, ev, f"fig15 auto={hw_auto} setpm={with_setpm}")


@pytest.mark.parametrize("seed", [0, 7, 2024])
def test_randomized_sparse_exact_equality(seed):
    """Random sparse programs: gaps, multi-cycle latencies, overlapping
    same-unit uses (stalls), setpm on every FU family, mixed initial
    modes, with and without hardware auto-gating. Seeded through an
    explicit ``numpy.random.Generator`` (the repo-wide determinism
    contract) and parametrized so no single stream hides a bug."""
    rng = np.random.default_rng(seed)
    for trial in range(10):
        events = []
        c = 0
        for _ in range(40):
            c += int(rng.choice([1, 2, 3, 7, 15, 40, 200, 900]))
            b = {}
            if rng.random() < 0.3:
                b["misc"] = setpm(
                    ("vu", "sa", "hbm", "ici")[int(rng.integers(4))],
                    int(rng.integers(1, 4)),
                    (PMode.ON, PMode.OFF)[int(rng.integers(2))])
            for u in ("sa0", "vu0", "vu1", "dma0", "ici0"):
                if rng.random() < 0.4:
                    b[u] = Instr("op", u,
                                 int(rng.choice([1, 2, 5, 30, 100])))
            if b:
                events.append((c, b))
        horizon = c + int(rng.choice([0, 5, 500]))
        for hw_auto in (False, True):
            kw = dict(n_sa=1, n_vu=2, hw_auto_gating=hw_auto,
                      extra_units={"dma0": "hbm", "ici0": "ici"},
                      delay_keys={"sa": "sa_pe"},
                      initial_modes={"vu1": PMode.ON})
            ref = VLIWTimeline(**kw).run(expand_events(events, horizon))
            ev = EventTimeline(**kw).run(events, horizon)
            _assert_equal(ref, ev, f"trial={trial} auto={hw_auto}")


@pytest.mark.parametrize("wl_idx", [0, 8, 15])  # train, decode, diffusion
def test_sampled_workload_program_equality(wl_idx):
    """Lowered + instrumented suite programs, schedule-compressed so the
    dense reference stays steppable, must agree exactly."""
    wl = paper_suite()[wl_idx]
    prog = rescale_program(lower_workload(wl, "NPU-D"), 200_000)
    events = build_events(prog, instrument_program(prog))
    kw = dict(npu="NPU-D", **REGATE_FULL_TIMELINE)
    ref = VLIWTimeline(**kw).run(expand_events(events, prog.horizon))
    ev = EventTimeline(**kw).run(events, horizon=prog.horizon)
    _assert_equal(ref, ev, wl.name)
    assert len(events) > 50  # really a workload-scale program


def test_event_executor_rejects_unsorted():
    tl = EventTimeline(n_sa=1, n_vu=1)
    ev = [(5, {"sa0": Instr("op", "sa0", 1)}),
          (5, {"vu0": Instr("op", "vu0", 1)})]
    with pytest.raises(ValueError):
        tl.run(ev)


def test_same_cycle_duplicates_merge_canonically():
    """Raw colliding event streams (the perturbation fuzzer's output
    shape) are rejected by the executor but canonicalized by
    ``merge_events`` with later-write-wins VLIW slot semantics."""
    late_vu = Instr("op", "vu0", 7)
    late_pm = setpm("vu", 1, PMode.ON)
    raw = [(5, {"vu0": Instr("op", "vu0", 3)}),
           (2, {"sa0": Instr("op", "sa0", 1)}),
           (5, {"vu0": late_vu, "misc": setpm("vu", 1, PMode.OFF)}),
           (5, {"misc": late_pm})]
    with pytest.raises(ValueError):
        EventTimeline(n_sa=1, n_vu=1).run(
            sorted(raw, key=lambda e: e[0]))
    events = merge_events(raw)
    assert [c for c, _ in events] == [2, 5]
    assert events[1][1]["vu0"] is late_vu
    assert events[1][1]["misc"] is late_pm
    ref = VLIWTimeline(n_sa=1, n_vu=1).run(expand_events(events, 20))
    ev = EventTimeline(n_sa=1, n_vu=1).run(events, horizon=20)
    _assert_equal(ref, ev, "merged duplicates")


@pytest.mark.parametrize("unit,kind", [("sa0", "sa"), ("vu0", "vu"),
                                       ("dma0", "hbm"), ("ici0", "ici")])
def test_gap_exactly_at_window_per_unit(unit, kind):
    """Idle gap of exactly the detection window, one cycle under, and
    one over — for every FU family (sa uses the per-PE sa_pe delay
    key). The closed-form gap split must hit the stepper's boundary."""
    kw = dict(n_sa=1, n_vu=1, hw_auto_gating=True,
              extra_units={"dma0": "hbm", "ici0": "ici"},
              delay_keys={"sa": "sa_pe"})
    win = VLIWTimeline(**kw)._window(kind)
    for gap in (win - 1, win, win + 1):
        events = [(0, {unit: Instr("op", unit, 1)}),
                  (1 + gap, {unit: Instr("op", unit, 1)})]
        horizon = 2 + gap + 200
        ref = VLIWTimeline(**kw).run(expand_events(events, horizon))
        ev = EventTimeline(**kw).run(events, horizon=horizon)
        _assert_equal(ref, ev, f"{unit} gap={gap}")
        if gap >= win:
            assert ev.wake_events.get(unit, 0) >= 1, (unit, gap)


def test_setpm_during_exposed_wake():
    """A setpm lands while its unit is mid-wake (paying the exposed
    wake delay after hw auto-gating): both executors must resolve the
    race identically for every offset into the wake and every mode."""
    kw = dict(n_sa=1, n_vu=1, hw_auto_gating=True)
    tl = VLIWTimeline(**kw)
    win, delay = tl._window("vu"), tl._delay("vu")
    wake_start = 1 + win + 5
    base = [(0, {"vu0": Instr("op", "vu0", 1)}),
            (wake_start, {"vu0": Instr("op", "vu0", 1)})]
    for off in (0, 1, max(1, delay // 2), max(1, delay - 1), delay):
        for mode in (PMode.ON, PMode.OFF, PMode.AUTO):
            events = merge_events(base + [
                (wake_start + off, {"misc": setpm("vu", 1, mode)})])
            horizon = wake_start + delay + 50
            ref = VLIWTimeline(**kw).run(expand_events(events, horizon))
            ev = EventTimeline(**kw).run(events, horizon=horizon)
            _assert_equal(ref, ev, f"off={off} mode={mode}")


def test_window_straddling_bursts():
    """Back-to-back idle runs hovering around the window boundary
    (win-1, win, win+1, ...) — repeated gate/no-gate flips where an
    off-by-one in the closed-form idle split would accumulate."""
    kw = dict(n_sa=1, n_vu=2, hw_auto_gating=True)
    win = VLIWTimeline(**kw)._window("vu")
    events, c = [], 0
    for gap in (win - 1, win, win + 1, win - 1, win + 1, win):
        events.append((c, {"vu0": Instr("op", "vu0", 1),
                           "vu1": Instr("op", "vu1", 2)}))
        c += 1 + gap
    events.append((c, {"vu0": Instr("op", "vu0", 1)}))
    horizon = c + 100
    ref = VLIWTimeline(**kw).run(expand_events(events, horizon))
    ev = EventTimeline(**kw).run(events, horizon=horizon)
    _assert_equal(ref, ev, "window straddle")
    assert ev.wake_events.get("vu0", 0) >= 2


def test_event_gap_autogating_boundary():
    """A unit crosses its idle-detection window mid-gap: the closed-form
    gap split must match the stepper at the exact boundary cycle."""
    win = VLIWTimeline()._window("vu")
    for gap in (win - 1, win, win + 1, win + 37):
        events = [(0, {"vu0": Instr("op", "vu0", 1)}),
                  (1 + gap, {"vu0": Instr("op", "vu0", 1)})]
        ref = VLIWTimeline(n_sa=1, n_vu=1).run(
            expand_events(events, 2 + gap))
        ev = EventTimeline(n_sa=1, n_vu=1).run(events, horizon=2 + gap)
        _assert_equal(ref, ev, f"gap={gap}")


def test_rerun_does_not_accumulate_counters():
    """stall/setpm counters reset per run() (FU cycle accounting has
    always accumulated across runs; the counters must not). FU power
    state also carries over, so restore it between runs to isolate the
    counters."""
    prog = fig15_program(4, with_setpm=False)
    tl = VLIWTimeline(n_sa=2, n_vu=2, hw_auto_gating=True)
    first = tl.run(prog)
    assert first.stall_cycles > 0  # hw auto-gating exposes VU wakes
    for fu in tl.fus.values():
        fu.powered, fu.mode = True, PMode.AUTO
        fu.ready_at = fu.busy_until = fu.idle_since = 0
    second = tl.run(prog)
    assert second.setpm_executed == first.setpm_executed == 0
    assert second.stall_cycles == first.stall_cycles


def test_unit_index():
    assert unit_index("vu0") == 0
    assert unit_index("sa12") == 12
    assert unit_index("dma0") == 0
    assert unit_index("dma") == 0
    assert unit_index("ici") == 0
