import os
import sys

# Tests run on the single real CPU device (the dry-run fabricates its own
# 512 devices in a separate process). Keep compilation light.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# make _hypothesis_fallback importable regardless of pytest import mode
sys.path.insert(0, os.path.dirname(__file__))
