"""Property tests for the shared SLO re-tune operator rule
(``slo.retune_knobs``) — ISSUE 8 satellite.

The rule is the single governor both ``sweep.sweep_robustness`` and
``fleet.sweep_fleet`` delegate to, so its contract is pinned here
property-style (random energy/runtime tables) plus once per call site:

* idempotent while feasible — a deployed knob meeting its bound is
  never abandoned by the stateless rule, and the rule is a fixed point
  of itself;
* never selects an infeasible knob when a feasible one exists, and a
  violating row retunes to the cheapest feasible knob;
* deterministic tie-break — duplicated columns resolve to the lowest
  knob index, bit-stably across calls;
* the hysteresis governor agrees with the stateless target on forced
  switches, never moves during cooldown, and counts switches exactly.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # degrade: property tests skip, rest still run
    from _hypothesis_fallback import given, settings, st

from repro.core.slo import GovernorState, Hysteresis, retune_knobs


def _tables(seed, n, k):
    rng = np.random.default_rng(seed)
    energy = rng.uniform(0.5, 2.0, (n, k))
    runtime = rng.uniform(0.5, 2.0, (n, k))
    bound = rng.uniform(0.4, 2.2, (n, 1))
    return energy, runtime, bound


@settings(max_examples=60, deadline=None)
@given(st.integers(min_value=0, max_value=10_000),
       st.integers(min_value=1, max_value=12),
       st.integers(min_value=1, max_value=9))
def test_idempotent_when_deployed_feasible(seed, n, k):
    energy, runtime, bound = _tables(seed, n, k)
    rng = np.random.default_rng(seed + 1)
    deployed = rng.integers(0, k, n)
    chosen = retune_knobs(energy, runtime, bound, deployed=deployed)
    feas = runtime <= bound
    rows = np.arange(n)
    keep = feas[rows, deployed]
    assert (chosen[keep] == deployed[keep]).all()
    # and the rule is a fixed point: re-running on its own output
    # changes nothing (retuned rows landed on feasible or
    # least-violating knobs, both stable)
    again = retune_knobs(energy, runtime, bound, deployed=chosen)
    assert (again == chosen).all()


@settings(max_examples=60, deadline=None)
@given(st.integers(min_value=0, max_value=10_000),
       st.integers(min_value=1, max_value=12),
       st.integers(min_value=1, max_value=9))
def test_never_infeasible_when_feasible_exists(seed, n, k):
    energy, runtime, bound = _tables(seed, n, k)
    rng = np.random.default_rng(seed + 1)
    deployed = rng.integers(0, k, n)
    chosen = retune_knobs(energy, runtime, bound, deployed=deployed)
    feas = runtime <= bound
    rows = np.arange(n)
    has = feas.any(axis=1)
    assert feas[rows, chosen][has].all()
    # violating rows retune to the CHEAPEST feasible knob
    viol = has & ~feas[rows, deployed]
    cheapest = np.argmin(np.where(feas, energy, np.inf), axis=1)
    assert (chosen[viol] == cheapest[viol]).all()


@settings(max_examples=40, deadline=None)
@given(st.integers(min_value=0, max_value=10_000),
       st.integers(min_value=1, max_value=10),
       st.integers(min_value=1, max_value=5))
def test_deterministic_lowest_index_tie_break(seed, n, k):
    energy, runtime, bound = _tables(seed, n, k)
    # duplicate every column: ties everywhere between column j and j+k
    e2 = np.concatenate([energy, energy], axis=1)
    r2 = np.concatenate([runtime, runtime], axis=1)
    deployed = np.zeros(n, np.int64)
    a = retune_knobs(e2, r2, bound, deployed=deployed)
    b = retune_knobs(e2, r2, bound, deployed=deployed)
    assert (a == b).all()
    assert (a < k).all()          # the duplicate never wins a tie


@settings(max_examples=40, deadline=None)
@given(st.integers(min_value=0, max_value=10_000),
       st.integers(min_value=1, max_value=10),
       st.integers(min_value=2, max_value=6))
def test_hysteresis_forced_matches_stateless_target(seed, n, k):
    """With an elapsed cooldown, forced switches (deployed violating)
    land exactly where the stateless rule would; feasible rows either
    stay put or take an opportunistic switch worth >= min_improvement.
    The retune counter counts switches exactly."""
    energy, runtime, bound = _tables(seed, n, k)
    rng = np.random.default_rng(seed + 1)
    deployed = rng.integers(0, k, n)
    hys = Hysteresis()
    state = GovernorState.init(n, hys)   # since_retune starts huge
    got = retune_knobs(energy, runtime, bound, deployed=deployed,
                       hysteresis=hys, state=state)
    stateless = retune_knobs(energy, runtime, bound, deployed=deployed)
    feas = runtime <= bound
    rows = np.arange(n)
    need = ~feas[rows, deployed]
    assert (got[need] == stateless[need]).all()
    cheapest = np.argmin(np.where(feas, energy, np.inf), axis=1)
    moved = ~need & (got != deployed)
    assert (got[moved] == cheapest[moved]).all()
    assert (energy[rows, got][moved]
            <= (1.0 - hys.min_improvement)
            * energy[rows, deployed][moved]).all()
    assert (state.retunes == (got != deployed).astype(np.int64)).all()


def test_stateless_contract_deterministic_sweep():
    """Hypothesis-free re-statement of the three stateless properties
    over a fixed seed sweep, so the contract is exercised even where
    hypothesis is unavailable (the @given tests then skip)."""
    for seed in range(40):
        n, k = 1 + seed % 11, 1 + seed % 7
        energy, runtime, bound = _tables(seed, n, k)
        deployed = np.random.default_rng(seed + 1).integers(0, k, n)
        chosen = retune_knobs(energy, runtime, bound, deployed=deployed)
        feas = runtime <= bound
        rows = np.arange(n)
        keep = feas[rows, deployed]
        assert (chosen[keep] == deployed[keep]).all()
        has = feas.any(axis=1)
        assert feas[rows, chosen][has].all()
        cheapest = np.argmin(np.where(feas, energy, np.inf), axis=1)
        viol = has & ~keep
        assert (chosen[viol] == cheapest[viol]).all()
        assert (retune_knobs(energy, runtime, bound, deployed=chosen)
                == chosen).all()
        # tie-break: duplicated columns never beat the original
        e2 = np.concatenate([energy, energy], axis=1)
        r2 = np.concatenate([runtime, runtime], axis=1)
        dup = retune_knobs(e2, r2, bound,
                           deployed=np.zeros(n, np.int64))
        assert (dup < k).all()


def test_hysteresis_cooldown_blocks_switch():
    energy, runtime, bound = _tables(7, 6, 4)
    deployed = np.random.default_rng(8).integers(0, 4, 6)
    hys = Hysteresis(cooldown_epochs=2)
    state = GovernorState.init(6, hys)
    state.since_retune = np.zeros(6, np.int64)   # just retuned
    got = retune_knobs(energy, runtime, bound, deployed=deployed,
                       hysteresis=hys, state=state)
    assert (got == deployed).all()
    assert (state.retunes == 0).all()
    # two epochs later the cooldown has elapsed and switching resumes
    got2 = retune_knobs(energy, runtime, bound, deployed=deployed,
                        hysteresis=hys, state=state)
    assert (got2 == deployed).all()              # since_retune == 1
    got3 = retune_knobs(energy, runtime, bound, deployed=deployed,
                        hysteresis=hys, state=state)
    stateless = retune_knobs(energy, runtime, bound, deployed=deployed)
    feas = runtime <= bound
    need = ~feas[np.arange(6), deployed]
    assert (got3[need] == stateless[need]).all()


def test_hysteresis_requires_state_and_deployed():
    energy, runtime, bound = _tables(0, 4, 3)
    hys = Hysteresis()
    with pytest.raises(ValueError, match="deployed"):
        retune_knobs(energy, runtime, bound, hysteresis=hys,
                     state=GovernorState.init(4, hys))
    with pytest.raises(ValueError, match="GovernorState"):
        retune_knobs(energy, runtime, bound,
                     deployed=np.zeros(4, np.int64), hysteresis=hys)
    with pytest.raises(ValueError, match="rows"):
        retune_knobs(energy, runtime, bound,
                     deployed=np.zeros(4, np.int64), hysteresis=hys,
                     state=GovernorState.init(3, hys))


# --------------------------------------------------------------------------
# the rule holds at both call sites
# --------------------------------------------------------------------------

def test_rule_holds_in_sweep_robustness_records():
    """Reconstruct the feasible set from the records (perturbed runtime
    vs the same threshold's severity-0 runtime) and check the chosen
    threshold obeys the operator rule."""
    from repro.core.opgen import llm_workload
    from repro.core.sweep import sweep_robustness
    slo_relax = 1.1
    wl = llm_workload("llama2-13b", "decode", batch=8, n_chips=8, tp=8)
    out = sweep_robustness(
        [wl], npus=("NPU-D",), policies=("ReGate-Full",),
        severities=(0.0, 1.0, 2.0), threshold_scales=(0.25, 1.0, 2.0),
        seed=0, slo_relax=slo_relax)
    recs = out["records"]
    assert recs
    clean_rt = {(r["npu"], r["policy"], r["knob_idx"]): r["runtime_s"]
                for r in recs if r["severity"] == 0.0}
    cells: dict = {}
    for r in recs:
        cells.setdefault((r["npu"], r["policy"], r["severity"]),
                         []).append(r)
    for key, group in cells.items():
        npu, policy, _sev = key
        feas = {r["knob_idx"]: r["runtime_s"] <= slo_relax
                * clean_rt[(npu, policy, r["knob_idx"])] for r in group}
        chosen = [r for r in group if r["chosen"]]
        deployed = [r for r in group if r["deployed"]]
        assert len(chosen) == 1 and len(deployed) == 1
        if feas[deployed[0]["knob_idx"]]:
            # idempotence: feasible deployed knob is kept
            assert chosen[0]["knob_idx"] == deployed[0]["knob_idx"]
        elif any(feas.values()):
            # never infeasible when a feasible knob exists, and the
            # cheapest feasible one wins
            assert feas[chosen[0]["knob_idx"]]
            cheapest = min((r for r in group if feas[r["knob_idx"]]),
                           key=lambda r: (r["total_j"], r["knob_idx"]))
            assert chosen[0]["knob_idx"] == cheapest["knob_idx"]


def test_rule_holds_in_sweep_fleet_records():
    from repro.core.fleet import (ArrivalSpec, FleetScenario,
                                  WorkloadClass, sweep_fleet)
    from repro.core.opgen import llm_workload
    from repro.core.policies import PolicyKnobs
    wl = llm_workload("llama2-13b", "decode", batch=8, n_chips=8, tp=8)
    sc = FleetScenario(
        classes=(WorkloadClass(
            "d", wl,
            ArrivalSpec("bursty", rate_rps=30.0, burst_prob=0.3,
                        burst_factor=16.0),
            requests_per_invocation=8),),
        n_chips=16, npu="NPU-D", policies=("ReGate-Full",),
        duration_s=6 * 900.0, epoch_s=900.0, seed=1)
    rep = sweep_fleet(sc, (PolicyKnobs(),
                           PolicyKnobs(window_scale=2.0)))
    assert rep.records
    for r in rep.records:
        # stateless governor: a feasible set is never left violated
        if r["feasible_exists"]:
            assert not r["slo_violated"]
        # retuned flag is exactly "chosen != deployed"
        assert r["retuned"] == (r["knob_idx"] != r["deployed_knob_idx"])
