"""Input-validation hardening (jitter plane, ISSUE 6, satellite).

Malformed op streams and knob grids must fail loudly at the boundary —
``compile_trace`` / ``stack_traces`` / ``evaluate_batch`` — naming the
workload, op, and field, instead of silently corrupting service times
or flipping gating inequalities deep in the sweep kernels.
"""
import numpy as np
import pytest

from repro.core.opgen import (Op, Workload, compile_trace, llm_workload,
                              stack_traces)
from repro.core.policies import PolicyKnobs, evaluate_batch

GOOD = llm_workload("llama3-8b", "decode", batch=8, n_chips=8, tp=8,
                    dp=1)


def _wl(op, name="bad-wl"):
    return Workload(name, "decode", (Op("warmup", flops_vu=1e6), op))


@pytest.mark.parametrize("field,value,kind", [
    ("flops_sa", -1.0, "negative"),
    ("flops_vu", float("nan"), "non-finite"),
    ("bytes_hbm", float("inf"), "non-finite"),
    ("bytes_ici", -3.5, "negative"),
    ("count", -2, "negative"),
])
def test_compile_trace_rejects_bad_carriers(field, value, kind):
    wl = _wl(Op("evil", **{field: value}))
    with pytest.raises(ValueError) as e:
        compile_trace(wl)
    msg = str(e.value)
    assert "bad-wl" in msg and "evil" in msg
    assert field in msg and kind in msg


def test_compile_trace_rejects_zero_matmul_dims():
    wl = _wl(Op("mm", flops_sa=1e9, matmul_dims=(128, 0, 128)))
    with pytest.raises(ValueError, match="matmul_dims"):
        compile_trace(wl)


def test_stack_traces_rejects_non_workload():
    with pytest.raises(ValueError, match="index 1"):
        stack_traces([GOOD, {"not": "a workload"}])


def test_stack_traces_rejects_malformed_member():
    with pytest.raises(ValueError, match="bad-wl"):
        stack_traces([GOOD, _wl(Op("evil", bytes_hbm=-1.0))])


@pytest.mark.parametrize("knob,field", [
    (PolicyKnobs(delay_scale=0.0), "delay_scale"),
    (PolicyKnobs(delay_scale=float("nan")), "delay_scale"),
    (PolicyKnobs(window_scale=0.0), "window_scale"),
    (PolicyKnobs(window_scale=-1.0), "window_scale"),
    (PolicyKnobs(window_scale=float("nan")), "window_scale"),
    (PolicyKnobs(leak_off_logic=-0.1), "leak_off_logic"),
    (PolicyKnobs(leak_sram_sleep=float("inf")), "leak_sram_sleep"),
    (PolicyKnobs(sa_width=0), "sa_width"),
])
def test_evaluate_batch_rejects_bad_knobs(knob, field):
    grid = (PolicyKnobs(), knob)
    with pytest.raises(ValueError) as e:
        evaluate_batch([GOOD], ("NPU-D",), ("ReGate-HW",), grid)
    assert field in str(e.value)
    assert "knob 1" in str(e.value)


def test_good_grid_still_passes():
    res = evaluate_batch(
        [GOOD], ("NPU-D",), ("ReGate-HW",),
        (PolicyKnobs(window_scale=0.5, delay_scale=2.0, sa_width=64),),
        backend="numpy")
    assert np.isfinite(res.runtime_s).all()
