"""Input-validation hardening (jitter plane, ISSUE 6, satellite).

Malformed op streams and knob grids must fail loudly at the boundary —
``compile_trace`` / ``stack_traces`` / ``evaluate_batch`` — naming the
workload, op, and field, instead of silently corrupting service times
or flipping gating inequalities deep in the sweep kernels.
"""
import numpy as np
import pytest

from repro.core.opgen import (Op, Workload, compile_trace, llm_workload,
                              stack_traces)
from repro.core.policies import PolicyKnobs, evaluate_batch

GOOD = llm_workload("llama3-8b", "decode", batch=8, n_chips=8, tp=8,
                    dp=1)


def _wl(op, name="bad-wl"):
    return Workload(name, "decode", (Op("warmup", flops_vu=1e6), op))


@pytest.mark.parametrize("field,value,kind", [
    ("flops_sa", -1.0, "negative"),
    ("flops_vu", float("nan"), "non-finite"),
    ("bytes_hbm", float("inf"), "non-finite"),
    ("bytes_ici", -3.5, "negative"),
    ("count", -2, "negative"),
])
def test_compile_trace_rejects_bad_carriers(field, value, kind):
    wl = _wl(Op("evil", **{field: value}))
    with pytest.raises(ValueError) as e:
        compile_trace(wl)
    msg = str(e.value)
    assert "bad-wl" in msg and "evil" in msg
    assert field in msg and kind in msg


def test_compile_trace_rejects_zero_matmul_dims():
    wl = _wl(Op("mm", flops_sa=1e9, matmul_dims=(128, 0, 128)))
    with pytest.raises(ValueError, match="matmul_dims"):
        compile_trace(wl)


def test_stack_traces_rejects_non_workload():
    with pytest.raises(ValueError, match="index 1"):
        stack_traces([GOOD, {"not": "a workload"}])


def test_stack_traces_rejects_malformed_member():
    with pytest.raises(ValueError, match="bad-wl"):
        stack_traces([GOOD, _wl(Op("evil", bytes_hbm=-1.0))])


@pytest.mark.parametrize("knob,field", [
    (PolicyKnobs(delay_scale=0.0), "delay_scale"),
    (PolicyKnobs(delay_scale=float("nan")), "delay_scale"),
    (PolicyKnobs(window_scale=0.0), "window_scale"),
    (PolicyKnobs(window_scale=-1.0), "window_scale"),
    (PolicyKnobs(window_scale=float("nan")), "window_scale"),
    (PolicyKnobs(leak_off_logic=-0.1), "leak_off_logic"),
    (PolicyKnobs(leak_sram_sleep=float("inf")), "leak_sram_sleep"),
    (PolicyKnobs(sa_width=0), "sa_width"),
])
def test_evaluate_batch_rejects_bad_knobs(knob, field):
    grid = (PolicyKnobs(), knob)
    with pytest.raises(ValueError) as e:
        evaluate_batch([GOOD], ("NPU-D",), ("ReGate-HW",), grid)
    assert field in str(e.value)
    assert "knob 1" in str(e.value)


def test_good_grid_still_passes():
    res = evaluate_batch(
        [GOOD], ("NPU-D",), ("ReGate-HW",),
        (PolicyKnobs(window_scale=0.5, delay_scale=2.0, sa_width=64),),
        backend="numpy")
    assert np.isfinite(res.runtime_s).all()


# --------------------------------------------------------------------------
# chaos plane (ISSUE 8): fault specs, timelines, governor, fleet knobs
# --------------------------------------------------------------------------

from repro.core.faults import (ChipFaultSpec, FaultSpec, FaultTimeline,
                               LinkFaultSpec, build_fault_timeline,
                               fault_plan)


@pytest.mark.parametrize("kwargs,field", [
    ({"mtbf_epochs": 0.0}, "mtbf_epochs"),
    ({"mtbf_epochs": -5.0}, "mtbf_epochs"),
    ({"mtbf_epochs": float("nan")}, "mtbf_epochs"),
    ({"repair_epochs": 0}, "repair_epochs"),
    ({"repair_epochs": 2.5}, "repair_epochs"),
    ({"drain_every": -1}, "drain_every"),
    ({"drain_frac": -0.1}, "drain_frac"),
    ({"drain_frac": 1.5}, "drain_frac"),
    ({"drain_epochs": 0}, "drain_epochs"),
    ({"pg_fault_prob": float("inf")}, "pg_fault_prob"),
    ({"pg_fault_prob": -0.2}, "pg_fault_prob"),
])
def test_chip_fault_spec_rejects_bad_params(kwargs, field):
    with pytest.raises(ValueError, match=field):
        ChipFaultSpec(**kwargs)


@pytest.mark.parametrize("kwargs,field", [
    ({"flap_prob": -0.1}, "flap_prob"),
    ({"flap_prob": 1.1}, "flap_prob"),
    ({"flap_epochs": 0}, "flap_epochs"),
    ({"degrade_prob": float("nan")}, "degrade_prob"),
    ({"degrade_rate": 0.0}, "degrade_rate"),
    ({"degrade_rate": 1.0}, "degrade_rate"),
    ({"degrade_rate": -0.5}, "degrade_rate"),
    ({"degrade_epochs": -2}, "degrade_epochs"),
    ({"down_prob": 2.0}, "down_prob"),
    ({"down_epochs": 0}, "down_epochs"),
])
def test_link_fault_spec_rejects_bad_params(kwargs, field):
    with pytest.raises(ValueError, match=field):
        LinkFaultSpec(**kwargs)


def test_fault_spec_rejects_wrong_member_types():
    with pytest.raises(ValueError, match="ChipFaultSpec"):
        FaultSpec(chip={"mtbf_epochs": 10})
    with pytest.raises(ValueError, match="LinkFaultSpec"):
        FaultSpec(link=0.5)


def test_fault_plan_rejects_bad_severity():
    for bad in (-0.5, float("nan"), float("inf"), "high"):
        with pytest.raises(ValueError, match="severity"):
            fault_plan(bad)


@pytest.mark.parametrize("seed", [
    None, 1.5, "zero", (), (1, 2.5), True, np.random.default_rng(0),
])
def test_build_timeline_rejects_bad_seed(seed):
    with pytest.raises(ValueError, match="seed"):
        build_fault_timeline(FaultSpec(), n_epochs=4, n_chips=2,
                             seed=seed)


def test_build_timeline_rejects_bad_dims():
    with pytest.raises(ValueError, match="n_epochs"):
        build_fault_timeline(FaultSpec(), n_epochs=0, n_chips=2)
    with pytest.raises(ValueError, match="n_chips"):
        build_fault_timeline(FaultSpec(), n_epochs=4, n_chips=0)
    with pytest.raises(ValueError, match="n_links"):
        build_fault_timeline(FaultSpec(), n_epochs=4, n_chips=2,
                             n_links=-1)
    with pytest.raises(ValueError, match="FaultSpec"):
        build_fault_timeline(None, n_epochs=4, n_chips=2)


def test_fault_timeline_rejects_inconsistent_arrays():
    ok = FaultTimeline.empty(4, 8, 2)
    with pytest.raises(ValueError, match="chips_down"):
        FaultTimeline(4, 8, 2, chips_down=np.zeros(3, np.int64),
                      link_rates=ok.link_rates, pg_fault=ok.pg_fault,
                      severity_hint=ok.severity_hint)
    with pytest.raises(ValueError, match="chips_down"):
        FaultTimeline(4, 8, 2,
                      chips_down=np.full(4, 9, np.int64),  # > n_chips
                      link_rates=ok.link_rates, pg_fault=ok.pg_fault,
                      severity_hint=ok.severity_hint)
    with pytest.raises(ValueError, match="link_rates"):
        FaultTimeline(4, 8, 2, chips_down=ok.chips_down,
                      link_rates=np.full((4, 2), 1.5),
                      pg_fault=ok.pg_fault,
                      severity_hint=ok.severity_hint)
    with pytest.raises(ValueError, match="link_rates"):
        FaultTimeline(4, 8, 2, chips_down=ok.chips_down,
                      link_rates=np.ones((4, 3)),
                      pg_fault=ok.pg_fault,
                      severity_hint=ok.severity_hint)
    with pytest.raises(ValueError, match="pg_fault"):
        FaultTimeline(4, 8, 2, chips_down=ok.chips_down,
                      link_rates=ok.link_rates,
                      pg_fault=np.zeros(4, np.int64),
                      severity_hint=ok.severity_hint)
    with pytest.raises(ValueError, match="severity_hint"):
        FaultTimeline(4, 8, 2, chips_down=ok.chips_down,
                      link_rates=ok.link_rates, pg_fault=ok.pg_fault,
                      severity_hint=np.full(4, -1.0))


def test_fault_severity_rejects_bad_inputs():
    from repro.core.perturb import fault_severity
    with pytest.raises(ValueError, match="chip_down_frac"):
        fault_severity(-0.1)
    with pytest.raises(ValueError, match="chip_down_frac"):
        fault_severity(float("nan"))
    with pytest.raises(ValueError, match="link_rates"):
        fault_severity(0.0, link_rates=[1.0, 2.0])


@pytest.mark.parametrize("kwargs,field", [
    ({"cooldown_epochs": -1}, "cooldown_epochs"),
    ({"cooldown_epochs": 1.5}, "cooldown_epochs"),
    ({"min_improvement": -0.1}, "min_improvement"),
    ({"min_improvement": 1.0}, "min_improvement"),
    ({"backoff_base": 0.5}, "backoff_base"),
    ({"backoff_base": float("nan")}, "backoff_base"),
    ({"backoff_cap": 0}, "backoff_cap"),
])
def test_hysteresis_rejects_bad_params(kwargs, field):
    from repro.core.slo import Hysteresis
    with pytest.raises(ValueError, match=field):
        Hysteresis(**kwargs)


def _tiny_scenario(**kw):
    from repro.core.fleet import ArrivalSpec, FleetScenario, WorkloadClass
    from repro.core.opgen import llm_workload
    cls = WorkloadClass(
        "d", llm_workload("llama3-8b", "decode", batch=8),
        ArrivalSpec("poisson", rate_rps=1.0), requests_per_invocation=8)
    base = dict(classes=(cls,), n_chips=8, npu="NPU-D",
                policies=("NoPG",), duration_s=1800.0, epoch_s=900.0,
                seed=0)
    base.update(kw)
    return FleetScenario(**base)


def test_fleet_scenario_rejects_bad_shed_backlog():
    for bad in (0.0, -2.0, float("nan")):
        with pytest.raises(ValueError, match="shed_backlog_x"):
            _tiny_scenario(shed_backlog_x=bad)


def test_arrival_spec_rejects_negative_rate():
    from repro.core.fleet import ArrivalSpec
    with pytest.raises(ValueError, match="rate_rps"):
        ArrivalSpec("poisson", rate_rps=-1.0)
    with pytest.raises(ValueError, match="rate_rps"):
        ArrivalSpec("bursty", rate_rps=float("inf"))


def test_sweep_fleet_rejects_mismatched_faults_and_hysteresis():
    from repro.core.fleet import sweep_fleet
    sc = _tiny_scenario()
    with pytest.raises(ValueError, match="FaultTimeline"):
        sweep_fleet(sc, None, faults="chaos")
    with pytest.raises(ValueError, match="epochs"):
        sweep_fleet(sc, None,
                    faults=FaultTimeline.empty(5, sc.n_chips))
    with pytest.raises(ValueError, match="chips"):
        sweep_fleet(sc, None, faults=FaultTimeline.empty(2, 4))
    with pytest.raises(ValueError, match="Hysteresis"):
        sweep_fleet(sc, None, hysteresis=0.5)


# --------------------------------------------------------------------------
# guard plane (ISSUE 9): policy, manifest, checkpoint + entry-point args
# --------------------------------------------------------------------------

from repro.core.guard import (CampaignCheckpoint, GuardPolicy,
                              GuardedRunner, RunManifest)


@pytest.mark.parametrize("kwargs,field", [
    ({"timeout_s": 0.0}, "timeout_s"),
    ({"timeout_s": -1.0}, "timeout_s"),
    ({"timeout_s": float("nan")}, "timeout_s"),
    ({"timeout_s": True}, "timeout_s"),
    ({"max_retries": -1}, "max_retries"),
    ({"max_retries": 1.5}, "max_retries"),
    ({"backoff_base_s": 0.0}, "backoff_base_s"),
    ({"backoff_base_s": float("inf")}, "backoff_base_s"),
    ({"backoff_factor": 0.5}, "backoff_factor"),
    ({"backoff_factor": float("nan")}, "backoff_factor"),
    ({"backoff_jitter": -0.1}, "backoff_jitter"),
    ({"backoff_jitter": 1.0}, "backoff_jitter"),
    ({"oracle_tol": 0.0}, "oracle_tol"),
    ({"oracle_tol": float("inf")}, "oracle_tol"),
    ({"checkpoint_every": 0}, "checkpoint_every"),
    ({"checkpoint_every": 2.5}, "checkpoint_every"),
])
def test_guard_policy_rejects_bad_params(kwargs, field):
    with pytest.raises(ValueError, match=field):
        GuardPolicy(**kwargs)


_MANIFEST = dict(kind="fleet", seed=1, n_epochs=4, backend="numpy",
                 knob_digest="k", scenario_digest="s")


@pytest.mark.parametrize("kwargs,field", [
    ({"kind": ""}, "kind"),
    ({"kind": 3}, "kind"),
    ({"seed": 1.5}, "seed"),
    ({"seed": True}, "seed"),
    ({"n_epochs": 0}, "n_epochs"),
    ({"backend": ""}, "backend"),
    ({"knob_digest": ""}, "knob_digest"),
    ({"scenario_digest": None}, "scenario_digest"),
])
def test_run_manifest_rejects_bad_fields(kwargs, field):
    with pytest.raises(ValueError, match=field):
        RunManifest(**{**_MANIFEST, **kwargs})


def test_campaign_checkpoint_rejects_bad_args(tmp_path):
    m = RunManifest(**_MANIFEST)
    with pytest.raises(ValueError, match="directory path"):
        CampaignCheckpoint(42, m)
    with pytest.raises(ValueError, match="RunManifest"):
        CampaignCheckpoint(str(tmp_path), {"kind": "fleet"})
    with pytest.raises(ValueError, match="keep"):
        CampaignCheckpoint(str(tmp_path), m, keep=0)


def test_guarded_runner_rejects_bad_policy_and_rungs():
    with pytest.raises(ValueError, match="GuardPolicy"):
        GuardedRunner("strict")
    with pytest.raises(ValueError, match="rungs"):
        GuardedRunner(GuardPolicy(), rungs=())


def test_sweep_fleet_rejects_bad_guard_args(tmp_path):
    from repro.core.fleet import sweep_fleet
    sc = _tiny_scenario()
    with pytest.raises(ValueError, match="GuardPolicy"):
        sweep_fleet(sc, None, guard="strict")
    with pytest.raises(ValueError, match="directory path"):
        sweep_fleet(sc, None, checkpoint=7)
    with pytest.raises(ValueError, match="keep_epoch_inputs"):
        sweep_fleet(sc, None, checkpoint=str(tmp_path / "ck"),
                    keep_epoch_inputs=True)


def test_sweep_chaos_rejects_bad_checkpoint():
    from repro.core.fleet import sweep_chaos
    with pytest.raises(ValueError, match="directory path"):
        sweep_chaos(_tiny_scenario(), None, checkpoint=7)


def test_session_rejects_bad_guard():
    from repro.core.session import SweepSession
    with pytest.raises(ValueError, match="GuardPolicy"):
        SweepSession(guard="paranoid")
