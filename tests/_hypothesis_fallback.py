"""Degraded stand-in for ``hypothesis`` so the suite collects everywhere.

When hypothesis is installed the test modules import the real thing; when
it is missing they fall back to these shims, which turn every
``@given``-decorated property test into a ``pytest.skip`` instead of a
collection error. Strategy constructors accept anything and return None —
they are only ever passed back into ``given``.
"""
import pytest


class _Strategies:
    def __getattr__(self, name):
        def _strategy(*args, **kwargs):
            return None
        return _strategy


st = _Strategies()


def given(*args, **kwargs):
    def deco(fn):
        return pytest.mark.skip(reason="hypothesis not installed")(fn)
    return deco


def settings(*args, **kwargs):
    def deco(fn):
        return fn
    return deco
