"""Jitter-plane perturbation engine + differential fuzz (ISSUE 6).

Covers the determinism contract (same Generator seed -> bit-identical
perturbed traces), the conservation invariants of each transform, the
severity axis (0 = exact identity), the perturbed-stack sweep
equivalence (numpy batched vs scalar oracle; jax vs numpy when jax is
present), and the >= 200-program EventTimeline-vs-VLIWTimeline
differential fuzz harness.
"""
import dataclasses

import numpy as np
import pytest

from repro.core.isa import (EventTimeline, Instr, PMode, VLIWTimeline,
                            expand_events, merge_events, setpm)
from repro.core.opgen import dlrm_workload, llm_workload
from repro.core.perturb import (FUZZ_KW, BurstCompression, ClockJitter,
                                IdleFragmentation, LinkDegradation,
                                Straggler, adversarial_events,
                                differential_fuzz, perturb_suite,
                                perturb_workload, severity_plan)
from repro.core.policies import PolicyKnobs, evaluate, evaluate_batch

from _sweep_equiv import rel

WL = llm_workload("llama3-8b", "decode", batch=8, n_chips=8, tp=8, dp=1)
PLAN = severity_plan(1.0)


def _cols(wl):
    return {
        "flops_sa": np.array([o.flops_sa for o in wl.ops]),
        "flops_vu": np.array([o.flops_vu for o in wl.ops]),
        "bytes_hbm": np.array([o.bytes_hbm for o in wl.ops]),
        "bytes_ici": np.array([o.bytes_ici for o in wl.ops]),
        "count": np.array([float(o.count) for o in wl.ops]),
    }


# ---------------------------------------------------------------- determinism

@pytest.mark.parametrize("seed", [0, 7, 1234])
def test_perturb_deterministic_under_fixed_seed(seed):
    a = perturb_workload(WL, PLAN, np.random.default_rng(seed))
    b = perturb_workload(WL, PLAN, np.random.default_rng(seed))
    assert a.ops == b.ops  # Op is a frozen dataclass: exact equality
    c = perturb_workload(WL, PLAN, np.random.default_rng(seed + 1))
    assert a.ops != c.ops


def test_perturb_suite_order_stable():
    wls = [WL, dlrm_workload("S"), dlrm_workload("M")]
    full = perturb_suite(wls, PLAN, seed=3)
    # dropping workload 1 must not change workload 2's perturbation ...
    assert perturb_suite([wls[0], wls[2]], PLAN, seed=3)[0].ops \
        == full[0].ops
    # ... because child generators key on (seed, stream, index)
    assert perturb_suite(wls, PLAN, seed=3, stream=1)[0].ops \
        != full[0].ops


def test_perturb_requires_explicit_generator():
    with pytest.raises(TypeError, match="Generator"):
        perturb_workload(WL, PLAN, 1234)
    with pytest.raises(TypeError, match="Generator"):
        BurstCompression().apply(_cols(WL), np.random.RandomState(0))


# --------------------------------------------------------------- conservation

def test_severity_zero_is_exact_identity():
    assert severity_plan(0.0) == ()
    out = perturb_workload(WL, (), np.random.default_rng(0), name="x")
    assert out.name == "x"
    assert out.ops == WL.ops


def test_severity_plan_validates():
    with pytest.raises(ValueError):
        severity_plan(-0.5)
    with pytest.raises(ValueError):
        severity_plan(float("nan"))


def test_burst_compression_conserves_wire_bytes():
    # topology lowering turns each collective into a run of step ops —
    # the multi-op ICI-active runs burst compression acts on (pure
    # byte split: staging ops would break up the contiguous runs)
    from repro.core.ici_topology import lower_collectives
    wl = lower_collectives(WL, staging=False)
    cols = _cols(wl)
    total = (cols["bytes_ici"] * cols["count"]).sum()
    cols["collective"] = np.array([o.collective for o in wl.ops])
    out = BurstCompression(factor=3.0).apply(cols, np.random.default_rng(0))
    assert rel((out["bytes_ici"] * out["count"]).sum(), total) <= 1e-9
    # bursts are denser: strictly fewer ICI-active ops
    assert (out["bytes_ici"] > 0).sum() < sum(
        o.bytes_ici > 0 for o in wl.ops)


def test_idle_fragmentation_conserves_totals():
    wl = perturb_workload(WL, [IdleFragmentation(factor=8)],
                          np.random.default_rng(0))
    for f in ("flops_sa", "flops_vu", "bytes_hbm", "bytes_ici"):
        a = sum(getattr(o, f) * o.count for o in WL.ops)
        b = sum(getattr(o, f) * o.count for o in wl.ops)
        assert rel(a, b) <= 1e-9, f
    assert sum(o.count for o in wl.ops) > sum(o.count for o in WL.ops)


def test_transform_param_validation():
    for bad in (lambda: BurstCompression(factor=0.5),
                lambda: LinkDegradation(rate=0.0),
                lambda: LinkDegradation(rate=1.5),
                lambda: LinkDegradation(window_frac=0.0),
                lambda: Straggler(slowdown=0.9),
                lambda: Straggler(frac=1.5),
                lambda: IdleFragmentation(factor=0),
                lambda: IdleFragmentation(factor=2.5),
                lambda: ClockJitter(sigma=-0.1)):
        with pytest.raises(ValueError):
            bad()


def test_composition_draw_counts_fixed():
    """A no-op transform must still consume its rng draws, so a
    composed plan's downstream transforms see the same stream whether
    or not earlier ones fired."""
    plan_a = (Straggler(slowdown=1.0, frac=0.0), ClockJitter(sigma=0.02))
    plan_b = (Straggler(slowdown=2.0, frac=0.0), ClockJitter(sigma=0.02))
    a = perturb_workload(WL, plan_a, np.random.default_rng(5))
    b = perturb_workload(WL, plan_b, np.random.default_rng(5))
    assert a.ops == b.ops


# ----------------------------------------------- perturbed sweep equivalence

def test_perturbed_stack_numpy_matches_scalar_oracle():
    pert = perturb_suite([WL, dlrm_workload("S")], severity_plan(1.5),
                         seed=11)
    grid = (PolicyKnobs(window_scale=0.25), PolicyKnobs(),
            PolicyKnobs(window_scale=4.0, delay_scale=2.0))
    pols = ("ReGate-HW", "ReGate-Full", "NoPG")
    res = evaluate_batch(pert, ("NPU-D",), pols, grid, backend="numpy")
    for wi, wl in enumerate(pert):
        for pi, pol in enumerate(pols):
            for ki, kn in enumerate(grid):
                ref = evaluate(wl, "NPU-D", pol, kn)
                got = res.report(wi, 0, pi, ki)
                assert rel(ref.runtime_s, got.runtime_s) <= 1e-9
                assert rel(ref.total_j, got.total_j) <= 1e-9
                for c in ref.static_j:
                    assert rel(ref.static_j[c], got.static_j[c]) \
                        <= 1e-9, (wl.name, pol, ki, c)


def test_perturbed_stack_jax_matches_numpy():
    pytest.importorskip("jax")
    from repro.core.backend import get_backend
    bk = get_backend("jax")
    if bk._x64_ctx is None and not bk.x64_enabled():
        pytest.skip("this jax has no scoped x64 switch and "
                    "jax_enable_x64 is off")
    pert = perturb_suite([WL, dlrm_workload("S")], severity_plan(2.0),
                         seed=2)
    grid = (PolicyKnobs(window_scale=1 / 16), PolicyKnobs(),
            PolicyKnobs(window_scale=4.0))
    pols = ("ReGate-HW", "NoPG")
    bn = evaluate_batch(pert, ("NPU-C", "NPU-D"), pols, grid,
                        backend="numpy")
    bj = evaluate_batch(pert, ("NPU-C", "NPU-D"), pols, grid,
                        backend="jax")
    assert np.allclose(bn.runtime_s, bj.runtime_s, rtol=1e-9, atol=0)
    for c in bn.static_j:
        assert np.allclose(bn.static_j[c], bj.static_j[c],
                           rtol=1e-9, atol=1e-9), c
        assert np.allclose(bn.dynamic_j[c], bj.dynamic_j[c],
                           rtol=1e-9, atol=1e-9), c


# ------------------------------------------------------------------- fuzzing

def test_adversarial_events_are_canonical():
    events, horizon = adversarial_events(np.random.default_rng(0))
    cycles = [c for c, _ in events]
    assert cycles == sorted(cycles)
    assert len(set(cycles)) == len(cycles)  # merge_events collapsed dups
    assert horizon >= (cycles[-1] if cycles else 0)


def test_adversarial_events_deterministic():
    a, ha = adversarial_events(np.random.default_rng(42), n_events=30)
    b, hb = adversarial_events(np.random.default_rng(42), n_events=30)
    assert a == b and ha == hb


def test_differential_fuzz_200_programs():
    stats = differential_fuzz(200, seed=0)
    assert stats["programs"] == 200
    assert stats["mismatches"] == 0
    assert stats["runs"] == 400  # one per (program, hw_auto) pairing
    assert stats["events"] > 0 and stats["cycles"] > 0


def test_differential_fuzz_is_deterministic():
    a = differential_fuzz(10, seed=9)
    b = differential_fuzz(10, seed=9)
    assert a == b


def test_fuzz_detects_divergence():
    """The harness itself must fail loudly: corrupt one executor run
    by hand and check the mismatch formatter names the counter."""
    events, horizon = adversarial_events(np.random.default_rng(1))
    kw = dict(FUZZ_KW, hw_auto_gating=True,
              initial_modes=dict(FUZZ_KW["initial_modes"]))
    ref = VLIWTimeline(npu="NPU-D", **kw).run(
        expand_events(events, horizon))
    got = EventTimeline(npu="NPU-D", **kw).run(events, horizon=horizon)
    from repro.core.perturb import _exec_mismatch
    assert _exec_mismatch(ref, got) is None
    bad = dataclasses.replace(got, cycles=got.cycles + 1)
    assert "cycles" in _exec_mismatch(ref, bad)
