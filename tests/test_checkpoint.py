"""Checkpoint manager: roundtrip, atomicity, async, retention, elastic."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (8, 4)),
                       "b": jnp.zeros((4,))},
            "opt": {"m": jnp.ones((8, 4)) * 0.5,
                    "step": jnp.asarray(7, jnp.int32)}}


def test_roundtrip(tmp_path):
    cm = CheckpointManager(str(tmp_path))
    st = _state()
    cm.save(3, st, extras={"data_state": {"step": 3}}, blocking=True)
    restored, extras = cm.restore(_state(seed=9))
    assert extras["data_state"]["step"] == 3
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save_and_wait(tmp_path):
    cm = CheckpointManager(str(tmp_path))
    cm.save(1, _state(), blocking=False)
    cm.wait()
    assert cm.latest_step() == 1


def test_atomic_publish(tmp_path):
    """No .tmp directories survive a successful save."""
    cm = CheckpointManager(str(tmp_path))
    cm.save(5, _state(), blocking=True)
    names = os.listdir(tmp_path)
    assert "step_5" in names
    assert not any(n.endswith(".tmp") for n in names)
    assert os.path.exists(tmp_path / "step_5" / "manifest.json")


def test_retention_gc(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        cm.save(s, _state(), blocking=True)
    assert cm.all_steps() == [3, 4]


def test_restore_specific_step(tmp_path):
    cm = CheckpointManager(str(tmp_path))
    cm.save(1, _state(seed=1), blocking=True)
    cm.save(2, _state(seed=2), blocking=True)
    r1, _ = cm.restore(_state(), step=1)
    want = _state(seed=1)
    np.testing.assert_array_equal(np.asarray(r1["params"]["w"]),
                                  np.asarray(want["params"]["w"]))


def test_shape_mismatch_raises(tmp_path):
    cm = CheckpointManager(str(tmp_path))
    cm.save(1, _state(), blocking=True)
    bad = _state()
    bad["params"]["w"] = jnp.zeros((4, 4))
    with pytest.raises(ValueError, match="shape"):
        cm.restore(bad)


def test_elastic_resharding_restore(tmp_path):
    """Restore with explicit shardings (single-device here; the same path
    device_puts each leaf to its mesh placement on a pod)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((1,), ("data",))
    cm = CheckpointManager(str(tmp_path))
    st = _state()
    cm.save(1, st, blocking=True)
    shardings = jax.tree.map(lambda _: NamedSharding(mesh, P()), st)
    restored, _ = cm.restore(_state(seed=9), shardings=shardings)
    assert restored["params"]["w"].sharding == NamedSharding(mesh, P())
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(st["params"]["w"]))


def test_crash_mid_save_keeps_previous(tmp_path):
    """A leftover .tmp dir from a crashed save must not break restore."""
    cm = CheckpointManager(str(tmp_path))
    cm.save(1, _state(seed=1), blocking=True)
    os.makedirs(tmp_path / "step_2.tmp")  # simulated crash debris
    with open(tmp_path / "step_2.tmp" / "leaf_0.npy", "w") as f:
        f.write("garbage")
    assert cm.latest_step() == 1
    restored, _ = cm.restore(_state())
    np.testing.assert_array_equal(
        np.asarray(restored["params"]["w"]),
        np.asarray(_state(seed=1)["params"]["w"]))
