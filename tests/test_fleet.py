"""Fleet serving plane (ISSUE 7).

Pins the four acceptance properties of ``sweep_fleet`` — seeded
determinism (bit-identical reports), per-epoch equivalence with a
hand-built direct ``sweep_grid`` call on the same epoch inputs (≤1e-9),
the governor's SLO invariant (the chosen knob never violates the
relaxed SLO when a feasible knob exists), and the carbon roll-up
reconciling with the sum of per-record chip energies (≤1e-9) — plus the
arrival-generator contracts (fixed draw counts, diurnal shape, the
continuous-batching replay binning rule) and the scenario/allocation
edge cases.
"""
import math

import numpy as np
import pytest

from repro.core.carbon import (CARBON_INTENSITY, PUE, USD_PER_KWH,
                               fleet_rollup)
from repro.core.fleet import (ArrivalSpec, FleetScenario, WorkloadClass,
                              _allocate_chips, arrival_counts,
                              bin_requests, epoch_rates, sweep_fleet)
from repro.core.opgen import dlrm_workload, llm_workload
from repro.core.policies import KnobGrid, PolicyKnobs
from repro.core.sweep import sweep_grid

from _sweep_equiv import RTOL
from _sweep_equiv import rel as _rel

GRID = KnobGrid(window_scale=(0.5, 1.0, 2.0))


def _scenario(n_chips=48, rate=4.0, rank_rate=1.0, duration_s=3600.0,
              epoch_s=600.0, severity_levels=(0.0, 1.0),
              seed=11) -> FleetScenario:
    decode = WorkloadClass(
        "decode", llm_workload("llama3-8b", "decode", batch=8),
        ArrivalSpec("diurnal", rate_rps=rate, peak_frac=0.8,
                    period_s=duration_s),
        requests_per_invocation=8)
    rank = WorkloadClass(
        "rank", dlrm_workload("S"),
        ArrivalSpec("bursty", rate_rps=rank_rate, burst_prob=0.3,
                    burst_factor=6.0),
        requests_per_invocation=1024)
    return FleetScenario(
        classes=(decode, rank), n_chips=n_chips, npu="NPU-D",
        policies=("NoPG", "ReGate-Full"), duration_s=duration_s,
        epoch_s=epoch_s, slo_relax=1.15, seed=seed,
        severity_levels=severity_levels)


# --------------------------------------------------------------------------
# arrival generators
# --------------------------------------------------------------------------

def test_arrivals_deterministic_per_stream():
    """Same (spec, generator seed) → identical counts, and every
    stochastic kind honors the explicit-generator discipline. Trace
    isolation in composed scenarios comes from per-class generator
    streams — re-tuning one class's spec must not move another class's
    trace (tested end-to-end below via (seed, class_index) streams)."""
    for kind, kw in (("poisson", dict(rate_rps=7.0)),
                     ("diurnal", dict(rate_rps=7.0, peak_frac=2.0,
                                      period_s=720.0)),
                     ("bursty", dict(rate_rps=7.0, burst_prob=0.4,
                                     burst_factor=8.0))):
        spec = ArrivalSpec(kind, **kw)
        a = arrival_counts(spec, 12, 60.0, np.random.default_rng(5))
        b = arrival_counts(spec, 12, 60.0, np.random.default_rng(5))
        assert (a == b).all() and a.dtype == np.int64, kind
        c = arrival_counts(spec, 12, 60.0, np.random.default_rng(6))
        assert (a != c).any(), kind


def test_class_streams_isolated():
    """Changing one class's traffic spec leaves every other class's
    trace bit-identical: each class draws from its own
    (scenario.seed, class_index) generator."""
    sc1 = _scenario(rate=4.0)
    sc2 = _scenario(rate=32.0)   # only the first class's rate moves
    rank1 = [r["requests"] for r in sweep_fleet(sc1, None).records
             if r["class"] == "rank" and r["policy"] == "NoPG"]
    rank2 = [r["requests"] for r in sweep_fleet(sc2, None).records
             if r["class"] == "rank" and r["policy"] == "NoPG"]
    assert rank1 == rank2


def test_stochastic_kinds_require_generator():
    with pytest.raises(TypeError, match="explicit numpy.random"):
        arrival_counts(ArrivalSpec("poisson"), 4, 60.0)
    # replay consumes no randomness at all
    spec = ArrivalSpec("replay", times_s=(0.0, 10.0))
    got = arrival_counts(spec, 4, 60.0)
    assert got.tolist() == [1, 1, 0, 0]


def test_diurnal_rate_shape():
    spec = ArrivalSpec("diurnal", rate_rps=10.0, peak_frac=1.5,
                       period_s=240.0)
    rates = epoch_rates(spec, 8, 30.0)
    assert rates.shape == (8,) and (rates >= 0.0).all()
    assert rates.min() == 0.0          # peak_frac > 1 clips the trough
    assert rates.max() > 10.0          # and overshoots the mean at peak
    flat = epoch_rates(ArrivalSpec("poisson", rate_rps=3.0), 5, 60.0)
    assert (flat == 3.0).all()


def test_replay_binning_rule():
    """launch/serve.py continuous batching: join at the NEXT epoch
    boundary; exact-boundary arrivals join the epoch starting there;
    the final epoch clamps (no epoch e+1 to defer to)."""
    counts = bin_requests(np.array([0.0, 5.0, 10.0, 15.0, 35.0, 40.0]),
                          4, 10.0)
    #  t=0 -> e0 (boundary);  t=5 -> e1;  t=10 -> e1 (boundary);
    #  t=15 -> e2;  t=35 -> clamp e3;  t=40 -> clamp e3
    assert counts.tolist() == [1, 2, 1, 2]
    with pytest.raises(ValueError, match="finite"):
        bin_requests(np.array([-1.0]), 4, 10.0)
    with pytest.raises(ValueError, match="exceed"):
        bin_requests(np.array([41.0]), 4, 10.0)


def test_replay_binning_surfaces_clamped():
    """The final-epoch clamp used to be silent; with_clamped=True
    counts exactly the arrivals whose next-boundary rule pointed at or
    past the horizon (ISSUE 8 satellite — regression pin)."""
    times = np.array([0.0, 5.0, 10.0, 15.0, 35.0, 40.0])
    counts, clamped = bin_requests(times, 4, 10.0, with_clamped=True)
    # ceil(35/10)=4 and ceil(40/10)=4 both fold back into epoch 3
    assert counts.tolist() == [1, 2, 1, 2]
    assert clamped == 2
    # default return shape is unchanged (no tuple) and counts agree
    assert bin_requests(times, 4, 10.0).tolist() == counts.tolist()
    # a boundary arrival inside the window defers, not clamps
    _, c2 = bin_requests(np.array([30.0]), 4, 10.0, with_clamped=True)
    assert c2 == 0
    _, c3 = bin_requests(np.array([]), 4, 10.0, with_clamped=True)
    assert c3 == 0


def test_arrival_spec_validation():
    with pytest.raises(ValueError, match="unknown arrival kind"):
        ArrivalSpec("weibull")
    with pytest.raises(ValueError, match="times_s"):
        ArrivalSpec("replay")
    with pytest.raises(ValueError, match="rate_rps"):
        ArrivalSpec("poisson", rate_rps=-1.0)
    with pytest.raises(ValueError, match="burst_factor"):
        ArrivalSpec("bursty", burst_factor=0.5)
    with pytest.raises(ValueError, match="period_s"):
        ArrivalSpec("diurnal", period_s=0.0)


# --------------------------------------------------------------------------
# chip allocation
# --------------------------------------------------------------------------

def test_allocate_chips_no_starvation():
    """Proportional apportionment, but a positive-demand class is never
    starved to zero while chips remain — its queue would diverge no
    matter what knob the governor picked."""
    d = np.array([1e4, 1e-3, 0.0])
    a = _allocate_chips(100, d)
    assert a.sum() == 100 and a[1] >= 1 and a[2] == 0
    # fewer chips than positive classes: largest demands first
    assert _allocate_chips(1, d).tolist() == [1, 0, 0]
    # zero demand everywhere: nothing allocated
    assert _allocate_chips(10, np.zeros(3)).sum() == 0
    # exact proportionality when it divides evenly
    assert _allocate_chips(30, np.array([2.0, 1.0])).tolist() == [20, 10]


# --------------------------------------------------------------------------
# the simulator: determinism, equivalence, governor, carbon
# --------------------------------------------------------------------------

def test_report_bit_identical_under_seed():
    sc = _scenario()
    a, b = sweep_fleet(sc, GRID), sweep_fleet(sc, GRID)
    assert a.records == b.records
    assert a.epoch_summary == b.epoch_summary
    assert a.summary == b.summary
    assert a.severity_by_epoch == b.severity_by_epoch
    assert a.requests_total == b.requests_total
    # a different seed genuinely moves the arrivals
    c = sweep_fleet(_scenario(seed=12), GRID)
    assert c.requests_total != a.requests_total


def test_requests_total_matches_generators():
    """The report's arrival totals are exactly the per-class generator
    outputs under the documented (seed, class-index) streams."""
    sc = _scenario()
    total = 0
    for ci, cls in enumerate(sc.classes):
        rng = np.random.default_rng((sc.seed, ci))
        total += int(arrival_counts(cls.arrivals, sc.n_epochs,
                                    sc.epoch_s, rng).sum())
    assert sweep_fleet(sc, GRID).requests_total == total


def test_epoch_records_match_direct_sweep():
    """Each fleet epoch is ONE batched sweep call: replaying one
    epoch's inputs through a hand-built sweep_grid reproduces every
    fleet record's runtime and per-invocation energy ≤1e-9."""
    sc = _scenario(duration_s=1800.0, epoch_s=600.0)
    rep = sweep_fleet(sc, GRID, keep_epoch_inputs=True)
    assert len(rep.epoch_inputs) == rep.n_epochs
    for e, (wls, sev) in enumerate(rep.epoch_inputs):
        direct = sweep_grid(wls, npus=(rep.npu,),
                            policies=rep.policies, grid=GRID)
        by_cell = {(r["workload"], r["policy"], r["knob_idx"]): r
                   for r in direct}
        frecs = [r for r in rep.records if r["epoch"] == e]
        assert len(frecs) == len(sc.classes) * len(rep.policies)
        for fr in frecs:
            assert fr["severity"] == sev
            dr = by_cell[(fr["workload"], fr["policy"],
                          fr["knob_idx"])]
            assert _rel(fr["runtime_s"], dr["runtime_s"]) <= RTOL
            assert _rel(fr["inv_total_j"], dr["total_j"]) <= RTOL


def test_governor_never_violates_when_feasible():
    """The SLO invariant, exercised under genuine overload: a
    two-chip fleet saturated by its arrivals (queueing inflation pushes
    every knob past the bound) must violate, but a record with
    feasible_exists=True is NEVER violated — the governor always lands
    on a feasible knob when one exists."""
    sc = _scenario(n_chips=2, rate=650.0)
    rep = sweep_fleet(sc, GRID)
    assert all(not r["slo_violated"] for r in rep.records
               if r["feasible_exists"])
    assert any(r["slo_violated"] for r in rep.records)   # real overload
    assert all(0 <= r["knob_idx"] < GRID.size for r in rep.records)
    # a violated record had no feasible knob at all (contrapositive)
    assert all(not r["feasible_exists"] for r in rep.records
               if r["slo_violated"])
    # backlog carries: fleet-wide served never exceeds demand
    for r in rep.records:
        assert r["served_inv"] <= r["demand_inv"] + 1e-12
        assert _rel(r["backlog_inv"],
                    r["demand_inv"] - r["served_inv"]) <= 1e-9 \
            or abs(r["backlog_inv"]
                   - (r["demand_inv"] - r["served_inv"])) <= 1e-12


def test_governor_retunes_under_pressure():
    """Traffic jitter (severity variants) inflates the deployed
    energy-optimal knob's runtime past the relaxed SLO in busy epochs;
    the governor switches knobs — records flag it, summaries count
    it. (Queueing inflation alone rarely retunes: rho multiplies every
    knob's runtime alike, so all knobs cross the bound together; it is
    perturbation reshaping the *relative* knob runtimes that forces a
    switch, exactly the jitter-plane re-tune story.)"""
    decode = WorkloadClass(
        "decode", llm_workload("llama3-8b", "decode", batch=8),
        ArrivalSpec("diurnal", rate_rps=8.0, peak_frac=0.8,
                    period_s=3600.0),
        requests_per_invocation=8)
    rank = WorkloadClass(
        "rank", dlrm_workload("M"), ArrivalSpec("poisson", rate_rps=2.0),
        requests_per_invocation=1024)
    sc = FleetScenario(
        classes=(decode, rank), n_chips=48, npu="NPU-D",
        policies=("NoPG", "ReGate-Full"), duration_s=3600.0,
        epoch_s=600.0, slo_relax=1.2, seed=2,
        severity_levels=(0.0, 0.5, 1.0))
    rep = sweep_fleet(sc, KnobGrid(window_scale=(0.5, 1.0, 2.0),
                                   delay_scale=(1.0, 2.0)))
    retuned = [r for r in rep.records if r["retuned"]]
    assert retuned, "scenario failed to trigger any governor retune"
    for r in retuned:
        assert r["knob_idx"] != r["deployed_knob_idx"]
    for s in rep.summary:
        assert s["retunes"] == sum(1 for r in rep.records
                                   if r["policy"] == s["policy"]
                                   and r["retuned"])


def test_carbon_rollup_reconciles():
    sc = _scenario()
    rep = sweep_fleet(sc, GRID)
    for s in rep.summary:
        pol = s["policy"]
        recs = [r for r in rep.records if r["policy"] == pol]
        eps = [x for x in rep.epoch_summary if x["policy"] == pol]
        direct = math.fsum(r["total_j"] for r in recs) \
            + math.fsum(x["unallocated_idle_j"] for x in eps)
        assert _rel(s["total_j"], direct) <= RTOL
        assert _rel(s["busy_j"] + s["idle_j"], s["total_j"]) <= RTOL
        kwh = s["total_j"] / 3.6e6
        assert _rel(s["chip_kwh"], kwh) <= RTOL
        assert _rel(s["facility_kwh"], kwh * PUE) <= RTOL
        assert _rel(s["co2_kg"], kwh * PUE * CARBON_INTENSITY) <= RTOL
        assert _rel(s["cost_usd"], kwh * PUE * USD_PER_KWH) <= RTOL
        ru = rep.rollup(pol)
        assert ru.chip_kwh == s["chip_kwh"]
        assert ru.cost_usd == s["cost_usd"]
        # per-epoch summaries cover the same joules
        assert _rel(math.fsum(x["total_j"] for x in eps),
                    s["total_j"]) <= RTOL
    # gating saves fleet energy: ReGate-Full below NoPG
    nopg = rep.policy_summary("NoPG")["total_j"]
    full = rep.policy_summary("ReGate-Full")["total_j"]
    assert full < nopg
    with pytest.raises(ValueError):
        fleet_rollup(float("nan"))
    with pytest.raises(ValueError):
        fleet_rollup(-1.0)


def test_knob_grid_and_flat_tuple_agree():
    """sweep_fleet accepts KnobGrid / flat PolicyKnobs sequence / None
    with the same semantics as every other sweep entry point."""
    sc = _scenario(duration_s=1800.0, epoch_s=600.0)
    a = sweep_fleet(sc, GRID)
    b = sweep_fleet(sc, tuple(GRID.product()))
    assert a.records == b.records and a.summary == b.summary
    single = sweep_fleet(sc, None)
    assert all(r["knob_idx"] == 0 for r in single.records)
    assert all(r["window_scale"] == 1.0 for r in single.records)


def test_severity_tracks_demand():
    """Busier epochs draw harsher perturbation levels: the severity
    assignment is the demand quantile, and the variant names show up in
    the records' workload column."""
    sc = _scenario()
    rep = sweep_fleet(sc, GRID)
    assert set(rep.severity_by_epoch) <= set(sc.severity_levels)
    counts = np.array([r["requests"] for r in rep.records
                       if r["policy"] == rep.policies[0]
                       and r["class"] == "decode"])
    # single-level scenarios pin every epoch to that level
    flat = sweep_fleet(_scenario(severity_levels=(0.5,)), GRID)
    assert set(flat.severity_by_epoch) == {0.5}
    assert counts.shape == (rep.n_epochs,)


def test_scenario_validation():
    wl = llm_workload("llama3-8b", "decode", batch=8)
    cls = WorkloadClass("a", wl, ArrivalSpec("poisson"))
    with pytest.raises(ValueError, match="duplicate class names"):
        FleetScenario(classes=(cls, cls))
    with pytest.raises(ValueError, match="at least one class"):
        FleetScenario(classes=())
    with pytest.raises(ValueError, match="epoch_s"):
        FleetScenario(classes=(cls,), epoch_s=0.0)
    with pytest.raises(ValueError, match="at least one epoch"):
        FleetScenario(classes=(cls,), duration_s=1.0, epoch_s=900.0)
    with pytest.raises(ValueError, match="slo_relax"):
        FleetScenario(classes=(cls,), slo_relax=0.0)
    with pytest.raises(ValueError, match="severity_levels"):
        FleetScenario(classes=(cls,), severity_levels=())
    with pytest.raises(ValueError, match="requests_per_invocation"):
        WorkloadClass("b", wl, ArrivalSpec("poisson"),
                      requests_per_invocation=0.0)
